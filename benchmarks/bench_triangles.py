"""GraphChallenge triangle counting (the paper's named future-work item).

Times BOTH mxm formulations per scale so the `impl="auto"` policy can later
consume the crossover:

  dense   — C<A> = A (x) A_dense: masked plus_pair mxm against a densified
            B operand (the pre-SpGEMM formulation),
  spgemm  — C<A> = A (x) A via the BSR x BSR SpGEMM kernel (sparse output,
            block-wise mask).

Both are validated against the trace(A^3)/6 oracle; the summary row names
the first scale where SpGEMM wins (the dense-vs-SpGEMM crossover).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.algorithms import triangle_count
from repro.core import grb, semiring as S
from repro.core.grb import Descriptor
from repro.graph.datagen import rmat_edges
from repro.graph.graph import GraphBuilder

SCALES = (7, 8, 9, 10)
EDGE_FACTOR = 8


def _undirected_rmat(scale: int, seed: int = 7):
    src, dst, n = rmat_edges(scale=scale, edge_factor=EDGE_FACTOR, seed=seed)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    return GraphBuilder(n).add_edges("R", s, d).build(fmt="bsr", block=128)


def _count_dense(A: grb.GBMatrix) -> int:
    """The pre-SpGEMM formulation: densified B operand, dense masked C."""
    dense = A.to_dense()
    mask = (dense != 0).astype(jnp.int8)
    C = grb.mxm(A, dense, S.PLUS_PAIR, Descriptor(mask=mask))
    return int(jnp.sum(C) / 6)


def _time(fn):
    fn()                                  # warmup: exclude trace/compile time
    t0 = time.perf_counter()
    got = fn()
    return got, (time.perf_counter() - t0) * 1e6


def run(rows):
    crossover = None
    for scale in SCALES:
        g = _undirected_rmat(scale)
        A = g.relations["R"].A
        got_d, us_d = _time(lambda: _count_dense(A))
        got_s, us_s = _time(lambda: int(triangle_count(A)))
        D = np.asarray(A.to_dense()) != 0
        want = int(np.trace(D.astype(np.int64) @ D @ D) // 6)
        assert got_d == want, ("dense", scale, got_d, want)
        assert got_s == want, ("spgemm", scale, got_s, want)
        rows.append((f"triangles_dense_s{scale}", us_d, f"count={want}"))
        rows.append((f"triangles_spgemm_s{scale}", us_s,
                     f"count={want} speedup={us_d / max(us_s, 1e-9):.2f}x"))
        if crossover is None and us_s < us_d:
            crossover = scale
    rows.append(("triangles_crossover", 0.0,
                 f"spgemm_wins_from_scale={crossover}"
                 if crossover is not None else "spgemm_wins_from_scale=none"))
    return rows
