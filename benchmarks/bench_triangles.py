"""GraphChallenge triangle counting (the paper's named future-work item):
masked plus_pair mxm; validated against the trace(A^3)/6 oracle."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.algorithms import triangle_count
from repro.graph.datagen import rmat_edges
from repro.graph.graph import GraphBuilder


def run(rows):
    src, dst, n = rmat_edges(scale=10, edge_factor=8, seed=7)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    g = GraphBuilder(n).add_edges("R", s, d).build(fmt="bsr", block=128)
    A = g.relations["R"].A
    t0 = time.perf_counter()
    got = int(triangle_count(A))
    dt = time.perf_counter() - t0
    D = np.asarray(A.to_dense()) != 0
    want = int(np.trace(D.astype(np.int64) @ D @ D) // 6)
    assert got == want, (got, want)
    rows.append(("triangles_rmat_s10", dt * 1e6, f"count={got}"))
    return rows
