"""Algorithm breadth suite: batched multi-source centrality vs the naive
one-BFS-per-source race, packed vs unpacked sweep widths, BitELL vs ELL.

Three claims behind the `CALL algo.*` tentpole, each validated against the
reference answer before it is timed (a fast wrong sweep is worthless):

  betweenness — Brandes over F sources as ONE (n, F) columned sweep vs F
                single-source sweeps: the multi-source batching that also
                lets the query server coalesce many CALLs into one launch
                (AUTO_CENTRALITY_BATCH provenance, with calibrate.py's
                calibrate_centrality_batch as the host-drift check)
  closeness   — the same BFS batched wide enough for the word-resident
                packed route vs narrow sub-packing chunks: the 32-lanes-
                per-word frontier claim applied to centrality
  labelprop/closeness on BitELL vs ELL — the bit-packed adjacency cells:
                structural algorithms ride the word route on 1-bit edges

Rows land in BENCH_algos.json via `make bench-smoke`.
"""
from __future__ import annotations

import time

import numpy as np

from repro import algorithms as alg
from repro.core import grb
from repro.core.bitadj import BitELL
from repro.core.ell import ELL
from repro.graph.datagen import rmat_edges

SCALE = 8
EDGE_FACTOR = 8
SOURCES = 64


def _time(fn):
    fn()                                  # warmup: exclude trace/compile time
    t0 = time.perf_counter()
    got = fn()
    return got, (time.perf_counter() - t0) * 1e6


def _handles(scale: int):
    src, dst, n = rmat_edges(scale=scale, edge_factor=EDGE_FACTOR, seed=scale)
    s = np.concatenate([src, dst])        # symmetrize: undirected traversal
    d = np.concatenate([dst, src])
    key = s.astype(np.int64) * n + d
    _, idx = np.unique(key, return_index=True)
    s, d = s[idx], d[idx]
    keep = s != d
    s, d = s[keep], d[keep]
    e = grb.GBMatrix(ELL.from_coo(s, d, None, (n, n)))
    b = grb.GBMatrix(BitELL.from_coo(s, d, None, (n, n)))
    return e, b, n


def run(rows):
    e, b, n = _handles(SCALE)
    srcs = np.arange(SOURCES)

    # -- batched multi-source Brandes vs one-BFS-per-source -------------------
    batched, t_batch = _time(
        lambda: np.asarray(alg.betweenness(e, sources=srcs, batch=SOURCES)))
    solo, t_solo = _time(lambda: sum(
        np.asarray(alg.brandes_parts(e, [s]))[:, 0] for s in srcs))
    np.testing.assert_allclose(batched, solo, atol=1e-3, rtol=1e-4)
    rows.append((f"betweenness_batched_s{SCALE}_f{SOURCES}", t_batch,
                 f"speedup={t_solo / t_batch:.1f}x"))
    rows.append((f"betweenness_persource_s{SCALE}_f{SOURCES}", t_solo,
                 f"n={n}"))

    # -- packed (word-resident) vs unpacked closeness sweep -------------------
    packed, t_packed = _time(
        lambda: np.asarray(alg.closeness(e, sources=srcs, batch=SOURCES)))
    narrow, t_narrow = _time(
        lambda: np.asarray(alg.closeness(e, sources=srcs, batch=4)))
    np.testing.assert_array_equal(packed, narrow)
    rows.append((f"closeness_packed_s{SCALE}_f{SOURCES}", t_packed,
                 f"speedup={t_narrow / t_packed:.1f}x"))
    rows.append((f"closeness_narrow_s{SCALE}_f4chunks", t_narrow,
                 "below AUTO_PACK_MIN_WIDTH"))

    # -- BitELL vs ELL cells --------------------------------------------------
    cl_bit, t_bit = _time(
        lambda: np.asarray(alg.closeness(b, sources=srcs, batch=SOURCES)))
    np.testing.assert_array_equal(cl_bit, packed)
    rows.append((f"closeness_bitell_s{SCALE}_f{SOURCES}", t_bit,
                 f"vs_ell={t_packed / t_bit:.2f}x"))
    lp_ell, t_lp_ell = _time(lambda: np.asarray(alg.label_propagation(e)))
    lp_bit, t_lp_bit = _time(lambda: np.asarray(alg.label_propagation(b)))
    np.testing.assert_array_equal(lp_bit, lp_ell)
    rows.append((f"labelprop_bitell_s{SCALE}", t_lp_bit,
                 f"vs_ell={t_lp_ell / t_lp_bit:.2f}x"))
