"""Re-measure the AUTO_* crossover constants on this host (`make calibrate`).

Every `AUTO_*` policy constant in the tree was measured once on the
XLA-CPU reference host and committed with its provenance next to the
definition (`core.grb` for the format/packing crossovers, `core.delta` for
the compaction ratio). Hardware moves; this sweep re-runs each measurement
small-scale and prints

    constant,committed,measured,status

where ``status`` is ``ok`` when the measured crossover lands within one
sweep step of the committed value and ``drift`` otherwise. Drift is a
prompt to re-run the full calibrating benchmark named in the constant's
comment (bench_triangles / bench_khop.run_packed / bench_mutations) and
update the constant, never an error — exit code is always 0.

Criteria per constant:
  AUTO_MIN_GRID        first block-grid (block-rows) where the sparse
                       kernel formulation beats one dense matmul
  AUTO_MAX_FILL        first stored-tile fill where dense wins back
  AUTO_MIN_WIDTH       first B width where the sparse kernel wins
  AUTO_PACK_MIN_WIDTH  first frontier width where the packed boolean
                       route beats the float route
  AUTO_DELTA_COMPACT   first pending-ratio whose composed-read overhead
                       exceeds 1.2x the compacted read
  AUTO_BITADJ_MIN_FILL first occupied-tile fill where the bit-packed
                       adjacency (BitELL word route) beats the ELL or_and
                       traversal
  AUTO_BITADJ_MAX_SLOTS first widest-panel slot count where the ELL route
                       wins back (slot padding outgrows the bit payload)
  AUTO_CENTRALITY_BATCH first source-batch width where widening the
                       multi-source centrality sweep stops paying (per-
                       source time within 10% of the sweep's best)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSR, grb, ops, semiring as S
from repro.core.delta import AUTO_DELTA_COMPACT, DeltaMatrix
from repro.graph.datagen import rmat_edges, rmat_graph


def _timeit(fn, reps: int = 3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _sparse_pattern(n: int, nnz: int, seed: int):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, size=nnz), rng.integers(0, n, size=nnz)


def _bsr_vs_dense(n: int, nnz: int, f: int, seed: int = 0):
    """(t_sparse, t_dense) for one or_and traversal step, XLA paths only
    (the committed constants' provenance host is XLA-CPU)."""
    r, c = _sparse_pattern(n, nnz, seed)
    X = jnp.asarray((np.random.default_rng(seed + 1)
                     .uniform(size=(n, f)) < 0.05).astype(np.float32))
    bsr = BSR.from_coo(r, c, None, (n, n), block=128)
    dense = jnp.asarray(bsr.to_dense())
    fs = jax.jit(lambda x: ops.mxm(bsr, x, S.OR_AND))
    fd = jax.jit(lambda x: ops.mxm(dense, x, S.OR_AND))
    np.testing.assert_allclose(np.asarray(fs(X)), np.asarray(fd(X)))
    return (_timeit(lambda: np.asarray(fs(X))),
            _timeit(lambda: np.asarray(fd(X))),
            bsr.fill_ratio)


def _first(pairs, pred, default):
    for key, val in pairs:
        if pred(val):
            return key
    return default


def _status(committed, measured, steps) -> str:
    steps = sorted(steps)
    if measured == committed:
        return "ok"
    try:
        i, j = steps.index(committed), steps.index(measured)
        return "ok" if abs(i - j) <= 1 else "drift"
    except ValueError:
        return "drift"


def calibrate_min_grid(rows):
    sweep = []
    for nbr in (2, 4, 8):
        n = nbr * 128
        ts, td, _ = _bsr_vs_dense(n, nnz=2 * n, f=128, seed=nbr)
        sweep.append((nbr, ts < td))
    measured = _first(sweep, bool, default=16)
    rows.append(("AUTO_MIN_GRID", grb.AUTO_MIN_GRID, measured,
                 _status(grb.AUTO_MIN_GRID, measured, [s for s, _ in sweep])))


def calibrate_max_fill(rows):
    n = 8 * 128
    sweep = []
    for nnz in (2 * n, 16 * n, 64 * n, 256 * n):
        ts, td, fill = _bsr_vs_dense(n, nnz=nnz, f=128, seed=17)
        sweep.append((round(fill, 3), td < ts))
    measured = _first(sweep, bool, default=1.0)
    # committed 0.25 sits between sweep points; nearest-step tolerance
    steps = [s for s, _ in sweep] + [grb.AUTO_MAX_FILL]
    rows.append(("AUTO_MAX_FILL", grb.AUTO_MAX_FILL, measured,
                 _status(grb.AUTO_MAX_FILL, measured, steps)))


def calibrate_min_width(rows):
    n = 8 * 128
    sweep = []
    for f in (2, 4, 8, 16, 32):
        ts, td, _ = _bsr_vs_dense(n, nnz=2 * n, f=f, seed=23)
        sweep.append((f, ts < td))
    measured = _first(sweep, bool, default=64)
    rows.append(("AUTO_MIN_WIDTH", grb.AUTO_MIN_WIDTH, measured,
                 _status(grb.AUTO_MIN_WIDTH, measured, [s for s, _ in sweep])))


def calibrate_pack_min_width(rows):
    from repro import algorithms as alg
    g = rmat_graph(scale=8, edge_factor=8, seed=3, fmt="ell")
    rel = g.relations["KNOWS"]
    rng = np.random.default_rng(0)
    sweep = []
    for f in (1, 2, 4, 8, 16, 32):
        seeds = rng.integers(0, g.n, size=f)
        times = {}
        for mode in ("off", "on"):
            with grb.packed_frontiers(mode):
                fn = jax.jit(lambda s: alg.khop_counts(rel, s, k=2))
                times[mode] = _timeit(lambda: np.asarray(fn(seeds)))
        sweep.append((f, times["on"] < times["off"]))
    measured = _first(sweep, bool, default=64)
    rows.append(("AUTO_PACK_MIN_WIDTH", grb.AUTO_PACK_MIN_WIDTH, measured,
                 _status(grb.AUTO_PACK_MIN_WIDTH, measured,
                         [s for s, _ in sweep])))


def calibrate_delta_compact(rows):
    src, dst, n = rmat_edges(10, edge_factor=8, seed=11)
    keep = src != dst
    r, c = src[keep], dst[keep]
    base = grb.GBMatrix.from_coo(r, c, np.ones(len(r), np.float32),
                                 (n, n), fmt="ell")
    x = np.random.default_rng(0).random(n).astype(np.float32)
    compacted_t = _timeit(lambda: np.asarray(grb.mxv(base, x, S.PLUS_TIMES)))
    live = {(int(a), int(b)) for a, b in zip(r, c)}
    sweep = []
    for ratio in (0.02, 0.05, 0.1, 0.2):
        k = max(1, int(ratio * base.nvals))
        rng = np.random.default_rng(int(ratio * 100))
        ops_ = []
        while len(ops_) < k:
            a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
            if a != b and (a, b) not in live:
                ops_.append(("add", a, b, 1.0))
        dm = DeltaMatrix.wrap(base.store).apply_ops(ops_)
        h = grb.GBMatrix(dm)
        dm.patch()
        delta_t = _timeit(lambda: np.asarray(grb.mxv(h, x, S.PLUS_TIMES)))
        sweep.append((ratio, delta_t / compacted_t > 1.2))
    measured = _first(sweep, bool, default=1.0)
    rows.append(("AUTO_DELTA_COMPACT", AUTO_DELTA_COMPACT, measured,
                 _status(AUTO_DELTA_COMPACT, measured, [s for s, _ in sweep])))


def _bitadj_vs_ell(r, c, n, f: int = 64, seed: int = 0):
    """(t_bit, t_ell) for one or_and mxm on the same boolean structure."""
    from repro.core.bitadj import BitELL
    from repro.core.ell import ELL

    hb = grb.GBMatrix(BitELL.from_coo(r, c, None, (n, n)))
    he = grb.GBMatrix(ELL.from_coo(r, c, None, (n, n)))
    X = jnp.asarray((np.random.default_rng(seed + 1)
                     .uniform(size=(n, f)) < 0.1).astype(np.float32))
    fb = jax.jit(lambda x: grb.mxm(hb, x, S.OR_AND))
    fe = jax.jit(lambda x: grb.mxm(he, x, S.OR_AND))
    np.testing.assert_array_equal(np.asarray(fb(X)), np.asarray(fe(X)))
    return (_timeit(lambda: np.asarray(fb(X))),
            _timeit(lambda: np.asarray(fe(X))))


def calibrate_bitadj_fill(rows):
    from repro.core import bitadj
    n = 64 * 32
    rng = np.random.default_rng(5)
    sweep = []
    # edges clustered into a fixed set of tiles: the tile count holds the
    # slot geometry steady while edges-per-tile sweeps the fill axis
    tiles = rng.integers(0, (n // 32) ** 2, size=n // 2)
    for per_tile in (2, 8, 32, 128):
        t = np.repeat(tiles, per_tile)
        lr = rng.integers(0, 32, size=t.size)
        lc = rng.integers(0, 32, size=t.size)
        r = (t // (n // 32)) * 32 + lr
        c = (t % (n // 32)) * 32 + lc
        fill, _ = bitadj._tile_stats(r, c, (n, n))
        tb, te = _bitadj_vs_ell(r, c, n, seed=per_tile)
        sweep.append((round(fill, 3), tb < te))
    measured = _first(sweep, bool, default=1.0)
    steps = [s for s, _ in sweep] + [bitadj.AUTO_BITADJ_MIN_FILL]
    rows.append(("AUTO_BITADJ_MIN_FILL", bitadj.AUTO_BITADJ_MIN_FILL,
                 measured,
                 _status(bitadj.AUTO_BITADJ_MIN_FILL, measured, steps)))


def calibrate_bitadj_slots(rows):
    from repro.core import bitadj
    n = 256 * 32                 # column-tile grid wide enough for the sweep
    rng = np.random.default_rng(7)
    sweep = []
    # a dense-ish body plus one hub panel whose occupied column tiles sweep
    # the slot axis: every panel pads to the hub's width
    body_r = rng.integers(0, n, size=8 * n)
    body_c = (body_r + rng.integers(1, 64, size=8 * n)) % n
    for slots in (16, 64, 128, 256):
        hub_c = rng.integers(0, slots * 32, size=slots * 4)
        r = np.concatenate([body_r, np.zeros_like(hub_c)])
        c = np.concatenate([body_c, hub_c])
        _, got_slots = bitadj._tile_stats(r, c, (n, n))
        tb, te = _bitadj_vs_ell(r, c, n, seed=slots)
        sweep.append((got_slots, te < tb))
    measured = _first(sweep, bool, default=1024)
    steps = [s for s, _ in sweep] + [bitadj.AUTO_BITADJ_MAX_SLOTS]
    rows.append(("AUTO_BITADJ_MAX_SLOTS", bitadj.AUTO_BITADJ_MAX_SLOTS,
                 measured,
                 _status(bitadj.AUTO_BITADJ_MAX_SLOTS, measured, steps)))


def calibrate_centrality_batch(rows):
    from repro import algorithms as alg
    from repro.algorithms import centrality
    g = rmat_graph(scale=8, edge_factor=8, seed=9, fmt="ell")
    rel = g.relations["KNOWS"]
    srcs = np.arange(g.n)
    widths = (16, 32, 64, 128, 256)
    times = [_timeit(lambda: np.asarray(
        alg.closeness(rel, sources=srcs, batch=w)), reps=1) for w in widths]
    best = min(times)
    # the crossover is diminishing returns, not a winner flip: take the
    # first width already within 10% of the sweep's best per-source time
    sweep = [(w, t <= 1.1 * best) for w, t in zip(widths, times)]
    measured = _first(sweep, bool, default=widths[-1])
    rows.append(("AUTO_CENTRALITY_BATCH", centrality.AUTO_CENTRALITY_BATCH,
                 measured,
                 _status(centrality.AUTO_CENTRALITY_BATCH, measured, widths)))


def main() -> None:
    rows: list = []
    calibrate_min_grid(rows)
    calibrate_max_fill(rows)
    calibrate_min_width(rows)
    calibrate_pack_min_width(rows)
    calibrate_delta_compact(rows)
    calibrate_bitadj_fill(rows)
    calibrate_bitadj_slots(rows)
    calibrate_centrality_batch(rows)
    print("constant,committed,measured,status")
    drifted = [r for r in rows if r[3] == "drift"]
    for name, committed, measured, status in rows:
        print(f"{name},{committed},{measured},{status}")
    if drifted:
        print(f"# {len(drifted)} constant(s) drifted on this host — re-run "
              f"the full calibrating benchmark named beside each constant "
              f"before editing it")


if __name__ == "__main__":
    main()
