"""Bit-packed adjacency (BitELL) vs the float ELL route: memory + speed.

The storage claim: a boolean adjacency spends 32 words on a 32x32 edge tile
(4 B per potential edge -> 1 *bit*), so anywhere tiles are reasonably filled
the structural payload undercuts ELL's ~9 B/edge and the or_and traversal
moves words instead of floats. Three measurements per RMAT scale:

  payload    — resident adjacency bytes, BitELL vs ELL vs dense float
  triangles  — AND + popcount over tile pairs vs the masked plus_pair mxm
  bfs        — packed-frontier BFS on the bit route vs the ELL route

Every speed row is validated bit-identical against the ELL result first —
a fast wrong kernel is worthless. Rows land in BENCH_bitadj.json via
`make bench-smoke`; the AUTO_BITADJ_* constants this suite informs are
re-checked host-side by `make calibrate`.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro import algorithms as alg
from repro.algorithms import triangle_count
from repro.core import grb
from repro.core.bitadj import BitELL
from repro.core.ell import ELL
from repro.graph.datagen import rmat_edges

SCALES = (7, 8, 9)
EDGE_FACTOR = 8


def _time(fn):
    fn()                                  # warmup: exclude trace/compile time
    t0 = time.perf_counter()
    got = fn()
    return got, (time.perf_counter() - t0) * 1e6


def _pair(scale: int):
    src, dst, n = rmat_edges(scale=scale, edge_factor=EDGE_FACTOR, seed=scale)
    s = np.concatenate([src, dst])        # symmetrize: undirected traversal
    d = np.concatenate([dst, src])
    key = s.astype(np.int64) * n + d
    _, idx = np.unique(key, return_index=True)
    s, d = s[idx], d[idx]
    e = ELL.from_coo(s, d, None, (n, n))
    b = BitELL.from_coo(s, d, None, (n, n))
    return grb.GBMatrix(e), grb.GBMatrix(b), n


def _ell_bytes(e: ELL) -> int:
    return int(e.indices.nbytes + e.mask.nbytes + e.values.nbytes)


def run(rows):
    rng = np.random.default_rng(0)
    for scale in SCALES:
        he, hb, n = _pair(scale)
        bit_b = hb.store.payload_bytes
        ell_b = _ell_bytes(he.store)
        dense_b = n * n * 4
        rows.append((f"bitadj_payload_s{scale}", 0.0,
                     f"bit={bit_b}B ell={ell_b}B dense={dense_b}B "
                     f"vs_ell={ell_b / max(bit_b, 1):.2f}x"))

        want_t, us_e = _time(lambda: int(np.asarray(triangle_count(he))))
        got_t, us_b = _time(lambda: int(np.asarray(triangle_count(hb))))
        assert got_t == want_t, (scale, got_t, want_t)
        rows.append((f"bitadj_triangles_s{scale}", us_b,
                     f"count={got_t} ell_us={us_e:.0f} "
                     f"speedup={us_e / max(us_b, 1e-9):.2f}x"))

        seeds = rng.integers(0, n, size=64)
        with grb.packed_frontiers("on"):
            want_l, us_e = _time(lambda: np.asarray(alg.bfs_levels(he, seeds)))
            got_l, us_b = _time(lambda: np.asarray(alg.bfs_levels(hb, seeds)))
        np.testing.assert_array_equal(got_l, want_l)
        rows.append((f"bitadj_bfs_s{scale}", us_b,
                     f"ell_us={us_e:.0f} "
                     f"speedup={us_e / max(us_b, 1e-9):.2f}x"))
    return rows
