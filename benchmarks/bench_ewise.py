"""Mesh/device-resident element-wise microbench (docs §Transfer-accounting).

Two comparisons, both on the paths this repo keeps off the host:

  bsr_*   — BSR union/intersect/mask through the Pallas gathered-tile
            kernel vs the XLA gather reference vs the pre-refactor host
            round-trip (pull every tile to numpy, merge there, reassemble
            through `BSR.from_blocks`). The derived column carries the
            speedup over the host baseline and the host-numpy call count
            per call (device paths: 0).
  shard_* — shard-local slot-aligned ewise on identically-meshed
            ShardedELL operands vs the gather oracle (to_ell both sides,
            merge on host, redistribute). Only runs with >= 2 local
            devices (`REPRO_FORCE_DEVICES=8` matches the dist suite); the
            derived column carries `grb.host_transfers()` per call —
            shard-local: 0, gather oracle: 2.

CPU timings are indicative (interpret-mode Pallas); the structural claims —
zero host-numpy calls, zero host transfers — hold on any backend.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import bsr as bsrmod, grb, semiring as S
from repro.core.bsr import BSR
from repro.core.shard import ShardedELL
from repro.kernels import ops as kops

_ADD = lambda a, b: a + b                                  # noqa: E731
_MUL = lambda a, b: a * b                                  # noqa: E731


def _timeit(fn, reps: int = 3) -> float:
    fn()                                    # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _pattern(n: int, seed: int, density: float = 0.08) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pat = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    return pat * rng.uniform(0.5, 2.0, size=(n, n)).astype(np.float32)


def _host_roundtrip_union(A: BSR, B: BSR, op) -> BSR:
    """The pre-refactor shape of BSR ewise: every tile crosses to host
    numpy, the merge runs there, and `from_blocks` reassembles (one
    host-numpy call per op). Kept here as the benchmark baseline only."""
    nbc = A.nbcols
    ka = np.asarray(A.block_rows)[np.asarray(A.valid) > 0].astype(np.int64) \
        * nbc + np.asarray(A.block_cols)[np.asarray(A.valid) > 0]
    kb = np.asarray(B.block_rows)[np.asarray(B.valid) > 0].astype(np.int64) \
        * nbc + np.asarray(B.block_cols)[np.asarray(B.valid) > 0]
    ta = np.asarray(A.blocks)[np.asarray(A.valid) > 0]
    tb = np.asarray(B.blocks)[np.asarray(B.valid) > 0]
    keys = np.union1d(ka, kb)
    blocks = np.zeros((len(keys), A.block, A.block), np.float32)
    pa = np.searchsorted(keys, ka)
    pb = np.searchsorted(keys, kb)
    blocks[pa] += ta
    blocks[pb] += tb                       # op == add: union accumulates
    return BSR.from_blocks((keys // nbc).astype(np.int32),
                           (keys % nbc).astype(np.int32),
                           blocks, A.shape, A.block)


def _bench_bsr(rows):
    # CPU note: the Pallas cells run in interpret mode here (a Python loop
    # over tiles), so their absolute numbers are meaningless off-TPU — the
    # XLA-vs-host-roundtrip cells carry the CPU story, the host_numpy_calls
    # column carries the structural one.
    n, block = 1024, 32
    A = BSR.from_dense(_pattern(n, seed=1), block=block)
    B = BSR.from_dense(_pattern(n, seed=2), block=block)
    ref = np.asarray(A.to_dense()) + np.asarray(B.to_dense())

    t_host = _timeit(lambda: _host_roundtrip_union(A, B, _ADD))
    for impl, call in (
            ("xla", lambda: bsrmod.ewise_add(A, B, _ADD)),
            ("pallas", lambda: kops.bsr_ewise(A, B, "union", _ADD))):
        h0 = bsrmod.host_numeric_calls()
        out = call()
        per_call = bsrmod.host_numeric_calls() - h0
        np.testing.assert_allclose(np.asarray(out.to_dense()), ref,
                                   rtol=1e-5, err_msg=impl)
        t = _timeit(call)
        rows.append((f"bsr_union_{impl}_n{n}b{block}", t * 1e6,
                     f"vs_host_roundtrip={t_host / t:.2f}x_"
                     f"host_numpy_calls={per_call}"))
    rows.append((f"bsr_union_hostloop_n{n}b{block}", t_host * 1e6,
                 "host_numpy_calls=1"))

    for mode, op in (("intersect", _MUL), ("mask", None)):
        t_x = _timeit(lambda: kops.bsr_ewise(A, B, mode, op))
        t_r = _timeit(lambda: (bsrmod.ewise_mult(A, B, _MUL) if
                               mode == "intersect" else
                               bsrmod.mask_keep(A, B)))
        rows.append((f"bsr_{mode}_pallas_n{n}b{block}", t_x * 1e6,
                     f"vs_xla={t_r / t_x:.2f}x_host_numpy_calls=0"))
    return rows


def _bench_sharded(rows):
    ndev = jax.device_count()
    if ndev < 2:
        return rows                        # needs REPRO_FORCE_DEVICES>=2
    d = min(ndev, 8)
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:d]).reshape(d, 1, 1),
        ("data", "pod", "model"))
    n = 2048
    ea = grb.GBMatrix.from_dense(_pattern(n, seed=3, density=0.01),
                                 fmt="ell")
    eb = grb.GBMatrix.from_dense(_pattern(n, seed=4, density=0.01),
                                 fmt="ell")
    sa, sb = grb.distribute(ea, mesh), grb.distribute(eb, mesh)

    def shard_local():
        return jax.block_until_ready(grb.ewise_add(sa, sb, S.PLUS).store.values)

    def gather_oracle():
        # the fallback this PR retired for same-mesh operands: gather both
        # shards to host ELL, merge there, push the result back out
        merged = grb.ewise_add(grb.GBMatrix(sa.store.to_ell()),
                               grb.GBMatrix(sb.store.to_ell()), S.PLUS)
        return jax.block_until_ready(
            ShardedELL.from_ell(merged.store, mesh).values)

    x0 = grb.host_transfers()
    shard_local()
    local_xfers = grb.host_transfers() - x0
    x0 = grb.host_transfers()
    gather_oracle()
    gather_xfers = grb.host_transfers() - x0
    t_local = _timeit(shard_local)
    t_gather = _timeit(gather_oracle)
    rows.append((f"shard_ewise_local_n{n}d{d}", t_local * 1e6,
                 f"vs_gather={t_gather / t_local:.2f}x_"
                 f"host_transfers={local_xfers}"))
    rows.append((f"shard_ewise_gather_n{n}d{d}", t_gather * 1e6,
                 f"host_transfers={gather_xfers}"))
    return rows


def run(rows):
    _bench_bsr(rows)
    _bench_sharded(rows)
    return rows
