"""Paper Fig. 1: k-hop neighborhood-count response time (k = 1, 2, 3, 6).

Reproduces the TigerGraph/RedisGraph protocol at CPU scale: 300 seeds for
k in {1,2}, 10 seeds for k in {3,6}, sequential single-request latency, on
Graph500 RMAT and a Twitter-like power-law graph. The naive adjacency-list
BFS baseline stands in for the non-algebraic engines the paper compares
against; the GraphBLAS path is this repo's contribution. The batched column
is the threadpool analog (all seeds in one frontier matrix).
"""
from __future__ import annotations

import time
from collections import deque

import jax
import numpy as np

from repro import algorithms as alg
from repro.graph.datagen import rmat_graph, twitter_like_graph


def naive_khop(adj, seed, k):
    lvl = {seed: 0}
    q = deque([seed])
    cnt = 0
    while q:
        u = q.popleft()
        if lvl[u] >= k:
            continue
        for v in adj[u]:
            if v not in lvl:
                lvl[v] = lvl[u] + 1
                cnt += 1
                q.append(v)
    return cnt


def adj_list(g, rel):
    D = np.asarray(g.relations[rel].A.to_dense()) != 0
    return [np.nonzero(row)[0].tolist() for row in D]


def bench_graph(name, g, rel, rows):
    rng = np.random.default_rng(0)
    adj = adj_list(g, rel)
    R = g.relations[rel]
    for k in (1, 2, 3, 6):
        n_seeds = 300 if k <= 2 else 10
        seeds = rng.integers(0, g.n, size=n_seeds)

        # GraphBLAS batched (the threadpool analog): one frontier matrix
        fn = jax.jit(lambda s: alg.khop_counts(R, s, k=k))
        counts = np.asarray(fn(seeds))  # compile + run
        t0 = time.perf_counter()
        counts = np.asarray(fn(seeds))
        dt_batch = time.perf_counter() - t0

        # GraphBLAS sequential single requests (paper protocol)
        one = jax.jit(lambda s: alg.khop_counts(R, s, k=k))
        _ = np.asarray(one(seeds[:1]))
        t0 = time.perf_counter()
        for s in seeds[: min(n_seeds, 30)]:
            np.asarray(one(np.asarray([s])))
        dt_seq = (time.perf_counter() - t0) / min(n_seeds, 30)

        # naive baseline (the "other databases" stand-in)
        t0 = time.perf_counter()
        base = [naive_khop(adj, int(s), k) for s in seeds]
        dt_naive = (time.perf_counter() - t0) / n_seeds

        assert list(counts) == base, f"correctness: {name} k={k}"
        rows.append((f"khop_{name}_k{k}_graphblas_batched",
                     dt_batch / n_seeds * 1e6, f"{n_seeds}seeds"))
        rows.append((f"khop_{name}_k{k}_graphblas_single",
                     dt_seq * 1e6, "per_query"))
        rows.append((f"khop_{name}_k{k}_naive_baseline",
                     dt_naive * 1e6,
                     f"speedup_batched={dt_naive / (dt_batch / n_seeds):.1f}x"))
    return rows


def run(rows):
    g500 = rmat_graph(scale=11, edge_factor=8, seed=3, fmt="bsr", block=128)
    bench_graph("graph500_s11", g500, "KNOWS", rows)
    tw = twitter_like_graph(n=2048, avg_deg=16, seed=1, fmt="ell")
    bench_graph("twitter2k", tw, "FOLLOWS", rows)
    return rows
