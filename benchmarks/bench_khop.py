"""Paper Fig. 1: k-hop neighborhood-count response time (k = 1, 2, 3, 6).

Reproduces the TigerGraph/RedisGraph protocol at CPU scale: 300 seeds for
k in {1,2}, 10 seeds for k in {3,6}, sequential single-request latency, on
Graph500 RMAT and a Twitter-like power-law graph. The naive adjacency-list
BFS baseline stands in for the non-algebraic engines the paper compares
against; the GraphBLAS path is this repo's contribution. The batched column
is the threadpool analog (all seeds in one frontier matrix).
"""
from __future__ import annotations

import time
from collections import deque

import jax
import numpy as np

from repro import algorithms as alg
from repro.graph.datagen import rmat_graph, twitter_like_graph


def naive_khop(adj, seed, k):
    lvl = {seed: 0}
    q = deque([seed])
    cnt = 0
    while q:
        u = q.popleft()
        if lvl[u] >= k:
            continue
        for v in adj[u]:
            if v not in lvl:
                lvl[v] = lvl[u] + 1
                cnt += 1
                q.append(v)
    return cnt


def adj_list(g, rel):
    D = np.asarray(g.relations[rel].A.to_dense()) != 0
    return [np.nonzero(row)[0].tolist() for row in D]


def bench_graph(name, g, rel, rows):
    rng = np.random.default_rng(0)
    adj = adj_list(g, rel)
    R = g.relations[rel]
    for k in (1, 2, 3, 6):
        n_seeds = 300 if k <= 2 else 10
        seeds = rng.integers(0, g.n, size=n_seeds)

        # GraphBLAS batched (the threadpool analog): one frontier matrix
        fn = jax.jit(lambda s: alg.khop_counts(R, s, k=k))
        counts = np.asarray(fn(seeds))  # compile + run
        t0 = time.perf_counter()
        counts = np.asarray(fn(seeds))
        dt_batch = time.perf_counter() - t0

        # GraphBLAS sequential single requests (paper protocol)
        one = jax.jit(lambda s: alg.khop_counts(R, s, k=k))
        _ = np.asarray(one(seeds[:1]))
        t0 = time.perf_counter()
        for s in seeds[: min(n_seeds, 30)]:
            np.asarray(one(np.asarray([s])))
        dt_seq = (time.perf_counter() - t0) / min(n_seeds, 30)

        # naive baseline (the "other databases" stand-in)
        t0 = time.perf_counter()
        base = [naive_khop(adj, int(s), k) for s in seeds]
        dt_naive = (time.perf_counter() - t0) / n_seeds

        assert list(counts) == base, f"correctness: {name} k={k}"
        rows.append((f"khop_{name}_k{k}_graphblas_batched",
                     dt_batch / n_seeds * 1e6, f"{n_seeds}seeds"))
        rows.append((f"khop_{name}_k{k}_graphblas_single",
                     dt_seq * 1e6, "per_query"))
        rows.append((f"khop_{name}_k{k}_naive_baseline",
                     dt_naive * 1e6,
                     f"speedup_batched={dt_naive / (dt_batch / n_seeds):.1f}x"))
    return rows


def run(rows):
    g500 = rmat_graph(scale=11, edge_factor=8, seed=3, fmt="bsr", block=128)
    bench_graph("graph500_s11", g500, "KNOWS", rows)
    tw = twitter_like_graph(n=2048, avg_deg=16, seed=1, fmt="ell")
    bench_graph("twitter2k", tw, "FOLLOWS", rows)
    return rows


# -- shared timing harness (jit + warmup + averaged reps) ---------------------
def _timed_khop(handle, seeds, k, reps):
    """(counts, seconds/call) of a jitted batched khop — the one warmup +
    rep-averaging recipe every sweep in this file uses."""
    fn = jax.jit(lambda s: alg.khop_counts(handle, s, k=k))
    counts = np.asarray(fn(seeds))                       # compile + run
    t0 = time.perf_counter()
    for _ in range(reps):
        counts = np.asarray(fn(seeds))
    return counts, (time.perf_counter() - t0) / reps


def _timed_modes(handle, seeds, k, reps):
    """The packed-vs-unpacked comparison cell: time both policy modes and
    assert the counts identical (the bit-identity claim)."""
    from repro.core import grb
    times, counts = {}, {}
    for mode in ("off", "on"):
        with grb.packed_frontiers(mode):
            counts[mode], times[mode] = _timed_khop(handle, seeds, k, reps)
    assert list(counts["on"]) == list(counts["off"]), "packed diverged"
    return times


# -- bitmap-packed vs unpacked crossover (the §Bitmap dispatch) ---------------
def run_packed(rows, scale=10, k=2, reps=3):
    """Where does the packed boolean frontier overtake the float route, per
    frontier width F? One khop per width with the policy forced off then on
    (`grb.packed_frontiers`); the measured crossover is what
    `grb.AUTO_PACK_MIN_WIDTH` pins — re-run this sweep to recalibrate it on
    new hardware."""
    from repro.core import bitmap

    g = rmat_graph(scale=scale, edge_factor=8, seed=3, fmt="ell")
    rel = g.relations["KNOWS"]
    rng = np.random.default_rng(0)
    for f in (8, 16, 32, 64, 128, 256, 512):
        seeds = rng.integers(0, g.n, size=f)
        times = _timed_modes(rel, seeds, k, reps)
        rows.append((f"khop_packed_s{scale}_k{k}_f{f}",
                     times["on"] / f * 1e6,
                     f"vs_unpacked={times['off'] / times['on']:.2f}x_"
                     f"frontier_bytes={bitmap.payload_reduction(f):.0f}x_less"))
    return rows


# -- sharded-vs-single-device crossover (the §Sharded dispatch) ---------------
def _row_mesh(d):
    """d-way "data" mesh over the first d local devices (pod/model size 1:
    the crossover isolates the row-shard collectives, not query scale-out)."""
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:d]).reshape(d, 1, 1)
    return Mesh(devs, ("data", "pod", "model"))


def run_dist(rows, scale=10, k=2, n_seeds=32, reps=3):
    """k-hop through the unchanged algorithm surface on sharded handles,
    per device count — where does the mesh overtake one device?

    On a real pod the "data" collectives ride ICI; on this CPU host the
    fake devices share one memory bus, so the printed crossover is a lower
    bound (the per-hop all-gather is nearly free, the sharded row gathers
    still pay shard_map dispatch). Run under REPRO_FORCE_DEVICES=8 (run.py
    applies it to XLA_FLAGS before jax loads) to sweep 1/2/4/8.
    """
    from repro.core import grb

    g = rmat_graph(scale=scale, edge_factor=8, seed=3, fmt="ell")
    rel = g.relations["KNOWS"]
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.n, size=n_seeds)

    base, dt_single = _timed_khop(rel.A, seeds, k, reps)
    rows.append((f"khop_dist_s{scale}_k{k}_single_device",
                 dt_single / n_seeds * 1e6, f"{n_seeds}seeds"))
    ndev = jax.device_count()
    if ndev < 2:
        rows.append((f"khop_dist_s{scale}_k{k}_sharded", 0.0,
                     "skipped_single_device_host_set_REPRO_FORCE_DEVICES=8"))
        return rows
    for d in (1, 2, 4, 8):
        if d > ndev:
            break
        sh = grb.distribute(rel.A, _row_mesh(d))
        counts, dt = _timed_khop(sh, seeds, k, reps)
        assert list(counts) == list(base), f"sharded d={d} diverged"
        rows.append((f"khop_dist_s{scale}_k{k}_sharded_dev{d}",
                     dt / n_seeds * 1e6,
                     f"vs_single={dt_single / dt:.2f}x"))

    # packed-vs-unpacked on the mesh: a wide frontier so the per-hop
    # all-gather payload cut (core.bitmap words) dominates. Fake CPU devices
    # share one memory bus, so the wall-clock ratio here is a lower bound —
    # the payload accounting column is the hardware-independent claim.
    from repro.core import bitmap
    f = 256
    d = min(4, ndev)
    sh = grb.distribute(rel.A, _row_mesh(d))
    times = _timed_modes(sh, rng.integers(0, g.n, size=f), k, reps)
    rows.append((f"khop_dist_s{scale}_k{k}_packed_f{f}_dev{d}",
                 times["on"] / f * 1e6,
                 f"vs_unpacked={times['off'] / times['on']:.2f}x_"
                 f"allgather_payload="
                 f"{bitmap.payload_reduction(f):.0f}x_less"))
    return rows
