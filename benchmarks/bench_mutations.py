"""Live-mutation serving: delta-matrix writes vs stop-the-world rebuilds.

Two suites behind ``python benchmarks/run.py mutations``:

  mutations_*  — end-to-end Database latency under a sustained Poisson
                 insert/delete stream with interleaved k-hop reads, delta
                 mode vs the legacy rebuild-on-freeze mode
                 (``Database(delta=False)``). Reports per-query latency and
                 the rebuild counters — the paper's "modifying the graph is
                 done by modifying these matrices" claim made measurable.
  crossover_*  — the AUTO_DELTA_COMPACT calibration: per pending-ratio
                 (|deltas| / base nnz), the read overhead of composing the
                 deltas at query time vs a compacted base, and the one-off
                 compaction cost; ``breakeven`` is how many reads at that
                 ratio repay one compaction. The threshold in
                 repro.core.delta is chosen where the composed read first
                 costs measurably more than the compacted one.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import grb, semiring as S
from repro.core.delta import AUTO_DELTA_COMPACT, DeltaMatrix
from repro.engine import Database
from repro.graph.datagen import rmat_edges


def _timeit(fn, reps: int = 20) -> float:
    fn()                                    # warm (trace + compile)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _populate(db: Database, src, dst, n) -> None:
    mg = db._graph("g")
    mg.next_id = n
    for s, d in zip(src.tolist(), dst.tolist()):
        if s != d:
            mg.create_edge(s, "KNOWS", d)


def _poisson_stream(rng, src, dst, n, events: int):
    """(kind, s, d) events: inserts of absent pairs and deletes of live
    edges, interleaved with Poisson-ish burst sizes."""
    live = {(int(a), int(b)) for a, b in zip(src, dst) if a != b}
    out = []
    while len(out) < events:
        for _ in range(max(1, rng.poisson(2))):
            if rng.random() < 0.5 and live:
                i = rng.integers(0, len(live))
                pair = list(live)[int(i)]
                live.discard(pair)
                out.append(("del", *pair))
            else:
                a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
                if a != b and (a, b) not in live:
                    live.add((a, b))
                    out.append(("add", a, b))
    return out[:events]


def run(rows):
    # -- end-to-end: query latency under a live write stream ------------------
    # s12 (n=4096, 32k edges): the scale where one GraphBuilder rebuild
    # (~70ms host) costs more than the read itself — the regime the delta
    # layer exists for. Headline metric is p50: the mean folds in the
    # handful of one-off XLA compiles of new bucketed patch shapes.
    scale, events, reads_per_write = 12, 40, 2
    src, dst, n = rmat_edges(scale, edge_factor=8, seed=7)
    rng = np.random.default_rng(7)
    stream = _poisson_stream(rng, src, dst, n, events)
    q = "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 3 RETURN count(DISTINCT b)"
    for mode, delta in (("delta", True), ("rebuild", False)):
        db = Database(delta=delta)
        _populate(db, src, dst, n)
        db.query("g", q)                    # base build + compile, off-clock
        t0 = time.perf_counter()
        lat = []
        for kind, a, b in stream:
            if kind == "add":
                db.query("g", f"CREATE ({a})-[:KNOWS]->({b})")
            else:
                db.query("g", f"DELETE ({a})-[:KNOWS]->({b})")
            for _ in range(reads_per_write):
                tq = time.perf_counter()
                db.query("g", q)
                lat.append(time.perf_counter() - tq)
        wall = time.perf_counter() - t0
        mg = db._graph("g")
        rows.append((f"mutations_{mode}_s{scale}",
                     float(np.percentile(lat, 50)) * 1e6,
                     f"mean_us={np.mean(lat) * 1e6:.0f};"
                     f"wall_s={wall:.2f};rebuilds={mg.rebuilds};"
                     f"compactions={mg.compactions}"))

    # -- crossover sweep: composed-read overhead vs compaction cost -----------
    src, dst, n = rmat_edges(12, edge_factor=8, seed=11)
    keep = src != dst
    r, c = src[keep], dst[keep]
    base = grb.GBMatrix.from_coo(r, c, np.ones(len(r), np.float32),
                                 (n, n), fmt="ell")
    x = np.random.default_rng(0).random(n).astype(np.float32)
    compacted_t = _timeit(
        lambda: np.asarray(grb.mxv(base, x, S.PLUS_TIMES)))
    live = {(int(a), int(b)) for a, b in zip(r, c)}
    for ratio in (0.01, 0.02, 0.05, 0.1, 0.25, 0.5):
        k = max(1, int(ratio * base.nvals))
        rng = np.random.default_rng(int(ratio * 100))
        ops = []
        while len(ops) < k:
            a, b = int(rng.integers(0, n)), int(rng.integers(0, n))
            if a != b and (a, b) not in live:
                ops.append(("add", a, b, 1.0))
        dm = DeltaMatrix.wrap(base.store).apply_ops(ops)
        h = grb.GBMatrix(dm)
        dm.patch()                          # patch build off-clock (cached)
        delta_t = _timeit(lambda: np.asarray(grb.mxv(h, x, S.PLUS_TIMES)))
        t0 = time.perf_counter()
        dm._mat = None                      # force a fresh fold
        dm.materialize()
        compact_cost = time.perf_counter() - t0
        over = max(delta_t - compacted_t, 1e-9)
        rows.append((f"crossover_ratio{ratio}", delta_t * 1e6,
                     f"compacted_us={compacted_t * 1e6:.1f};"
                     f"overhead_x={delta_t / compacted_t:.2f};"
                     f"compact_ms={compact_cost * 1e3:.1f};"
                     f"breakeven_reads={compact_cost / over:.0f}"))
    rows.append(("crossover_threshold", AUTO_DELTA_COMPACT * 1e6,
                 f"AUTO_DELTA_COMPACT={AUTO_DELTA_COMPACT}"))
    return rows
