"""k-truss: sparse (masked SpGEMM + sparse select) vs dense formulation.

Shares the RMAT symmetrization and warmup-timing helpers with
bench_triangles.py so the two crossover reports measure identically.
Races the two routes `algorithms.ktruss` can take per RMAT scale:

  sparse — BSR-backed handle: support<A> via the BSR x BSR SpGEMM kernel,
           block-sparse select, zero densifications (the Graphulo shape),
  dense  — the same recurrence on a dense-backed handle (dense masked
           plus_pair matmul + dense structural select).

Both are validated against an independent NumPy peeling oracle; the summary
row names the first scale where the sparse route wins, mirroring
bench_triangles.py (whose measured crossover feeds grb's impl="auto"
policy).
"""
from __future__ import annotations

import numpy as np

from benchmarks.bench_triangles import _time, _undirected_rmat
from repro.algorithms import ktruss
from repro.core import grb

SCALES = (7, 8, 9)
K = 4


def _ktruss_oracle(D: np.ndarray, k: int) -> np.ndarray:
    """Independent NumPy peeling loop (support recount each round)."""
    A = (np.asarray(D) != 0).astype(np.int64)
    np.fill_diagonal(A, 0)
    while True:
        sup = (A @ A) * A
        A2 = ((sup >= k - 2) & (A != 0)).astype(np.int64)
        if (A2 == A).all():
            return A2
        A = A2


def run(rows):
    crossover = None
    for scale in SCALES:
        g = _undirected_rmat(scale)
        A = g.relations["R"].A
        dense_h = grb.GBMatrix(A.to_dense())
        got_s, us_s = _time(lambda: ktruss(A, K).nvals)
        got_d, us_d = _time(lambda: ktruss(dense_h, K).nvals)
        want = int(_ktruss_oracle(np.asarray(A.to_dense()), K).sum())
        assert got_s == want, ("sparse", scale, got_s, want)
        assert got_d == want, ("dense", scale, got_d, want)
        rows.append((f"ktruss{K}_dense_s{scale}", us_d, f"edges={want}"))
        rows.append((f"ktruss{K}_sparse_s{scale}", us_s,
                     f"edges={want} speedup={us_d / max(us_s, 1e-9):.2f}x"))
        if crossover is None and us_s < us_d:
            crossover = scale
    rows.append(("ktruss_crossover", 0.0,
                 f"sparse_wins_from_scale={crossover}"
                 if crossover is not None else "sparse_wins_from_scale=none"))
    return rows
