"""Benchmark harness: one function per paper table/claim.

  khop        — paper Fig. 1 (k-hop response time, RedisGraph protocol)
  khop-dist   — sharded-vs-single-device k-hop crossover per device count
                (REPRO_FORCE_DEVICES=8 sweeps 1/2/4/8 fake CPU devices),
                plus the packed-vs-unpacked all-gather payload comparison
  khop-packed — bitmap-packed vs float boolean frontiers per frontier
                width (the measured AUTO_PACK_MIN_WIDTH crossover)
  throughput  — paper §II (threadpool/read-scaling claim): Poisson
                open-loop serving, continuous batching vs one-query-at-a-
                time (qps, p50/p99 latency, plan-cache hit rate)
  kernels     — format-selection crossover (BSR/ELL/dense)
  ewise       — mesh/device-resident element-wise: BSR Pallas vs XLA vs
                the pre-refactor host round-trip; shard-local vs gather
  triangles   — GraphChallenge (paper future-work item)
  ktruss      — Graphulo k-truss, sparse (masked SpGEMM) vs dense
  bitadj      — bit-packed adjacency (BitELL): resident bytes + triangle
                and BFS speed vs the float ELL route, validated
                bit-identical first (AUTO_BITADJ_* provenance)
  algos       — algorithm breadth (CALL algo.* tentpole): batched multi-
                source Brandes vs one-BFS-per-source, packed vs unpacked
                closeness widths, BitELL vs ELL cells — each validated
                against the reference before timing
                (AUTO_CENTRALITY_BATCH provenance)
  mutations   — query latency under a live Poisson insert/delete stream
                (delta serving vs rebuild-on-freeze) + the delta-vs-rebuild
                crossover sweep calibrating AUTO_DELTA_COMPACT

Prints ``name,us_per_call,derived`` CSV. ``--json out.json`` additionally
writes the rows as machine-readable records
(``{"suite", "metric", "value", "derived"}``) — what `make bench-smoke`
archives as ``BENCH_*.json`` and CI diffs run-over-run. Roofline terms come
from the dry-run artifacts: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import json
import os
import sys

# Must run before anything imports jax: a fake multi-device CPU topology
# (the khop-dist sweep) can only be forced through XLA_FLAGS at backend
# init — same env guard as tests/conftest.py.
if os.environ.get("REPRO_FORCE_DEVICES"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count="
            + os.environ["REPRO_FORCE_DEVICES"]).strip()


def main(argv=None) -> None:
    from benchmarks import bench_algos, bench_bitadj, bench_ewise, \
        bench_khop, bench_kernels, bench_ktruss, bench_mutations, \
        bench_throughput, bench_triangles
    argv = list(sys.argv[1:] if argv is None else argv)
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            raise SystemExit("--json needs an output path")
        del argv[i:i + 2]
    only = argv[0] if argv else None
    suites = {
        "khop": bench_khop.run,
        "khop-dist": bench_khop.run_dist,
        "khop-packed": bench_khop.run_packed,
        "throughput": bench_throughput.run,
        "kernels": bench_kernels.run,
        "ewise": bench_ewise.run,
        "triangles": bench_triangles.run,
        "ktruss": bench_ktruss.run,
        "mutations": bench_mutations.run,
        "bitadj": bench_bitadj.run,
        "algos": bench_algos.run,
    }
    if only and only not in suites:
        raise SystemExit(f"unknown suite {only!r}; one of "
                         f"{', '.join(suites)}")
    rows: list = []
    records: list = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name != only:
            continue
        start = len(rows)
        fn(rows)
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
            records.append({"suite": name, "metric": r[0],
                            "value": float(r[1]), "derived": str(r[2])})
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(records, fh, indent=1)
        print(f"# wrote {len(records)} records to {json_path}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
