"""Benchmark harness: one function per paper table/claim.

  khop        — paper Fig. 1 (k-hop response time, RedisGraph protocol)
  throughput  — paper §II (threadpool/read-scaling claim)
  kernels     — format-selection crossover (BSR/ELL/dense)
  triangles   — GraphChallenge (paper future-work item)
  ktruss      — Graphulo k-truss, sparse (masked SpGEMM) vs dense

Prints ``name,us_per_call,derived`` CSV. Roofline terms come from the
dry-run artifacts: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import bench_khop, bench_kernels, bench_ktruss, \
        bench_throughput, bench_triangles
    rows: list = []
    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = {
        "khop": bench_khop.run,
        "throughput": bench_throughput.run,
        "kernels": bench_kernels.run,
        "triangles": bench_triangles.run,
        "ktruss": bench_ktruss.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name != only:
            continue
        start = len(rows)
        fn(rows)
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
