"""Benchmark harness: one function per paper table/claim.

  khop        — paper Fig. 1 (k-hop response time, RedisGraph protocol)
  khop-dist   — sharded-vs-single-device k-hop crossover per device count
                (REPRO_FORCE_DEVICES=8 sweeps 1/2/4/8 fake CPU devices),
                plus the packed-vs-unpacked all-gather payload comparison
  khop-packed — bitmap-packed vs float boolean frontiers per frontier
                width (the measured AUTO_PACK_MIN_WIDTH crossover)
  throughput  — paper §II (threadpool/read-scaling claim): Poisson
                open-loop serving, continuous batching vs one-query-at-a-
                time (qps, p50/p99 latency, plan-cache hit rate)
  kernels     — format-selection crossover (BSR/ELL/dense)
  triangles   — GraphChallenge (paper future-work item)
  ktruss      — Graphulo k-truss, sparse (masked SpGEMM) vs dense
  mutations   — query latency under a live Poisson insert/delete stream
                (delta serving vs rebuild-on-freeze) + the delta-vs-rebuild
                crossover sweep calibrating AUTO_DELTA_COMPACT

Prints ``name,us_per_call,derived`` CSV. Roofline terms come from the
dry-run artifacts: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import os
import sys

# Must run before anything imports jax: a fake multi-device CPU topology
# (the khop-dist sweep) can only be forced through XLA_FLAGS at backend
# init — same env guard as tests/conftest.py.
if os.environ.get("REPRO_FORCE_DEVICES"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count="
            + os.environ["REPRO_FORCE_DEVICES"]).strip()


def main() -> None:
    from benchmarks import bench_khop, bench_kernels, bench_ktruss, \
        bench_mutations, bench_throughput, bench_triangles
    rows: list = []
    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = {
        "khop": bench_khop.run,
        "khop-dist": bench_khop.run_dist,
        "khop-packed": bench_khop.run_packed,
        "throughput": bench_throughput.run,
        "kernels": bench_kernels.run,
        "triangles": bench_triangles.run,
        "ktruss": bench_ktruss.run,
        "mutations": bench_mutations.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name != only:
            continue
        start = len(rows)
        fn(rows)
        for r in rows[start:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")


if __name__ == "__main__":
    main()
