"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
Writes experiments/roofline.md (markdown) + prints a CSV summary.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def table(recs, mesh="pod16x16"):
    lines = [
        "| cell | kind | compute | memory | collective | dominant | "
        "MFU-bound | useful/HLO flops | mem GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['cell']} | - | ERROR: "
                         f"{r.get('error', '?')[:60]} |" + " |" * 8)
            continue
        rl = r["roofline"]
        ratio = r.get("useful_flops_ratio", 0.0)
        mfu_bound = (rl["compute_s"] / rl["bound_s"] * ratio
                     if rl["bound_s"] else 0.0)
        mem = r["memory"]["peak_per_device_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} x {r['shape']} | {r.get('kind','?')} "
            f"| {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
            f"| {fmt_s(rl['collective_s'])} | {rl['dominant']} "
            f"| {mfu_bound:.3f} | {ratio:.3f} | {mem:.2f} "
            f"| {'Y' if r.get('fits_hbm') else 'N'} |")
    return "\n".join(lines)


def csv(recs):
    out = ["cell,status,dominant,compute_s,memory_s,collective_s,"
           "useful_ratio,mem_gb,fits"]
    for r in recs:
        if r.get("status") != "ok":
            out.append(f"{r['cell']},error,,,,,,,")
            continue
        rl = r["roofline"]
        out.append(
            f"{r['cell']},ok,{rl['dominant']},{rl['compute_s']:.4e},"
            f"{rl['memory_s']:.4e},{rl['collective_s']:.4e},"
            f"{r.get('useful_flops_ratio', 0):.3f},"
            f"{r['memory']['peak_per_device_bytes'] / 1e9:.2f},"
            f"{int(bool(r.get('fits_hbm')))}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print("no dryrun records found")
        return
    md = ["# Roofline (single-pod 16x16, per-device terms)", "",
          table(recs, "pod16x16"), "",
          "# Multi-pod compile check (2x16x16)", "",
          table(recs, "pod2x16x16")]
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(md) + "\n")
    print(csv(recs))
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
