"""Paper §II claim ("reads scale and handle large throughput easily") made
measurable: continuous-batching serving under Poisson open-loop load.

One arrival trace — N k=2-hop count queries with exponential inter-arrival
gaps at an offered rate chosen to oversaturate the solo path — is replayed
on the wall clock against the same RMAT graph twice:

  batched  QueryServer continuous batching: signature-compatible queries
           coalesce into width-admission-controlled packed sweeps, host
           scheduling overlapped with device execution.
  solo     the same server machinery capped at one query per sweep
           (max_batch=1, no lane padding) — the one-query-at-a-time path.

Open loop means arrivals never wait for completions (the "millions of
users" don't coordinate), so a server slower than the offered rate builds a
queue and its p99 completion-minus-arrival latency explodes; queries/sec
measures sustained service capacity. Reported per mode: queries/sec, p50
and p99 latency, plan-cache hit rate, packed-lane utilization. The claim
pinned by the `_speedup` row: batched >= 2x solo queries/sec at
equal-or-better p99 (both answers differentially checked equal first).
"""
from __future__ import annotations

import time

import numpy as np

from repro.engine import QueryServer
from repro.graph.datagen import rmat_graph

# seed-free shape template: every submission binds seeds out of band, so
# all N queries are one PlanCache entry (hit rate ~ (N-1)/N)
TEMPLATE = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"


def _arrivals(n: int, rate_qps: float, rng) -> np.ndarray:
    return np.cumsum(rng.exponential(1.0 / rate_qps, size=n))


def _drive(srv: QueryServer, arrivals: np.ndarray, seeds: np.ndarray):
    """Open-loop replay on the wall clock: submit each query when its
    arrival time is due (never waiting for earlier completions), pump
    whenever there is work. Returns (results, total_s, latencies_s) with
    latency = completion - scheduled arrival (queue wait included)."""
    out = {}
    order = {}
    i, n = 0, len(arrivals)
    t0 = time.perf_counter()
    while len(out) < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            qid = srv.submit(TEMPLATE, seeds=[int(seeds[i])],
                             arrival_s=t0 + arrivals[i])
            order[qid] = i
            i += 1
        if srv.pending:
            out.update(srv.pump())
        elif i < n:
            time.sleep(min(arrivals[i] - now, 1e-3))
    total = time.perf_counter() - t0
    lat = np.array([m.latency_s for m in srv.log])
    return out, order, total, lat


def run(rows, scale: int = 10, n_queries: int = 256, rate_qps: float = 4000.0):
    g = rmat_graph(scale=scale, edge_factor=8, seed=5, fmt="ell")
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.n, size=n_queries)
    arrivals = _arrivals(n_queries, rate_qps, rng)

    def warm(srv):
        # compile the sweep shapes outside the timed replay
        srv.submit(TEMPLATE, seeds=[0])
        srv.flush()
        srv.log.clear()

    batched = QueryServer(g, max_width=512)
    warm(batched)
    out_b, order_b, total_b, lat_b = _drive(batched, arrivals, seeds)

    solo = QueryServer(g, max_batch=1, align=False)
    warm(solo)
    out_s, order_s, total_s, lat_s = _drive(solo, arrivals, seeds)

    # differential: same trace, same answers, no errors in either mode
    by_i_b = {i: out_b[q].rows for q, i in order_b.items()}
    by_i_s = {i: out_s[q].rows for q, i in order_s.items()}
    assert not any(r.error for r in out_b.values())
    assert not any(r.error for r in out_s.values())
    assert by_i_b == by_i_s, "batched serving diverged from solo"

    qps_b, qps_s = n_queries / total_b, n_queries / total_s
    p50_b, p99_b = np.percentile(lat_b, [50, 99])
    p50_s, p99_s = np.percentile(lat_s, [50, 99])
    rows.append((f"serve_poisson_s{scale}_batched", p50_b * 1e6,
                 f"qps={qps_b:.0f}_p99_ms={p99_b * 1e3:.1f}"
                 f"_hit_rate={batched.stats['plan_cache_hit_rate']:.2f}"
                 f"_pack_ratio={batched.stats['pack_ratio']:.2f}"
                 f"_batches={batched.stats['batches']}"))
    rows.append((f"serve_poisson_s{scale}_solo", p50_s * 1e6,
                 f"qps={qps_s:.0f}_p99_ms={p99_s * 1e3:.1f}"))
    rows.append((f"serve_poisson_s{scale}_speedup", p99_b * 1e6,
                 f"batched_vs_solo_qps={qps_b / qps_s:.1f}x"
                 f"_p99_vs_solo={p99_s / p99_b:.1f}x_better"))
    return rows
