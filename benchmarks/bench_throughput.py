"""Paper §II claim: "reads scale and handle large throughput easily" —
queries/sec vs concurrent batch width (the threadpool analog: width = F)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import algorithms as alg
from repro.graph.datagen import rmat_graph


def run(rows):
    g = rmat_graph(scale=11, edge_factor=8, seed=5, fmt="bsr", block=128)
    R = g.relations["KNOWS"]
    rng = np.random.default_rng(0)
    k = 2
    for width in (1, 8, 64, 256):
        seeds = rng.integers(0, g.n, size=width)
        fn = jax.jit(lambda s: alg.khop_counts(R, s, k=k))
        np.asarray(fn(seeds))
        reps = max(1, 256 // width)
        t0 = time.perf_counter()
        for _ in range(reps):
            np.asarray(fn(seeds))
        dt = (time.perf_counter() - t0) / reps
        rows.append((f"throughput_width{width}", dt / width * 1e6,
                     f"qps={width / dt:.0f}"))
    return rows
