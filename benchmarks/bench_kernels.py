"""Kernel-layer microbench: BSR (batched-MXU path) vs ELL (gather path) vs
dense matmul for the or_and traversal step, across fill ratios.

CPU timings are indicative only (the roofline analysis in EXPERIMENTS.md is
the TPU perf story); what this table demonstrates is the format-selection
crossover that `core.ops.auto_format` encodes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BSR, ELL, ops, semiring as S


def run(rows):
    rng = np.random.default_rng(0)
    n, f = 4096, 128
    for nnz, tag in ((40_000, "sparse0.2%"), (400_000, "dense2.4%")):
        r = rng.integers(0, n, size=nnz)
        c = rng.integers(0, n, size=nnz)
        X = (rng.uniform(size=(n, f)) < 0.05).astype(np.float32)
        Xj = jnp.asarray(X)
        bsr = BSR.from_coo(r, c, None, (n, n), block=128)
        ell = ELL.from_coo(r, c, None, (n, n))
        dense = jnp.asarray(bsr.to_dense())
        impls = {
            "bsr_jnp": jax.jit(lambda x: ops.mxm(bsr, x, S.OR_AND)),
            "ell_gather": jax.jit(lambda x: ops.mxm(ell, x, S.OR_AND)),
            "dense_mxu": jax.jit(lambda x: ops.mxm(dense, x, S.OR_AND)),
        }
        outs = {}
        for name, fn in impls.items():
            outs[name] = np.asarray(fn(Xj))
            t0 = time.perf_counter()
            for _ in range(3):
                np.asarray(fn(Xj))
            dt = (time.perf_counter() - t0) / 3
            rows.append((f"kernel_{tag}_{name}", dt * 1e6,
                         f"fill={bsr.fill_ratio:.4f}"))
        for name, out in outs.items():
            np.testing.assert_allclose(out, outs["dense_mxu"],
                                       err_msg=f"{tag} {name}")
    return rows
