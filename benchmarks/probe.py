import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Probe-corrected roofline terms.

XLA's `cost_analysis()` (and static HLO text) counts a while-loop body ONCE,
not x trip-count — with scan-over-layers every measured term undercounts by
~n_layers. Fix, using only compiled artifacts: lower the SAME cell at probe
layer counts (e.g. L=1 and L=2, attention chunk-scan folded via kv_chunk=0),
fit the linear model f(L) = base + L * per_layer per metric
(flops / bytes / collective bytes), and evaluate at the real L.

Families with a *time* recurrence (rwkv6 wkv, zamba2 SSD) additionally get an
analytic recurrence term (the scan step is an outer product; S steps cannot
be folded) — documented in EXPERIMENTS.md §Roofline caveats.

Usage:
  PYTHONPATH=src python -m benchmarks.probe --all [--out experiments/probe]
"""
import argparse
import dataclasses
import json
import sys
import time

import numpy as np

from repro.configs.base import ARCHS, SHAPES, get_config, shapes_for


def _probe_plans(cfg):
    """Returns (rows, eval_row, replace_list): design rows [1, *counts] per
    probe config, the evaluation row for the real config, and the dataclass
    replacements producing each probe."""
    if cfg.family == "whisper":
        probes = [(1, 1), (2, 1), (1, 2)]
        rows = [[1, ld, le] for ld, le in probes]
        evalr = [1, cfg.n_layers, cfg.encoder_layers]
        reps = [dict(n_layers=ld, encoder_layers=le, kv_chunk=0,
                     scan_unroll=True) for ld, le in probes]
        return rows, evalr, reps
    if cfg.family == "zamba2":
        e = cfg.shared_attn_every
        Ls = [e, e + 1, 2 * e]

        def counts(L):
            n_full, rem = divmod(L, e)
            ns = n_full + (1 if rem else 0)
            return [1, L, ns]
        rows = [counts(L) for L in Ls]
        evalr = counts(cfg.n_layers)
        reps = [dict(n_layers=L, kv_chunk=0, scan_unroll=True) for L in Ls]
        return rows, evalr, reps
    # dense / moe / llava / rwkv6: linear in n_layers
    rows = [[1, 1], [1, 2]]
    evalr = [1, cfg.n_layers]
    reps = [dict(n_layers=L, kv_chunk=0, scan_unroll=True)
            for L in (1, 2)]
    return rows, evalr, reps


def _recurrence_flops(cfg, shape):
    """Analytic per-device add for time-recurrence scans (fwd; x3 for train)."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    if cfg.family == "rwkv6":
        per_step = 7 * B * cfg.ssm_heads * cfg.head_dim ** 2
        return per_step * S * cfg.n_layers
    if cfg.family == "zamba2":
        d_inner = 2 * cfg.d_model
        P = d_inner // cfg.ssm_heads
        per_step = 7 * B * cfg.ssm_heads * P * cfg.ssm_state
        return per_step * S * cfg.n_layers
    return 0.0


def probe_cell(arch: str, shape_name: str, outdir: str, multi_pod=False,
               rules=None):
    # local import so XLA_FLAGS is already set
    from repro.launch import dryrun as dr
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rows, evalr, reps = _probe_plans(cfg)
    meshname = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{meshname}"
    print(f"[probe] {cell}: {len(reps)} probes", flush=True)
    mets = {"flops": [], "bytes": [], "coll": []}
    try:
        for rep in reps:
            pcfg = dataclasses.replace(cfg, **rep)
            lowered, mesh, _, _ = dr.lower_cell(
                arch, shape_name, multi_pod, rules=rules, cfg=pcfg)
            compiled = lowered.compile()
            cost = dr.cost_stats(compiled)
            coll, _ = dr.collective_stats(compiled.as_text())
            mets["flops"].append(cost["flops_per_device"])
            mets["bytes"].append(cost["bytes_per_device"])
            mets["coll"].append(float(coll))
        X = np.asarray(rows, dtype=np.float64)
        ev = np.asarray(evalr, dtype=np.float64)
        corrected = {}
        for k, ys in mets.items():
            theta, *_ = np.linalg.lstsq(X, np.asarray(ys), rcond=None)
            corrected[k] = float(max(ev @ theta, 0.0))
        rec_fl = _recurrence_flops(cfg, shape)
        if rec_fl:
            nchips = 512 if multi_pod else 256
            mult = 3.0 if shape.kind == "train" else 1.0
            corrected["flops"] += rec_fl * mult / nchips
            corrected["recurrence_flops_added"] = rec_fl * mult / nchips
        rl = dr.roofline(512 if multi_pod else 256, corrected["flops"],
                         corrected["bytes"], corrected["coll"])
        n_active = cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        model_flops_dev = ((6 if shape.kind == "train" else 2)
                           * n_active * tokens / (512 if multi_pod else 256))
        rec = {"cell": cell, "arch": arch, "shape": shape_name,
               "mesh": meshname, "status": "ok", "kind": shape.kind,
               "corrected": corrected, "roofline": rl,
               "probe_points": {k: v for k, v in mets.items()},
               "model_flops_per_device": model_flops_dev,
               "useful_flops_ratio": model_flops_dev
               / max(corrected["flops"], 1.0)}
        print(f"  corrected: dom={rl['dominant']} "
              f"compute={rl['compute_s']*1e3:.1f}ms "
              f"mem={rl['memory_s']*1e3:.1f}ms "
              f"coll={rl['collective_s']*1e3:.1f}ms "
              f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
    except Exception as e:
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        print(f"  ERROR {type(e).__name__}: {str(e)[:200]}", flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/probe")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    archs = ARCHS if args.all else [args.arch]
    err = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in shapes_for(cfg)])
        for s in shapes:
            if s in cfg.skip_shapes:
                continue
            p = os.path.join(args.out, f"{arch}__{s}__pod16x16.json")
            if args.resume and os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("status") == "ok":
                        continue
            rec = probe_cell(arch, s, args.out)
            err += rec.get("status") != "ok"
    sys.exit(1 if err else 0)


if __name__ == "__main__":
    main()
