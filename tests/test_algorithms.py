"""Graph algorithms vs pure-python oracles on random graphs."""
import heapq
from collections import deque

import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms as alg
from repro.graph.graph import GraphBuilder

N = 220


def rand_digraph(n=N, m=1400, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    w = rng.uniform(0.5, 3.0, size=src.shape[0]).astype(np.float32) if weighted else None
    return src, dst, w


def adj_list(src, dst, n, w=None):
    out = [[] for _ in range(n)]
    for i in range(len(src)):
        out[src[i]].append((int(dst[i]), float(w[i]) if w is not None else 1.0))
    return out


def py_bfs(adj, seed, n):
    lvl = [float("inf")] * n
    lvl[seed] = 0
    q = deque([seed])
    while q:
        u = q.popleft()
        for v, _ in adj[u]:
            if lvl[v] == float("inf"):
                lvl[v] = lvl[u] + 1
                q.append(v)
    return lvl


def py_dijkstra(adj, seed, n):
    dist = [float("inf")] * n
    dist[seed] = 0.0
    h = [(0.0, seed)]
    while h:
        d, u = heapq.heappop(h)
        if d > dist[u]:
            continue
        for v, w in adj[u]:
            nd = d + w
            if nd < dist[v] - 1e-9:
                dist[v] = nd
                heapq.heappush(h, (nd, v))
    return dist


@pytest.fixture(scope="module", params=["bsr", "ell"])
def graph_fixture(request):
    src, dst, _ = rand_digraph(seed=1)
    g = GraphBuilder(N).add_edges("R", src, dst).build(fmt=request.param, block=64)
    # oracle adjacency from the *deduped* edges the builder kept
    D = np.asarray(g.relations["R"].A.to_dense())
    r, c = np.nonzero(D)
    return g, adj_list(r, c, N)


def test_bfs_levels(graph_fixture):
    g, adj = graph_fixture
    seeds = [0, 5, 77, 123]
    got = np.asarray(alg.bfs_levels(g.relations["R"], seeds, max_iter=N))
    for j, s in enumerate(seeds):
        want = np.array(py_bfs(adj, s, g.n))
        np.testing.assert_array_equal(got[:, j], want, err_msg=f"seed {s}")


@pytest.mark.parametrize("k", [1, 2, 3, 6])
def test_khop_counts(graph_fixture, k):
    g, adj = graph_fixture
    seeds = [3, 50, 199]
    got = np.asarray(alg.khop_counts(g.relations["R"], seeds, k=k))
    for j, s in enumerate(seeds):
        lv = py_bfs(adj, s, g.n)
        want = sum(1 for v in range(g.n) if 1 <= lv[v] <= k)
        assert got[j] == want, f"seed {s} k {k}"


def test_sssp_vs_dijkstra():
    src, dst, w = rand_digraph(seed=2, weighted=True)
    g = GraphBuilder(N).add_edges("R", src, dst, w).build(fmt="bsr", block=64)
    D = np.asarray(g.relations["R"].A.to_dense())
    r, c = np.nonzero(D)
    adj = [[] for _ in range(N)]
    for i in range(len(r)):
        adj[r[i]].append((int(c[i]), float(D[r[i], c[i]])))
    seeds = [0, 10, 111]
    got = np.asarray(alg.sssp(g.relations["R"], seeds))
    for j, s in enumerate(seeds):
        want = np.array(py_dijkstra(adj, s, g.n))
        np.testing.assert_allclose(got[:, j], want, rtol=1e-4, atol=1e-4)


def test_pagerank_sums_to_one_and_matches_numpy():
    src, dst, _ = rand_digraph(seed=3)
    g = GraphBuilder(N).add_edges("R", src, dst).build(fmt="bsr", block=64)
    rel = g.relations["R"]
    got = np.asarray(alg.pagerank(rel, iters=60))
    assert abs(got.sum() - 1.0) < 1e-4
    # numpy power iteration oracle
    D = np.asarray(rel.A.to_dense())
    deg = D.sum(1)
    P = np.where(deg[:, None] > 0, D / np.maximum(deg[:, None], 1e-30), 0.0)
    r = np.full(N, 1.0 / N)
    for _ in range(60):
        dmass = r[deg == 0].sum() / N
        r = (1 - 0.85) / N + 0.85 * (P.T @ r + dmass)
    np.testing.assert_allclose(got, r, rtol=1e-3, atol=1e-6)


def test_wcc_matches_union_find():
    rng = np.random.default_rng(5)
    # a few disjoint clusters with random internal edges
    sizes = [40, 80, 25, 75]
    offs = np.cumsum([0] + sizes)
    src_all, dst_all = [], []
    for i, sz in enumerate(sizes):
        # random spanning path + extra edges keeps each cluster connected
        perm = rng.permutation(sz) + offs[i]
        src_all += list(perm[:-1])
        dst_all += list(perm[1:])
        e = rng.integers(0, sz, size=(sz, 2)) + offs[i]
        src_all += list(e[:, 0])
        dst_all += list(e[:, 1])
    n = offs[-1]
    src, dst = np.array(src_all), np.array(dst_all)
    keep = src != dst
    g = GraphBuilder(n).add_edges("R", src[keep], dst[keep]).build(fmt="bsr", block=64)
    rel = g.relations["R"]
    labels = np.asarray(alg.wcc(rel))
    for i, sz in enumerate(sizes):
        comp = labels[offs[i]:offs[i + 1]]
        assert (comp == comp[0]).all(), f"cluster {i} split"
    assert len(np.unique(labels)) == len(sizes)


def test_triangle_count_vs_bruteforce():
    rng = np.random.default_rng(6)
    n = 96
    e = rng.integers(0, n, size=(600, 2))
    e = e[e[:, 0] != e[:, 1]]
    # symmetrize
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    g = GraphBuilder(n).add_edges("R", src, dst).build(fmt="bsr", block=32)
    A = g.relations["R"].A
    got = int(alg.triangle_count(A))
    D = np.asarray(A.to_dense()) != 0
    want = int(np.trace((D.astype(np.int64) @ D @ D)) // 6)
    assert got == want


# -- triangle goldens: known graphs + pure-NumPy counter ----------------------
def _tri_numpy(src, dst, n) -> int:
    """Independent counter: trace(A^3)/6 on a dense bool adjacency."""
    D = np.zeros((n, n), dtype=np.int64)
    D[src, dst] = 1
    D[dst, src] = 1
    np.fill_diagonal(D, 0)
    return int(np.trace(D @ D @ D) // 6)


def _sym_graph(src, dst, n, fmt, block=32):
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    return GraphBuilder(n).add_edges("R", s, d).build(fmt=fmt, block=block)


PETERSEN_EDGES = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0),       # outer C5
                  (5, 7), (7, 9), (9, 6), (6, 8), (8, 5),       # inner star
                  (0, 5), (1, 6), (2, 7), (3, 8), (4, 9)]       # spokes

GOLDEN_GRAPHS = {
    # complete graph K4: C(4,3) = 4 triangles
    "K4": ([(i, j) for i in range(4) for j in range(i + 1, 4)], 4, 4),
    # 5-cycle: girth 5, no triangles
    "C5": ([(i, (i + 1) % 5) for i in range(5)], 5, 0),
    # Petersen graph: girth 5, no triangles
    "petersen": (PETERSEN_EDGES, 10, 0),
    # complete bipartite K33: bipartite graphs are triangle-free
    "K33": ([(i, 3 + j) for i in range(3) for j in range(3)], 6, 0),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_GRAPHS))
@pytest.mark.parametrize("fmt", ["bsr", "dense"])
def test_triangle_count_golden(name, fmt):
    edges, n, want = GOLDEN_GRAPHS[name]
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    assert _tri_numpy(src, dst, n) == want          # the golden is golden
    g = _sym_graph(src, dst, n, fmt, block=8)
    assert int(alg.triangle_count(g.relations["R"].A)) == want


@pytest.mark.parametrize("fmt", ["bsr", "dense"])
def test_triangle_count_rmat_golden(fmt):
    from repro.graph.datagen import rmat_edges
    src, dst, n = rmat_edges(scale=7, edge_factor=6, seed=123)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    g = _sym_graph(src, dst, n, fmt, block=32)
    assert int(alg.triangle_count(g.relations["R"].A)) == \
        _tri_numpy(src, dst, n)
