"""End-to-end behaviour: the paper's workload through the full stack.

Graph500 RMAT graph -> Database -> Cypher k-hop queries -> batched server,
with BSR (MXU path) and ELL (gather path) agreeing with each other and with
the pure-python reference.
"""
import numpy as np
import pytest

from repro.engine import Database, QueryServer
from repro.graph.datagen import rmat_graph
from repro.query.executor import execute
from repro.query.reference import execute_ref


@pytest.fixture(scope="module")
def rmat_pair():
    # same RMAT edges in both formats
    bsr = rmat_graph(scale=8, edge_factor=8, seed=42, fmt="bsr", block=64)
    ell = rmat_graph(scale=8, edge_factor=8, seed=42, fmt="ell")
    return bsr, ell


@pytest.mark.parametrize("k", [1, 2, 3, 6])
def test_khop_bsr_ell_reference_agree(rmat_pair, k):
    bsr, ell = rmat_pair
    rng = np.random.default_rng(k)
    seeds = rng.integers(0, bsr.n, size=5)
    for s in seeds:
        q = (f"MATCH (a)-[:KNOWS*1..{k}]->(b) WHERE id(a) = {s} "
             f"RETURN count(DISTINCT b)")
        got_bsr = execute(bsr, q).scalar()
        got_ell = execute(ell, q).scalar()
        want = execute_ref(bsr, q).scalar()
        assert got_bsr == got_ell == want, f"k={k} seed={s}"


def test_database_end_to_end_graph500(tmp_path):
    db = Database(data_dir=str(tmp_path))
    db.load_graph("g500", rmat_graph(scale=7, edge_factor=8, seed=1, fmt="bsr",
                                     block=64))
    res = db.query("g500", "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) IN "
                           "[0, 1, 2, 3] RETURN a, count(DISTINCT b)")
    assert len(res.rows) == 4
    assert all(cnt >= 0 for _, cnt in res.rows)
    # EXPLAIN shows the algebraic plan
    txt = db.explain("g500", "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 0 "
                             "RETURN count(DISTINCT b)")
    assert "ConditionalTraverse" in txt


def test_server_throughput_batching_300_seeds(rmat_pair):
    """The paper's single-request benchmark setup: 300 seeds, k=2."""
    bsr, _ = rmat_pair
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, bsr.n, size=300)
    srv = QueryServer(bsr, max_batch=512)
    qids = [srv.submit(f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = {s} "
                       f"RETURN count(DISTINCT b)") for s in seeds]
    out = srv.flush()
    assert srv.stats["batches"] == 1 and srv.stats["queries"] == 300
    # spot-check five against the reference
    for i in rng.choice(300, size=5, replace=False):
        q = (f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = {seeds[i]} "
             f"RETURN count(DISTINCT b)")
        assert out[qids[i]].scalar() == execute_ref(bsr, q).scalar()


def test_no_timeouts_no_oom_style_robustness(rmat_pair):
    """Paper: 'none of the queries timed out ... none created OOM'. Run the
    deep k=6 hop on every-format and ensure sane bounded results."""
    bsr, ell = rmat_pair
    for g in (bsr, ell):
        res = execute(g, "MATCH (a)-[:KNOWS*1..6]->(b) WHERE id(a) = 10 "
                         "RETURN count(DISTINCT b)")
        assert 0 <= res.scalar() < g.n
