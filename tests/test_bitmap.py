"""Bitmap-packed frontier conformance: packed == unpacked, bit for bit.

The packed boolean route (core.bitmap behind grb, docs/API.md §Bitmap) is
an execution detail — so every test here is differential: force the policy
on and off (`grb.packed_frontiers`) and require *exact* equality on the
golden graph zoo (K4, C5, Petersen, RMAT s6-s8), across formats, mask /
complement / accum blends, transposes, algorithms (BFS / k-hop / WCC), and
both session meshes. The sharded payload claim is pinned two ways: the
words-per-frontier accounting (`bitmap.payload_bytes`) and the all-gather
result bytes read off the lowered HLO of the mesh mxm (>= 8x smaller).

Single-device tests run in tier-1; the mesh grid carries the `distributed`
marker (forced 8-device topology — `make test-dist`, or tier-1's
subprocess wrapper).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap, grb, ops as cops, semiring as S
from repro.core.ell import ELL
from repro.core.grb import Descriptor
from repro.graph.datagen import rmat_graph

pytestmark = pytest.mark.bitmap


# -- graph zoo (the test_sharded_grb golden set) ------------------------------
def _undirected(n, edges):
    D = np.zeros((n, n), np.float32)
    for a, b in edges:
        D[a, b] = D[b, a] = 1.0
    return D


def _graph_dense(name: str) -> np.ndarray:
    if name == "k4":
        return 1.0 - np.eye(4, dtype=np.float32)
    if name == "c5":
        return _undirected(5, [(i, (i + 1) % 5) for i in range(5)])
    if name == "petersen":
        return _undirected(10, [(i, (i + 1) % 5) for i in range(5)]
                           + [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
                           + [(i, 5 + i) for i in range(5)])
    scale = int(name.split("_s")[1])
    g = rmat_graph(scale=scale, edge_factor=8, seed=scale, fmt="ell")
    D = np.asarray(g.relations["KNOWS"].A.to_dense())
    return (D != 0).astype(np.float32)


GRAPHS = ("k4", "c5", "petersen", "rmat_s6", "rmat_s7", "rmat_s8")
_CACHE: dict = {}


def _dense_of(name):
    if name not in _CACHE:
        _CACHE[name] = _graph_dense(name)
    return _CACHE[name]


def _bool_frontier(n, f, seed=0, p=0.2):
    rng = np.random.default_rng(seed)
    return (rng.random((n, f)) < p).astype(np.float32)


F = 40   # deliberately not a multiple of 32: exercises word padding


def _descriptors(n, f, seed):
    M = jnp.asarray(_bool_frontier(n, f, seed=seed + 100, p=0.5))
    out = jnp.asarray(_bool_frontier(n, f, seed=seed + 200, p=0.3))
    return [
        ("null", grb.NULL, None),
        ("mask", Descriptor(mask=M), None),
        ("mask_comp", Descriptor(mask=M, complement=True), None),
        ("transpose", grb.TRANSPOSE_A, None),
        ("mask_T", Descriptor(mask=M, complement=True, transpose_a=True),
         None),
        ("accum_out", Descriptor(mask=M, accum=S.OR), out),
        ("replace", Descriptor(mask=M, replace=True), out),
    ]


# -- pack / unpack primitives -------------------------------------------------
@pytest.mark.parametrize("f", [1, 7, 31, 32, 33, 40, 64, 100])
def test_pack_unpack_roundtrip(f):
    rng = np.random.default_rng(f)
    X = (rng.random((23, f)) < 0.4).astype(np.float32)
    Xw = bitmap.pack(jnp.asarray(X))
    assert Xw.dtype == jnp.uint32
    assert Xw.shape == (23, bitmap.n_words(f))
    np.testing.assert_array_equal(np.asarray(bitmap.unpack(Xw, f)), X)
    # popcount: per-word set bits sum to the frontier's population
    assert int(np.asarray(bitmap.popcount(Xw)).sum()) == int(X.sum())
    np.testing.assert_array_equal(
        np.asarray(bitmap.reduce_or_columns(Xw, f)), X.sum(axis=0))


def test_pack_is_structural_not_boolean():
    # any nonzero packs as 1 — the or_and stored-iff-nonzero convention
    X = np.array([[0.0, 2.5, -3.0, 0.0, 1.0]], np.float32)
    got = np.asarray(bitmap.unpack(bitmap.pack(jnp.asarray(X)), 5))
    np.testing.assert_array_equal(got, (X != 0).astype(np.float32))


def test_word_algebra_matches_set_algebra():
    rng = np.random.default_rng(0)
    A = (rng.random((9, F)) < 0.4).astype(np.float32)
    B = (rng.random((9, F)) < 0.4).astype(np.float32)
    Aw, Bw = bitmap.pack(jnp.asarray(A)), bitmap.pack(jnp.asarray(B))
    for fn, op in [(bitmap.word_or, np.maximum),
                   (bitmap.word_and, lambda a, b: a * b),
                   (bitmap.word_andnot, lambda a, b: a * (1 - b))]:
        np.testing.assert_array_equal(
            np.asarray(bitmap.unpack(fn(Aw, Bw), F)), op(A, B))


def test_nibble_words_sum_carry_free():
    # simulate the transposed-form collective: per-shard 0/1 partials summed
    # across the maximum shard count must saturate back to the exact OR
    rng = np.random.default_rng(1)
    parts = (rng.random((bitmap.NIBBLE_MAX_SHARDS, 6, 24)) < 0.3)
    summed = sum(np.asarray(bitmap.pack_nibbles(jnp.asarray(p)))
                 for p in parts)
    want = parts.any(axis=0)
    got = np.asarray(bitmap.unpack_nibbles(jnp.asarray(summed), 24))
    np.testing.assert_array_equal(got, want)


def test_payload_accounting():
    # the words-per-frontier regression: a packed frontier row is ceil(F/32)
    # uint32 words vs F float32 lanes — >= 8x less wire from F = 8 on
    for f in (8, 32, 40, 64, 256):
        assert bitmap.payload_bytes(100, f, packed=True) == \
            100 * bitmap.n_words(f) * 4
        assert bitmap.payload_reduction(f) >= 8
    assert bitmap.payload_reduction(256) == 32
    assert bitmap.payload_reduction(4) < 8          # why the policy floor


# -- policy -------------------------------------------------------------------
# Counter pins below request `fresh_trace` (conftest): pack_calls has
# trace-time semantics, so without cache isolation a pin can pass vacuously
# against a compilation an earlier test left behind.
def test_trace_time_counters_need_cache_isolation(fresh_trace):
    # the mechanism itself: a jitted caller counts at trace, a jit-cache hit
    # re-runs the op without re-counting, and clearing the caches restores
    # counting — the reason every pin in this file takes `fresh_trace`.
    import jax

    f = jax.jit(lambda x: bitmap.pack(x))
    x = jnp.ones((4, 8), dtype=jnp.float32)
    c0 = bitmap.pack_calls()
    np.asarray(f(x))
    assert bitmap.pack_calls() == c0 + 1, "fresh trace must count"
    np.asarray(f(x))
    assert bitmap.pack_calls() == c0 + 1, \
        "cache hit re-runs without counting — the vacuous-pass mode"
    fresh_trace()
    np.asarray(f(x))
    assert bitmap.pack_calls() == c0 + 2, "isolation restores counting"


def test_policy_width_floor_and_overrides(fresh_trace):
    D = _dense_of("rmat_s6")
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    wide = jnp.asarray(_bool_frontier(D.shape[0], grb.AUTO_PACK_MIN_WIDTH))
    narrow = wide[:, :grb.AUTO_PACK_MIN_WIDTH - 1]
    fresh_trace()
    c0 = bitmap.pack_calls()
    grb.mxm(h, narrow, S.OR_AND)
    assert bitmap.pack_calls() == c0, "below the floor must stay unpacked"
    grb.mxm(h, wide, S.OR_AND)
    assert bitmap.pack_calls() > c0, "at the floor must pack"
    c1 = bitmap.pack_calls()
    with grb.packed_frontiers("off"):
        grb.mxm(h, wide, S.OR_AND)
    assert bitmap.pack_calls() == c1
    with grb.packed_frontiers("on"):
        grb.mxv(h, wide[:, 0], S.OR_AND)        # width-1 forced on
    assert bitmap.pack_calls() > c1
    with pytest.raises(ValueError):
        with grb.packed_frontiers("sideways"):
            pass


def test_policy_skips_bsr_and_other_semirings(fresh_trace):
    D = _dense_of("rmat_s6")
    wide = jnp.asarray(_bool_frontier(D.shape[0], F))
    fresh_trace()
    c0 = bitmap.pack_calls()
    grb.mxm(grb.GBMatrix.from_dense(D, fmt="bsr", block=64), wide, S.OR_AND)
    grb.mxm(grb.GBMatrix.from_dense(D, fmt="ell"), wide, S.PLUS_TIMES)
    grb.mxm(grb.GBMatrix.from_dense(D, fmt="ell"), wide, S.MIN_PLUS)
    assert bitmap.pack_calls() == c0


# -- differential grid: packed vs unpacked, single device ---------------------
@pytest.mark.parametrize("fmt", ["dense", "ell"])
@pytest.mark.parametrize("name", GRAPHS)
def test_mxm_packed_matches_unpacked(name, fmt):
    D = _dense_of(name)
    n = D.shape[0]
    h = grb.GBMatrix.from_dense(D, fmt=fmt)
    X = jnp.asarray(_bool_frontier(n, F, seed=7))
    for dname, d, out in _descriptors(n, F, seed=3):
        with grb.packed_frontiers("off"):
            want = np.asarray(grb.mxm(h, X, S.OR_AND, d, out=out))
        with grb.packed_frontiers("on"):
            got = np.asarray(grb.mxm(h, X, S.OR_AND, d, out=out))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name} {fmt} {dname}")


@pytest.mark.parametrize("name", ["petersen", "rmat_s7"])
def test_mxv_vxm_packed_matches_unpacked(name):
    D = _dense_of(name)
    n = D.shape[0]
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    x = jnp.asarray(_bool_frontier(n, 1, seed=5)[:, 0])
    m = jnp.asarray(_bool_frontier(n, 1, seed=6)[:, 0])
    d = Descriptor(mask=m, complement=True)
    for op in (grb.mxv, grb.vxm):
        args = (h, x) if op is grb.mxv else (x, h)
        with grb.packed_frontiers("off"):
            want = np.asarray(op(*args, S.OR_AND, d))
        with grb.packed_frontiers("on"):
            got = np.asarray(op(*args, S.OR_AND, d))
        np.testing.assert_array_equal(got, want, err_msg=f"{name} {op}")


def test_any_pair_packs_too(fresh_trace):
    D = _dense_of("c5")
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    X = jnp.asarray(_bool_frontier(5, F, seed=9))
    fresh_trace()
    c0 = bitmap.pack_calls()
    with grb.packed_frontiers("off"):
        want = np.asarray(grb.mxm(h, X, S.ANY_PAIR))
    got = np.asarray(grb.mxm(h, X, S.ANY_PAIR))
    assert bitmap.pack_calls() > c0
    np.testing.assert_array_equal(got, want)


# -- the Pallas kernel vs the XLA reference -----------------------------------
@pytest.mark.parametrize("name", ["petersen", "rmat_s6", "rmat_s7"])
def test_bitmap_kernel_interpret_matches_reference(name):
    from repro.kernels import bitmap_mxv
    D = _dense_of(name)
    e = ELL.from_dense(D)
    Xw = bitmap.pack(jnp.asarray(_bool_frontier(D.shape[0], F, seed=2)))
    want = np.asarray(cops.ell_mxm_packed(e, Xw))
    got = np.asarray(bitmap_mxv.ell_mxv_packed(e, Xw, interpret=True))
    np.testing.assert_array_equal(got, want)


# -- algorithms ride the packed path bit-identically --------------------------
def test_khop_bfs_wcc_packed_identical():
    from repro import algorithms as alg
    g = rmat_graph(scale=7, edge_factor=8, seed=0, fmt="ell")
    rel = g.relations["KNOWS"]
    seeds = np.random.default_rng(0).integers(0, g.n, size=64)
    runs = {}
    for mode in ("off", "on"):
        with grb.packed_frontiers(mode):
            runs[mode] = (
                np.asarray(alg.khop_counts(rel, seeds, k=3)),
                np.asarray(alg.bfs_levels(rel, seeds)),
                np.asarray(alg.wcc(rel)))
    for a, b, what in zip(runs["off"], runs["on"],
                          ("khop", "bfs_levels", "wcc")):
        np.testing.assert_array_equal(a, b, err_msg=what)


def test_wcc_labels_are_component_minima():
    # the min-seed closure formulation must reproduce min-label semantics
    D = _dense_of("petersen")                       # one component -> all 0
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    from repro.algorithms.wcc import wcc
    assert np.asarray(wcc(h)).tolist() == [0] * 10
    # two components + an isolate, tiny batch forces multiple closures
    D2 = np.zeros((7, 7), np.float32)
    D2[0, 1] = D2[1, 0] = D2[3, 4] = D2[4, 3] = D2[4, 5] = D2[5, 4] = 1.0
    got = np.asarray(wcc(grb.GBMatrix.from_dense(D2, fmt="ell"),
                         batch=2)).tolist()
    assert got == [0, 0, 2, 3, 3, 3, 6]


# -- sharded: both meshes, packed vs unpacked vs oracle -----------------------
def _sharded_pair(name, mesh):
    D = _dense_of(name)
    h = grb.GBMatrix.from_dense(D, fmt="ell", name=name)
    return h, grb.distribute(h, mesh)


@pytest.mark.distributed
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
@pytest.mark.parametrize("name", GRAPHS)
def test_sharded_packed_matches_unpacked(name, meshname, request):
    mesh = request.getfixturevalue(meshname)
    D = _dense_of(name)
    n = D.shape[0]
    h, sh = _sharded_pair(name, mesh)
    X = jnp.asarray(_bool_frontier(n, F, seed=13))
    for dname, d, out in _descriptors(n, F, seed=17):
        with grb.packed_frontiers("off"):
            want = np.asarray(grb.mxm(sh, X, S.OR_AND, d, out=out))
        with grb.packed_frontiers("on"):
            got = np.asarray(grb.mxm(sh, X, S.OR_AND, d, out=out))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name} {meshname} {dname}")
        oracle = np.asarray(grb.mxm(h, X, S.OR_AND, d, out=out))
        np.testing.assert_array_equal(got, oracle,
                                      err_msg=f"oracle {name} {dname}")


@pytest.mark.distributed
def test_sharded_packed_transposed_scatter(mesh222):
    # no linked transpose -> the nibble-word psum_scatter lowering
    from repro.core.shard import ShardedELL
    D = _dense_of("rmat_s7")
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    sh = grb.GBMatrix(ShardedELL.from_ell(h.store, mesh222))
    assert sh._T is None
    X = jnp.asarray(_bool_frontier(D.shape[0], F, seed=23))
    with grb.packed_frontiers("on"):
        got = np.asarray(grb.mxm(sh, X, S.OR_AND, grb.TRANSPOSE_A))
    want = np.asarray(grb.mxm(h, X, S.OR_AND, grb.TRANSPOSE_A))
    np.testing.assert_array_equal(got, want)


@pytest.mark.distributed
def test_sharded_khop_packed_identical(mesh222, mesh421):
    from repro import algorithms as alg
    g = rmat_graph(scale=7, edge_factor=8, seed=1, fmt="ell")
    rel = g.relations["KNOWS"]
    seeds = np.random.default_rng(3).integers(0, g.n, size=64)
    want = np.asarray(alg.khop_counts(rel, seeds, k=3))
    for mesh in (mesh222, mesh421):
        sh = grb.distribute(rel.A, mesh)
        for mode in ("off", "on"):
            with grb.packed_frontiers(mode):
                got = np.asarray(alg.khop_counts(sh, seeds, k=3))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{mesh} {mode}")


@pytest.mark.distributed
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
def test_allgather_payload_reduction_in_hlo(meshname, request):
    """The 8x claim, read off the lowered HLO: the row-form all-gather of a
    packed 256-wide frontier must move >= 8x fewer bytes than the float
    one, and exactly the words-per-frontier accounting predicts."""
    from repro.launch.dryrun import collective_stats
    mesh = request.getfixturevalue(meshname)
    D = _dense_of("rmat_s8")
    n = D.shape[0]
    f = 256
    sh = grb.distribute(grb.GBMatrix.from_dense(D, fmt="ell"), mesh)
    X = jax.ShapeDtypeStruct((n, f), jnp.float32)

    def gather_bytes(mode):
        with grb.packed_frontiers(mode):
            compiled = jax.jit(
                lambda x: grb.mxm(sh, x, S.OR_AND)).lower(X).compile()
        _, kinds = collective_stats(compiled.as_text())
        return kinds["all-gather"]["bytes"]

    unpacked, packed = gather_bytes("off"), gather_bytes("on")
    assert unpacked >= 8 * packed, (unpacked, packed)
    # exact words-per-frontier accounting: same gathered rows, F float32
    # lanes vs ceil(F/32) uint32 words (f=256 divides both paddings evenly)
    assert unpacked == packed * f // bitmap.n_words(f)
