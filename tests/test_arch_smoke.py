"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + a few decode steps on CPU; asserts shapes + finiteness.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, ShapeConfig, get_config
from repro.models import get_model

TINY_SHAPE = ShapeConfig("tiny", seq_len=16, global_batch=2, kind="train")


def tiny_of(name):
    """Shrink every assigned config to CPU scale, keeping its family quirks."""
    cfg = get_config(name)
    kw = dict(
        n_layers=2, d_model=32, d_ff=64, vocab=97, dtype="float32",
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), head_dim=8,
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, experts_per_token=cfg.experts_per_token)
    if cfg.family in ("rwkv6", "zamba2"):
        kw.update(ssm_heads=4, head_dim=8)
    if cfg.family == "zamba2":
        kw.update(n_layers=5, shared_attn_every=2, ssm_state=8,
                  n_heads=4, n_kv_heads=4)
    if cfg.family == "whisper":
        kw.update(encoder_layers=2, n_audio_frames=12, d_frontend=16,
                  n_kv_heads=4)
    if cfg.family == "llava":
        kw.update(n_image_tokens=4, d_frontend=16)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    return dataclasses.replace(cfg, **kw)


def make_batch(cfg, shape, rng):
    s = shape.seq_len
    if cfg.family == "llava":
        s = shape.seq_len - cfg.n_image_tokens
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (shape.global_batch, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (shape.global_batch, s)),
                              jnp.int32),
    }
    if cfg.family == "whisper":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(shape.global_batch, cfg.n_audio_frames,
                             cfg.d_frontend)), jnp.float32)
    if cfg.family == "llava":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(shape.global_batch, cfg.n_image_tokens,
                             cfg.d_frontend)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_loss_and_grad(name):
    cfg = tiny_of(name)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, TINY_SHAPE, rng)
    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g))), grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{name}: degenerate grads"


@pytest.mark.parametrize("name", ARCHS)
def test_decode_steps(name):
    cfg = tiny_of(name)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(1)
    B, T = 2, 12
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, T),
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step = jax.jit(model.decode_fn)
    for pos in range(3):
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        logits, cache = step(params, cache, {"tokens": tok}, pos)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all()), f"{name}: pos {pos} not finite"


def test_decode_matches_forward_dense():
    """Teacher-forced decode == full forward for a dense arch (cache math)."""
    cfg = tiny_of("qwen2-1.5b")
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(2)
    B, S = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    # full forward logits
    from repro.models import transformer as tr
    from repro.models import layers as Lx
    h = Lx.embed(params["embed"], tokens, cfg.d_model, cfg.embed_scale)
    h, _ = tr.forward(cfg, params, h, jnp.arange(S))
    full = Lx.unembed(params["embed"], h, cfg.logit_softcap, cfg.tie_embeddings)
    # step-by-step decode
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, S),
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step = jax.jit(model.decode_fn)
    outs = []
    for pos in range(S):
        logits, cache = step(params, cache, {"tokens": tokens[:, pos:pos+1]}, pos)
        outs.append(np.asarray(logits[:, 0]))
    np.testing.assert_allclose(np.stack(outs, axis=1), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_rwkv():
    """Same recurrence equality for the SSM family (state correctness)."""
    cfg = tiny_of("rwkv6-3b")
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(3)
    B, S = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import rwkv6 as rw
    full, _ = rw.forward(cfg, params, tokens)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, S),
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step = jax.jit(model.decode_fn)
    outs = []
    for pos in range(S):
        logits, cache = step(params, cache, {"tokens": tokens[:, pos:pos+1]}, pos)
        outs.append(np.asarray(logits[:, 0]))
    np.testing.assert_allclose(np.stack(outs, axis=1), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer():
    """Mixtral-style SWA: decode beyond the window stays finite & bounded."""
    cfg = tiny_of("mixtral-8x7b")
    model = get_model(cfg)
    params = model.init(0)
    B = 2
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, 32),
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    assert cache[0].shape[2] == cfg.sliding_window  # ring capped
    step = jax.jit(model.decode_fn)
    rng = np.random.default_rng(4)
    for pos in range(cfg.sliding_window + 4):   # wrap the ring
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        logits, cache = step(params, cache, {"tokens": tok}, pos)
        assert bool(jnp.isfinite(logits).all())
