"""Unit tests for the dry-run analysis helpers (HLO parsing, roofline math)."""
import numpy as np


def _import_dr():
    # dryrun sets XLA_FLAGS via setdefault; importing here is safe because
    # conftest-less tests already initialized jax with 1 device.
    import os
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch import dryrun as dr
    return dr


def test_collective_stats_parses_hlo_text():
    dr = _import_dr()
    hlo = """
  %ag = bf16[2048,14336]{1,0} all-gather(%p0), replica_groups=...
  %ar = f32[16,4096]{1,0} all-reduce(%p1), to_apply=%sum
  %rs = f32[256,128]{1,0} reduce-scatter(%p2), dimensions={0}
  %a2a = s8[64,64]{1,0} all-to-all(%p3)
  %cp = f32[8]{0} collective-permute(%p4)
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""
    total, kinds = dr.collective_stats(hlo)
    want = (2048 * 14336 * 2 + 16 * 4096 * 4 + 256 * 128 * 4
            + 64 * 64 * 1 + 8 * 4)
    assert total == want
    assert kinds["all-gather"]["count"] == 1
    assert kinds["all-reduce"]["bytes"] == 16 * 4096 * 4
    assert "dot" not in kinds


def test_roofline_terms_and_dominance():
    dr = _import_dr()
    rl = dr.roofline(256, flops_dev=197e12, bytes_dev=819e9 * 2,
                     coll_bytes_dev=50e9 * 0.5)
    assert abs(rl["compute_s"] - 1.0) < 1e-9
    assert abs(rl["memory_s"] - 2.0) < 1e-9
    assert abs(rl["collective_s"] - 0.5) < 1e-9
    assert rl["dominant"] == "memory_s"
    assert rl["bound_s"] == rl["memory_s"]


def test_model_flops_conventions():
    from repro.configs.base import get_config
    dense = get_config("qwen2-1.5b")
    moe = get_config("mixtral-8x7b")
    # MoE active params strictly below total; dense equal
    assert moe.active_param_count() < moe.param_count()
    assert dense.active_param_count() == dense.param_count()
    # mixtral ~13B active of ~47B total (top-2 of 8) — sanity band
    ratio = moe.active_param_count() / moe.param_count()
    assert 0.2 < ratio < 0.45


def test_probe_plan_shapes():
    from benchmarks.probe import _probe_plans
    from repro.configs.base import get_config
    rows, evalr, reps = _probe_plans(get_config("qwen2-7b"))
    assert rows == [[1, 1], [1, 2]] and evalr == [1, 28]
    rows, evalr, reps = _probe_plans(get_config("whisper-medium"))
    assert evalr == [1, 24, 24] and len(reps) == 3
    rows, evalr, reps = _probe_plans(get_config("zamba2-1.2b"))
    # 38 layers, shared every 6 -> 7 sites
    assert evalr == [1, 38, 7]
    X = np.asarray(rows, dtype=float)
    assert np.linalg.matrix_rank(X) == 3  # solvable design
