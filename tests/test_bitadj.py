"""Bit-packed adjacency (core.bitadj) conformance: BitELL == ELL, bit for bit.

BitELL is the sixth storage kind — boolean adjacency as 32x32-edge uint32
tiles — and, like the packed frontier form, it is an *execution detail*: every
or_and/any_pair product must land bit-identically on what the ELL route
computes. So the suite is differential across the golden graph zoo (K4, C5,
Petersen, RMAT s6-s8) x {mxm, mxv, vxm} x packed/unpacked frontiers x the
descriptor blend grid, plus round-trips, reduces, triangle goldens, the
auto-format policy pins, and the Pallas kernel vs its XLA reference.

Sharded coverage (`distributed` marker) runs ShardedBitELL on both session
meshes against the single-device oracle and pins the wire-format claim off
the lowered HLO: the per-hop frontier all-gather of the bit route moves
>= 8x fewer bytes than the float route. The nibble-overflow regression
(transposed packed scatter past NIBBLE_MAX_SHARDS = 15 row shards) runs in a
forced 16-device subprocess — the build-time fallback must produce exact
results where the pre-fix code raised.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitadj, bitmap, grb, semiring as S
from repro.core.bitadj import BitELL
from repro.core.ell import ELL
from repro.core.grb import Descriptor
from repro.graph.datagen import rmat_graph

pytestmark = pytest.mark.bitadj

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- graph zoo (the test_bitmap golden set) -----------------------------------
def _undirected(n, edges):
    D = np.zeros((n, n), np.float32)
    for a, b in edges:
        D[a, b] = D[b, a] = 1.0
    return D


def _graph_dense(name: str) -> np.ndarray:
    if name == "k4":
        return 1.0 - np.eye(4, dtype=np.float32)
    if name == "c5":
        return _undirected(5, [(i, (i + 1) % 5) for i in range(5)])
    if name == "petersen":
        return _undirected(10, [(i, (i + 1) % 5) for i in range(5)]
                           + [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
                           + [(i, 5 + i) for i in range(5)])
    scale = int(name.split("_s")[1])
    g = rmat_graph(scale=scale, edge_factor=8, seed=scale, fmt="ell")
    D = np.asarray(g.relations["KNOWS"].A.to_dense())
    return (D != 0).astype(np.float32)


GRAPHS = ("k4", "c5", "petersen", "rmat_s6", "rmat_s7", "rmat_s8")
_CACHE: dict = {}


def _dense_of(name):
    if name not in _CACHE:
        _CACHE[name] = _graph_dense(name)
    return _CACHE[name]


def _bool_frontier(n, f, seed=0, p=0.2):
    rng = np.random.default_rng(seed)
    return (rng.random((n, f)) < p).astype(np.float32)


F = 40   # not a multiple of 32: exercises word and query-tile padding


def _descriptors(n, f, seed):
    M = jnp.asarray(_bool_frontier(n, f, seed=seed + 100, p=0.5))
    out = jnp.asarray(_bool_frontier(n, f, seed=seed + 200, p=0.3))
    return [
        ("null", grb.NULL, None),
        ("mask", Descriptor(mask=M), None),
        ("mask_comp", Descriptor(mask=M, complement=True), None),
        ("transpose", grb.TRANSPOSE_A, None),
        ("mask_T", Descriptor(mask=M, complement=True, transpose_a=True),
         None),
        ("accum_out", Descriptor(mask=M, accum=S.OR), out),
        ("replace", Descriptor(mask=M, replace=True), out),
    ]


def _pair(name):
    D = _dense_of(name)
    return (grb.GBMatrix.from_dense(D, fmt="bitadj", name=name + "_b"),
            grb.GBMatrix.from_dense(D, fmt="ell", name=name + "_e"))


# -- layout round-trips -------------------------------------------------------
@pytest.mark.parametrize("name", GRAPHS)
def test_roundtrip(name):
    D = _dense_of(name)
    b = BitELL.from_dense(D)
    assert b.tiles.dtype == jnp.uint32
    assert b.nnz == int((D != 0).sum())
    np.testing.assert_array_equal(np.asarray(b.to_dense()), D)
    np.testing.assert_array_equal(np.asarray(b.transpose().to_dense()), D.T)
    np.testing.assert_array_equal(np.asarray(b.to_ell().to_dense()), D)
    r, c, v = b.to_coo()
    got = np.zeros_like(D)
    got[r, c] = v
    np.testing.assert_array_equal(got, D)
    # occupied 32x32 tiles at 32 words each: even fully tiled the payload is
    # 1/32 of the dense float array, and sparse graphs store fewer tiles
    if name == "rmat_s8":
        assert b.payload_bytes < D.nbytes // 16


def test_from_coo_rejects_weights():
    with pytest.raises(TypeError):
        BitELL.from_coo(np.array([0]), np.array([1]),
                        np.array([2.5], np.float32), (4, 4))


# -- grb dispatch: bit-identical to the ELL route -----------------------------
@pytest.mark.parametrize("packmode", ["off", "on"])
@pytest.mark.parametrize("name", GRAPHS)
def test_mxm_matches_ell(name, packmode):
    hb, he = _pair(name)
    n = _dense_of(name).shape[0]
    X = jnp.asarray(_bool_frontier(n, F, seed=7))
    for dname, d, out in _descriptors(n, F, seed=3):
        with grb.packed_frontiers(packmode):
            got = np.asarray(grb.mxm(hb, X, S.OR_AND, d, out=out))
        want = np.asarray(grb.mxm(he, X, S.OR_AND, d, out=out))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{name} {packmode} {dname}")


@pytest.mark.parametrize("name", ["petersen", "rmat_s7"])
def test_mxv_vxm_match_ell(name):
    hb, he = _pair(name)
    n = _dense_of(name).shape[0]
    x = jnp.asarray(_bool_frontier(n, 1, seed=5)[:, 0])
    m = jnp.asarray(_bool_frontier(n, 1, seed=6)[:, 0])
    d = Descriptor(mask=m, complement=True)
    for mode in ("off", "on"):
        for op in (grb.mxv, grb.vxm):
            args_b = (hb, x) if op is grb.mxv else (x, hb)
            args_e = (he, x) if op is grb.mxv else (x, he)
            with grb.packed_frontiers(mode):
                got = np.asarray(op(*args_b, S.OR_AND, d))
            want = np.asarray(op(*args_e, S.OR_AND, d))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{name} {mode} {op}")


def test_any_pair_rides_words_too():
    hb, he = _pair("rmat_s6")
    n = _dense_of("rmat_s6").shape[0]
    X = jnp.asarray(_bool_frontier(n, F, seed=9))
    got = np.asarray(grb.mxm(hb, X, S.ANY_PAIR))
    want = np.asarray(grb.mxm(he, X, S.ANY_PAIR))
    np.testing.assert_array_equal(got, want)


def test_weighted_semirings_materialize_and_match():
    # BitELL carries structure only; non-indicator semirings go through the
    # cached ELL materialization and must agree on the unit-weight graph
    hb, he = _pair("rmat_s6")
    n = _dense_of("rmat_s6").shape[0]
    X = jnp.asarray(_bool_frontier(n, 8, seed=11) *
                    np.float32(2.0))          # non-0/1 payload
    for sr in (S.PLUS_TIMES, S.MIN_PLUS, S.PLUS_FIRST):
        got = np.asarray(grb.mxm(hb, X, sr))
        want = np.asarray(grb.mxm(he, X, sr))
        np.testing.assert_allclose(got, want, rtol=1e-6,
                                   err_msg=sr.name)


@pytest.mark.parametrize("axis", [None, 0, 1])
@pytest.mark.parametrize("monoid", ["plus", "or"])
def test_reduce_matches_ell(axis, monoid):
    hb, he = _pair("rmat_s7")
    mono = S.PLUS if monoid == "plus" else S.OR
    got = np.asarray(grb.reduce(hb, mono, axis=axis))
    want = np.asarray(grb.reduce(he, mono, axis=axis))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_ewise_falls_back_through_ell():
    hb, he = _pair("c5")
    got = grb.ewise_add(hb, he, S.PLUS)
    want = 2.0 * _dense_of("c5")
    np.testing.assert_allclose(np.asarray(got.to_dense()), want, rtol=1e-6)


# -- triangles: AND + popcount over tile pairs --------------------------------
def test_triangle_goldens():
    from repro.algorithms import triangle_count
    for name, want in (("k4", 4), ("c5", 0), ("petersen", 0)):
        hb, _ = _pair(name)
        assert int(np.asarray(triangle_count(hb))) == want, name


@pytest.mark.parametrize("name", ["rmat_s6", "rmat_s7", "rmat_s8"])
def test_triangles_match_ell_route(name):
    from repro.algorithms import triangle_count
    hb, he = _pair(name)
    D = _dense_of(name)
    got = int(np.asarray(triangle_count(hb)))
    assert got == int(np.asarray(triangle_count(he)))
    # the repo convention: closed edge-masked wedges / 6 (RMAT graphs keep
    # self-loops and aren't symmetric, so this is not trace(D^3)/6)
    assert got == int(((D @ D) * D).sum()) // 6


# -- algorithms ride the bit route end to end ---------------------------------
def test_bfs_khop_wcc_bit_identical():
    from repro import algorithms as alg
    hb, he = _pair("rmat_s7")
    n = _dense_of("rmat_s7").shape[0]
    seeds = np.random.default_rng(0).integers(0, n, size=48)
    with grb.packed_frontiers("on"):
        got = (np.asarray(alg.bfs_levels(hb, seeds)),
               np.asarray(alg.khop_counts(hb, seeds, k=3)),
               np.asarray(alg.wcc(hb)))
    want = (np.asarray(alg.bfs_levels(he, seeds)),
            np.asarray(alg.khop_counts(he, seeds, k=3)),
            np.asarray(alg.wcc(he)))
    for g, w, what in zip(got, want, ("bfs", "khop", "wcc")):
        np.testing.assert_array_equal(g, w, err_msg=what)


# -- auto-format policy -------------------------------------------------------
def test_auto_policy_pins():
    # boolean dense-ish blocks -> bit tiles pay off
    r = np.repeat(np.arange(64), 32)
    c = np.tile(np.arange(32), 64)
    assert bitadj.auto_bitadj_ok(r, c, None, (64, 64))
    assert bitadj.auto_bitadj_ok(r, c, np.ones(len(r), np.float32), (64, 64))
    # any non-unit weight disqualifies (structure-only storage)
    w = np.full(len(r), 1.5, np.float32)
    assert not bitadj.auto_bitadj_ok(r, c, w, (64, 64))
    # occupied-tile fill below AUTO_BITADJ_MIN_FILL: one edge per 32x32 tile
    n = 32 * 64
    diag = np.arange(0, n, 32)
    assert not bitadj.auto_bitadj_ok(diag, diag, None, (n, n))
    # widest-panel slots past AUTO_BITADJ_MAX_SLOTS: padding loses
    hub_c = np.arange(0, 32 * (bitadj.AUTO_BITADJ_MAX_SLOTS + 1), 32)
    hub_r = np.zeros_like(hub_c)
    assert not bitadj.auto_bitadj_ok(
        hub_r, hub_c, None, (hub_c[-1] + 1, hub_c[-1] + 1))


# -- the Pallas kernel vs the XLA reference -----------------------------------
@pytest.mark.parametrize("name", ["petersen", "rmat_s6", "rmat_s7"])
def test_bitadj_kernel_interpret_matches_reference(name):
    from repro.kernels import bitadj_mxv
    D = _dense_of(name)
    b = BitELL.from_dense(D)
    Xw = bitmap.pack(jnp.asarray(_bool_frontier(D.shape[0], F, seed=2)))
    want = np.asarray(bitadj.mxm_words(b, Xw))
    got = np.asarray(bitadj_mxv.bitadj_mxv_packed(b, Xw, interpret=True))
    np.testing.assert_array_equal(got, want)


# -- property sweep -----------------------------------------------------------
@pytest.mark.hypothesis
def test_random_coo_bit_identity():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 90), st.integers(0, 300), st.integers(0, 2**31 - 1))
    def go(n, m, seed):
        rng = np.random.default_rng(seed)
        r = rng.integers(0, n, size=m)
        c = rng.integers(0, n, size=m)
        D = np.zeros((n, n), np.float32)
        D[r, c] = 1.0
        b = BitELL.from_coo(r, c, None, (n, n))
        np.testing.assert_array_equal(np.asarray(b.to_dense()), D)
        X = (rng.random((n, 9)) < 0.3).astype(np.float32)
        want = ((D @ X) > 0).astype(np.float32)
        Yw = bitadj.mxm_words(b, bitmap.pack(jnp.asarray(X)))
        np.testing.assert_array_equal(
            np.asarray(bitmap.unpack(Yw, 9)), want)

    go()


# -- sharded: both meshes, vs the single-device oracle ------------------------
def _sharded_pair(name, mesh):
    hb, _ = _pair(name)
    return hb, grb.distribute(hb, mesh)


@pytest.mark.distributed
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
@pytest.mark.parametrize("name", GRAPHS)
def test_sharded_bit_matches_oracle(name, meshname, request):
    mesh = request.getfixturevalue(meshname)
    hb, sh = _sharded_pair(name, mesh)
    assert sh.fmt == "bitshard"
    n = _dense_of(name).shape[0]
    X = jnp.asarray(_bool_frontier(n, F, seed=13))
    for dname, d, out in _descriptors(n, F, seed=17):
        for mode in ("off", "on"):
            with grb.packed_frontiers(mode):
                got = np.asarray(grb.mxm(sh, X, S.OR_AND, d, out=out))
            oracle = np.asarray(grb.mxm(hb, X, S.OR_AND, d, out=out))
            np.testing.assert_array_equal(
                got, oracle, err_msg=f"{name} {meshname} {mode} {dname}")


@pytest.mark.distributed
def test_sharded_weighted_materializes(mesh222):
    hb, sh = _sharded_pair("rmat_s6", mesh222)
    n = _dense_of("rmat_s6").shape[0]
    X = jnp.asarray(_bool_frontier(n, 8, seed=19) * np.float32(3.0))
    got = np.asarray(grb.mxm(sh, X, S.PLUS_TIMES))
    want = np.asarray(grb.mxm(hb, X, S.PLUS_TIMES))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.distributed
def test_sharded_khop_and_triangles(mesh222, mesh421):
    from repro import algorithms as alg
    hb, _ = _pair("rmat_s7")
    n = _dense_of("rmat_s7").shape[0]
    seeds = np.random.default_rng(3).integers(0, n, size=48)
    want_k = np.asarray(alg.khop_counts(hb, seeds, k=3))
    want_t = int(np.asarray(alg.triangle_count(hb)))
    for mesh in (mesh222, mesh421):
        sh = grb.distribute(hb, mesh)
        with grb.packed_frontiers("on"):
            got_k = np.asarray(alg.khop_counts(sh, seeds, k=3))
        np.testing.assert_array_equal(got_k, want_k)
        assert int(np.asarray(alg.triangle_count(sh))) == want_t


@pytest.mark.distributed
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
def test_bit_allgather_payload_in_hlo(meshname, request):
    """The wire-format claim off the lowered HLO: the per-hop frontier
    all-gather of the fully bit-level route (ShardedBitELL + packed words)
    must move >= 8x fewer bytes than the float ELL route — and exactly the
    words-per-frontier accounting predicts (u32 words vs f32 lanes)."""
    from repro.launch.dryrun import collective_stats
    mesh = request.getfixturevalue(meshname)
    D = _dense_of("rmat_s8")
    n, f = D.shape[0], 256
    hb, sb = _sharded_pair("rmat_s8", mesh)
    se = grb.distribute(grb.GBMatrix.from_dense(D, fmt="ell"), mesh)
    X = jax.ShapeDtypeStruct((n, f), jnp.float32)

    def gather_bytes(sh, mode):
        with grb.packed_frontiers(mode):
            compiled = jax.jit(
                lambda x: grb.mxm(sh, x, S.OR_AND)).lower(X).compile()
        _, kinds = collective_stats(compiled.as_text())
        return kinds["all-gather"]["bytes"]

    float_route = gather_bytes(se, "off")
    bit_route = gather_bytes(sb, "on")
    assert float_route >= 8 * bit_route, (float_route, bit_route)
    assert float_route == bit_route * f // bitmap.n_words(f)
    # the bit route stays word-sized even with the packing policy off:
    # the adjacency side is bit-packed storage, not a frontier-policy choice
    assert gather_bytes(sb, "off") == bit_route


# -- nibble-overflow regression: 16 row shards in a forced subprocess ---------
_NIB16 = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import bitadj, bitmap, grb, shard, semiring as S
from repro.core.ell import ELL

mesh = Mesh(np.array(jax.devices()[:16]).reshape(16, 1, 1),
            ("data", "pod", "model"))
assert mesh.shape["data"] > bitmap.NIBBLE_MAX_SHARDS
rng = np.random.default_rng(1)
n, m, F = 160, 900, 64
r, c = rng.integers(0, n, m), rng.integers(0, n, m)
e = ELL.from_coo(r, c, np.ones(m, np.float32), (n, n))
D = np.asarray(e.to_dense())
X = (rng.random((n, F)) < 0.2).astype(np.float32)
oracle_T = ((D.T @ X) > 0).astype(np.float32)

# pre-fix: ShardedELL.mxm on 16 row shards silently dropped to the float
# route (or the lowering refused outright) — now the packed transposed form
# must stay word-in/word-out at any shard count and stay exact
s = shard.ShardedELL.from_ell(e, mesh)
got = np.asarray(shard.mxm(s, jnp.asarray(X), S.OR_AND,
                           transposed=True, packed=True))
assert np.array_equal(got, oracle_T), "packed transposed mxm wrong @16"
Yw = shard.mxm_words(s, bitmap.pack(jnp.asarray(X)), transposed=True)
assert np.array_equal(np.asarray(bitmap.unpack(Yw, F)), oracle_T), \
    "mxm_words transposed wrong @16"

# and the bit route composes on the same 16-way mesh
b = bitadj.BitELL.from_coo(r, c, None, (n, n))
sb = bitadj.ShardedBitELL.from_bitell(b, mesh)
Yb = bitadj.sharded_mxm_words(sb, bitmap.pack(jnp.asarray(X)))
oracle = ((D @ X) > 0).astype(np.float32)
assert np.array_equal(np.asarray(bitmap.unpack(Yb, F)), oracle), \
    "ShardedBitELL mxm_words wrong @16"
print("NIB16_OK")
"""


def test_nibble_overflow_falls_back_at_16_shards():
    """NIBBLE_MAX_SHARDS = 15: past it the nibble psum_scatter would carry
    between lanes (wrong, not just slow). The lowering must detect the mesh
    geometry at build time and take the unpacked-scatter fallback — exact
    results on a 16-row-shard topology where the pre-fix path raised."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _NIB16], cwd=ROOT, env=env,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0 and "NIB16_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
