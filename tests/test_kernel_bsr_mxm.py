"""Pallas bsr_mxm kernel (interpret mode) vs pure-jnp oracle.

Sweeps shapes x block sizes x F widths x semirings x masks, per the brief.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSR, semiring as S
from repro.kernels import ops as kops
from repro.kernels.ref import bsr_mxm_ref

ALL_SR = ["plus_times", "or_and", "plus_pair", "min_plus", "max_plus", "plus_first"]


def make_case(n, m, f, nnz, block, seed, weighted=True):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, size=nnz)
    c = rng.integers(0, m, size=nnz)
    key = r * m + c
    _, idx = np.unique(key, return_index=True)
    r, c = r[idx], c[idx]
    v = rng.uniform(0.5, 2.0, size=r.shape[0]) if weighted else np.ones(r.shape[0])
    A = BSR.from_coo(r, c, v, (n, m), block=block)
    X = np.where(rng.uniform(size=(m, f)) < 0.35,
                 rng.uniform(0.5, 2.0, size=(m, f)), 0.0).astype(np.float32)
    return A, jnp.asarray(X)


@pytest.mark.parametrize("srname", ALL_SR)
def test_kernel_semirings(srname):
    sr = S.get(srname)
    A, X = make_case(96, 96, 16, 500, block=32, seed=0)
    got = kops.bsr_mxm(A, X, sr, interpret=True)
    want = bsr_mxm_ref(A, X, sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 64, 8, 200, 32),
                                   (130, 70, 5, 300, 32),
                                   (256, 256, 33, 2000, 64),
                                   (100, 260, 130, 900, 64),
                                   (32, 32, 1, 40, 16)])
def test_kernel_shape_sweep(shape):
    n, m, f, nnz, block = shape
    sr = S.PLUS_TIMES
    A, X = make_case(n, m, f, nnz, block, seed=n + m)
    got = kops.bsr_mxm(A, X, sr, interpret=True, f_tile=64)
    want = bsr_mxm_ref(A, X, sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_kernel_dtype_sweep(dtype):
    rng = np.random.default_rng(7)
    n = m = 64
    r = rng.integers(0, n, size=300)
    c = rng.integers(0, m, size=300)
    A = BSR.from_coo(r, c, None, (n, m), block=32, dtype=dtype)  # 0/1 structural
    X = (rng.uniform(size=(m, 8)) < 0.4).astype(np.float32)
    got = kops.bsr_mxm(A, jnp.asarray(X), S.OR_AND, interpret=True)
    want = bsr_mxm_ref(A, jnp.asarray(X), S.OR_AND)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("complement", [False, True])
def test_kernel_masked(complement):
    rng = np.random.default_rng(3)
    A, X = make_case(96, 96, 12, 600, block=32, seed=3)
    mask = jnp.asarray((rng.uniform(size=(96, 12)) < 0.5).astype(np.int8))
    for srname in ["or_and", "plus_times", "min_plus"]:
        sr = S.get(srname)
        got = kops.bsr_mxm(A, X, sr, mask=mask, complement=complement,
                           interpret=True)
        want = bsr_mxm_ref(A, X, sr, mask=mask, complement=complement)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5, err_msg=srname)


@pytest.mark.tpu_only
def test_kernel_compiled_mosaic():
    """The non-interpret (compiled) kernel path — only meaningful on TPU."""
    A, X = make_case(256, 256, 128, 3000, block=128, seed=42)
    got = kops.bsr_mxm(A, X, S.PLUS_TIMES, interpret=False)
    want = bsr_mxm_ref(A, X, S.PLUS_TIMES)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_kernel_empty_rows_and_padding():
    # rows in [0, 32) and [64, 96) empty; nnzb padding exercised
    r = np.array([40, 41, 42, 99])
    c = np.array([1, 2, 3, 4])
    A = BSR.from_coo(r, c, None, (128, 128), block=32)
    X = jnp.ones((128, 4), dtype=jnp.float32)
    got = kops.bsr_mxm(A, X, S.PLUS_TIMES, interpret=True)
    want = bsr_mxm_ref(A, X, S.PLUS_TIMES)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
