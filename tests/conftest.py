"""Shared test plumbing: the `tpu_only` marker.

Pallas kernels run in interpret mode on CPU (correctness), but tests marked
`tpu_only` exercise the compiled Mosaic path and would error, not fail, on
hosts without TPU support — so they are skipped up front.
"""
import jax
import pytest


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="tpu_only: requires a TPU backend (compiled Pallas path)")
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)
