"""Shared test plumbing: the `tpu_only`/`distributed` markers + the
multi-device harness.

The early-import hook below MUST run before jax initializes anywhere in the
session: a fake multi-device CPU topology can only be forced through
XLA_FLAGS at backend init. `make test-dist` (REPRO_FORCE_DEVICES=8) takes
this path directly; plain tier-1 `pytest -x -q` keeps its single-device jax
and runs the distributed suite through the env-guarded subprocess wrapper in
test_distributed.py instead.

Pallas kernels run in interpret mode on CPU (correctness), but tests marked
`tpu_only` exercise the compiled Mosaic path and would error, not fail, on
hosts without TPU support — so they are skipped up front. Tests marked
`distributed` need the 8-device topology and are skipped when it is absent.
"""
import os

if os.environ.get("REPRO_FORCE_DEVICES"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count="
            + os.environ["REPRO_FORCE_DEVICES"]).strip()

import jax
import numpy as np
import pytest

DIST_DEVICES = 8


def mesh8(shape, names):
    """Mesh over the first 8 local devices — robust to a topology forced
    larger than 8 (jax.make_mesh would demand the axis product equal the
    full device count)."""
    return jax.sharding.Mesh(
        np.array(jax.devices()[:DIST_DEVICES]).reshape(shape), names)


def pytest_collection_modifyitems(config, items):
    if jax.default_backend() != "tpu":
        skip_tpu = pytest.mark.skip(
            reason="tpu_only: requires a TPU backend (compiled Pallas path)")
        for item in items:
            if "tpu_only" in item.keywords:
                item.add_marker(skip_tpu)
    if jax.device_count() < DIST_DEVICES:
        skip_dist = pytest.mark.skip(
            reason=f"distributed: needs {DIST_DEVICES} devices — run "
                   f"`make test-dist` (REPRO_FORCE_DEVICES=8); tier-1 covers "
                   f"this suite via the subprocess wrapper in "
                   f"test_distributed.py")
        for item in items:
            if "distributed" in item.keywords:
                item.add_marker(skip_dist)


# -- cache isolation for trace-time counter pins ------------------------------
# The observability counters (core.bitmap.pack_calls, core.bsr.densify_calls /
# host_numeric_calls, grb.host_transfers for mesh lowerings) bump at *trace*
# time: a jit-cache hit re-runs the op without re-counting, so a pin that
# asserts "this route packs / never densifies" proves nothing when an earlier
# test already traced the same shapes — it passes vacuously against stale
# compilations. Counter-pin tests request this fixture and call it before each
# measured section; it drops every jit trace/compilation cache so the pinned
# call is guaranteed to trace (and therefore count) afresh.
@pytest.fixture
def fresh_trace():
    def _fresh():
        jax.clear_caches()
    _fresh()
    return _fresh


# -- the meshes the sharded suite runs on -------------------------------------
# Both use all 8 forced devices: 2x2x2 exercises a frontier sharded over
# pod x model with 2-way row blocks; 4x2x1 puts 4-way row blocks under a
# 2-way frontier (the degenerate "model" axis checks size-1 axes too).
@pytest.fixture(scope="session")
def mesh222():
    if jax.device_count() < DIST_DEVICES:
        pytest.skip("needs the forced 8-device topology")
    return mesh8((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def mesh421():
    if jax.device_count() < DIST_DEVICES:
        pytest.skip("needs the forced 8-device topology")
    return mesh8((4, 2, 1), ("data", "pod", "model"))
