"""Core GraphBLAS ops: BSR/ELL round-trips + semiring matmul vs dense oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSR, ELL, ops, semiring as S

RNG = np.random.default_rng(0)


def rand_coo(n, m, nnz, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, size=nnz)
    c = rng.integers(0, m, size=nnz)
    # dedup
    key = r * m + c
    _, idx = np.unique(key, return_index=True)
    r, c = r[idx], c[idx]
    v = rng.uniform(0.5, 2.0, size=r.shape[0]) if weighted else np.ones(r.shape[0])
    return r, c, v


def dense_of(r, c, v, shape):
    A = np.zeros(shape, dtype=np.float32)
    A[r, c] = v
    return A


ALL_SR = ["plus_times", "or_and", "plus_pair", "min_plus", "max_plus", "plus_first"]


@pytest.mark.parametrize("fmt", ["bsr", "ell"])
def test_roundtrip(fmt):
    r, c, v = rand_coo(200, 150, 900, seed=1)
    D = dense_of(r, c, v, (200, 150))
    M = (BSR if fmt == "bsr" else ELL).from_coo(r, c, v, (200, 150), **({"block": 64} if fmt == "bsr" else {}))
    np.testing.assert_allclose(np.asarray(M.to_dense()), D, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(M.transpose().to_dense()), D.T, rtol=1e-6)
    assert M.nnz == len(r)


@pytest.mark.parametrize("srname", ALL_SR)
@pytest.mark.parametrize("fmt", ["bsr", "ell", "dense"])
def test_mxm_matches_oracle(srname, fmt):
    sr = S.get(srname)
    n, m, f = 130, 170, 7
    r, c, v = rand_coo(n, m, 800, seed=2)
    D = dense_of(r, c, v, (n, m))
    X = np.where(RNG.uniform(size=(m, f)) < 0.3,
                 RNG.uniform(0.5, 2.0, size=(m, f)), 0.0).astype(np.float32)
    want = S.dense_mxm(S.structural_dense(jnp.asarray(D), sr), jnp.asarray(X), sr)
    if fmt == "bsr":
        A = BSR.from_coo(r, c, v, (n, m), block=64)
    elif fmt == "ell":
        A = ELL.from_coo(r, c, v, (n, m))
    else:
        A = jnp.asarray(D)
    got = ops.mxm(A, jnp.asarray(X), sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_empty_block_rows_covered():
    # rows 0..63 empty (block 64): padding tiles must still init the output
    r = np.array([100, 101, 120])
    c = np.array([3, 50, 90])
    A = BSR.from_coo(r, c, None, (128, 128), block=64)
    X = np.ones((128, 4), dtype=np.float32)
    y = ops.mxm(A, jnp.asarray(X), S.PLUS_TIMES)
    assert y.shape == (128, 4)
    np.testing.assert_allclose(np.asarray(y)[:64], 0.0)


def test_mask_and_accum():
    sr = S.PLUS_TIMES
    A = jnp.asarray(RNG.uniform(size=(8, 8)).astype(np.float32))
    X = jnp.asarray(RNG.uniform(size=(8, 3)).astype(np.float32))
    mask = jnp.asarray((RNG.uniform(size=(8, 3)) < 0.5).astype(np.int8))
    raw = np.asarray(S.dense_mxm(A, X, sr))
    got = np.asarray(ops.mxm(A, X, sr, mask=mask))
    np.testing.assert_allclose(got, raw * np.asarray(mask), rtol=1e-6)
    got_c = np.asarray(ops.mxm(A, X, sr, mask=mask, complement=True))
    np.testing.assert_allclose(got_c, raw * (1 - np.asarray(mask)), rtol=1e-6)
    old = jnp.ones((8, 3), dtype=jnp.float32)
    got_a = np.asarray(ops.mxm(A, X, sr, mask=mask, accum=S.PLUS, C=old))
    np.testing.assert_allclose(got_a, 1.0 + raw * np.asarray(mask), rtol=1e-6)


def test_mxv_vxm_consistency():
    r, c, v = rand_coo(96, 96, 400, seed=3)
    A = BSR.from_coo(r, c, v, (96, 96), block=32)
    D = dense_of(r, c, v, (96, 96))
    x = RNG.uniform(size=96).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ops.mxv(A, jnp.asarray(x), S.PLUS_TIMES)),
                               D @ x, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ops.vxm(jnp.asarray(x), A, S.PLUS_TIMES)),
                               x @ D, rtol=1e-4, atol=1e-4)


def test_auto_format():
    from repro.core.bitadj import BitELL
    # dense-ish *boolean* blocks -> BitELL (structure is the whole payload);
    # the same structure with real weights -> BSR; scattered hypersparse -> ELL
    r = np.repeat(np.arange(64), 32)
    c = np.tile(np.arange(32), 64)
    assert isinstance(ops.auto_format(r, c, None, (64, 64), block=64), BitELL)
    w = np.linspace(1.0, 2.0, len(r)).astype(np.float32)
    assert isinstance(ops.auto_format(r, c, w, (64, 64), block=64), BSR)
    rng = np.random.default_rng(0)
    r2 = rng.integers(0, 100_000, size=500)
    c2 = rng.integers(0, 100_000, size=500)
    assert isinstance(ops.auto_format(r2, c2, None, (100_000, 100_000)), ELL)
