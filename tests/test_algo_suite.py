"""Cross-format oracle conformance grid for the algorithm breadth suite.

Every algorithm family added by the `CALL algo.*` tentpole — betweenness +
closeness centrality (batched Brandes), jaccard/cosine/overlap similarity,
and label-propagation community detection — checked against pure-NumPy
oracles on a named-graph zoo (K4, C5, Petersen, K3,3) plus RMAT s6-s8,
across every storage format (dense / BSR / ELL / BitELL). Boolean-derived
outputs are exact; float scores get atol 1e-5 (betweenness 1e-4: its
delta-ratio sums are order-sensitive).

The sharded cells re-run the same workloads on both session meshes
(2x2x2 and 4x2x1): integer-count-derived outputs (closeness, similarity,
label propagation) must be BIT-IDENTICAL to local — plus_pair counts and
or_and levels are exact under any shard reduction order — while
betweenness (float dependency ratios, order-sensitive) gets allclose.
Every sharded hot loop is pinned to a zero `grb.host_transfers()` delta
and the BSR cells to a zero `bsr.densify_calls()` delta (under
`fresh_trace`, so a stale jit cache can't make the pin vacuous).

Also here: the zero-edge goldens (regression for the isolated-vertex
short-circuits in wcc/bfs/khop and each new algorithm), the property
sweep (hypothesis when installed, a seeded random sweep otherwise), and
the `CALL algo.*` end-to-end conformance through `engine.Database`.
"""
from collections import deque

import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms as alg
from repro.core import bsr as _bsr, grb
from repro.core.bitadj import BitELL
from repro.core.bsr import BSR
from repro.core.ell import ELL
from repro.engine.database import Database
from repro.graph.datagen import rmat_edges
from repro.graph.graph import GraphBuilder

pytestmark = pytest.mark.algos

try:                                    # property sweep: hypothesis when
    from hypothesis import given, settings, strategies as st  # installed,

    def _prop(f):
        return settings(max_examples=15, deadline=None)(
            given(seed=st.integers(0, 10 ** 6))(f))
except ImportError:                     # else a seeded random sweep
    def _prop(f):
        def wrapper():
            for seed in range(10):
                f(seed=seed)
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper


# -- NumPy oracles ------------------------------------------------------------
def _adj(D):
    return [np.nonzero(D[v])[0] for v in range(D.shape[0])]


def _bfs_np(adj, s, n):
    lvl = np.full(n, np.inf)
    lvl[s] = 0
    q = deque([s])
    order = [s]
    while q:
        u = q.popleft()
        for v in adj[u]:
            if not np.isfinite(lvl[v]):
                lvl[v] = lvl[u] + 1
                q.append(v)
                order.append(v)
    return lvl, order


def brandes_np(D, sources):
    """Reference Brandes: per-source BFS path counts + reversed dependency
    accumulation (directed, unit edges, endpoints excluded)."""
    n = D.shape[0]
    adj = _adj(D)
    bc = np.zeros(n)
    for s in sources:
        sigma = np.zeros(n)
        sigma[s] = 1.0
        dist = np.full(n, -1)
        dist[s] = 0
        order = []
        q = deque([s])
        while q:
            v = q.popleft()
            order.append(v)
            for w in adj[v]:
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    q.append(w)
                if dist[w] == dist[v] + 1:
                    sigma[w] += sigma[v]
        delta = np.zeros(n)
        for v in reversed(order):
            for w in adj[v]:
                if dist[w] == dist[v] + 1:
                    delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
            if v != s:
                bc[v] += delta[v]
    return bc


def closeness_np(D, sources):
    """Wasserman-Faust closeness over the reachable set."""
    n = D.shape[0]
    adj = _adj(D)
    out = []
    for s in sources:
        lvl, _ = _bfs_np(adj, s, n)
        fin = lvl[np.isfinite(lvl)]
        r, tot = len(fin), fin.sum()
        out.append((r - 1) ** 2 / ((n - 1) * tot) if tot > 0 else 0.0)
    return np.asarray(out, dtype=np.float64)


def sim_np(D, sources, kind):
    """Pairwise out-neighborhood set similarity (n, len(sources))."""
    n = D.shape[0]
    nbrs = [set(np.nonzero(D[v])[0]) for v in range(n)]
    out = np.zeros((n, len(sources)))
    for j, s in enumerate(sources):
        for v in range(n):
            m = len(nbrs[v] & nbrs[s])
            if m == 0:
                continue
            if kind == "jaccard":
                d = len(nbrs[v] | nbrs[s])
            elif kind == "cosine":
                d = np.sqrt(len(nbrs[v]) * len(nbrs[s]))
            else:
                d = min(len(nbrs[v]), len(nbrs[s]))
            out[v, j] = m / d
    return out


def lpa_np(D, max_iter=50):
    """Synchronous CDLP: both-direction + self vote, min tie-break."""
    n = D.shape[0]
    labels = np.arange(n)
    for _ in range(max_iter):
        new = labels.copy()
        for v in range(n):
            votes = {labels[v]: 1}
            for w in np.nonzero(D[v])[0]:
                votes[labels[w]] = votes.get(labels[w], 0) + 1
            for w in np.nonzero(D[:, v])[0]:
                votes[labels[w]] = votes.get(labels[w], 0) + 1
            top = max(votes.values())
            new[v] = min(l for l, c in votes.items() if c == top)
        if np.array_equal(new, labels):
            break
        labels = new
    return labels.astype(np.int32)


# -- the graph zoo ------------------------------------------------------------
def _undirected(pairs):
    src = np.asarray([a for a, b in pairs] + [b for a, b in pairs])
    dst = np.asarray([b for a, b in pairs] + [a for a, b in pairs])
    return src, dst


def _zoo_edges(name):
    if name == "K4":
        return 4, *_undirected([(i, j) for i in range(4)
                                for j in range(i + 1, 4)])
    if name == "C5":
        return 5, *_undirected([(i, (i + 1) % 5) for i in range(5)])
    if name == "petersen":
        pairs = ([(i, (i + 1) % 5) for i in range(5)]
                 + [(i, i + 5) for i in range(5)]
                 + [(5 + i, 5 + (i + 2) % 5) for i in range(5)])
        return 10, *_undirected(pairs)
    if name == "K33":
        return 6, *_undirected([(i, 3 + j) for i in range(3)
                                for j in range(3)])
    scale = int(name[len("rmat"):])
    src, dst, n = rmat_edges(scale, edge_factor=4, seed=scale)
    keep = src != dst
    return n, src[keep], dst[keep]


GRAPHS = ("K4", "C5", "petersen", "K33", "rmat6", "rmat7", "rmat8")
FORMATS = ("dense", "bsr", "ell", "bitadj")
_cells = {}


def _cell(name, fmt):
    """(dense oracle D, GBMatrix handle) for one grid cell, cached."""
    key = (name, fmt)
    if key not in _cells:
        n, src, dst = _zoo_edges(name)
        if fmt == "dense":
            D = np.zeros((n, n), dtype=np.float32)
            D[src, dst] = 1.0
            h = grb.GBMatrix(jnp.asarray(D))
        else:
            g = GraphBuilder(n).add_edges("R", src, dst).build(
                fmt=fmt, block=min(32, n))
            h = g.relations["R"].A
        D = np.zeros((n, n), dtype=np.float32)
        D[src, dst] = 1.0
        _cells[key] = (D, h)
    return _cells[key]


def _sources(n):
    """All vertices on the named graphs; a fixed stride sample on RMAT
    (the oracle is O(n*m) per source — sampled sources keep tier-1 fast
    while still batching wider than one packed word)."""
    return list(range(n)) if n <= 16 else list(range(0, n, max(1, n // 24)))


# -- the conformance grid -----------------------------------------------------
@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("name", GRAPHS)
def test_betweenness_grid(name, fmt):
    D, h = _cell(name, fmt)
    srcs = _sources(D.shape[0])
    got = np.asarray(alg.betweenness(h, sources=srcs))
    np.testing.assert_allclose(got, brandes_np(D, srcs),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("name", GRAPHS)
def test_closeness_grid(name, fmt):
    D, h = _cell(name, fmt)
    srcs = _sources(D.shape[0])
    got = np.asarray(alg.closeness(h, sources=srcs))
    np.testing.assert_allclose(got, closeness_np(D, srcs), atol=1e-5)


@pytest.mark.parametrize("kind", ("jaccard", "cosine", "overlap"))
@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("name", GRAPHS)
def test_similarity_grid(name, fmt, kind):
    D, h = _cell(name, fmt)
    srcs = _sources(D.shape[0])[:8]
    got = np.asarray(alg.similarity(h, srcs, kind))
    np.testing.assert_allclose(got, sim_np(D, srcs, kind), atol=1e-5)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("name", GRAPHS)
def test_labelprop_grid(name, fmt):
    D, h = _cell(name, fmt)
    got = np.asarray(alg.label_propagation(h))
    np.testing.assert_array_equal(got, lpa_np(D))


@pytest.mark.parametrize("name", ("petersen", "rmat6"))
def test_similarity_matrix_masked(name):
    """similarity_matrix = masked SpGEMM + sparse ewise: scores only on
    stored edge positions, equal to the pairwise oracle there. The A@A
    product counts common neighbors only on a symmetric adjacency, so the
    RMAT pattern is symmetrized first (the k-truss convention)."""
    D, _ = _cell(name, "ell")
    D = ((D + D.T) > 0).astype(np.float32)
    np.fill_diagonal(D, 0)
    h = _ell_of(D)
    n = D.shape[0]
    Sm = alg.similarity_matrix(h, "jaccard")
    r, c, v = Sm.store.to_coo()
    got = np.zeros((n, n))
    got[r, c] = v
    want = sim_np(D, list(range(n)), "jaccard") * D
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_betweenness_bsr_never_densifies(fresh_trace):
    """The whole centrality/similarity/labelprop stack on BSR adjacency is
    mxm + ewise on device carries: zero to_dense() anywhere."""
    D, h = _cell("rmat7", "bsr")
    srcs = _sources(D.shape[0])
    fresh_trace()
    d0 = _bsr.densify_calls()
    np.asarray(alg.betweenness(h, sources=srcs))
    np.asarray(alg.closeness(h, sources=srcs))
    np.asarray(alg.similarity(h, srcs[:8], "jaccard"))
    np.asarray(alg.label_propagation(h))
    assert _bsr.densify_calls() - d0 == 0


# -- zero-edge goldens --------------------------------------------------------
def _empty_handle(n, fmt):
    e = np.zeros(0, dtype=np.int64)
    w = np.zeros(0, dtype=np.float32)
    if fmt == "dense":
        return grb.GBMatrix(jnp.zeros((n, n), dtype=jnp.float32))
    if fmt == "bsr":
        return grb.GBMatrix(BSR.from_coo(e, e, w, (n, n), block=min(32, n)))
    if fmt == "ell":
        return grb.GBMatrix(ELL.from_coo(e, e, w, (n, n)))
    return grb.GBMatrix(BitELL.from_coo(e, e, None, (n, n)))


@pytest.mark.parametrize("fmt", FORMATS)
def test_zero_edge_goldens(fmt):
    """An entirely-isolated (zero-edge) graph: every algorithm answers
    from first principles without tracing a zero-trip hop loop — the
    regression for the wcc/bfs/khop short-circuits and the new families'
    empty-adjacency paths."""
    n = 7
    h = _empty_handle(n, fmt)
    np.testing.assert_array_equal(np.asarray(alg.wcc(h)), np.arange(n))
    lv = np.asarray(alg.bfs_levels(h, [3]))
    want = np.full((n, 1), np.inf, dtype=np.float32)
    want[3, 0] = 0.0
    np.testing.assert_array_equal(lv, want)
    np.testing.assert_array_equal(np.asarray(alg.khop_counts(h, [0, 3], 2)),
                                  np.zeros(2, dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(alg.betweenness(h)), np.zeros(n, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(alg.closeness(h)), np.zeros(n, dtype=np.float32))
    np.testing.assert_array_equal(
        np.asarray(alg.similarity(h, [0, 5], "jaccard")),
        np.zeros((n, 2), dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(alg.label_propagation(h)),
                                  np.arange(n, dtype=np.int32))


# -- sharded cells: both session meshes, bit-identity + transfer pins ---------
@pytest.fixture(scope="module")
def _sharded_refs():
    """Local ELL answers the mesh cells compare against (computed once)."""
    D, h = _cell("rmat7", "ell")
    srcs = _sources(D.shape[0])
    return {
        "h": h, "srcs": srcs,
        "bc": np.asarray(alg.betweenness(h, sources=srcs)),
        "cl": np.asarray(alg.closeness(h, sources=srcs)),
        "sim": np.asarray(alg.similarity(h, srcs, "jaccard")),
        "lp": np.asarray(alg.label_propagation(h)),
    }


def _mesh_cell(refs, mesh):
    sh = grb.distribute(refs["h"], mesh)
    x0 = grb.host_transfers()
    bc = alg.betweenness(sh, sources=refs["srcs"])
    cl = alg.closeness(sh, sources=refs["srcs"])
    sim = alg.similarity(sh, refs["srcs"], "jaccard")
    lp = alg.label_propagation(sh)
    # the transfer delta is read BEFORE materializing results: pulling an
    # answer is allowed, a gather inside the sharded hot loop is not
    dx = grb.host_transfers() - x0
    assert dx == 0, f"sharded hot loop gathered to host {dx}x"
    # integer-count-derived outputs are exact under any reduction order
    np.testing.assert_array_equal(np.asarray(cl), refs["cl"])
    np.testing.assert_array_equal(np.asarray(sim), refs["sim"])
    np.testing.assert_array_equal(np.asarray(lp), refs["lp"])
    # betweenness sums float delta ratios in shard order: allclose
    np.testing.assert_allclose(np.asarray(bc), refs["bc"],
                               atol=1e-3, rtol=1e-4)


@pytest.mark.distributed
def test_algorithms_sharded_mesh222(_sharded_refs, mesh222):
    _mesh_cell(_sharded_refs, mesh222)


@pytest.mark.distributed
def test_algorithms_sharded_mesh421(_sharded_refs, mesh421):
    _mesh_cell(_sharded_refs, mesh421)


# -- property sweep (hypothesis when installed, seeded sweep otherwise) -------
def _rand_digraph(seed, n=24, p=0.12):
    rng = np.random.default_rng(seed)
    D = (rng.random((n, n)) < p).astype(np.float32)
    np.fill_diagonal(D, 0)
    return D


def _ell_of(D):
    r, c = np.nonzero(D)
    return grb.GBMatrix(ELL.from_coo(r, c, None, D.shape))


@_prop
def test_prop_betweenness_off_path_zero(seed):
    """A vertex on no shortest path has betweenness exactly 0: sources
    (no in-DAG predecessors... they are excluded by definition) aside,
    any sink (no out-edges) or source-only vertex (no in-edges) can never
    be interior to a shortest path."""
    D = _rand_digraph(seed)
    bc = np.asarray(alg.betweenness(_ell_of(D)))
    interior_less = (D.sum(axis=1) == 0) | (D.sum(axis=0) == 0)
    assert np.all(bc[interior_less] == 0.0)
    assert np.all(bc >= 0.0)


@_prop
def test_prop_closeness_relabel_invariant(seed):
    """Closeness is a per-vertex structural score: permuting vertex ids
    permutes the scores and changes nothing else."""
    D = _rand_digraph(seed)
    n = D.shape[0]
    perm = np.random.default_rng(seed + 1).permutation(n)
    Dp = np.zeros_like(D)
    Dp[perm[:, None], perm[None, :]] = D        # Dp[perm[i],perm[j]]=D[i,j]
    base = np.asarray(alg.closeness(_ell_of(D)))
    relab = np.asarray(alg.closeness(_ell_of(Dp)))
    np.testing.assert_allclose(relab[perm], base, atol=1e-6)


@_prop
def test_prop_jaccard_symmetric_and_reflexive(seed):
    """jaccard(u, v) == jaccard(v, u), and a vertex pair with identical
    out-neighborhoods scores exactly 1.0 (we clone row 0 into row 1)."""
    D = _rand_digraph(seed)
    D[1, :] = D[0, :]
    D[0, 1] = D[1, 0] = D[0, 0] = D[1, 1] = 0
    h = _ell_of(D)
    S = np.asarray(alg.similarity(h, list(range(D.shape[0])), "jaccard"))
    np.testing.assert_allclose(S, S.T, atol=1e-6)
    if D[0].sum() > 0:
        assert S[0, 1] == pytest.approx(1.0)


@_prop
def test_prop_labelprop_respects_components(seed):
    """On a disjoint union of cliques of size >= 3, label propagation
    converges to one label per clique — the WCC labels exactly (a 2-clique
    is the known synchronous-CDLP oscillator: its two members trade labels
    forever, which is why the sweep draws >= 3). On any graph, a vertex's
    final label is the id of some member of its own weak component
    (labels never cross components)."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(3, 7, size=4)
    n = int(sizes.sum())
    D = np.zeros((n, n), dtype=np.float32)
    off = 0
    for s in sizes:
        D[off:off + s, off:off + s] = 1.0
        off += s
    np.fill_diagonal(D, 0)
    h = _ell_of(D)
    labels = np.asarray(alg.label_propagation(h))
    np.testing.assert_array_equal(labels, np.asarray(alg.wcc(h)))
    # general invariant on a random digraph
    Dr = _rand_digraph(seed)
    hr = _ell_of(Dr)
    comp = np.asarray(alg.wcc(hr))
    lab = np.asarray(alg.label_propagation(hr))
    assert np.all(comp[lab] == comp), "a label crossed a weak component"


# -- CALL algo.* end-to-end through engine.Database ---------------------------
def test_call_surface_through_database():
    """Every registered procedure served through `Database.query` answers
    exactly what the direct algorithm call computes — the Cypher-ish
    surface is a thin shell over the same device sweeps."""
    D, _ = _cell("rmat6", "ell")
    n = D.shape[0]
    r, c = np.nonzero(D)
    g = GraphBuilder(n).add_edges("R", r, c).build(fmt="ell")
    db = Database()
    db.load_graph("g", g)
    rel = g.relations["R"]

    res = db.query("g", "CALL algo.pagerank(rel: R, iters: 40)")
    assert res.columns == ["node", "score"] and len(res.rows) == n
    np.testing.assert_allclose(
        [s for _, s in res.rows], np.asarray(alg.pagerank(rel, iters=40)),
        atol=1e-6)

    res = db.query("g", "CALL algo.betweenness(rel: R) YIELD node, score")
    np.testing.assert_allclose([s for _, s in res.rows],
                               np.asarray(alg.betweenness(rel)), atol=1e-4)

    res = db.query("g", "CALL algo.closeness(rel: R, sources: [1, 4, 9]) "
                        "YIELD node, score")
    assert [v for v, _ in res.rows] == [1, 4, 9]
    np.testing.assert_allclose(
        [s for _, s in res.rows],
        np.asarray(alg.closeness(rel, sources=[1, 4, 9])), atol=1e-6)

    res = db.query("g", "CALL algo.similarity(rel: R, sources: [0, 2], "
                        "kind: overlap) YIELD node1, node2, score")
    S = np.asarray(alg.similarity(rel, [0, 2], "overlap"))
    want = sorted((int(s), int(i), float(S[i, j]))
                  for i, j in zip(*np.nonzero(S > 0))
                  for s in [[0, 2][j]])
    assert [(a, b) for a, b, _ in res.rows] == [(a, b) for a, b, _ in want]
    np.testing.assert_allclose([s for _, _, s in res.rows],
                               [s for _, _, s in want], atol=1e-6)

    res = db.query("g", "CALL algo.wcc(rel: R)")
    np.testing.assert_array_equal([comp for _, comp in res.rows],
                                  np.asarray(alg.wcc(rel)))

    res = db.query("g", "CALL algo.labelprop(rel: R) "
                        "YIELD node, community AS c")
    assert res.columns == ["node", "c"]
    np.testing.assert_array_equal([lab for _, lab in res.rows],
                                  np.asarray(alg.label_propagation(rel)))

    res = db.query("g", "CALL algo.bfs(rel: R, sources: [0], max_hops: 2) "
                        "YIELD source, node, level")
    lv = np.asarray(alg.bfs_levels(rel, [0], max_iter=2))
    want = sorted((0, int(i), int(lv[i, 0]))
                  for i in np.nonzero(np.isfinite(lv[:, 0]))[0])
    assert res.rows == want

    # YIELD reorder/alias + LIMIT apply after canonical rows
    res = db.query("g", "CALL algo.pagerank(rel: R, iters: 40) "
                        "YIELD score AS s, node LIMIT 3")
    assert res.columns == ["s", "node"] and len(res.rows) == 3
    assert all(isinstance(v, float) for v, _ in res.rows)


def test_call_undirected_triangles_through_database():
    """algo.triangles needs a symmetric adjacency; one global count row."""
    D, _ = _cell("petersen", "ell")
    r, c = np.nonzero(D)
    g = GraphBuilder(10).add_edges("R", r, c).build(fmt="ell")
    db = Database()
    db.load_graph("g", g)
    res = db.query("g", "CALL algo.triangles(rel: R)")
    assert res.columns == ["triangles"]
    assert res.rows == [(0,)]           # the Petersen graph is triangle-free

    Dk, _ = _cell("K4", "ell")
    rk, ck = np.nonzero(Dk)
    gk = GraphBuilder(4).add_edges("R", rk, ck).build(fmt="ell")
    db.load_graph("k4", gk)
    assert db.query("k4", "CALL algo.triangles(rel: R)").rows == [(4,)]


def test_call_explain_and_default_relation():
    """CallPlan.explain names the procedure; `rel:` omitted uses the
    graph-wide adjacency union like an unlabeled MATCH edge."""
    from repro.query.planner import plan
    from repro.query.parser import parse
    p = plan(parse("CALL algo.closeness(sources: [1, 2]) YIELD node, score"))
    assert "ProcedureCall(algo.closeness" in p.explain()
    D, _ = _cell("C5", "ell")
    r, c = np.nonzero(D)
    g = GraphBuilder(5).add_edges("R", r, c).build(fmt="ell")
    db = Database()
    db.load_graph("g", g)
    res = db.query("g", "CALL algo.closeness(sources: [0])")
    np.testing.assert_allclose([res.rows[0][1]], closeness_np(D, [0]),
                               atol=1e-6)
