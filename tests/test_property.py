"""Property-based tests (hypothesis) on the system's algebraic invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.hypothesis   # excluded by `make test-fast`

from repro import algorithms as alg
from repro.core import BSR, ELL, ops, semiring as S
from repro.graph.graph import GraphBuilder
from repro.kernels import ops as kops
from repro.kernels.ref import bsr_mxm_ref


def coo(draw_n, draw_m, rng):
    nnz = int(rng.integers(1, draw_n * 4))
    r = rng.integers(0, draw_n, size=nnz)
    c = rng.integers(0, draw_m, size=nnz)
    key = r * draw_m + c
    _, i = np.unique(key, return_index=True)
    return r[i], c[i]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(8, 96), m=st.integers(8, 96),
       f=st.integers(1, 24),
       srname=st.sampled_from(["plus_times", "or_and", "min_plus",
                               "plus_pair"]),
       block=st.sampled_from([8, 16, 32]))
def test_kernel_random_sweep(seed, n, m, f, srname, block):
    """Pallas kernel == oracle on random shapes/densities/semirings."""
    rng = np.random.default_rng(seed)
    r, c = coo(n, m, rng)
    v = rng.uniform(0.5, 2.0, size=len(r))
    A = BSR.from_coo(r, c, v, (n, m), block=block)
    X = np.where(rng.uniform(size=(m, f)) < 0.4,
                 rng.uniform(0.5, 2.0, size=(m, f)), 0.0).astype(np.float32)
    sr = S.get(srname)
    got = kops.bsr_mxm(A, jnp.asarray(X), sr, interpret=True, f_tile=32)
    want = bsr_mxm_ref(A, jnp.asarray(X), sr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(8, 64))
def test_or_and_matmul_is_associative_on_reachability(seed, n):
    """(A (x) B) (x) x == A (x) (B (x) x) over or_and (path composition)."""
    rng = np.random.default_rng(seed)
    A = (rng.uniform(size=(n, n)) < 0.1).astype(np.float32)
    B = (rng.uniform(size=(n, n)) < 0.1).astype(np.float32)
    x = (rng.uniform(size=(n, 3)) < 0.2).astype(np.float32)
    AB = np.asarray(ops.mxm(jnp.asarray(A), jnp.asarray(B), S.OR_AND))
    lhs = np.asarray(ops.mxm(jnp.asarray(AB), jnp.asarray(x), S.OR_AND))
    Bx = np.asarray(ops.mxm(jnp.asarray(B), jnp.asarray(x), S.OR_AND))
    rhs = np.asarray(ops.mxm(jnp.asarray(A), jnp.asarray(Bx), S.OR_AND))
    np.testing.assert_array_equal(lhs, rhs)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_plus_times_is_linear(seed):
    rng = np.random.default_rng(seed)
    n = 48
    r, c = coo(n, n, rng)
    v = rng.uniform(0.5, 2.0, size=len(r))
    A = BSR.from_coo(r, c, v, (n, n), block=16)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.normal(size=(n, 2)).astype(np.float32)
    Axy = np.asarray(ops.mxm(A, jnp.asarray(x + y), S.PLUS_TIMES))
    Ax = np.asarray(ops.mxm(A, jnp.asarray(x), S.PLUS_TIMES))
    Ay = np.asarray(ops.mxm(A, jnp.asarray(y), S.PLUS_TIMES))
    np.testing.assert_allclose(Axy, Ax + Ay, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), k=st.integers(1, 4))
def test_khop_monotone_in_k_and_edges(seed, k):
    """k-hop counts are monotone in k AND in edge addition."""
    rng = np.random.default_rng(seed)
    n = 64
    r, c = coo(n, n, rng)
    keep = r != c
    r, c = r[keep], c[keep]
    if len(r) < 2:
        return
    g1 = GraphBuilder(n).add_edges("R", r[: len(r) // 2],
                                   c[: len(r) // 2]).build(block=32)
    g2 = GraphBuilder(n).add_edges("R", r, c).build(block=32)
    seeds = [0, 7]
    k1 = np.asarray(alg.khop_counts(g1.relations["R"], seeds, k=k))
    k1b = np.asarray(alg.khop_counts(g1.relations["R"], seeds, k=k + 1))
    k2 = np.asarray(alg.khop_counts(g2.relations["R"], seeds, k=k))
    assert (k1b >= k1).all()          # monotone in k
    assert (k2 >= k1).all()           # monotone in edges (superset graph)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_formats_agree_on_random_graphs(seed):
    """BSR, ELL and dense paths compute identical or_and traversals."""
    rng = np.random.default_rng(seed)
    n = 72
    r, c = coo(n, n, rng)
    X = (rng.uniform(size=(n, 5)) < 0.3).astype(np.float32)
    bsr = BSR.from_coo(r, c, None, (n, n), block=24)
    ell = ELL.from_coo(r, c, None, (n, n))
    dense = bsr.to_dense()
    outs = [np.asarray(ops.mxm(a, jnp.asarray(X), S.OR_AND))
            for a in (bsr, ell, dense)]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_sssp_triangle_inequality(seed):
    """dist(s, v) <= dist(s, u) + w(u, v) for every edge (u, v)."""
    rng = np.random.default_rng(seed)
    n = 48
    r, c = coo(n, n, rng)
    keep = r != c
    r, c = r[keep], c[keep]
    if len(r) == 0:
        return
    w = rng.uniform(0.5, 3.0, size=len(r)).astype(np.float32)
    g = GraphBuilder(n).add_edges("R", r, c, w).build(fmt="bsr", block=16)
    dist = np.asarray(alg.sssp(g.relations["R"], [0]))[:, 0]
    D = np.asarray(g.relations["R"].A.to_dense())
    rr, cc = np.nonzero(D)
    for u, v in zip(rr, cc):
        if np.isfinite(dist[u]):
            assert dist[v] <= dist[u] + D[u, v] + 1e-4
