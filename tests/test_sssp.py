"""Tropical-path regressions: zero-weight edges are structure, not absence.

min_plus/max_plus render absent entries as their +/-inf identity, so a stored
0.0-weight edge used to be indistinguishable from no edge inside the tile
matmul — SSSP through a free edge reported inf. The fix carries structure
separately (ELL's mask already does; BSR grows a per-entry `emask` when
explicit zeros occur), and these goldens pin it end to end: they fail on the
pre-fix storage paths.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import sssp
from repro.core import bsr as bsr_mod, grb, semiring as S


def _zero_weight_chain(fmt):
    # 0 --(0.0)--> 1 --(1.0)--> 2 : the first hop is free but real
    r = np.array([0, 1])
    c = np.array([1, 2])
    v = np.array([0.0, 1.0], np.float32)
    kw = {"block": 2} if fmt == "bsr" else {}
    return grb.GBMatrix.from_coo(r, c, v, (3, 3), fmt=fmt, **kw)


@pytest.mark.parametrize("fmt", ["bsr", "ell"])
def test_sssp_zero_weight_golden(fmt):
    h = _zero_weight_chain(fmt)
    dist = np.asarray(sssp(h, jnp.asarray([0])))[:, 0]
    np.testing.assert_array_equal(dist, [0.0, 0.0, 1.0])


@pytest.mark.parametrize("fmt", ["bsr", "ell"])
@pytest.mark.parametrize("srname", ["min_plus", "max_plus"])
def test_tropical_mxm_keeps_zero_edges(fmt, srname):
    sr = S.get(srname)
    h = _zero_weight_chain(fmt)
    x = jnp.asarray(np.array([[0.0], [10.0], [20.0]], np.float32))
    got = np.asarray(grb.mxm(h, x, sr, grb.TRANSPOSE_A))[:, 0]
    # pulling along in-edges: node 1 reaches node 0's 0.0 through the free
    # edge (0 + 0.0), node 2 reaches node 1's 10.0 through weight 1.0
    ident = np.float32(sr.identity)
    np.testing.assert_array_equal(got, [ident, 0.0, 11.0])


def test_bsr_emask_only_when_needed():
    # zero-free builds must not pay the mask: emask stays None
    r, c = np.array([0, 1]), np.array([1, 2])
    plain = bsr_mod.BSR.from_coo(r, c, np.array([2.0, 1.0], np.float32),
                                 (3, 3), block=2)
    assert plain.emask is None
    zeroed = bsr_mod.BSR.from_coo(r, c, np.array([0.0, 1.0], np.float32),
                                  (3, 3), block=2)
    assert zeroed.emask is not None
    # structure survives transpose and COO round-trips
    rt, ct, vt = zeroed.transpose().to_coo()
    assert sorted(zip(rt.tolist(), ct.tolist(), vt.tolist())) == \
        [(1, 0, 0.0), (2, 1, 1.0)]
    assert zeroed.nnz == 2
