"""End-to-end sharded-vs-local goldens through the *public* route.

The acceptance bar of the sharded-storage refactor: `k_hop`, `pagerank`,
and `sssp` — and the query executor / database shell above them — produce
the single-device answers on a forced 8-device mesh, called through the
unchanged `grb`/algorithm surface with zero sharding-specific arguments at
the call site (the only sharding-aware line anywhere is the one
`grb.distribute` / `mesh=` handoff). k-hop and SSSP are bit-identical
(integer counts / exact-min relaxation); PageRank sums float partials in a
different order across shards and gets atol=1e-5.

Folds the old orphan `tests/distributed_check.py` script into pytest proper
(its khop/pagerank/sssp checks now go through grb instead of the deleted
`*_2d` algorithm entry points; its train-lowering checks live in
test_distributed.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms as alg
from repro.core import grb
from repro.engine.database import Database
from repro.graph.datagen import rmat_graph
from repro.graph.graph import GraphBuilder
from repro.query.executor import ExecutionContext

pytestmark = pytest.mark.distributed


@pytest.fixture(scope="module")
def rmat_ell():
    return rmat_graph(scale=7, edge_factor=8, seed=0, fmt="ell")


@pytest.fixture(scope="module")
def weighted_ell():
    """RMAT pattern with deterministic value weights >= 0.5 (the tropical
    storage convention), built through GraphBuilder so the relation carries
    a linked ELL transpose like any engine graph."""
    g0 = rmat_graph(scale=7, edge_factor=8, seed=3, fmt="ell")
    r, c, _ = g0.relations["KNOWS"].A.to_coo()
    w = (0.5 + (r * 48271 + c * 16807) % 97 / 38.8).astype(np.float32)
    return GraphBuilder(g0.n).add_edges("ROAD", r, c, w).build(fmt="ell")


def test_khop_bit_identical(rmat_ell, mesh222):
    rel = rmat_ell.relations["KNOWS"]
    sh = grb.distribute(rel.A, mesh222)       # the only sharding-aware line
    seeds = np.random.default_rng(0).integers(0, rmat_ell.n, size=8)
    for k in (1, 2, 3):
        want = np.asarray(alg.khop_counts(rel.A, seeds, k=k))
        got = np.asarray(alg.khop_counts(sh, seeds, k=k))
        np.testing.assert_array_equal(got, want, err_msg=f"k={k}")


def test_khop_4way_mesh(rmat_ell, mesh421):
    rel = rmat_ell.relations["KNOWS"]
    sh = grb.distribute(rel.A, mesh421)
    seeds = np.arange(6) * 11
    np.testing.assert_array_equal(
        np.asarray(alg.khop_counts(sh, seeds, k=3)),
        np.asarray(alg.khop_counts(rel.A, seeds, k=3)))


def test_bfs_levels_bit_identical(rmat_ell, mesh222):
    rel = rmat_ell.relations["KNOWS"]
    sh = grb.distribute(rel.A, mesh222)
    seeds = np.asarray([0, 17, 63])
    np.testing.assert_array_equal(
        np.asarray(alg.bfs_levels(sh, seeds, max_iter=4)),
        np.asarray(alg.bfs_levels(rel.A, seeds, max_iter=4)))


def test_pagerank_close(rmat_ell, mesh222):
    rel = rmat_ell.relations["KNOWS"]
    sh = grb.distribute(rel.A, mesh222)
    want = np.asarray(alg.pagerank(rel.A, iters=30))
    got = np.asarray(alg.pagerank(sh, iters=30))
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(got.sum(), 1.0, atol=1e-4)


def test_sssp_bit_identical(weighted_ell, mesh222):
    rel = weighted_ell.relations["ROAD"]
    sh = grb.distribute(rel.A, mesh222)
    seeds = np.arange(8) * 3
    want = np.asarray(alg.sssp(rel.A, seeds, max_iter=weighted_ell.n // 8))
    got = np.asarray(alg.sssp(sh, seeds, max_iter=weighted_ell.n // 8))
    np.testing.assert_array_equal(got, want)
    assert np.isfinite(got).sum() > len(seeds)    # actually reached things


# -- engine / query route -----------------------------------------------------
def test_execution_context_mesh(rmat_ell, mesh222):
    q = ("MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) IN [0, 9, 33] "
         "RETURN a, count(DISTINCT b)")
    local = ExecutionContext(rmat_ell).run(q)
    sharded = ExecutionContext(rmat_ell, mesh=mesh222).run(q)
    assert sharded.columns == local.columns
    assert sharded.rows == local.rows


def test_database_sharded_mode(mesh222):
    db = Database()
    db.query("g", "CREATE (:Person {id: 0}), (:Person {id: 1}), "
                  "(:Person {id: 2}), (:Person {id: 3}), (:Person {id: 4})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2), "
                  "(2)-[:KNOWS]->(3), (3)-[:KNOWS]->(4), (4)-[:KNOWS]->(0)")
    q = ("MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 0 "
         "RETURN count(DISTINCT b)")
    want = db.query("g", q).scalar()
    got = db.query("g", q, mesh=mesh222).scalar()
    assert got == want == 3
    # the sharded context's handles really are mesh-backed
    ctx = db.context("g", mesh=mesh222)
    assert ctx.matrix("KNOWS").fmt == "sharded"
    # alternating mesh/local reads must not thrash rebuilds or re-shards:
    # builds cache per format, distributed twins cache per mesh
    g_local = db.context("g").graph
    g_mesh = db.context("g", mesh=mesh222).graph
    assert db.context("g").graph is g_local
    assert db.context("g", mesh=mesh222).graph is g_mesh
    m1 = db.context("g", mesh=mesh222).matrix("KNOWS")
    assert db.context("g", mesh=mesh222).matrix("KNOWS") is m1


def test_context_mesh_rejects_bsr_graph(mesh222):
    """A pre-built BSR graph on a mesh surfaces the non-ELL contract as a
    clear TypeError (the Database freeze path avoids it by freezing ELL)."""
    g = rmat_graph(scale=6, edge_factor=8, seed=1, fmt="bsr")
    ctx = ExecutionContext(g, mesh=mesh222)
    with pytest.raises(TypeError, match="needs ELL or BitELL row"):
        ctx.matrix("KNOWS")
