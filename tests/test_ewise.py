"""Element-wise family conformance vs a dense NumPy oracle.

The documented entry semantics (stored == nonzero, union for eWiseAdd,
intersection for eWiseMult, stored-entries-only apply/select, empty — not a
monoid identity — outside the mask, union-merge accum), checked for all
three formats across the full descriptor grid, plus:

  * the GrB_assign / GrB_extract analogs (aligned-range fast path and COO
    relabeling) under the same blend rule,
  * the satellite regressions: clear TypeError on mixed operand kinds,
    select honoring its descriptor, BSR "or" reduce with negative values,
    axis=0/1 sparse reductions, and the impl="auto" crossover policy,
  * a hypothesis sweep over random COO operands (same oracle), guarded
    with the importorskip convention from test_spgemm.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSR, ELL, grb, semiring as S
from repro.core import bsr as bsr_mod
from repro.core.grb import Descriptor

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.ewise

N, M = 96, 80


def _rand_dense(seed, density=0.12, lo=0.5, hi=2.0, shape=(N, M)):
    rng = np.random.default_rng(seed)
    D = np.where(rng.uniform(size=shape) < density,
                 rng.uniform(lo, hi, size=shape), 0.0).astype(np.float32)
    return D


def _handle(fmt, D, block=32):
    if fmt == "dense":
        return jnp.asarray(D)
    r, c = np.nonzero(D)
    if fmt == "bsr":
        return grb.GBMatrix(BSR.from_coo(r, c, D[r, c], D.shape, block=block))
    return grb.GBMatrix(ELL.from_coo(r, c, D[r, c], D.shape))


def _materialize(x, shape):
    if isinstance(x, grb.GBMatrix):
        return np.asarray(x.to_dense())
    return np.asarray(x)


# -- the documented rules, independently in NumPy ------------------------------
def o_union(a, b, op):
    both = (a != 0) & (b != 0)
    return np.where(both, np.asarray(op(a, b), np.float32), a + b)


def o_blend(raw, C, mask, complement, accum_np, replace):
    z = o_union(C, raw, accum_np) if (accum_np is not None
                                      and C is not None) else raw
    if mask is None:
        return z
    m = (mask == 0) if complement else (mask != 0)
    outside = np.zeros_like(z) if (C is None or replace) else C
    return np.where(m, z, outside)


_F = lambda x: x * 2.0 + 1.0         # f(0) != 0: pins stored-only semantics
_PRED = lambda x: x > 1.0

# op name -> (runner(a, b, d, out), oracle_raw(D1, D2))
OPS = {
    "add_plus": (lambda a, b, d, o: grb.ewise_add(a, b, S.PLUS, d, out=o),
                 lambda D1, D2: o_union(D1, D2, np.add)),
    "add_min": (lambda a, b, d, o: grb.ewise_add(a, b, S.MIN, d, out=o),
                lambda D1, D2: o_union(D1, D2, np.minimum)),
    "mult_times": (lambda a, b, d, o: grb.ewise_mult(a, b,
                                                     lambda x, y: x * y,
                                                     d, out=o),
                   lambda D1, D2: np.where((D1 != 0) & (D2 != 0),
                                           D1 * D2, 0.0)),
    "mult_min": (lambda a, b, d, o: grb.ewise_mult(a, b, jnp.minimum, d,
                                                   out=o),
                 lambda D1, D2: np.where((D1 != 0) & (D2 != 0),
                                         np.minimum(D1, D2), 0.0)),
    "apply": (lambda a, b, d, o: grb.apply(_F, a, d, out=o),
              lambda D1, D2: np.where(D1 != 0, _F(D1), 0.0)),
    "select": (lambda a, b, d, o: grb.select(_PRED, a, d, out=o),
               lambda D1, D2: np.where((D1 != 0) & _PRED(D1), D1, 0.0)),
}

_ACCUM = {"none": None, "plus": S.PLUS, "min": S.MIN}
_ACCUM_NP = {"none": None, "plus": np.add, "min": np.minimum}


def _out_for(fmt, D, block=32):
    """An existing-C operand of the right kind for the format's path."""
    return _handle(fmt if fmt != "dense" else "dense", D, block=block)


@pytest.mark.parametrize("fmt", ["dense", "bsr", "ell"])
@pytest.mark.parametrize("opname", sorted(OPS))
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
@pytest.mark.parametrize("accum", ["none", "plus"])
@pytest.mark.parametrize("replace", [False, True])
@pytest.mark.parametrize("with_c", [False, True])
def test_ewise_blend_grid(fmt, opname, mask_mode, accum, replace, with_c):
    runner, oracle_raw = OPS[opname]
    D1 = _rand_dense(seed=3)
    D2 = _rand_dense(seed=4)
    DC = _rand_dense(seed=5, density=0.3)
    mask = (np.random.default_rng(6).uniform(size=(N, M)) < 0.5
            ).astype(np.int8)
    a = _handle(fmt, D1)
    b = _handle(fmt, D2)
    out = _out_for(fmt, DC) if with_c else None
    m = None if mask_mode == "none" else mask
    d = Descriptor(mask=None if m is None else jnp.asarray(m),
                   complement=mask_mode == "comp",
                   accum=_ACCUM[accum], replace=replace)
    got = runner(a, b, d, out)
    if fmt != "dense":
        assert isinstance(got, grb.GBMatrix) and got.fmt == fmt
    want = o_blend(oracle_raw(D1, D2), DC if with_c else None, m,
                   mask_mode == "comp", _ACCUM_NP[accum], replace)
    np.testing.assert_allclose(_materialize(got, (N, M)), want,
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{fmt}/{opname}/{mask_mode}/"
                                       f"accum={accum}/replace={replace}/"
                                       f"C={with_c}")
    if fmt != "dense":
        assert got.nvals == int(np.count_nonzero(want))


@pytest.mark.parametrize("fmt", ["bsr", "ell"])
def test_sparse_mask_may_be_sparse_handle(fmt):
    """The descriptor mask can itself be a sparse GBMatrix (k-truss passes
    the adjacency); block-level pruning must match the dense oracle."""
    D1 = _rand_dense(seed=11)
    DM = _rand_dense(seed=12, density=0.4)
    a = _handle(fmt, D1)
    mh = _handle(fmt, DM)
    got = grb.apply(_F, a, Descriptor(mask=mh))
    want = np.where(DM != 0, np.where(D1 != 0, _F(D1), 0.0), 0.0)
    np.testing.assert_allclose(_materialize(got, (N, M)), want, rtol=1e-5)
    got_c = grb.apply(_F, a, Descriptor(mask=mh, complement=True))
    want_c = np.where(DM == 0, np.where(D1 != 0, _F(D1), 0.0), 0.0)
    np.testing.assert_allclose(_materialize(got_c, (N, M)), want_c,
                               rtol=1e-5)


def test_ell_mask_on_bsr_path_stays_sparse(fresh_trace):
    """An ELL descriptor mask over BSR operands converts sparse-to-sparse
    (COO), never through a dense intermediate."""
    D1 = _rand_dense(seed=42)
    DM = _rand_dense(seed=43, density=0.4)
    a = _handle("bsr", D1)
    mh = _handle("ell", DM)
    before = bsr_mod.densify_calls()
    got = grb.apply(_F, a, Descriptor(mask=mh))
    assert bsr_mod.densify_calls() == before
    want = np.where(DM != 0, np.where(D1 != 0, _F(D1), 0.0), 0.0)
    np.testing.assert_allclose(np.asarray(got.to_dense()), want, rtol=1e-5)


def test_bsr_ell_operands_coerce_sparsely(fresh_trace):
    """A BSR and an ELL operand meet via COO relabeling, never to_dense."""
    D1 = _rand_dense(seed=13)
    D2 = _rand_dense(seed=14)
    a = _handle("bsr", D1)
    b = _handle("ell", D2)
    before = bsr_mod.densify_calls()
    got = grb.ewise_add(a, b, S.PLUS)
    assert bsr_mod.densify_calls() == before
    assert got.fmt == "bsr"
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               o_union(D1, D2, np.add), rtol=1e-5)


def test_select_emptied_tiles_are_pruned():
    """A predicate that kills every entry must leave no stored tiles."""
    D = _rand_dense(seed=15)
    a = _handle("bsr", D)
    got = grb.select(lambda x: x > 1e9, a)
    assert got.nvals == 0
    assert int(np.asarray(got.store.valid).sum()) == 0


# -- satellite: clear TypeError on mixed operand kinds -------------------------
def test_mixed_operand_kinds_raise_clear_typeerror():
    D1 = _rand_dense(seed=16)
    D2 = _rand_dense(seed=17)
    a = _handle("bsr", D1)
    for fn, call in [
        ("ewise_add", lambda: grb.ewise_add(a, jnp.asarray(D2), S.PLUS)),
        ("ewise_add", lambda: grb.ewise_add(jnp.asarray(D1), a, S.PLUS)),
        ("ewise_mult", lambda: grb.ewise_mult(a, jnp.asarray(D2),
                                              jnp.minimum)),
    ]:
        with pytest.raises(TypeError) as ei:
            call()
        msg = str(ei.value)
        assert fn in msg and "dense" in msg and "BSR/ELL" in msg


def test_sparse_operands_reject_dense_out():
    D = _rand_dense(seed=18)
    a = _handle("bsr", D)
    with pytest.raises(TypeError) as ei:
        grb.apply(_F, a, Descriptor(accum=S.PLUS), out=jnp.asarray(D))
    assert "out=" in str(ei.value)
    with pytest.raises(TypeError):
        grb.ewise_add(jnp.asarray(D), jnp.asarray(D), S.PLUS,
                      out=_handle("bsr", D))


def test_ewise_shape_mismatch_raises():
    a = _handle("bsr", _rand_dense(seed=19))
    b = _handle("bsr", _rand_dense(seed=20, shape=(N, M + 16)))
    with pytest.raises(ValueError):
        grb.ewise_add(a, b, S.PLUS)


def test_numpy_array_mask_accepted_on_dense_path():
    """A plain numpy mask must work like a jnp one (mxm accepts both)."""
    D1 = _rand_dense(seed=37)
    D2 = _rand_dense(seed=38)
    mask = (np.random.default_rng(39).uniform(size=(N, M)) < 0.5
            ).astype(np.int8)
    got = grb.ewise_add(jnp.asarray(D1), jnp.asarray(D2), S.PLUS,
                        Descriptor(mask=mask))
    want = np.where(mask != 0, o_union(D1, D2, np.add), 0.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_ell_mask_shape_mismatch_raises():
    """The ELL COO path must reject a mis-shaped mask (it would otherwise
    build a garbage key set), matching the BSR/dense behavior."""
    a = _handle("ell", _rand_dense(seed=40))
    with pytest.raises(ValueError):
        grb.apply(_F, a, Descriptor(mask=jnp.ones((4, 16))))


def test_extract_empty_indices():
    for fmt in ("dense", "bsr", "ell"):
        A = _handle(fmt, _rand_dense(seed=41))
        got = grb.extract(A, np.array([], dtype=np.int64), None)
        assert _materialize(got, (0, M)).shape == (0, M)
        if fmt != "dense":
            assert got.nvals == 0


# -- satellite: select honors its descriptor (used to drop it) -----------------
def test_select_descriptor_not_ignored():
    D = _rand_dense(seed=21)
    DC = _rand_dense(seed=22, density=0.3)
    mask = (np.random.default_rng(23).uniform(size=(N, M)) < 0.5
            ).astype(np.int8)
    d = Descriptor(mask=jnp.asarray(mask), accum=S.PLUS)
    for fmt in ("dense", "bsr", "ell"):
        got = grb.select(_PRED, _handle(fmt, D), d, out=_out_for(fmt, DC))
        raw = np.where((D != 0) & _PRED(D), D, 0.0)
        want = o_blend(raw, DC, mask, False, np.add, False)
        np.testing.assert_allclose(_materialize(got, (N, M)), want,
                                   rtol=1e-5, err_msg=fmt)
        # and it must differ from the descriptor-free call (the old bug)
        bare = _materialize(grb.select(_PRED, _handle(fmt, D)), (N, M))
        assert not np.allclose(bare, want)


# -- satellite: reduce fixes ---------------------------------------------------
def test_bsr_or_reduce_negative_values():
    """OR is "any stored entry", not max — wrong before for negatives."""
    A = grb.GBMatrix(BSR.from_coo([0, 5], [3, 7], [-2.0, -3.5], (64, 64),
                                  block=32))
    assert float(grb.reduce(A, S.OR)) == 1.0
    empty = grb.GBMatrix(BSR.from_coo([], [], [], (64, 64), block=32))
    assert float(grb.reduce(empty, S.OR)) == 0.0


@pytest.mark.parametrize("fmt", ["bsr", "ell"])
@pytest.mark.parametrize("axis", [None, 0, 1])
def test_sparse_reduce_axes_match_dense_oracle(fmt, axis, fresh_trace):
    D = _rand_dense(seed=24)
    D[:, 7] = 0.0                      # a structurally empty column
    D[33, :] = 0.0                     # and row
    A = _handle(fmt, D)
    before = bsr_mod.densify_calls()
    got_p = np.asarray(grb.reduce(A, S.PLUS, axis=axis))
    got_o = np.asarray(grb.reduce(A, S.OR, axis=axis))
    if fmt == "bsr":
        assert bsr_mod.densify_calls() == before    # no silent densification
    np.testing.assert_allclose(got_p, D.sum(axis=axis), rtol=1e-5, atol=1e-5)
    want_o = (D != 0).any(axis=axis).astype(np.float32)
    np.testing.assert_array_equal(got_o, want_o)


def test_sparse_reduce_other_monoids_fall_back():
    D = _rand_dense(seed=25)
    A = _handle("bsr", D)
    np.testing.assert_allclose(float(grb.reduce(A, S.MIN)), D.min())
    np.testing.assert_allclose(np.asarray(grb.reduce(A, S.MAX, axis=1)),
                               D.max(axis=1), rtol=1e-6)


# -- assign / extract ----------------------------------------------------------
def _indices(kind, n, block, seed):
    if kind == "all":
        return None, np.arange(n)
    if kind == "aligned":
        lo = block
        return np.arange(lo, n), np.arange(lo, n)
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(n, size=n // 3, replace=False))
    return idx, idx


@pytest.mark.parametrize("fmt", ["dense", "bsr", "ell"])
@pytest.mark.parametrize("idx_kind", ["all", "aligned", "random"])
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
def test_extract_grid(fmt, idx_kind, mask_mode):
    D = _rand_dense(seed=26)
    A = _handle(fmt, D)
    rows, I = _indices(idx_kind, N, 32, seed=27)
    cols, J = _indices(idx_kind, M, 32, seed=28)
    raw = D[np.ix_(I, J)]
    DC = _rand_dense(seed=29, density=0.3, shape=raw.shape)
    mask = (np.random.default_rng(30).uniform(size=raw.shape) < 0.5
            ).astype(np.int8)
    m = None if mask_mode == "none" else mask
    d = Descriptor(mask=None if m is None else jnp.asarray(m),
                   complement=mask_mode == "comp", accum=S.PLUS)
    out = (_handle(fmt, DC) if fmt != "dense" else jnp.asarray(DC))
    got = grb.extract(A, rows, cols, d, out=out)
    want = o_blend(raw, DC, m, mask_mode == "comp", np.add, False)
    np.testing.assert_allclose(_materialize(got, raw.shape), want,
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{fmt}/{idx_kind}/{mask_mode}")
    if fmt != "dense":
        assert isinstance(got, grb.GBMatrix)


def test_extract_aligned_bsr_stays_in_tile_land(fresh_trace):
    """Block-aligned ranges take tile-list surgery — zero densifications."""
    D = _rand_dense(seed=31)
    A = _handle("bsr", D)
    before = bsr_mod.densify_calls()
    got = grb.extract(A, range(32, 96), range(0, 64))
    assert bsr_mod.densify_calls() == before
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               D[32:96, 0:64], rtol=1e-6)


@pytest.mark.parametrize("fmt", ["dense", "bsr", "ell"])
@pytest.mark.parametrize("mask_mode", ["none", "mask"])
@pytest.mark.parametrize("accum", ["none", "plus"])
@pytest.mark.parametrize("replace", [False, True])
def test_assign_grid(fmt, mask_mode, accum, replace):
    D = _rand_dense(seed=32)
    rng = np.random.default_rng(33)
    I = np.sort(rng.choice(N, size=30, replace=False))
    J = np.sort(rng.choice(M, size=25, replace=False))
    DA = _rand_dense(seed=34, density=0.3, shape=(len(I), len(J)))
    mask = (rng.uniform(size=(len(I), len(J))) < 0.5).astype(np.int8)
    m = None if mask_mode == "none" else mask
    C = _handle(fmt, D)
    A = _handle(fmt, DA) if fmt != "dense" else jnp.asarray(DA)
    d = Descriptor(mask=None if m is None else jnp.asarray(m),
                   accum=_ACCUM[accum], replace=replace)
    got = grb.assign(C, A, I, J, d)
    sub = D[np.ix_(I, J)]
    want = D.copy()
    want[np.ix_(I, J)] = o_blend(DA, sub, m, False, _ACCUM_NP[accum],
                                 replace)
    np.testing.assert_allclose(_materialize(got, (N, M)), want,
                               rtol=1e-5, atol=1e-5,
                               err_msg=f"{fmt}/{mask_mode}/{accum}/"
                                       f"replace={replace}")
    # functional: the input handle is untouched
    np.testing.assert_allclose(_materialize(C, (N, M)), D, rtol=1e-6)


def test_assign_region_overwrite_deletes_absent():
    """No accum/mask: the region pattern is *replaced* (GrB_assign)."""
    D = _rand_dense(seed=35, density=0.5)
    C = _handle("bsr", D)
    Z = _handle("bsr", np.zeros((32, 32), np.float32))
    got = grb.assign(C, Z, range(0, 32), range(0, 32))
    want = D.copy()
    want[:32, :32] = 0.0
    np.testing.assert_allclose(np.asarray(got.to_dense()), want, rtol=1e-6)
    assert got.nvals == int(np.count_nonzero(want))


def test_index_validation():
    A = _handle("bsr", _rand_dense(seed=36))
    with pytest.raises(ValueError):
        grb.extract(A, np.array([1, 1, 2]), None)       # duplicates
    with pytest.raises(ValueError):
        grb.extract(A, np.array([0, N]), None)          # out of range
    with pytest.raises(ValueError):
        grb.assign(A, _handle("bsr", np.zeros((3, 3), np.float32)),
                   np.arange(4), np.arange(3))          # region mismatch


# -- satellite: impl="auto" crossover policy -----------------------------------
def _store(n, density, block=128, seed=0):
    D = _rand_dense(seed=seed, density=density, shape=(n, n))
    r, c = np.nonzero(D)
    return BSR.from_coo(r, c, D[r, c], (n, n), block=block)


def test_auto_policy_cpu_is_xla():
    s = _store(1024, 0.01)
    assert grb._resolve_impl("auto", "bsr", s) == "xla"
    assert grb._resolve_impl("pallas", "bsr", s) == "pallas"   # forced


def test_auto_policy_uses_fill_and_grid(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    big_sparse = _store(1024, 0.01)          # 8 block-rows, sparse tiles
    small = _store(256, 0.01)                # 2 block-rows: dense matmul wins
    assert min(big_sparse.nbrows, big_sparse.nbcols) >= grb.AUTO_MIN_GRID
    assert grb._resolve_impl("auto", "bsr", big_sparse) == "pallas"
    assert grb._resolve_impl("auto", "bsr", small) == "xla"
    dense_ish = _store(1024, 0.6)            # stored tiles mostly full
    assert dense_ish.fill_ratio > grb.AUTO_MAX_FILL
    assert grb._resolve_impl("auto", "bsr", dense_ish) == "xla"
    assert grb._resolve_impl("xla", "bsr", big_sparse) == "xla"    # forced
    h = grb.GBMatrix(big_sparse)             # handle resolution, auto flag
    assert h.impl == "pallas" and h.auto
    assert h.with_impl("auto") is h


def test_wrap_sparse_preserves_auto_policy(monkeypatch):
    """Results derived from an auto handle stay auto: the crossover policy
    re-resolves against the result's own store instead of being pinned to
    the parent's resolved choice."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    h = grb.GBMatrix(_store(1024, 0.01))
    assert h.impl == "pallas" and h.auto
    sel = grb.select(lambda x: x > 0, h)
    assert sel.auto
    forced = grb.select(lambda x: x > 0, h.with_impl("xla"))
    assert not forced.auto and forced.impl == "xla"
    assert h.T.auto                          # cached transpose stays auto
    assert not h.with_impl("pallas").T.auto  # explicit request stays pinned


def test_auto_policy_narrow_frontier_takes_xla(monkeypatch):
    """Width side of the crossover: an auto-resolved pallas handle routes a
    frontier narrower than AUTO_MIN_WIDTH through the XLA path (an explicit
    impl="pallas" request is never second-guessed)."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    h = grb.GBMatrix(_store(1024, 0.01))
    assert h.impl == "pallas" and h.auto
    forced = h.with_impl("pallas")
    assert forced.impl == "pallas" and not forced.auto

    from repro.kernels import ops as kops

    def _kernel_spy(*a, **k):
        raise AssertionError("kernel path taken")

    monkeypatch.setattr(kops, "bsr_mxm", _kernel_spy)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")  # run on host
    X = jnp.ones((1024, grb.AUTO_MIN_WIDTH - 1), jnp.float32)
    y = grb.mxm(h, X, S.PLUS_TIMES)          # narrow: XLA, kernel untouched
    assert y.shape == (1024, grb.AUTO_MIN_WIDTH - 1)
    with pytest.raises(AssertionError):
        grb.mxm(h, jnp.ones((1024, 128), jnp.float32), S.PLUS_TIMES)
    with pytest.raises(AssertionError):      # forced pallas: always kernel
        grb.mxm(forced, X, S.PLUS_TIMES)


# -- hypothesis property sweep -------------------------------------------------
if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(8, 96),
           m=st.integers(8, 96), density=st.floats(0.01, 0.3),
           fmt=st.sampled_from(["dense", "bsr", "ell"]),
           opname=st.sampled_from(sorted(OPS)),
           mask_mode=st.sampled_from(["none", "mask", "comp"]),
           block=st.sampled_from([8, 16, 32]))
    def test_ewise_random_sweep(seed, n, m, density, fmt, opname, mask_mode,
                                block):
        runner, oracle_raw = OPS[opname]
        rng = np.random.default_rng(seed)
        D1 = _rand_dense(seed=seed, density=density, shape=(n, m))
        D2 = _rand_dense(seed=seed + 1, density=density, shape=(n, m))
        mask = (rng.uniform(size=(n, m)) < 0.5).astype(np.int8)
        mm = None if mask_mode == "none" else mask
        d = Descriptor(mask=None if mm is None else jnp.asarray(mm),
                       complement=mask_mode == "comp")
        got = runner(_handle(fmt, D1, block=block),
                     _handle(fmt, D2, block=block), d, None)
        want = o_blend(oracle_raw(D1, D2), None, mm, mask_mode == "comp",
                       None, False)
        np.testing.assert_allclose(_materialize(got, (n, m)), want,
                                   rtol=1e-5, atol=1e-5)

else:

    @pytest.mark.hypothesis
    def test_ewise_random_sweep():
        pytest.importorskip("hypothesis", reason="hypothesis not installed "
                            "(see requirements-dev.txt)")
