"""Cypher-lite engine: parser, planner, executor vs pure-python reference."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # see requirements-dev.txt
    HAVE_HYPOTHESIS = False

from repro.graph.datagen import social_graph
from repro.graph.graph import GraphBuilder
from repro.query import execute, explain, parse
from repro.query.reference import execute_ref


@pytest.fixture(scope="module")
def g():
    return social_graph(n=256, seed=0)


def same(got, want):
    assert got.columns == want.columns
    assert sorted(got.rows) == sorted(want.rows)


QUERIES = [
    "MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = 5 RETURN count(DISTINCT b)",
    "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) IN [1, 7, 33] RETURN a, count(DISTINCT b)",
    "MATCH (a:Person)-[:KNOWS*1..3]->(b:Person) WHERE id(a) = 12 AND b.age > 40 RETURN count(DISTINCT b)",
    "MATCH (a:Person)-[:KNOWS]->(b)-[:VISITS]->(c:City) WHERE id(a) = 9 RETURN count(DISTINCT c)",
    "MATCH (a:Person)<-[:KNOWS]-(b) WHERE id(a) = 14 RETURN count(DISTINCT b)",
    "MATCH (a:Person)-[:KNOWS]-(b) WHERE id(a) = 21 RETURN count(DISTINCT b)",
    "MATCH (a)-[:KNOWS]->(b) WHERE id(a) IN [2, 3] RETURN a, b LIMIT 10",
    "MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = 5 AND (b.age < 20 OR b.age >= 60) RETURN count(DISTINCT b)",
    "MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = 5 AND NOT b.age < 30 RETURN count(DISTINCT b)",
    "MATCH (a:Person)-[:KNOWS*2..3]->(b) WHERE id(a) = 40 RETURN count(DISTINCT b)",
]


@pytest.mark.parametrize("q", QUERIES)
def test_executor_matches_reference(g, q):
    same(execute(g, q), execute_ref(g, q))


def test_label_scan_no_seeds(g):
    got = execute(g, "MATCH (a:City)<-[:VISITS]-(b) RETURN count(DISTINCT b)")
    want = execute_ref(g, "MATCH (a:City)<-[:VISITS]-(b) RETURN count(DISTINCT b)")
    same(got, want)


def test_khop_matches_paper_query_shape(g):
    # the paper's benchmark query lowers to ConditionalTraverse over or_and
    txt = explain(g, "MATCH (a)-[:KNOWS*1..6]->(b) WHERE id(a) = 3 "
                     "RETURN count(DISTINCT b)")
    assert "NodeByIdSeek" in txt
    assert "*1..6" in txt and "or_and" in txt


def test_prop_projection(g):
    res = execute(g, "MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = 5 "
                     "RETURN b, b.age LIMIT 5")
    assert res.columns == ["b", "b.age"]
    for b, age in res.rows:
        assert age is None or 10 <= age < 80


def test_parse_errors():
    with pytest.raises(SyntaxError):
        parse("MATCH (a RETURN a")
    with pytest.raises(SyntaxError):
        parse("MATCH (a)-[:R*]->(b) RETURN b")  # unbounded var-length
    with pytest.raises(NotImplementedError):
        execute(social_graph(64),
                "MATCH (a)-[:KNOWS]->(b) WHERE a.age < b.age RETURN a")


def _khop_random_graphs(seed, k, src):
    """Property: algebraic k-hop == reference BFS on random digraphs."""
    rng = np.random.default_rng(seed)
    n = 64
    m = int(rng.integers(1, 500))
    s = rng.integers(0, n, size=m)
    d = rng.integers(0, n, size=m)
    keep = s != d
    if keep.sum() == 0:
        return
    g = GraphBuilder(n).add_edges("R", s[keep], d[keep]).build(block=32)
    q = (f"MATCH (a)-[:R*1..{k}]->(b) WHERE id(a) = {src} "
         f"RETURN count(DISTINCT b)")
    same(execute(g, q), execute_ref(g, q))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 4),
           src=st.integers(0, 63))
    def test_property_khop_random_graphs(seed, k, src):
        _khop_random_graphs(seed, k, src)
else:
    def test_property_khop_random_graphs():
        # deterministic fallback sweep when hypothesis is unavailable
        for seed, k, src in [(0, 1, 3), (7, 2, 40), (123, 3, 0), (999, 4, 63)]:
            _khop_random_graphs(seed, k, src)
