"""Descriptor semantics vs a dense NumPy oracle, for all three formats.

Exercises the centralized blend rule (grb.finalize) end-to-end through
grb.mxm over every mask-mode x accum x replace x existing-C combination,
plus the GBMatrix handle contract: cached lazy transpose, linked transposes
from the graph builder, introspection, and policy resolution.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BSR, ELL, grb, ops, semiring as S
from repro.core.grb import Descriptor

N, M, F = 96, 80, 6


def _case(seed=0):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, N, size=700)
    c = rng.integers(0, M, size=700)
    key = r * M + c
    _, i = np.unique(key, return_index=True)
    r, c = r[i], c[i]
    v = rng.uniform(0.5, 2.0, size=len(r)).astype(np.float32)
    D = np.zeros((N, M), np.float32)
    D[r, c] = v
    X = np.where(rng.uniform(size=(M, F)) < 0.4,
                 rng.uniform(0.5, 2.0, size=(M, F)), 0.0).astype(np.float32)
    mask = (rng.uniform(size=(N, F)) < 0.5).astype(np.int8)
    C = rng.uniform(0.5, 1.5, size=(N, F)).astype(np.float32)
    return r, c, v, D, X, mask, C


def _handle(fmt, r, c, v, D):
    if fmt == "bsr":
        return grb.GBMatrix(BSR.from_coo(r, c, v, (N, M), block=32))
    if fmt == "ell":
        return grb.GBMatrix(ELL.from_coo(r, c, v, (N, M)))
    return grb.GBMatrix(jnp.asarray(D))


_ACCUM = {"none": None, "plus": S.PLUS, "min": S.MIN}
_ACCUM_NP = {"none": None, "plus": np.add, "min": np.minimum}


def _oracle(raw, C, mask, complement, accum_np, replace, identity):
    """The documented blend rule, independently in NumPy."""
    z = accum_np(C, raw) if (accum_np is not None and C is not None) else raw
    if mask is None:
        return z
    m = (mask == 0) if complement else (mask != 0)
    outside = np.float32(identity) if (C is None or replace) else C
    return np.where(m, z, outside)


@pytest.mark.parametrize("fmt", ["dense", "bsr", "ell"])
@pytest.mark.parametrize("srname", ["plus_times", "min_plus"])
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
@pytest.mark.parametrize("accum", ["none", "plus"])
@pytest.mark.parametrize("replace", [False, True])
@pytest.mark.parametrize("with_c", [False, True])
def test_descriptor_blend_combinations(fmt, srname, mask_mode, accum,
                                       replace, with_c):
    sr = S.get(srname)
    r, c, v, D, X, mask, C = _case(seed=3)
    A = _handle(fmt, r, c, v, D)
    raw = np.asarray(S.dense_mxm(S.structural_dense(jnp.asarray(D), sr),
                                 jnp.asarray(X), sr))
    m = None if mask_mode == "none" else mask
    d = Descriptor(mask=None if m is None else jnp.asarray(m),
                   complement=mask_mode == "comp",
                   accum=_ACCUM[accum], replace=replace)
    out = jnp.asarray(C) if with_c else None
    got = np.asarray(grb.mxm(A, jnp.asarray(X), sr, d, out=out))
    want = _oracle(raw, C if with_c else None, m, mask_mode == "comp",
                   _ACCUM_NP[accum], replace, sr.identity)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                               err_msg=f"{fmt}/{srname}/{mask_mode}/"
                                       f"accum={accum}/replace={replace}/"
                                       f"C={with_c}")


@pytest.mark.parametrize("fmt", ["dense", "bsr", "ell"])
def test_transpose_descriptor_and_cache(fmt):
    r, c, v, D, X, _, _ = _case(seed=5)
    A = _handle(fmt, r, c, v, D)
    assert A._T is None                      # lazy: nothing built yet
    got = np.asarray(grb.mxm(A, jnp.asarray(np.resize(X, (N, F))),
                             S.PLUS_TIMES, grb.TRANSPOSE_A))
    want = D.T @ np.resize(X, (N, F))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert A._T is not None and A.T is A.T   # built once, cached
    assert A.T.T is A                        # round-trip identity
    np.testing.assert_allclose(np.asarray(A.T.to_dense()), D.T, rtol=1e-6)


def test_builder_links_explicit_transpose():
    from repro.graph.graph import GraphBuilder
    r, c, v, D, _, _, _ = _case(seed=7)
    keep = (r < 64) & (c < 64)
    g = GraphBuilder(64).add_edges("R", r[keep], c[keep],
                                   v[keep]).build(fmt="bsr", block=32)
    A = g.relations["R"].A
    assert A._T is not None                  # loader installed the transpose
    assert g.relations["R"].A_T is A.T
    np.testing.assert_allclose(np.asarray(A.T.to_dense()),
                               np.asarray(A.to_dense()).T, rtol=1e-6)


def test_handle_introspection_and_policy():
    r, c, v, D, _, _, _ = _case(seed=9)
    for fmt, expect_nvals in (("bsr", len(r)), ("ell", len(r)),
                              ("dense", int((D != 0).sum()))):
        A = _handle(fmt, r, c, v, D)
        assert A.shape == (N, M)
        assert A.fmt == fmt
        assert A.nvals == expect_nvals
        assert A.impl in ("xla", "pallas")
    A = _handle("bsr", r, c, v, D)
    assert A.with_impl("auto") is A          # same resolved policy -> same handle
    B = A.with_impl("pallas")
    assert B.impl == "pallas" and B.store is A.store


def test_mxv_vxm_vector_masks():
    r, c, v, D, _, _, _ = _case(seed=11)
    A = _handle("bsr", r, c, v, D)
    x = np.random.default_rng(0).uniform(size=M).astype(np.float32)
    xn = np.random.default_rng(1).uniform(size=N).astype(np.float32)
    mask = (np.arange(N) % 2).astype(np.float32)
    got = np.asarray(grb.mxv(A, jnp.asarray(x), S.PLUS_TIMES,
                             Descriptor(mask=jnp.asarray(mask))))
    np.testing.assert_allclose(got, (D @ x) * mask, rtol=1e-5, atol=1e-5)
    got_v = np.asarray(grb.vxm(jnp.asarray(xn), A, S.PLUS_TIMES))
    np.testing.assert_allclose(got_v, xn @ D, rtol=1e-4, atol=1e-4)


def test_legacy_ops_surface_delegates():
    """ops.mxm kwargs spelling == grb.mxm Descriptor spelling."""
    r, c, v, D, X, mask, C = _case(seed=13)
    A = BSR.from_coo(r, c, v, (N, M), block=32)
    legacy = np.asarray(ops.mxm(A, jnp.asarray(X), S.PLUS_TIMES,
                                mask=jnp.asarray(mask), accum=S.PLUS,
                                C=jnp.asarray(C)))
    uniform = np.asarray(grb.mxm(grb.GBMatrix(A), jnp.asarray(X),
                                 S.PLUS_TIMES,
                                 Descriptor(mask=jnp.asarray(mask),
                                            accum=S.PLUS),
                                 out=jnp.asarray(C)))
    np.testing.assert_allclose(legacy, uniform, rtol=1e-6)


def test_descriptor_with_():
    d = Descriptor(complement=True)
    d2 = d.with_(transpose_a=True)
    assert d2.complement and d2.transpose_a and not d.transpose_a
    assert grb.NULL.mask_only


# -- GBMatrix x GBMatrix (SpGEMM path) vs the same dense oracle ---------------
F2 = 48  # sparse B operand width


def _sparse_case(seed=17):
    rng = np.random.default_rng(seed)
    r, c, v, D, _, _, _ = _case(seed=seed)
    rb = rng.integers(0, M, size=500)
    cb = rng.integers(0, F2, size=500)
    key = rb * F2 + cb
    _, i = np.unique(key, return_index=True)
    rb, cb = rb[i], cb[i]
    vb = rng.uniform(0.5, 2.0, size=len(rb)).astype(np.float32)
    DB = np.zeros((M, F2), np.float32)
    DB[rb, cb] = vb
    mask = (rng.uniform(size=(N, F2)) < 0.5).astype(np.int8)
    C = rng.uniform(0.5, 1.5, size=(N, F2)).astype(np.float32)
    A = grb.GBMatrix(BSR.from_coo(r, c, v, (N, M), block=32))
    B = grb.GBMatrix(BSR.from_coo(rb, cb, vb, (M, F2), block=32))
    return A, B, D, DB, mask, C


@pytest.mark.spgemm
@pytest.mark.parametrize("srname", ["plus_times", "plus_pair"])
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
@pytest.mark.parametrize("accum", ["none", "plus"])
@pytest.mark.parametrize("replace", [False, True])
@pytest.mark.parametrize("with_c", [False, True])
def test_sparse_sparse_blend_combinations(srname, mask_mode, accum, replace,
                                          with_c):
    """mask x complement x accum x replace x existing-C on GBMatrix x
    GBMatrix operands. out=None keeps C sparse (SpGEMM, mask folded
    block-wise); an existing C blends through the dense finalize — both must
    match the documented rule the dense oracle implements."""
    sr = S.get(srname)
    A, B, D, DB, mask, C = _sparse_case(seed=19)
    raw = np.asarray(S.dense_mxm(jnp.asarray(D), jnp.asarray(DB), sr))
    m = None if mask_mode == "none" else mask
    d = Descriptor(mask=None if m is None else jnp.asarray(m),
                   complement=mask_mode == "comp",
                   accum=_ACCUM[accum], replace=replace)
    out = jnp.asarray(C) if with_c else None
    got = grb.mxm(A, B, sr, d, out=out)
    if isinstance(got, grb.GBMatrix):
        assert not with_c                 # sparse result only when C absent
        assert got.fmt == "bsr"
        got = got.to_dense()
    want = _oracle(raw, C if with_c else None, m, mask_mode == "comp",
                   _ACCUM_NP[accum], replace, sr.identity)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5,
                               err_msg=f"{srname}/{mask_mode}/accum={accum}/"
                                       f"replace={replace}/C={with_c}")


@pytest.mark.spgemm
def test_sparse_sparse_gbmatrix_mask():
    """The mask itself may be a sparse GBMatrix handle (triangle counting's
    C<A> = A (x) A) on both the sparse and dense pipelines."""
    A, B, D, DB, _, _ = _sparse_case(seed=23)
    raw = np.asarray(S.dense_mxm(jnp.asarray(D), jnp.asarray(DB),
                                 S.PLUS_PAIR))
    mask_h = grb.GBMatrix(BSR.from_dense((raw > 1).astype(np.float32),
                                         block=32))
    got = grb.mxm(A, B, S.PLUS_PAIR, Descriptor(mask=mask_h))
    want = np.where(raw > 1, raw, 0.0)
    np.testing.assert_allclose(np.asarray(got.to_dense()), want, rtol=1e-5)
    # same handle-mask through the dense pipeline (dense A)
    Ad = grb.GBMatrix(jnp.asarray(D))
    got_d = grb.mxm(Ad, jnp.asarray(DB), S.PLUS_PAIR,
                    Descriptor(mask=mask_h))
    np.testing.assert_allclose(np.asarray(got_d), want, rtol=1e-5)
