"""docs-check: the fenced ```python blocks in docs/*.md are executable.

Docs drift silently unless their examples run: every ```python fence in
every docs/*.md executes here, top to bottom, sharing one namespace per
file (later blocks may use names earlier blocks defined, doctest-style).
Diagrams, tables, and signatures that are not meant to execute use plain
``` fences and are skipped. Wired as `make docs-check` and into tier-1.
"""
import glob
import os
import re
import traceback

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
FENCE = re.compile(r"^```python[^\n]*\n(.*?)^```\s*$", re.M | re.S)


def python_blocks(path):
    with open(path) as f:
        text = f.read()
    out = []
    for m in FENCE.finditer(text):
        line = text[:m.start()].count("\n") + 2   # first line inside fence
        out.append((line, m.group(1)))
    return out


def test_docs_exist():
    names = {os.path.basename(p) for p in DOCS}
    assert "API.md" in names and "ARCHITECTURE.md" in names


@pytest.mark.parametrize("path", DOCS,
                         ids=[os.path.basename(p) for p in DOCS])
def test_doc_examples_execute(path):
    blocks = python_blocks(path)
    assert blocks, (f"{os.path.basename(path)} has no executable "
                    f"```python blocks — docs must carry runnable examples")
    ns = {"__name__": f"docscheck_{os.path.basename(path)}"}
    for line, src in blocks:
        try:
            code = compile(src, f"{os.path.basename(path)}:{line}", "exec")
            exec(code, ns)
        except Exception:
            pytest.fail(f"{os.path.basename(path)} block at line {line} "
                        f"failed:\n{traceback.format_exc()}")
