"""Subprocess body for distributed tests: runs on 8 fake CPU devices.

Checks, on a (2, 4) ("data", "model") mesh:
  1. khop_counts_2d (shard_map, explicit collectives) == single-device oracle;
  2. a dense-arch train_step lowers+compiles with the full sharding policy
     (the dry-run path) on a small config — and its HLO contains collectives;
  3. reduced-device multi-pod mesh (2, 2, 2) compiles the same cell.
Exit code 0 = all good (asserted).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import algorithms as alg
from repro.configs.base import ShapeConfig, get_config
from repro.distr import graph2d, sharding as sh
from repro.distr.shardctx import ShardCtx, use
from repro.graph.datagen import rmat_graph
from repro.models import get_model
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_train_step


def check_khop_2d():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    g = rmat_graph(scale=7, edge_factor=8, seed=0, fmt="ell")
    n = g.n
    rel = g.relations["KNOWS"]
    k = 3
    f = 8
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, n, size=f)
    # ELL of A^T (pull form) via the grb handle, one-hot frontier
    frontier = np.zeros((n, f), np.int8)
    frontier[seeds, np.arange(f)] = 1
    want = np.asarray(alg.khop_counts(rel, seeds, k=k))
    idx, msk = graph2d.ell_shard_inputs(rel.A_T)
    idx_sent, _ = graph2d.ell_shard_inputs(rel.A_T, sentinel=True)
    for packed, sentinel in ((False, False), (True, False), (True, True)):
        fn = graph2d.khop_counts_2d(mesh, n, k, packed=packed,
                                    sentinel=sentinel)
        shards = graph2d.shardings_2d(mesh, n, idx.shape[1], f)
        jfn = jax.jit(fn, in_shardings=shards)
        got = np.asarray(jfn(jnp.asarray(idx_sent if sentinel else idx),
                             jnp.asarray(msk), jnp.asarray(frontier)))
        np.testing.assert_array_equal(
            got, want, err_msg=f"packed={packed} sentinel={sentinel}")
    print("khop_2d ok (incl. bitmap-packed + sentinel):", got[:4])

    # distributed PageRank == single-device reference
    deg = np.asarray(rel.A.to_dense()).astype(bool).sum(1).astype(np.float32)
    pr_fn = graph2d.pagerank_2d(mesh, n, iters=30)
    jpr = jax.jit(pr_fn)
    got_pr = np.asarray(jpr(jnp.asarray(idx), jnp.asarray(msk),
                            jnp.asarray(deg)))
    want_pr = np.asarray(alg.pagerank(rel, iters=30))
    np.testing.assert_allclose(got_pr, want_pr, rtol=1e-4, atol=1e-6)
    print("pagerank_2d ok: mass", got_pr.sum())

    # distributed SSSP (min_plus) == single-device Bellman-Ford
    gw = rmat_graph(scale=7, edge_factor=8, seed=3, fmt="ell")
    relw = gw.relations["KNOWS"]
    # re-weight edges host-side (datagen emits structural 1.0 weights; use
    # value-ish weights 0.5..3 derived deterministically from indices)
    idx, msk = graph2d.ell_shard_inputs(relw.A_T)
    wts = (0.5 + (idx.astype(np.int64) * 48271 % 97) / 38.8).astype(np.float32)
    f2 = 8
    seeds2 = np.arange(f2) * 3
    d0 = np.full((gw.n, f2), np.inf, np.float32)
    d0[seeds2, np.arange(f2)] = 0.0
    fn = jax.jit(graph2d.sssp_2d(mesh, gw.n, iters=gw.n // 8))
    got_d = np.asarray(fn(jnp.asarray(idx), jnp.asarray(msk),
                          jnp.asarray(wts), jnp.asarray(d0)))
    # oracle: dense Bellman-Ford on the same weight assignment
    W = np.full((gw.n, gw.n), np.inf, np.float32)
    rr, ss = np.nonzero(msk)
    W[idx[rr, ss], rr] = np.minimum(W[idx[rr, ss], rr], wts[rr, ss])
    want_d = d0.copy()
    for _ in range(gw.n // 8):
        relax = (want_d[:, None, :] + W[:, :, None]).min(axis=0)
        want_d = np.minimum(want_d, relax)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-5, atol=1e-5)
    print("sssp_2d ok: reached", int(np.isfinite(got_d).sum()))


def check_train_lowering(multi_pod: bool):
    mesh = (jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((2, 4), ("data", "model")))
    cfg = get_config("qwen2-1.5b")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=64, d_ff=128, vocab=160, n_heads=4,
        n_kv_heads=2, head_dim=16, dtype="float32")
    shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
    model = get_model(cfg)
    ctx = ShardCtx(mesh)
    pspecs = model.param_specs()
    pshard = sh.param_shardings(pspecs, mesh, vocab=cfg.vocab)
    ospecs = jax.eval_shape(opt_mod.init_fn(cfg.optimizer), pspecs)
    oshard = sh.opt_state_shardings(ospecs, mesh, vocab=cfg.vocab)
    bspecs = model.train_input_specs(shape)
    bshard = sh.batch_shardings(bspecs, mesh)
    step = make_train_step(model, opt_mod.OptConfig(name=cfg.optimizer))
    with use(ctx):
        lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard)) \
            .lower(pspecs, ospecs, bspecs)
    compiled = lowered.compile()
    txt = compiled.as_text()
    assert ("all-reduce" in txt or "all-gather" in txt
            or "reduce-scatter" in txt), "no collectives in SPMD module?"
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict], newer a dict
        cost = cost[0]
    print(f"train lowering ok (multi_pod={multi_pod}): "
          f"{cost['flops']:.2e} flops/dev")


if __name__ == "__main__":
    check_khop_2d()
    check_train_lowering(multi_pod=False)
    check_train_lowering(multi_pod=True)
    print("ALL DISTRIBUTED CHECKS PASSED")
