"""Database shell: CREATE/AOF replay, snapshots, batched query server."""
import numpy as np
import pytest

from repro.engine import Database, QueryServer, load_snapshot, save_snapshot
from repro.graph.datagen import social_graph
from repro.query.executor import execute
from repro.query.reference import execute_ref


def test_create_and_query(tmp_path):
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:Person {id: 0, age: 30}), (:Person {id: 1, age: 40}), "
                  "(:Person {id: 2, age: 50})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2)")
    res = db.query("g", "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 0 "
                        "RETURN count(DISTINCT b)")
    assert res.scalar() == 2
    res = db.query("g", "MATCH (a:Person)-[:KNOWS]->(b) WHERE b.age > 45 "
                        "RETURN a, b")
    assert res.rows == [(1, 2)]


def test_aof_replay_recovers_after_crash(tmp_path):
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:Person {id: 0}), (:Person {id: 1}), (:Person {id: 2})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2), (2)-[:KNOWS]->(0)")
    del db  # crash
    db2 = Database(data_dir=str(tmp_path))
    res = db2.query("g", "MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 0 "
                         "RETURN count(DISTINCT b)")
    assert res.scalar() == 2  # reaches 1 and 2 (0 excluded as seed)


def test_delete_edge_and_node_forms(tmp_path):
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:Person {id: 0}), (:Person {id: 1}), "
                  "(:Person {id: 2})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2), "
                  "(2)-[:KNOWS]->(0)")
    res = db.query("g", "DELETE (1)-[:KNOWS]->(2)")
    assert res.columns == ["nodes_deleted", "edges_deleted"]
    assert res.rows == [(0, 1)]
    assert db.query("g", "MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 0 "
                         "RETURN count(DISTINCT b)").scalar() == 1
    # whole-node tombstone takes its incident edges with it
    res = db.query("g", "DELETE (0)")
    assert res.rows == [(1, 2)]       # (0)->(1) and (2)->(0)
    assert db.query("g", "MATCH (a)-[:KNOWS]->(b) "
                         "RETURN count(b)").scalar() == 0
    # deletes are AOF-logged: a crash-restart converges to the same state
    del db
    db2 = Database(data_dir=str(tmp_path))
    assert db2.query("g", "MATCH (a)-[:KNOWS]->(b) "
                          "RETURN count(b)").scalar() == 0


def test_create_auto_id_aof_round_trip(tmp_path):
    """create_node without an explicit {id: ...} auto-assigns next_id (the
    KeyError regression), and the assignment replays identically."""
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:Person {age: 30}), (:Person {age: 40})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1)")
    rows = db.query("g", "MATCH (a:Person)-[:KNOWS]->(b) WHERE b.age > 35 "
                         "RETURN a, b").rows
    assert rows == [(0, 1)]
    del db
    db2 = Database(data_dir=str(tmp_path))
    assert db2._graph("g").next_id == 2
    assert db2.query("g", "MATCH (a:Person)-[:KNOWS]->(b) WHERE b.age > 35 "
                          "RETURN a, b").rows == rows


def test_snapshot_of_delta_served_graph(tmp_path):
    """save_snapshot on a mid-write-stream delta view captures the exact
    effective matrix (DeltaMatrix.to_coo composes it)."""
    db = Database()
    db.query("g", "CREATE (:Person {id: 0, age: 30}), "
                  "(:Person {id: 1, age: 40}), (:Person {id: 2, age: 50})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2)")
    db.query("g", "MATCH (a)-[:KNOWS]->(b) RETURN count(b)")  # freeze a base
    db.query("g", "DELETE (0)-[:KNOWS]->(1)")
    db.query("g", "CREATE (2)-[:KNOWS]->(0)")                 # pending deltas
    g = db._graph("g").freeze()
    path = str(tmp_path / "snap.npz")
    save_snapshot(g, path)
    g2 = load_snapshot(path)
    q = "MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 1 RETURN count(DISTINCT b)"
    assert execute(g2, q).rows == execute(g, q).rows
    assert g2.relation("KNOWS").A.nvals == 2


def test_snapshot_roundtrip(tmp_path):
    g = social_graph(n=128, seed=3)
    path = str(tmp_path / "snap.npz")
    save_snapshot(g, path)
    g2 = load_snapshot(path)
    q = "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) IN [1, 5, 9] RETURN a, count(DISTINCT b)"
    assert sorted(execute(g, q).rows) == sorted(execute(g2, q).rows)
    assert g2.nnz == g.nnz


def test_server_batches_compatible_queries():
    g = social_graph(n=256, seed=1)
    srv = QueryServer(g)
    qids, want = [], []
    for s in [1, 3, 5, 7, 11, 13]:
        q = (f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = {s} "
             f"RETURN count(DISTINCT b)")
        qids.append(srv.submit(q))
        want.append(execute_ref(g, q).rows)
    # one incompatible query rides along solo
    solo_q = "MATCH (a:City)<-[:VISITS]-(b) RETURN count(DISTINCT b)"
    solo_id = srv.submit(solo_q)
    out = srv.flush()
    for qid, w in zip(qids, want):
        assert out[qid].rows == w
    assert out[solo_id].rows == execute_ref(g, solo_q).rows
    assert srv.stats["batches"] == 1          # 6 queries -> 1 batch
    assert srv.stats["queries"] == 7
    assert srv.stats["solo"] == 1


def test_server_batch_matches_sequential():
    g = social_graph(n=256, seed=2)
    seeds = list(range(0, 60, 7))
    srv = QueryServer(g)
    qids = {s: srv.submit(f"MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = {s} "
                          f"RETURN count(DISTINCT b)") for s in seeds}
    out = srv.flush()
    for s in seeds:
        solo = execute(g, f"MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = {s} "
                          f"RETURN count(DISTINCT b)")
        assert out[qids[s]].rows == solo.rows
