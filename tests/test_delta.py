"""Delta-matrix mutation layer (core.delta + the engine write path).

Three layers of guarantees:

  * DeltaMatrix composition is *exact*: every grb op on a delta handle
    equals the same op on a from-scratch rebuild of the effective matrix
    (oracle grid below: CREATE/DELETE streams on K4 / C5 / Petersen /
    RMAT s6-s8 over dense / BSR / ELL bases) — bit-identical for the
    integer-valued semirings (or_and / min_plus / plus_pair), atol 1e-5 for
    real-valued pagerank (summation-order rounding, the PR4 precedent).
  * The engine serves writes with ZERO rebuilds: one base build per format,
    functional catch-up after, compaction only past AUTO_DELTA_COMPACT.
  * Snapshot isolation + crash recovery: a reader frozen before a writer
    batch never sees its edits; AOF replay of interleaved CREATE/DELETE
    converges to the live run's nvals and query results.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import algorithms as alg
from repro.core import grb, semiring as S
from repro.core.delta import AUTO_DELTA_COMPACT, DeltaMatrix, needs_compaction
from repro.engine import Database
from repro.graph.datagen import rmat_edges

pytestmark = pytest.mark.delta


# -- fixtures: named graphs + deterministic mutation streams --------------------
def _dense_of(name: str) -> np.ndarray:
    if name == "K4":                       # complete digraph on 4 vertices
        D = np.ones((4, 4), np.float32) - np.eye(4, dtype=np.float32)
    elif name == "C5":                     # directed 5-cycle
        D = np.zeros((5, 5), np.float32)
        D[np.arange(5), (np.arange(5) + 1) % 5] = 1.0
    elif name == "Petersen":               # both directions of the 15 edges
        outer = [(i, (i + 1) % 5) for i in range(5)]
        spokes = [(i, i + 5) for i in range(5)]
        inner = [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
        D = np.zeros((10, 10), np.float32)
        for a, b in outer + spokes + inner:
            D[a, b] = D[b, a] = 1.0
    else:                                  # rmat_s6 / rmat_s7 / rmat_s8
        scale = int(name.split("_s")[1])
        src, dst, n = rmat_edges(scale, edge_factor=8, seed=scale)
        keep = src != dst
        D = np.zeros((n, n), np.float32)
        D[src[keep], dst[keep]] = 1.0
    return D


def _stream(D: np.ndarray, seed: int = 0, frac: float = 0.15):
    """Deterministic CREATE/DELETE op stream: ~frac*nnz deletions of
    existing entries interleaved with as many insertions of currently-absent
    ones (tracked against the evolving matrix, so a dense graph like K4 can
    only re-add what it just deleted — the re-add path gets exercised)."""
    rng = np.random.default_rng(seed)
    n = D.shape[0]
    W = D.copy()
    er, ec = np.nonzero(D)
    k = max(2, int(frac * len(er)))
    drop = rng.choice(len(er), size=min(k, len(er)), replace=False)
    ops = []
    for i in drop:
        ops.append(("del", int(er[i]), int(ec[i]), 0.0))
        W[er[i], ec[i]] = 0.0
        while True:                        # one absent-pair insertion each
            a, b = rng.integers(0, n, size=2)
            if a != b and W[a, b] == 0:
                break
        ops.append(("add", int(a), int(b), 1.0))
        W[a, b] = 1.0
    return ops


def _apply_dense(D: np.ndarray, ops) -> np.ndarray:
    out = D.copy()
    for kind, i, j, w in ops:
        out[i, j] = w if kind == "add" else 0.0
    return out


def _delta_handle(D: np.ndarray, ops, fmt: str,
                  block: int = 32) -> grb.GBMatrix:
    """Delta handle over a frozen `fmt` base of D with `ops` pending, the
    linked transpose twin maintained incrementally (swapped ops) — exactly
    what engine.MutableGraph serves."""
    base = grb.GBMatrix.from_dense(D, fmt=fmt, block=block)
    baseT = grb.GBMatrix.from_dense(D.T, fmt=fmt, block=block)
    fwd = DeltaMatrix.wrap(base.store).apply_ops(ops)
    twin = DeltaMatrix.wrap(baseT.store).apply_ops(
        [(k, j, i, w) for k, i, j, w in ops])
    h = grb.GBMatrix(fwd, name="A")
    h.link_transpose(grb.GBMatrix(twin, name="A^T"))
    return h


GRAPHS = ["K4", "C5", "Petersen", "rmat_s6", "rmat_s7", "rmat_s8"]
FMTS = ["dense", "bsr", "ell"]


# -- DeltaMatrix unit behavior ---------------------------------------------------
class TestDeltaMatrix:
    def test_wrap_and_effective_algebra(self):
        D = _dense_of("Petersen")
        dm = DeltaMatrix.wrap(grb.GBMatrix.from_dense(D, fmt="ell").store)
        assert dm.nnz == int((D != 0).sum()) and dm.pending == 0
        ops = [("del", 0, 1, 0.0), ("add", 0, 3, 2.0), ("add", 1, 1, 1.0)]
        d2 = dm.apply_ops(ops)
        E = _apply_dense(D, ops)
        assert np.array_equal(np.asarray(d2.to_dense()), E)
        assert d2.nnz == int((E != 0).sum())
        # functional: the pre-batch view is untouched (snapshot isolation)
        assert np.array_equal(np.asarray(dm.to_dense()), D)

    def test_invariants_zero_add_readd_missing_delete(self):
        D = _dense_of("C5")
        dm = DeltaMatrix.wrap(grb.GBMatrix.from_dense(D, fmt="dense").store)
        # add of explicit 0 == delete (stored iff nonzero, repo-wide)
        assert dm.apply_ops([("add", 0, 1, 0.0)]).nnz == dm.nnz - 1
        # deleting an absent entry is a no-op
        assert dm.apply_ops([("del", 3, 3, 0.0)]).nnz == dm.nnz
        # delete-then-re-add round-trips; later ops win within a batch
        d2 = dm.apply_ops([("del", 0, 1, 0.0), ("add", 0, 1, 5.0)])
        assert d2.nnz == dm.nnz
        assert float(np.asarray(d2.to_dense())[0, 1]) == 5.0
        # plus/minus invariant: disjoint, minus inside the base
        assert len(np.intersect1d(
            d2.plus_r * 5 + d2.plus_c, d2.minus_r * 5 + d2.minus_c)) == 0

    def test_growth_and_bounds(self):
        D = _dense_of("K4")
        dm = DeltaMatrix.wrap(grb.GBMatrix.from_dense(D, fmt="bsr",
                                                      block=4).store)
        big = dm.apply_ops([("add", 6, 2, 1.0)], grow_to=(7, 7))
        assert big.shape == (7, 7) and big.nnz == dm.nnz + 1
        assert np.asarray(big.to_dense())[6, 2] == 1.0
        with pytest.raises(ValueError):
            dm.apply_ops([("add", 9, 0, 1.0)])       # out of bounds
        with pytest.raises(ValueError):
            big.resize((4, 4))                       # never shrinks

    @pytest.mark.parametrize("fmt", FMTS)
    def test_to_coo_transpose_compact(self, fmt):
        D = _dense_of("rmat_s6")
        ops = _stream(D, seed=1)
        dm = DeltaMatrix.wrap(
            grb.GBMatrix.from_dense(D, fmt=fmt, block=32).store).apply_ops(ops)
        E = _apply_dense(D, ops)
        r, c, v = dm.to_coo()
        R = np.zeros_like(E)
        R[r, c] = v
        assert np.array_equal(R, E)
        assert np.array_equal(np.asarray(dm.transpose().to_dense()), E.T)
        folded = dm.compact()
        assert folded.pending == 0 and folded.nnz == dm.nnz
        assert np.array_equal(np.asarray(folded.to_dense()), E)
        assert folded.fmt == fmt                    # compacts into base kind

    def test_compaction_policy_threshold(self):
        D = _dense_of("Petersen")
        dm = DeltaMatrix.wrap(grb.GBMatrix.from_dense(D, fmt="ell").store)
        assert not needs_compaction(dm)
        k = int(AUTO_DELTA_COMPACT * dm.base_nnz) + 1
        ops = [("add", i % 10, (i * 7 + 3) % 10, 1.0) for i in range(k * 2)]
        d2 = dm.apply_ops(ops)
        if d2.pending > AUTO_DELTA_COMPACT * d2.base_nnz:
            assert needs_compaction(d2)
        assert not needs_compaction(d2.compact())


# -- grb conformance: every op vs the rebuilt-effective oracle -------------------
@pytest.mark.parametrize("fmt", FMTS)
def test_grb_ops_match_rebuild(fmt):
    D = _dense_of("rmat_s6")
    ops = _stream(D, seed=2)
    E = _apply_dense(D, ops)
    h = _delta_handle(D, ops, fmt)
    o = grb.GBMatrix.from_dense(E, fmt=fmt, block=32)
    o.link_transpose(grb.GBMatrix.from_dense(E.T, fmt=fmt, block=32))
    assert h.nvals == o.nvals == int((E != 0).sum())
    rng = np.random.default_rng(3)
    B = rng.random((D.shape[0], 9)).astype(np.float32)
    for sr in (S.OR_AND, S.MIN_PLUS, S.PLUS_PAIR):
        got = np.asarray(grb.mxm(h, B, sr))
        want = np.asarray(grb.mxm(o, B, sr))
        assert np.array_equal(got, want), sr.name     # bit-identical
        gotT = np.asarray(grb.mxm(h, B, sr, grb.TRANSPOSE_A))
        wantT = np.asarray(grb.mxm(o, B, sr, grb.TRANSPOSE_A))
        assert np.array_equal(gotT, wantT), sr.name
    assert np.allclose(np.asarray(grb.mxm(h, B, S.PLUS_TIMES)),
                       np.asarray(grb.mxm(o, B, S.PLUS_TIMES)), atol=1e-5)
    # masked write + accum blend
    M = (rng.random(B.shape) < 0.5).astype(np.float32)
    d = grb.Descriptor(mask=M, accum=S.PLUS)
    got = np.asarray(grb.mxm(h, B, S.OR_AND, d, out=B))
    want = np.asarray(grb.mxm(o, B, S.OR_AND, d, out=B))
    assert np.array_equal(got, want)
    # mxv / vxm (the pagerank pull shapes)
    x = rng.random(D.shape[0]).astype(np.float32)
    assert np.allclose(np.asarray(grb.mxv(h, x, S.PLUS_TIMES)),
                       np.asarray(grb.mxv(o, x, S.PLUS_TIMES)), atol=1e-5)
    assert np.allclose(np.asarray(grb.vxm(x, h, S.PLUS_TIMES)),
                       np.asarray(grb.vxm(x, o, S.PLUS_TIMES)), atol=1e-5)
    # reduce: composed plus/or all axes, min/max materialize fallback
    for m in (S.PLUS, S.OR, S.MIN, S.MAX):
        for ax in (None, 0, 1):
            got = np.asarray(grb.reduce(h, m, axis=ax))
            want = np.asarray(grb.reduce(o, m, axis=ax))
            assert np.allclose(got, want), (m.name, ax)
    # element-wise family through the materialize fallback
    other = grb.GBMatrix.from_dense((E * 0.5), fmt=fmt, block=32)
    ga = grb.ewise_add(h, other, S.PLUS)
    wa = grb.ewise_add(o, other, S.PLUS)
    assert np.allclose(np.asarray(grb.GBMatrix.wrap(ga).to_dense()),
                       np.asarray(grb.GBMatrix.wrap(wa).to_dense()))
    gm = grb.ewise_mult(h, other, S.MIN)
    wm = grb.ewise_mult(o, other, S.MIN)
    assert np.allclose(np.asarray(grb.GBMatrix.wrap(gm).to_dense()),
                       np.asarray(grb.GBMatrix.wrap(wm).to_dense()))
    gs = grb.select(lambda v: v > 0.5, h)
    ws = grb.select(lambda v: v > 0.5, o)
    assert np.array_equal(np.asarray(grb.GBMatrix.wrap(gs).to_dense()),
                          np.asarray(grb.GBMatrix.wrap(ws).to_dense()))
    # extract a block through the delta
    ge = grb.extract(h, rows=np.arange(8), cols=np.arange(8))
    we = grb.extract(o, rows=np.arange(8), cols=np.arange(8))
    assert np.array_equal(np.asarray(grb.GBMatrix.wrap(ge).to_dense()),
                          np.asarray(grb.GBMatrix.wrap(we).to_dense()))
    # delta handle as a descriptor mask (the triangles shape)
    t1 = grb.mxm(h, h, S.PLUS_PAIR, grb.Descriptor(mask=h))
    t2 = grb.mxm(o, o, S.PLUS_PAIR, grb.Descriptor(mask=o))
    assert np.array_equal(np.asarray(grb.GBMatrix.wrap(t1).to_dense()),
                          np.asarray(grb.GBMatrix.wrap(t2).to_dense()))


# -- the acceptance grid: all five algorithms, delta vs rebuild -----------------
@pytest.mark.parametrize("gname", GRAPHS)
@pytest.mark.parametrize("fmt", FMTS)
def test_algorithms_delta_vs_rebuild(gname, fmt):
    D = _dense_of(gname)
    ops = _stream(D, seed=sum(map(ord, gname)))
    E = _apply_dense(D, ops)
    h = _delta_handle(D, ops, fmt)
    o = grb.GBMatrix.from_dense(E, fmt=fmt, block=32)
    o.link_transpose(grb.GBMatrix.from_dense(E.T, fmt=fmt, block=32))
    n = D.shape[0]
    seeds = np.arange(min(8, n))
    # bfs levels — or_and, bit-identical
    assert np.array_equal(np.asarray(alg.bfs_levels(h, seeds)),
                          np.asarray(alg.bfs_levels(o, seeds)))
    # sssp — min_plus, bit-identical
    assert np.array_equal(np.asarray(alg.sssp(h, seeds)),
                          np.asarray(alg.sssp(o, seeds)))
    # wcc — or_and closures + or-reduce, bit-identical labels
    assert np.array_equal(np.asarray(alg.wcc(h)), np.asarray(alg.wcc(o)))
    # triangles — plus_pair under the adjacency mask, exact integer counts
    assert int(alg.triangle_count(h)) == int(alg.triangle_count(o))
    # pagerank — real-valued plus_times: summation-order atol (PR4 precedent)
    assert np.allclose(np.asarray(alg.pagerank(h, iters=20)),
                       np.asarray(alg.pagerank(o, iters=20)), atol=1e-5)


# -- engine: queries on a mutated graph, delta-served vs rebuild ----------------
def _mutate_db(db: Database, name: str = "g"):
    """One scripted CREATE/DELETE session with interleaved reads."""
    db.query(name, "CREATE (:Person {id: 0, age: 30}), "
                   "(:Person {id: 1, age: 40}), (:Person {id: 2, age: 50}), "
                   "(:Person {id: 3, age: 60})")
    db.query(name, "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2), "
                   "(2)-[:KNOWS]->(3), (3)-[:KNOWS]->(0)")
    db.query(name, "MATCH (a)-[:KNOWS]->(b) RETURN count(b)")  # freeze a base
    db.query(name, "DELETE (1)-[:KNOWS]->(2)")
    db.query(name, "CREATE (1)-[:VISITS]->(3), (0)-[:KNOWS]->(2)")
    db.query(name, "CREATE (:Person {age: 70})")               # auto-id: 4
    db.query(name, "CREATE (4)-[:KNOWS]->(0)")
    db.query(name, "DELETE (3)")                               # tombstone


QUERIES = [
    "MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 0 RETURN count(DISTINCT b)",
    "MATCH (a)-[:KNOWS]->(b) RETURN a, b",
    "MATCH (a:Person)-[:KNOWS]->(b) WHERE b.age > 35 RETURN a, b",
    "MATCH (a)-[:VISITS]->(b) RETURN count(b)",
    "MATCH (a)<-[:KNOWS]-(b) WHERE id(a) = 0 RETURN count(DISTINCT b)",
]


def test_queries_delta_vs_rebuild_bit_identical():
    live, oracle = Database(delta=True), Database(delta=False)
    _mutate_db(live)
    _mutate_db(oracle)
    for q in QUERIES:
        assert live.query("g", q).rows == oracle.query("g", q).rows, q
    mg = live._graph("g")
    assert mg.rebuilds == 1          # the one base build; writes never rebuilt
    assert oracle._graph("g").rebuilds > 1


def test_zero_rebuilds_under_write_stream():
    db = Database()
    mg = db._graph("g")
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1})")
    db.query("g", "CREATE (0)-[:R]->(1)")
    for i in range(2, 20):
        db.query("g", f"CREATE (:N {{id: {i}}})")
        db.query("g", f"CREATE ({i - 1})-[:R]->({i})")
        res = db.query("g", f"MATCH (a)-[:R*1..3]->(b) WHERE id(a) = 0 "
                            f"RETURN count(DISTINCT b)")
        assert res.scalar() == min(3, i)
    assert mg.rebuilds == 1


def test_compaction_triggers_and_stays_correct():
    db = Database()
    mg = db._graph("g")
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1}), (:N {id: 2})")
    db.query("g", "CREATE (0)-[:R]->(1), (1)-[:R]->(2)")
    db.query("g", "MATCH (a)-[:R]->(b) RETURN count(b)")   # base: 2 entries
    # stream enough inserts past AUTO_DELTA_COMPACT * base_nnz to force folds
    for i in range(3, 40):
        db.query("g", f"CREATE (:N {{id: {i}}})")
        db.query("g", f"CREATE (0)-[:R]->({i})")
        db.query("g", "MATCH (a)-[:R]->(b) WHERE id(a) = 0 RETURN count(b)")
    assert mg.compactions > 0
    assert mg.rebuilds == 1
    res = db.query("g", "MATCH (a)-[:R]->(b) WHERE id(a) = 0 RETURN count(b)")
    assert res.scalar() == 38        # 1 original + 37 streamed


# -- snapshot isolation ----------------------------------------------------------
def test_snapshot_isolation_reader_never_sees_writer_batch():
    db = Database()
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1}), (:N {id: 2})")
    db.query("g", "CREATE (0)-[:R]->(1), (1)-[:R]->(2)")
    reader = db.context("g")                       # frozen pre-batch
    q = "MATCH (a)-[:R*1..2]->(b) WHERE id(a) = 0 RETURN count(DISTINCT b)"
    before = reader.run(q).rows
    # writer streams a batch: the reader's view must not move
    for i in range(3, 10):
        db.query("g", f"CREATE (:N {{id: {i}}}), ({i - 1})-[:R]->({i})")
        db.query("g", "DELETE (0)-[:R]->(1)" if i == 5
                 else f"MATCH (a)-[:R]->(b) WHERE id(a) = {i - 1} "
                      f"RETURN count(b)")
        assert reader.run(q).rows == before
    # a context opened now sees everything
    after = db.query("g", q)
    assert after.rows != before
    assert after.scalar() == 0                     # (0)->(1) was deleted


# -- crash recovery ---------------------------------------------------------------
def test_aof_replay_interleaved_creates_deletes_converges(tmp_path):
    q_count = "MATCH (a)-[:R*1..4]->(b) WHERE id(a) = 0 RETURN count(DISTINCT b)"
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1}), (:N {id: 2}), "
                  "(:N {id: 3})")
    db.query("g", "CREATE (0)-[:R]->(1), (1)-[:R]->(2), (2)-[:R]->(3)")
    db.query("g", "DELETE (1)-[:R]->(2)")
    db.query("g", "CREATE (1)-[:R]->(3), (3)-[:R]->(2)")
    db.query("g", "CREATE (:N)")                   # auto-id: 4
    db.query("g", "CREATE (2)-[:R]->(4)")
    db.query("g", "DELETE (3)")                    # node tombstone
    live_rows = db.query("g", q_count).rows
    live_nvals = db._graph("g").freeze().relation("R").A.nvals
    del db                                          # crash
    db2 = Database(data_dir=str(tmp_path))
    assert db2.query("g", q_count).rows == live_rows
    g2 = db2._graph("g").freeze()
    assert g2.relation("R").A.nvals == live_nvals
    assert db2._graph("g").rebuilds == 1           # replay coalesced


def test_aof_replay_auto_assigned_ids_round_trip(tmp_path):
    db = Database(data_dir=str(tmp_path))
    db.query("g", "CREATE (:Person {age: 10})")    # auto -> 0
    db.query("g", "CREATE (:Person {id: 5, age: 20})")
    db.query("g", "CREATE (:Person {age: 30})")    # auto -> 6
    db.query("g", "CREATE (0)-[:R]->(6)")
    rows = db.query("g", "MATCH (a:Person)-[:R]->(b) WHERE b.age > 25 "
                         "RETURN a, b").rows
    assert rows == [(0, 6)]
    del db
    db2 = Database(data_dir=str(tmp_path))
    assert db2._graph("g").next_id == 7
    assert db2.query("g", "MATCH (a:Person)-[:R]->(b) WHERE b.age > 25 "
                          "RETURN a, b").rows == rows


# -- query surface: DELETE grammar ------------------------------------------------
def test_delete_parses_and_routes():
    from repro.query import qast as A
    from repro.query.parser import parse
    q = parse("DELETE (3)-[:KNOWS]->(5), (7)")
    assert isinstance(q, A.DeleteQuery)
    assert q.items == [A.DeleteEdge(3, "KNOWS", 5), A.DeleteNode(7)]
    db = Database()
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1})")
    db.query("g", "CREATE (0)-[:R]->(1)")
    res = db.query("g", "DELETE (0)-[:R]->(1)")
    assert res.columns == ["nodes_deleted", "edges_deleted"]
    assert res.rows == [(0, 1)]
    # deleting an absent edge is a counted no-op, not an error
    assert db.query("g", "DELETE (0)-[:R]->(1)").rows == [(0, 0)]


def test_delete_rejected_by_read_context():
    from repro.query.executor import ExecutionContext
    db = Database()
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1})")
    db.query("g", "CREATE (0)-[:R]->(1)")
    ctx = db.context("g")
    with pytest.raises(TypeError, match="DELETE goes through"):
        ctx.run("DELETE (0)-[:R]->(1)")


def test_create_without_id_auto_assigns():
    db = Database()
    res = db.query("g", "CREATE (:Person {age: 41}), (:Person {age: 42})")
    assert res.rows == [(2, 0)]
    rows = db.query("g", "MATCH (a:Person) WHERE a.age > 41 RETURN a").rows
    assert rows == [(1,)]
    assert db._graph("g").next_id == 2


# -- mesh serving of a mutated graph ----------------------------------------------
def test_mesh_context_compacts_deltas():
    """context(mesh=...) must hand grb.distribute plain ELL (no delta
    lowering exists); with a single-device mesh unavailable in tier-1 we
    check the compacted freeze directly."""
    db = Database()
    db.query("g", "CREATE (:N {id: 0}), (:N {id: 1}), (:N {id: 2})")
    db.query("g", "CREATE (0)-[:R]->(1)")
    db.query("g", "MATCH (a)-[:R]->(b) RETURN count(b)")
    db.query("g", "CREATE (1)-[:R]->(2)")
    g = db._graph("g").freeze(fmt="ell", compact=True)
    assert g.relation("R").A.fmt == "ell"          # plain, distribute-ready
    assert g.relation("R").A.nvals == 2
    assert g.relation("R").A.T.fmt == "ell"
