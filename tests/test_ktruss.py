"""k-truss goldens + the zero-densification contract.

Named graphs with known truss structure (K4, C5, Petersen, K3,3), an RMAT
sweep against an independent NumPy peeling oracle, agreement between the
sparse (masked SpGEMM) and dense formulations, and the acceptance pin: the
BSR hot path performs *zero* ``to_dense()`` calls, asserted through the
densification counter in repro.core.bsr.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import ktruss
from repro.core import bsr as bsr_mod
from repro.core import grb
from repro.core.bsr import BSR
from repro.core.ell import ELL

pytestmark = pytest.mark.ewise


def _sym(edges, n):
    D = np.zeros((n, n), np.float32)
    for i, j in edges:
        D[i, j] = D[j, i] = 1.0
    return D


def _k4():
    return _sym([(i, j) for i in range(4) for j in range(i + 1, 4)], 4)


def _c5():
    return _sym([(i, (i + 1) % 5) for i in range(5)], 5)


def _petersen():
    outer = [(i, (i + 1) % 5) for i in range(5)]
    inner = [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
    spokes = [(i, 5 + i) for i in range(5)]
    return _sym(outer + inner + spokes, 10)


def _k33():
    return _sym([(i, 3 + j) for i in range(3) for j in range(3)], 6)


def _oracle(D, k):
    """Independent NumPy peeling loop."""
    A = (np.asarray(D) != 0).astype(np.int64)
    np.fill_diagonal(A, 0)
    while True:
        sup = (A @ A) * A
        A2 = ((sup >= k - 2) & (A != 0)).astype(np.int64)
        if (A2 == A).all():
            return A2
        A = A2


def _bsr_handle(D, block=4):
    return grb.GBMatrix(BSR.from_dense(D, block=block))


GOLDENS = [
    # (name, builder, k, surviving edge count) — directed count (2x edges)
    ("K4_3truss", _k4, 3, 12),        # K4 is a 4-truss: everything stays
    ("K4_4truss", _k4, 4, 12),
    ("K4_5truss", _k4, 5, 0),         # no edge closes 3 triangles
    ("C5_3truss", _c5, 3, 0),         # cycle: triangle-free
    ("Petersen_3truss", _petersen, 3, 0),   # girth 5: triangle-free
    ("K33_3truss", _k33, 3, 0),       # bipartite: triangle-free
]


@pytest.mark.parametrize("name,builder,k,edges", GOLDENS,
                         ids=[g[0] for g in GOLDENS])
def test_ktruss_goldens(name, builder, k, edges):
    D = builder()
    T = ktruss(_bsr_handle(D), k)
    assert T.nvals == edges, name
    want = _oracle(D, k)
    np.testing.assert_array_equal(
        (np.asarray(T.to_dense()) != 0).astype(np.int64), want)


@pytest.mark.parametrize("scale", [6, 7, 8])
@pytest.mark.parametrize("k", [3, 4, 5])
def test_ktruss_rmat_matches_oracle(scale, k):
    from repro.graph.datagen import rmat_edges
    from repro.graph.graph import GraphBuilder
    src, dst, n = rmat_edges(scale=scale, edge_factor=8, seed=7)
    keep = src != dst
    s = np.concatenate([src[keep], dst[keep]])
    d = np.concatenate([dst[keep], src[keep]])
    g = GraphBuilder(n).add_edges("R", s, d).build(fmt="bsr", block=64)
    A = g.relations["R"].A
    D = np.asarray(A.to_dense())
    want = _oracle(D, k)

    before = bsr_mod.densify_calls()
    T = ktruss(A, k)
    assert bsr_mod.densify_calls() == before, \
        "k-truss BSR hot path must not densify"
    got = (np.asarray(T.to_dense()) != 0).astype(np.int64)
    np.testing.assert_array_equal(got, want)
    # values are the final supports within the truss
    sup = (want @ want) * want
    np.testing.assert_allclose(np.asarray(T.to_dense()),
                               sup.astype(np.float32), rtol=1e-5)


def test_ktruss_dense_and_sparse_formulations_agree():
    D = _petersen()
    # add a triangle-rich pocket so k=3 is non-trivial
    D2 = np.zeros((16, 16), np.float32)
    D2[:10, :10] = D
    for i, j in [(10, 11), (11, 12), (10, 12), (12, 13), (11, 13),
                 (0, 10), (1, 11)]:
        D2[i, j] = D2[j, i] = 1.0
    sparse = ktruss(_bsr_handle(D2, block=8), 3)
    dense = ktruss(grb.GBMatrix(jnp.asarray(D2)), 3)
    np.testing.assert_array_equal(
        np.asarray(sparse.to_dense()) != 0,
        np.asarray(dense.to_dense()) != 0)
    np.testing.assert_array_equal(
        (np.asarray(sparse.to_dense()) != 0).astype(np.int64),
        _oracle(D2, 3))


def test_ktruss_ell_input_reblocks_sparsely():
    D = _k4()
    E = ELL.from_dense(D)
    before = bsr_mod.densify_calls()
    T = ktruss(grb.GBMatrix(E), 4)
    assert bsr_mod.densify_calls() == before
    assert T.nvals == 12 and T.fmt == "bsr"


def test_ktruss_k2_returns_input():
    A = _bsr_handle(_c5())
    assert ktruss(A, 2) is A


@pytest.mark.parametrize("fmt", ["bsr", "dense"])
def test_ktruss_ignores_self_loops(fmt):
    """Self-loops must not manufacture support: a lone edge with loops at
    both endpoints closes no triangles (oracle zeroes the diagonal)."""
    D = np.zeros((4, 4), np.float32)
    D[0, 1] = D[1, 0] = 1.0
    D[0, 0] = D[1, 1] = 1.0
    h = _bsr_handle(D) if fmt == "bsr" else grb.GBMatrix(jnp.asarray(D))
    T = ktruss(h, 3)
    assert T.nvals == 0
    # and on a triangle-rich graph with loops sprinkled in
    D2 = _k4()
    np.fill_diagonal(D2, 1.0)
    h2 = _bsr_handle(D2) if fmt == "bsr" else grb.GBMatrix(jnp.asarray(D2))
    T2 = ktruss(h2, 4)
    np.testing.assert_array_equal(
        (np.asarray(T2.to_dense()) != 0).astype(np.int64), _oracle(D2, 4))


def test_ktruss_fixpoint_idempotent():
    D = _oracle(_k4(), 4).astype(np.float32)
    T = ktruss(_bsr_handle(D), 4)
    T2 = ktruss(T, 4)
    np.testing.assert_array_equal(np.asarray(T.to_dense()) != 0,
                                  np.asarray(T2.to_dense()) != 0)
