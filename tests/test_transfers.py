"""Transfer-accounting regressions: the mesh/device-resident hot paths.

Three families, all under the `transfers` marker (`make test-transfers`):

  * shard-local ewise vs the gather oracle — differential grid over both
    session meshes, all descriptor blends (mask / complement / accum /
    replace and their products), plus a hypothesis fuzz on top. The
    sharded call itself must leave `grb.host_transfers()` untouched: only
    the post-hoc `.to_dense()` comparison gathers.
  * BSR device ewise — Pallas gathered-tile kernel vs the XLA reference
    vs a dense numpy oracle for every mode, and the
    `bsr.host_numeric_calls()` == 0 pin over the whole ewise family.
  * word-resident loops — BFS / k-hop / WCC / the server's batched sweep
    bit-identical packed-vs-float, with `grb.host_transfers()` == 0 over
    the sharded hot loops and `distr.graph2d.scan_host_transfers` finding
    no host-transfer ops in the lowered HLO.

The counters count *gathers* (ShardedELL.to_ell, BSR.to_dense/to_coo), so
tests measure deltas BEFORE materializing results for comparison — final
result materialization is the caller's one legitimate gather.

Distributed cases need the forced 8-device topology: `make test-dist` runs
them directly; tier-1 covers them through the subprocess wrapper in
test_distributed.py (hypothesis-marked sweeps excluded there, as
everywhere).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import algorithms as alg
from repro.core import bitmap, bsr as bsrmod, grb, semiring as S
from repro.core.bsr import BSR
from repro.core.grb import Descriptor, GBMatrix
from repro.engine import QueryServer
from repro.graph.graph import GraphBuilder
from repro.kernels import ops as kops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.transfers


def _weighted(pattern: np.ndarray, salt: int = 0) -> np.ndarray:
    """Deterministic weights >= 0.5 on a 0/1 pattern (0.0 == absent in
    tile/slot storage, so stored values must stay away from it)."""
    n, m = pattern.shape
    r, c = np.mgrid[0:n, 0:m]
    w = 0.5 + ((r * 31 + c * 17 + salt * 7) % 13) / 6.0
    return (pattern * w).astype(np.float32)


def _pattern(n: int, seed: int, density: float = 0.15) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return _weighted((rng.uniform(size=(n, n)) < density).astype(np.float32),
                     salt=seed)


def _sym_graph(n: int, seed: int, fmt: str = "ell"):
    rng = np.random.default_rng(seed)
    m = 3 * n
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    s, d = np.concatenate([src, dst]), np.concatenate([dst, src])
    return GraphBuilder(n).add_edges("R", s, d).build(fmt=fmt)


# =====================================================================
# BSR device ewise: Pallas kernel vs XLA reference vs dense numpy oracle
# =====================================================================

# module-level ops: the jit caches key on function identity
_ADD = lambda a, b: a + b                                  # noqa: E731
_MUL = lambda a, b: a * b                                  # noqa: E731
_SCALE = lambda a: a * 2.0 + 1.0                           # noqa: E731
_PRED = lambda a: a > 1.2                                  # noqa: E731

BSR_MODES = ["union", "intersect", "apply", "select", "mask", "mask_c"]


def _bsr_dense_oracle(Da, Db, mode):
    sa, sb = Da != 0, Db != 0
    if mode == "union":
        return Da + Db        # op(a,b) where both, the stored value where one
    if mode == "intersect":
        return np.where(sa & sb, Da * Db, 0.0)
    if mode == "apply":
        return np.where(sa, Da * 2.0 + 1.0, 0.0)
    if mode == "select":
        return np.where(sa & (Da > 1.2), Da, 0.0)
    if mode == "mask":
        return np.where(sb, Da, 0.0)
    return np.where(~sb, Da, 0.0)                          # mask_c


@pytest.mark.parametrize("n,block", [(32, 8), (48, 16)])
@pytest.mark.parametrize("mode", BSR_MODES)
def test_bsr_ewise_pallas_matches_xla_and_oracle(mode, n, block):
    Da, Db = _pattern(n, seed=3), _pattern(n, seed=4, density=0.2)
    A = BSR.from_dense(Da, block=block)
    B = BSR.from_dense(Db, block=block)
    op = {"union": _ADD, "intersect": _MUL,
          "apply": _SCALE, "select": _PRED}.get(mode)
    got = kops.bsr_ewise(A, B, mode, op)
    if mode == "union":
        ref = bsrmod.ewise_add(A, B, _ADD)                 # impl="xla"
    elif mode == "intersect":
        ref = bsrmod.ewise_mult(A, B, _MUL)
    elif mode == "apply":
        ref = bsrmod.apply_stored(A, _SCALE)
    elif mode == "select":
        ref = bsrmod.select_stored(A, _PRED)
    else:
        ref = bsrmod.mask_keep(A, B, complement=mode == "mask_c")
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(ref.to_dense()), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               _bsr_dense_oracle(Da, Db, mode), rtol=1e-6)


def test_bsr_ewise_family_runs_device_side(fresh_trace):
    """The whole ewise family, both impls: zero trips through the
    host-numpy `from_blocks` assembly (the pre-refactor round-trip)."""
    Da, Db = _pattern(40, seed=7), _pattern(40, seed=8)
    A = BSR.from_dense(Da, block=8)
    B = BSR.from_dense(Db, block=8)
    before = bsrmod.host_numeric_calls()
    for impl in ("xla", "pallas"):
        bsrmod.ewise_add(A, B, _ADD, impl=impl)
        bsrmod.ewise_mult(A, B, _MUL, impl=impl)
        bsrmod.apply_stored(A, _SCALE, impl=impl)
        bsrmod.select_stored(A, _PRED, impl=impl)
        bsrmod.mask_keep(A, B, complement=False, impl=impl)
        bsrmod.mask_keep(A, B, complement=True, impl=impl)
    assert bsrmod.host_numeric_calls() == before


def test_bsr_from_blocks_still_counts(fresh_trace):
    """The counter itself stays honest: the host assembly path bumps."""
    before = bsrmod.host_numeric_calls()
    BSR.from_blocks(np.array([0]), np.array([0]),
                    np.ones((1, 8, 8), np.float32), (8, 8), 8)
    assert bsrmod.host_numeric_calls() == before + 1


# =====================================================================
# Word-resident frontier loops: packed == float, counters stay flat
# =====================================================================

@pytest.mark.parametrize("fmt", ["ell", "dense"])
def test_word_loops_match_float_loops(fmt):
    g = _sym_graph(48, seed=11, fmt=fmt)
    A = g.relations["R"].A
    seeds = jnp.arange(12) * 4
    with grb.packed_frontiers("on"):
        lw = alg.bfs_levels(A, seeds)
        kw = alg.khop_counts(A, seeds, k=3)
        ww = alg.wcc(A)
    with grb.packed_frontiers("off"):
        lf = alg.bfs_levels(A, seeds)
        kf = alg.khop_counts(A, seeds, k=3)
        wf = alg.wcc(A)
    np.testing.assert_array_equal(np.asarray(lw), np.asarray(lf))
    np.testing.assert_array_equal(np.asarray(kw), np.asarray(kf))
    np.testing.assert_array_equal(np.asarray(ww), np.asarray(wf))


def test_server_batched_sweep_zero_transfers(fresh_trace):
    """The continuous-batching sweep never gathers a frontier: the stats
    line the server now reports must read zero for a full mixed queue."""
    g = _sym_graph(64, seed=13, fmt="ell")
    srv = QueryServer(g)
    qids = [srv.submit(f"MATCH (a)-[:R*1..3]->(b) WHERE id(a) = {s} "
                       f"RETURN count(DISTINCT b)") for s in range(0, 64, 3)]
    out = srv.flush()
    assert all(out[q].error is None for q in qids)
    assert srv.stats["errors"] == 0
    assert srv.stats["host_transfers"] == 0


# =====================================================================
# Sharded hot loops: zero host transfers, HLO free of transfer ops
# =====================================================================

def _distributed_pair(mesh, n=48, seed=21):
    g = _sym_graph(n, seed=seed, fmt="ell")
    ell = g.relations["R"].A
    return ell, grb.distribute(ell, mesh)


@pytest.mark.distributed
def test_sharded_traversals_zero_transfers(mesh222, fresh_trace):
    ell, sh = _distributed_pair(mesh222)
    seeds = jnp.arange(10) * 4
    before = grb.host_transfers()
    lv = jax.block_until_ready(alg.bfs_levels(sh, seeds))
    kc = jax.block_until_ready(alg.khop_counts(sh, seeds, k=3))
    wl = jax.block_until_ready(alg.wcc(sh))
    assert grb.host_transfers() == before, \
        "sharded BFS/k-hop/WCC gathered a frontier to the host"
    np.testing.assert_array_equal(np.asarray(lv),
                                  np.asarray(alg.bfs_levels(ell, seeds)))
    np.testing.assert_array_equal(np.asarray(kc),
                                  np.asarray(alg.khop_counts(ell, seeds, k=3)))
    np.testing.assert_array_equal(np.asarray(wl), np.asarray(alg.wcc(ell)))


@pytest.mark.distributed
def test_sharded_hot_loop_hlo_is_transfer_free(mesh421):
    """Inspect the lowered+compiled HLO, not just the counter: no infeed /
    outfeed / host callback / host-transfer ops anywhere in the program."""
    from repro.distr import graph2d
    _, sh = _distributed_pair(mesh421)
    seeds = jnp.arange(10) * 4
    assert graph2d.scan_host_transfers(
        lambda s: alg.bfs_levels(sh, s), seeds) == []
    assert graph2d.scan_host_transfers(
        lambda s: alg.khop_counts(sh, s, k=3), seeds) == []


# =====================================================================
# Shard-local ewise vs the gather oracle: descriptor-blend grid
# =====================================================================

DESC_BLENDS = ["null", "mask", "mask_comp", "accum", "mask_replace",
               "accum_mask", "accum_mask_comp_replace"]


def _blend(name: str, mask: np.ndarray):
    return Descriptor(
        mask=jnp.asarray(mask) if "mask" in name else None,
        complement="comp" in name,
        accum=S.PLUS if "accum" in name else None,
        replace="replace" in name)


@pytest.mark.distributed
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
@pytest.mark.parametrize("blend", DESC_BLENDS)
@pytest.mark.parametrize("opname", ["add", "mult"])
def test_shardlocal_ewise_matches_gather_oracle(request, meshname, blend,
                                                opname, fresh_trace):
    mesh = request.getfixturevalue(meshname)
    n = 24
    Da, Db = _pattern(n, seed=31, density=0.2), _pattern(n, seed=32,
                                                         density=0.25)
    Dc = _pattern(n, seed=33, density=0.3)
    mask = ((np.arange(n)[:, None] + np.arange(n)[None, :]) % 2) \
        .astype(np.float32)
    ea = GBMatrix.from_dense(Da, fmt="ell")
    eb = GBMatrix.from_dense(Db, fmt="ell")
    ec = GBMatrix.from_dense(Dc, fmt="ell")
    sa, sb = grb.distribute(ea, mesh), grb.distribute(eb, mesh)
    sc = grb.distribute(ec, mesh)
    d = _blend(blend, mask)
    needs_out = d.accum is not None or d.replace
    before = grb.host_transfers()
    if opname == "add":
        got = grb.ewise_add(sa, sb, S.PLUS, d, out=sc if needs_out else None)
        ref = grb.ewise_add(ea, eb, S.PLUS, d, out=ec if needs_out else None)
    else:
        got = grb.ewise_mult(sa, sb, _MUL, d, out=sc if needs_out else None)
        ref = grb.ewise_mult(ea, eb, _MUL, d, out=ec if needs_out else None)
    assert grb.host_transfers() == before, \
        "identically-meshed ewise took the gather-to-host fallback"
    assert got.fmt == "sharded"
    np.testing.assert_allclose(np.asarray(got.to_dense()),
                               np.asarray(ref.to_dense()), rtol=1e-5)


@pytest.mark.distributed
def test_shardlocal_unary_family_matches_oracle(mesh222, fresh_trace):
    """apply / select / min-max reduce / extract stay shard-local and agree
    with the ELL oracle (default descriptor; the blend grid above covers
    the descriptor surface through ewise)."""
    n = 24
    Da = _pattern(n, seed=41, density=0.2)
    ea = GBMatrix.from_dense(Da, fmt="ell")
    sa = grb.distribute(ea, mesh222)
    before = grb.host_transfers()
    ga = grb.apply(_SCALE, sa)
    gs = grb.select(_PRED, sa)
    gmin = grb.reduce(sa, S.MIN, axis=1)
    gmax = grb.reduce(sa, S.MAX, axis=1)
    gx = grb.extract(sa, cols=np.arange(0, n, 2))
    assert grb.host_transfers() == before
    np.testing.assert_allclose(np.asarray(ga.to_dense()),
                               np.asarray(grb.apply(_SCALE, ea).to_dense()),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gs.to_dense()),
                               np.asarray(grb.select(_PRED, ea).to_dense()),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gmin),
                               np.asarray(grb.reduce(ea, S.MIN, axis=1)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gmax),
                               np.asarray(grb.reduce(ea, S.MAX, axis=1)),
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(gx.to_dense()),
        np.asarray(grb.extract(ea, cols=np.arange(0, n, 2)).to_dense()),
        rtol=1e-6)


if HAVE_HYPOTHESIS:

    @pytest.mark.distributed
    @pytest.mark.hypothesis
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(9, 33), density=st.floats(0.05, 0.5),
           opname=st.sampled_from(["add", "mult"]),
           blend=st.sampled_from(DESC_BLENDS), seed=st.integers(0, 99))
    def test_shardlocal_ewise_random_sweep(n, density, opname, blend, seed):
        if jax.device_count() < 8:
            pytest.skip("needs the forced 8-device topology")
        # hypothesis forbids function-scoped fixtures; build the mesh
        # directly over the first 8 devices (same axes as mesh222)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))
        rng = np.random.default_rng(seed)
        Da = _weighted((rng.uniform(size=(n, n)) < density)
                       .astype(np.float32), salt=seed)
        Db = _weighted((rng.uniform(size=(n, n)) < density)
                       .astype(np.float32), salt=seed + 1)
        Dc = _weighted((rng.uniform(size=(n, n)) < density)
                       .astype(np.float32), salt=seed + 2)
        mask = (rng.uniform(size=(n, n)) < 0.5).astype(np.float32)
        ea = GBMatrix.from_dense(Da, fmt="ell")
        eb = GBMatrix.from_dense(Db, fmt="ell")
        ec = GBMatrix.from_dense(Dc, fmt="ell")
        sa, sb = grb.distribute(ea, mesh), grb.distribute(eb, mesh)
        sc = grb.distribute(ec, mesh)
        d = _blend(blend, mask)
        needs_out = d.accum is not None or d.replace
        if opname == "add":
            got = grb.ewise_add(sa, sb, S.PLUS, d,
                                out=sc if needs_out else None)
            ref = grb.ewise_add(ea, eb, S.PLUS, d,
                                out=ec if needs_out else None)
        else:
            got = grb.ewise_mult(sa, sb, _MUL, d,
                                 out=sc if needs_out else None)
            ref = grb.ewise_mult(ea, eb, _MUL, d,
                                 out=ec if needs_out else None)
        np.testing.assert_allclose(np.asarray(got.to_dense()),
                                   np.asarray(ref.to_dense()), rtol=1e-5)

else:

    @pytest.mark.hypothesis
    def test_shardlocal_ewise_random_sweep():
        pytest.importorskip("hypothesis", reason="hypothesis not installed "
                            "(see requirements-dev.txt)")
