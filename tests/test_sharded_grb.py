"""Differential conformance: sharded GBMatrix ops vs the single-device oracle.

The sharded kind must be *invisible* through the grb surface: every op on a
`grb.distribute`d handle (mesh collectives underneath) has to agree with the
same call on dense/ELL storage — same graphs, same semirings, same
descriptors, zero sharding arguments at the call site. Graphs cover the
golden set (K4, C5, Petersen) plus RMAT s6-s8 patterns with deterministic
value weights (so the value-carrying semirings are actually exercised);
semirings cover all four dispatch modes (dot / dot_indicator / bcast-min /
bcast-max). Mixed sharded/unsharded operands and non-ELL stores raise
TypeErrors naming the expected kinds — the PR 3 contract, extended to the
mesh. A hypothesis sweep (importorskip fallback, matching test_ewise.py)
fuzzes shapes/density/semiring/mask on top of the fixed grid.

Needs the forced 8-device CPU topology: `make test-dist` runs it directly;
tier-1 runs it through the subprocess wrapper in test_distributed.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import grb, semiring as S
from repro.core.grb import Descriptor
from repro.core.shard import ShardedELL
from repro.graph.datagen import rmat_graph

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.distributed

SEMIRINGS = {s.name: s for s in
             (S.OR_AND, S.PLUS_TIMES, S.MIN_PLUS, S.MAX_PLUS)}


# -- graph zoo ----------------------------------------------------------------
def _weighted(pattern: np.ndarray) -> np.ndarray:
    """Deterministic value weights >= 0.5 on a 0/1 pattern (the tropical
    convention: 0.0 is indistinguishable from absent in tile storage)."""
    n, m = pattern.shape
    r, c = np.mgrid[0:n, 0:m]
    w = 0.5 + ((r * 31 + c * 17) % 13) / 6.0
    return (pattern * w).astype(np.float32)


def _undirected(n, edges):
    D = np.zeros((n, n), np.float32)
    for a, b in edges:
        D[a, b] = D[b, a] = 1.0
    return D


def _graph_dense(name: str) -> np.ndarray:
    if name == "k4":
        D = 1.0 - np.eye(4, dtype=np.float32)
    elif name == "c5":
        D = _undirected(5, [(i, (i + 1) % 5) for i in range(5)])
    elif name == "petersen":
        D = _undirected(10, [(i, (i + 1) % 5) for i in range(5)]
                        + [(5 + i, 5 + (i + 2) % 5) for i in range(5)]
                        + [(i, 5 + i) for i in range(5)])
    else:                                   # rmat_s6 / rmat_s7 / rmat_s8
        scale = int(name.split("_s")[1])
        g = rmat_graph(scale=scale, edge_factor=8, seed=scale, fmt="ell")
        D = np.asarray(g.relations["KNOWS"].A.to_dense())
        D = (D != 0).astype(np.float32)
    return _weighted(D)


GRAPHS = ("k4", "c5", "petersen", "rmat_s6", "rmat_s7", "rmat_s8")
_DENSE_CACHE: dict = {}


def _dense_of(name):
    if name not in _DENSE_CACHE:
        _DENSE_CACHE[name] = _graph_dense(name)
    return _DENSE_CACHE[name]


def _handles(name, mesh):
    """(dense-oracle handle, sharded handle) for one graph on one mesh."""
    D = _dense_of(name)
    dense = grb.GBMatrix(jnp.asarray(D), name=name)
    sh = grb.distribute(grb.GBMatrix.from_dense(D, fmt="ell", name=name),
                        mesh)
    return dense, sh


def _frontier(name, f=5, seed=0):
    n = _dense_of(name).shape[0]
    rng = np.random.default_rng(seed)
    return rng.uniform(0.5, 2.0, size=(n, f)).astype(np.float32)


# -- mxm / mxv / vxm ----------------------------------------------------------
@pytest.mark.parametrize("srname", sorted(SEMIRINGS))
@pytest.mark.parametrize("name", GRAPHS)
def test_mxm_matches_oracle(name, srname, mesh222):
    sr = SEMIRINGS[srname]
    dense, sh = _handles(name, mesh222)
    X = jnp.asarray(_frontier(name))
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, X, sr)), np.asarray(grb.mxm(dense, X, sr)),
        rtol=1e-5, atol=1e-5)
    # transpose descriptor with no linked transpose: the psum_scatter /
    # pmin row-block lowering, never a materialized flip
    assert sh._T is None
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, X, sr, grb.TRANSPOSE_A)),
        np.asarray(grb.mxm(dense, X, sr, grb.TRANSPOSE_A)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("srname", sorted(SEMIRINGS))
def test_mxm_on_4way_row_mesh(srname, mesh421):
    """Same contract on the 4x2x1 layout (4-way row blocks, size-1 axis)."""
    sr = SEMIRINGS[srname]
    dense, sh = _handles("rmat_s7", mesh421)
    X = jnp.asarray(_frontier("rmat_s7", f=3, seed=7))
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, X, sr)), np.asarray(grb.mxm(dense, X, sr)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, X, sr, grb.TRANSPOSE_A)),
        np.asarray(grb.mxm(dense, X, sr, grb.TRANSPOSE_A)),
        rtol=1e-5, atol=1e-5)


def test_mxm_linked_transpose(mesh222):
    """A linked ELL transpose is sharded alongside and served for
    transpose_a — the all-gather row lowering on the stored A^T."""
    D = _dense_of("petersen")
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    h.link_transpose(grb.GBMatrix.from_dense(D.T, fmt="ell"))
    sh = grb.distribute(h, mesh222)
    assert sh._T is not None and sh._T.fmt == "sharded"
    assert sh.T.T is sh
    X = jnp.asarray(_frontier("petersen", seed=3))
    for sr in (S.PLUS_TIMES, S.MIN_PLUS):
        np.testing.assert_allclose(
            np.asarray(grb.mxm(sh, X, sr, grb.TRANSPOSE_A)),
            np.asarray(grb.mxm(grb.GBMatrix(jnp.asarray(D)), X, sr,
                               grb.TRANSPOSE_A)),
            rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("srname", ["or_and", "plus_times"])
@pytest.mark.parametrize("name", ["c5", "rmat_s6"])
def test_mxv_vxm_match_oracle(name, srname, mesh222):
    sr = SEMIRINGS[srname]
    dense, sh = _handles(name, mesh222)
    x = jnp.asarray(_frontier(name, f=1, seed=1)[:, 0])
    np.testing.assert_allclose(np.asarray(grb.mxv(sh, x, sr)),
                               np.asarray(grb.mxv(dense, x, sr)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(grb.vxm(x, sh, sr)),
                               np.asarray(grb.vxm(x, dense, sr)),
                               rtol=1e-5, atol=1e-5)


# -- descriptor blend ---------------------------------------------------------
@pytest.mark.parametrize("comp", [False, True])
@pytest.mark.parametrize("accum", [False, True])
@pytest.mark.parametrize("replace", [False, True])
def test_descriptor_blend_matches_dense(comp, accum, replace, mesh222):
    """mask/complement/accum/replace ride the identical finalize as the
    dense path — the blend happens on the global (GSPMD) result."""
    name = "petersen"
    dense, sh = _handles(name, mesh222)
    X = jnp.asarray(_frontier(name, seed=5))
    rng = np.random.default_rng(9)
    mask = jnp.asarray((rng.uniform(size=X.shape) < 0.5).astype(np.float32))
    out = jnp.asarray(rng.uniform(0.5, 1.5, size=X.shape).astype(np.float32))
    d = Descriptor(mask=mask, complement=comp,
                   accum=S.PLUS if accum else None, replace=replace)
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, X, S.PLUS_TIMES, d, out=out)),
        np.asarray(grb.mxm(dense, X, S.PLUS_TIMES, d, out=out)),
        rtol=1e-5, atol=1e-5)


# -- reduce -------------------------------------------------------------------
@pytest.mark.parametrize("axis", [None, 0, 1])
@pytest.mark.parametrize("monname", ["plus", "or"])
@pytest.mark.parametrize("name", ["petersen", "rmat_s7"])
def test_reduce_matches_ell(name, monname, axis, mesh222):
    mon = {"plus": S.PLUS, "or": S.OR}[monname]
    D = _dense_of(name)
    ell = grb.GBMatrix.from_dense(D, fmt="ell")
    sh = grb.distribute(ell, mesh222)
    np.testing.assert_allclose(np.asarray(grb.reduce(sh, mon, axis=axis)),
                               np.asarray(grb.reduce(ell, mon, axis=axis)),
                               rtol=1e-5, atol=1e-6)


def test_reduce_minmax_falls_back(mesh421):
    """min/max need absent entries and take the documented gather-to-host
    dense fallback — same numbers as the ELL route."""
    D = _dense_of("rmat_s6")
    ell = grb.GBMatrix.from_dense(D, fmt="ell")
    sh = grb.distribute(ell, mesh421)
    for mon in (S.MIN, S.MAX):
        for axis in (None, 1):
            np.testing.assert_allclose(
                np.asarray(grb.reduce(sh, mon, axis=axis)),
                np.asarray(grb.reduce(ell, mon, axis=axis)))


# -- apply / select (shard-local) ---------------------------------------------
@pytest.mark.parametrize("meshname", ["mesh222", "mesh421"])
def test_apply_select_stay_sharded(meshname, request):
    mesh = request.getfixturevalue(meshname)
    D = _dense_of("rmat_s6")
    ell = grb.GBMatrix.from_dense(D, fmt="ell")
    sh = grb.distribute(ell, mesh)
    ga, ge = grb.apply(lambda v: v * 2.0 + 1.0, sh), \
        grb.apply(lambda v: v * 2.0 + 1.0, ell)
    assert ga.fmt == "sharded" and ga.nvals == ge.nvals
    np.testing.assert_allclose(np.asarray(ga.to_dense()),
                               np.asarray(ge.to_dense()), rtol=1e-6)
    sa, se = grb.select(lambda v: v > 1.2, sh), \
        grb.select(lambda v: v > 1.2, ell)
    assert sa.fmt == "sharded" and sa.nvals == se.nvals
    np.testing.assert_allclose(np.asarray(sa.to_dense()),
                               np.asarray(se.to_dense()), rtol=1e-6)


def test_apply_with_descriptor_gathers_and_reshards(mesh222):
    D = _dense_of("c5")
    ell = grb.GBMatrix.from_dense(D, fmt="ell")
    sh = grb.distribute(ell, mesh222)
    mask = jnp.asarray((D != 0) * (np.arange(5)[:, None] % 2 == 0))
    d = Descriptor(mask=mask.astype(jnp.float32))
    ga = grb.apply(lambda v: v + 3.0, sh, d)
    assert ga.fmt == "sharded"
    np.testing.assert_allclose(
        np.asarray(ga.to_dense()),
        np.asarray(grb.apply(lambda v: v + 3.0, ell, d).to_dense()),
        rtol=1e-6)


# -- ewise family: gather-to-host path keeps the mesh -------------------------
def test_ewise_add_mult_roundtrip(mesh222):
    Da = _dense_of("petersen")
    Db = _weighted(((np.arange(10)[:, None] + np.arange(10)[None, :]) % 3
                    == 0).astype(np.float32))
    ea = grb.GBMatrix.from_dense(Da, fmt="ell")
    eb = grb.GBMatrix.from_dense(Db, fmt="ell")
    sa, sb = grb.distribute(ea, mesh222), grb.distribute(eb, mesh222)
    got = grb.ewise_add(sa, sb, S.PLUS)
    assert got.fmt == "sharded"
    np.testing.assert_allclose(
        np.asarray(got.to_dense()),
        np.asarray(grb.ewise_add(ea, eb, S.PLUS).to_dense()), rtol=1e-6)
    got = grb.ewise_mult(sa, sb, lambda a, b: a * b)
    assert got.fmt == "sharded"
    np.testing.assert_allclose(
        np.asarray(got.to_dense()),
        np.asarray(grb.ewise_mult(ea, eb, lambda a, b: a * b).to_dense()),
        rtol=1e-6)


# -- the mixed-operand / wrong-store contract ---------------------------------
def test_distribute_rejects_non_ell(mesh222):
    D = _dense_of("k4")
    with pytest.raises(TypeError, match="needs ELL or BitELL row"):
        grb.distribute(grb.GBMatrix.from_dense(D, fmt="bsr", block=4),
                       mesh222)
    with pytest.raises(TypeError, match="needs ELL or BitELL row"):
        grb.distribute(grb.GBMatrix(jnp.asarray(D)), mesh222)


def test_distribute_needs_data_axis():
    devs = np.array(jax.devices()[:8]).reshape(8, 1)
    badmesh = jax.sharding.Mesh(devs, ("rows", "cols"))
    with pytest.raises(ValueError, match="'data' axis"):
        ShardedELL.from_dense(_dense_of("k4"), badmesh)


def test_mxm_mixed_operands_raise(mesh222):
    dense, sh = _handles("c5", mesh222)
    ell = grb.GBMatrix.from_dense(_dense_of("c5"), fmt="ell")
    with pytest.raises(TypeError, match=r"dense \(k, F\) frontier"):
        grb.mxm(sh, ell, S.OR_AND)
    with pytest.raises(TypeError, match="B is sharded but A is not"):
        grb.mxm(ell, sh, S.OR_AND)
    # a dense-format GBMatrix B is a dense frontier in handle clothing and
    # must work exactly like it does on an unsharded A
    X = _frontier("c5", seed=2)
    np.testing.assert_allclose(
        np.asarray(grb.mxm(sh, grb.GBMatrix(jnp.asarray(X)), S.PLUS_TIMES)),
        np.asarray(grb.mxm(dense, grb.GBMatrix(jnp.asarray(X)),
                           S.PLUS_TIMES)), rtol=1e-5, atol=1e-5)


def test_distribute_caches_per_mesh(mesh222, mesh421):
    """Per-query contexts re-resolve relations; the distributed twin must
    come from the handle cache, not a fresh pad + device_put every time."""
    ell = grb.GBMatrix.from_dense(_dense_of("rmat_s6"), fmt="ell")
    a = grb.distribute(ell, mesh222)
    assert grb.distribute(ell, mesh222) is a
    b = grb.distribute(ell, mesh421)
    assert b is not a and grb.distribute(ell, mesh421) is b
    assert grb.distribute(a, mesh222) is a      # already-on-mesh fast path


def test_ewise_mixed_operands_raise(mesh222, mesh421):
    ell = grb.GBMatrix.from_dense(_dense_of("c5"), fmt="ell")
    sh = grb.distribute(ell, mesh222)
    with pytest.raises(TypeError, match="operand kinds must match"):
        grb.ewise_add(sh, ell, S.PLUS)
    with pytest.raises(TypeError, match="operand kinds must match"):
        grb.ewise_mult(ell, sh, lambda a, b: a * b)
    with pytest.raises(TypeError, match="operand kinds must match"):
        grb.ewise_add(sh, jnp.asarray(_dense_of("c5")), S.PLUS)
    other = grb.distribute(ell, mesh421)
    with pytest.raises(TypeError, match="different meshes"):
        grb.ewise_add(sh, other, S.PLUS)
    with pytest.raises(TypeError, match="out= is sharded"):
        grb.ewise_add(ell, ell, S.PLUS, out=sh)
    # apply/select honor the same out= contract instead of silently
    # gathering the sharded out
    with pytest.raises(TypeError, match="out= is sharded"):
        grb.apply(lambda v: v + 1.0, ell, out=sh)
    with pytest.raises(TypeError, match="out= is sharded"):
        grb.select(lambda v: v > 0.5, ell, out=sh)


def test_distribute_rehome_keeps_transpose(mesh222, mesh421):
    """Re-homing a sharded handle onto another mesh keeps the linked
    transpose sharded and linked (no silent fall-back to the scatter
    lowering / host rebuild)."""
    D = _dense_of("petersen")
    h = grb.GBMatrix.from_dense(D, fmt="ell")
    h.link_transpose(grb.GBMatrix.from_dense(D.T, fmt="ell"))
    sh = grb.distribute(h, mesh222)
    re = grb.distribute(sh, mesh421)
    assert re.fmt == "sharded" and re.store.mesh == mesh421
    assert re._T is not None and re._T.fmt == "sharded"
    assert re._T.store.mesh == mesh421
    X = jnp.asarray(_frontier("petersen", seed=11))
    np.testing.assert_allclose(
        np.asarray(grb.mxm(re, X, S.PLUS_TIMES, grb.TRANSPOSE_A)),
        np.asarray(grb.mxm(grb.GBMatrix(jnp.asarray(D)), X, S.PLUS_TIMES,
                           grb.TRANSPOSE_A)), rtol=1e-5, atol=1e-5)


def test_assign_mixed_raise_and_roundtrip(mesh222):
    ell = grb.GBMatrix.from_dense(_dense_of("petersen"), fmt="ell")
    sh = grb.distribute(ell, mesh222)
    sub = grb.GBMatrix.from_dense(np.full((2, 2), 5.0, np.float32),
                                  fmt="ell")
    with pytest.raises(TypeError, match="A is sharded but C is not"):
        grb.assign(ell, grb.distribute(sub, mesh222), rows=[0, 1],
                   cols=[0, 1])
    got = grb.assign(sh, sub, rows=[0, 1], cols=[0, 1])
    assert got.fmt == "sharded"
    np.testing.assert_allclose(
        np.asarray(got.to_dense()),
        np.asarray(grb.assign(ell, sub, rows=[0, 1], cols=[0, 1]).to_dense()))


def test_extract_reshards(mesh222):
    ell = grb.GBMatrix.from_dense(_dense_of("rmat_s6"), fmt="ell")
    sh = grb.distribute(ell, mesh222)
    got = grb.extract(sh, rows=range(0, 32), cols=range(8, 40))
    assert got.fmt == "sharded"
    np.testing.assert_allclose(
        np.asarray(got.to_dense()),
        np.asarray(grb.extract(ell, rows=range(0, 32),
                               cols=range(8, 40)).to_dense()))


# -- hypothesis property sweep ------------------------------------------------
if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(2, 48),
           f=st.integers(1, 6), density=st.floats(0.05, 0.5),
           srname=st.sampled_from(sorted(SEMIRINGS)),
           transpose=st.booleans(), mask_mode=st.sampled_from(
               ["none", "mask", "comp"]))
    def test_sharded_mxm_random_sweep(seed, n, f, density, srname, transpose,
                                      mask_mode):
        # hypothesis forbids function-scoped fixtures; build the mesh
        # directly over the first 8 devices
        if jax.device_count() < 8:
            pytest.skip("needs the forced 8-device topology")
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))
        sr = SEMIRINGS[srname]
        rng = np.random.default_rng(seed)
        D = np.where(rng.uniform(size=(n, n)) < density,
                     rng.uniform(0.5, 2.0, size=(n, n)), 0.0) \
            .astype(np.float32)
        X = rng.uniform(0.5, 2.0, size=(n, f)).astype(np.float32)
        mask = (rng.uniform(size=(n, f)) < 0.5).astype(np.float32)
        d = Descriptor(mask=None if mask_mode == "none" else
                       jnp.asarray(mask), complement=mask_mode == "comp",
                       transpose_a=transpose)
        dense = grb.GBMatrix(jnp.asarray(D))
        sh = grb.distribute(grb.GBMatrix.from_dense(D, fmt="ell"), mesh)
        np.testing.assert_allclose(
            np.asarray(grb.mxm(sh, jnp.asarray(X), sr, d)),
            np.asarray(grb.mxm(dense, jnp.asarray(X), sr, d)),
            rtol=1e-5, atol=1e-5)

else:

    @pytest.mark.hypothesis
    def test_sharded_mxm_random_sweep():
        pytest.importorskip("hypothesis", reason="hypothesis not installed "
                            "(see requirements-dev.txt)")
