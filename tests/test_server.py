"""Continuous-batching query server: batched ≡ solo differential grid plus
the scheduler regression tests this PR pins.

The contract under test: coalescing signature-compatible queries into one
packed frontier sweep is a pure latency optimization — every query returns
exactly what the one-query-at-a-time `execute()` path returns, under mixed
queues (compatible / incompatible / unseeded), mid-batch error injection,
duplicate seeds, width-capped chunking, and live writes between flushes.

Regression anchors (each failed on the pre-PR server):
  * predicate CONTENT is part of the batching signature, not just count
  * a bad query poisons only itself — the queue always drains
  * plus_times walk counts keep the seed multiset (dups are distinct users)
  * admission is by total frontier width, not query count
  * each flush serves the freshest snapshot, not the construction-time one
"""
import numpy as np
import pytest

from repro.engine import Database, MutableGraph, QueryServer
from repro.graph.datagen import rmat_graph, social_graph
from repro.graph.graph import GraphBuilder
from repro.query.executor import execute
from repro.query.reference import execute_ref

pytestmark = pytest.mark.serve

K4_EDGES = [(i, j) for i in range(4) for j in range(i + 1, 4)]
PETERSEN_EDGES = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0),
                  (5, 7), (7, 9), (9, 6), (6, 8), (8, 5),
                  (0, 5), (1, 6), (2, 7), (3, 8), (4, 9)]


def _sym_graph(edges, n, fmt="auto"):
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    s, d = np.concatenate([src, dst]), np.concatenate([dst, src])
    return GraphBuilder(n).add_edges("R", s, d).build(fmt=fmt, block=8)


def _grid_graph(name):
    if name == "K4":
        return _sym_graph(K4_EDGES, 4), "R"
    if name == "petersen":
        return _sym_graph(PETERSEN_EDGES, 10), "R"
    scale = int(name[-1])
    return rmat_graph(scale=scale, edge_factor=8, seed=scale,
                      fmt="ell"), "KNOWS"


# -- the differential grid ----------------------------------------------------

@pytest.mark.parametrize("name", ["K4", "petersen", "rmat6", "rmat7", "rmat8"])
def test_batched_matches_solo_grid(name):
    """Mixed queue — two compatible signature groups, an unseeded scan —
    served batched must equal every query served alone."""
    g, rel = _grid_graph(name)
    srv = QueryServer(g)
    texts = {}
    for s in range(0, g.n, max(1, g.n // 7)):
        texts[srv.submit(f"MATCH (a)-[:{rel}*1..2]->(b) WHERE id(a) = {s} "
                         f"RETURN count(DISTINCT b)")] = \
            f"MATCH (a)-[:{rel}*1..2]->(b) WHERE id(a) = {s} " \
            f"RETURN count(DISTINCT b)"
        texts[srv.submit(f"MATCH (a)-[:{rel}*2..3]->(b) WHERE id(a) = {s} "
                         f"RETURN count(DISTINCT b)")] = \
            f"MATCH (a)-[:{rel}*2..3]->(b) WHERE id(a) = {s} " \
            f"RETURN count(DISTINCT b)"
    scan = f"MATCH (a)-[:{rel}]->(b) RETURN count(DISTINCT b)"
    texts[srv.submit(scan)] = scan
    out = srv.flush()
    assert srv.pending == 0
    for qid, text in texts.items():
        assert out[qid].error is None
        assert out[qid].rows == execute(g, text).rows, text
    # two signature groups batch, the unseeded scan rides alone
    assert srv.stats["batches"] == 2
    assert srv.stats["solo"] == 1
    assert srv.stats["queries"] == len(texts)


@pytest.mark.parametrize("name", ["petersen", "rmat6"])
def test_batched_matches_reference_oracle(name):
    """Triangulate against the pure-numpy reference executor, not just the
    solo engine path (or_and queries only — all execute_ref supports)."""
    g, rel = _grid_graph(name)
    srv = QueryServer(g)
    q = f"MATCH (a)-[:{rel}*1..2]->(b) WHERE id(a) IN [0, 2, 5] " \
        f"RETURN count(DISTINCT b)"
    qid = srv.submit(q)
    other = srv.submit(f"MATCH (a)-[:{rel}*1..2]->(b) WHERE id(a) = 1 "
                       f"RETURN count(DISTINCT b)")
    out = srv.flush()
    assert out[qid].rows == execute_ref(g, q).rows
    assert out[other].rows != [] and srv.stats["batches"] == 1


# -- regression: signature is content-complete --------------------------------

def test_signature_includes_predicate_content():
    """Two queries differing ONLY in a WHERE constant must not share a
    sweep (pre-PR the signature hashed predicate COUNTS, silently giving
    both tenants one of the two filters)."""
    g = social_graph(n=128, seed=3)
    srv = QueryServer(g)
    qa = "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 1 AND b.age > 30 " \
         "RETURN count(DISTINCT b)"
    qb = "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 2 AND b.age > 60 " \
         "RETURN count(DISTINCT b)"
    ia, ib = srv.submit(qa), srv.submit(qb)
    out = srv.flush()
    assert out[ia].rows == execute(g, qa).rows
    assert out[ib].rows == execute(g, qb).rows
    assert srv.stats["batches"] == 2      # incompatible: different filters
    # same constants DO batch
    srv2 = QueryServer(g)
    srv2.submit(qa)
    srv2.submit(qa.replace("id(a) = 1", "id(a) = 2"))
    srv2.flush()
    assert srv2.stats["batches"] == 1


# -- regression: error isolation ----------------------------------------------

def test_error_injection_mid_batch():
    """A query naming an unknown relation, queued between good ones, comes
    back as an error Result; the good tenants still get answers and the
    queue drains (pre-PR: flush raised and left the queue poisoned)."""
    g = social_graph(n=128, seed=1)
    srv = QueryServer(g)
    good1 = srv.submit("MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 3 "
                       "RETURN count(DISTINCT b)")
    bad = srv.submit("MATCH (a)-[:NOPE]->(b) WHERE id(a) = 3 "
                     "RETURN count(DISTINCT b)")
    good2 = srv.submit("MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 5 "
                       "RETURN count(DISTINCT b)")
    out = srv.flush()
    assert srv.pending == 0
    assert out[bad].error is not None and "NOPE" in out[bad].error
    for qid, s in [(good1, 3), (good2, 5)]:
        assert out[qid].error is None
        assert out[qid].rows == execute(
            g, f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = {s} "
               f"RETURN count(DISTINCT b)").rows
    assert srv.stats["errors"] == 1
    # the server stays serviceable after the failure
    again = srv.submit("MATCH (a)-[:KNOWS]->(b) WHERE id(a) = 3 "
                       "RETURN count(DISTINCT b)")
    assert srv.flush()[again].error is None


def test_bad_seed_isolated_within_batch():
    """An out-of-range seed id fails ONLY its own query; signature-equal
    members sharing the sweep still answer correctly."""
    g = social_graph(n=128, seed=2)
    srv = QueryServer(g)
    ok = srv.submit("MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 4 "
                    "RETURN count(DISTINCT b)")
    bad = srv.submit(f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = {10**6} "
                     f"RETURN count(DISTINCT b)")
    out = srv.flush()
    assert out[bad].error is not None and "seed id out of range" in out[bad].error
    assert out[ok].rows == execute(
        g, "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 4 "
           "RETURN count(DISTINCT b)").rows
    assert srv.stats["errors"] == 1


def test_submit_rejects_parse_errors_eagerly():
    g = social_graph(n=64, seed=0)
    srv = QueryServer(g)
    with pytest.raises(SyntaxError):
        srv.submit("MATCH (a)-[:KNOWS->(b RETURN")
    assert srv.pending == 0               # nothing reached the queue


def test_masked_out_seeds_return_empty():
    """Seeds that fail the source label mask produce zero rows — batched
    and solo agree (pre-PR the batched path emitted a bogus 0-count row)."""
    g = social_graph(n=128, seed=4)
    city = int(np.nonzero(np.asarray(g.label_mask("City")))[0][0])
    q = f"MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = {city} " \
        f"RETURN count(DISTINCT b)"
    srv = QueryServer(g)
    masked = srv.submit(q)
    live = srv.submit("MATCH (a:Person)-[:KNOWS]->(b) WHERE id(a) = 1 "
                      "RETURN count(DISTINCT b)")
    out = srv.flush()
    assert out[masked].rows == execute(g, q).rows == []
    assert out[live].rows != []


# -- regression: duplicate-seed walk counts -----------------------------------

def test_duplicate_seeds_keep_walk_multiplicity():
    """count(b) without DISTINCT is plus_times walk counting: `id(a) IN
    [3, 3, 5]` means seed 3 contributes TWICE (two users who happen to
    start at the same vertex). Pre-PR both paths collapsed the multiset
    through sorted(set(...)))."""
    g = _sym_graph(PETERSEN_EDGES, 10)
    q = "MATCH (a)-[:R*2..2]->(b) WHERE id(a) IN [3, 3, 5] RETURN count(b)"
    A = np.zeros((10, 10))
    for s, d in PETERSEN_EDGES:
        A[s, d] = A[d, s] = 1
    A2 = A @ A
    want = int(2 * A2[3].sum() + A2[5].sum())
    assert execute(g, q).rows == [(want,)]              # solo path
    srv = QueryServer(g)
    dup = srv.submit(q)
    mate = srv.submit("MATCH (a)-[:R*2..2]->(b) WHERE id(a) IN [0, 1] "
                      "RETURN count(b)")
    out = srv.flush()
    assert out[dup].rows == [(want,)]                   # batched ≡ solo
    assert out[mate].rows == [(int(A2[0].sum() + A2[1].sum()),)]
    assert srv.stats["batches"] == 1                    # dups still coalesce
    # or_and reachability stays deduped: same seeds, DISTINCT count
    qd = "MATCH (a)-[:R*2..2]->(b) WHERE id(a) IN [3, 3, 5] " \
         "RETURN count(DISTINCT b)"
    srv2 = QueryServer(g)
    did = srv2.submit(qd)
    assert srv2.flush()[did].rows == execute_ref(g, qd).rows


# -- regression: width-based admission control --------------------------------

def test_chunking_is_by_total_frontier_width():
    """8 compatible queries x 16 seeds = 128 columns. max_width=64 must
    split them into 2 sweeps (pre-PR chunking counted queries, flattening
    all 128 columns into one frontier)."""
    g = social_graph(n=256, seed=5)
    srv = QueryServer(g, max_width=64)
    t = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"
    qids = {}
    for i in range(8):
        seeds = list(range(16 * i, 16 * i + 16))
        qids[srv.submit(t, seeds=seeds)] = seeds
    out = srv.flush()
    assert srv.stats["batches"] == 2
    assert srv.stats["batch_width_max"] <= 64
    for qid, seeds in qids.items():
        seed_list = ", ".join(map(str, seeds))
        assert out[qid].rows == execute(
            g, f"MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) IN [{seed_list}] "
               f"RETURN count(DISTINCT b)").rows
    # one query wider than the cap still runs — alone
    srv2 = QueryServer(g, max_width=64)
    wide = srv2.submit(t, seeds=list(range(100)))
    srv2.submit(t, seeds=[1])
    out2 = srv2.flush()
    assert srv2.stats["batches"] == 2
    assert out2[wide].error is None


def test_max_batch_caps_member_count():
    g = social_graph(n=128, seed=6)
    srv = QueryServer(g, max_batch=3)
    t = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"
    for s in range(7):
        srv.submit(t, seeds=[s])
    srv.flush()
    assert srv.stats["batches"] == 3      # 3 + 3 + 1


# -- plan cache ---------------------------------------------------------------

def test_plan_cache_hit_accounting():
    g = social_graph(n=128, seed=7)
    srv = QueryServer(g)
    t = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"
    for s in range(10):
        srv.submit(t, seeds=[s])          # parameterized: one cache entry
    srv.submit("MATCH  (a)-[:KNOWS*1..2]->(b)   RETURN count(DISTINCT b)",
               seeds=[3])                 # whitespace-normalized: still a hit
    srv.submit("MATCH (a)-[:VISITS]->(b) RETURN count(DISTINCT b)")  # miss
    out = srv.flush()
    assert srv.stats["plan_cache_misses"] == 2
    assert srv.stats["plan_cache_hits"] == 10
    assert srv.stats["plan_cache_hit_rate"] == pytest.approx(10 / 12)
    assert all(r.error is None for r in out.values())
    # the 11 parameterized submissions share one signature -> one sweep
    assert srv.stats["batches"] == 1


def test_parameterized_seeds_do_not_leak_between_queries():
    """dataclasses.replace on the cached Plan: two bindings of one template
    must not see each other's seeds."""
    g = _sym_graph(K4_EDGES, 4)
    srv = QueryServer(g)
    t = "MATCH (a)-[:R*1..1]->(b) RETURN count(DISTINCT b)"
    q0 = srv.submit(t, seeds=[0])
    q1 = srv.submit(t, seeds=[0, 1, 2, 3])
    out = srv.flush()
    assert out[q0].rows == [(3,)]         # K4: one seed reaches the other 3
    assert out[q1].rows == [(12,)]        # 4 seed columns x 3 reachable each


# -- regression: snapshot freshness -------------------------------------------

def test_flush_serves_fresh_snapshot():
    """Writes committed after the server is constructed are visible to the
    next flush (pre-PR the server froze its graph once, at construction,
    and served stale reads forever)."""
    mg = MutableGraph()
    mg.create_node("Person", {"id": 0})
    mg.create_node("Person", {"id": 1})
    mg.create_node("Person", {"id": 2})
    mg.create_edge(0, "KNOWS", 1)
    mg.create_edge(1, "KNOWS", 2)
    srv = QueryServer(mg)
    q = "MATCH (a)-[:KNOWS*1..3]->(b) WHERE id(a) = 0 " \
        "RETURN count(DISTINCT b)"
    first = srv.submit(q)
    assert srv.flush()[first].rows == [(2,)]
    mg.create_node("Person", {"id": 3})
    mg.create_edge(2, "KNOWS", 3)         # create AFTER first flush
    second = srv.submit(q)
    assert srv.flush()[second].rows == [(3,)]          # not stale


def test_database_server_tracks_creates():
    db = Database()
    db.query("g", "CREATE (:Person {id: 0}), (:Person {id: 1}), "
                  "(:Person {id: 2})")
    db.query("g", "CREATE (0)-[:KNOWS]->(1)")
    srv = db.server("g")
    q = "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 0 " \
        "RETURN count(DISTINCT b)"
    first = srv.submit(q)
    assert srv.flush()[first].rows == [(1,)]
    db.query("g", "CREATE (1)-[:KNOWS]->(2)")
    second = srv.submit(q)
    assert srv.flush()[second].rows == [(2,)]


def test_callable_source_is_a_refresh_hook():
    graphs = [_sym_graph(K4_EDGES, 4), _sym_graph(PETERSEN_EDGES, 10)]
    srv = QueryServer(lambda: graphs[0])
    q = "MATCH (a)-[:R*1..1]->(b) WHERE id(a) = 0 RETURN count(DISTINCT b)"
    a = srv.submit(q)
    assert srv.flush()[a].rows == [(3,)]               # K4 degree
    graphs[0] = graphs[1]
    b = srv.submit(q)
    assert srv.flush()[b].rows == [(3,)]               # Petersen: also 3
    c = srv.submit("MATCH (a)-[:R*1..2]->(b) WHERE id(a) = 0 "
                   "RETURN count(DISTINCT b)")
    assert srv.flush()[c].rows == [(9,)]               # Petersen diameter 2


def test_database_source_requires_graph_name():
    db = Database()
    db.query("g", "CREATE (:Person {id: 0})")
    with pytest.raises(TypeError):
        QueryServer(db)
    with pytest.raises(TypeError):
        QueryServer(42)                   # not a servable source at all


# -- serving metrics ----------------------------------------------------------

def test_serving_metrics_recorded():
    g = social_graph(n=128, seed=8)
    srv = QueryServer(g)
    t = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"
    for s in range(6):
        srv.submit(t, seeds=[s])
    srv.flush()
    # 6 lanes pad to AUTO_PACK_MIN_WIDTH-aligned 8 slots
    assert srv.stats["pack_lanes"] == 6
    assert srv.stats["pack_slots"] == 8
    assert srv.stats["pack_ratio"] == pytest.approx(0.75)
    assert srv.stats["batch_width_max"] == 6
    assert srv.stats["queue_wait_s_total"] > 0.0
    assert len(srv.log) == 6
    for m in srv.log:
        assert m.result is not None
        assert 0.0 <= m.wait_s <= m.latency_s


def test_unaligned_mode_packs_exact_width():
    g = social_graph(n=128, seed=8)
    srv = QueryServer(g, align=False)
    t = "MATCH (a)-[:KNOWS*1..2]->(b) RETURN count(DISTINCT b)"
    for s in range(6):
        srv.submit(t, seeds=[s])
    srv.flush()
    assert srv.stats["pack_slots"] == 6
    assert srv.stats["pack_ratio"] == pytest.approx(1.0)


# -- CALL algo.* through the scheduler ----------------------------------------

def test_call_batched_matches_solo():
    """Seeded CALL queries with one signature coalesce into ONE device
    sweep (proc + args + YIELD equal, sources differ) and every member
    still answers exactly what the solo execute() path answers — the
    batched ≡ solo contract extends to procedures."""
    g, rel = _grid_graph("rmat6")
    srv = QueryServer(g)
    t = "CALL algo.closeness(rel: KNOWS) YIELD node, score"
    seed_sets = [[0], [3, 9], [17], [2, 5, 30]]
    qids = [srv.submit(t, seeds=s) for s in seed_sets]
    # a different-kind similarity call must NOT join the closeness sweep
    qsim = srv.submit("CALL algo.similarity(rel: KNOWS, kind: cosine) "
                      "YIELD node1, node2, score", seeds=[1, 4])
    # an unseeded whole-graph procedure rides alone
    qpr = srv.submit("CALL algo.pagerank(rel: KNOWS, iters: 30) "
                     "YIELD node, score LIMIT 5")
    out = srv.flush()
    for qid, seeds in zip(qids, seed_sets):
        want = execute(g, "CALL algo.closeness(rel: KNOWS, sources: "
                          f"{seeds}) YIELD node, score")
        assert out[qid].error is None
        assert out[qid].rows == want.rows, f"seeds {seeds}"
    want = execute(g, "CALL algo.similarity(rel: KNOWS, kind: cosine, "
                      "sources: [1, 4]) YIELD node1, node2, score")
    assert out[qsim].rows == want.rows
    want = execute(g, "CALL algo.pagerank(rel: KNOWS, iters: 30) "
                      "YIELD node, score LIMIT 5")
    assert out[qpr].rows == want.rows
    # 4 closeness members -> one sweep; similarity -> its own; pagerank solo
    assert srv.stats["batches"] == 2
    assert srv.stats["solo"] == 1
    assert srv.stats["errors"] == 0


def test_call_plan_cache_normalizes_argument_lists():
    """PlanCache whitespace normalization reaches INSIDE parenthesized
    CALL argument lists: spaces next to punctuation never split the cache
    (the pre-PR key only collapsed whitespace runs, so `(iters: 20)` and
    `( iters:20 )` were two entries)."""
    g, rel = _grid_graph("rmat6")
    srv = QueryServer(g)
    variants = [
        "CALL algo.closeness(rel: KNOWS) YIELD node, score",
        "CALL algo.closeness( rel: KNOWS ) YIELD node , score",
        "CALL  algo.closeness(rel:KNOWS)  YIELD node,score",
        "CALL algo . closeness ( rel : KNOWS ) YIELD node, score",
    ]
    qids = [srv.submit(t, seeds=[i]) for i, t in enumerate(variants)]
    out = srv.flush()
    assert srv.stats["plan_cache_misses"] == 1
    assert srv.stats["plan_cache_hits"] == len(variants) - 1
    # one cache entry -> one signature -> ONE coalesced sweep
    assert srv.stats["batches"] == 1
    for i, qid in enumerate(qids):
        want = execute(g, f"CALL algo.closeness(rel: KNOWS, sources: [{i}])"
                          " YIELD node, score")
        assert out[qid].rows == want.rows


def test_call_unknown_procedure_error_isolated():
    """An unknown procedure name (or bad args / bad YIELD column) plans
    fine and fails at execution — the server answers it with an error
    Result and every other tenant still gets its rows."""
    g, rel = _grid_graph("K4")
    srv = QueryServer(g)
    qgood1 = srv.submit("CALL algo.closeness(rel: R) YIELD node, score",
                        seeds=[0])
    qbad = srv.submit("CALL algo.nosuch() YIELD x")
    qargs = srv.submit("CALL algo.pagerank(rel: R, bogus: 3)")
    qyield = srv.submit("CALL algo.wcc(rel: R) YIELD nope")
    qsrc = srv.submit("CALL algo.wcc(rel: R, sources: [1])")
    qgood2 = srv.submit("MATCH (a)-[:R*1..1]->(b) RETURN count(DISTINCT b)",
                        seeds=[1])
    out = srv.flush()
    assert out[qbad].error is not None and "no procedure" in out[qbad].error
    assert out[qargs].error is not None and "bogus" in out[qargs].error
    assert out[qyield].error is not None and "nope" in out[qyield].error
    assert out[qsrc].error is not None and "takes no sources" in out[qsrc].error
    assert out[qgood1].error is None and len(out[qgood1].rows) == 1
    assert out[qgood2].rows == [(3,)]
    assert srv.stats["errors"] == 4
