"""Structural perf checks for the Pallas kernel: VMEM working-set budget and
MXU alignment of the production tile configuration (reasoned from BlockSpecs,
per the dry-run-profiling methodology — no TPU needed)."""
import numpy as np

from repro.kernels import bsr_mxm as K

VMEM_BYTES = 16 * 1024 * 1024     # v5e per-core VMEM


def working_set_bytes(block: int, f_tile: int, in_dtype_bytes: int = 4,
                      bcast_chunk: int = 8):
    """Live VMEM per grid step: A tile + X tile + Y tile (+ mask tile) plus
    the tropical path's broadcast chunk."""
    a = block * block * in_dtype_bytes
    x = block * f_tile * 4
    y = block * f_tile * 4
    m = block * f_tile * 4
    trop = bcast_chunk * block * f_tile * 4
    return a + x + y + m + trop


def test_default_config_fits_vmem():
    assert working_set_bytes(128, K.DEFAULT_F_TILE) < VMEM_BYTES // 2


def test_large_tiles_fit_with_headroom():
    # the tuning range the kernel exposes stays inside VMEM
    for block in (128, 256):
        for f_tile in (128, 256, 512):
            ws = working_set_bytes(block, f_tile)
            assert ws < VMEM_BYTES, (block, f_tile, ws)


def test_mxu_alignment_of_production_tiles():
    # MXU is 128x128: production block sizes must be multiples of 128
    for block in (128, 256):
        assert block % 128 == 0
    assert K.DEFAULT_F_TILE % 128 == 0


def test_grid_is_sequential_minor_for_revisits():
    """The accumulation schedule requires the nnzb axis to iterate minormost
    (revisited output tiles stay in VMEM): documented invariant check on the
    grid construction — (F_tiles, nnzb) with nnzb last."""
    import inspect
    src = inspect.getsource(K.bsr_mxm)
    assert "grid = (fp // ft, A.nnzb)" in src
