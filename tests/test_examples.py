"""The examples are part of the public API surface: run each as a subprocess
(proves they are genuinely runnable, not just importable)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(name, args=(), timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, os.path.join(ROOT, "examples", name),
                        *args],
                       cwd=ROOT, env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"{name}: {r.stdout[-1500:]}\n{r.stderr[-1500:]}"
    return r.stdout


def test_quickstart():
    out = run_example("quickstart.py")
    assert "2-hop neighborhood of node 0:" in out
    assert "ConditionalTraverse" in out
    # the mesh= surface answers the same query identically
    assert "device mesh: 3" in out


def test_serve_queries():
    out = run_example("serve_queries.py", ["--scale", "9", "--queries", "64"])
    assert "queries/s" in out
    # continuous batching under open-loop arrivals: batch count is timing-
    # dependent, but the serving metrics must be reported
    assert "batches=" in out and "pack ratio" in out
    assert "plan cache" in out and "p99=" in out


def test_graph_analytics():
    out = run_example("graph_analytics.py")
    assert "pagerank" in out and "triangles" in out
    assert "wcc" in out and "sssp" in out
    # the grb.distribute surface runs the unchanged algorithm bit-identically
    assert "sharded khop" in out and "bit-identical" in out


def test_train_lm():
    out = run_example("train_lm.py", ["--steps", "8"])
    assert "descending" in out
