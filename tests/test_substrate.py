"""Training substrate: optimizers, checkpointing, data pipeline, gradient
compression, elastic restart policy, sharding inference."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.distr import compression
from repro.launch.elastic import RestartPolicy, plan_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.data import synthetic_batch


def quad_params():
    return {"w": jnp.asarray([3.0, -2.0, 5.0]), "b": jnp.asarray([[1.0, 2.0]] * 80)}


def quad_loss(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends(name):
    opt = opt_mod.OptConfig(name=name, lr=0.05, warmup_steps=0,
                            total_steps=200, weight_decay=0.0)
    params = quad_params()
    state = opt_mod.init_fn(name)(params)
    update = opt_mod.update_fn(name)
    l0 = float(quad_loss(params))
    for _ in range(100):
        grads = jax.grad(quad_loss)(params)
        params, state = update(opt, params, grads, state)
    assert float(quad_loss(params)) < 0.1 * l0


def test_adafactor_is_factored():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8, 8))}
    state = opt_mod.adafactor_init(params)
    assert set(state["acc"]["big"].keys()) == {"vr", "vc"}
    assert state["acc"]["big"]["vr"].shape == (256,)
    assert set(state["acc"]["small"].keys()) == {"v"}


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = opt_mod.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(gn) == pytest.approx(200.0)


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"p": jnp.arange(12.0).reshape(3, 4), "s": jnp.asarray(7)}
    ckpt.save(tree, str(tmp_path), 5)
    ckpt.save(jax.tree.map(lambda x: x + 1, tree), str(tmp_path), 9)
    assert ckpt.latest_step(str(tmp_path)) == 9
    restored, step = ckpt.restore(tree, str(tmp_path))
    assert step == 9
    np.testing.assert_allclose(restored["p"], np.asarray(tree["p"]) + 1)


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"p": jnp.ones((4,))}
    ckpt.save(tree, str(tmp_path), 1)
    # flip bytes of the leaf file
    leaf = os.path.join(str(tmp_path), "step_1", "leaf_0.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError):
        ckpt.restore(tree, str(tmp_path))


def test_async_checkpointer_gc(tmp_path):
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"p": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        w.save(tree, s)
    w.wait()
    steps = sorted(n for n in os.listdir(str(tmp_path)) if n.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    _, s = ckpt.restore(tree, str(tmp_path))
    assert s == 4


def test_data_deterministic_and_restart_safe():
    cfg = get_config("qwen2-1.5b")
    shape = ShapeConfig("t", 32, 8, "train")
    a = synthetic_batch(cfg, shape, step=7)
    b = synthetic_batch(cfg, shape, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, shape, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the same global batch
    h0 = synthetic_batch(cfg, shape, step=7, host_index=0, host_count=2)
    h1 = synthetic_batch(cfg, shape, step=7, host_index=1, host_count=2)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"])


def test_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    # one-shot quantization error is bounded by scale/2
    dq, err = compression.compress_decompress(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(dq["w"] - g["w"]))) <= scale * 0.5 + 1e-6
    # with error feedback, the *sum* of compressed grads tracks the true sum
    total_true = np.zeros((64, 64), np.float32)
    total_comp = np.zeros((64, 64), np.float32)
    err = None
    for i in range(50):
        gi = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        dq, err = compression.compress_decompress(gi, err)
        total_true += np.asarray(gi["w"])
        total_comp += np.asarray(dq["w"])
    resid = np.abs(total_comp - total_true).max()
    assert resid <= scale * 1.5  # residual bounded, not accumulating


def test_elastic_plan_mesh():
    assert plan_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256) == ((16, 16), ("data", "model"))
    assert plan_mesh(192) == ((12, 16), ("data", "model"))  # shrunk DP
    with pytest.raises(RuntimeError):
        plan_mesh(8)


def test_restart_policy_detects_dead_and_stragglers():
    t = [0.0]
    pol = RestartPolicy(timeout_s=10, straggler_factor=2.0,
                        clock=lambda: t[0])
    for w in ("w0", "w1", "w2", "w3"):
        pol.heartbeat(w, 1.0)
    t[0] = 8.0
    for w in ("w0", "w1", "w2"):
        pol.heartbeat(w, 1.0 if w != "w2" else 5.0)
    t[0] = 16.0  # w3 last beat at 0 -> dead; w0..w2 beat 8s ago -> alive
    assert pol.dead_workers() == ["w3"]
    assert pol.stragglers() == ["w2"]
    assert pol.should_restart()
    shape, axes = pol.plan_restart(chips_per_worker=256)
    assert shape == ((2, 16, 16))[:len(shape)] or shape[0] * shape[1] <= 512


def test_train_loop_descends_and_resumes(tmp_path):
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", "qwen2-1.5b", "--steps", "12",
                         "--batch", "4", "--seq", "32",
                         "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"])
    assert losses[-1] < losses[0]
    # resume continues from the checkpoint (12 steps saved)
    losses2 = train_main(["--arch", "qwen2-1.5b", "--steps", "14",
                          "--batch", "4", "--seq", "32",
                          "--ckpt-dir", str(tmp_path), "--resume"])
    assert len(losses2) == 2  # only steps 12..13 ran
