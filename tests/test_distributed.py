"""Distributed correctness + dry-run smoke, in subprocesses (so the fake
device count never leaks into this process's jax)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(cmd, env_extra=None, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=timeout)


def test_distributed_checks():
    r = run([sys.executable, os.path.join(ROOT, "tests", "distributed_check.py")])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL DISTRIBUTED CHECKS PASSED" in r.stdout


def test_dryrun_cli_smoke(tmp_path):
    """The real dryrun module end-to-end on a reduced 32-device grid."""
    r = run([sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "gemma-2b", "--shape", "decode_32k",
             "--mesh", "single", "--out", str(tmp_path)],
            env_extra={"XLA_FLAGS":
                       "--xla_force_host_platform_device_count=256"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1 ok, 0 errors" in r.stdout
