"""Distributed suite plumbing.

Tier-1 pytest runs single-device jax (the fake multi-device CPU topology
can only be forced through XLA_FLAGS before backend init), so the
`distributed`-marked suite — sharded GBMatrix conformance
(test_sharded_grb.py), end-to-end goldens (test_sharded_e2e.py), and the
train-lowering checks below — auto-skips in-process and runs here once in
an env-guarded subprocess (`REPRO_FORCE_DEVICES=8`, the conftest
early-import hook). `make test-dist` runs the same suite directly.

The dry-run CLI smoke keeps its own subprocess (256 fake devices).
"""
import dataclasses
import os
import re
import subprocess
import sys

import jax
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(cmd, env_extra=None, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=timeout)


def test_distributed_suite_subprocess():
    """The whole `distributed` marker on the forced 8-device topology."""
    if jax.device_count() >= 8:
        pytest.skip("already on a multi-device topology; the distributed "
                    "suite runs directly in this session")
    r = run([sys.executable, "-m", "pytest", "-q",
             "-m", "distributed and not hypothesis",
             os.path.join(ROOT, "tests")],
            env_extra={"REPRO_FORCE_DEVICES": "8"})
    tail = r.stdout[-4000:] + r.stderr[-2000:]
    assert r.returncode == 0, tail
    m = re.search(r"(\d+) passed", r.stdout)
    assert m and int(m.group(1)) >= 40, \
        f"distributed suite barely ran anything:\n{tail}"


# -- dryrun probes stay numerically honest (folded from distributed_check) ----
@pytest.mark.distributed
def test_dryrun_probes_match_oracle():
    """The fused khop_counts_2d (incl. bitmap-packed + sentinel perf
    variants) and pagerank_2d loops only serve launch.dryrun rooflines now,
    but a roofline computed from a numerically wrong kernel is worthless —
    pin them to the single-device grb oracle like distributed_check.py did."""
    import jax.numpy as jnp
    from repro import algorithms as alg
    from repro.distr import graph2d
    from repro.graph.datagen import rmat_graph

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                             ("data", "model"))
    g = rmat_graph(scale=7, edge_factor=8, seed=0, fmt="ell")
    n, rel, k, f = g.n, g.relations["KNOWS"], 3, 8
    seeds = np.random.default_rng(0).integers(0, n, size=f)
    frontier = np.zeros((n, f), np.int8)
    frontier[seeds, np.arange(f)] = 1
    want = np.asarray(alg.khop_counts(rel, seeds, k=k))
    idx, msk = graph2d.ell_shard_inputs(rel.A_T)
    idx_sent, _ = graph2d.ell_shard_inputs(rel.A_T, sentinel=True)
    for packed, sentinel in ((False, False), (True, False), (True, True)):
        fn = graph2d.khop_counts_2d(mesh, n, k, packed=packed,
                                    sentinel=sentinel)
        jfn = jax.jit(fn, in_shardings=graph2d.shardings_2d(
            mesh, n, idx.shape[1], f))
        got = np.asarray(jfn(jnp.asarray(idx_sent if sentinel else idx),
                             jnp.asarray(msk), jnp.asarray(frontier)))
        np.testing.assert_array_equal(
            got, want, err_msg=f"packed={packed} sentinel={sentinel}")

    deg = np.asarray(rel.A.to_dense()).astype(bool).sum(1).astype(np.float32)
    got_pr = np.asarray(jax.jit(graph2d.pagerank_2d(mesh, n, iters=30))(
        jnp.asarray(idx), jnp.asarray(msk), jnp.asarray(deg)))
    np.testing.assert_allclose(got_pr, np.asarray(alg.pagerank(rel, iters=30)),
                               rtol=1e-4, atol=1e-6)


# -- train-step lowering on the mesh (folded from distributed_check.py) -------
def _lower_train(multi_pod: bool):
    from repro.configs.base import ShapeConfig, get_config
    from repro.distr import sharding as sh
    from repro.distr.shardctx import ShardCtx, use
    from repro.models import get_model
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import make_train_step

    devs = np.array(jax.devices()[:8])       # robust to > 8 forced devices
    mesh = (jax.sharding.Mesh(devs.reshape(2, 2, 2),
                              ("pod", "data", "model")) if multi_pod
            else jax.sharding.Mesh(devs.reshape(2, 4), ("data", "model")))
    cfg = get_config("qwen2-1.5b")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=64, d_ff=128, vocab=160, n_heads=4,
        n_kv_heads=2, head_dim=16, dtype="float32")
    shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")
    model = get_model(cfg)
    ctx = ShardCtx(mesh)
    pspecs = model.param_specs()
    pshard = sh.param_shardings(pspecs, mesh, vocab=cfg.vocab)
    ospecs = jax.eval_shape(opt_mod.init_fn(cfg.optimizer), pspecs)
    oshard = sh.opt_state_shardings(ospecs, mesh, vocab=cfg.vocab)
    bspecs = model.train_input_specs(shape)
    bshard = sh.batch_shardings(bspecs, mesh)
    step = make_train_step(model, opt_mod.OptConfig(name=cfg.optimizer))
    with use(ctx):
        lowered = jax.jit(step, in_shardings=(pshard, oshard, bshard)) \
            .lower(pspecs, ospecs, bspecs)
    return lowered.compile()


@pytest.mark.distributed
@pytest.mark.parametrize("multi_pod", [False, True])
def test_train_lowering_has_collectives(multi_pod):
    compiled = _lower_train(multi_pod)
    txt = compiled.as_text()
    assert ("all-reduce" in txt or "all-gather" in txt
            or "reduce-scatter" in txt), "no collectives in SPMD module?"
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict], newer a dict
        cost = cost[0]
    assert cost["flops"] > 0


def test_dryrun_cli_smoke(tmp_path):
    """The real dryrun module end-to-end on a reduced 32-device grid."""
    r = run([sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "gemma-2b", "--shape", "decode_32k",
             "--mesh", "single", "--out", str(tmp_path)],
            env_extra={"XLA_FLAGS":
                       "--xla_force_host_platform_device_count=256"},
            timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "1 ok, 0 errors" in r.stdout
