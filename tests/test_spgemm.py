"""BSR x BSR SpGEMM conformance suite vs the dense oracle.

Two layers, both marked `spgemm`:

  * a deterministic parametrized sweep (shapes incl. n not divisible by the
    block, densities, block sizes, plus_times/plus_pair, masked/unmasked/
    complemented, XLA and Pallas-interpret numeric phases) that always runs;
  * hypothesis-generated COO graphs over the same oracle, guarded with the
    `importorskip` convention from test_property.py (the guard is per-test
    here so the deterministic sweep still runs without hypothesis).

Also pins the structural contract: explicit zero blocks (masked-out or
numerically cancelled tiles) are pruned on construction so `nvals` and
`fill_ratio` report stored structure.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BSR, grb, semiring as S
from repro.core.bsr import bsr_union, spgemm, spgemm_symbolic
from repro.core.grb import Descriptor
from repro.kernels import ops as kops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.spgemm


# -- helpers -----------------------------------------------------------------
def rand_bsr(n, m, nnz, block, seed, weighted=True):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, size=max(nnz, 1))
    c = rng.integers(0, m, size=max(nnz, 1))
    key = r * m + c
    _, i = np.unique(key, return_index=True)
    r, c = r[i], c[i]
    v = (rng.uniform(0.5, 2.0, size=len(r)).astype(np.float32)
         if weighted else None)
    return BSR.from_coo(r, c, v, (n, m), block=block)


def dense_oracle(DA, DB, sr, mask=None, complement=False):
    """Independent NumPy SpGEMM oracle: dense semiring matmul + mask."""
    raw = np.asarray(S.dense_mxm(jnp.asarray(DA), jnp.asarray(DB), sr))
    if mask is None:
        return raw
    keep = (mask == 0) if complement else (mask != 0)
    return np.where(keep, raw, np.float32(sr.identity))


def check_case(A, B, sr, mask=None, complement=False, impl="xla"):
    C = spgemm(A, B, sr, mask=mask, complement=complement, impl=impl,
               interpret=True)
    DM = None if mask is None else np.asarray(mask.to_dense())
    want = dense_oracle(np.asarray(A.to_dense()), np.asarray(B.to_dense()),
                        sr, mask=DM, complement=complement)
    np.testing.assert_allclose(np.asarray(C.to_dense()), want,
                               rtol=1e-5, atol=1e-5)
    assert C.nnz == int(np.count_nonzero(want))
    return C


# -- deterministic oracle sweep ----------------------------------------------
SHAPES = [
    (96, 96, 96, 32),      # block-aligned square
    (130, 70, 50, 32),     # nothing divisible by the block
    (64, 128, 96, 16),     # rectangular chain
    (37, 53, 41, 16),      # small odd everything
    (100, 100, 100, 48),   # block larger than needed, non-divisible
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("srname", ["plus_times", "plus_pair"])
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
def test_spgemm_oracle(shape, srname, mask_mode):
    n, k, m, block = shape
    sr = S.get(srname)
    A = rand_bsr(n, k, n * k // 16, block, seed=n + k)
    B = rand_bsr(k, m, k * m // 16, block, seed=k + m + 1)
    mask = (None if mask_mode == "none"
            else rand_bsr(n, m, n * m // 8, block, seed=5))
    check_case(A, B, sr, mask=mask, complement=mask_mode == "comp")


@pytest.mark.parametrize("srname", ["plus_times", "plus_pair", "or_and",
                                    "plus_first"])
@pytest.mark.parametrize("mask_mode", ["none", "mask", "comp"])
def test_spgemm_pallas_kernel_matches_oracle(srname, mask_mode):
    """The Pallas numeric phase (interpret mode on CPU) == dense oracle."""
    sr = S.get(srname)
    A = rand_bsr(96, 80, 700, 32, seed=11)
    B = rand_bsr(80, 64, 600, 32, seed=12)
    mask = None if mask_mode == "none" else rand_bsr(96, 64, 900, 32, seed=13)
    check_case(A, B, sr, mask=mask, complement=mask_mode == "comp",
               impl="pallas")


def test_spgemm_density_sweep():
    """From near-empty to near-dense operands, same oracle."""
    n = 64
    for nnz in (1, 8, 64, 512, 2048, n * n):
        A = rand_bsr(n, n, nnz, 16, seed=nnz)
        B = rand_bsr(n, n, nnz, 16, seed=nnz + 1)
        check_case(A, B, S.PLUS_TIMES)


def test_spgemm_kernel_wrapper():
    """kernels.ops.bsr_spgemm is the kernel-path public entry."""
    A = rand_bsr(64, 64, 400, 32, seed=3)
    C = kops.bsr_spgemm(A, A, S.PLUS_PAIR, mask=A)
    want = dense_oracle(np.asarray(A.to_dense()), np.asarray(A.to_dense()),
                        S.PLUS_PAIR, mask=np.asarray(A.to_dense()))
    np.testing.assert_allclose(np.asarray(C.to_dense()), want, rtol=1e-5)


def test_spgemm_block_size_mismatch_rebuilds():
    A = rand_bsr(64, 64, 300, 32, seed=21)
    B = rand_bsr(64, 64, 300, 16, seed=22)
    check_case(A, B, S.PLUS_TIMES)


def test_spgemm_empty_product():
    """Disjoint patterns: the symbolic phase finds zero tasks."""
    A = BSR.from_coo([0], [0], None, (64, 64), block=32)
    B = BSR.from_coo([63], [63], None, (64, 64), block=32)
    C = spgemm(A, B, S.PLUS_TIMES)
    assert C.nnz == 0
    assert float(np.asarray(C.to_dense()).sum()) == 0.0


def test_spgemm_inner_dim_mismatch_raises():
    A = rand_bsr(32, 48, 50, 16, seed=1)
    B = rand_bsr(32, 32, 50, 16, seed=2)
    with pytest.raises(ValueError):
        spgemm(A, B, S.PLUS_TIMES)


def test_spgemm_tropical_mode_unsupported():
    A = rand_bsr(32, 32, 50, 16, seed=1)
    with pytest.raises(NotImplementedError):
        spgemm(A, A, S.MIN_PLUS)


# -- symbolic-phase structure -------------------------------------------------
def test_symbolic_schedule_invariants():
    A = rand_bsr(96, 96, 800, 32, seed=31)
    plan = spgemm_symbolic(A, A)
    c = plan.c_sel[plan.valid == 1]
    assert (np.diff(c) >= 0).all()                  # grouped by output tile
    assert plan.first.sum() == plan.nc              # one init per tile
    assert plan.last.sum() == plan.nc               # one epilogue per tile
    assert plan.ntasks % 8 == 0                     # grid padding applied


def test_symbolic_mask_prunes_blockwise():
    """A non-complemented mask must shrink the schedule, not just the output."""
    A = rand_bsr(128, 128, 1000, 32, seed=41)
    tiny = BSR.from_coo([0], [0], None, (128, 128), block=32)
    full = spgemm_symbolic(A, A)
    masked = spgemm_symbolic(A, A, mask=tiny)
    assert masked.nc < full.nc
    assert masked.ntasks < full.ntasks
    comp = spgemm_symbolic(A, A, mask=tiny, complement=True)
    assert comp.nc == full.nc                       # complement cannot prune


# -- explicit-zero pruning: nvals / fill_ratio contract ------------------------
def test_masked_out_blocks_are_pruned():
    """A mask that zeroes an entire output tile must not leave an explicit
    zero block behind — nvals/fill_ratio report stored structure."""
    A = rand_bsr(64, 64, 900, 16, seed=51)
    mask = BSR.from_coo([0], [0], None, (64, 64), block=16)  # single entry
    C = spgemm(A, A, S.PLUS_PAIR, mask=mask)
    want = dense_oracle(np.asarray(A.to_dense()), np.asarray(A.to_dense()),
                        S.PLUS_PAIR, mask=np.asarray(mask.to_dense()))
    nz = int(np.count_nonzero(want))
    assert C.nnz == nz and nz <= 1
    # at most the one stored tile survives (plus per-row padding tiles)
    assert int(np.asarray(C.valid).sum()) == (1 if nz else 0)
    cap = int(np.asarray(C.valid).sum()) * C.block * C.block
    assert C.fill_ratio == (nz / cap if cap else 0.0)


def test_cancellation_zeros_not_counted():
    """plus_times cancellation (+1 * 1 + -1 * 1) produces an explicit zero
    entry; nvals must count nonzeros, and an all-cancelled tile is pruned."""
    # A row [1, -1], B column [1, 1]^T -> C[0,0] = 0 exactly
    A = BSR.from_coo([0, 0], [0, 1], [1.0, -1.0], (16, 16), block=16)
    B = BSR.from_coo([0, 1], [0, 0], [1.0, 1.0], (16, 16), block=16)
    C = spgemm(A, B, S.PLUS_TIMES)
    assert C.nnz == 0
    assert int(np.asarray(C.valid).sum()) == 0      # tile fully pruned
    g = grb.GBMatrix(C)
    assert g.nvals == 0


def test_from_blocks_prunes_and_counts():
    blocks = np.zeros((3, 8, 8), np.float32)
    blocks[0, 1, 2] = 4.0
    blocks[2, 0, 0] = 1.0
    blocks[2, 7, 7] = 2.0
    C = BSR.from_blocks([0, 1, 2], [0, 1, 2], blocks, (24, 24), block=8)
    assert C.nnz == 3
    assert int(np.asarray(C.valid).sum()) == 2      # block 1 was all-zero
    D = np.zeros((24, 24), np.float32)
    D[1, 2] = 4.0
    D[16, 16] = 1.0
    D[23, 23] = 2.0
    np.testing.assert_array_equal(np.asarray(C.to_dense()), D)


# -- grb dispatch --------------------------------------------------------------
def test_grb_mxm_sparse_dispatch_returns_gbmatrix():
    A = grb.GBMatrix(rand_bsr(96, 96, 700, 32, seed=61))
    C = grb.mxm(A, A, S.PLUS_PAIR, Descriptor(mask=A))
    assert isinstance(C, grb.GBMatrix) and C.fmt == "bsr"
    D = np.asarray(A.to_dense())
    want = dense_oracle(D, D, S.PLUS_PAIR, mask=D)
    np.testing.assert_allclose(np.asarray(C.to_dense()), want, rtol=1e-5)
    assert C.nvals == int(np.count_nonzero(want))
    # sparse reduce without densifying
    tot = float(grb.reduce(C, S.PLUS))
    assert abs(tot - want.sum()) < 1e-3


def test_grb_mxm_dense_mask_on_sparse_path():
    """A dense descriptor mask is converted block-wise for the sparse path."""
    A = grb.GBMatrix(rand_bsr(64, 64, 500, 32, seed=62))
    rng = np.random.default_rng(0)
    mask = (rng.uniform(size=(64, 64)) < 0.3).astype(np.float32)
    C = grb.mxm(A, A, S.PLUS_TIMES, Descriptor(mask=jnp.asarray(mask)))
    D = np.asarray(A.to_dense())
    want = dense_oracle(D, D, S.PLUS_TIMES, mask=mask)
    np.testing.assert_allclose(np.asarray(C.to_dense()), want,
                               rtol=1e-5, atol=1e-5)


def test_grb_mxm_tropical_falls_back_to_dense():
    A = grb.GBMatrix(rand_bsr(64, 64, 500, 32, seed=63))
    y = grb.mxm(A, A, S.MIN_PLUS)
    assert not isinstance(y, grb.GBMatrix)          # dense fallback result
    D = np.asarray(A.to_dense())
    want = np.asarray(S.dense_mxm(S.structural_dense(jnp.asarray(D),
                                                     S.MIN_PLUS),
                                  jnp.asarray(D), S.MIN_PLUS))
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5)


def test_bsr_union():
    A = rand_bsr(64, 64, 200, 16, seed=71, weighted=False)
    B = rand_bsr(64, 64, 200, 16, seed=72, weighted=False)
    U = bsr_union(A, B)
    DU = (np.asarray(A.to_dense()) != 0) | (np.asarray(B.to_dense()) != 0)
    np.testing.assert_array_equal(np.asarray(U.to_dense()) != 0, DU)
    assert U.nnz == int(DU.sum())


# -- hypothesis property sweep -------------------------------------------------
if HAVE_HYPOTHESIS:

    @pytest.mark.hypothesis
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(8, 96),
           k=st.integers(8, 96), m=st.integers(8, 96),
           density=st.floats(0.005, 0.2),
           srname=st.sampled_from(["plus_times", "plus_pair"]),
           mask_mode=st.sampled_from(["none", "mask", "comp"]),
           block=st.sampled_from([8, 16, 32, 48]))
    def test_spgemm_random_sweep(seed, n, k, m, density, srname, mask_mode,
                                 block):
        """Hypothesis-generated COO graphs (incl. n not divisible by the
        block): BSR x BSR == dense oracle, masked and unmasked."""
        rng = np.random.default_rng(seed)
        sr = S.get(srname)
        A = rand_bsr(n, k, int(n * k * density) + 1, block, seed=seed)
        B = rand_bsr(k, m, int(k * m * density) + 1, block, seed=seed + 1)
        mask = (None if mask_mode == "none"
                else rand_bsr(n, m, int(n * m * density * 2) + 1, block,
                              seed=seed + 2))
        impl = "pallas" if rng.uniform() < 0.5 else "xla"
        check_case(A, B, sr, mask=mask, complement=mask_mode == "comp",
                   impl=impl)

else:

    @pytest.mark.hypothesis
    def test_spgemm_random_sweep():
        pytest.importorskip("hypothesis", reason="hypothesis not installed "
                            "(see requirements-dev.txt)")
