# Developer entry points. PYTHONPATH is injected per-target so the repo works
# without an install step (there is no setup.py; the image bakes in runtime
# deps — requirements-dev.txt lists the test-only extras).

PY ?= python
# src for the package, repo root so `benchmarks.*` resolves as a namespace pkg
export PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-ewise test-dist test-delta test-serve test-transfers test-algos bench-smoke calibrate docs-check

# tier-1 verification (the command ROADMAP.md pins)
test:
	$(PY) -m pytest -x -q

# inner-loop pass: everything except the hypothesis property sweeps and the
# TPU-only compiled-kernel tests (markers registered in pytest.ini). Picks
# up the ewise suite (element-wise family + k-truss) via its marker.
test-fast:
	$(PY) -m pytest -x -q -m "not hypothesis and not tpu_only"

# just the sparse element-wise family + k-truss conformance suite
test-ewise:
	$(PY) -m pytest -x -q -m "ewise and not hypothesis"

# sharded GBMatrix / mesh suite on the forced 8-device CPU topology
# (conftest applies REPRO_FORCE_DEVICES to XLA_FLAGS before jax loads).
# Includes the distributed hypothesis sweep where hypothesis is installed —
# this target is its only wired runner (the tier-1 subprocess wrapper
# excludes `hypothesis` for image parity).
test-dist:
	REPRO_FORCE_DEVICES=8 $(PY) -m pytest -x -q -m distributed

# delta-matrix mutation layer: composition oracles over every storage kind,
# the engine write path (zero rebuilds), snapshot isolation, AOF coalescing
test-delta:
	$(PY) -m pytest -x -q -m delta

# continuous-batching query server: batched-vs-solo differential grid,
# scheduler regression tests, plan cache, serving metrics
test-serve:
	$(PY) -m pytest -x -q -m serve

# algorithm breadth suite: the cross-format oracle conformance grid
# (betweenness/closeness/similarity/labelprop x dense/BSR/ELL/BitELL x
# named + RMAT graphs), zero-edge goldens, the property sweep, and the
# CALL algo.* end-to-end cells (the sharded bit-identity cells carry the
# distributed marker and run under `make test-dist` / the tier-1
# subprocess wrapper)
test-algos:
	$(PY) -m pytest -x -q -m algos

# transfer-accounting suite: shard-local ewise vs the gather oracle, BSR
# device ewise vs the XLA reference, zero-host-transfer pins on the sharded
# and word-resident hot loops (the distributed half needs the forced
# topology, so this runs on it; tier-1 covers the same tests via the
# subprocess wrapper)
test-transfers:
	REPRO_FORCE_DEVICES=8 $(PY) -m pytest -x -q -m "transfers and not hypothesis"

# fast end-to-end benchmark pass: the masked plus_pair mxm vs the
# trace(A^3)/6 oracle, plus the Poisson open-loop serving comparison
# (batched vs solo differentially checked), each archived as a
# machine-readable BENCH_*.json next to the CSV. Full suite:
# benchmarks/run.py.
bench-smoke:
	$(PY) benchmarks/run.py triangles --json BENCH_triangles.json
	$(PY) benchmarks/run.py throughput --json BENCH_throughput.json
	$(PY) benchmarks/run.py bitadj --json BENCH_bitadj.json
	$(PY) benchmarks/run.py algos --json BENCH_algos.json

# re-measure every AUTO_* crossover constant on this host and print the
# drift vs the committed values (benchmarks/calibrate.py — report only,
# never fails; re-run the full calibrating benchmark before editing one)
calibrate:
	$(PY) benchmarks/calibrate.py

# execute every fenced ```python block in docs/*.md against the current
# surface (tests/test_docs.py — also part of tier-1, so docs can't drift)
docs-check:
	$(PY) -m pytest -x -q tests/test_docs.py
