"""Train a reduced-config LM end-to-end on CPU with the full production path
(config -> model registry -> optimizer -> async checkpointing -> resume).
The same launcher drives the 16x16-mesh dry-run configs.

  PYTHONPATH=src python examples/train_lm.py [--arch mixtral-8x7b]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-1.5b")
ap.add_argument("--steps", type=int, default=30)
args = ap.parse_args()

ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
losses = train_main(["--arch", args.arch, "--steps", str(args.steps),
                     "--batch", "8", "--seq", "64",
                     "--ckpt-dir", ckpt_dir, "--ckpt-every", "10"])
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'descending ✓' if losses[-1] < losses[0] else 'NOT descending'})")
print(f"checkpoints in {ckpt_dir}")
