"""End-to-end driver (the paper's kind is a database => serving):

Graph500 RMAT graph -> snapshot persistence -> continuous-batching query
serving with the QueryServer (the TPU analog of RedisGraph's threadpool)
under Poisson open-loop arrivals, measuring queries/sec, p50/p99 latency,
plan-cache hit rate and packed-lane utilization for the paper's k-hop
workload.

  PYTHONPATH=src python examples/serve_queries.py [--scale 11] [--queries 300]
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.engine import QueryServer, load_snapshot, save_snapshot
from repro.graph.datagen import rmat_graph

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=11)
ap.add_argument("--queries", type=int, default=300)
ap.add_argument("--k", type=int, default=2)
ap.add_argument("--rate", type=float, default=2000.0,
                help="offered Poisson arrival rate, queries/sec")
args = ap.parse_args()

print(f"[1/4] generating Graph500 RMAT scale={args.scale} ...")
g = rmat_graph(scale=args.scale, edge_factor=8, seed=0, fmt="bsr", block=128)
print(f"      {g.n} vertices, {g.nnz} edges")

print("[2/4] snapshot round-trip (RDB analog) ...")
snap = os.path.join(tempfile.mkdtemp(prefix="repro_rdb_"), "g500.npz")
save_snapshot(g, snap)
g = load_snapshot(snap, fmt="bsr", block=128)
print(f"      restored from {snap}")

print(f"[3/4] serving {args.queries} k={args.k}-hop queries "
      f"(Poisson open-loop @ {args.rate:.0f} q/s) ...")
rng = np.random.default_rng(0)
seeds = rng.integers(0, g.n, size=args.queries)
arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.queries))
srv = QueryServer(g)
template = (f"MATCH (a)-[:KNOWS*1..{args.k}]->(b) "
            f"RETURN count(DISTINCT b)")

out, qids = {}, []
i = 0
t0 = time.perf_counter()
while len(out) < args.queries:
    now = time.perf_counter() - t0
    while i < args.queries and arrivals[i] <= now:
        qids.append(srv.submit(template, seeds=[int(seeds[i])],
                               arrival_s=t0 + arrivals[i]))
        i += 1
    if srv.pending:
        out.update(srv.pump())
    elif i < args.queries:
        time.sleep(min(arrivals[i] - now, 1e-3))
dt = time.perf_counter() - t0

print("[4/4] results:")
counts = [out[q].scalar() for q in qids]
lat = np.array([m.latency_s for m in srv.log])
p50, p99 = np.percentile(lat, [50, 99])
print(f"      batches={srv.stats['batches']} "
      f"(width {srv.stats['batched_width_total']}, "
      f"max {srv.stats['batch_width_max']}, "
      f"pack ratio {srv.stats['pack_ratio']:.2f})")
print(f"      plan cache: {srv.stats['plan_cache_hits']} hits / "
      f"{srv.stats['plan_cache_misses']} misses "
      f"(hit rate {srv.stats['plan_cache_hit_rate']:.2f})")
print(f"      total {dt * 1e3:.1f} ms, {args.queries / dt:.0f} queries/s, "
      f"latency p50={p50 * 1e3:.1f} ms p99={p99 * 1e3:.1f} ms")
print(f"      count stats: min={min(counts)} max={max(counts)} "
      f"mean={np.mean(counts):.1f}")
