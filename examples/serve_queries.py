"""End-to-end driver (the paper's kind is a database => serving):

Graph500 RMAT graph -> snapshot persistence -> batched query serving with the
QueryServer (the TPU analog of RedisGraph's threadpool), measuring latency
and throughput for the paper's k-hop workload.

  PYTHONPATH=src python examples/serve_queries.py [--scale 11] [--queries 300]
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.engine import QueryServer, load_snapshot, save_snapshot
from repro.graph.datagen import rmat_graph

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=11)
ap.add_argument("--queries", type=int, default=300)
ap.add_argument("--k", type=int, default=2)
args = ap.parse_args()

print(f"[1/4] generating Graph500 RMAT scale={args.scale} ...")
g = rmat_graph(scale=args.scale, edge_factor=8, seed=0, fmt="bsr", block=128)
print(f"      {g.n} vertices, {g.nnz} edges")

print("[2/4] snapshot round-trip (RDB analog) ...")
snap = os.path.join(tempfile.mkdtemp(prefix="repro_rdb_"), "g500.npz")
save_snapshot(g, snap)
g = load_snapshot(snap, fmt="bsr", block=128)
print(f"      restored from {snap}")

print(f"[3/4] submitting {args.queries} k={args.k}-hop queries ...")
rng = np.random.default_rng(0)
seeds = rng.integers(0, g.n, size=args.queries)
srv = QueryServer(g, max_batch=512)
qids = [srv.submit(
    f"MATCH (a)-[:KNOWS*1..{args.k}]->(b) WHERE id(a) = {s} "
    f"RETURN count(DISTINCT b)") for s in seeds]

t0 = time.perf_counter()
out = srv.flush()
dt = time.perf_counter() - t0

print("[4/4] results:")
counts = [out[q].scalar() for q in qids]
print(f"      batches={srv.stats['batches']} "
      f"(width {srv.stats['batched_width_total']})")
print(f"      total {dt * 1e3:.1f} ms, "
      f"{dt / args.queries * 1e6:.0f} us/query, "
      f"{args.queries / dt:.0f} queries/s")
print(f"      count stats: min={min(counts)} max={max(counts)} "
      f"mean={np.mean(counts):.1f}")
