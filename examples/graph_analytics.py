"""Graph analytics over semirings: PageRank (plus_times), SSSP (min_plus),
WCC (min-label), triangles (plus_pair) — each a different GraphBLAS semiring
on the same stored graph.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro import algorithms as alg
from repro.graph.datagen import rmat_edges
from repro.graph.graph import GraphBuilder

src, dst, n = rmat_edges(scale=10, edge_factor=8, seed=1)
keep = src != dst
src, dst = src[keep], dst[keep]
rng = np.random.default_rng(0)
w = rng.uniform(0.5, 3.0, size=src.shape[0]).astype(np.float32)
g = GraphBuilder(n).add_edges("E", src, dst, w).build(fmt="bsr", block=128)
rel = g.relations["E"]
print(f"graph: {n} vertices, {rel.nnz} edges")

pr = np.asarray(alg.pagerank(rel, iters=40))
top = np.argsort(-pr)[:5]
print(f"pagerank (plus_times): top-5 hubs {top.tolist()}, "
      f"mass {pr[top].sum():.3f}")

dist = np.asarray(alg.sssp(rel, [0]))[:, 0]
reach = np.isfinite(dist)
print(f"sssp (min_plus) from 0: reaches {reach.sum()} vertices, "
      f"max dist {dist[reach].max():.2f}")

cc = np.asarray(alg.wcc(rel))
print(f"wcc (min-label): {len(np.unique(cc))} components")

# triangles need a symmetric graph
s2 = np.concatenate([src, dst])
d2 = np.concatenate([dst, src])
gu = GraphBuilder(n).add_edges("E", s2, d2).build(fmt="bsr", block=128)
t = int(alg.triangle_count(gu.relations["E"]))
print(f"triangles (plus_pair, GraphChallenge): {t}")
