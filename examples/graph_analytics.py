"""Graph analytics over semirings: PageRank (plus_times), SSSP (min_plus),
WCC (min-seed boolean closures), triangles (plus_pair) — each a different
GraphBLAS semiring on the same stored graph — then the same k-hop run on a
device mesh through `grb.distribute` (zero algorithm changes; wide boolean
frontiers ride the bitmap-packed path automatically).

  PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro import algorithms as alg
from repro.core import grb
from repro.graph.datagen import rmat_edges
from repro.graph.graph import GraphBuilder

src, dst, n = rmat_edges(scale=10, edge_factor=8, seed=1)
keep = src != dst
src, dst = src[keep], dst[keep]
rng = np.random.default_rng(0)
w = rng.uniform(0.5, 3.0, size=src.shape[0]).astype(np.float32)
g = GraphBuilder(n).add_edges("E", src, dst, w).build(fmt="bsr", block=128)
rel = g.relations["E"]
print(f"graph: {n} vertices, {rel.nnz} edges")

pr = np.asarray(alg.pagerank(rel, iters=40))
top = np.argsort(-pr)[:5]
print(f"pagerank (plus_times): top-5 hubs {top.tolist()}, "
      f"mass {pr[top].sum():.3f}")

dist = np.asarray(alg.sssp(rel, [0]))[:, 0]
reach = np.isfinite(dist)
print(f"sssp (min_plus) from 0: reaches {reach.sum()} vertices, "
      f"max dist {dist[reach].max():.2f}")

cc = np.asarray(alg.wcc(rel))
print(f"wcc (min-label): {len(np.unique(cc))} components")

# triangles need a symmetric graph
s2 = np.concatenate([src, dst])
d2 = np.concatenate([dst, src])
gu = GraphBuilder(n).add_edges("E", s2, d2).build(fmt="bsr", block=128)
t = int(alg.triangle_count(gu.relations["E"]))
print(f"triangles (plus_pair, GraphChallenge): {t}")

# the distributed surface: re-home the graph onto a mesh (ELL rows shard
# over "data") and run the unchanged algorithm — each or_and hop all-gathers
# a bitmap-packed frontier (128 seeds = 4 uint32 words per row, 32x less
# wire than float32 indicators).
import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

ge = GraphBuilder(n).add_edges("E", src, dst, w).build(fmt="ell")
mesh = Mesh(np.array(jax.devices()).reshape(-1, 1, 1),
            ("data", "pod", "model"))
sharded = grb.distribute(ge.relations["E"].A, mesh)
seeds = np.arange(128)
local = np.asarray(alg.khop_counts(ge.relations["E"], seeds, k=2))
dist = np.asarray(alg.khop_counts(sharded, seeds, k=2))
assert (local == dist).all(), "sharded khop diverged"
print(f"sharded khop (mesh of {mesh.devices.size}, packed frontiers): "
      f"bit-identical over {len(seeds)} seeds")
