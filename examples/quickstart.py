"""Quickstart: the GraphBLAS graph database in 40 lines — the write path,
the paper's k-hop query, the algebraic plan, and the same query answered
over a device mesh (`mesh=`, PR 4's sharded surface).

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.engine import Database

db = Database(data_dir=tempfile.mkdtemp(prefix="repro_aof_"))

# write path (AOF-journaled, like Redis)
db.query("social", """CREATE (:Person {id: 0, age: 33}), (:Person {id: 1, age: 44}),
                     (:Person {id: 2, age: 25}), (:Person {id: 3, age: 61}),
                     (:City {id: 4})""")
db.query("social", "CREATE (0)-[:KNOWS]->(1), (1)-[:KNOWS]->(2), "
                   "(2)-[:KNOWS]->(3), (0)-[:KNOWS]->(2), (3)-[:VISITS]->(4)")

# the paper's benchmark query shape: k-hop neighborhood count
res = db.query("social", "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 0 "
                         "RETURN count(DISTINCT b)")
print("2-hop neighborhood of node 0:", res.scalar())

# property filters + projections
res = db.query("social", "MATCH (a:Person)-[:KNOWS]->(b:Person) "
                         "WHERE b.age > 30 RETURN a, b, b.age")
print("edges into >30-year-olds:", res.rows)

# the algebraic plan (Cypher -> linear algebra, the paper's contribution)
print("\nEXPLAIN:")
print(db.explain("social", "MATCH (a)-[:KNOWS*1..6]->(b) WHERE id(a) = 0 "
                           "RETURN count(DISTINCT b)"))

# sharded mode: the same query surface over a device mesh — pass mesh= and
# the context distributes every relation (grb.distribute); no other call
# site changes. On this host the mesh covers whatever devices exist.
import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

devs = np.array(jax.devices()).reshape(-1, 1, 1)
mesh = Mesh(devs, ("data", "pod", "model"))
res = db.query("social", "MATCH (a)-[:KNOWS*1..2]->(b) WHERE id(a) = 0 "
                         "RETURN count(DISTINCT b)", mesh=mesh)
print(f"\nsame answer on a {devs.size}-device mesh:", res.scalar())
