"""Pure-python reference interpreter for the Cypher subset — the differential
oracle for the algebraic executor (same BFS distinct-vertex semantics)."""
from __future__ import annotations

from collections import deque
from typing import List

import numpy as np

from repro.graph.graph import Graph
from repro.query import qast as A
from repro.query.executor import ExecutionContext, Result, _colname, _prop
from repro.query.parser import parse
from repro.query.planner import plan


def _adj(graph: Graph, rel, direction) -> list:
    r = graph.relation(rel)
    mats = []
    if direction in (A.OUT, A.BOTH):
        mats.append(np.asarray(r.A.to_dense()) != 0)
    if direction in (A.IN, A.BOTH):
        mats.append(np.asarray(r.A_T.to_dense()) != 0)
    n = graph.n
    out = [set() for _ in range(n)]
    for M in mats:
        rr, cc = np.nonzero(M)
        for i in range(len(rr)):
            out[rr[i]].add(int(cc[i]))
    return out


def _bfs_range(adj, seeds: set, minh: int, maxh: int, allowed_dst) -> set:
    lvl = {s: 0 for s in seeds}
    q = deque(seeds)
    reach = set()
    while q:
        u = q.popleft()
        if lvl[u] >= maxh:
            continue
        for v in adj[u]:
            if v not in lvl:
                lvl[v] = lvl[u] + 1
                q.append(v)
                if minh <= lvl[v] <= maxh and allowed_dst[v]:
                    reach.add(v)
    return reach


def execute_ref(graph: Graph, query) -> Result:
    q = parse(query) if isinstance(query, str) else query
    p = plan(q)
    if p.semiring != "or_and":
        raise NotImplementedError("reference covers distinct semantics only")

    ctx = ExecutionContext(graph)
    src_mask = ctx.node_mask(p.src_label, p.var_preds.get(p.src_var))
    if p.seeds is not None:
        seeds = [s for s in sorted(set(p.seeds)) if src_mask[s]]
    else:
        seeds = list(np.nonzero(src_mask)[0])

    per_seed: List[set] = []
    for s in seeds:
        cur = {int(s)}
        for e in p.expands:
            adj = _adj(graph, e.rel, e.direction)
            dst_mask = ctx.node_mask(e.dst_label,
                                     p.var_preds.get(e.dst_var))
            cur = _bfs_range(adj, cur, e.min_hops, e.max_hops, dst_mask)
        per_seed.append(cur)

    cols = [_colname(r) for r in p.returns]
    src_var = p.src_var
    returns_src = any(r.var == src_var and r.kind != "count" for r in p.returns)
    only_counts = all(r.kind == "count" for r in p.returns)

    rows = []
    if only_counts and not returns_src:
        total = sum(len(c) for c in per_seed)
        rows = [tuple(total for _ in p.returns)]
    elif only_counts or (returns_src and all(r.kind == "count" or r.var == src_var
                                             for r in p.returns)):
        for j, s in enumerate(seeds):
            vals = []
            for r in p.returns:
                if r.kind == "count":
                    vals.append(len(per_seed[j]))
                elif r.kind == "prop":
                    vals.append(_prop(graph, r.prop, int(s)))
                else:
                    vals.append(int(s))
            rows.append(tuple(vals))
    else:
        for j, s in enumerate(seeds):
            for d in sorted(per_seed[j]):
                vals = []
                for r in p.returns:
                    node = int(s) if r.var == src_var else int(d)
                    if r.kind == "prop":
                        vals.append(_prop(graph, r.prop, node))
                    else:
                        vals.append(node)
                rows.append(tuple(vals))
        rows.sort()
    if p.limit is not None:
        rows = rows[: p.limit]
    return Result(cols, rows)
