"""AST for the Cypher subset (RedisGraph 1.0-era surface).

Supported:
  MATCH (a:L1)-[:R*1..3]->(b:L2)(...linear chains...)
        directions -> <- and undirected -, variable-length hops [*min..max]
  WHERE conjunctions of single-variable predicates over node properties,
        id(v) = k / id(v) IN [..] seed selectors; OR/NOT within a predicate
  RETURN v | v.prop | count(v) | count(DISTINCT v)  (+ LIMIT)
  CREATE (:Label {id: i, prop: v}) | CREATE (i)-[:R]->(j)
         (node ids optional — engine.MutableGraph auto-assigns next_id)
  DELETE (i)-[:R]->(j) | DELETE (i)   (edge / whole-node forms; node
         deletion tombstones: incident edges, labels and props go, the id
         row stays allocated)
  CALL algo.name(arg: v, sources: [i, j], kind: word) YIELD col AS alias
       (+ LIMIT) — procedure invocation; args are named, values are
       numbers, [number lists] or bare words. YIELD omitted = every
       column the procedure defines (query.planner.PROC_COLUMNS).

Semantics note (DESIGN.md): variable-length expansion uses BFS distinct-vertex
semantics (the TigerGraph k-hop benchmark definition), not Cypher trail
semantics — this is the algebraic traversal the paper implements.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

OUT, IN, BOTH = "OUT", "IN", "BOTH"


@dataclasses.dataclass
class NodePat:
    var: Optional[str]
    label: Optional[str]
    props: dict


@dataclasses.dataclass
class EdgePat:
    var: Optional[str]
    rel: Optional[str]
    direction: str           # OUT | IN | BOTH
    min_hops: int = 1
    max_hops: int = 1


@dataclasses.dataclass
class Comparison:
    op: str                  # < <= > >= = <>
    lhs: Tuple[str, ...]     # ("prop", var, name) | ("id", var) | ("lit", v)
    rhs: Tuple[str, ...]


@dataclasses.dataclass
class BoolExpr:
    op: str                  # AND | OR | NOT
    args: List[Union["BoolExpr", Comparison]]


@dataclasses.dataclass
class InSeeds:
    var: str
    seeds: List[int]


@dataclasses.dataclass
class ReturnItem:
    kind: str                # var | prop | count
    var: str
    prop: Optional[str] = None
    distinct: bool = False
    alias: Optional[str] = None


@dataclasses.dataclass
class MatchQuery:
    nodes: List[NodePat]
    edges: List[EdgePat]
    where: List[Union[BoolExpr, Comparison, InSeeds]]   # conjunction
    returns: List[ReturnItem]
    limit: Optional[int] = None


@dataclasses.dataclass
class CallQuery:
    proc: str                # dotted procedure name, e.g. "algo.pagerank"
    args: dict               # name -> number | tuple of numbers | str
    yields: List[ReturnItem]   # [] = all of the procedure's columns
    limit: Optional[int] = None


@dataclasses.dataclass
class CreateNode:
    label: Optional[str]
    props: dict              # "id" optional: the engine auto-assigns next_id


@dataclasses.dataclass
class CreateEdge:
    src: int
    rel: str
    dst: int


@dataclasses.dataclass
class CreateQuery:
    items: list


@dataclasses.dataclass
class DeleteNode:
    id: int


@dataclasses.dataclass
class DeleteEdge:
    src: int
    rel: str
    dst: int


@dataclasses.dataclass
class DeleteQuery:
    items: list
