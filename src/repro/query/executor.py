"""Physical execution: plans -> GraphBLAS ops on the graph's matrices.

The binding state is a frontier matrix B (n, F): column j is the reachable
set (or walk counts) of source binding j. Each Expand is min..max masked
semiring vxm hops; node predicates become diagonal masks applied between
hops. This is the paper's Cypher->linear-algebra translation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import ops, semiring as S
from repro.graph.graph import Graph
from repro.query import qast as A
from repro.query.parser import parse
from repro.query.planner import Plan, plan


@dataclasses.dataclass
class Result:
    columns: List[str]
    rows: List[tuple]

    def scalar(self):
        assert len(self.rows) == 1 and len(self.rows[0]) == 1
        return self.rows[0][0]


# -- predicate evaluation -----------------------------------------------------
def _operand_vec(graph: Graph, side, n: int):
    if side[0] == "lit":
        return np.full(n, side[1], dtype=np.float64), None
    if side[0] == "id":
        return np.arange(n, dtype=np.float64), None
    if side[0] == "prop":
        col = graph.node_props.get(side[2])
        if col is None:
            return np.full(n, np.nan), np.zeros(n, dtype=bool)
        col = np.asarray(col, dtype=np.float64)
        return col, ~np.isnan(col)
    raise TypeError(side)


_CMP = {"<": np.less, "<=": np.less_equal, ">": np.greater,
        ">=": np.greater_equal, "=": np.equal, "<>": np.not_equal}


def eval_pred(graph: Graph, node, n: int) -> np.ndarray:
    if isinstance(node, A.Comparison):
        lv, lp = _operand_vec(graph, node.lhs, n)
        rv, rp = _operand_vec(graph, node.rhs, n)
        with np.errstate(invalid="ignore"):
            out = _CMP[node.op](lv, rv)
        for present in (lp, rp):
            if present is not None:
                out &= present
        return out
    if isinstance(node, A.BoolExpr):
        parts = [eval_pred(graph, a, n) for a in node.args]
        if node.op == "AND":
            return np.logical_and.reduce(parts)
        if node.op == "OR":
            return np.logical_or.reduce(parts)
        if node.op == "NOT":
            return ~parts[0]
    if isinstance(node, A.InSeeds):
        m = np.zeros(n, dtype=bool)
        m[node.seeds] = True
        return m
    raise TypeError(node)


def _node_mask(graph: Graph, label, preds, n) -> np.ndarray:
    m = np.asarray(graph.label_mask(label))
    for p in preds or []:
        m = m & eval_pred(graph, p, n)
    return m


# -- expansion ----------------------------------------------------------------
def _matrices(graph: Graph, rel: Optional[str], direction: str):
    r = graph.relation(rel)
    if r is None:
        raise ValueError(f"no relation {rel!r}")
    if direction == A.OUT:
        return [r.A_T]          # pull: next = A^T (x) frontier
    if direction == A.IN:
        return [r.A]
    return [r.A_T, r.A]


def _expand(graph: Graph, B: jnp.ndarray, e, sr: S.Semiring,
            dst_mask: np.ndarray, impl: str) -> jnp.ndarray:
    mats = _matrices(graph, e.rel, e.direction)
    reach = jnp.zeros_like(B)
    frontier = B
    visited = (B > 0).astype(jnp.float32)
    for h in range(1, e.max_hops + 1):
        nxt = None
        for M in mats:
            step = ops.mxm(M, frontier, sr,
                           mask=visited if sr.name == "or_and" else None,
                           complement=True, impl=impl)
            nxt = step if nxt is None else S_add(sr, nxt, step)
        frontier = nxt
        if sr.name == "or_and":
            visited = jnp.maximum(visited, (frontier > 0).astype(jnp.float32))
        if h >= e.min_hops:
            reach = S_add(sr, reach, frontier)
    # destination label/property diagonal
    reach = reach * jnp.asarray(dst_mask, dtype=jnp.float32)[:, None]
    if sr.name == "or_and":
        reach = (reach > 0).astype(jnp.float32)
    return reach


def S_add(sr: S.Semiring, a, b):
    return jnp.maximum(a, b) if sr.name == "or_and" else a + b


# -- top level ------------------------------------------------------------------
def execute(graph: Graph, query, impl: str = "auto") -> Result:
    q = parse(query) if isinstance(query, str) else query
    if isinstance(q, A.CreateQuery):
        raise TypeError("CREATE goes through engine.Database, not execute()")
    p = plan(q)
    n = graph.n

    src_mask = _node_mask(graph, p.src_label, p.var_preds.get(p.src_var), n)
    if p.seeds is not None:
        seeds = np.asarray(sorted(set(p.seeds)), dtype=np.int64)
        seeds = seeds[src_mask[seeds]]
    else:
        seeds = np.nonzero(src_mask)[0]
    f = len(seeds)
    if f == 0:
        return Result([_colname(r) for r in p.returns], [])

    sr = S.get(p.semiring)
    B = jnp.zeros((n, f), dtype=jnp.float32).at[jnp.asarray(seeds),
                                                jnp.arange(f)].set(1.0)
    var_of_col = {p.src_var: "seed"}
    for e in p.expands:
        dst_mask = _node_mask(graph, e.dst_label,
                              p.var_preds.get(e.dst_var), n)
        B = _expand(graph, B, e, sr, dst_mask, impl)

    return _project(graph, p, seeds, B)


def _colname(r: A.ReturnItem) -> str:
    if r.alias:
        return r.alias
    if r.kind == "count":
        return f"count({'DISTINCT ' if r.distinct else ''}{r.var})"
    if r.kind == "prop":
        return f"{r.var}.{r.prop}"
    return r.var


def _project(graph: Graph, p: Plan, seeds: np.ndarray, B: jnp.ndarray) -> Result:
    Bn = np.asarray(B)
    cols = [_colname(r) for r in p.returns]
    src_var = p.src_var
    terminal = p.expands[-1].dst_var if p.expands else src_var

    returns_src = any(r.var == src_var and r.kind != "count" for r in p.returns)
    only_counts = all(r.kind == "count" for r in p.returns)

    rows: List[tuple] = []
    if only_counts and not returns_src:
        # global aggregate: one row
        vals = []
        for r in p.returns:
            tot = (Bn > 0).sum() if r.distinct or p.semiring == "or_and" else Bn.sum()
            vals.append(int(tot))
        rows = [tuple(vals)]
    elif only_counts or (returns_src and all(r.kind == "count" or r.var == src_var
                                             for r in p.returns)):
        # grouped by seed
        for j, s in enumerate(seeds):
            vals = []
            for r in p.returns:
                if r.kind == "count":
                    tot = (Bn[:, j] > 0).sum() if (r.distinct or p.semiring == "or_and") else Bn[:, j].sum()
                    vals.append(int(tot))
                elif r.kind == "prop":
                    vals.append(_prop(graph, r.prop, int(s)))
                else:
                    vals.append(int(s))
            rows.append(tuple(vals))
    else:
        # materialize (seed, dst) bindings
        dst_rows, seed_cols = np.nonzero(Bn > 0)
        for d, j in zip(dst_rows, seed_cols):
            vals = []
            for r in p.returns:
                node = int(seeds[j]) if r.var == src_var else int(d)
                if r.kind == "prop":
                    vals.append(_prop(graph, r.prop, node))
                else:
                    vals.append(node)
            rows.append(tuple(vals))
        rows.sort()
    if p.limit is not None:
        rows = rows[: p.limit]
    return Result(cols, rows)


def _prop(graph: Graph, prop: str, node: int):
    col = graph.node_props.get(prop)
    if col is None:
        return None
    v = float(np.asarray(col)[node])
    return None if np.isnan(v) else v


def explain(graph: Graph, query) -> str:
    q = parse(query) if isinstance(query, str) else query
    return plan(q).explain()
