"""Physical execution: plans -> GraphBLAS ops on the graph's matrices.

The binding state is a frontier matrix B (n, F): column j is the reachable
set (or walk counts) of source binding j. Each Expand is min..max masked
semiring hops through the `repro.core.grb` surface (mask/complement/transpose
ride in a Descriptor); node predicates become diagonal masks applied between
hops. This is the paper's Cypher->linear-algebra translation. Structural
(or_and) expands over a wide seed batch ride grb's bitmap-packed frontier
route automatically (docs/API.md §Bitmap) — nothing here opts in.

`ExecutionContext` is the public execution surface: `node_mask`,
`seed_frontier`, `expand`, `traverse`, and `project` are the primitives a
scheduler composes — the continuous-batching server (`repro.engine.server`)
drives them directly to answer many pattern-compatible queries with one
frontier traversal (`traverse` returns the frontier unmaterialized, so the
server overlaps host-side scheduling with device execution). `execute()` is
the solo driver over the same context; `resolve_seeds` is the ONE seed
semantics both paths share (or_and dedupes bindings, plus_times keeps the
seed multiset), so batched and solo answers are definitionally equal.

Public contract: a context reads one *frozen* Graph (CREATE / DELETE raise
TypeError — writes go through `engine.Database`); unknown relations raise
ValueError naming the ones that exist. Frozen means snapshot-consistent,
not necessarily rebuilt: `engine.Database` serves views whose relation
handles may be delta-backed (`core.delta.DeltaMatrix` — a frozen base plus
pending writes), and every grb call here composes those deltas exactly, so
a context opened before a writer batch never sees its edits and a context
opened after sees all of them with zero rebuild. `impl` and `mesh` are
resolved once per context, never per call; with `mesh` set every relation
handle is distributed on first use (`grb.distribute` — which raises
TypeError unless the graph was frozen as ELL; `engine.Database` freezes
sharded-mode graphs as ELL *with deltas compacted* for exactly this
reason) and traversal hops run as mesh collectives. `project` materializes rows host-side by design (results are
Python values); `node_mask` evaluates predicates host-side on node
property columns.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as _bitmap, grb, semiring as S
from repro.core.grb import Descriptor
from repro.graph.graph import Graph
from repro.query import qast as A
from repro.query.parser import parse
from repro.query.planner import PROC_COLUMNS, CallPlan, Plan, plan


@dataclasses.dataclass
class Result:
    columns: List[str]
    rows: List[tuple]
    # serving error isolation: a query that failed inside a batch reports
    # here ("ValueError: no relation ...") instead of poisoning its batch
    error: Optional[str] = None

    def scalar(self):
        assert len(self.rows) == 1 and len(self.rows[0]) == 1
        return self.rows[0][0]


def empty_result(p: Plan) -> Result:
    """The no-seeds-survived answer — shared by the solo driver and the
    batched server so an all-masked-out seed list means the same thing
    (zero rows, NOT a zero-count row) on both paths."""
    return Result([_colname(r) for r in p.returns], [])


def resolve_seeds(p: Plan, src_mask: np.ndarray) -> np.ndarray:
    """Seed ids a seeded plan actually starts from — the ONE definition the
    solo driver and the batched server share. or_and (distinct
    reachability) binds each seed vertex once: sorted, deduped.
    plus_times counts walks from the seed *multiset*: duplicates are
    distinct walk sources and written order is kept, so
    `id(a) IN [3, 3, 5]` contributes vertex 3's walks twice. Seeds failing
    the source label/predicate mask drop their column entirely."""
    if p.semiring == "or_and":
        seeds = np.asarray(sorted(set(p.seeds)), dtype=np.int64)
    else:
        seeds = np.asarray(list(p.seeds), dtype=np.int64)
    n = len(src_mask)
    if seeds.size and (seeds.min() < 0 or seeds.max() >= n):
        raise ValueError(f"seed id out of range 0..{n - 1}: "
                         f"{[int(s) for s in seeds if s < 0 or s >= n]}")
    return seeds[src_mask[seeds]]


# -- predicate evaluation -----------------------------------------------------
def _operand_vec(graph: Graph, side, n: int):
    if side[0] == "lit":
        return np.full(n, side[1], dtype=np.float64), None
    if side[0] == "id":
        return np.arange(n, dtype=np.float64), None
    if side[0] == "prop":
        col = graph.node_props.get(side[2])
        if col is None:
            return np.full(n, np.nan), np.zeros(n, dtype=bool)
        col = np.asarray(col, dtype=np.float64)
        return col, ~np.isnan(col)
    raise TypeError(side)


_CMP = {"<": np.less, "<=": np.less_equal, ">": np.greater,
        ">=": np.greater_equal, "=": np.equal, "<>": np.not_equal}


def eval_pred(graph: Graph, node, n: int) -> np.ndarray:
    if isinstance(node, A.Comparison):
        lv, lp = _operand_vec(graph, node.lhs, n)
        rv, rp = _operand_vec(graph, node.rhs, n)
        with np.errstate(invalid="ignore"):
            out = _CMP[node.op](lv, rv)
        for present in (lp, rp):
            if present is not None:
                out &= present
        return out
    if isinstance(node, A.BoolExpr):
        parts = [eval_pred(graph, a, n) for a in node.args]
        if node.op == "AND":
            return np.logical_and.reduce(parts)
        if node.op == "OR":
            return np.logical_or.reduce(parts)
        if node.op == "NOT":
            return ~parts[0]
    if isinstance(node, A.InSeeds):
        m = np.zeros(n, dtype=bool)
        m[node.seeds] = True
        return m
    raise TypeError(node)


# -- CALL procedures ----------------------------------------------------------
# The `CALL algo.*` surface: each procedure is a (device, rows) pair that
# plugs into the SAME scheduler hooks MATCH plans use — `device` is the
# traverse analog (seeds -> an unmaterialized (n, F) device array whose
# columns belong to seed columns), `rows` is the project analog (the
# member's column slice -> row tuples in canonical column order). Seeded
# procedures batch: the server concatenates signature-equal members'
# source lists into ONE device call and slices each member's columns back
# out in finish — so `CALL algo.closeness(sources: [3])` and
# `(sources: [5])` cost one BFS sweep, exactly like two seeded MATCHes.
# Source-less calls are global (label-scan analog: every vertex) and ride
# alone. Unseeded procedures (pagerank, wcc, ...) return one shared
# column; numpy slice-clamping makes the server's per-member column
# slicing a no-op on them.

@dataclasses.dataclass(frozen=True)
class Procedure:
    columns: tuple                      # canonical yield columns, in order
    seeded: bool                        # accepts a `sources:` list
    defaults: dict                      # allowed args + default values
    device: object                      # (ctx, args, seeds) -> jnp (n, F)
    rows: object                        # (ctx, args, seeds, Bn) -> [tuple]


def _proc_M(ctx: "ExecutionContext", args: dict) -> grb.GBMatrix:
    return ctx.matrix(args["rel"])


def _pagerank_device(ctx, a, seeds):
    from repro.algorithms import pagerank
    return pagerank(_proc_M(ctx, a), alpha=float(a["alpha"]),
                    iters=int(a["iters"]))[:, None]


def _betweenness_device(ctx, a, seeds):
    from repro.algorithms import brandes_parts
    return brandes_parts(_proc_M(ctx, a), seeds)


def _levels_device(ctx, a, seeds):
    from repro.algorithms import bfs_levels
    return bfs_levels(_proc_M(ctx, a), seeds,
                      max_iter=int(a.get("max_hops", 0)))


def _similarity_device(ctx, a, seeds):
    from repro.algorithms import similarity
    return similarity(_proc_M(ctx, a), seeds, kind=a["kind"])


def _wcc_device(ctx, a, seeds):
    from repro.algorithms import wcc
    return wcc(_proc_M(ctx, a))[:, None]


def _labelprop_device(ctx, a, seeds):
    from repro.algorithms import label_propagation
    return label_propagation(_proc_M(ctx, a),
                             max_iter=int(a["max_iter"]))[:, None]


def _triangles_device(ctx, a, seeds):
    from repro.algorithms import triangle_count
    return triangle_count(_proc_M(ctx, a)).reshape(1, 1)


def _node_float_rows(ctx, a, seeds, Bn):
    col = Bn[:, 0]
    return [(i, float(col[i])) for i in range(Bn.shape[0])]


def _node_int_rows(ctx, a, seeds, Bn):
    col = Bn[:, 0]
    return [(i, int(col[i])) for i in range(Bn.shape[0])]


def _betweenness_rows(ctx, a, seeds, Bn):
    # a member's score is the dependency sum over ITS source columns —
    # batched members each sum their own slice, so batched == solo
    bc = Bn.sum(axis=1)
    return [(i, float(bc[i])) for i in range(Bn.shape[0])]


def _closeness_rows(ctx, a, seeds, Bn):
    from repro.algorithms import closeness_from_levels
    scores = np.asarray(closeness_from_levels(jnp.asarray(Bn)))
    return [(int(s), float(scores[j])) for j, s in enumerate(seeds)]


def _similarity_rows(ctx, a, seeds, Bn):
    rows = [(int(seeds[j]), int(i), float(Bn[i, j]))
            for i, j in zip(*np.nonzero(Bn > 0))]
    rows.sort()
    return rows


def _bfs_rows(ctx, a, seeds, Bn):
    rows = [(int(seeds[j]), int(i), int(Bn[i, j]))
            for i, j in zip(*np.nonzero(np.isfinite(Bn)))]
    rows.sort()
    return rows


def _triangles_rows(ctx, a, seeds, Bn):
    return [(int(Bn[0, 0]),)]


PROCEDURES = {
    "algo.pagerank": Procedure(
        PROC_COLUMNS["algo.pagerank"], False,
        {"rel": None, "alpha": 0.85, "iters": 50},
        _pagerank_device, _node_float_rows),
    "algo.betweenness": Procedure(
        PROC_COLUMNS["algo.betweenness"], True,
        {"rel": None}, _betweenness_device, _betweenness_rows),
    "algo.closeness": Procedure(
        PROC_COLUMNS["algo.closeness"], True,
        {"rel": None}, _levels_device, _closeness_rows),
    "algo.similarity": Procedure(
        PROC_COLUMNS["algo.similarity"], True,
        {"rel": None, "kind": "jaccard"},
        _similarity_device, _similarity_rows),
    "algo.wcc": Procedure(
        PROC_COLUMNS["algo.wcc"], False,
        {"rel": None}, _wcc_device, _node_int_rows),
    "algo.labelprop": Procedure(
        PROC_COLUMNS["algo.labelprop"], False,
        {"rel": None, "max_iter": 50}, _labelprop_device, _node_int_rows),
    "algo.triangles": Procedure(
        PROC_COLUMNS["algo.triangles"], False,
        {"rel": None}, _triangles_device, _triangles_rows),
    "algo.bfs": Procedure(
        PROC_COLUMNS["algo.bfs"], True,
        {"rel": None, "max_hops": 0}, _levels_device, _bfs_rows),
}
assert set(PROCEDURES) == set(PROC_COLUMNS) and all(
    p.columns == PROC_COLUMNS[k] for k, p in PROCEDURES.items()), \
    "planner.PROC_COLUMNS out of sync with executor.PROCEDURES"


def _procedure(name: str) -> Procedure:
    proc = PROCEDURES.get(name)
    if proc is None:
        # raised at EXECUTION, not planning: the server turns this into a
        # per-query error Result instead of failing the submitter
        raise ValueError(f"no procedure {name!r} "
                         f"(have: {sorted(PROCEDURES)})")
    return proc


def _call_args(name: str, proc: Procedure, args: dict) -> dict:
    unknown = sorted(set(args) - set(proc.defaults))
    if unknown:
        takes = sorted(proc.defaults) + (["sources"] if proc.seeded else [])
        raise ValueError(f"{name}: unknown argument(s) {unknown} "
                         f"(takes: {takes})")
    out = dict(proc.defaults)
    out.update(args)
    return out


# -- public execution surface -------------------------------------------------
class ExecutionContext:
    """Execution primitives over one frozen Graph.

    node_mask  label + predicate scan -> bool (n,) diagonal
    expand     one variable-length traversal step on a frontier matrix
    traverse   seeds -> final frontier for a plan (unmaterialized device work)
    project    frontier matrix -> Result rows per the plan's RETURN clause
    run        parse/plan/execute a full read query (also accepts a Plan)

    The adjacency handles come from the graph's relations; `impl` re-resolves
    their execution policy once per context (not per call). With `mesh` set,
    every relation handle is distributed onto it (`grb.distribute`) and the
    same expand/run calls lower to mesh collectives — the context carries
    the mesh exactly like it carries `impl`; no primitive takes a sharding
    argument. Needs ELL-stored relations (grb raises a TypeError naming the
    expected kinds otherwise; `engine.Database` freezes sharded-mode graphs
    as ELL for this reason).
    """

    # multi-hop SpGEMM fast path is only planned for adjacencies up to this
    # many vertices (hop-matrix fill grows with hop count)
    SPGEMM_EXPAND_MAX_N = 16384

    def __init__(self, graph: Graph, impl: str = "auto",
                 spgemm_expand: bool = True, mesh=None):
        self.graph = graph
        self.impl = impl
        self.spgemm_expand = spgemm_expand
        self.mesh = mesh
        self._mats: Dict[str, grb.GBMatrix] = {}
        self._hops: Dict[tuple, grb.GBMatrix] = {}

    # -- primitives ----------------------------------------------------------
    def matrix(self, rel: Optional[str]) -> grb.GBMatrix:
        """Relation adjacency handle under this context's execution policy."""
        try:
            r = self.graph.relation(rel)
        except KeyError:
            r = None
        if r is None:
            raise ValueError(f"no relation {rel!r} "
                             f"(have: {sorted(self.graph.relations)})")
        m = self._mats.get(r.name)
        if m is None:
            m = r.A.with_impl(self.impl)
            if self.mesh is not None:
                m = grb.distribute(m, self.mesh)
            self._mats[r.name] = m
        return m

    def node_mask(self, label, preds=None) -> np.ndarray:
        """bool (n,): vertices carrying `label` and passing all predicates."""
        n = self.graph.n
        m = np.asarray(self.graph.label_mask(label))
        for p in preds or []:
            m = m & eval_pred(self.graph, p, n)
        return m

    def seed_frontier(self, seeds, keep=None) -> jnp.ndarray:
        """One-hot (n, F) frontier from seed ids; columns where keep is False
        stay empty (filtered seeds still occupy their result column)."""
        seeds = np.asarray(seeds, dtype=np.int64)
        f = len(seeds)
        if keep is None:
            keep = np.ones(f, dtype=bool)
        B = jnp.zeros((self.graph.n, f), dtype=jnp.float32)
        return B.at[jnp.asarray(np.where(keep, seeds, 0)),
                    jnp.arange(f)].set(jnp.asarray(keep.astype(np.float32)))

    def _hop_matrix(self, rel, transpose: bool,
                    max_hops: int) -> grb.GBMatrix:
        """Union of walk matrices OR_{h=1..max} Mt^h over or_and, built once
        per (relation, direction, max_hops) via masked BSR x BSR SpGEMM and
        cached — one sparse handle that answers a whole multi-hop pattern."""
        key = (rel, transpose, max_hops)
        P = self._hops.get(key)
        if P is None:
            from repro.core.bsr import bsr_union, spgemm
            M = self.matrix(rel)
            Mt = (M.T if transpose else M).store
            acc = walk = Mt
            for _ in range(max_hops - 1):
                walk = spgemm(walk, Mt, S.OR_AND, impl=M.impl)
                acc = bsr_union(acc, walk)
            P = self._hops[key] = grb.GBMatrix(acc, impl=self.impl,
                                               name=f"{rel}^1..{max_hops}")
        return P

    def _expand_spgemm_ok(self, e, sr: S.Semiring, transposes) -> bool:
        """The hop-matrix rewrite is exact only for structural reachability
        starting at hop 1 in a single direction (walk-union == first-reach
        union once the seed columns are masked back out)."""
        return (self.spgemm_expand and sr.name == "or_and"
                and e.min_hops == 1 and e.max_hops > 1
                and len(transposes) == 1
                and self.matrix(e.rel).fmt == "bsr"
                and self.graph.n <= self.SPGEMM_EXPAND_MAX_N)

    def expand(self, B: jnp.ndarray, e, sr: S.Semiring,
               dst_mask: np.ndarray) -> jnp.ndarray:
        """min..max-hop traversal of B along e.rel in e.direction."""
        M = self.matrix(e.rel)
        transposes = {A.OUT: (True,), A.IN: (False,),
                      A.BOTH: (True, False)}[e.direction]
        structural = sr.name == "or_and"
        if self._expand_spgemm_ok(e, sr, transposes):
            # one masked mxm against the precomputed 1..max hop matrix
            # replaces max_hops sequential hops; <!seeds> removes the
            # closed-walk returns the loop's visited mask would have blocked
            P = self._hop_matrix(e.rel, transposes[0], e.max_hops)
            seeds0 = (B > 0).astype(jnp.float32)
            reach = grb.mxm(P, B, sr,
                            Descriptor(mask=seeds0, complement=True))
            reach = reach * jnp.asarray(dst_mask, dtype=jnp.float32)[:, None]
            return (reach > 0).astype(jnp.float32)
        if structural and grb.words_route_ok(M, B.shape[1]):
            # word-resident hop loop: pack once, hop on uint32 words with
            # word-wise visited blends ((a & ~v) | (b & ~v) == (a | b) & ~v),
            # unpack once at the end — no per-hop pack/unpack/gather
            f = B.shape[1]
            fw = _bitmap.pack(B)
            vw = fw
            reach_w = jnp.zeros_like(fw)
            for h in range(1, e.max_hops + 1):
                nw = None
                for t in transposes:
                    step = grb.mxm_words(M, fw, transpose_a=t)
                    nw = step if nw is None else _bitmap.word_or(nw, step)
                fw = _bitmap.word_andnot(nw, vw)
                vw = _bitmap.word_or(vw, fw)
                if h >= e.min_hops:
                    reach_w = _bitmap.word_or(reach_w, fw)
            reach = _bitmap.unpack(reach_w, f)
            return reach * jnp.asarray(dst_mask, dtype=jnp.float32)[:, None]
        reach = jnp.zeros_like(B)
        frontier = B
        visited = (B > 0).astype(jnp.float32)
        for h in range(1, e.max_hops + 1):
            nxt = None
            for t in transposes:
                d = Descriptor(mask=visited if structural else None,
                               complement=True, transpose_a=t)
                step = grb.mxm(M, frontier, sr, d)
                nxt = step if nxt is None else _sr_add(sr, nxt, step)
            frontier = nxt
            if structural:
                visited = jnp.maximum(visited,
                                      (frontier > 0).astype(jnp.float32))
            if h >= e.min_hops:
                reach = _sr_add(sr, reach, frontier)
        # destination label/property diagonal
        reach = reach * jnp.asarray(dst_mask, dtype=jnp.float32)[:, None]
        if structural:
            reach = (reach > 0).astype(jnp.float32)
        return reach

    def traverse(self, p: Plan, seeds, keep=None) -> jnp.ndarray:
        """Seeds -> final (n, F) frontier for a plan: the device half of
        `run`, and the batch hook the server composes (it concatenates many
        compatible members' seed columns into one call, padding lanes with
        keep=False columns). The frontier comes back UNmaterialized — under
        jax async dispatch the caller keeps scheduling host-side while the
        device sweeps. A CallPlan dispatches to its procedure's device
        half instead (same contract: columns belong to seed columns, so
        the server's per-member slicing works identically; padding lanes
        compute and get sliced away)."""
        if isinstance(p, CallPlan):
            return self._call_device(p, seeds)
        sr = S.get(p.semiring)
        B = self.seed_frontier(seeds, keep=keep)
        for e in p.expands:
            dst_mask = self.node_mask(e.dst_label, p.var_preds.get(e.dst_var))
            B = self.expand(B, e, sr, dst_mask)
        return B

    def project(self, p: Plan, seeds: np.ndarray, B: jnp.ndarray) -> Result:
        """Materialize RETURN rows from the final frontier matrix."""
        if isinstance(p, CallPlan):
            return self._call_project(p, seeds, np.asarray(B))
        Bn = np.asarray(B)
        cols = [_colname(r) for r in p.returns]
        src_var = p.src_var
        graph = self.graph

        returns_src = any(r.var == src_var and r.kind != "count"
                          for r in p.returns)
        only_counts = all(r.kind == "count" for r in p.returns)

        rows: List[tuple] = []
        if only_counts and not returns_src:
            # global aggregate: one row
            vals = []
            for r in p.returns:
                tot = ((Bn > 0).sum()
                       if r.distinct or p.semiring == "or_and" else Bn.sum())
                vals.append(int(tot))
            rows = [tuple(vals)]
        elif only_counts or (returns_src
                             and all(r.kind == "count" or r.var == src_var
                                     for r in p.returns)):
            # grouped by seed
            for j, s in enumerate(seeds):
                vals = []
                for r in p.returns:
                    if r.kind == "count":
                        tot = ((Bn[:, j] > 0).sum()
                               if (r.distinct or p.semiring == "or_and")
                               else Bn[:, j].sum())
                        vals.append(int(tot))
                    elif r.kind == "prop":
                        vals.append(_prop(graph, r.prop, int(s)))
                    else:
                        vals.append(int(s))
                rows.append(tuple(vals))
        else:
            # materialize (seed, dst) bindings
            dst_rows, seed_cols = np.nonzero(Bn > 0)
            for d, j in zip(dst_rows, seed_cols):
                vals = []
                for r in p.returns:
                    node = int(seeds[j]) if r.var == src_var else int(d)
                    if r.kind == "prop":
                        vals.append(_prop(graph, r.prop, node))
                    else:
                        vals.append(node)
                rows.append(tuple(vals))
            rows.sort()
        if p.limit is not None:
            rows = rows[: p.limit]
        return Result(cols, rows)

    # -- CALL dispatch -------------------------------------------------------
    def _call_device(self, p: CallPlan, seeds) -> jnp.ndarray:
        """Device half of a procedure call (traverse analog). Seeded
        procedures compute one column per seed; unseeded ones return a
        single shared column and reject an explicit `sources:` list."""
        proc = _procedure(p.proc)
        a = _call_args(p.proc, proc, p.args)
        if p.seeds is not None and not proc.seeded:
            raise ValueError(f"{p.proc} takes no sources "
                             f"(it is a whole-graph procedure)")
        return proc.device(self, a, np.asarray(seeds, dtype=np.int64))

    def _call_project(self, p: CallPlan, seeds, Bn: np.ndarray) -> Result:
        """Host half (project analog): the member's column slice -> YIELD
        rows. YIELD selects/renames/reorders the procedure's canonical
        columns; an unknown yield name raises (per-member, isolated)."""
        proc = _procedure(p.proc)
        a = _call_args(p.proc, proc, p.args)
        rows = proc.rows(self, a, np.asarray(seeds, dtype=np.int64), Bn)
        cols, idx = [], []
        for r in p.returns:
            if r.var not in proc.columns:
                raise ValueError(f"{p.proc} yields {list(proc.columns)}, "
                                 f"not {r.var!r}")
            cols.append(r.alias or r.var)
            idx.append(proc.columns.index(r.var))
        rows = [tuple(row[i] for i in idx) for row in rows]
        if p.limit is not None:
            rows = rows[: p.limit]
        return Result(cols, rows)

    # -- solo driver ---------------------------------------------------------
    def run(self, query) -> Result:
        """Execute a read query: text, MatchQuery AST, or an already-built
        Plan (the server's cached-plan path — no re-parse)."""
        if isinstance(query, (Plan, CallPlan)):
            p = query
        else:
            q = parse(query) if isinstance(query, str) else query
            if isinstance(q, (A.CreateQuery, A.DeleteQuery)):
                kw = "CREATE" if isinstance(q, A.CreateQuery) else "DELETE"
                raise TypeError(f"{kw} goes through engine.Database, not a "
                                f"read ExecutionContext")
            p = plan(q)

        src_mask = self.node_mask(p.src_label, p.var_preds.get(p.src_var))
        if p.seeds is not None:
            seeds = resolve_seeds(p, src_mask)
        else:
            seeds = np.nonzero(src_mask)[0]
        if len(seeds) == 0:
            return empty_result(p)
        return self.project(p, seeds, self.traverse(p, seeds))


def _sr_add(sr: S.Semiring, a, b):
    return jnp.maximum(a, b) if sr.name == "or_and" else a + b


# -- top level ----------------------------------------------------------------
def execute(graph: Graph, query, impl: str = "auto", mesh=None) -> Result:
    return ExecutionContext(graph, impl=impl, mesh=mesh).run(query)


def _colname(r: A.ReturnItem) -> str:
    if r.alias:
        return r.alias
    if r.kind == "count":
        return f"count({'DISTINCT ' if r.distinct else ''}{r.var})"
    if r.kind == "prop":
        return f"{r.var}.{r.prop}"
    return r.var


def _prop(graph: Graph, prop: str, node: int):
    col = graph.node_props.get(prop)
    if col is None:
        return None
    v = float(np.asarray(col)[node])
    return None if np.isnan(v) else v


def explain(graph: Graph, query) -> str:
    q = parse(query) if isinstance(query, str) else query
    return plan(q).explain()
