from repro.query.executor import ExecutionContext, Result, execute, explain
from repro.query.parser import parse
from repro.query.planner import plan

__all__ = ["ExecutionContext", "Result", "execute", "explain", "parse",
           "plan"]
