from repro.query.executor import Result, execute, explain
from repro.query.parser import parse
from repro.query.planner import plan

__all__ = ["Result", "execute", "explain", "parse", "plan"]
