"""Logical planning: MatchQuery AST -> algebraic execution plan.

The plan mirrors RedisGraph's ExecutionPlan: a NodeScan (label diagonal or
seed one-hots) followed by Expand operators (semiring vxm per hop, masked by
label/property diagonals), ending in Project/Aggregate.

Serving additions (the RedisGraph execution-plan cache analog):
`signature(plan)` is the batching-compatibility key — everything about a
plan except WHICH seed ids it starts from, predicate *content* included —
and `PlanCache` memoizes parse+plan per normalized query text so a repeat
shape never re-parses. Both are what `engine.server` schedules with.
"""
from __future__ import annotations

import dataclasses
import re
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

from repro.query import qast as A


@dataclasses.dataclass
class Expand:
    rel: Optional[str]
    direction: str
    min_hops: int
    max_hops: int
    dst_var: Optional[str]
    dst_label: Optional[str]


@dataclasses.dataclass
class Plan:
    src_var: Optional[str]
    src_label: Optional[str]
    seeds: Optional[List[int]]          # explicit seed ids, else label scan
    var_preds: dict                     # var -> predicate AST list (conjunction)
    expands: List[Expand]
    returns: List[A.ReturnItem]
    limit: Optional[int]
    semiring: str                       # or_and (distinct) | plus_times (walks)

    def explain(self) -> str:
        lines = []
        scan = (f"NodeByIdSeek({self.src_var}, ids={self.seeds})" if self.seeds
                else f"NodeByLabelScan({self.src_var}:{self.src_label or '*'})")
        lines.append(scan)
        for e in self.expands:
            lines.append(
                f"ConditionalTraverse([{e.rel or '*'}] {e.direction} "
                f"*{e.min_hops}..{e.max_hops} -> {e.dst_var}:{e.dst_label or '*'}"
                f") [semiring={self.semiring}]")
        for v, preds in self.var_preds.items():
            if preds:
                lines.append(f"Filter({v}: {len(preds)} predicate(s))")
        lines.append(f"Project({[r.kind + ':' + r.var for r in self.returns]}"
                     f" limit={self.limit})")
        return "\n".join(lines)


# Column names each built-in procedure yields, in canonical order — the ONE
# place the surface is declared. `plan_call` fills an omitted YIELD clause
# from here; `query.executor.PROCEDURES` (the implementations) asserts it
# stays in sync at import.
PROC_COLUMNS = {
    "algo.pagerank":    ("node", "score"),
    "algo.betweenness": ("node", "score"),
    "algo.closeness":   ("node", "score"),
    "algo.similarity":  ("node1", "node2", "score"),
    "algo.wcc":         ("node", "component"),
    "algo.labelprop":   ("node", "community"),
    "algo.triangles":   ("triangles",),
    "algo.bfs":         ("source", "node", "level"),
}


@dataclasses.dataclass
class CallPlan:
    """Execution plan for `CALL algo.*` — the procedure analog of `Plan`.

    Carries the same scheduler surface a MATCH plan does (`seeds`,
    `semiring`, `src_var`/`src_label`/`var_preds`), so `engine.server`
    batches CALL sweeps through the identical admission/launch/finish
    machinery: seeded calls (a `sources:` list) coalesce with every
    signature-equal member into one device sweep whose columns are the
    union of their sources; source-less calls ride alone like label
    scans. `semiring` is pinned to or_and so `executor.resolve_seeds`
    binds each source vertex once (sorted, deduped)."""
    proc: str
    args: dict                          # named args minus `sources`
    seeds: Optional[List[int]]          # the popped `sources` list
    returns: List[A.ReturnItem]         # YIELD items (kind="var")
    limit: Optional[int] = None
    # server-compatibility surface (a CALL has no pattern to scan/filter)
    src_var: Optional[str] = None
    src_label: Optional[str] = None
    var_preds: dict = dataclasses.field(default_factory=dict)
    expands: List[Expand] = dataclasses.field(default_factory=list)
    semiring: str = "or_and"

    def explain(self) -> str:
        src = (f"sources={self.seeds}" if self.seeds is not None
               else "sources=*")
        cols = [r.alias or r.var for r in self.returns]
        return (f"ProcedureCall({self.proc}, {src}, args={self.args})\n"
                f"Project({cols} limit={self.limit})")


def plan_call(q: A.CallQuery) -> CallPlan:
    """CallQuery AST -> CallPlan. `sources:` moves out of the arg dict into
    the plan's seed slot (the batched-over dimension, excluded from the
    signature); an omitted YIELD expands to the procedure's full column
    list. Unknown procedure names plan fine and fail at *execution* — the
    server isolates them as per-query error Results instead of poisoning
    the submitter."""
    args = dict(q.args)
    seeds = args.pop("sources", None)
    if seeds is not None:
        if not isinstance(seeds, (list, tuple)):
            seeds = [seeds]             # `sources: 3` — a single id
        seeds = [int(s) for s in seeds]
    returns = list(q.yields)
    if not returns:
        returns = [A.ReturnItem("var", c)
                   for c in PROC_COLUMNS.get(q.proc, ())]
    return CallPlan(q.proc, args, seeds, returns, q.limit)


def _pred_vars(node) -> set:
    if isinstance(node, A.Comparison):
        out = set()
        for side in (node.lhs, node.rhs):
            if side[0] in ("prop", "id"):
                out.add(side[1])
        return out
    if isinstance(node, A.BoolExpr):
        out = set()
        for a in node.args:
            out |= _pred_vars(a)
        return out
    if isinstance(node, A.InSeeds):
        return {node.var}
    raise TypeError(node)


def plan(q) -> Plan:
    if isinstance(q, A.CallQuery):
        return plan_call(q)
    if not q.nodes:
        raise ValueError("empty pattern")
    src = q.nodes[0]
    var_preds: dict = {n.var: [] for n in q.nodes if n.var}
    seeds = None

    for pred in q.where:
        vars_ = _pred_vars(pred)
        if len(vars_) != 1:
            raise NotImplementedError(
                f"cross-variable predicate over {vars_} not supported")
        v = next(iter(vars_))
        if v not in var_preds:
            raise ValueError(f"unknown variable {v}")
        # seed selectors on the source variable become NodeByIdSeek
        if v == src.var and isinstance(pred, A.InSeeds):
            seeds = (seeds or []) + list(pred.seeds)
        elif (v == src.var and isinstance(pred, A.Comparison)
              and pred.op == "=" and pred.lhs[0] == "id" and pred.rhs[0] == "lit"):
            seeds = (seeds or []) + [int(pred.rhs[1])]
        else:
            var_preds[v].append(pred)

    # distinct-vertex reachability (or_and) unless someone counts walks
    semiring = "or_and"
    for r in q.returns:
        if r.kind == "count" and not r.distinct:
            semiring = "plus_times"

    expands = []
    for i, e in enumerate(q.edges):
        dst = q.nodes[i + 1]
        expands.append(Expand(e.rel, e.direction, e.min_hops, e.max_hops,
                              dst.var, dst.label))
    return Plan(src.var, src.label, seeds, var_preds, expands,
                q.returns, q.limit, semiring)


# -- serving: signatures + the plan cache -------------------------------------
def pred_key(node) -> tuple:
    """Hashable normal form of one predicate AST node."""
    if isinstance(node, A.Comparison):
        return ("cmp", node.op, tuple(node.lhs), tuple(node.rhs))
    if isinstance(node, A.BoolExpr):
        return ("bool", node.op, tuple(pred_key(a) for a in node.args))
    if isinstance(node, A.InSeeds):
        return ("in", node.var, tuple(node.seeds))
    raise TypeError(node)


def signature(p: Plan) -> tuple:
    """Batching-compatibility key: two seeded plans with equal signatures
    answer from ONE shared frontier traversal (their seed columns sit side
    by side in the same matrix sweep). The key covers the full predicate
    content — a predicate-count-only key would let queries with different
    WHERE clauses share one (wrong) node mask — and excludes exactly the
    seed ids, the batched-over dimension. CALL plans key on the procedure
    plus full argument content (seeds excluded, exactly like MATCH): two
    `algo.closeness(sources: ...)` calls with different source lists share
    one sweep; a different `kind:`/`iters:`/YIELD/LIMIT does not."""
    if isinstance(p, CallPlan):
        return ("call", p.proc, tuple(sorted(p.args.items())),
                tuple((r.kind, r.var, r.prop, r.distinct, r.alias)
                      for r in p.returns),
                p.limit)
    return (p.src_var, p.src_label,
            tuple((e.rel, e.direction, e.min_hops, e.max_hops,
                   e.dst_var, e.dst_label) for e in p.expands),
            p.semiring,
            tuple((r.kind, r.var, r.prop, r.distinct, r.alias)
                  for r in p.returns),
            p.limit,
            tuple(sorted((v, tuple(pred_key(q) for q in ps))
                         for v, ps in p.var_preds.items())))


class PlanCache:
    """LRU parse+plan cache keyed by whitespace-normalized query text — the
    RedisGraph execution-plan cache analog. `get` returns a SHARED
    (plan, signature) pair: callers must treat the plan as immutable
    (`engine.server` re-binds seeds via `dataclasses.replace`). Repeat
    query shapes skip tokenize+parse+plan entirely; the parameterized
    submit form (`QueryServer.submit(text, seeds=...)`) keeps the text
    seed-free so every seed binding of one shape is a hit."""

    def __init__(self, maxsize: int = 1024):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._entries: "OrderedDict[str, Tuple[Plan, tuple]]" = OrderedDict()

    @staticmethod
    def key(text: str) -> str:
        """Whitespace-normal form: runs of whitespace collapse to one
        space, and spaces adjacent to punctuation drop entirely — so
        `CALL algo.pagerank( iters: 20 )` and `CALL algo.pagerank(iters:20)`
        are one cache entry (argument lists vary freely in formatting).
        Word-adjacent tokens keep their separating space, so distinct
        token streams can never normalize together."""
        return re.sub(r"\s*([^\w\s])\s*", r"\1", " ".join(text.split()))

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        seen = self.hits + self.misses
        return self.hits / seen if seen else 0.0

    def get(self, text: str) -> Tuple[Plan, tuple]:
        """(plan, signature) for the query text; parse+plan on first sight.
        Parse/plan errors propagate to the submitter and cache nothing."""
        from repro.query.parser import parse  # deferred: no import cycle
        k = self.key(text)
        entry = self._entries.get(k)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(k)
            return entry
        p = plan(parse(text))
        self.misses += 1
        entry = (p, signature(p))
        self._entries[k] = entry
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return entry
