"""Logical planning: MatchQuery AST -> algebraic execution plan.

The plan mirrors RedisGraph's ExecutionPlan: a NodeScan (label diagonal or
seed one-hots) followed by Expand operators (semiring vxm per hop, masked by
label/property diagonals), ending in Project/Aggregate.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.query import qast as A


@dataclasses.dataclass
class Expand:
    rel: Optional[str]
    direction: str
    min_hops: int
    max_hops: int
    dst_var: Optional[str]
    dst_label: Optional[str]


@dataclasses.dataclass
class Plan:
    src_var: Optional[str]
    src_label: Optional[str]
    seeds: Optional[List[int]]          # explicit seed ids, else label scan
    var_preds: dict                     # var -> predicate AST list (conjunction)
    expands: List[Expand]
    returns: List[A.ReturnItem]
    limit: Optional[int]
    semiring: str                       # or_and (distinct) | plus_times (walks)

    def explain(self) -> str:
        lines = []
        scan = (f"NodeByIdSeek({self.src_var}, ids={self.seeds})" if self.seeds
                else f"NodeByLabelScan({self.src_var}:{self.src_label or '*'})")
        lines.append(scan)
        for e in self.expands:
            lines.append(
                f"ConditionalTraverse([{e.rel or '*'}] {e.direction} "
                f"*{e.min_hops}..{e.max_hops} -> {e.dst_var}:{e.dst_label or '*'}"
                f") [semiring={self.semiring}]")
        for v, preds in self.var_preds.items():
            if preds:
                lines.append(f"Filter({v}: {len(preds)} predicate(s))")
        lines.append(f"Project({[r.kind + ':' + r.var for r in self.returns]}"
                     f" limit={self.limit})")
        return "\n".join(lines)


def _pred_vars(node) -> set:
    if isinstance(node, A.Comparison):
        out = set()
        for side in (node.lhs, node.rhs):
            if side[0] in ("prop", "id"):
                out.add(side[1])
        return out
    if isinstance(node, A.BoolExpr):
        out = set()
        for a in node.args:
            out |= _pred_vars(a)
        return out
    if isinstance(node, A.InSeeds):
        return {node.var}
    raise TypeError(node)


def plan(q: A.MatchQuery) -> Plan:
    if not q.nodes:
        raise ValueError("empty pattern")
    src = q.nodes[0]
    var_preds: dict = {n.var: [] for n in q.nodes if n.var}
    seeds = None

    for pred in q.where:
        vars_ = _pred_vars(pred)
        if len(vars_) != 1:
            raise NotImplementedError(
                f"cross-variable predicate over {vars_} not supported")
        v = next(iter(vars_))
        if v not in var_preds:
            raise ValueError(f"unknown variable {v}")
        # seed selectors on the source variable become NodeByIdSeek
        if v == src.var and isinstance(pred, A.InSeeds):
            seeds = (seeds or []) + list(pred.seeds)
        elif (v == src.var and isinstance(pred, A.Comparison)
              and pred.op == "=" and pred.lhs[0] == "id" and pred.rhs[0] == "lit"):
            seeds = (seeds or []) + [int(pred.rhs[1])]
        else:
            var_preds[v].append(pred)

    # distinct-vertex reachability (or_and) unless someone counts walks
    semiring = "or_and"
    for r in q.returns:
        if r.kind == "count" and not r.distinct:
            semiring = "plus_times"

    expands = []
    for i, e in enumerate(q.edges):
        dst = q.nodes[i + 1]
        expands.append(Expand(e.rel, e.direction, e.min_hops, e.max_hops,
                              dst.var, dst.label))
    return Plan(src.var, src.label, seeds, var_preds, expands,
                q.returns, q.limit, semiring)
