"""Hand-written lexer + recursive-descent parser for the Cypher subset."""
from __future__ import annotations

import re
from typing import List

from repro.query import qast as A

_TOKEN = re.compile(r"""
    (?P<WS>\s+)
  | (?P<NUM>-?\d+(\.\d+)?)
  | (?P<ARROW_R>->)
  | (?P<ARROW_L><-)
  | (?P<DOTS>\.\.)
  | (?P<NEQ><>)
  | (?P<LE><=) | (?P<GE>>=)
  | (?P<SYM>[(){}\[\],:.=<>*-])
  | (?P<NAME>[A-Za-z_][A-Za-z_0-9]*)
""", re.VERBOSE)

KEYWORDS = {"MATCH", "WHERE", "RETURN", "LIMIT", "AND", "OR", "NOT", "COUNT",
            "DISTINCT", "ID", "IN", "CREATE", "DELETE", "AS", "CALL", "YIELD"}


def tokenize(s: str) -> List[tuple]:
    out, pos = [], 0
    while pos < len(s):
        m = _TOKEN.match(s, pos)
        if not m:
            raise SyntaxError(f"bad token at: {s[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "WS":
            continue
        text = m.group()
        if kind == "NAME" and text.upper() in KEYWORDS:
            out.append((text.upper(), text))
        elif kind in ("ARROW_R", "ARROW_L", "DOTS", "NEQ", "LE", "GE"):
            out.append((text, text))
        elif kind == "SYM":
            out.append((text, text))
        elif kind == "NUM":
            out.append(("NUM", text))
        else:
            out.append(("NAME", text))
    out.append(("EOF", ""))
    return out


class Parser:
    def __init__(self, text: str):
        self.toks = tokenize(text)
        self.i = 0

    # -- token helpers ------------------------------------------------------
    def peek(self, k=0):
        return self.toks[min(self.i + k, len(self.toks) - 1)][0]

    def next(self):
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind):
        t = self.next()
        if t[0] != kind:
            raise SyntaxError(f"expected {kind}, got {t}")
        return t

    def accept(self, kind):
        if self.peek() == kind:
            return self.next()
        return None

    def expect_name(self) -> str:
        """A NAME, or a keyword used in name position (e.g. {id: ...})."""
        t = self.next()
        if t[0] == "NAME" or t[0] in KEYWORDS:
            return t[1]
        raise SyntaxError(f"expected name, got {t}")

    # -- entry ---------------------------------------------------------------
    def parse(self):
        if self.peek() == "CREATE":
            return self.parse_create()
        if self.peek() == "DELETE":
            return self.parse_delete()
        if self.peek() == "CALL":
            return self.parse_call()
        return self.parse_match()

    # -- CALL ----------------------------------------------------------------
    def parse_call(self):
        """CALL algo.name(arg: v, ...) [YIELD col [AS a], ...] [LIMIT k]"""
        self.expect("CALL")
        parts = [self.expect_name()]
        while self.accept("."):
            parts.append(self.expect_name())
        args = {}
        self.expect("(")
        while self.peek() != ")":
            name = self.expect_name()
            self.expect(":")
            args[name] = self.parse_call_value()
            self.accept(",")
        self.expect(")")
        yields = []
        if self.accept("YIELD"):
            yields.append(self.parse_yield_item())
            while self.accept(","):
                yields.append(self.parse_yield_item())
        limit = None
        if self.accept("LIMIT"):
            limit = int(self.expect("NUM")[1])
        self.expect("EOF")
        return A.CallQuery(".".join(parts), args, yields, limit)

    def parse_call_value(self):
        """number | [number, ...] -> tuple | bare word -> string."""
        if self.peek() == "NUM":
            return _num(self.next()[1])
        if self.accept("["):
            vals = []
            while self.peek() == "NUM":
                vals.append(_num(self.next()[1]))
                self.accept(",")
            self.expect("]")
            return tuple(vals)
        return self.expect_name()

    def parse_yield_item(self):
        item = A.ReturnItem("var", self.expect_name())
        if self.accept("AS"):
            item.alias = self.expect_name()
        return item

    # -- CREATE --------------------------------------------------------------
    def parse_create(self):
        items = []
        self.expect("CREATE")
        more = True
        while more:
            self.accept("CREATE")
            self.expect("(")
            if self.peek() == "NUM":  # CREATE (3)-[:R]->(5)
                src = int(self.next()[1])
                self.expect(")")
                self.expect("-")
                self.expect("[")
                self.expect(":")
                rel = self.expect("NAME")[1]
                self.expect("]")
                self.expect("->")
                self.expect("(")
                dst = int(self.expect("NUM")[1])
                self.expect(")")
                items.append(A.CreateEdge(src, rel, dst))
            else:                       # CREATE (:Label {id: 3, age: 30})
                label = None
                if self.accept(":"):
                    label = self.expect("NAME")[1]
                props = self.parse_props()
                self.expect(")")
                # "id" is optional: the engine auto-assigns next_id
                items.append(A.CreateNode(label, props))
            more = bool(self.accept(",")) or self.peek() == "CREATE"
        self.expect("EOF")
        return A.CreateQuery(items)

    # -- DELETE --------------------------------------------------------------
    def parse_delete(self):
        items = []
        self.expect("DELETE")
        more = True
        while more:
            self.accept("DELETE")
            self.expect("(")
            nid = int(self.expect("NUM")[1])
            self.expect(")")
            if self.peek() == "-":      # DELETE (3)-[:R]->(5)
                self.expect("-")
                self.expect("[")
                self.expect(":")
                rel = self.expect("NAME")[1]
                self.expect("]")
                self.expect("->")
                self.expect("(")
                dst = int(self.expect("NUM")[1])
                self.expect(")")
                items.append(A.DeleteEdge(nid, rel, dst))
            else:                       # DELETE (3): whole-node tombstone
                items.append(A.DeleteNode(nid))
            more = bool(self.accept(",")) or self.peek() == "DELETE"
        self.expect("EOF")
        return A.DeleteQuery(items)

    def parse_props(self):
        props = {}
        if self.accept("{"):
            while self.peek() != "}":
                name = self.expect_name()
                self.expect(":")
                props[name] = float(self.expect("NUM")[1])
                self.accept(",")
            self.expect("}")
        return props

    # -- MATCH ----------------------------------------------------------------
    def parse_match(self):
        self.expect("MATCH")
        nodes, edges = [self.parse_node()], []
        while self.peek() in ("-", "<-"):
            edges.append(self.parse_edge())
            nodes.append(self.parse_node())
        where = []
        if self.accept("WHERE"):
            where = self.parse_where()
        self.expect("RETURN")
        rets = [self.parse_return_item()]
        while self.accept(","):
            rets.append(self.parse_return_item())
        limit = None
        if self.accept("LIMIT"):
            limit = int(self.expect("NUM")[1])
        self.expect("EOF")
        return A.MatchQuery(nodes, edges, where, rets, limit)

    def parse_node(self):
        self.expect("(")
        var = label = None
        if self.peek() == "NAME":
            var = self.next()[1]
        if self.accept(":"):
            label = self.expect("NAME")[1]
        props = self.parse_props()
        self.expect(")")
        return A.NodePat(var, label, props)

    def parse_edge(self):
        direction = A.OUT
        if self.accept("<-"):
            direction = A.IN
        else:
            self.expect("-")
        var = rel = None
        minh = maxh = 1
        if self.accept("["):
            if self.peek() == "NAME":
                var = self.next()[1]
            if self.accept(":"):
                rel = self.expect("NAME")[1]
            if self.accept("*"):
                if self.peek() == "NUM":
                    minh = int(self.next()[1])
                    if self.accept(".."):
                        maxh = int(self.expect("NUM")[1])
                    else:
                        maxh = minh
                elif self.accept(".."):
                    minh, maxh = 1, int(self.expect("NUM")[1])
                else:
                    raise SyntaxError("unbounded *: give a max hop count")
            self.expect("]")
        if direction == A.IN:
            self.expect("-")
        elif self.accept("->"):
            pass
        else:
            self.expect("-")
            direction = A.BOTH
        return A.EdgePat(var, rel, direction, minh, maxh)

    # -- WHERE -----------------------------------------------------------------
    def parse_where(self):
        conj = [self.parse_or()]
        while self.accept("AND"):
            conj.append(self.parse_or())
        return conj

    def parse_or(self):
        left = self.parse_not()
        args = [left]
        while self.accept("OR"):
            args.append(self.parse_not())
        return args[0] if len(args) == 1 else A.BoolExpr("OR", args)

    def parse_not(self):
        if self.accept("NOT"):
            return A.BoolExpr("NOT", [self.parse_not()])
        if self.peek() == "(" and self.peek(1) in ("NOT",) :
            self.expect("(")
            e = self.parse_or()
            self.expect(")")
            return e
        return self.parse_cmp()

    def parse_cmp(self):
        if self.peek() == "(":
            self.expect("(")
            e = self.parse_or()
            self.expect(")")
            return e
        lhs = self.parse_operand()
        # id(v) IN [s1, s2, ...]
        if self.accept("IN"):
            if lhs[0] != "id":
                raise SyntaxError("IN only supported on id(var)")
            self.expect("[")
            seeds = []
            while self.peek() == "NUM":
                seeds.append(int(self.next()[1]))
                self.accept(",")
            self.expect("]")
            return A.InSeeds(lhs[1], seeds)
        op = self.next()[0]
        if op not in ("<", "<=", ">", ">=", "=", "<>"):
            raise SyntaxError(f"bad comparison op {op}")
        rhs = self.parse_operand()
        return A.Comparison(op, lhs, rhs)

    def parse_operand(self):
        if self.accept("ID"):
            self.expect("(")
            var = self.expect("NAME")[1]
            self.expect(")")
            return ("id", var)
        if self.peek() == "NUM":
            return ("lit", float(self.next()[1]))
        var = self.expect("NAME")[1]
        self.expect(".")
        prop = self.expect("NAME")[1]
        return ("prop", var, prop)

    def parse_return_item(self):
        if self.accept("COUNT"):
            self.expect("(")
            distinct = bool(self.accept("DISTINCT"))
            var = self.expect("NAME")[1]
            self.expect(")")
            item = A.ReturnItem("count", var, distinct=distinct)
        else:
            var = self.expect("NAME")[1]
            if self.accept("."):
                prop = self.expect("NAME")[1]
                item = A.ReturnItem("prop", var, prop=prop)
            else:
                item = A.ReturnItem("var", var)
        if self.accept("AS"):
            item.alias = self.expect("NAME")[1]
        return item


def _num(text: str):
    """CALL argument numbers keep their intness: `iters: 50` is an int."""
    return float(text) if "." in text else int(text)


def parse(text: str):
    return Parser(text).parse()
