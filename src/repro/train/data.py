"""Deterministic synthetic data pipeline (token streams + family extras).

Deterministic per (seed, step, host): every host computes only its shard of
the global batch — restart-safe (the stream index derives from the step, so
resuming from step N replays exactly the post-N stream) and elastic-safe
(host count can change between runs; the global batch content is invariant).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    # markov-chain-ish synthetic text: more structure than uniform noise so
    # loss curves actually descend.
    branch: int = 31


def _batch_rng(seed: int, step: int):
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                    data_cfg: DataConfig = DataConfig(),
                    host_index: int = 0, host_count: int = 1):
    """Returns this host's slice of the global batch for `step`."""
    rng = _batch_rng(data_cfg.seed, step)
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "llava":
        S = S - cfg.n_image_tokens
    # low-entropy sequence: x_{t+1} = (a*x_t + noise) % vocab
    a = 31
    x0 = rng.integers(0, cfg.vocab, size=(B, 1))
    noise = rng.integers(0, data_cfg.branch, size=(B, S + 1))
    toks = np.zeros((B, S + 1), dtype=np.int64)
    toks[:, 0] = x0[:, 0]
    for t in range(S):
        toks[:, t + 1] = (a * toks[:, t] + noise[:, t]) % cfg.vocab
    lo = host_index * B // host_count
    hi = (host_index + 1) * B // host_count
    batch = {"tokens": toks[lo:hi, :-1].astype(np.int32),
             "labels": toks[lo:hi, 1:].astype(np.int32)}
    if cfg.family == "whisper":
        batch["frames"] = rng.normal(
            size=(hi - lo, cfg.n_audio_frames, cfg.d_frontend)).astype(np.float32)
    if cfg.family == "llava":
        batch["patches"] = rng.normal(
            size=(hi - lo, cfg.n_image_tokens, cfg.d_frontend)).astype(np.float32)
    return batch


def stream(cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
           **kw) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, shape, step, **kw)
        step += 1
