"""Sharded, atomic, async checkpointing (orbax-free, built in-repo).

Layout:   <dir>/step_<N>/
            manifest.json        # tree structure, shapes, dtypes, hashes
            leaf_<i>.npy         # one file per pytree leaf
          <dir>/LATEST           # atomic pointer (write-tmp + rename)

Fault tolerance: writes go to step_<N>.tmp then a single atomic rename; a
crash mid-write never corrupts LATEST. The async writer runs in a background
thread (compute/IO overlap); `wait()` joins before the next save.
Elastic restore: leaves are loaded host-side and re-sharded onto whatever
mesh the restarted job has (jax.device_put with the new sharding), so the
job can resume on a different data-parallel size.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def save(tree, directory: str, step: int) -> str:
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    for i, (kp, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fn = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(kp), "file": fn,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(tree_like, directory: str, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of `tree_like` (specs or arrays).
    `shardings`: optional matching tree of NamedSharding for elastic resume."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    assert len(manifest["leaves"]) == len(flat), "tree structure changed"
    out = []
    for meta, spec, shd in zip(manifest["leaves"], flat, shard_flat):
        arr = np.load(os.path.join(d, meta["file"]))
        if verify:
            h = hashlib.sha1(arr.tobytes()).hexdigest()
            if h != meta["sha1"]:
                raise IOError(f"checksum mismatch for {meta['path']}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread writer: training continues while the previous step
    serializes. Device->host transfer happens on the caller thread (cheap,
    and correct w.r.t. donated buffers); file IO happens off-thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, tree, step: int):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(host_tree, step), daemon=True)
        self._thread.start()

    def _write(self, host_tree, step):
        save(host_tree, self.directory, step)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
