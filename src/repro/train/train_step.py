"""The jit-able train step: loss -> grad -> (optional compression) -> update.

Supports gradient-accumulation microbatching (activation memory lever) and
int8 gradient compression with error feedback (distr/compression.py) for
bandwidth-bound DP meshes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distr import compression
from repro.train import optimizer as opt_mod


def make_train_step(model, opt_cfg: opt_mod.OptConfig, *,
                    microbatches: int = 1, compress_grads: bool = False,
                    accum_dtype=jnp.float32, hoist_weight_gather: bool = False):
    update = opt_mod.update_fn(opt_cfg.name)

    def loss_of(params, batch):
        return model.loss_fn(params, batch)

    def _tp_only(params):
        """§Perf T11: pin params replicated over the data group (TP-only) so
        the FSDP all-gather is hoisted OUT of the microbatch loop — GSPMD
        emits one all-gather fwd and one reduce-scatter for the scan-summed
        cotangent, instead of 2 x params-bytes per LAYER per MICROBATCH."""
        from repro.distr import shardctx, sharding as sh
        from jax.sharding import NamedSharding, PartitionSpec as P
        ctx = shardctx.get()
        if ctx is None:
            return params
        mesh = ctx.mesh
        drop = set(sh.data_axes(mesh))

        def one(kp, p):
            spec = sh.param_pspec(jax.tree_util.keystr(kp), p.shape, mesh,
                                  vocab=getattr(model.cfg, "vocab", None))
            kept = tuple(
                None if (e in drop or (isinstance(e, tuple)
                                       and set(e) & drop)) else e
                for e in spec)
            return jax.lax.with_sharding_constraint(
                p, NamedSharding(mesh, P(*kept)))

        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        return jax.tree_util.tree_unflatten(
            treedef, [one(kp, p) for kp, p in flat])

    def train_step(params, opt_state, batch, error_fb=None):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            if hoist_weight_gather:
                # §Perf T11 variant — MEASURED AND REFUTED at mb=4/256 chips
                # (EXPERIMENTS.md): the replicated cotangent accumulator +
                # TP-only weight copies cost more memory (51 GB) than the
                # per-microbatch re-gathers cost collectives. Kept behind the
                # flag: napkin math says it wins at mb >= 16 or pod-scale DP.
                def total_loss(params):
                    params_use = _tp_only(params)

                    def acc_step(loss_acc, mb):
                        return loss_acc + loss_of(params_use, mb), None

                    acc_step = jax.checkpoint(acc_step)
                    loss_sum, _ = jax.lax.scan(
                        acc_step, jnp.float32(0.0), micro)
                    return loss_sum / microbatches

                loss, grads = jax.value_and_grad(total_loss)(params)
            else:
                def acc_step(carry, mb):
                    loss_acc, grad_acc = carry
                    l, g = jax.value_and_grad(loss_of)(params, mb)
                    return (loss_acc + l,
                            jax.tree.map(
                                lambda a, b: a + b.astype(accum_dtype),
                                grad_acc, g)), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, accum_dtype), params)
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.float32(0.0), zeros), micro)
                loss = loss / microbatches
                grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if compress_grads:
            grads, error_fb = compression.compress_decompress(grads, error_fb)

        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt_mod.schedule(opt_cfg, opt_state["step"])}
        if compress_grads:
            return params, opt_state, metrics, error_fb
        return params, opt_state, metrics

    return train_step
