"""Optimizers built in-repo (no optax): AdamW and Adafactor (factored second
moment — required for llama4-maverick, whose Adam state exceeds 256x16 GB),
plus cosine LR schedule and global-norm clipping. State trees shard exactly
like their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128
    # §Perf T7: apply the update lax.map'd over the leading (stacked-layer)
    # dim of leaves bigger than this, so f32 temporaries are one layer's
    # worth, not the whole 2 TB stacked tensor (llama4 wg = 15.7 GB/device
    # f32 otherwise).
    chunked_update_min_bytes: int = 1 << 30


def _chunk_leafwise(fn, opt: OptConfig, p, *args):
    """Run `fn(p_slice, *arg_slices)` lax.map'd over dim0 for huge leaves."""
    if (p.ndim >= 3 and p.size * 4 >= opt.chunked_update_min_bytes):
        return jax.lax.map(lambda xs: fn(*xs), (p, *args))
    return fn(p, *args)


def schedule(opt: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = opt.lr * step / jnp.maximum(opt.warmup_steps, 1)
    t = jnp.clip((step - opt.warmup_steps)
                 / jnp.maximum(opt.total_steps - opt.warmup_steps, 1), 0, 1)
    cos = opt.lr * (opt.min_lr_frac
                    + (1 - opt.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * t)))
    return jnp.where(step < opt.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


# -- AdamW ----------------------------------------------------------------------
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(opt: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(opt, step)
    b1, b2 = opt.b1, opt.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd_inner(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + opt.eps)
        if p.ndim >= 2:
            u = u + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    def upd(p, g, m, v):
        return _chunk_leafwise(upd_inner, opt, p, g, m, v)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# -- Adafactor --------------------------------------------------------------------
def _factored(shape, min_dim):
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, opt: OptConfig = OptConfig()):
    def one(p):
        if _factored(p.shape, opt.factored_min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"acc": jax.tree.map(one, params,
                                is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(opt: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(opt, step)
    beta2 = 1.0 - step.astype(jnp.float32) ** (-opt.decay_rate)

    def upd_inner(p, g, acc):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if "vr" in acc:
            vr = beta2 * acc["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * acc["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.sqrt(
                vr[..., :, None] * vc[..., None, :]
                / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], 1e-30))
            new_acc = {"vr": vr, "vc": vc}
        else:
            v = beta2 * acc["v"] + (1 - beta2) * g2
            denom = jnp.sqrt(v)
            new_acc = {"v": v}
        u = g / jnp.maximum(denom, 1e-30)
        # update clipping (RMS <= 1) per the Adafactor paper
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            u = u + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_acc

    def upd(p, g, acc):
        if (p.ndim >= 4 and "vr" in acc
                and p.size * 4 >= opt.chunked_update_min_bytes):
            # factored stats factor the *last two* dims; map over dim0 keeps
            # that structure per layer slice.
            return jax.lax.map(lambda xs: upd_inner(*xs), (p, g, acc))
        return upd_inner(p, g, acc)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    accs = state["acc"]
    flat_a = jax.tree.leaves(accs, is_leaf=lambda x: isinstance(x, dict)
                             and ("v" in x or "vr" in x))
    out = [upd(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_a = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_p, {"acc": new_a, "step": step}


def init_fn(name: str):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name]


def update_fn(name: str):
    return {"adamw": adamw_update, "adafactor": adafactor_update}[name]
