"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU backends the same call
compiles to Mosaic. `interpret` is resolved from the default backend unless
forced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as S
from repro.core.bsr import BSR
from repro.kernels import bsr_mxm as _bsr


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def bsr_mxm(A, X: jnp.ndarray, sr: S.Semiring, *,
            mask: jnp.ndarray | None = None, complement: bool = False,
            f_tile: int = _bsr.DEFAULT_F_TILE,
            interpret: bool | None = None) -> jnp.ndarray:
    if not isinstance(A, BSR):            # GBMatrix handle -> raw storage
        A = A.store
    if interpret is None:
        interpret = _interpret_default()
    return _bsr.bsr_mxm(A, X, sr, mask=mask, complement=complement,
                        f_tile=f_tile, interpret=interpret)


def ell_mxv_packed(A, Xw: jnp.ndarray, *,
                   interpret: bool | None = None) -> jnp.ndarray:
    """Packed or_and gather-reduce over uint32 frontier words (see
    kernels/bitmap_mxv.py). Takes an ELL store or a GBMatrix handle; the
    XLA reference is `core.ops.ell_mxm_packed`."""
    from repro.kernels import bitmap_mxv as _bm
    store = getattr(A, "store", A)
    if interpret is None:
        interpret = _interpret_default()
    return _bm.ell_mxv_packed(store, Xw, interpret=interpret)


def bitadj_mxv_packed(A, Xw: jnp.ndarray, *,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Bit-tile or_and matmul over uint32 frontier words (see
    kernels/bitadj_mxv.py). Takes a BitELL store or a GBMatrix handle; the
    XLA reference is `core.bitadj.panels_mxm_words`."""
    from repro.kernels import bitadj_mxv as _ba
    store = getattr(A, "store", A)
    if interpret is None:
        interpret = _interpret_default()
    return _ba.bitadj_mxv_packed(store, Xw, interpret=interpret)


def bsr_ewise(A, B, mode: str, op=None) -> BSR:
    """BSR element-wise family through the Pallas gathered-tile kernel
    (interpret mode off-TPU; the XLA reference is the ``impl="xla"`` default
    on the `core.bsr` functions). ``mode`` is one of
    union | intersect | apply | select | mask | mask_c; the unary modes
    (apply/select) ignore ``B``."""
    from repro.core import bsr as _b
    A = A.store if not isinstance(A, BSR) else A
    if B is not None and not isinstance(B, BSR):
        B = getattr(B, "store", B)
    if mode == "union":
        return _b.ewise_add(A, B, op, impl="pallas")
    if mode == "intersect":
        return _b.ewise_mult(A, B, op, impl="pallas")
    if mode == "apply":
        return _b.apply_stored(A, op, impl="pallas")
    if mode == "select":
        return _b.select_stored(A, op, impl="pallas")
    if mode in ("mask", "mask_c"):
        return _b.mask_keep(A, B, complement=mode == "mask_c", impl="pallas")
    raise ValueError(f"bsr_ewise mode {mode!r}")


def bsr_spgemm(A, B, sr: S.Semiring, *, mask=None, complement: bool = False,
               interpret: bool | None = None) -> BSR:
    """BSR x BSR -> BSR through the Pallas SpGEMM kernel (symbolic phase on
    host, numeric phase on device; interpret mode off-TPU)."""
    from repro.core.bsr import spgemm
    A = A.store if not isinstance(A, BSR) else A
    B = B.store if not isinstance(B, BSR) else B
    if mask is not None and not isinstance(mask, BSR):
        mask = getattr(mask, "store", mask)       # GBMatrix handle -> storage
        if not isinstance(mask, BSR):             # dense array -> structural BSR
            mask = BSR.from_dense(np.asarray(mask), block=A.block)
    if interpret is None:
        interpret = _interpret_default()
    return spgemm(A, B, sr, mask=mask, complement=complement,
                  impl="pallas", interpret=interpret)
