"""Pallas TPU kernel: bit-tile or_and matmul  Yw = BitELL (&|) Xw.

The BitELL analog of `kernels/bitmap_mxv.py`: the adjacency *structure*
itself is packed (32x32 edge tiles in 32 uint32 words, `core.bitadj`), the
frontier is packed (PR 5), so the whole traversal inner loop is word loads
+ bitwise select + OR — no floats, no MXU, 32x less adjacency traffic than
the ELL gather on top of the 32x frontier cut.

Layout / schedule
-----------------
  grid = (P,)                       # one step per 32-row panel
  cols (scalar prefetch, SMEM)      # (P*S,) flattened slot -> column-tile
                                    #   ids; sentinel C points at the
                                    #   appended all-zero query tile
  tiles (P, S*32) uint32 per step   # this panel's bit-tiles, flattened so
                                    #   the panel is one BlockSpec row
  Xw   ((C+1)*32, W) uint32, VMEM   # packed frontier squared up to the
                                    #   column-tile grid + zero sentinel
                                    #   tile; whole-resident (packed = 32x
                                    #   smaller, same budget as bitmap_mxv)
  Yw   (32, W) per step             # the panel's 32 result rows

Per slot the kernel loads one 32-row query tile with a dynamic slice and
one 32-word bit-tile, then spreads each of the 32 bit positions as an
all-ones/all-zeros mask (`0 - bit` on uint32) over the matching query row
— the word-AND + OR the XLA reference (`core.bitadj.panels_mxm_words`)
expresses as a bit-spread einsum. CPU runs interpret mode for conformance;
`grb` dispatch uses the XLA reference off-TPU (resolved by
`kernels.ops.bitadj_mxv_packed`, same pattern as the BSR kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitadj import TILE, BitELL, _pad_query_tiles

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(cols_ref, tiles_ref, x_ref, y_ref, *, slots: int):
    p = pl.program_id(0)
    w = y_ref.shape[1]

    def slot_body(s, acc):
        c = cols_ref[p * slots + s]                  # column-tile id
        xb = x_ref[pl.dslice(c * TILE, TILE), :]     # (32, W) query tile
        tw = tiles_ref[0, pl.dslice(s * TILE, TILE)]  # (32,) panel words

        def bit_body(b, acc):
            # all-ones where bit b is set in each of the 32 row words
            sel = jnp.uint32(0) - jnp.bitwise_and(
                jnp.right_shift(tw, b.astype(jnp.uint32)), jnp.uint32(1))
            xr = jax.lax.dynamic_slice_in_dim(xb, b, 1, axis=0)  # (1, W)
            return jnp.bitwise_or(acc, sel[:, None] & xr)

        return jax.lax.fori_loop(0, TILE, bit_body, acc)

    y_ref[...] = jax.lax.fori_loop(
        0, slots, slot_body, jnp.zeros((TILE, w), dtype=jnp.uint32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitadj_mxv_packed(A: BitELL, Xw: jnp.ndarray, *,
                      interpret: bool = False) -> jnp.ndarray:
    """Yw[i] = OR_{j in adj(i)} Xw[j] over uint32 words, adjacency served
    straight from the bit-tiles. Xw: (k, W) packed frontier. -> (n, W)."""
    n, k = A.shape
    Pn, Sn, _ = A.tiles.shape
    w = Xw.shape[1]
    Xt = _pad_query_tiles(Xw.astype(jnp.uint32), k)   # (C+1, 32, W)

    out = pl.pallas_call(
        functools.partial(_kernel, slots=Sn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Pn,),
            in_specs=[
                pl.BlockSpec((1, Sn * TILE), lambda p, cols: (p, 0)),
                pl.BlockSpec((Xt.shape[0] * TILE, w), lambda p, cols: (0, 0)),
            ],
            out_specs=pl.BlockSpec((TILE, w), lambda p, cols: (p, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Pn * TILE, w), jnp.uint32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(A.cols.reshape(-1).astype(jnp.int32),
      A.tiles.reshape(Pn, Sn * TILE),
      Xt.reshape(-1, w))
    return out[:n]
