"""Pallas TPU kernel: BSR x BSR semiring matmul (SpGEMM numeric phase).

The sparse-output counterpart of `kernels/bsr_mxm.py`: instead of walking a
block-row against a dense frontier, the grid walks the *task list* the
symbolic phase planned (`core.bsr.spgemm_symbolic`) — one task per matching
(A tile (i,l), B tile (l,j)) pair, tasks grouped contiguously by output tile.

Layout / schedule
-----------------
  grid = (ntasks,)                  # sequential; output tile revisited while
  A.blocks[a_sel[t]] : (b, b) tile  # consecutive tasks share c_sel, so the
  B.blocks[b_sel[t]] : (b, b) tile  # accumulator stays resident in VMEM and
  C.blocks[c_sel[t]] : (b, b) tile  # is written back once per output tile
  mask_blocks[c_sel[t]]             # mask tile aligned to the output tile

Scalar prefetch feeds (a_sel, b_sel, c_sel, first, last, valid) to the index
maps: the planned sparsity steers DMA, the body stays a dense (b, b) MXU dot.
The GraphBLAS mask is applied in two places: the symbolic phase already
dropped output tiles outside a non-complemented mask's block pattern, and the
epilogue on the *last* task of each output tile applies the mask's element
pattern (or its complement) inside the surviving tiles — accumulation stays
mask-free, matching GrB_mxm's "mask applied to the result" timing.

Only MXU dot modes are supported (plus_times / plus_pair / or_and /
plus_first); tropical semirings take the dense fallback in `grb.mxm`.

`spgemm_blocks` is the jit'd entry: `impl="xla"` runs the gather +
segment-sum reference (the CPU path), `impl="pallas"` the kernel
(interpret-mode off-TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring as S
from repro.core.bsr import SPGEMM_MODES, SpGEMMPlan

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _tile_product(a: jnp.ndarray, b: jnp.ndarray, sr: S.Semiring) -> jnp.ndarray:
    """One (b, b) x (b, b) semiring tile product on the MXU (f32)."""
    if sr.mode == "dot":
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    if sr.mode in ("dot_indicator", "dot_pair"):
        return jnp.dot((a != 0).astype(jnp.float32),
                       (b != 0).astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    if sr.mode == "dot_first":
        return jnp.dot(a, (b != 0).astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    raise NotImplementedError(sr.mode)


def _kernel(a_sel_ref, b_sel_ref, c_sel_ref, first_ref, last_ref, valid_ref,
            ablk_ref, bblk_ref, mblk_ref, y_ref, *,
            sr: S.Semiring, masked: bool, complement: bool):
    t = pl.program_id(0)
    ident = np.float32(sr.identity)

    @pl.when(first_ref[t] == 1)
    def _init():
        y_ref[...] = jnp.full_like(y_ref, ident)

    @pl.when(valid_ref[t] == 1)
    def _accum():
        a = ablk_ref[0].astype(jnp.float32)
        b = bblk_ref[0].astype(jnp.float32)
        part = _tile_product(a, b, sr)
        if sr.mode == "dot_indicator":
            y_ref[0] = jnp.maximum(y_ref[0], (part > 0).astype(jnp.float32))
        else:
            y_ref[0] = y_ref[0] + part

    if masked:
        @pl.when(last_ref[t] == 1)
        def _epilogue():
            m = mblk_ref[0]
            keep = (m == 0) if complement else (m != 0)
            y_ref[0] = jnp.where(keep, y_ref[0], ident)


@functools.partial(
    jax.jit, static_argnames=("sr", "nc", "block", "masked", "complement",
                              "interpret"))
def _spgemm_pallas(Ab, Bb, Mb, a_sel, b_sel, c_sel, first, last, valid, *,
                   sr: S.Semiring, nc: int, block: int, masked: bool,
                   complement: bool, interpret: bool) -> jnp.ndarray:
    b = block
    grid = (a_sel.shape[0],)
    kernel = functools.partial(_kernel, sr=sr, masked=masked,
                               complement=complement)
    mask_map = ((lambda t, asel, bsel, csel, fst, lst, vld: (csel[t], 0, 0))
                if masked else
                (lambda t, asel, bsel, csel, fst, lst, vld: (0, 0, 0)))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, b, b),
                             lambda t, asel, bsel, csel, fst, lst, vld:
                             (asel[t], 0, 0)),
                pl.BlockSpec((1, b, b),
                             lambda t, asel, bsel, csel, fst, lst, vld:
                             (bsel[t], 0, 0)),
                pl.BlockSpec((1, b, b), mask_map),
            ],
            out_specs=pl.BlockSpec(
                (1, b, b),
                lambda t, asel, bsel, csel, fst, lst, vld: (csel[t], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nc, b, b), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
    )(a_sel, b_sel, c_sel, first, last, valid, Ab, Bb, Mb)


@functools.partial(
    jax.jit, static_argnames=("sr", "nc", "masked", "complement"))
def _spgemm_jnp(Ab, Bb, Mb, a_sel, b_sel, c_sel, valid, *,
                sr: S.Semiring, nc: int, masked: bool,
                complement: bool) -> jnp.ndarray:
    """XLA reference numeric phase: gather task tiles, batched tile products,
    segment-sum into output tiles. The CPU/fallback path."""
    a = Ab.astype(jnp.float32)[a_sel]                  # (T, b, b)
    b = Bb.astype(jnp.float32)[b_sel]
    if sr.mode == "dot":
        contrib = jnp.einsum("tij,tjk->tik", a, b,
                             preferred_element_type=jnp.float32)
    elif sr.mode in ("dot_indicator", "dot_pair"):
        contrib = jnp.einsum("tij,tjk->tik", (a != 0).astype(jnp.float32),
                             (b != 0).astype(jnp.float32),
                             preferred_element_type=jnp.float32)
    elif sr.mode == "dot_first":
        contrib = jnp.einsum("tij,tjk->tik", a,
                             (b != 0).astype(jnp.float32),
                             preferred_element_type=jnp.float32)
    else:
        raise NotImplementedError(sr.mode)
    contrib = contrib * valid.astype(jnp.float32)[:, None, None]
    y = jax.ops.segment_sum(contrib, c_sel, num_segments=nc)
    if sr.mode == "dot_indicator":
        y = (y > 0).astype(jnp.float32)
    if masked:
        keep = (Mb == 0) if complement else (Mb != 0)
        y = jnp.where(keep, y, np.float32(sr.identity))
    return y


def spgemm_blocks(Ablocks: jnp.ndarray, Bblocks: jnp.ndarray,
                  plan: SpGEMMPlan, sr: S.Semiring, *,
                  mask_blocks: Optional[jnp.ndarray] = None,
                  complement: bool = False, impl: str = "xla",
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Run a symbolic plan's numeric phase; returns (nc, b, b) output tiles."""
    assert sr.mode in SPGEMM_MODES, sr.mode
    block = int(Ablocks.shape[1])
    masked = mask_blocks is not None
    sel = dict(a_sel=jnp.asarray(plan.a_sel), b_sel=jnp.asarray(plan.b_sel),
               c_sel=jnp.asarray(plan.c_sel))
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        Mb = (mask_blocks if masked
              else jnp.zeros((1, block, block), jnp.float32))
        return _spgemm_pallas(Ablocks, Bblocks, Mb, sel["a_sel"],
                              sel["b_sel"], sel["c_sel"],
                              jnp.asarray(plan.first), jnp.asarray(plan.last),
                              jnp.asarray(plan.valid), sr=sr, nc=plan.nc,
                              block=block, masked=masked,
                              complement=complement, interpret=interpret)
    # unmasked: the jitted fn never reads Mb (masked is static), so a
    # (1, b, b) dummy avoids materializing an (nc, b, b) zero array
    Mb = mask_blocks if masked else jnp.zeros((1, block, block), jnp.float32)
    return _spgemm_jnp(Ablocks, Bblocks, Mb, sel["a_sel"], sel["b_sel"],
                       sel["c_sel"], jnp.asarray(plan.valid), sr=sr,
                       nc=plan.nc, masked=masked, complement=complement)
