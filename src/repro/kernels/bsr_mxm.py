"""Pallas TPU kernel: block-sparse semiring matmul  Y = A_bsr (x) X  [+ mask].

This is the traversal hot spot of the GraphBLAS engine — the TPU-native
replacement for SuiteSparse's sparse matmul at the heart of RedisGraph.

Layout / schedule
-----------------
  grid = (F_tiles, nnzb)            # nnzb minor => sequential over a row's tiles
  blocks[k]  : (bm, bk) dense tile, streamed HBM->VMEM by BlockSpec
  X[bcol[k]] : (bk, ft) tile of the dense frontier matrix
  Y[brow[k]] : (bm, ft) output tile — revisited while k walks one block-row,
               so the accumulator lives in VMEM (registers of the schedule);
               Pallas only writes it back to HBM when brow changes.

Scalar prefetch (pltpu.PrefetchScalarGridSpec) feeds the tile coordinate
arrays (block_rows / block_cols / first / last / valid) to the index maps —
the sparsity pattern steers DMA, the kernel body stays dense (MXU).

Semiring specialization
-----------------------
  dot            plus_times   : acc += A @ X                     (MXU)
  dot_indicator  or_and       : acc |= (A!=0) @ (X!=0) > 0       (MXU + clamp)
  dot_pair       plus_pair    : acc += (A!=0) @ (X!=0)           (MXU)
  dot_first      plus_first   : acc += A @ (X!=0)                (MXU)
  bcast          min/max_plus : chunked broadcast-reduce         (VPU)

The optional GraphBLAS mask (with complement) is fused into the epilogue on
the *last* tile of each block-row — tiles whose rows are fully masked still
stream (structural zeros), which the block-level `valid` flag short-circuits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semiring as S
from repro.core.bsr import BSR

DEFAULT_F_TILE = 128

# jax renamed TPUCompilerParams -> CompilerParams across releases; resolve
# whichever this jax ships so the kernel builds on both sides of the rename.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(brow_ref, bcol_ref, first_ref, last_ref, valid_ref,  # scalar prefetch
            blocks_ref, x_ref, mask_ref, y_ref, *,
            sr: S.Semiring, masked: bool, complement: bool, bcast_chunk: int):
    k = pl.program_id(1)
    ident = np.float32(sr.identity)

    @pl.when(first_ref[k] == 1)
    def _init():
        y_ref[...] = jnp.full_like(y_ref, ident)

    @pl.when(valid_ref[k] == 1)
    def _accum():
        a = blocks_ref[0].astype(jnp.float32)          # (bm, bk)
        x = x_ref[...].astype(jnp.float32)             # (bk, ft)
        if sr.mode == "dot":
            part = jnp.dot(a, x, preferred_element_type=jnp.float32)
            y_ref[...] = y_ref[...] + part
        elif sr.mode in ("dot_indicator", "dot_pair"):
            part = jnp.dot((a != 0).astype(jnp.float32),
                           (x != 0).astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            if sr.mode == "dot_indicator":
                y_ref[...] = jnp.maximum(y_ref[...], (part > 0).astype(jnp.float32))
            else:
                y_ref[...] = y_ref[...] + part
        elif sr.mode == "dot_first":
            part = jnp.dot(a, (x != 0).astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            y_ref[...] = y_ref[...] + part
        elif sr.mode == "bcast":
            # tropical inner block: chunk rows of A to bound the (rows, bk, ft)
            # broadcast intermediate inside VMEM.
            a_s = jnp.where(a != 0, a, ident)
            bm = a_s.shape[0]
            nchunk = bm // bcast_chunk

            def body(i, _):
                rows = jax.lax.dynamic_slice_in_dim(
                    a_s, i * bcast_chunk, bcast_chunk)               # (ch, bk)
                prod = sr.mul(rows[:, :, None], x[None, :, :])       # (ch, bk, ft)
                part = sr.add.reduce(prod, axis=1)                   # (ch, ft)
                cur = y_ref[pl.dslice(i * bcast_chunk, bcast_chunk), :]
                y_ref[pl.dslice(i * bcast_chunk, bcast_chunk), :] = sr.add.op(cur, part)
                return 0

            jax.lax.fori_loop(0, nchunk, body, 0)
        else:
            raise NotImplementedError(sr.mode)

    if masked:
        @pl.when(last_ref[k] == 1)
        def _epilogue():
            m = mask_ref[...]
            keep = (m == 0) if complement else (m != 0)
            y_ref[...] = jnp.where(keep, y_ref[...], ident)


@functools.partial(
    jax.jit,
    static_argnames=("sr", "f_tile", "complement", "interpret", "bcast_chunk"))
def bsr_mxm(A: BSR, X: jnp.ndarray, sr: S.Semiring, *,
            mask: jnp.ndarray | None = None, complement: bool = False,
            f_tile: int = DEFAULT_F_TILE, bcast_chunk: int = 8,
            interpret: bool = False) -> jnp.ndarray:
    """Y[n,f] = add_j mul(A[n,j], X[j,f]), optionally masked (<mask> / <!mask>)."""
    n, m = A.shape
    b = A.block
    nbr, nbc = A.nbrows, A.nbcols
    f = X.shape[1]
    ft = min(f_tile, max(f, 1))
    f_pad = (-f) % ft

    Xp = jnp.pad(X.astype(jnp.float32), ((0, nbc * b - m), (0, f_pad)))
    fp = Xp.shape[1]
    if mask is not None:
        Mp = jnp.pad(mask.astype(jnp.float32), ((0, nbr * b - n), (0, f_pad)))
    else:
        Mp = jnp.zeros((nbr * b, fp), dtype=jnp.float32)  # unused

    grid = (fp // ft, A.nnzb)

    kernel = functools.partial(
        _kernel, sr=sr, masked=mask is not None, complement=complement,
        bcast_chunk=bcast_chunk)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, b, b),
                             lambda fi, k, br, bc, fst, lst, vld: (k, 0, 0)),
                pl.BlockSpec((b, ft),
                             lambda fi, k, br, bc, fst, lst, vld: (bc[k], fi)),
                pl.BlockSpec((b, ft),
                             lambda fi, k, br, bc, fst, lst, vld: (br[k], fi)),
            ],
            out_specs=pl.BlockSpec(
                (b, ft), lambda fi, k, br, bc, fst, lst, vld: (br[k], fi)),
        ),
        out_shape=jax.ShapeDtypeStruct((nbr * b, fp), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(A.block_rows, A.block_cols, A.first, A.last, A.valid,
      A.blocks, Xp, Mp)
    return out[:n, :f]
