"""Pallas TPU kernel: BSR element-wise numeric phase (gathered tile map).

The element-wise counterpart of `kernels/bsr_spgemm.py`: the host-side
coordinate plan in `core.bsr` (union / intersection / mask alignment of the
valid-tile key lists — the element-wise symbolic phase) produces, per output
tile, a *gather selector* into each operand's tile payload array; this module
runs the numeric phase on device, so the tile values never round-trip through
host numpy the way the pre-kernel implementation did.

Layout / schedule
-----------------
  grid = (T,)                        # one program per output tile; programs
  A.blocks[sel_a[t]] : (b, b) tile   # are independent (no revisit schedule —
  B.blocks[sel_b[t]] : (b, b) tile   # unlike SpGEMM there is no accumulation
  C.blocks[t]        : (b, b) tile   # across tasks)

Scalar prefetch feeds (sel_a, pa, sel_b, pb) to the index maps. A selector
of -1 means "no stored tile on this side" — the host plan clips it to 0 and
zeroes the presence flag (pa/pb), and the kernel multiplies the DMA'd tile
by the flag, so an absent operand tile reads as the all-zero tile the
structural convention demands (stored == nonzero).

Modes (the closure applied per tile pair; zeros stay zeros, so tiles the op
empties are pruned later by ``BSR.from_blocks_device``):
  union      where(both stored, op(a, b), a + b)   — GrB_eWiseAdd
  intersect  where(both stored, op(a, b), 0)       — GrB_eWiseMult
  apply      where(a stored, op(a), 0)             — GrB_apply (unary)
  select     where(a stored and op(a), a, 0)       — GxB_select (unary)
  mask       where(b stored, a, 0)                 — <M> restrict
  mask_c     where(b absent, a, 0)                 — <!M> restrict

`map_tiles` is the jit'd entry: `impl="xla"` runs the batched gather
reference (the CPU path), `impl="pallas"` the kernel (interpret mode
off-TPU). Both produce identical (T, b, b) float32 payloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

EWISE_MODES = ("union", "intersect", "apply", "select", "mask", "mask_c")

# modes whose second operand is never read (the B gather is a dummy)
UNARY_MODES = ("apply", "select")


def _tile_fn(mode: str, op):
    """The per-tile-pair closure; inputs are (b, b) f32, absent == 0."""
    if mode == "union":
        def fn(a, b):
            both = (a != 0) & (b != 0)
            # where only one side stores, the other tile holds 0, so a + b
            # is exactly the stored value there (0 where neither stores)
            return jnp.where(both, op(a, b).astype(jnp.float32), a + b)
    elif mode == "intersect":
        def fn(a, b):
            both = (a != 0) & (b != 0)
            return jnp.where(both, op(a, b).astype(jnp.float32),
                             jnp.float32(0.0))
    elif mode == "apply":
        def fn(a, b):
            del b
            return jnp.where(a != 0, op(a).astype(jnp.float32),
                             jnp.float32(0.0))
    elif mode == "select":
        def fn(a, b):
            del b
            return jnp.where((a != 0) & op(a), a, jnp.float32(0.0))
    elif mode == "mask":
        def fn(a, b):
            return jnp.where(b != 0, a, jnp.float32(0.0))
    elif mode == "mask_c":
        def fn(a, b):
            return jnp.where(b == 0, a, jnp.float32(0.0))
    else:
        raise NotImplementedError(f"bsr_ewise mode {mode!r}")
    return fn


def _kernel(sel_a_ref, pa_ref, sel_b_ref, pb_ref, ablk_ref, bblk_ref,
            y_ref, *, fn, unary: bool):
    t = pl.program_id(0)
    a = ablk_ref[0].astype(jnp.float32) * pa_ref[t].astype(jnp.float32)
    if unary:
        b = a                      # never read by fn; keeps the arity uniform
    else:
        b = bblk_ref[0].astype(jnp.float32) * pb_ref[t].astype(jnp.float32)
    y_ref[0] = fn(a, b)


@functools.partial(
    jax.jit, static_argnames=("mode", "op", "block", "interpret"))
def _ewise_pallas(Ab, Bb, sel_a, pa, sel_b, pb, *, mode: str, op,
                  block: int, interpret: bool) -> jnp.ndarray:
    b = block
    nt = sel_a.shape[0]
    kernel = functools.partial(_kernel, fn=_tile_fn(mode, op),
                               unary=mode in UNARY_MODES)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(nt,),
            in_specs=[
                pl.BlockSpec((1, b, b),
                             lambda t, sa, pa_, sb, pb_: (sa[t], 0, 0)),
                pl.BlockSpec((1, b, b),
                             lambda t, sa, pa_, sb, pb_: (sb[t], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, b, b),
                                   lambda t, sa, pa_, sb, pb_: (t, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nt, b, b), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
    )(sel_a, pa, sel_b, pb, Ab, Bb)


@functools.partial(jax.jit, static_argnames=("mode", "op"))
def _ewise_jnp(Ab, Bb, sel_a, pa, sel_b, pb, *, mode: str, op) -> jnp.ndarray:
    """XLA reference numeric phase: batched gathers + the tile closure."""
    a = Ab.astype(jnp.float32)[sel_a] * pa.astype(jnp.float32)[:, None, None]
    if mode in UNARY_MODES:
        b = a
    else:
        b = (Bb.astype(jnp.float32)[sel_b]
             * pb.astype(jnp.float32)[:, None, None])
    return _tile_fn(mode, op)(a, b)


def map_tiles(Ablocks, sel_a, Bblocks, sel_b, mode: str, op=None, *,
              impl: str = "xla", interpret: bool | None = None):
    """Numeric phase of a BSR element-wise op: (T, b, b) output payloads.

    ``sel_a``/``sel_b`` are host int arrays of length T indexing the operand
    payload arrays; -1 selects the all-zero tile. For unary modes pass
    ``Bblocks=None`` / ``sel_b=None``. Returns device-resident float32 tiles
    aligned with the caller's output coordinate list.
    """
    assert mode in EWISE_MODES, mode
    block = int(Ablocks.shape[1])
    sel_a = np.asarray(sel_a, dtype=np.int32)
    nt = len(sel_a)
    if nt == 0:
        return jnp.zeros((0, block, block), jnp.float32)
    pa = (sel_a >= 0).astype(np.int32)
    sel_a = np.clip(sel_a, 0, None)
    if mode in UNARY_MODES or sel_b is None:
        sel_b = np.zeros(nt, dtype=np.int32)
        pb = np.zeros(nt, dtype=np.int32)
        Bblocks = jnp.zeros((1, block, block), jnp.float32)
    else:
        sel_b = np.asarray(sel_b, dtype=np.int32)
        pb = (sel_b >= 0).astype(np.int32)
        sel_b = np.clip(sel_b, 0, None)
        if Bblocks.shape[0] == 0:
            Bblocks = jnp.zeros((1, block, block), jnp.float32)
    if Ablocks.shape[0] == 0:
        Ablocks = jnp.zeros((1, block, block), jnp.float32)
    args = (jnp.asarray(Ablocks), jnp.asarray(Bblocks),
            jnp.asarray(sel_a), jnp.asarray(pa),
            jnp.asarray(sel_b), jnp.asarray(pb))
    if impl == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _ewise_pallas(*args, mode=mode, op=op, block=block,
                             interpret=interpret)
    return _ewise_jnp(*args, mode=mode, op=op)
