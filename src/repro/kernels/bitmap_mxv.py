"""Pallas TPU kernel: packed or_and gather-reduce  Yw = A_ell (|) Xw.

The inner loop of every structural traversal once the frontier is in
`core.bitmap` packed form: for each vertex row, OR together the uint32
frontier words of its neighbors. One word column carries 32 concurrent
queries, so this is 32 fused boolean mxv's per word — all VPU bitwise ops,
no MXU, and 32x less VMEM traffic than the float indicator route.

Layout / schedule
-----------------
  grid = (n_pad / rows_tile,)       # one step per tile of ELL rows
  idx (scalar prefetch, SMEM)       # flattened sentinel neighbor ids:
                                    #   padded / invalid slots point at the
                                    #   dedicated all-zero row k (the
                                    #   graph2d sentinel trick) — the kernel
                                    #   body has no mask operand at all
  Xw  (k+1, W) uint32, VMEM         # packed frontier + the zero sentinel
                                    #   row; whole-resident (a packed
                                    #   frontier is 32x smaller, so even
                                    #   wide query batches fit)
  Yw  (rows_tile, W) per step       # OR-accumulated in registers, written
                                    #   once per row

The fori over degree slots does one dynamic row slice of Xw per edge — the
gather the XLA reference (`core.ops.ell_mxm_packed`) expresses as a fancy
index. On CPU the kernel runs in interpret mode for conformance only; the
`grb` dispatch uses the XLA reference off-TPU (`kernels.ops.ell_mxv_packed`
resolves this the same way the BSR kernels do).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.ell import ELL

DEFAULT_ROWS_TILE = 8

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(idx_ref, x_ref, y_ref, *, deg: int, rows_tile: int):
    t = pl.program_id(0)
    base = t * rows_tile * deg
    w = y_ref.shape[1]
    for r in range(rows_tile):                 # static unroll, rows_tile small

        def body(s, acc):
            j = idx_ref[base + r * deg + s]    # sentinel -> the zero row
            return jnp.bitwise_or(acc, x_ref[pl.dslice(j, 1), :])

        acc = jax.lax.fori_loop(0, deg, body,
                                jnp.zeros((1, w), dtype=jnp.uint32))
        y_ref[pl.dslice(r, 1), :] = acc


@functools.partial(jax.jit, static_argnames=("rows_tile", "interpret"))
def ell_mxv_packed(A: ELL, Xw: jnp.ndarray, *,
                   rows_tile: int = DEFAULT_ROWS_TILE,
                   interpret: bool = False) -> jnp.ndarray:
    """Yw[i] = OR_{j in adj(i)} Xw[j] over uint32 frontier words.

    A: ELL adjacency (only indices/mask used — or_and is structural).
    Xw: (k, W) packed frontier, k = A.shape[1]. Returns (n, W) uint32.
    """
    n, k = A.shape
    deg = A.max_deg
    w = Xw.shape[1]
    n_pad = n + (-n) % rows_tile

    # sentinel spelling: invalid / padded slots index the appended zero row
    idx = jnp.where(A.mask, A.indices, jnp.int32(k)).astype(jnp.int32)
    idx = jnp.pad(idx, ((0, n_pad - n), (0, 0)), constant_values=k)
    Xe = jnp.concatenate(
        [Xw.astype(jnp.uint32), jnp.zeros((1, w), dtype=jnp.uint32)], axis=0)

    out = pl.pallas_call(
        functools.partial(_kernel, deg=deg, rows_tile=rows_tile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_pad // rows_tile,),
            in_specs=[
                pl.BlockSpec((k + 1, w), lambda t, idx: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rows_tile, w), lambda t, idx: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_pad, w), jnp.uint32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(idx.reshape(-1), Xe)
    return out[:n]
