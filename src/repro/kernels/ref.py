"""Pure-jnp oracle for the Pallas kernels: densify + semiring.dense_mxm."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import semiring as S
from repro.core.bsr import BSR


def bsr_mxm_ref(A: BSR, X: jnp.ndarray, sr: S.Semiring, *,
                mask: jnp.ndarray | None = None,
                complement: bool = False) -> jnp.ndarray:
    D = A.to_dense()
    y = S.dense_mxm(S.structural_dense(D, sr), X, sr)
    if mask is not None:
        keep = (mask == 0) if complement else (mask != 0)
        y = jnp.where(keep, y, np.float32(sr.identity))
    return y
