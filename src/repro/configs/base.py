"""Config system: model configs (one file per assigned arch) + shape grid.

`get_config(name)` resolves `repro.configs.<name_with_underscores>.CONFIG`;
CLI overrides use `--set key=value` (launch/ parses them onto dataclasses).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import List, Optional

ARCHS = [
    "qwen2-1.5b", "qwen2-7b", "gemma-2b", "gemma2-9b", "mixtral-8x7b",
    "llama4-maverick-400b-a17b", "rwkv6-3b", "zamba2-1.2b",
    "whisper-medium", "llava-next-mistral-7b",
]


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                   # dense | moe | rwkv6 | zamba2 | whisper | llava
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention flavor ------------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    mlp: str = "swiglu"           # swiglu | geglu
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    sliding_window: int = 0       # 0 = full attention
    local_global_alternating: bool = False   # gemma2: alternate SWA/global
    embed_scale: bool = False     # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False
    # moe ----------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # ssm / hybrid ---------------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_conv: int = 4
    shared_attn_every: int = 0    # zamba2: shared block period
    # enc-dec / frontends ----------------------------------------------------------
    encoder_layers: int = 0
    n_audio_frames: int = 1500
    n_image_tokens: int = 576
    d_frontend: int = 1024        # stub frontend embedding width
    # training ----------------------------------------------------------------------
    optimizer: str = "adamw"      # adamw | adafactor
    remat: bool = True
    dtype: str = "bfloat16"
    kv_chunk: int = 1024          # flash-attention KV block (0 = single chunk)
    scan_unroll: bool = False     # unroll layer scans (probe/analysis mode)
    microbatches: int = 1         # grad-accumulation microbatches (train)
    grad_accum_dtype: str = "float32"  # bf16 halves the accumulator (T7)
    hoist_weight_gather: bool = False  # §Perf T11: one AG/RS per step
    # which grid shapes this arch skips, with reasons (DESIGN.md §skips)
    skip_shapes: tuple = ()

    @property
    def attn_free(self) -> bool:
        return self.family == "rwkv6"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        o = self.n_heads * self.head_dim * d
        attn = qkv + o
        if self.mlp in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            mlp *= self.n_experts
            mlp += d * self.n_experts      # router
        if self.family == "rwkv6":
            attn = 5 * d * d               # r,k,v,g,o mixes
            mlp = 2 * d * f
        if self.family == "zamba2":
            nd = 2 * d
            attn = (3 * d * nd + nd * d) // max(self.n_layers, 1) * self.n_layers
            attn = 4 * d * d               # in/out proj of mamba block approx
            mlp = 2 * d * f
        per_layer = attn + mlp
        total = self.n_layers * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.family == "whisper":
            total += self.encoder_layers * (4 * d * d + 2 * d * f)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only) for 6·N_active·D."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = 3 * d * f
        full = self.param_count()
        return int(full - self.n_layers * dense_mlp * self.n_experts
                   + self.n_layers * dense_mlp * self.experts_per_token)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return mod.CONFIG


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    return [s for s in SHAPES.values() if s.name not in cfg.skip_shapes]


def apply_overrides(cfg, pairs: List[str]):
    """--set key=value CLI overrides (typed via existing field values)."""
    for p in pairs:
        k, v = p.split("=", 1)
        cur = getattr(cfg, k)
        typ = type(cur)
        if typ is bool:
            val = v.lower() in ("1", "true", "yes")
        elif cur is None:
            val = v
        else:
            val = typ(v)
        object.__setattr__(cfg, k, val) if dataclasses.is_dataclass(cfg) and getattr(cfg, "__dataclass_params__", None) and cfg.__dataclass_params__.frozen else setattr(cfg, k, val)
    return cfg
