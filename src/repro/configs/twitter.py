"""Twitter graph config (paper: 41.6M vertices / 1.47B edges) — the large
dataset of the paper's benchmark, as a distributed ELL dry-run cell."""

GRAPH_CONFIG = dict(
    name="twitter41m",
    n_vertices=41_600_000,
    max_deg=64,                # degree-bucketed ELL stand-in (DESIGN.md GE-3)
    queries=256,
    k=2,
    formats=("khop", "khop_bitmap", "khop_bitmap_sentinel"),
)
