"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Hybrid: long_500k runs (shared-attn KV mesh-sharded)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="zamba2", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab=32000,
    ssm_state=64, ssm_heads=32, shared_attn_every=6,
    microbatches=4,   # §Perf T6: activation working set / 4
)
