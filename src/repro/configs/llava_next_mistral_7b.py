"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres tiling STUB
(input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="llava", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    mlp="swiglu", n_image_tokens=576, d_frontend=1024,
    skip_shapes=("long_500k",),   # backbone treated as full attention (v0.2),
    microbatches=2,   # §Perf T6: activation working set / 2
)
