"""The paper's own workload configs: Graph500 scale-21 (dry-run analog of the
paper's 2.4M-vertex / 67M-edge dataset, padded-deg-64 ELL) — consumed by
launch/dryrun.py GRAPH_CELLS and the benchmarks."""

GRAPH_CONFIG = dict(
    name="graph500_s21",
    n_vertices=2_097_152,      # scale 21
    max_deg=64,                # padded ELL degree (edge factor 16, bucketed)
    queries=256,               # concurrent k-hop queries (threadpool width)
    k=2,
    formats=("khop", "khop_bitmap", "khop_bitmap_sentinel"),
)
