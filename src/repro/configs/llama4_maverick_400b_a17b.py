"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
Adafactor: adam states for ~0.8T params exceed 256x16GB (EXPERIMENTS.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
    mlp="swiglu", n_experts=128, experts_per_token=1,
    optimizer="adafactor",
    skip_shapes=("long_500k",),   # full attention,
    microbatches=8,   # §Perf T6: activation working set / 8
    grad_accum_dtype="bfloat16",  # §Perf T7: f32 accum = 12.4GB/dev at 0.79T
)
