"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]. Constant-state decode: long_500k runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv6", n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, head_dim=64, d_ff=8960, vocab=65536,
    ssm_heads=40,
    microbatches=2,   # §Perf T6: activation working set / 2
)
