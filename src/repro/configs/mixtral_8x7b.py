"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf].
SWA caps the KV cache at the window, so long_500k decode runs."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    mlp="swiglu", n_experts=8, experts_per_token=2, sliding_window=4096,
    microbatches=4,   # §Perf T6: activation working set / 4 -> fits HBM
)
