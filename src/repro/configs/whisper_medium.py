"""whisper-medium [audio] — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="whisper", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51865,
    encoder_layers=24, n_audio_frames=1500, d_frontend=1024, mlp="gelu",
    skip_shapes=("long_500k",),   # enc-dec decoder positions capped by design,
    microbatches=4,   # §Perf T6: activation working set / 4
)
