"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14336, vocab=256000,
    mlp="geglu", embed_scale=True, tie_embeddings=True,
    logit_softcap=30.0, attn_softcap=50.0,
    sliding_window=4096, local_global_alternating=True,
    skip_shapes=("long_500k",),   # global (full-attn) layers every other block,
    microbatches=2,   # §Perf T6: activation working set / 2
)
