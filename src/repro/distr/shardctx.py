"""Logical-axis sharding context (MaxText-style logical axis rules).

Model code annotates activations with *logical* axes ("batch", "seq",
"embed", ...); the active ShardCtx maps them onto mesh axes and applies
with_sharding_constraint. With no context set (unit tests, single-device
smoke runs) every annotation is a no-op, keeping model code mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tuples tried in full, then progressively dropped
# if the dimension size isn't divisible by the axis-group product)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                 # sequence replicated by default; SP opts in
    "seq_shard": "skip",       # §Perf T1: forced q seq-sharding made GSPMD
                               # re-replicate per layer; leave to propagation
    "seq_full": ("pod", "data", "model"),  # long-context decode KV
    "embed": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "head_dim": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "none": (),
}

_CTX: Optional["ShardCtx"] = None


class ShardCtx:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def axes_for(self, logical: Optional[str], dim_size: int):
        if logical is None:
            return None
        group = self.rules.get(logical, ())
        if group == "skip":
            return None
        group = tuple(a for a in group if a in self.mesh.axis_names)
        # drop leading axes until the group divides the dimension
        while group:
            prod = 1
            for a in group:
                prod *= self.mesh.shape[a]
            if prod <= dim_size and dim_size % prod == 0:
                return group if len(group) > 1 else group[0]
            group = group[1:]
        return None

    def pspec(self, shape, *logical) -> P:
        assert len(logical) == len(shape), (shape, logical)
        spec = []
        used = set()
        for l, s in zip(logical, shape):
            axes = self.axes_for(l, s)
            group = axes if isinstance(axes, tuple) else (axes,) if axes else ()
            if any(a in used for a in group):
                axes = None          # a mesh axis shards at most one dim:
                group = ()           # first logical annotation wins
            used.update(group)
            spec.append(axes)
        return P(*spec)

    def constrain(self, x, *logical):
        # rule value "skip": leave the tensor entirely unconstrained (no
        # with_sharding_constraint op at all) — lets GSPMD propagate freely.
        if any(self.rules.get(l) == "skip" for l in logical if l):
            return x
        spec = self.pspec(x.shape, *logical)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def get() -> Optional[ShardCtx]:
    return _CTX


@contextlib.contextmanager
def use(ctx: Optional[ShardCtx]):
    global _CTX
    prev = _CTX
    _CTX = ctx
    try:
        yield ctx
    finally:
        _CTX = prev


def shard(x, *logical):
    """Annotate activation x with logical axes; no-op without a ShardCtx."""
    ctx = get()
    if ctx is None:
        return x
    return ctx.constrain(x, *logical)
