"""Distributed lowerings for the 2D-sharded ELL layout (explicit collectives).

Layout (DESIGN.md §5):
  * adjacency rows (ELL indices/mask)  -> "data" axis (within a pod, the
    graph is row-partitioned; pods replicate the graph),
  * frontier/query columns F           -> ("pod", "model") — queries scale
    out across pods, the paper's threadpool claim at pod scale,
  * between hops, each data-shard owns the new frontier rows it produced;
    an all-gather over "data" rebuilds the full frontier for the next
    gather step (the explicit collective the roofline reads).

Two kinds of exports:

  * **Reusable op lowerings** — :func:`mxm_2d` and :func:`reduce_2d` are the
    shard_map bodies `grb` dispatches to when a GBMatrix holds ShardedELL
    storage (core.shard). Row form: one frontier all-gather over "data" +
    local ELL gather-reduce; with `packed=True` (or_and, set by grb's
    bitmap policy) both sides of the collective carry `core.bitmap` uint32
    words — 32x less wire payload. Transposed form (`A^T (x) x` with no
    stored transpose): local scatter-accumulate + a psum_scatter of row
    blocks (pmin/pmax for the tropical semirings; summable nibble words,
    8x less payload, when packed). Engine / query / algorithm layers never
    call these directly — they go through `grb`.
  * **Dry-run probes** — :func:`khop_counts_2d` (with the bitmap-packed and
    sentinel perf variants, packing via the same public `core.bitmap`
    route the ops use) and :func:`pagerank_2d` keep whole-algorithm
    loops fused in one shard_map so `launch.dryrun` can compile a single
    cell and read its collective bytes off the HLO. They are lowering-
    analysis tools, not an algorithm surface: the engine runs the same
    algorithms through `grb` ops on sharded handles.

Public contract: every callable here is mesh-resident and collective-
explicit — nothing gathers to host (the gather-to-host fallbacks live in
`grb`). Inputs must arrive pre-padded to the mesh (core.shard owns that);
mis-padded `out_rows` or a packed call on a non-indicator semiring raise
ValueError / NotImplementedError at trace time. The packed transposed
form's nibble-lane compression is valid only up to
`bitmap.NIBBLE_MAX_SHARDS` row shards; wider data axes are detected at
build time here and served by an unpacked-psum_scatter body with the same
word-in/word-out signature (see mxm_2d). shard_map
keeps the collectives explicit — `lowered.as_text()` shows exactly one
all-gather per hop plus the final reduce, which is what the payload
regression in tests/test_bitmap.py pins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ops as _core_ops
from repro.core import semiring as S
from repro.core.ell import ELL
# single source of truth for the frontier-axis convention (F over pod x
# model) — shared with the ShardedELL storage this module lowers for
from repro.core.shard import frontier_axes as _frontier_axes
from repro.core.shard import frontier_spec as _fr_spec

# shard_map moved from jax.experimental to jax core (and its replication-check
# kwarg was renamed check_rep -> check_vma); resolve whichever this jax ships.
try:
    _shard_map = jax.shard_map
    _SM_CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


def _smap(body, mesh, in_specs, out_specs):
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SM_CHECK_KW: False})


def ell_shard_inputs(A, sentinel: bool = False):
    """Host (indices, mask) arrays for the row-sharded ELL layout.

    Accepts the `grb` surface's handles — a Relation, a GBMatrix, or raw ELL
    storage. Every kernel in this module pulls (rows of A^T), so a Relation
    resolves to its stored transpose; pass a GBMatrix (`rel.A` / `rel.A_T`)
    explicitly to pick a direction yourself. With sentinel=True, padded
    slots index the dedicated all-zero row (id = shape[1]) instead of
    carrying the mask.
    """
    if hasattr(A, "A") and hasattr(A, "name"):   # Relation -> pull layout
        A = A.A_T
    store = getattr(A, "store", A)               # GBMatrix -> storage
    if not hasattr(store, "indices"):
        raise TypeError(f"2D sharding needs ELL rows, got {type(store).__name__}")
    idx = np.asarray(store.indices)
    msk = np.asarray(store.mask)
    if sentinel:
        idx = np.where(msk, idx, store.shape[1]).astype(np.int32)
    return idx, msk


# ---------------------------------------------------------------------------
# reusable op lowerings — what grb dispatches sharded GBMatrix ops to
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def mxm_2d(mesh: Mesh, sr: S.Semiring, transposed: bool = False,
           out_rows: int = 0, packed: bool = False):
    """One semiring matmul over the mesh: (idx, msk, val, x) -> y.

    Row form (transposed=False): y = A (x) x. idx/msk/val are A's row-padded
    ELL arrays "data"-sharded; x is the (col_pad, F_pad) frontier, rows over
    "data", F over pod x model. One all-gather of x over "data", then each
    shard runs the local ELL gather-reduce (core.ops.ell_mxm) on its rows.

    Transposed form (transposed=True): y = A^T (x) x *without a stored
    transpose* — x rides A's row shards, each shard scatter-accumulates its
    edges' contributions over all `out_rows` output rows (A's column count,
    row-padded), and a psum_scatter over "data" hands every shard its own
    output row block (pmin/pmax + local slice for the tropical add monoids,
    which have no scatter-reduce collective).

    packed=True (or_and only — `core.shard.mxm` sets it from grb's bitmap
    policy): x and y are core.bitmap uint32 word arrays, (rows, W) with W
    sharded where F was. Row form all-gathers the *words* — 32x less wire
    payload per hop — and ORs them through the packed gather-reduce.
    Transposed form still sums: the local partial bits are re-packed into
    summable nibble words (8 lanes/word, 4 bits each) so one psum_scatter
    carries an 8x-smaller payload without bit carries. Nibble lanes
    saturate at 15, so with more than `bitmap.NIBBLE_MAX_SHARDS` row
    shards a 16th shard's contribution would carry into the next lane —
    detected here at build time and served by the unpacked psum_scatter
    body instead (full float partials on the wire, identical word-in/
    word-out signature, bit-identical results).

    The jitted callable is lru-cached per (mesh, semiring, direction,
    packing) — repeated hops recompile only on new operand shapes.
    """
    fr = _fr_spec(mesh)
    dsz = mesh.shape["data"]
    if packed and sr.mode != "dot_indicator":
        raise NotImplementedError(
            f"packed mxm_2d is or_and/any_pair only (mode dot_indicator); "
            f"got {sr.mode}")

    if not transposed and packed:
        def body(idx_l, msk_l, val_l, xw_l):
            xw = jax.lax.all_gather(xw_l, "data", axis=0, tiled=True)
            local = ELL(shape=(idx_l.shape[0], xw.shape[0]), indices=idx_l,
                        mask=msk_l, values=val_l, nnz=0)
            return _core_ops.ell_mxm_packed(local, xw)
    elif not transposed:
        def body(idx_l, msk_l, val_l, x_l):
            x = jax.lax.all_gather(x_l, "data", axis=0, tiled=True)
            local = ELL(shape=(idx_l.shape[0], x.shape[0]), indices=idx_l,
                        mask=msk_l, values=val_l, nnz=0)
            return _core_ops.ell_mxm(local, x, sr)
    elif packed:
        from repro.core import bitmap
        if out_rows <= 0 or out_rows % dsz:
            raise ValueError(f"transposed mxm_2d needs out_rows padded to "
                             f"the data axis ({dsz}); got {out_rows}")
        # Nibble lanes sum carry-free only while every shard contributes at
        # most 1 to a 4-bit lane: dsz shards can reach dsz <= 15. Past
        # NIBBLE_MAX_SHARDS the compression is wrong, not just slow —
        # detect at build time (dsz is mesh geometry, static) and keep the
        # word-in/word-out contract via full float partials on the wire.
        nibble_ok = dsz <= bitmap.NIBBLE_MAX_SHARDS

        def body(idx_l, msk_l, val_l, xw_l):
            # edge (i -> j) at local row i ORs x's words at row i into
            # output row j. The cross-shard combine has to ride an add
            # collective, so: expand local words -> per-bit partial counts
            # -> saturate to bits -> nibble-pack -> psum_scatter -> saturate.
            fl = xw_l.shape[1] * bitmap.WORD_BITS
            bits = bitmap.unpack(xw_l, fl)             # (rows_l, fl)
            term = jnp.where(msk_l[:, :, None], bits[:, None, :], 0.0)
            ids = jnp.where(msk_l, idx_l, out_rows).reshape(-1)
            part = jax.ops.segment_sum(term.reshape(-1, fl), ids,
                                       num_segments=out_rows + 1)[:out_rows]
            if nibble_ok:
                nib = bitmap.pack_nibbles(part > 0)    # (out_rows, fl/8)
                tot = jax.lax.psum_scatter(nib, "data", scatter_dimension=0,
                                           tiled=True)
                own = bitmap.unpack_nibbles(tot, fl)   # (out_rows/dsz, fl)
            else:
                # unpacked psum_scatter fallback: float partial counts on
                # the wire (no lane limit), saturate to bits after
                own = jax.lax.psum_scatter(part, "data",
                                           scatter_dimension=0, tiled=True)
                own = (own > 0).astype(jnp.float32)
            return bitmap.pack(own)
    else:
        if out_rows <= 0 or out_rows % dsz:
            raise ValueError(f"transposed mxm_2d needs out_rows padded to "
                             f"the data axis ({dsz}); got {out_rows}")

        def body(idx_l, msk_l, val_l, x_l):
            # edge (i -> j) stored at local row i contributes mul(w_ij, x_i)
            # to output row j; segment-accumulate locally over all out_rows,
            # then combine across shards.
            w = val_l[:, :, None]
            m = msk_l[:, :, None]
            xg = x_l[:, None, :]                       # (rows_l, 1, F_l)
            ident = np.float32(sr.identity)
            if sr.mode == "dot":
                term = jnp.where(m, w * xg, 0.0)
            elif sr.mode in ("dot_indicator", "dot_pair"):
                term = jnp.where(m & (xg != 0), 1.0, 0.0)
            elif sr.mode == "dot_first":
                term = jnp.where(m & (xg != 0), w, 0.0)
            elif sr.mode == "bcast":
                term = jnp.where(m, sr.mul(w, xg), ident)
            else:
                raise NotImplementedError(sr.mode)
            flat = term.reshape(-1, term.shape[-1])
            ids = jnp.where(msk_l, idx_l, out_rows).reshape(-1)
            if sr.mode == "bcast":                     # min/max add monoid
                seg = (jax.ops.segment_min if sr.add.name == "min"
                       else jax.ops.segment_max)
                part = seg(flat, ids, num_segments=out_rows + 1)[:out_rows]
                full = (jax.lax.pmin if sr.add.name == "min"
                        else jax.lax.pmax)(part, "data")
                k = jax.lax.axis_index("data")
                return jax.lax.dynamic_slice_in_dim(
                    full, k * (out_rows // dsz), out_rows // dsz)
            part = jax.ops.segment_sum(flat, ids,
                                       num_segments=out_rows + 1)[:out_rows]
            y = jax.lax.psum_scatter(part, "data", scatter_dimension=0,
                                     tiled=True)
            if sr.mode == "dot_indicator":
                y = (y > 0).astype(jnp.float32)
            return y

    return jax.jit(_smap(
        body, mesh,
        in_specs=(P("data", None),) * 3 + (P("data", fr),),
        out_specs=P("data", fr)))


@functools.lru_cache(maxsize=None)
def bit_mxm_2d(mesh: Mesh, slots: int, k: int):
    """or_and matmul on ShardedBitELL panels: (tiles, cols, xw) -> yw.

    The fully bit-level row form — both the *adjacency* (core.bitadj
    32x32-edge uint32 tiles, panels "data"-sharded) and the *frontier*
    (core.bitmap words, rows over "data", words over pod x model) are
    packed, so the per-hop all-gather over "data" carries uint32 frontier
    words (32x less wire than the float route — the >= 8x all-gather
    payload cut tests/test_bitadj.py pins off the HLO) and the local
    gather-reduce is `core.bitadj.panels_mxm_words`: word-AND + OR, zero
    float intermediates. `k` is A's logical column count (frontier rows;
    gathered padding rows beyond the column-tile grid are zero and
    sliced off by the query-tile squaring). Output is (p_pad*32, W) words,
    rows "data"-sharded; `core.shard`-side padding rows are all-sentinel
    panels and render zero. lru-cached per (mesh, slot width, k) like
    every lowering factory here.
    """
    from repro.core import bitadj
    fr = _fr_spec(mesh)

    def body(tiles_l, cols_l, xw_l):
        xw = jax.lax.all_gather(xw_l, "data", axis=0, tiled=True)
        return bitadj.panels_mxm_words(tiles_l, cols_l, xw, k)

    del slots      # cache key only: slot width changes the traced shapes
    return jax.jit(_smap(
        body, mesh,
        in_specs=(P("data", None, None), P("data", None), P("data", fr)),
        out_specs=P("data", fr)))


@functools.lru_cache(maxsize=None)
def reduce_2d(mesh: Mesh, monoid_name: str, axis, ncols: int):
    """Stored-entry plus/or reduction over the mesh: (idx, msk, val) -> out.

    axis=1 (per row) is collective-free — rows live whole on one shard; the
    full (axis=None) and per-column (axis=0) reductions psum partials over
    "data" and return a replicated result. "or" reduces indicator counts and
    renders any-stored (> 0), matching grb.reduce's sparse contract.
    """
    if monoid_name not in ("plus", "or"):
        raise NotImplementedError(monoid_name)

    def body(idx_l, msk_l, val_l):
        w = val_l * msk_l.astype(jnp.float32)
        if monoid_name == "or":
            w = (w != 0).astype(jnp.float32)
        if axis == 1:
            out = jnp.sum(w, axis=1)
        elif axis is None:
            out = jax.lax.psum(jnp.sum(w), "data")
        else:                                          # axis == 0
            ids = jnp.where(msk_l, idx_l, ncols).reshape(-1)
            part = jax.ops.segment_sum(w.reshape(-1), ids,
                                       num_segments=ncols + 1)[:ncols]
            out = jax.lax.psum(part, "data")
        if monoid_name == "or":
            out = (out > 0).astype(jnp.float32)
        return out

    return jax.jit(_smap(body, mesh, in_specs=(P("data", None),) * 3,
                         out_specs=P("data") if axis == 1 else P()))


# ---------------------------------------------------------------------------
# shard-local element-wise lowerings — the slot-aligned COO set algebra grb's
# sharded ewise/assign/extract dispatch to (no collectives: rows live whole
# on one shard, so union/intersect/mask surgery is embarrassingly row-local)
# ---------------------------------------------------------------------------
# sentinel sort key for invalid slots; real keys are col*2 + source, so this
# is unreachable for any column count below ~2^30 (document, don't check:
# the int32 ELL index arrays cap columns well before that).
_MERGE_SENT = np.int32(np.iinfo(np.int32).max)


def _ewise_merge(ia, ma, va, ib, mb, vb, mode, op):
    """Row-local merge of two ELL row blocks into one (idx, mask, val) block.

    The *slot-alignment pass*: concatenate the two slot layouts (static width
    wa+wb), sort each row by (column, source) — source breaks ties so an A
    entry always immediately precedes its B partner at the same column — and
    pair adjacent equal columns. Each side stores at most one entry per
    (row, col) (the ELL invariant), so runs of equal columns have length <= 2
    and one shifted compare finds every pair.

    mode: "union"     op(a,b) where both, pass-through singletons (eWiseAdd)
          "intersect" op(a,b) where both, singletons dropped     (eWiseMult)
          "mask"      A entries where B stored (mask restrict)
          "mask_c"    A entries where B absent (complemented restrict)

    Zero results are dropped (stored == nonzero, the repo-wide convention).
    Pure row-local jnp — callers run it under shard_map (ewise_2d) or on
    plain host arrays (the differential oracle in tests does exactly that).
    """
    rows, wa = ia.shape
    col = jnp.concatenate([ia, ib], axis=1).astype(jnp.int32)
    src = jnp.concatenate(
        [jnp.zeros((rows, wa), jnp.int32),
         jnp.ones((rows, ib.shape[1]), jnp.int32)], axis=1)
    valid_in = jnp.concatenate([ma, mb], axis=1)
    val = jnp.concatenate([va, vb], axis=1).astype(jnp.float32)
    key = jnp.where(valid_in, col * 2 + src, _MERGE_SENT)
    key, col, src, val = jax.lax.sort((key, col, src, val),
                                      dimension=1, num_keys=1)
    valid = key != _MERGE_SENT
    same = valid[:, :-1] & valid[:, 1:] & (col[:, :-1] == col[:, 1:])
    pair_first = jnp.pad(same, ((0, 0), (0, 1)))     # slot i pairs with i+1
    pair_second = jnp.pad(same, ((0, 0), (1, 0)))
    val_nxt = jnp.pad(val[:, 1:], ((0, 0), (0, 1)))
    if mode == "union":
        out_val = jnp.where(pair_first, op(val, val_nxt), val)
        out_ok = valid & ~pair_second
    elif mode == "intersect":
        out_val = op(val, val_nxt)
        out_ok = pair_first
    elif mode == "mask":
        out_val = val
        out_ok = pair_first                           # slot i is the A entry
    elif mode == "mask_c":
        out_val = val
        out_ok = valid & (src == 0) & ~pair_first
    else:
        raise ValueError(f"unknown merge mode {mode!r}")
    out_ok = out_ok & (out_val != 0)
    return (jnp.where(out_ok, col, 0),
            out_ok,
            jnp.where(out_ok, out_val, 0.0))


@functools.lru_cache(maxsize=None)
def ewise_2d(mesh: Mesh, mode: str, op):
    """Shard-local element-wise merge over the mesh:
    (ia, ma, va, ib, mb, vb) -> (idx, mask, val), all (n_pad, w) row blocks
    "data"-sharded. No collectives — the shard_map is here so the lowering
    is structurally mesh-resident (scan_host_transfers proves it empty).

    lru-cached per (mesh, mode, op); monoid ops are module-level singletons
    so algorithm loops hit the cache, ad-hoc lambdas retrace per identity.
    """
    def body(ia, ma, va, ib, mb, vb):
        return _ewise_merge(ia, ma, va, ib, mb, vb, mode, op)

    return jax.jit(_smap(body, mesh, in_specs=(P("data", None),) * 6,
                         out_specs=(P("data", None),) * 3))


@functools.lru_cache(maxsize=None)
def restrict_dense_2d(mesh: Mesh, complement: bool):
    """Keep stored entries where a *dense* (n_pad, m) mask row block is
    nonzero (or zero, complemented) — one shard-local take_along_axis, the
    dense-mask side of the descriptor blend."""
    def body(idx_l, msk_l, val_l, dm_l):
        keep = jnp.take_along_axis(dm_l != 0, idx_l, axis=1)
        if complement:
            keep = ~keep
        m = msk_l & keep
        return (jnp.where(m, idx_l, 0), m,
                jnp.where(m, val_l, 0.0))

    return jax.jit(_smap(body, mesh,
                         in_specs=(P("data", None),) * 4,
                         out_specs=(P("data", None),) * 3))


@functools.lru_cache(maxsize=None)
def extract_cols_2d(mesh: Mesh):
    """Column-subset extract: relabel stored columns through a replicated
    (m,) LUT (new column id, or -1 to drop). Row-local — extracting columns
    never crosses row shards; row subsets do, and stay on the counted
    gather fallback in grb."""
    def body(idx_l, msk_l, val_l, lut):
        nc = lut[idx_l]
        m = msk_l & (nc >= 0)
        return (jnp.where(m, nc, 0).astype(jnp.int32), m,
                jnp.where(m, val_l, 0.0))

    return jax.jit(_smap(body, mesh,
                         in_specs=(P("data", None),) * 3 + (P(None),),
                         out_specs=(P("data", None),) * 3))


@functools.lru_cache(maxsize=None)
def reduce_minmax_2d(mesh: Mesh, monoid_name: str, axis, nrows: int,
                     ncols: int):
    """min/max reduction with *dense* semantics on the mesh: absent entries
    render as 0 and participate (grb.reduce's contract for non-plus/or
    monoids). Stored entries reduce under a +/-inf identity; one stored-count
    compare folds the implicit zeros back in. axis=1 is collective-free;
    axis=0/None combine shards with pmin/pmax + a psum of stored counts.

    nrows/ncols are the *logical* shape — padded rows are all mask-false and
    only ever contribute the identity."""
    if monoid_name not in ("min", "max"):
        raise NotImplementedError(monoid_name)
    big = np.float32(np.inf if monoid_name == "min" else -np.inf)
    comb = jnp.minimum if monoid_name == "min" else jnp.maximum
    seg = (jax.ops.segment_min if monoid_name == "min"
           else jax.ops.segment_max)
    pcomb = jax.lax.pmin if monoid_name == "min" else jax.lax.pmax

    def body(idx_l, msk_l, val_l):
        w = jnp.where(msk_l, val_l, big)
        if axis == 1:
            stored = (jnp.min if monoid_name == "min" else jnp.max)(w, axis=1)
            absent = jnp.sum(msk_l, axis=1) < ncols
            return jnp.where(absent, comb(stored, 0.0), stored)
        if axis is None:
            stored = pcomb(
                (jnp.min if monoid_name == "min" else jnp.max)(w), "data")
            total = jax.lax.psum(jnp.sum(msk_l.astype(jnp.int32)), "data")
            return jnp.where(total < nrows * ncols, comb(stored, 0.0), stored)
        ids = jnp.where(msk_l, idx_l, ncols).reshape(-1)
        part = seg(w.reshape(-1), ids, num_segments=ncols + 1)[:ncols]
        stored = pcomb(part, "data")
        cnt = jax.lax.psum(
            jax.ops.segment_sum(msk_l.astype(jnp.int32).reshape(-1), ids,
                                num_segments=ncols + 1)[:ncols], "data")
        return jnp.where(cnt < nrows, comb(stored, 0.0), stored)

    return jax.jit(_smap(body, mesh, in_specs=(P("data", None),) * 3,
                         out_specs=P("data") if axis == 1 else P()))


# ---------------------------------------------------------------------------
# transfer-count inspection — the HLO side of the host_transfers() regression
# ---------------------------------------------------------------------------
# Lowered-text markers that indicate a device->host hop. Pure mesh-resident
# programs (every lowering above) contain none of them.
_TRANSFER_TOKENS = ("infeed", "outfeed", "is_host_transfer=true",
                    "cpu_callback", "host_callback",
                    "annotate_device_placement")


def scan_host_transfers(fn, *args, **kwargs):
    """Lower ``fn(*args, **kwargs)`` and return every StableHLO/HLO line that
    marks a device->host transfer (infeed/outfeed/host callbacks/placement
    annotations). An empty list certifies the traced program is
    device-resident end to end — the structural half of the
    ``grb.host_transfers()`` regression (the counter pins the Python-level
    gathers the tracer can't see)."""
    lowered = jax.jit(fn).lower(*args, **kwargs)
    texts = [lowered.as_text()]
    try:
        texts.append(lowered.compile().as_text())
    except Exception:            # pragma: no cover - backend-dependent
        pass
    hits = []
    for txt in texts:
        for ln in txt.splitlines():
            low = ln.lower()
            if any(tok in low for tok in _TRANSFER_TOKENS):
                hits.append(ln.strip())
    return hits


# ---------------------------------------------------------------------------
# dry-run probes — fused whole-algorithm loops for lowering/roofline analysis
# ---------------------------------------------------------------------------
def khop_counts_2d(mesh: Mesh, n: int, k: int, packed: bool = False,
                   sentinel: bool = False):
    """Returns a function (indices, mask, frontier0) -> counts (F,).

    indices/mask: (N, max_deg) ELL rows (row-sharded over "data");
    frontier0:    (N, F) one-hot seeds (int8; F sharded over pod+model).

    Dry-run probe: `launch.dryrun` compiles this fused k-hop cell to read
    collective bytes / roofline terms off one HLO module. The engine runs
    k-hop through `grb.mxm` on a sharded handle instead (same collectives,
    one shard_map per hop).

    packed=True — GraphBLAS *bitmap format* on the query axis via the public
    packed-frontier route (`core.bitmap`, 32 queries per uint32 word): the
    or_and semiring over {0,1} is bitwise, so the per-hop frontier
    all-gather and the neighbor gathers move 32x fewer bytes (§Perf GE-1).
    This is the same word layout `grb.mxm` uses automatically for wide
    or_and frontiers; the probe only exists to keep the whole loop in one
    shard_map for HLO collective accounting.

    sentinel=True — padded slots point at a dedicated all-zero row (index n)
    instead of carrying a validity mask: the mask array and its `where` op
    disappear from the hop loop (§Perf GE-2). The mask input is ignored.
    """
    fr_axes = _frontier_axes(mesh)

    from repro.core import bitmap

    def body(idx_l, msk_l, seed_l):
        # seed_l: (N/data, F_l) this shard's rows of the one-hot frontier
        if packed:
            frontier = bitmap.pack(seed_l)    # (rows, ceil(F_l/32)) uint32
        else:
            frontier = seed_l
        visited = frontier

        for _ in range(k):
            x_full = jax.lax.all_gather(frontier, "data", axis=0, tiled=True)
            if sentinel:
                # padded slots index row n: append one zero row, skip masking
                x_full = jnp.concatenate(
                    [x_full, jnp.zeros((1,) + x_full.shape[1:], x_full.dtype)],
                    axis=0)
            gathered = x_full[idx_l]                      # (rows, deg, F')
            if packed:
                if not sentinel:
                    gathered = jnp.where(msk_l[..., None], gathered,
                                         jnp.uint32(0))
                nxt = jax.lax.reduce(
                    gathered, jnp.uint32(0), jax.lax.bitwise_or, (1,))
                nxt = bitmap.word_andnot(nxt, visited)
                visited = bitmap.word_or(visited, nxt)
            else:
                if not sentinel:
                    gathered = jnp.where(msk_l[..., None], gathered, 0)
                nxt = gathered.max(axis=1)
                nxt = jnp.where(visited > 0, 0, nxt).astype(jnp.int8)
                visited = jnp.maximum(visited, nxt)
            frontier = nxt

        if packed:
            # unpack once at the end: reached count per query column
            count = bitmap.reduce_or_columns(
                visited, seed_l.shape[1]).astype(jnp.int32)
        else:
            count = visited.astype(jnp.int32).sum(axis=0)
        # rows are sharded over "data": total count sums across row shards
        count = jax.lax.psum(count, "data") - 1           # exclude the seed
        return count

    fr_spec = P("data", fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None))
    out_spec = P(fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None))
    return _smap(body, mesh,
                 in_specs=(P("data", None), P("data", None), fr_spec),
                 out_specs=out_spec)


def pagerank_2d(mesh: Mesh, n: int, iters: int, alpha: float = 0.85,
                push_dtype=None):
    """Dry-run probe: fused distributed PageRank (plus_times) on the
    row-sharded layout — per iteration one frontier all-gather over "data" +
    local gather-reduce + dangling-mass psum. Returns fn(indices, mask,
    out_deg); input geometry comes from :func:`pagerank_specs_2d`.

    The engine runs PageRank through `grb.mxv` on a sharded handle instead;
    this probe keeps the whole loop in one shard_map so dryrun reads its
    collective bytes off one HLO module.

    indices/mask: (N, max_deg) rows of A^T (in-neighbors), "data"-sharded;
    out_deg: (N,) f32, "data"-sharded. Result: ranks (N,) "data"-sharded.

    push_dtype=bf16 (§Perf GE-4): the all-gathered push vector is the
    collective payload; ranks sum in f32 locally, so bf16 on the wire halves
    collective bytes at ~3 decimal digits of rank precision.
    """

    def body(idx_l, msk_l, deg_l):
        rows = idx_l.shape[0]
        r_l = jnp.full((rows,), 1.0 / n, jnp.float32)
        inv_deg_l = jnp.where(deg_l > 0, 1.0 / jnp.maximum(deg_l, 1e-30), 0.0)
        dangling_l = deg_l == 0

        for _ in range(iters):
            push_l = r_l * inv_deg_l
            if push_dtype is not None:
                push_l = push_l.astype(push_dtype)
            push = jax.lax.all_gather(push_l, "data", axis=0, tiled=True)
            # convert only inside the reduce (f32 accumulator): converting
            # the gathered values eagerly makes XLA hoist the f32 cast above
            # the all-gather, silently doubling the wire bytes (§Perf GE-4).
            gathered = jnp.where(msk_l, push[idx_l],
                                 jnp.zeros((), push.dtype))
            pulled_l = jnp.sum(gathered, axis=1, dtype=jnp.float32)
            dmass = jax.lax.psum(
                jnp.sum(jnp.where(dangling_l, r_l, 0.0)), "data") / n
            r_l = (1.0 - alpha) / n + alpha * (pulled_l + dmass)
        return r_l

    return _smap(body, mesh,
                 in_specs=(P("data", None), P("data", None), P("data")),
                 out_specs=P("data"))


def pagerank_specs_2d(mesh: Mesh, n: int, max_deg: int):
    """Transpose-aware input geometry for the pagerank probe: (specs,
    shardings). The ELL arrays are rows of **A^T** (the pull direction —
    in-neighbors at each output row), "data"-sharded like every row layout
    here; out-degree rides the same row shards."""
    specs = (jax.ShapeDtypeStruct((n, max_deg), jnp.int32),
             jax.ShapeDtypeStruct((n, max_deg), jnp.bool_),
             jax.ShapeDtypeStruct((n,), jnp.float32))
    shards = (NamedSharding(mesh, P("data", None)),
              NamedSharding(mesh, P("data", None)),
              NamedSharding(mesh, P("data")))
    return specs, shards


def input_specs_2d(n: int, max_deg: int, f: int):
    """ShapeDtypeStruct stand-ins for the distributed k-hop dry-run."""
    return (jax.ShapeDtypeStruct((n, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((n, max_deg), jnp.bool_),
            jax.ShapeDtypeStruct((n, f), jnp.int8))


def shardings_2d(mesh: Mesh, n: int, max_deg: int, f: int):
    fr_axes = _frontier_axes(mesh)
    fr = fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None)
    return (NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P("data", fr)))
