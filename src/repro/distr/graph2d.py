"""Distributed graph traversal: 2D-sharded ELL k-hop over the mesh.

Layout (DESIGN.md §5):
  * adjacency rows (ELL indices/mask)  -> "data" axis (within a pod, the
    graph is row-partitioned; pods replicate the graph),
  * frontier/query columns F           -> ("pod", "model") — queries scale
    out across pods, the paper's threadpool claim at pod scale,
  * between hops, each data-shard owns the new frontier rows it produced;
    an all-gather over "data" rebuilds the full frontier for the next
    gather step (the explicit collective the roofline reads).

shard_map keeps the collectives explicit — `lowered.as_text()` shows exactly
one all-gather per hop plus the final reduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved from jax.experimental to jax core (and its replication-check
# kwarg was renamed check_rep -> check_vma); resolve whichever this jax ships.
try:
    _shard_map = jax.shard_map
    _SM_CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


def _smap(body, mesh, in_specs, out_specs):
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SM_CHECK_KW: False})


def ell_shard_inputs(A, sentinel: bool = False):
    """Host (indices, mask) arrays for the row-sharded ELL layout.

    Accepts the `grb` surface's handles — a Relation, a GBMatrix, or raw ELL
    storage. Every kernel in this module pulls (rows of A^T), so a Relation
    resolves to its stored transpose; pass a GBMatrix (`rel.A` / `rel.A_T`)
    explicitly to pick a direction yourself. With sentinel=True, padded
    slots index the dedicated all-zero row (id = shape[1]) instead of
    carrying the mask.
    """
    if hasattr(A, "A") and hasattr(A, "name"):   # Relation -> pull layout
        A = A.A_T
    store = getattr(A, "store", A)               # GBMatrix -> storage
    if not hasattr(store, "indices"):
        raise TypeError(f"2D sharding needs ELL rows, got {type(store).__name__}")
    idx = np.asarray(store.indices)
    msk = np.asarray(store.mask)
    if sentinel:
        idx = np.where(msk, idx, store.shape[1]).astype(np.int32)
    return idx, msk


def khop_counts_2d(mesh: Mesh, n: int, k: int, packed: bool = False,
                   sentinel: bool = False):
    """Returns a function (indices, mask, frontier0) -> counts (F,).

    indices/mask: (N, max_deg) ELL rows (row-sharded over "data");
    frontier0:    (N, F) one-hot seeds (int8; F sharded over pod+model).

    packed=True — GraphBLAS *bitmap format* on the query axis: 8 queries per
    byte. The or_and semiring over {0,1} is bitwise, so the per-hop frontier
    all-gather and the neighbor gathers move 8x fewer bytes (§Perf GE-1).

    sentinel=True — padded slots point at a dedicated all-zero row (index n)
    instead of carrying a validity mask: the mask array and its `where` op
    disappear from the hop loop (§Perf GE-2). The mask input is ignored.
    """
    fr_axes = tuple(a for a in ("pod", "model") if a in mesh.axis_names)

    def body(idx_l, msk_l, seed_l):
        # seed_l: (N/data, F_l) this shard's rows of the one-hot frontier
        if packed:
            # pack query bits: (rows, F_l) int8 -> (rows, ceil(F_l/8)) uint8
            rows, fl = seed_l.shape
            pad = (-fl) % 8
            bits = jnp.pad(seed_l, ((0, 0), (0, pad)))
            bits = bits.reshape(rows, (fl + pad) // 8, 8).astype(jnp.uint8)
            weights = (1 << jnp.arange(8, dtype=jnp.uint8))
            frontier = (bits * weights).sum(axis=-1).astype(jnp.uint8)
        else:
            frontier = seed_l
        visited = frontier

        for _ in range(k):
            x_full = jax.lax.all_gather(frontier, "data", axis=0, tiled=True)
            if sentinel:
                # padded slots index row n: append one zero row, skip masking
                x_full = jnp.concatenate(
                    [x_full, jnp.zeros((1,) + x_full.shape[1:], x_full.dtype)],
                    axis=0)
            gathered = x_full[idx_l]                      # (rows, deg, F')
            if packed:
                if not sentinel:
                    gathered = jnp.where(msk_l[..., None], gathered,
                                         jnp.uint8(0))
                nxt = jax.lax.reduce(
                    gathered, jnp.uint8(0), jax.lax.bitwise_or, (1,))
                nxt = jnp.bitwise_and(nxt, jnp.bitwise_not(visited))
                visited = jnp.bitwise_or(visited, nxt)
            else:
                if not sentinel:
                    gathered = jnp.where(msk_l[..., None], gathered, 0)
                nxt = gathered.max(axis=1)
                nxt = jnp.where(visited > 0, 0, nxt).astype(jnp.int8)
                visited = jnp.maximum(visited, nxt)
            frontier = nxt

        if packed:
            # unpack once at the end: count_j = popcount(visited bit j) - seed
            shifts = jnp.arange(8, dtype=jnp.uint8)
            per_bit = (visited[:, :, None] >> shifts) & jnp.uint8(1)
            count = per_bit.astype(jnp.int32).sum(axis=0).reshape(-1)
            count = count[: seed_l.shape[1]]              # drop bit padding
        else:
            count = visited.astype(jnp.int32).sum(axis=0)
        # rows are sharded over "data": total count sums across row shards
        count = jax.lax.psum(count, "data") - 1           # exclude the seed
        return count

    fr_spec = P("data", fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None))
    out_spec = P(fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None))
    return _smap(body, mesh,
                 in_specs=(P("data", None), P("data", None), fr_spec),
                 out_specs=out_spec)


def pagerank_2d(mesh: Mesh, n: int, iters: int, alpha: float = 0.85,
                push_dtype=None):
    """Distributed PageRank on the same row-sharded ELL layout (plus_times
    semiring): per iteration one frontier all-gather over "data" + local
    gather-reduce + dangling-mass psum. Returns fn(indices, mask, out_deg).

    indices/mask: (N, max_deg) rows of A^T (in-neighbors), "data"-sharded;
    out_deg: (N,) f32, "data"-sharded. Result: ranks (N,) "data"-sharded.

    push_dtype=bf16 (§Perf GE-4): the all-gathered push vector is the
    collective payload; ranks sum in f32 locally, so bf16 on the wire halves
    collective bytes at ~3 decimal digits of rank precision.
    """

    def body(idx_l, msk_l, deg_l):
        rows = idx_l.shape[0]
        r_l = jnp.full((rows,), 1.0 / n, jnp.float32)
        inv_deg_l = jnp.where(deg_l > 0, 1.0 / jnp.maximum(deg_l, 1e-30), 0.0)
        dangling_l = deg_l == 0

        for _ in range(iters):
            push_l = r_l * inv_deg_l
            if push_dtype is not None:
                push_l = push_l.astype(push_dtype)
            push = jax.lax.all_gather(push_l, "data", axis=0, tiled=True)
            # convert only inside the reduce (f32 accumulator): converting
            # the gathered values eagerly makes XLA hoist the f32 cast above
            # the all-gather, silently doubling the wire bytes (§Perf GE-4).
            gathered = jnp.where(msk_l, push[idx_l],
                                 jnp.zeros((), push.dtype))
            pulled_l = jnp.sum(gathered, axis=1, dtype=jnp.float32)
            dmass = jax.lax.psum(
                jnp.sum(jnp.where(dangling_l, r_l, 0.0)), "data") / n
            r_l = (1.0 - alpha) / n + alpha * (pulled_l + dmass)
        return r_l

    return _smap(body, mesh,
                 in_specs=(P("data", None), P("data", None), P("data")),
                 out_specs=P("data"))


def sssp_2d(mesh: Mesh, n: int, iters: int):
    """Distributed Bellman-Ford over min_plus on the row-sharded ELL layout —
    the third core semiring on the mesh (or_and: khop; plus_times: pagerank).

    Returns fn(indices, mask, weights, dist0):
      indices/mask/weights: (N, max_deg) rows of A^T (in-neighbor edges,
      w(j->i) at row i), "data"-sharded; dist0: (N, F) seed distances
      (inf except 0 at seeds), F sharded over pod+model.
    """
    fr_axes = tuple(a for a in ("pod", "model") if a in mesh.axis_names)

    def body(idx_l, msk_l, w_l, dist_l):
        for _ in range(iters):
            dist = jax.lax.all_gather(dist_l, "data", axis=0, tiled=True)
            cand = dist[idx_l] + w_l[..., None]            # (rows, deg, F_l)
            cand = jnp.where(msk_l[..., None], cand, jnp.inf)
            relaxed = cand.min(axis=1)
            dist_l = jnp.minimum(dist_l, relaxed)
        return dist_l

    fr = fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None)
    return _smap(body, mesh,
                 in_specs=(P("data", None), P("data", None), P("data", None),
                           P("data", fr)),
                 out_specs=P("data", fr))


def input_specs_2d(n: int, max_deg: int, f: int):
    """ShapeDtypeStruct stand-ins for the distributed k-hop dry-run."""
    return (jax.ShapeDtypeStruct((n, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((n, max_deg), jnp.bool_),
            jax.ShapeDtypeStruct((n, f), jnp.int8))


def shardings_2d(mesh: Mesh, n: int, max_deg: int, f: int):
    fr_axes = tuple(a for a in ("pod", "model") if a in mesh.axis_names)
    fr = fr_axes if len(fr_axes) > 1 else (fr_axes[0] if fr_axes else None)
    return (NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P("data", None)),
            NamedSharding(mesh, P("data", fr)))
