"""Sharding policy: params / inputs / caches -> PartitionSpecs on the mesh.

Scheme (DESIGN.md §5): 2D FSDP x TP for LM weights — "model" on the last
divisible dim (column parallel), the data axis-group on the largest remaining
divisible dim (FSDP); stacked layer dims (scan) never shard. Embeddings are
special-cased so logits come out vocab-sharded on "model". Optimizer state
inherits its parameter's spec. Caches: batch -> data group, sequence -> the
largest remaining group (flash-decode style; batch=1 long-context shards the
sequence over the whole mesh).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _prod(mesh: Mesh, axes) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p


def _fits(dim: int, size: int) -> bool:
    return dim >= size and dim % size == 0


STACKED = re.compile(r"(layers|segments|enc_layers|dec_layers|seg\d+)")
EMBED = re.compile(r"(embed|tok|out)\b|vision_proj|front_proj")
# Row-parallel (Megatron pairing, §Perf T5): these matrices CONSUME a
# model-sharded activation (ff hidden / attention heads), so "model" must sit
# on their contraction (second-to-last) dim; the generic greedy would put it
# on the output dim and force GSPMD to all-gather the hidden per layer.
ROW_PARALLEL = re.compile(r"\['(wd|wo|wcv|out_proj)'\]")


def param_pspec(path: str, shape, mesh: Mesh, vocab: Optional[int] = None) -> P:
    ndim = len(shape)
    spec = [None] * ndim
    if ndim == 0:
        return P()
    skip = set()
    if STACKED.search(path):
        skip.add(0)
    model = mesh.shape["model"]
    dgroup = data_axes(mesh)
    dsize = _prod(mesh, dgroup)

    # embeddings: model on the vocab-sized dim -> vocab-sharded logits
    if EMBED.search(path) and vocab is not None and vocab in shape:
        vdim = shape.index(vocab)
        if _fits(shape[vdim], model):
            spec[vdim] = "model"
        for i in reversed(range(ndim)):
            if i != vdim and i not in skip and _fits(shape[i], dsize):
                spec[i] = dgroup if len(dgroup) > 1 else dgroup[0]
                break
        return P(*spec)

    # row-parallel down/out projections: model on the contraction dim
    if ROW_PARALLEL.search(path) and ndim >= 2 and _fits(shape[-2], model):
        spec[-2] = "model"
        if _fits(shape[-1], dsize):
            spec[-1] = dgroup if len(dgroup) > 1 else dgroup[0]
        return P(*spec)

    # generic greedy: model -> last divisible dim; data -> largest remaining
    mdim = None
    for i in reversed(range(ndim)):
        if i not in skip and _fits(shape[i], model):
            mdim = i
            spec[i] = "model"
            break
    best, best_sz = None, 0
    for i in range(ndim):
        if i in skip or i == mdim:
            continue
        if _fits(shape[i], dsize) and shape[i] > best_sz:
            best, best_sz = i, shape[i]
    if best is not None:
        spec[best] = dgroup if len(dgroup) > 1 else dgroup[0]
    return P(*spec)


def param_shardings(param_specs_tree, mesh: Mesh, vocab: Optional[int] = None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_specs_tree)
    out = []
    for kp, leaf in flat:
        spec = param_pspec(jax.tree_util.keystr(kp), leaf.shape, mesh, vocab)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_pspec(shape, mesh: Mesh) -> P:
    """Input batches: dim0 = batch over the data group (when divisible)."""
    dgroup = data_axes(mesh)
    spec = [None] * len(shape)
    if shape and _fits(shape[0], _prod(mesh, dgroup)):
        spec[0] = dgroup if len(dgroup) > 1 else dgroup[0]
    elif shape and "data" in mesh.axis_names and _fits(shape[0], mesh.shape["data"]):
        spec[0] = "data"
    return P(*spec)


def batch_shardings(batch_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, batch_pspec(s.shape, mesh)), batch_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_pspec(shape, mesh: Mesh, batch: int, seq_to_model: bool = True) -> P:
    """KV caches / recurrent states.

    batch > 1 : batch dim -> data group; longest (sequence) dim -> "model".
    batch == 1: longest dim -> the whole mesh (pod x data x model) — the
    long_500k layout; every chip holds a slice of the one sequence.
    """
    ndim = len(shape)
    spec = [None] * ndim
    dgroup = data_axes(mesh)
    model = mesh.shape["model"]
    used = set()
    if batch > 1:
        for i, d in enumerate(shape):
            if d == batch and _fits(d, _prod(mesh, dgroup)):
                spec[i] = dgroup if len(dgroup) > 1 else dgroup[0]
                used.add(i)
                break
        if seq_to_model:
            # largest remaining dim gets "model"
            cands = [(d, i) for i, d in enumerate(shape)
                     if i not in used and i != 0 and _fits(d, model)]
            if cands:
                d, i = max(cands)
                spec[i] = "model"
    else:
        all_axes = dgroup + ("model",)
        total = _prod(mesh, all_axes)
        cands = [(d, i) for i, d in enumerate(shape) if i != 0 and _fits(d, total)]
        if cands:
            d, i = max(cands)
            spec[i] = all_axes
        else:
            cands = [(d, i) for i, d in enumerate(shape)
                     if i != 0 and _fits(d, model)]
            if cands:
                d, i = max(cands)
                spec[i] = "model"
    return P(*spec)


def cache_shardings(cache_specs_tree, mesh: Mesh, batch: int,
                    seq_to_model: bool = True):
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, cache_pspec(s.shape, mesh, batch, seq_to_model)),
        cache_specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_state_shardings(opt_state_specs, mesh: Mesh,
                        vocab: Optional[int] = None):
    """Optimizer moments shard like their parameters (same shapes -> same
    inference); factored Adafactor rows/cols and scalars get their own."""

    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_pspec(path, leaf.shape, mesh, vocab))

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state_specs)
    out = [one(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
