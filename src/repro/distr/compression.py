"""int8 gradient compression with error feedback.

At 1000+ node scale the DP all-reduce is bandwidth-bound; quantizing grads to
int8 (per-tensor absmax scale) cuts collective bytes 4x vs f32 / 2x vs bf16.
Error feedback (residual carried to the next step) keeps SGD unbiased in the
long run (Seide et al.; Karimireddy et al.).

In SPMD jit the all-reduce is implicit (GSPMD inserts it for sharded-batch
grads); compressing before the mean-reduce is modeled here by quantize ->
dequantize around the gradient tree — the dry-run HLO then carries int8
collectives when wired via shard_map (see distr/graph2d.py for the explicit-
collective pattern). Numerics are what tests validate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_fb=None):
    """Quantize each gradient leaf to int8 (+ error feedback residual)."""
    if error_fb is None:
        error_fb = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize(g32)
        deq = dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
