from repro.graph.graph import Graph, GraphBuilder, Relation
from repro.graph import datagen

__all__ = ["Graph", "GraphBuilder", "Relation", "datagen"]
