"""Graph generators: Graph500 R-MAT (the paper's dataset generator), a
Twitter-like power-law sampler, and a labeled "social" graph for query tests."""
from __future__ import annotations

import numpy as np

from repro.graph.graph import GraphBuilder

# Graph500 R-MAT parameters
RMAT_A, RMAT_B, RMAT_C = 0.57, 0.19, 0.19


def rmat_edges(scale: int, edge_factor: int = 16, seed: int = 0,
               a: float = RMAT_A, b: float = RMAT_B, c: float = RMAT_C):
    """Vectorized R-MAT: the Graph500 kernel-0 generator."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        u = rng.uniform(size=m)
        src_bit = (u >= ab).astype(np.int64)
        dst_bit = (((u >= a) & (u < ab)) | (u >= abc)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    # Graph500 post-processing: random vertex relabeling kills locality; we
    # keep *both* orderings available — `relabel=True` is the adversarial
    # (hypersparse/ELL) case, False keeps RMAT block locality (BSR case).
    return src, dst, n


def rmat_graph(scale: int, edge_factor: int = 16, seed: int = 0,
               relabel: bool = False, fmt: str = "auto",
               block: int = 128, rel: str = "KNOWS"):
    src, dst, n = rmat_edges(scale, edge_factor, seed)
    if relabel:
        rng = np.random.default_rng(seed + 1)
        perm = rng.permutation(n)
        src, dst = perm[src], perm[dst]
    g = GraphBuilder(n).add_edges(rel, src, dst).build(fmt=fmt, block=block)
    return g


def twitter_like_graph(n: int = 4096, avg_deg: int = 16, seed: int = 0,
                       fmt: str = "auto", block: int = 128, rel: str = "FOLLOWS"):
    """Power-law in-degree sampler (preferential-attachment flavor)."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    # zipf-ish destination popularity
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    dst = rng.choice(n, size=m, p=p)
    src = rng.integers(0, n, size=m)
    return GraphBuilder(n).add_edges(rel, src, dst).build(fmt=fmt, block=block)


def social_graph(n: int = 512, seed: int = 0, fmt: str = "auto", block: int = 64):
    """Labeled property graph for Cypher tests: Person-KNOWS-Person,
    Person-VISITS-City, with an `age` property."""
    rng = np.random.default_rng(seed)
    n_city = max(8, n // 16)
    n_person = n - n_city
    person = np.arange(n_person)
    city = np.arange(n_person, n)
    b = GraphBuilder(n)
    b.add_label("Person", person)
    b.add_label("City", city)
    b.set_prop("age", person, rng.integers(10, 80, size=n_person))
    ks = rng.integers(0, n_person, size=n_person * 8)
    kd = rng.integers(0, n_person, size=n_person * 8)
    keep = ks != kd
    b.add_edges("KNOWS", ks[keep], kd[keep])
    vs = rng.integers(0, n_person, size=n_person * 2)
    vd = rng.integers(n_person, n, size=n_person * 2)
    b.add_edges("VISITS", vs, vd)
    return b.build(fmt=fmt, block=block)
