"""Property graph backed by sparse matrices — RedisGraph's data model.

  * one boolean adjacency matrix per relationship type (+ the union matrix),
  * one boolean diagonal (stored as a vector) per node label,
  * numeric node properties as dense columns (value + presence),
  * explicit transposes maintained per relation (RedisGraph does the same) so
    vxm pulls never transpose at query time.

Each relation holds a single `grb.GBMatrix` handle: storage lives in BSR
(MXU path) or ELL (hypersparse gather path) — chosen per relation by
`core.ops.auto_format` unless forced — and the explicitly-built transpose is
linked into the handle's cache, so `rel.A.T` (and the `rel.A_T` shorthand)
is always the stored transpose, never a runtime flip.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import BSR, ELL, grb, ops


@dataclasses.dataclass
class Relation:
    name: str
    A: grb.GBMatrix    # row i -> out-neighbors; A.T is the linked transpose
    nnz: int

    @property
    def A_T(self) -> grb.GBMatrix:
        """Stored transpose, for pull-style vxm (cached on the handle)."""
        return self.A.T


@dataclasses.dataclass
class Graph:
    n: int
    relations: Dict[str, Relation]
    labels: Dict[str, jnp.ndarray]             # label -> bool (n,)
    node_props: Dict[str, jnp.ndarray]         # prop -> f32 (n,) (nan = absent)
    adj: Optional[Relation] = None             # union over relation types

    def relation(self, name: Optional[str]) -> Relation:
        if name is None:
            return self.adj
        return self.relations[name]

    def label_mask(self, label: Optional[str]) -> jnp.ndarray:
        if label is None:
            return jnp.ones(self.n, dtype=bool)
        return self.labels[label]

    @property
    def nnz(self) -> int:
        return sum(r.nnz for r in self.relations.values())


class GraphBuilder:
    """Accumulates nodes/edges host-side, then freezes into device matrices."""

    def __init__(self, n: int):
        self.n = n
        self._edges: Dict[str, list] = {}
        self._labels: Dict[str, np.ndarray] = {}
        self._props: Dict[str, np.ndarray] = {}

    def add_label(self, label: str, node_ids) -> "GraphBuilder":
        mask = self._labels.setdefault(label, np.zeros(self.n, dtype=bool))
        mask[np.asarray(node_ids)] = True
        return self

    def set_prop(self, prop: str, node_ids, values) -> "GraphBuilder":
        col = self._props.setdefault(prop, np.full(self.n, np.nan, np.float32))
        col[np.asarray(node_ids)] = np.asarray(values, dtype=np.float32)
        return self

    def add_edges(self, rel: str, src, dst, weights=None) -> "GraphBuilder":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        w = (np.ones_like(src, dtype=np.float32) if weights is None
             else np.asarray(weights, dtype=np.float32))
        self._edges.setdefault(rel, []).append((src, dst, w))
        return self

    def build(self, fmt: str = "auto", block: int = 128,
              impl: str = "auto") -> Graph:
        relations = {}
        all_src, all_dst = [], []
        for rel, chunks in self._edges.items():
            src = np.concatenate([c[0] for c in chunks])
            dst = np.concatenate([c[1] for c in chunks])
            w = np.concatenate([c[2] for c in chunks])
            src, dst, w = _dedup(src, dst, w, self.n)
            relations[rel] = Relation(
                rel, _make_handle(rel, src, dst, w, self.n, fmt, block, impl),
                nnz=len(src))
            all_src.append(src)
            all_dst.append(dst)
        adj = None
        if all_src:
            s = np.concatenate(all_src)
            d = np.concatenate(all_dst)
            s, d, w = _dedup(s, d, np.ones_like(s, np.float32), self.n)
            adj = Relation("", _make_handle("", s, d, w, self.n, fmt, block,
                                            impl), nnz=len(s))
        return Graph(
            n=self.n,
            relations=relations,
            labels={k: jnp.asarray(v) for k, v in self._labels.items()},
            node_props={k: jnp.asarray(v) for k, v in self._props.items()},
            adj=adj)


def _dedup(src, dst, w, n):
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx], w[idx]


def _make(src, dst, w, n, fmt, block):
    if fmt == "bsr":
        return BSR.from_coo(src, dst, w, (n, n), block=block)
    if fmt == "ell":
        return ELL.from_coo(src, dst, w, (n, n))
    if fmt == "bitadj":
        from repro.core.bitadj import BitELL
        return BitELL.from_coo(src, dst, w, (n, n))
    return ops.auto_format(src, dst, w, (n, n), block=block)


def _make_handle(name, src, dst, w, n, fmt, block, impl) -> grb.GBMatrix:
    """Build forward + transpose storage and link them into one handle."""
    A = grb.GBMatrix(_make(src, dst, w, n, fmt, block), impl=impl, name=name)
    A.link_transpose(grb.GBMatrix(_make(dst, src, w, n, fmt, block),
                                  impl=impl, name=name + "^T"))
    return A
