"""Production meshes. Functions, not module constants: importing this module
never touches jax device state (the dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests, examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
