"""Serving driver: batched greedy decode on CPU scale, and the entry point
whose `serve_step` the decode-shape dry-run cells lower.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --tiny 1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch.train import tiny_config
from repro.models import get_model
from repro.serve.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--tiny", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)
    model = get_model(cfg)
    params = model.init(0)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.max_new,
                          cache_len=args.prompt_len + args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    print(f"[serve] first row: {np.asarray(out[0])[:12]}")
    return out


if __name__ == "__main__":
    main()
