"""End-to-end training driver (the example e2e path runs this on CPU).

Production path: sharded params on the host mesh, async checkpointing with
atomic LATEST, restart-safe data stream, elastic resume (restore reshards
onto whatever mesh the restarted job has), straggler note: at >1 pod the
launcher runs one process per pod; a pod that misses `heartbeat_timeout` is
declared dead and the job restarts from LATEST on the surviving pods
(launch/elastic.py simulates the control flow).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --tiny 1 --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.data import synthetic_batch
from repro.train.train_step import make_train_step


def tiny_config(cfg):
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, d_ff=128, vocab=251, n_heads=4,
        n_kv_heads=2, head_dim=16, dtype="float32",
        **({"n_experts": 4} if cfg.family == "moe" else {}),
        **({"ssm_heads": 4} if cfg.family in ("rwkv6", "zamba2") else {}),
        **({"encoder_layers": 2, "n_audio_frames": 8, "d_frontend": 16}
           if cfg.family == "whisper" else {}),
        **({"n_image_tokens": 4, "d_frontend": 16}
           if cfg.family == "llava" else {}),
        **({"shared_attn_every": 2, "ssm_state": 8, "n_layers": 4,
            "n_heads": 4, "n_kv_heads": 4}
           if cfg.family == "zamba2" else {}))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--tiny", type=int, default=1,
                    help="reduced config (CPU scale); 0 = full config")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    model = get_model(cfg)
    opt_cfg = opt_mod.OptConfig(name=cfg.optimizer, lr=args.lr,
                                warmup_steps=5, total_steps=args.steps)
    params = model.init(0)
    opt_state = opt_mod.init_fn(cfg.optimizer)(params)

    start = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start = ckpt.restore(
                (params, opt_state), args.ckpt_dir)
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(make_train_step(
        model, opt_cfg, microbatches=args.microbatches,
        compress_grads=bool(args.compress_grads)),
        donate_argnums=(0, 1))
    error_fb = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                if args.compress_grads else None)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_batch(cfg, shape, step)
        batch = jax.tree.map(jnp.asarray, batch)
        if args.compress_grads:
            params, opt_state, metrics, error_fb = step_fn(
                params, opt_state, batch, error_fb)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if writer and (step + 1) % args.ckpt_every == 0:
            writer.save((params, opt_state), step + 1)
    if writer:
        writer.save((params, opt_state), args.steps)
        writer.wait()
    dt = time.time() - t0
    print(f"[train] done: {args.steps - start} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
