"""Elastic / fault-tolerant launch logic (control plane).

On a real fleet each pod runs one process; this module holds the pure logic
(mesh re-planning, restart decisions, straggler policy) so it is unit-testable
without 512 real hosts:

  * `plan_mesh(n_healthy_chips)`: largest (data, model) grid that fits the
    survivors while keeping "model"=16 (TP degree is fixed by memory); data
    shrinks elastically — checkpoint restore re-shards (train/checkpoint.py).
  * `RestartPolicy`: heartbeat bookkeeping; a worker that misses
    `timeout_s` is dead; >0 dead => restart from LATEST with a new plan.
  * Straggler mitigation: workers report step latency; persistent p95
    outliers (> `straggler_factor` x median) are cordoned at the next
    restart boundary (standard backup-worker strategy; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple


def plan_mesh(n_healthy_chips: int, model_degree: int = 16,
              pod_size: int = 256) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest mesh (pods x data x model) runnable on the healthy chips."""
    if n_healthy_chips < model_degree:
        raise RuntimeError("fewer chips than the TP degree: cannot resume")
    pods = n_healthy_chips // pod_size
    if pods >= 2:
        data = pod_size // model_degree
        return (pods, data, model_degree), ("pod", "data", "model")
    data = n_healthy_chips // model_degree
    return (data, model_degree), ("data", "model")


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    step_latencies: list


class RestartPolicy:
    def __init__(self, timeout_s: float = 60.0, straggler_factor: float = 2.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.workers: Dict[str, WorkerState] = {}
        self.cordoned: set = set()

    def heartbeat(self, worker: str, step_latency_s: Optional[float] = None):
        st = self.workers.setdefault(worker, WorkerState(self.clock(), []))
        st.last_heartbeat = self.clock()
        if step_latency_s is not None:
            st.step_latencies.append(step_latency_s)
            st.step_latencies = st.step_latencies[-100:]

    def dead_workers(self):
        now = self.clock()
        return sorted(w for w, st in self.workers.items()
                      if now - st.last_heartbeat > self.timeout_s
                      and w not in self.cordoned)

    def stragglers(self):
        lats = {w: sorted(st.step_latencies)
                for w, st in self.workers.items() if st.step_latencies}
        if len(lats) < 2:
            return []
        medians = {w: l[len(l) // 2] for w, l in lats.items()}
        global_median = sorted(medians.values())[len(medians) // 2]
        return sorted(w for w, m in medians.items()
                      if m > self.straggler_factor * global_median)

    def should_restart(self) -> bool:
        return bool(self.dead_workers())

    def plan_restart(self, chips_per_worker: int = 256):
        """Cordon dead + persistent stragglers; re-plan the mesh."""
        for w in self.dead_workers() + self.stragglers():
            self.cordoned.add(w)
        healthy = [w for w in self.workers if w not in self.cordoned]
        return plan_mesh(len(healthy) * chips_per_worker)
