import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# (setdefault so tests can run reduced-device smoke dry-runs via env.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any real tensors:
  * compiled.memory_analysis()  -> does it fit 16 GB/chip,
  * compiled.cost_analysis()    -> per-device HLO FLOPs / bytes,
  * HLO-parsed collective bytes -> the roofline's collective term,
and writes one JSON under experiments/dryrun/. benchmarks/roofline.py
aggregates these into EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both      # the full 40-cell grid
  python -m repro.launch.dryrun --graph --mesh both    # paper-workload cells
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCHS, SHAPES, get_config, shapes_for
from repro.distr import graph2d, sharding as sh
from repro.distr.shardctx import ShardCtx, use
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.serve.serve_step import make_serve_step
from repro.train import optimizer as opt_mod
from repro.train.train_step import make_train_step

# TPU v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link

_COLL = re.compile(
    r"(\w+)\[([\d,]*)\]\S*\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)", re.IGNORECASE)
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_stats(hlo_text: str):
    """Sum result-buffer bytes of every collective op in the partitioned HLO
    (per-device convention; see EXPERIMENTS.md §Roofline)."""
    by_kind = {}
    total = 0
    for m in _COLL.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3).lower()
        sz = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * sz
        e = by_kind.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += b
        total += b
    return total, by_kind


def mem_stats(compiled):
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0) or 0)
    out["peak_per_device_bytes"] = (out["argument_size_in_bytes"]
                                    + out["temp_size_in_bytes"]
                                    + out["output_size_in_bytes"]
                                    - out["alias_size_in_bytes"])
    return out


def cost_stats(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0))}


def roofline(nchips, flops_dev, bytes_dev, coll_bytes_dev):
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_bytes_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


# ---------------------------------------------------------------------------
def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               seq_to_model: bool = True, rules: dict | None = None,
               cfg=None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = get_model(cfg)
    ctx = ShardCtx(mesh, rules=rules)

    pspecs = model.param_specs()
    pshard = sh.param_shardings(pspecs, mesh, vocab=cfg.vocab)

    with use(ctx):
        if shape.kind == "train":
            opt_cfg = opt_mod.OptConfig(name=cfg.optimizer)
            ospecs = jax.eval_shape(opt_mod.init_fn(cfg.optimizer), pspecs)
            oshard = sh.opt_state_shardings(ospecs, mesh, vocab=cfg.vocab)
            bspecs = model.train_input_specs(shape)
            bshard = sh.batch_shardings(bspecs, mesh)
            import jax.numpy as _jnp
            step = make_train_step(
                model, opt_cfg, microbatches=cfg.microbatches,
                accum_dtype={"float32": _jnp.float32,
                             "bfloat16": _jnp.bfloat16}[cfg.grad_accum_dtype],
                hoist_weight_gather=cfg.hoist_weight_gather)
            mshard = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                  {"loss": 0, "grad_norm": 0, "lr": 0})
            fn = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, mshard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(pspecs, ospecs, bspecs)
        elif shape.kind == "prefill":
            bspecs = model.train_input_specs(shape)
            bspecs.pop("labels", None)
            bshard = sh.batch_shardings(bspecs, mesh)
            fn = jax.jit(lambda p, b: model.prefill_fn(p, b)[0],
                         in_shardings=(pshard, bshard))
            lowered = fn.lower(pspecs, bspecs)
        else:  # decode
            cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
            cshard = sh.cache_shardings(cspecs, mesh, shape.global_batch,
                                        seq_to_model=seq_to_model)
            bspecs = model.decode_input_specs(shape)
            bshard = sh.batch_shardings(bspecs, mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            posshard = NamedSharding(mesh, P())
            serve = make_serve_step(model)
            tokshard = sh.batch_shardings(
                {"t": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)},
                mesh)["t"]
            fn = jax.jit(serve,
                         in_shardings=(pshard, cshard, bshard, posshard),
                         out_shardings=(tokshard, cshard),
                         donate_argnums=(1,))
            lowered = fn.lower(pspecs, cspecs, bspecs, pos)
    return lowered, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             seq_to_model: bool = True, tag: str = "", rules=None):
    t0 = time.time()
    meshname = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{meshname}{tag}"
    outpath = os.path.join(outdir, cell + ".json")
    print(f"[dryrun] {cell} ...", flush=True)
    try:
        lowered, mesh, cfg, shape = lower_cell(arch, shape_name, multi_pod,
                                               seq_to_model=seq_to_model,
                                               rules=rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        nchips = int(np.prod(list(mesh.shape.values())))
        cost = cost_stats(compiled)
        mem = mem_stats(compiled)
        coll_total, coll_kinds = collective_stats(compiled.as_text())
        rl = roofline(nchips, cost["flops_per_device"],
                      cost["bytes_per_device"], coll_total)
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
        rec = {
            "cell": cell, "arch": arch, "shape": shape_name,
            "mesh": meshname, "chips": nchips, "kind": shape.kind,
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "cost": cost, "memory": mem,
            "collective_bytes_per_device": coll_total,
            "collectives": coll_kinds,
            "roofline": rl,
            "n_params": n_params, "n_active_params": n_active,
            "model_flops": model_flops,
            "model_flops_per_device": model_flops / nchips,
            "useful_flops_ratio": (model_flops / nchips)
            / max(cost["flops_per_device"], 1.0),
            "fits_hbm": mem["peak_per_device_bytes"] < 16e9,
        }
        print(f"  ok: compile {t_compile:.0f}s  "
              f"dom={rl['dominant']} bound={rl['bound_s']*1e3:.2f}ms  "
              f"mem={mem['peak_per_device_bytes']/1e9:.2f}GB", flush=True)
    except Exception as e:  # record failures as cells too
        rec = {"cell": cell, "arch": arch, "shape": shape_name,
               "mesh": meshname, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        print(f"  ERROR: {type(e).__name__}: {str(e)[:300]}", flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(outpath, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


# -- the paper's own workload: distributed k-hop cells ---------------------------
GRAPH_CELLS = {
    # name: (n_vertices, max_deg buckets, F queries, k)
    "graph500_s21": (2_097_152, 64, 256, 2),
    "twitter41m": (41_600_000, 64, 256, 2),
}


def run_graph_cell(name: str, multi_pod: bool, outdir: str,
                   packed: bool = False, sentinel: bool = False):
    t0 = time.time()
    meshname = "pod2x16x16" if multi_pod else "pod16x16"
    kind = "khop" + ("_bitmap" if packed else "") + \
        ("_sentinel" if sentinel else "")
    cell = f"graph_{name}__{kind}__{meshname}"
    print(f"[dryrun] {cell} ...", flush=True)
    n, max_deg, fq, k = GRAPH_CELLS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        fn = graph2d.khop_counts_2d(mesh, n, k, packed=packed,
                                    sentinel=sentinel)
        specs = graph2d.input_specs_2d(n, max_deg, fq)
        shards = graph2d.shardings_2d(mesh, n, max_deg, fq)
        jfn = jax.jit(fn, in_shardings=shards)
        lowered = jfn.lower(*specs)
        compiled = lowered.compile()
        nchips = int(np.prod(list(mesh.shape.values())))
        cost = cost_stats(compiled)
        mem = mem_stats(compiled)
        coll_total, coll_kinds = collective_stats(compiled.as_text())
        rl = roofline(nchips, cost["flops_per_device"],
                      cost["bytes_per_device"], coll_total)
        rec = {"cell": cell, "arch": f"graph_{name}", "shape": kind,
               "mesh": meshname, "chips": nchips, "kind": "graph",
               "status": "ok", "compile_s": round(time.time() - t0, 1),
               "cost": cost, "memory": mem,
               "collective_bytes_per_device": coll_total,
               "collectives": coll_kinds, "roofline": rl,
               "fits_hbm": mem["peak_per_device_bytes"] < 16e9}
        print(f"  ok: dom={rl['dominant']} "
              f"mem={mem['peak_per_device_bytes']/1e9:.2f}GB", flush=True)
    except Exception as e:
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        print(f"  ERROR: {str(e)[:300]}", flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_pagerank_cell(name: str, multi_pod: bool, outdir: str,
                      iters: int = 10):
    t0 = time.time()
    meshname = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"graph_{name}__pagerank__{meshname}"
    print(f"[dryrun] {cell} ...", flush=True)
    n, max_deg, fq, k = GRAPH_CELLS[name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        fn = graph2d.pagerank_2d(mesh, n, iters=iters)
        specs, shards = graph2d.pagerank_specs_2d(mesh, n, max_deg)
        compiled = jax.jit(fn, in_shardings=shards).lower(*specs).compile()
        nchips = int(np.prod(list(mesh.shape.values())))
        cost = cost_stats(compiled)
        mem = mem_stats(compiled)
        coll_total, coll_kinds = collective_stats(compiled.as_text())
        rl = roofline(nchips, cost["flops_per_device"],
                      cost["bytes_per_device"], coll_total)
        rec = {"cell": cell, "arch": f"graph_{name}", "shape": "pagerank",
               "mesh": meshname, "chips": nchips, "kind": "graph",
               "status": "ok", "compile_s": round(time.time() - t0, 1),
               "cost": cost, "memory": mem,
               "collective_bytes_per_device": coll_total,
               "collectives": coll_kinds, "roofline": rl,
               "fits_hbm": mem["peak_per_device_bytes"] < 16e9}
        print(f"  ok: dom={rl['dominant']} "
              f"mem={mem['peak_per_device_bytes']/1e9:.2f}GB", flush=True)
    except Exception as e:
        rec = {"cell": cell, "status": "error",
               "error": f"{type(e).__name__}: {e}"}
        print(f"  ERROR: {str(e)[:300]}", flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graph", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists and is ok")
    ap.add_argument("--seq-to-model", default="1")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical-axis rule override, e.g. seq_shard=skip "
                         "or batch=pod,data (perf iterations)")
    ap.add_argument("--tag", default="", help="suffix for output cell names")
    args = ap.parse_args()

    rules = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        rules[k] = "skip" if v == "skip" else tuple(a for a in v.split(",") if a)
    rules = rules or None

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok = err = skip = 0

    def done(cell):
        p = os.path.join(args.out, cell + ".json")
        if not os.path.exists(p):
            return False
        with open(p) as f:
            return json.load(f).get("status") == "ok"

    if args.graph:
        # plus_times workload: distributed PageRank on the paper's graphs
        for name in GRAPH_CELLS:
            for mp in meshes:
                meshname = "pod2x16x16" if mp else "pod16x16"
                if args.resume and done(f"graph_{name}__pagerank__{meshname}"):
                    skip += 1
                    continue
                rec = run_pagerank_cell(name, mp, args.out)
                ok += rec.get("status") == "ok"
                err += rec.get("status") != "ok"
        for name in GRAPH_CELLS:
            for packed, sentinel in ((False, False), (True, False),
                                     (True, True)):
                kindname = "khop" + ("_bitmap" if packed else "") + \
                    ("_sentinel" if sentinel else "")
                for mp in meshes:
                    meshname = "pod2x16x16" if mp else "pod16x16"
                    if args.resume and done(f"graph_{name}__{kindname}__{meshname}"):
                        skip += 1
                        continue
                    rec = run_graph_cell(name, mp, args.out, packed=packed,
                                         sentinel=sentinel)
                    ok += rec.get("status") == "ok"
                    err += rec.get("status") != "ok"
    archs = ARCHS if args.all else ([args.arch] if args.arch else [])
    for arch in archs:
        cfg = get_config(arch)
        shape_list = ([args.shape] if args.shape
                      else [s.name for s in shapes_for(cfg)])
        for shape_name in shape_list:
            if shape_name in cfg.skip_shapes:
                print(f"[dryrun] skip {arch} x {shape_name} (documented)")
                continue
            for mp in meshes:
                meshname = "pod2x16x16" if mp else "pod16x16"
                if args.resume and done(f"{arch}__{shape_name}__{meshname}"):
                    skip += 1
                    continue
                rec = run_cell(arch, shape_name, mp, args.out,
                               seq_to_model=args.seq_to_model == "1",
                               tag=args.tag, rules=rules)
                ok += rec.get("status") == "ok"
                err += rec.get("status") != "ok"
    print(f"[dryrun] done: {ok} ok, {err} errors, {skip} skipped(resume)")
    sys.exit(1 if err else 0)


if __name__ == "__main__":
    main()
