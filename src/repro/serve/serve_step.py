"""Serving steps: prefill + decode drivers used by launch/serve.py, the
dry-run (decode shapes lower `serve_step`, not `train_step`) and examples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.base import ModelBundle


def make_serve_step(model: ModelBundle):
    """serve_step = one decode step with a full-size KV cache: the unit the
    decode_32k / long_500k grid cells lower and roofline."""

    def serve_step(params, cache, batch, pos):
        logits, cache = model.decode_fn(params, cache, batch, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def greedy_generate(model: ModelBundle, params, prompt, max_new: int,
                    cache_len: int):
    """CPU-scale generation loop (examples): prefill by teacher-forced decode
    steps, then greedy decode."""
    B, S = prompt.shape
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        model.cache_specs(B, cache_len),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step = jax.jit(model.decode_fn)
    logits = None
    for pos in range(S):
        logits, cache = step(params, cache, {"tokens": prompt[:, pos:pos+1]},
                             pos)
    out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    for pos in range(S, S + max_new - 1):
        logits, cache = step(params, cache, {"tokens": out[-1][:, None]}, pos)
        out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    return jnp.stack(out, axis=1)
