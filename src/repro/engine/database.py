"""The database shell: named graphs + query routing (GRAPH.QUERY analog).

Mutations (CREATE) stage host-side edits; reads rebuild the frozen matrix set
lazily (Redis fork-snapshot spirit: readers always see an immutable build).
Every mutating command is appended to the AOF before acking — replay after a
crash restores the graph (persistence.py).

Sharded mode: `query(..., mesh=m)` / `context(..., mesh=m)` serve the same
reads over a device mesh — the frozen build is ELL, the context distributes
the relation handles (`grb.distribute`), and execution goes through the
identical `grb` calls as single-device (no distributed code path here).
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from repro.graph.graph import Graph, GraphBuilder
from repro.query import qast as A
from repro.query.executor import ExecutionContext, Result, explain
from repro.query.parser import parse


class MutableGraph:
    def __init__(self, n_hint: int = 16):
        self.next_id = 0
        self.labels: Dict[str, list] = {}
        self.props: Dict[str, dict] = {}
        self.edges: list = []           # (rel, src, dst)
        self._builds: Dict[str, Graph] = {}     # fmt -> frozen build
        self.fmt = "auto"
        self.block = 64

    # -- mutations -------------------------------------------------------------
    def create_node(self, label: Optional[str], props: dict) -> int:
        nid = int(props["id"])
        self.next_id = max(self.next_id, nid + 1)
        if label:
            self.labels.setdefault(label, []).append(nid)
        for k, v in props.items():
            if k != "id":
                self.props.setdefault(k, {})[nid] = float(v)
        self._builds.clear()
        return nid

    def create_edge(self, src: int, rel: str, dst: int) -> None:
        self.next_id = max(self.next_id, src + 1, dst + 1)
        self.edges.append((rel, int(src), int(dst)))
        self._builds.clear()

    # -- reads -------------------------------------------------------------------
    def freeze(self, fmt: Optional[str] = None) -> Graph:
        """Frozen matrix build. fmt=None keeps this graph's default; an
        explicit fmt (the sharded mode freezes ELL) gets its own build.
        Builds are cached per format so a workload that interleaves mesh
        and local reads never thrashes rebuilds; any mutation clears all of
        them. Bulk-loaded graphs (load_graph) have no edge log to rebuild
        from and are served as-is for every format."""
        want = fmt or self.fmt
        if "external" in self._builds:
            return self._builds["external"]
        g = self._builds.get(want)
        if g is not None:
            return g
        n = max(self.next_id, 1)
        b = GraphBuilder(n)
        for label, ids in self.labels.items():
            b.add_label(label, ids)
        for prop, kv in self.props.items():
            b.set_prop(prop, list(kv.keys()), list(kv.values()))
        by_rel: Dict[str, list] = {}
        for rel, s, d in self.edges:
            by_rel.setdefault(rel, []).append((s, d))
        for rel, pairs in by_rel.items():
            arr = np.asarray(pairs, dtype=np.int64)
            b.add_edges(rel, arr[:, 0], arr[:, 1])
        g = b.build(fmt=want, block=self.block)
        self._builds[want] = g
        return g


class Database:
    def __init__(self, data_dir: Optional[str] = None):
        self.graphs: Dict[str, MutableGraph] = {}
        self.data_dir = data_dir
        if data_dir:
            os.makedirs(data_dir, exist_ok=True)
            self._replay_aof()

    def _graph(self, name: str) -> MutableGraph:
        return self.graphs.setdefault(name, MutableGraph())

    # -- commands ------------------------------------------------------------
    def query(self, name: str, text: str, impl: str = "auto",
              mesh=None) -> Result:
        q = parse(text)
        if isinstance(q, A.CreateQuery):
            self._append_aof(name, text)
            return self._apply_create(name, q)
        return self.context(name, impl=impl, mesh=mesh).run(q)

    def context(self, name: str, impl: str = "auto",
                mesh=None) -> ExecutionContext:
        """Public execution surface over the named graph's frozen build.

        Sharded mode is the same surface: pass a mesh and the context's
        relation handles are distributed onto it — reads freeze the graph
        as ELL (the mesh row layout) and every query lowers through the
        same `grb` calls as single-device; nothing else changes.
        """
        g = self._graph(name).freeze(fmt="ell" if mesh is not None else None)
        return ExecutionContext(g, impl=impl, mesh=mesh)

    def explain(self, name: str, text: str) -> str:
        return explain(self._graph(name).freeze(), text)

    def load_graph(self, name: str, graph_or_builder) -> None:
        """Bulk load a pre-built Graph (datagen path)."""
        mg = self._graph(name)
        g = graph_or_builder
        mg._builds = {"external": g}
        mg.next_id = g.n

    def _apply_create(self, name: str, q: A.CreateQuery) -> Result:
        mg = self._graph(name)
        created_n = created_e = 0
        for item in q.items:
            if isinstance(item, A.CreateNode):
                mg.create_node(item.label, item.props)
                created_n += 1
            else:
                mg.create_edge(item.src, item.rel, item.dst)
                created_e += 1
        return Result(["nodes_created", "edges_created"],
                      [(created_n, created_e)])

    # -- persistence (AOF) ------------------------------------------------------
    def _aof_path(self, name: str) -> str:
        return os.path.join(self.data_dir, f"{name}.aof")

    def _append_aof(self, name: str, text: str) -> None:
        if not self.data_dir:
            return
        with open(self._aof_path(name), "a") as f:
            f.write(text.replace("\n", " ") + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _replay_aof(self) -> None:
        for fn in sorted(os.listdir(self.data_dir)):
            if not fn.endswith(".aof"):
                continue
            name = fn[: -len(".aof")]
            with open(os.path.join(self.data_dir, fn)) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        self._apply_create(name, parse(line))
