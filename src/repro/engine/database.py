"""The database shell: named graphs + query routing (GRAPH.QUERY analog).

Mutations (CREATE / DELETE) apply as **delta appends** — the paper's
production write path: each relation keeps a frozen base matrix plus small
pending plus/minus deltas (`core.delta.DeltaMatrix`), so a write never
triggers a stop-the-world rebuild. `MutableGraph.freeze()` returns a
snapshot-consistent view: delta updates are functional, so a reader that
froze before a writer batch keeps seeing pre-batch state while the writer
streams edits (the Redis fork-snapshot spirit, without the fork). When a
relation's pending deltas cross the measured `grb.AUTO_DELTA_COMPACT`
fraction of its base, freeze folds them back into the base format —
compaction, not a from-scratch rebuild (the edge log is never replayed).

Every mutating command is appended to the AOF before acking — replay after
a crash coalesces the whole log into deltas over one initial build
(persistence.py), not N rebuilds.

Sharded mode: `query(..., mesh=m)` / `context(..., mesh=m)` serve the same
reads over a device mesh — the frozen view is compacted to ELL (the mesh
row layout has no delta lowering), the context distributes the relation
handles (`grb.distribute`), and execution goes through the identical `grb`
calls as single-device (no distributed code path here).
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import grb
from repro.core.delta import DeltaMatrix, needs_compaction
from repro.core.ell import ELL
from repro.engine import persistence as P
from repro.graph.graph import Graph, GraphBuilder, Relation
from repro.query import qast as A
from repro.query.executor import ExecutionContext, Result, explain
from repro.query.parser import parse


class MutableGraph:
    """Host-side mutable graph with delta-served frozen views.

    Writes append to an op log and the live edge set; `freeze()` serves a
    Graph whose relation handles are DeltaMatrix-backed — built **once** per
    format, then caught up functionally (apply_ops) on later freezes.
    `delta=False` restores the legacy rebuild-on-freeze behavior (every
    mutation clears the build cache); benchmarks/bench_mutations.py measures
    the two against each other.

    Deleted nodes are tombstones: DELETE (i) removes the node's incident
    edges, labels and properties, but the id row stays allocated (RedisGraph
    reuses ids on compaction; this surface never shrinks n).
    """

    def __init__(self, n_hint: int = 16, delta: bool = True):
        self.next_id = 0
        self.labels: Dict[str, list] = {}
        self.props: Dict[str, dict] = {}
        self.edges: Dict[Tuple[str, int, int], float] = {}  # live edge set
        # relation types ever created: like RedisGraph's schema, a relation
        # persists (possibly empty) after its last edge is deleted — keeps
        # delta-served and rebuilt views structurally identical
        self.rels: set = set()
        self.delta = delta
        self.fmt = "auto"
        self.block = 64
        # write clock: every mutating call advances it; freeze() keys
        # snapshot views by (fmt, epoch)
        self.epoch = 0
        self._oplog: list = []          # (rel, "add"/"del", src, dst, w)
        self._pairs: Dict[Tuple[int, int], int] = {}  # adj ("") refcounts
        # delta serving state per fmt: (oplog index consumed, Graph view)
        self._served: Dict[str, Tuple[int, Graph]] = {}
        self._views: Dict[tuple, Graph] = {}   # (fmt, epoch[, compacted])
        self._builds: Dict[str, Graph] = {}    # legacy mode + bulk loads
        # observability (tests pin these; bench_mutations reports them)
        self.rebuilds = 0               # full GraphBuilder builds
        self.compactions = 0            # delta folds back into base

    # -- mutations -------------------------------------------------------------
    def create_node(self, label: Optional[str], props: dict) -> int:
        nid = int(props["id"]) if "id" in props else self.next_id
        self.next_id = max(self.next_id, nid + 1)
        if label:
            ids = self.labels.setdefault(label, [])
            if nid not in ids:
                ids.append(nid)
        for k, v in props.items():
            if k != "id":
                self.props.setdefault(k, {})[nid] = float(v)
        self._mutated()
        return nid

    def create_edge(self, src: int, rel: str, dst: int,
                    weight: float = 1.0) -> None:
        src, dst = int(src), int(dst)
        self.next_id = max(self.next_id, src + 1, dst + 1)
        key = (rel, src, dst)
        self.rels.add(rel)
        fresh = key not in self.edges
        self.edges[key] = float(weight)
        self._oplog.append((rel, "add", src, dst, float(weight)))
        if fresh:
            pair = (src, dst)
            self._pairs[pair] = self._pairs.get(pair, 0) + 1
            if self._pairs[pair] == 1:
                self._oplog.append(("", "add", src, dst, 1.0))
        self._mutated()

    def delete_edge(self, src: int, rel: str, dst: int) -> bool:
        """Remove one edge; returns False (no-op) if it was not present."""
        src, dst = int(src), int(dst)
        if self.edges.pop((rel, src, dst), None) is None:
            return False
        self._oplog.append((rel, "del", src, dst, 0.0))
        pair = (src, dst)
        self._pairs[pair] -= 1
        if self._pairs[pair] == 0:
            del self._pairs[pair]
            self._oplog.append(("", "del", src, dst, 0.0))
        self._mutated()
        return True

    def delete_node(self, nid: int) -> int:
        """Tombstone a node: drop its incident edges, labels and props.
        Returns the number of edges removed alongside it."""
        nid = int(nid)
        incident = [k for k in self.edges if k[1] == nid or k[2] == nid]
        for rel, s, d in incident:
            self.delete_edge(s, rel, d)
        for ids in self.labels.values():
            if nid in ids:
                ids.remove(nid)
        for kv in self.props.values():
            kv.pop(nid, None)
        self._mutated()
        return len(incident)

    def _mutated(self) -> None:
        self.epoch += 1
        if not self.delta:
            self._builds.clear()        # legacy stop-the-world mode

    # -- reads -------------------------------------------------------------------
    def freeze(self, fmt: Optional[str] = None, compact: bool = False) -> Graph:
        """Snapshot-consistent frozen view at the current epoch.

        fmt=None keeps this graph's default; an explicit fmt (the sharded
        mode compacts to ELL) gets its own serving state. In delta mode the
        base matrices are built ONCE per format; later freezes catch the
        view up by applying the new op-log suffix as functional delta
        updates — a reader holding an earlier view keeps it unchanged.
        ``compact=True`` folds all pending deltas into plain base-format
        handles (mesh serving needs this — grb.distribute has no delta
        lowering). Bulk-loaded graphs (load_graph) are served as-is.
        """
        want = fmt or self.fmt
        if "external" in self._builds:
            return self._builds["external"]
        if not self.delta:
            return self._freeze_rebuild(want)
        key = (want, self.epoch, compact) if compact else (want, self.epoch)
        g = self._views.get(key)
        if g is not None:
            return g
        g = self._freeze_delta(want)
        if compact:
            g = _compact_view(g)
        # keep only the freshest view per (fmt, compact) flavor — older
        # epochs live exactly as long as their readers hold them
        self._views = {k: v for k, v in self._views.items()
                       if (k[0], len(k) > 2) != (want, compact)}
        self._views[key] = g
        return g

    # -- delta serving ---------------------------------------------------------
    def _freeze_delta(self, want: str) -> Graph:
        n = max(self.next_id, 1)
        served = self._served.get(want)
        if served is None:
            # the ONE full build this format ever pays: base matrices from
            # the current live edge set, then delta handles over them
            base = self._build_graph(want)
            g = Graph(n=base.n,
                      relations={r.name: _delta_relation(r, (n, n))
                                 for r in base.relations.values()},
                      labels=base.labels, node_props=base.node_props,
                      adj=_delta_relation(base.adj, (n, n))
                      if base.adj else None)
            self._served[want] = (len(self._oplog), g)
            return g
        idx, prev = served
        ops = self._oplog[idx:]
        by_rel: Dict[str, list] = {}
        for rel, kind, s, d, w in ops:
            by_rel.setdefault(rel, []).append((kind, s, d, w))
        relations: Dict[str, Relation] = {}
        names = set(prev.relations) | {r for r in by_rel if r != ""}
        for name in sorted(names):
            prev_rel = prev.relations.get(name)
            relations[name] = self._advance(prev_rel, name,
                                            by_rel.get(name), n)
        adj = self._advance(prev.adj, "", by_rel.get(""), n)
        g = Graph(n=n, relations=relations,
                  labels=self._label_arrays(n),
                  node_props=self._prop_arrays(n), adj=adj)
        self._served[want] = (len(self._oplog), g)
        return g

    def _advance(self, prev_rel: Optional[Relation], name: str, ops,
                 n: int) -> Optional[Relation]:
        """One relation's delta catch-up: apply the op-log suffix to the
        previous view's DeltaMatrix (functional — the previous view is
        untouched), maintaining the linked transpose twin incrementally by
        applying the src/dst-swapped ops, then compact if the pending set
        crossed the measured threshold."""
        if prev_rel is None:
            if not ops:
                return None
            # a relation born after the base build: empty ELL base, all
            # content served from the deltas until its first compaction
            empty = ELL.from_coo([], [], [], (n, n))
            fwd = DeltaMatrix.wrap(empty)
            twin = DeltaMatrix.wrap(empty)
        else:
            fwd: DeltaMatrix = prev_rel.A.store
            twin = prev_rel.A.T.store
        if ops:
            fwd = fwd.apply_ops([(k, s, d, w) for k, s, d, w in ops],
                                grow_to=(n, n))
            twin = twin.apply_ops([(k, d, s, w) for k, s, d, w in ops],
                                  grow_to=(n, n))
        elif fwd.shape[0] < n:
            fwd, twin = fwd.resize((n, n)), twin.resize((n, n))
        if needs_compaction(fwd):
            fwd, twin = fwd.compact(), twin.compact()
            self.compactions += 1
        h = grb.GBMatrix(fwd, name=name)
        h.link_transpose(grb.GBMatrix(twin, name=name + "^T"))
        return Relation(name, h, nnz=fwd.nnz)

    def _label_arrays(self, n: int) -> Dict[str, jnp.ndarray]:
        out = {}
        for label, ids in self.labels.items():
            m = np.zeros(n, dtype=bool)
            m[np.asarray(ids, dtype=np.int64)] = True
            out[label] = jnp.asarray(m)
        return out

    def _prop_arrays(self, n: int) -> Dict[str, jnp.ndarray]:
        out = {}
        for prop, kv in self.props.items():
            col = np.full(n, np.nan, np.float32)
            for k, v in kv.items():
                col[k] = v
            out[prop] = jnp.asarray(col)
        return out

    # -- legacy rebuild mode -----------------------------------------------------
    def _freeze_rebuild(self, want: str) -> Graph:
        g = self._builds.get(want)
        if g is None:
            g = self._builds[want] = self._build_graph(want)
        return g

    def _build_graph(self, want: str) -> Graph:
        self.rebuilds += 1
        n = max(self.next_id, 1)
        b = GraphBuilder(n)
        for label, ids in self.labels.items():
            b.add_label(label, ids)
        for prop, kv in self.props.items():
            b.set_prop(prop, list(kv.keys()), list(kv.values()))
        by_rel: Dict[str, list] = {rel: [] for rel in self.rels}
        for (rel, s, d), w in self.edges.items():
            by_rel.setdefault(rel, []).append((s, d, w))
        for rel, triples in by_rel.items():
            if not triples:             # schema survives an emptied relation
                b.add_edges(rel, [], [], [])
                continue
            arr = np.asarray(triples, dtype=np.float64)
            b.add_edges(rel, arr[:, 0].astype(np.int64),
                        arr[:, 1].astype(np.int64),
                        arr[:, 2].astype(np.float32))
        return b.build(fmt=want, block=self.block)


def _delta_relation(r: Relation, shape) -> Relation:
    """Wrap a freshly built relation's storage in empty-delta handles,
    keeping the builder's explicit transpose as the linked twin."""
    fwd = DeltaMatrix.wrap(r.A.store, shape)
    twin = DeltaMatrix.wrap(r.A.T.store, (shape[1], shape[0]))
    h = grb.GBMatrix(fwd, name=r.name)
    h.link_transpose(grb.GBMatrix(twin, name=r.name + "^T"))
    return Relation(r.name, h, nnz=fwd.nnz)


def _compact_view(g: Graph) -> Graph:
    """Fold every relation's deltas into plain base-format handles (the
    mesh-serving freeze: grb.distribute has no delta lowering)."""
    def plain(r: Optional[Relation]) -> Optional[Relation]:
        if r is None:
            return None
        store = r.A.store
        if not isinstance(store, DeltaMatrix):
            return r
        h = grb.GBMatrix(store.materialize(), name=r.name)
        twin = r.A.T.store
        if isinstance(twin, DeltaMatrix):
            h.link_transpose(grb.GBMatrix(twin.materialize(),
                                          name=r.name + "^T"))
        return Relation(r.name, h, nnz=r.nnz)

    return Graph(n=g.n, relations={k: plain(r)
                                   for k, r in g.relations.items()},
                 labels=g.labels, node_props=g.node_props, adj=plain(g.adj))


class Database:
    def __init__(self, data_dir: Optional[str] = None, delta: bool = True):
        self.graphs: Dict[str, MutableGraph] = {}
        self.data_dir = data_dir
        self.delta = delta
        if data_dir:
            os.makedirs(data_dir, exist_ok=True)
            self._replay_aof()

    def _graph(self, name: str) -> MutableGraph:
        return self.graphs.setdefault(name, MutableGraph(delta=self.delta))

    # -- commands ------------------------------------------------------------
    def query(self, name: str, text: str, impl: str = "auto",
              mesh=None) -> Result:
        q = parse(text)
        if isinstance(q, A.CreateQuery):
            self._append_aof(name, text)
            return self._apply_create(name, q)
        if isinstance(q, A.DeleteQuery):
            self._append_aof(name, text)
            return self._apply_delete(name, q)
        return self.context(name, impl=impl, mesh=mesh).run(q)

    def context(self, name: str, impl: str = "auto",
                mesh=None) -> ExecutionContext:
        """Public execution surface over the named graph's frozen view.

        The view is snapshot-consistent: writes issued after this call
        never appear in it (delta updates are functional). Sharded mode is
        the same surface: pass a mesh and the graph is frozen as ELL with
        pending deltas compacted (grb.distribute needs plain ELL), the
        relation handles are distributed onto the mesh, and every query
        lowers through the same `grb` calls as single-device.
        """
        mg = self._graph(name)
        g = mg.freeze(fmt="ell" if mesh is not None else None,
                      compact=mesh is not None)
        return ExecutionContext(g, impl=impl, mesh=mesh)

    def server(self, name: str, **kw) -> "QueryServer":
        """Continuous-batching server over the named graph — each batch
        serves the freshest snapshot-consistent freeze, so writes committed
        through `query()` between batches are visible to the next one
        (engine.server has the scheduler contract)."""
        from repro.engine.server import QueryServer
        return QueryServer(self._graph(name), **kw)

    def explain(self, name: str, text: str) -> str:
        return explain(self._graph(name).freeze(), text)

    def load_graph(self, name: str, graph_or_builder) -> None:
        """Bulk load a pre-built Graph (datagen path)."""
        mg = self._graph(name)
        g = graph_or_builder
        mg._builds = {"external": g}
        mg.next_id = g.n

    def _apply_create(self, name: str, q: A.CreateQuery) -> Result:
        mg = self._graph(name)
        created_n = created_e = 0
        for item in q.items:
            if isinstance(item, A.CreateNode):
                mg.create_node(item.label, item.props)
                created_n += 1
            else:
                mg.create_edge(item.src, item.rel, item.dst)
                created_e += 1
        return Result(["nodes_created", "edges_created"],
                      [(created_n, created_e)])

    def _apply_delete(self, name: str, q: A.DeleteQuery) -> Result:
        mg = self._graph(name)
        deleted_n = deleted_e = 0
        for item in q.items:
            if isinstance(item, A.DeleteNode):
                deleted_e += mg.delete_node(item.id)
                deleted_n += 1
            else:
                deleted_e += int(mg.delete_edge(item.src, item.rel,
                                                item.dst))
        return Result(["nodes_deleted", "edges_deleted"],
                      [(deleted_n, deleted_e)])

    # -- persistence (AOF) ------------------------------------------------------
    def _append_aof(self, name: str, text: str) -> None:
        if self.data_dir:
            P.append_aof(P.aof_path(self.data_dir, name), text)

    def _replay_aof(self) -> None:
        """Crash recovery: re-apply the append-only log. Every replayed
        write coalesces into the mutable host state (and, once a reader
        freezes, into deltas over ONE base build) — replay never triggers
        per-line rebuilds."""
        for name, line in P.iter_aof(self.data_dir):
            q = parse(line)
            if isinstance(q, A.DeleteQuery):
                self._apply_delete(name, q)
            else:
                self._apply_create(name, q)
