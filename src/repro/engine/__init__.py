from repro.engine.database import Database, MutableGraph
from repro.engine.persistence import load_snapshot, save_snapshot
from repro.engine.server import QueryServer

__all__ = ["Database", "MutableGraph", "QueryServer",
           "load_snapshot", "save_snapshot"]
