"""RDB-analog snapshots + the append-only file (AOF) primitives.

Snapshot + AOF tail = Redis-style point-in-time recovery: restore the
snapshot, then replay AOF entries appended after it. The AOF helpers here
(`aof_path` / `append_aof` / `iter_aof`) are the durability layer
`engine.Database` writes through: every mutating command is fsynced to the
log before acking, and replay streams the lines back for the database to
**coalesce into deltas** — the replayed writes accumulate in host state and
fold into delta matrices over one base build on first read, never one
rebuild per line (see `Database._replay_aof`).

Snapshots work unchanged on delta-served graphs: `rel.A.to_coo()` resolves
through the handle to `DeltaMatrix.to_coo`, which composes base-minus-
deletions-plus-additions — a snapshot taken mid-write-stream captures the
exact effective matrix.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.graph.graph import Graph, GraphBuilder


# -- AOF ------------------------------------------------------------------------
def aof_path(data_dir: str, name: str) -> str:
    return os.path.join(data_dir, f"{name}.aof")


def append_aof(path: str, text: str) -> None:
    """Append one mutating command, fsynced before the caller acks (the
    Redis appendfsync-always durability point)."""
    with open(path, "a") as f:
        f.write(text.replace("\n", " ") + "\n")
        f.flush()
        os.fsync(f.fileno())


def iter_aof(data_dir: str) -> Iterator[Tuple[str, str]]:
    """Yield (graph_name, command_line) across every AOF in the directory,
    in deterministic (sorted-filename, append) order — the replay stream."""
    for fn in sorted(os.listdir(data_dir)):
        if not fn.endswith(".aof"):
            continue
        name = fn[: -len(".aof")]
        with open(os.path.join(data_dir, fn)) as f:
            for line in f:
                line = line.strip()
                if line:
                    yield name, line


def save_snapshot(graph: Graph, path: str) -> None:
    """Atomic (write-temp + rename) snapshot — crash-safe like Redis RDB."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"n": np.asarray(graph.n)}
    manifest = {"n": graph.n, "relations": [], "labels": [], "props": []}
    for name, rel in graph.relations.items():
        r, c, v = rel.A.to_coo()
        payload[f"rel_{name}_r"] = r
        payload[f"rel_{name}_c"] = c
        payload[f"rel_{name}_v"] = v
        manifest["relations"].append(name)
    for name, mask in graph.labels.items():
        payload[f"label_{name}"] = np.asarray(mask)
        manifest["labels"].append(name)
    for name, col in graph.node_props.items():
        payload[f"prop_{name}"] = np.asarray(col)
        manifest["props"].append(name)
    payload["manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_snapshot(path: str, fmt: str = "auto", block: int = 64) -> Graph:
    with np.load(path) as z:
        manifest = json.loads(bytes(z["manifest"]).decode())
        n = manifest["n"]
        b = GraphBuilder(n)
        for name in manifest["labels"]:
            b.add_label(name, np.nonzero(z[f"label_{name}"])[0])
        for name in manifest["props"]:
            col = z[f"prop_{name}"]
            ids = np.nonzero(~np.isnan(col))[0]
            b.set_prop(name, ids, col[ids])
        for name in manifest["relations"]:
            b.add_edges(name, z[f"rel_{name}_r"], z[f"rel_{name}_c"],
                        z[f"rel_{name}_v"])
        return b.build(fmt=fmt, block=block)
