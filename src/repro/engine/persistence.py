"""RDB-analog snapshots: full binary dump of a frozen Graph (npz + manifest).

Snapshot + AOF tail = Redis-style point-in-time recovery: restore the
snapshot, then replay AOF entries appended after it.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Optional

import numpy as np

from repro.graph.graph import Graph, GraphBuilder


def save_snapshot(graph: Graph, path: str) -> None:
    """Atomic (write-temp + rename) snapshot — crash-safe like Redis RDB."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"n": np.asarray(graph.n)}
    manifest = {"n": graph.n, "relations": [], "labels": [], "props": []}
    for name, rel in graph.relations.items():
        r, c, v = rel.A.to_coo()
        payload[f"rel_{name}_r"] = r
        payload[f"rel_{name}_c"] = c
        payload[f"rel_{name}_v"] = v
        manifest["relations"].append(name)
    for name, mask in graph.labels.items():
        payload[f"label_{name}"] = np.asarray(mask)
        manifest["labels"].append(name)
    for name, col in graph.node_props.items():
        payload[f"prop_{name}"] = np.asarray(col)
        manifest["props"].append(name)
    payload["manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_snapshot(path: str, fmt: str = "auto", block: int = 64) -> Graph:
    with np.load(path) as z:
        manifest = json.loads(bytes(z["manifest"]).decode())
        n = manifest["n"]
        b = GraphBuilder(n)
        for name in manifest["labels"]:
            b.add_label(name, np.nonzero(z[f"label_{name}"])[0])
        for name in manifest["props"]:
            col = z[f"prop_{name}"]
            ids = np.nonzero(~np.isnan(col))[0]
            b.set_prop(name, ids, col[ids])
        for name in manifest["relations"]:
            b.add_edges(name, z[f"rel_{name}_r"], z[f"rel_{name}_c"],
                        z[f"rel_{name}_v"])
        return b.build(fmt=fmt, block=block)
