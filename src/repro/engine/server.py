"""Continuous-batching query server — 32 users per machine word.

RedisGraph serves reads with a threadpool: W workers, W concurrent queries.
The TPU analog is algebraic, not thread-based: pattern-compatible seeded
queries (equal `query.planner.signature`, different seed ids) coalesce into
ONE frontier traversal whose column dimension F is the threadpool width —
and for structural (or_and) traversals `grb` packs 32 of those boolean
columns into each uint32 word (docs/API.md §Bitmap), so one matrix sweep
answers up to 32 users per machine word.

The serving loop is continuous batching, not stop-the-world flushes:

  submit()   parse+plan through the shared `PlanCache` (repeat shapes skip
             both; the `seeds=` parameterized form keeps the text seed-free
             so every binding of one shape is a cache hit), then enqueue
             with an arrival timestamp.
  pump()     one scheduler tick. Admission control pops ONE batch off the
             queue head — signature-compatible members up to `max_width`
             TOTAL frontier columns (each query contributes its seed count,
             not "1") — pads it to packed-lane alignment, LAUNCHES it, and
             only then materializes/projects the PREVIOUS in-flight batch:
             under jax async dispatch the host schedules batch i+1 while
             the device sweeps batch i.
  flush()    drain: pump until the queue and the pipeline are empty.

Failures are isolated per query: a member whose label / relation / seed ids
do not resolve gets an error `Result` (``result.error`` set) and costs no
other tenant their answer; the queue always drains.

Serving live data: construct over an `engine.MutableGraph`, an
`engine.Database` (plus ``graph=`` name), or a zero-arg callable returning a
Graph, and every batch serves the freshest snapshot-consistent freeze (the
delta layer makes that a functional catch-up, not a rebuild). A plain frozen
`Graph` is served as-is.

Measured by `benchmarks/bench_throughput.py` (Poisson open-loop arrivals:
batched vs one-query-at-a-time queries/sec at matching p99) and pinned by
`tests/test_server.py` (batched ≡ solo differential grid).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import grb
from repro.graph.graph import Graph
from repro.query.executor import (ExecutionContext, Result, empty_result,
                                  resolve_seeds)
from repro.query.planner import Plan, PlanCache

# Serving policy constants (docs/API.md §Serving has the measured table):
# MAX_WIDTH caps a sweep's total frontier columns — admission is by width,
# not query count, so many multi-seed queries can't flatten into an
# unbounded frontier. 512 keeps the s10/s11 sweep under the measured
# latency knee while still filling 16 packed words.
MAX_WIDTH = 512
# Sweep widths round up to whole uint32 words once they'd pack (32 lanes),
# else to AUTO_PACK_MIN_WIDTH: bounded shape churn (at most MAX_WIDTH/32
# distinct widths reach the compiler) and full-word packed sweeps. Padded
# lanes are keep=False columns; stats["pack_ratio"] reports utilization.
LANE_ALIGN = 32


@dataclasses.dataclass
class Submitted:
    """One queued query and, once served, its per-query serving record."""
    qid: int
    plan: Plan
    sig: tuple
    t_submit: float                     # perf_counter clock
    width: int                          # admission width: seed columns asked
    result: Optional[Result] = None
    wait_s: float = 0.0                 # queue wait: submit -> batch launch
    latency_s: float = 0.0              # submit -> result materialized


@dataclasses.dataclass
class _Batch:
    """A launched sweep: in-flight device work + the host state to finish
    it. `error` marks a launch-time failure (finish() isolates it)."""
    members: List[Submitted]            # live members, column-sliced in order
    failed: List[Submitted]             # per-member launch failures (result set)
    ctx: ExecutionContext
    seed_lists: List[np.ndarray]
    B: Optional[object]                 # (n, F) device frontier, or None
    error: Optional[Exception]
    solo: bool                          # unseeded singleton (stats bucket)


def _error_result(e: Exception) -> Result:
    return Result(columns=[], rows=[], error=f"{type(e).__name__}: {e}")


def _aligned(width: int) -> int:
    a = LANE_ALIGN if width >= LANE_ALIGN else grb.AUTO_PACK_MIN_WIDTH
    return -(-width // a) * a


class QueryServer:
    """Continuous-batching scheduler over `ExecutionContext`.

    source     Graph (static) | MutableGraph | Database (+ graph=name) |
               zero-arg callable -> Graph. Non-Graph sources are re-frozen
               per batch, so writes committed between batches are served.
    max_width  admission cap: total frontier columns per sweep.
    max_batch  secondary cap on member count per sweep.
    align      pad sweep widths to packed-lane alignment (LANE_ALIGN).
    """

    def __init__(self, source, impl: str = "auto", max_batch: int = 512,
                 max_width: int = MAX_WIDTH, align: bool = True,
                 graph: Optional[str] = None, mesh=None):
        self._source = source
        self._graph_name = graph
        self.impl = impl
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_width = max_width
        self.align = align
        self._plans = PlanCache()
        self._queue: List[Submitted] = []
        self._inflight: Optional[_Batch] = None
        self._ctx: Optional[ExecutionContext] = None
        self._next_id = 0
        self.log: List[Submitted] = []      # completed queries, in order
        self.stats = {
            "queries": 0, "batches": 0, "solo": 0, "errors": 0,
            "batched_width_total": 0, "batch_width_max": 0,
            "plan_cache_hits": 0, "plan_cache_misses": 0,
            "plan_cache_hit_rate": 0.0,
            "pack_lanes": 0, "pack_slots": 0, "pack_ratio": 1.0,
            "queue_wait_s_total": 0.0,
            # device->host gathers attributable to serving (grb.host_transfers
            # delta since server construction); the batched or_and sweep
            # promises this stays 0 — tests/test_transfers.py pins it
            "host_transfers": 0,
        }
        self._xfer0 = grb.host_transfers()
        self._refresh()                     # fail fast on a bad source

    # -- submission -----------------------------------------------------------
    def submit(self, text: str, seeds=None,
               arrival_s: Optional[float] = None) -> int:
        """Queue one read query; returns its qid (the key in flush()'s
        result dict). ``seeds=`` is the parameterized form: the text is the
        seed-free shape template (cached once), the ids bind per call.
        ``arrival_s`` (perf_counter clock) backdates arrival for open-loop
        load replay; it defaults to now. Parse/plan errors raise here, to
        the submitter — they never reach the queue."""
        p, sig = self._plans.get(text)
        self.stats["plan_cache_hits"] = self._plans.hits
        self.stats["plan_cache_misses"] = self._plans.misses
        self.stats["plan_cache_hit_rate"] = self._plans.hit_rate
        if seeds is not None:
            p = dataclasses.replace(p, seeds=[int(s) for s in seeds])
        s = Submitted(self._next_id, p, sig,
                      arrival_s if arrival_s is not None
                      else time.perf_counter(),
                      len(p.seeds) if p.seeds is not None else 0)
        self._next_id += 1
        self._queue.append(s)
        return s.qid

    @property
    def pending(self) -> int:
        """Queries queued or in flight (not yet projected)."""
        inflight = (len(self._inflight.members) + len(self._inflight.failed)
                    if self._inflight is not None else 0)
        return len(self._queue) + inflight

    # -- the serving loop -----------------------------------------------------
    def pump(self) -> Dict[int, Result]:
        """One continuous-batching tick: launch the next admission-
        controlled batch, then finish the previously launched one while the
        new sweep runs on the device. Returns the queries completed this
        tick (usually the previous batch). Never raises per-query errors —
        they come back as error Results."""
        out: Dict[int, Result] = {}
        nxt: Optional[_Batch] = None
        chunk = self._next_chunk()
        if chunk:
            try:
                ctx = self._refresh()
                nxt = self._launch(ctx, chunk)
            except Exception as e:            # snapshot/refresh failure
                t0 = time.perf_counter()
                for m in chunk:
                    m.wait_s = t0 - m.t_submit
                self.stats["queries"] += len(chunk)
                nxt = _Batch(chunk, [], self._ctx, [], None, e,
                             chunk[0].plan.seeds is None)
        if self._inflight is not None:
            self._finish(self._inflight, out)
        self._inflight = nxt
        return out

    def flush(self) -> Dict[int, Result]:
        """Execute everything queued (and in flight); the queue always
        drains — per-query failures land as error Results, never as a
        flush-wide exception."""
        out: Dict[int, Result] = {}
        while self._queue or self._inflight is not None:
            out.update(self.pump())
        return out

    # -- scheduler internals --------------------------------------------------
    def _refresh(self) -> ExecutionContext:
        """Context over the freshest snapshot-consistent frozen view. The
        freeze is cached per epoch upstream, so an unchanged graph reuses
        the same ExecutionContext (and its hop-matrix caches)."""
        g = self._snapshot_graph()
        if self._ctx is None or self._ctx.graph is not g:
            self._ctx = ExecutionContext(g, impl=self.impl, mesh=self.mesh)
        return self._ctx

    def _snapshot_graph(self) -> Graph:
        src = self._source
        if isinstance(src, Graph):
            return src
        if callable(src):                   # refresh hook
            return src()
        fmt = "ell" if self.mesh is not None else None
        if hasattr(src, "freeze"):          # MutableGraph
            return src.freeze(fmt=fmt, compact=self.mesh is not None)
        if hasattr(src, "graphs"):          # Database
            if self._graph_name is None:
                raise TypeError("QueryServer(Database) needs graph=<name> "
                                "(or use Database.server(name))")
            return src._graph(self._graph_name).freeze(
                fmt=fmt, compact=self.mesh is not None)
        raise TypeError(
            f"cannot serve {type(src).__name__}: expected Graph, "
            f"MutableGraph, Database (+graph=), or a callable -> Graph")

    def _next_chunk(self) -> List[Submitted]:
        """Admission control: pop one batch off the queue head. Unseeded
        (label-scan) queries ride alone; seeded ones coalesce with every
        queued signature-equal member, in arrival order, until the chunk
        holds `max_batch` members or `max_width` total frontier columns.
        A single query wider than the cap still runs — alone."""
        if not self._queue:
            return []
        head = self._queue[0]
        if head.plan.seeds is None:
            self._queue = self._queue[1:]
            return [head]
        take, rest, width = [head], [], head.width
        for s in self._queue[1:]:
            if (len(take) < self.max_batch and s.sig == head.sig
                    and s.plan.seeds is not None
                    and width + s.width <= self.max_width):
                take.append(s)
                width += s.width
            else:
                rest.append(s)
        self._queue = rest
        return take

    def _launch(self, ctx: ExecutionContext,
                members: List[Submitted]) -> _Batch:
        """Resolve the chunk's seeds and enqueue its device sweep. Member-
        specific failures (bad seed ids) drop only that member; chunk-level
        failures (unknown label/relation — shared by construction, the
        members are signature-equal) mark the batch for finish() to
        isolate. Does NOT block on the device."""
        t0 = time.perf_counter()
        solo = members[0].plan.seeds is None
        for m in members:
            m.wait_s = t0 - m.t_submit
        b = _Batch(members, [], ctx, [], None, None, solo)
        p0 = members[0].plan
        try:
            src_mask = ctx.node_mask(p0.src_label,
                                     p0.var_preds.get(p0.src_var))
        except Exception as e:
            b.error = e
            src_mask = None
        if src_mask is not None:
            live: List[Submitted] = []
            for m in members:
                try:
                    s = (resolve_seeds(m.plan, src_mask)
                         if m.plan.seeds is not None else
                         np.nonzero(src_mask)[0])
                except Exception as e:
                    m.result = _error_result(e)
                    b.failed.append(m)
                    continue
                live.append(m)
                b.seed_lists.append(s)
            b.members = live
        width = int(sum(len(s) for s in b.seed_lists))
        if width:
            flat = np.concatenate(b.seed_lists)
            pad = (_aligned(width) - width) if self.align else 0
            keep = None
            if pad:
                flat = np.concatenate([flat, np.zeros(pad, np.int64)])
                keep = np.ones(len(flat), dtype=bool)
                keep[width:] = False
            try:
                b.B = ctx.traverse(p0, flat, keep=keep)
            except Exception as e:
                b.error = e
        # serving metrics (lanes are counted at launch, where padding is)
        self.stats["queries"] += len(members)
        if solo:
            self.stats["solo"] += 1
        else:
            self.stats["batches"] += 1
            self.stats["batched_width_total"] += width
            self.stats["batch_width_max"] = max(
                self.stats["batch_width_max"], width)
            if width and b.error is None:   # lanes of sweeps actually run
                self.stats["pack_lanes"] += width
                self.stats["pack_slots"] += (_aligned(width) if self.align
                                             else width)
                self.stats["pack_ratio"] = (self.stats["pack_lanes"]
                                            / self.stats["pack_slots"])
        self.stats["queue_wait_s_total"] += sum(m.wait_s for m in members)
        return b

    def _finish(self, b: _Batch, out: Dict[int, Result]) -> None:
        """Materialize a launched batch (blocks on the device) and project
        each member's columns. A batch-level launch error degrades to
        per-member solo retries, so one bad tenant never answers for the
        others; per-member projection errors stay per-member."""
        if b.error is not None:
            for m in b.members:
                try:
                    if b.ctx is None:       # snapshot refresh itself failed
                        raise b.error
                    m.result = b.ctx.run(m.plan)
                except Exception as e:
                    m.result = _error_result(e)
        elif b.B is not None:
            Bn = np.asarray(b.B)
            off = 0
            for m, seeds in zip(b.members, b.seed_lists):
                w = len(seeds)
                try:
                    m.result = (b.ctx.project(m.plan, seeds,
                                              Bn[:, off:off + w])
                                if w else empty_result(m.plan))
                except Exception as e:
                    m.result = _error_result(e)
                off += w
        else:                               # every member resolved empty
            for m in b.members:
                m.result = empty_result(m.plan)
        t1 = time.perf_counter()
        for m in b.members + b.failed:
            m.latency_s = t1 - m.t_submit
            if m.result.error is not None:
                self.stats["errors"] += 1
            out[m.qid] = m.result
            self.log.append(m)
        self.stats["host_transfers"] = grb.host_transfers() - self._xfer0
