"""Batched query server — the TPU analog of RedisGraph's threadpool.

RedisGraph: the Redis main thread accepts queries; a threadpool of W workers
executes them one-query-one-thread for throughput.  TPU analog: an accept
queue groups *pattern-compatible* queries (same plan signature, different
seeds) and executes each group as ONE batched frontier traversal — the F
dimension of the frontier matrix is the threadpool width.  Incompatible
queries fall back to solo execution (a width-1 batch).

The scheduler drives the executor's public `ExecutionContext` surface
(node_mask / seed_frontier / expand / project) — the same primitives the
solo path composes, so batched and solo answers are definitionally the same
algebra.

This is the serving driver used by examples/serve_queries.py and the
throughput benchmark (the paper's "reads scale easily" claim).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import semiring as S
from repro.graph.graph import Graph
from repro.query import qast as A
from repro.query.executor import ExecutionContext, Result
from repro.query.parser import parse
from repro.query.planner import Plan, plan


@dataclasses.dataclass
class Submitted:
    qid: int
    plan: Plan
    result: Optional[Result] = None
    latency_s: float = 0.0


def _signature(p: Plan):
    return (p.src_var, p.src_label,
            tuple((e.rel, e.direction, e.min_hops, e.max_hops,
                   e.dst_var, e.dst_label) for e in p.expands),
            p.semiring,
            tuple((r.kind, r.var, r.prop, r.distinct, r.alias)
                  for r in p.returns),
            p.limit,
            tuple(sorted((v, len(ps)) for v, ps in p.var_preds.items())))


class QueryServer:
    def __init__(self, graph: Graph, impl: str = "auto",
                 max_batch: int = 512):
        self.graph = graph
        self.ctx = ExecutionContext(graph, impl=impl)
        self.max_batch = max_batch
        self._queue: List[Submitted] = []
        self._next_id = 0
        self.stats = {"batches": 0, "queries": 0, "solo": 0,
                      "batched_width_total": 0}

    def submit(self, text: str) -> int:
        p = plan(parse(text))
        s = Submitted(self._next_id, p)
        self._next_id += 1
        self._queue.append(s)
        return s.qid

    def flush(self) -> Dict[int, Result]:
        """Execute everything queued; group compatible seeded queries."""
        groups: Dict[tuple, List[Submitted]] = {}
        solo: List[Submitted] = []
        for s in self._queue:
            if s.plan.seeds is not None:
                groups.setdefault(_signature(s.plan), []).append(s)
            else:
                solo.append(s)
        out: Dict[int, Result] = {}
        for sig, members in groups.items():
            for start in range(0, len(members), self.max_batch):
                chunk = members[start:start + self.max_batch]
                self._run_batch(chunk, out)
        for s in solo:
            t0 = time.perf_counter()
            res = self.ctx.run(_requery(s.plan))
            s.latency_s = time.perf_counter() - t0
            out[s.qid] = res
            self.stats["solo"] += 1
            self.stats["queries"] += 1
        self._queue.clear()
        return out

    def _run_batch(self, members: List[Submitted], out: Dict[int, Result]):
        """One batched frontier traversal answers every member's query."""
        ctx = self.ctx
        p0 = members[0].plan
        t0 = time.perf_counter()

        seed_lists = [sorted(set(m.plan.seeds)) for m in members]
        flat = np.concatenate([np.asarray(s, np.int64) for s in seed_lists])
        src_mask = ctx.node_mask(p0.src_label, p0.var_preds.get(p0.src_var))
        keep = src_mask[flat]

        sr = S.get(p0.semiring)
        f = len(flat)
        B = ctx.seed_frontier(flat, keep=keep)
        for e in p0.expands:
            dst_mask = ctx.node_mask(e.dst_label, p0.var_preds.get(e.dst_var))
            B = ctx.expand(B, e, sr, dst_mask)
        B = np.asarray(B)

        dt = time.perf_counter() - t0
        off = 0
        for m, seeds in zip(members, seed_lists):
            w = len(seeds)
            sub = B[:, off:off + w]
            kept = np.asarray(seeds)[keep[off:off + w]]
            subk = sub[:, keep[off:off + w]]
            m.result = ctx.project(m.plan, kept, subk)
            m.latency_s = dt
            out[m.qid] = m.result
            off += w
        self.stats["batches"] += 1
        self.stats["queries"] += len(members)
        self.stats["batched_width_total"] += f


def _requery(p: Plan):
    """Rebuild a MatchQuery from a plan (solo fallback path)."""
    nodes = [A.NodePat(p.src_var, p.src_label, {})]
    edges = []
    for e in p.expands:
        edges.append(A.EdgePat(None, e.rel, e.direction, e.min_hops, e.max_hops))
        nodes.append(A.NodePat(e.dst_var, e.dst_label, {}))
    where = []
    for v, preds in p.var_preds.items():
        where.extend(preds)
    if p.seeds is not None:
        where.append(A.InSeeds(p.src_var, list(p.seeds)))
    return A.MatchQuery(nodes, edges, where, p.returns, p.limit)
