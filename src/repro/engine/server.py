"""Batched query server — the TPU analog of RedisGraph's threadpool.

RedisGraph: the Redis main thread accepts queries; a threadpool of W workers
executes them one-query-one-thread for throughput.  TPU analog: an accept
queue groups *pattern-compatible* queries (same plan signature, different
seeds) and executes each group as ONE batched frontier traversal — the F
dimension of the frontier matrix is the threadpool width.  Incompatible
queries fall back to solo execution (a width-1 batch).

This is the serving driver used by examples/serve_queries.py and the
throughput benchmark (the paper's "reads scale easily" claim).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.graph.graph import Graph
from repro.query import qast as A
from repro.query.executor import Result, _node_mask, _project, execute
from repro.query.parser import parse
from repro.query.planner import Plan, plan

import jax.numpy as jnp

from repro.core import ops, semiring as S
from repro.query.executor import _expand


@dataclasses.dataclass
class Submitted:
    qid: int
    plan: Plan
    result: Optional[Result] = None
    latency_s: float = 0.0


def _signature(p: Plan):
    return (p.src_var, p.src_label,
            tuple((e.rel, e.direction, e.min_hops, e.max_hops,
                   e.dst_var, e.dst_label) for e in p.expands),
            p.semiring,
            tuple((r.kind, r.var, r.prop, r.distinct, r.alias)
                  for r in p.returns),
            p.limit,
            tuple(sorted((v, len(ps)) for v, ps in p.var_preds.items())))


class QueryServer:
    def __init__(self, graph: Graph, impl: str = "auto",
                 max_batch: int = 512):
        self.graph = graph
        self.impl = impl
        self.max_batch = max_batch
        self._queue: List[Submitted] = []
        self._next_id = 0
        self.stats = {"batches": 0, "queries": 0, "solo": 0,
                      "batched_width_total": 0}

    def submit(self, text: str) -> int:
        p = plan(parse(text))
        s = Submitted(self._next_id, p)
        self._next_id += 1
        self._queue.append(s)
        return s.qid

    def flush(self) -> Dict[int, Result]:
        """Execute everything queued; group compatible seeded queries."""
        groups: Dict[tuple, List[Submitted]] = {}
        solo: List[Submitted] = []
        for s in self._queue:
            if s.plan.seeds is not None:
                groups.setdefault(_signature(s.plan), []).append(s)
            else:
                solo.append(s)
        out: Dict[int, Result] = {}
        for sig, members in groups.items():
            for start in range(0, len(members), self.max_batch):
                chunk = members[start:start + self.max_batch]
                self._run_batch(chunk, out)
        for s in solo:
            t0 = time.perf_counter()
            res = execute(self.graph, _requery(s.plan), impl=self.impl)
            s.latency_s = time.perf_counter() - t0
            out[s.qid] = res
            self.stats["solo"] += 1
            self.stats["queries"] += 1
        self._queue.clear()
        return out

    def _run_batch(self, members: List[Submitted], out: Dict[int, Result]):
        """One batched frontier traversal answers every member's query."""
        g = self.graph
        n = g.n
        p0 = members[0].plan
        t0 = time.perf_counter()

        seed_lists = [sorted(set(m.plan.seeds)) for m in members]
        flat = np.concatenate([np.asarray(s, np.int64) for s in seed_lists])
        src_mask = _node_mask(g, p0.src_label, p0.var_preds.get(p0.src_var), n)
        keep = src_mask[flat]

        sr = S.get(p0.semiring)
        f = len(flat)
        B = jnp.zeros((n, f), dtype=jnp.float32)
        cols = jnp.arange(f)
        B = B.at[jnp.asarray(np.where(keep, flat, 0)), cols].set(
            jnp.asarray(keep.astype(np.float32)))
        for e in p0.expands:
            dst_mask = _node_mask(g, e.dst_label, p0.var_preds.get(e.dst_var), n)
            B = _expand(g, B, e, sr, dst_mask, self.impl)
        B = np.asarray(B)

        dt = time.perf_counter() - t0
        off = 0
        for m, seeds in zip(members, seed_lists):
            w = len(seeds)
            sub = B[:, off:off + w]
            kept = np.asarray(seeds)[keep[off:off + w]]
            subk = sub[:, keep[off:off + w]]
            m.result = _project(g, m.plan, kept, jnp.asarray(subk))
            m.latency_s = dt
            out[m.qid] = m.result
            off += w
        self.stats["batches"] += 1
        self.stats["queries"] += len(members)
        self.stats["batched_width_total"] += f


def _requery(p: Plan):
    """Rebuild a MatchQuery from a plan (solo fallback path)."""
    nodes = [A.NodePat(p.src_var, p.src_label, {})]
    edges = []
    for e in p.expands:
        edges.append(A.EdgePat(None, e.rel, e.direction, e.min_hops, e.max_hops))
        nodes.append(A.NodePat(e.dst_var, e.dst_label, {}))
    where = []
    for v, preds in p.var_preds.items():
        where.extend(preds)
    if p.seeds is not None:
        where.append(A.InSeeds(p.src_var, list(p.seeds)))
    return A.MatchQuery(nodes, edges, where, p.returns, p.limit)
