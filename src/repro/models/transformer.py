"""Decoder-only transformer: qwen2*, gemma*, mixtral/llama4 (MoE), and the
llava backbone. Scan-over-layers (compact HLO, fast SPMD compiles) + optional
remat; gemma2 local/global alternation and softcaps; MoE blocks per config.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distr.shardctx import shard
from repro.models import layers as L
from repro.models.base import (ModelBundle, cross_entropy, dtype_of,
                               token_specs)


def _flavor(cfg: ModelConfig, layer_local: bool) -> L.AttnFlavor:
    window = cfg.sliding_window if (cfg.sliding_window and
                                    (not cfg.local_global_alternating or
                                     layer_local)) else 0
    return L.AttnFlavor(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias,
        attn_softcap=cfg.attn_softcap, sliding_window=window)


def _stack(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def param_specs(cfg: ModelConfig):
    dt = dtype_of(cfg)
    block = {
        "ln1": L.spec((cfg.d_model,), dt),
        "ln2": L.spec((cfg.d_model,), dt),
        "attn": L.attn_specs(cfg.d_model, _flavor(cfg, True), dt),
    }
    if cfg.family == "moe":
        block["moe"] = L.moe_specs(cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        block["mlp"] = L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    p = {
        "embed": L.embed_specs(cfg.vocab, cfg.d_model, dt, cfg.tie_embeddings),
        "layers": _stack(block, cfg.n_layers),
        "ln_f": L.spec((cfg.d_model,), dt),
    }
    if cfg.family == "llava":
        p["vision_proj"] = L.spec((cfg.d_frontend, cfg.d_model), dt)
    return p


def _layer(cfg: ModelConfig, p, h, layer_idx, positions, cache, cache_slot,
           kv_positions, kv_chunk):
    # gemma2: even layers sliding-window ("local"), odd layers global.
    # Implemented as a *runtime* window scalar (§Perf T8) — a lax.cond here
    # duplicated every cache/attention buffer into both branches.
    if cfg.local_global_alternating:
        fl = _flavor(cfg, False)          # window applied at runtime
        window_rt = jnp.where(layer_idx % 2 == 0, cfg.sliding_window, 0)
    else:
        fl = _flavor(cfg, True)
        window_rt = None
    attn_out, new_cache = L.attention(
        p["attn"], L.rmsnorm(h, p["ln1"]), fl,
        positions=positions, cache=cache, cache_slot=cache_slot,
        kv_positions=kv_positions, kv_chunk=kv_chunk,
        window_runtime=window_rt)
    h = h + attn_out
    hn = L.rmsnorm(h, p["ln2"])
    if cfg.family == "moe":
        ff = L.moe_mlp(p["moe"], hn, cfg.n_experts, cfg.experts_per_token,
                       cfg.moe_capacity_factor)
    else:
        ff = L.mlp(p["mlp"], hn, cfg.mlp)
    h = h + ff
    h = shard(h, "batch", None, "embed")
    return h, new_cache


def forward(cfg: ModelConfig, params, h, positions, caches=None,
            cache_slot=None, kv_positions=None, kv_chunk: int = 0):
    kv_chunk = kv_chunk or cfg.kv_chunk
    """h: (B, S, D) embedded input. caches: None or (k, v) stacked (L, ...)."""
    decode = caches is not None

    def body(carry, xs):
        if decode:
            # §Perf T9: stacked caches ride the scan CARRY (while-loop
            # carries alias across iterations => one cache buffer), not
            # xs->ys (which keeps input AND output stacks live: 2x cache).
            h, ck_all, cv_all = carry
            lp, idx = xs
            ck = jax.lax.dynamic_index_in_dim(ck_all, idx, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, idx, keepdims=False)
            hh, new_cache = _layer(cfg, lp, h, idx, positions, (ck, cv),
                                   cache_slot, kv_positions, kv_chunk)
            ck_all = jax.lax.dynamic_update_index_in_dim(
                ck_all, new_cache[0], idx, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(
                cv_all, new_cache[1], idx, 0)
            return (hh, ck_all, cv_all), None
        h = carry
        lp, idx = xs
        hh, _ = _layer(cfg, lp, h, idx, positions, None, None, None, kv_chunk)
        return hh, None

    if cfg.remat and not decode:
        body = jax.checkpoint(body)

    idxs = jnp.arange(cfg.n_layers)
    if decode:
        (h, ck_all, cv_all), _ = jax.lax.scan(
            body, (h, caches[0], caches[1]), (params["layers"], idxs),
            unroll=cfg.n_layers if cfg.scan_unroll else 1)
        new_caches = (ck_all, cv_all)
    else:
        h, _ = jax.lax.scan(body, h, (params["layers"], idxs),
                          unroll=cfg.n_layers if cfg.scan_unroll else 1)
        new_caches = None
    h = L.rmsnorm(h, params["ln_f"])
    return h, new_caches


def _embed_batch(cfg, params, batch):
    h = L.embed(params["embed"], batch["tokens"], cfg.d_model, cfg.embed_scale)
    if cfg.family == "llava":
        patches = batch["patches"].astype(h.dtype) @ params["vision_proj"]
        h = jnp.concatenate([patches, h], axis=1)
    return h


def loss_fn(cfg: ModelConfig, params, batch):
    h = _embed_batch(cfg, params, batch)
    positions = jnp.arange(h.shape[1])
    h, _ = forward(cfg, params, h, positions)
    logits = L.unembed(params["embed"], h, cfg.logit_softcap,
                       cfg.tie_embeddings)
    labels = batch["labels"]
    if cfg.family == "llava":   # image positions carry no next-token loss
        pad = jnp.full((labels.shape[0], cfg.n_image_tokens), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return cross_entropy(logits, labels)


# -- serving ----------------------------------------------------------------------
def _ring(cfg: ModelConfig) -> bool:
    """Ring-buffer (window-capped) cache only for pure-SWA archs: gemma2's
    alternating global layers need the full-length cache."""
    return bool(cfg.sliding_window) and not cfg.local_global_alternating


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    dt = dtype_of(cfg)
    eff = min(seq, cfg.sliding_window) if _ring(cfg) else seq
    shape = (cfg.n_layers, batch, eff, cfg.n_kv_heads, cfg.head_dim)
    return (jax.ShapeDtypeStruct(shape, dt), jax.ShapeDtypeStruct(shape, dt))


def decode_fn(cfg: ModelConfig, params, caches, batch, pos, kv_chunk=0):
    kv_chunk = kv_chunk or cfg.kv_chunk
    """One decode step. batch = {"tokens": (B, 1)}; pos: scalar global
    position. SWA archs address the cache ring-buffer style (pos % window)."""
    h = L.embed(params["embed"], batch["tokens"], cfg.d_model, cfg.embed_scale)
    T = caches[0].shape[2]
    ring = _ring(cfg)
    slot = pos % T if ring else pos
    kv_positions = L.cache_kv_positions(pos, T, ring)
    positions = jnp.asarray([pos])
    h, new_caches = forward(cfg, params, h, positions, caches=caches,
                            cache_slot=slot, kv_positions=kv_positions,
                            kv_chunk=kv_chunk)
    logits = L.unembed(params["embed"], h, cfg.logit_softcap,
                       cfg.tie_embeddings)
    return logits, new_caches


def prefill_fn(cfg: ModelConfig, params, batch, kv_chunk=0):
    kv_chunk = kv_chunk or cfg.kv_chunk
    """Prefill = the training forward minus loss; returns last-position
    logits. (Cache writeback during prefill is fused in serve/serve_step.)"""
    h = _embed_batch(cfg, params, batch)
    positions = jnp.arange(h.shape[1])
    h, _ = forward(cfg, params, h, positions, kv_chunk=kv_chunk)
    logits = L.unembed(params["embed"], h[:, -1:], cfg.logit_softcap,
                       cfg.tie_embeddings)
    return logits, None


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    specs = token_specs(shape.global_batch, shape.seq_len)
    if cfg.family == "llava":
        specs["patches"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_image_tokens, cfg.d_frontend),
            jnp.bfloat16)
        # text tokens fill the remaining sequence budget
        specs["tokens"] = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len - cfg.n_image_tokens), jnp.int32)
        specs["labels"] = specs["tokens"]
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}


def build(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        param_specs=functools.partial(param_specs, cfg),
        loss_fn=functools.partial(loss_fn, cfg),
        train_input_specs=functools.partial(train_input_specs, cfg),
        prefill_fn=functools.partial(prefill_fn, cfg),
        decode_fn=functools.partial(decode_fn, cfg),
        cache_specs=functools.partial(cache_specs, cfg),
        decode_input_specs=functools.partial(decode_input_specs, cfg),
    )
