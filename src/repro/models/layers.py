"""Shared transformer building blocks (functional, dict-of-arrays params).

Attention uses a chunked online-softmax (flash-attention pattern) scan over
KV blocks so the (S, T) score matrix is never materialized — mandatory for
the 32k prefill shapes and HLO-compact (lax.scan) for fast SPMD compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distr.shardctx import shard

NEG_INF = -1e30


# -- helpers -------------------------------------------------------------------
def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x, positions, theta: float):
    """x: (..., S, n, h); positions: (S,) broadcast over batch/heads."""
    h = x.shape[-1]
    half = h // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs        # (S, half)
    cos = jnp.cos(ang)[:, None, :]                              # (S, 1, half)
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention ---------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AttnFlavor:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    sliding_window: int = 0      # 0 = full
    causal: bool = True
    use_rope: bool = True


def attn_specs(d_model: int, fl: AttnFlavor, dtype, prefix=()):
    H, K, h = fl.n_heads, fl.n_kv_heads, fl.head_dim
    p = {
        "wq": spec((d_model, H * h), dtype),
        "wk": spec((d_model, K * h), dtype),
        "wv": spec((d_model, K * h), dtype),
        "wo": spec((H * h, d_model), dtype),
    }
    if fl.qkv_bias:
        p.update({"bq": spec((H * h,), dtype), "bk": spec((K * h,), dtype),
                  "bv": spec((K * h,), dtype)})
    return p


def _proj_qkv(p, x, fl: AttnFlavor):
    B, S, _ = x.shape
    H, K, h = fl.n_heads, fl.n_kv_heads, fl.head_dim
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    return (q.reshape(B, S, K, H // K, h), k.reshape(B, S, K, h),
            v.reshape(B, S, K, h))


def chunked_attention(q, k, v, *, q_positions, kv_positions, fl: AttnFlavor,
                      kv_chunk: int = 1024, softcap_val: float = 0.0,
                      window_runtime=None):
    """Online-softmax attention.

    q: (B, S, K, G, h);  k, v: (B, T, K, h)
    q_positions: (S,), kv_positions: (T,) — global token positions for the
    causal / sliding-window masks (valid entries >= 0; padding marked -1).
    """
    B, S, K, G, h = q.shape
    T = k.shape[1]
    if S == 1:
        # Decode: chunking buys nothing (the S x T score tensor is 1 x T) and
        # the chunk reshape on a sequence-sharded KV cache forces GSPMD to
        # all-gather the ENTIRE cache per layer (§Perf T3: 1.2 s collective
        # on zamba2 long_500k). Single chunk keeps the cache sharded.
        kv_chunk = 0
    C = min(kv_chunk, T) if kv_chunk else T
    pad = (-T) % C
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    nchunks = (T + pad) // C
    kc = k.reshape(B, nchunks, C, K, h).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, C, K, h).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(nchunks, C)

    scale = 1.0 / np.sqrt(h)
    qf = (q * scale).astype(jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kch, vch, pch = xs
        logits = jnp.einsum("bskgh,bckh->bskgc", qf, kch.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, softcap_val)
        valid = pch[None, :] >= 0                              # (1, C)
        if fl.causal:
            causal = q_positions[:, None] >= pch[None, :]      # (S, C)
            valid = valid & causal
        if fl.sliding_window:
            inwin = q_positions[:, None] - pch[None, :] < fl.sliding_window
            valid = valid & inwin
        if window_runtime is not None:
            # traced per-layer window (gemma2 local/global alternation, §Perf
            # T8): a data-dependent mask instead of lax.cond'd twin attention
            # branches, which duplicated every cache/attention buffer.
            inwin = (q_positions[:, None] - pch[None, :]) < window_runtime
            valid = valid & (jnp.asarray(window_runtime <= 0) | inwin)
        logits = jnp.where(valid[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p_ = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p_.sum(axis=-1)
        pv = jnp.einsum("bskgc,bckh->bskgh", p_, vch.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, S, K, G), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, S, K, G), dtype=jnp.float32)
    a0 = jnp.zeros((B, S, K, G, h), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def attention(p, x, fl: AttnFlavor, *, positions, cache=None, cache_slot=None,
              kv_positions=None, kv_chunk: int = 1024, window_runtime=None):
    """Full attention layer.

    Training/prefill: cache=None, positions (S,).
    Decode: cache=(k,v) of (B, T, K, h); x is (B, 1, D); cache_slot is the
    write index (ring-buffer slot for SWA archs); kv_positions (T,) gives the
    *global* token position held by each cache slot (-1 = empty).
    """
    B, S, _ = x.shape
    q, k, v = _proj_qkv(p, x, fl)
    if fl.use_rope:
        q = rope(q.reshape(B, S, -1, fl.head_dim), positions, fl.rope_theta
                 ).reshape(q.shape)
        k = rope(k, positions, fl.rope_theta)
    if cache is None:
        q = shard(q, "batch", "seq_shard", None, None, None)
        out = chunked_attention(q, k, v, q_positions=positions,
                                kv_positions=positions, fl=fl,
                                kv_chunk=kv_chunk,
                                window_runtime=window_runtime)
    else:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_slot, axis=1)
        out = chunked_attention(q, ck, cv, q_positions=positions,
                                kv_positions=kv_positions, fl=fl,
                                kv_chunk=kv_chunk,
                                window_runtime=window_runtime)
        cache = (ck, cv)
    out = out.reshape(B, S, fl.n_heads * fl.head_dim)
    out = out @ p["wo"]
    return (out, cache)


def cache_kv_positions(pos, T: int, ring: bool):
    """Global position held by each cache slot after writing step `pos`.

    Linear cache: slot i holds position i (filled iff i <= pos).
    Ring cache (SWA window == T): slot i holds the newest position p <= pos
    with p % T == i.
    """
    idx = jnp.arange(T)
    if not ring:
        return jnp.where(idx <= pos, idx, -1)
    p = pos - ((pos - idx) % T)
    return jnp.where(p >= 0, p, -1)


# -- MLPs --------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int, kind: str, dtype):
    if kind in ("swiglu", "geglu"):
        return {"wg": spec((d_model, d_ff), dtype),
                "wu": spec((d_model, d_ff), dtype),
                "wd": spec((d_ff, d_model), dtype)}
    return {"wu": spec((d_model, d_ff), dtype),
            "wd": spec((d_ff, d_model), dtype)}


def mlp(p, x, kind: str):
    if kind == "swiglu":
        hidden = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif kind == "geglu":
        hidden = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])
    else:
        hidden = jax.nn.gelu(x @ p["wu"], approximate=True)
    hidden = shard(hidden, "batch", None, "ff")
    return hidden @ p["wd"]


# -- MoE (mixtral / llama4) ----------------------------------------------------------
def moe_specs(d_model: int, d_ff: int, n_experts: int, dtype):
    return {"router": spec((d_model, n_experts), jnp.float32),
            "wg": spec((n_experts, d_model, d_ff), dtype),
            "wu": spec((n_experts, d_model, d_ff), dtype),
            "wd": spec((n_experts, d_ff, d_model), dtype)}


def moe_mlp(p, x, n_experts: int, top_k: int, capacity_factor: float = 1.25):
    """Sort-based capacity dispatch, *local per batch row* (§Perf T2).

    The routing mask is a block-sparse GraphBLAS mask — the paper-technique
    analogue (DESIGN.md §4); dispatch scatter == BSR tile-list construction.

    Dispatch is vmapped over the batch dim: each row argsorts only its own
    S·k routing decisions, so the sort/scatter stay *local* to the data
    shard. (A global argsort over B·S·k tokens is unshardable — GSPMD
    replicates the dispatch buffers: mixtral train_4k peaked at 106 GB/device
    at baseline. Local dispatch = per-(row, expert) capacity, standard
    practice.) Expert FFNs run as one batched einsum — active-param FLOPs
    only. Over-capacity tokens drop.
    """
    B, S, D = x.shape
    cap = max(1, int(np.ceil(S * capacity_factor * top_k / n_experts)))

    def dispatch_row(xt):                                       # (S, D)
        logits = xt.astype(jnp.float32) @ p["router"]           # (S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, top_k)              # (S, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(S * top_k)
        flat_w = top_p.reshape(S * top_k)
        order = jnp.argsort(flat_e, stable=True)
        tok_of = order // top_k
        e_sorted = flat_e[order]
        w_sorted = flat_w[order]
        counts = jnp.bincount(e_sorted, length=n_experts)
        offsets = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(S * top_k) - offsets[e_sorted]
        keep = pos_in_e < cap
        slot = e_sorted * cap + jnp.where(keep, pos_in_e, 0)
        xe = jnp.zeros((n_experts * cap, D), xt.dtype)
        xe = xe.at[slot].add(jnp.where(keep[:, None], xt[tok_of], 0))
        return xe.reshape(n_experts, cap, D), (slot, keep, w_sorted, tok_of)

    def combine_row(ye, meta):                                  # (E, cap, D)
        slot, keep, w_sorted, tok_of = meta
        g = ye.reshape(n_experts * cap, D)[slot]                # (S*k, D)
        g = jnp.where(keep[:, None], g, 0) * w_sorted[:, None].astype(ye.dtype)
        return jnp.zeros((S, D), ye.dtype).at[tok_of].add(g)

    xe, meta = jax.vmap(dispatch_row)(x)                        # (B, E, cap, D)
    xe = shard(xe, "batch", "expert", None, None)
    he = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xe, p["wu"])
    he = shard(he, "batch", "expert", None, "ff")
    ye = jnp.einsum("becf,efd->becd", he, p["wd"])              # (B, E, cap, D)
    return jax.vmap(combine_row)(ye, meta)


# -- embeddings -----------------------------------------------------------------------
def embed_specs(vocab: int, d_model: int, dtype, tied: bool):
    p = {"tok": spec((vocab, d_model), dtype)}
    if not tied:
        p["out"] = spec((d_model, vocab), dtype)
    return p


def embed(p, tokens, d_model: int, scale: bool):
    h = p["tok"][tokens]
    if scale:
        h = h * np.sqrt(d_model).astype(np.float32)
    return shard(h, "batch", None, "embed")


def unembed(p, h, cap: float, tied: bool):
    w = p["tok"].T if tied else p["out"]
    logits = h @ w.astype(h.dtype)
    logits = softcap(logits.astype(jnp.float32), cap)
    return shard(logits, "batch", None, "vocab")
