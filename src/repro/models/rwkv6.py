"""RWKV6 "Finch": attention-free LM with data-dependent per-channel decay.

Time-mix: low-rank (LoRA) data-dependent decay w_t = exp(-exp(w0 + lora(x)));
wkv state recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t carried by lax.scan
(constant-size state => long_500k decode is O(1) memory per token).
Simplification vs. the release code (DESIGN.md): plain per-channel lerp
token-shift instead of the ddlerp mixing stack; the data-dependent decay —
the paper's headline feature — is kept exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distr.shardctx import shard
from repro.models import layers as L
from repro.models.base import (ModelBundle, cross_entropy, dtype_of,
                               token_specs)

LORA_R = 64


def param_specs(cfg: ModelConfig):
    dt = dtype_of(cfg)
    D, F, H, hd = cfg.d_model, cfg.d_ff, cfg.ssm_heads, cfg.head_dim
    block = {
        "ln1": L.spec((D,), dt), "ln2": L.spec((D,), dt),
        # time-mix
        "mu_r": L.spec((D,), dt), "mu_k": L.spec((D,), dt),
        "mu_v": L.spec((D,), dt), "mu_w": L.spec((D,), dt),
        "mu_g": L.spec((D,), dt),
        "wr": L.spec((D, D), dt), "wk": L.spec((D, D), dt),
        "wv": L.spec((D, D), dt), "wg": L.spec((D, D), dt),
        "w0": L.spec((D,), jnp.float32),
        "w_lora_a": L.spec((D, LORA_R), dt), "w_lora_b": L.spec((LORA_R, D), dt),
        "bonus_u": L.spec((H, hd), jnp.float32),
        "ln_x": L.spec((D,), dt),
        "wo": L.spec((D, D), dt),
        # channel-mix
        "mu_ck": L.spec((D,), dt), "mu_cr": L.spec((D,), dt),
        "wck": L.spec((D, F), dt), "wcv": L.spec((F, D), dt),
        "wcr": L.spec((D, D), dt),
    }
    return {
        "embed": L.embed_specs(cfg.vocab, cfg.d_model, dt, tied=False),
        "layers": jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
            block),
        "ln_f": L.spec((D,), dt),
    }


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _wkv_scan(r, k, v, w, u, state):
    """r,k,v: (B,T,H,hd); w: (B,T,H,hd) decay in (0,1); state: (B,H,hd,hd).
    y_t = r_t . (S_{t-1} + u (x) k_t v_t);  S_t = diag(w_t) S_{t-1} + k_t (x) v_t.
    """
    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv,
                       preferred_element_type=jnp.float32)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = jax.tree.map(lambda a: a.swapaxes(0, 1), (r, k, v, w))  # (T,B,H,hd)
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state               # (B,T,H,hd)


def _time_mix(cfg, p, x, shift_state, wkv_state):
    B, T, D = x.shape
    H, hd = cfg.ssm_heads, cfg.head_dim
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    xr = _lerp(x, x_prev, p["mu_r"])
    xk = _lerp(x, x_prev, p["mu_k"])
    xv = _lerp(x, x_prev, p["mu_v"])
    xw = _lerp(x, x_prev, p["mu_w"])
    xg = _lerp(x, x_prev, p["mu_g"])
    r = (xr @ p["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = xg @ p["wg"]
    # data-dependent decay (the Finch contribution)
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)) \
        @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + dd))     # (B,T,D)
    w = w.reshape(B, T, H, hd)
    y, wkv_state = _wkv_scan(r, k, v, w, p["bonus_u"].astype(jnp.float32),
                             wkv_state)
    y = y.reshape(B, T, D).astype(x.dtype)
    y = L.rmsnorm(y, p["ln_x"]) * jax.nn.silu(g)
    return y @ p["wo"], x[:, -1, :], wkv_state


def _channel_mix(p, x, shift_state):
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    xk = _lerp(x, x_prev, p["mu_ck"])
    xr = _lerp(x, x_prev, p["mu_cr"])
    k = jnp.square(jax.nn.relu(xk @ p["wck"]))
    k = shard(k, "batch", None, "ff")
    return jax.nn.sigmoid(xr @ p["wcr"]) * (k @ p["wcv"]), x[:, -1, :]


def forward(cfg: ModelConfig, params, tokens, states=None,
            last_only=False):
    """states: None (train: zero states) or per-layer pytree for decode."""
    B, T = tokens.shape
    D, H, hd = cfg.d_model, cfg.ssm_heads, cfg.head_dim
    h = L.embed(params["embed"], tokens, D, False)
    if states is None:
        states = {
            "tm_shift": jnp.zeros((cfg.n_layers, B, D), h.dtype),
            "cm_shift": jnp.zeros((cfg.n_layers, B, D), h.dtype),
            "wkv": jnp.zeros((cfg.n_layers, B, H, hd, hd), jnp.float32),
        }

    def body(carry, xs):
        h = carry
        lp, tm_s, cm_s, wkv_s = xs
        att, tm_new, wkv_new = _time_mix(cfg, lp, L.rmsnorm(h, lp["ln1"]),
                                         tm_s, wkv_s)
        h = h + att
        ffn, cm_new = _channel_mix(lp, L.rmsnorm(h, lp["ln2"]), cm_s)
        h = h + ffn
        h = shard(h, "batch", None, "embed")
        return h, (tm_new, cm_new, wkv_new)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (tm, cm, wkv) = jax.lax.scan(
        body, h, (params["layers"], states["tm_shift"], states["cm_shift"],
                  states["wkv"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    h = L.rmsnorm(h, params["ln_f"])
    if last_only:
        h = h[:, -1:]
    logits = h @ params["embed"]["out"].astype(h.dtype)
    new_states = {"tm_shift": tm, "cm_shift": cm, "wkv": wkv}
    return shard(logits.astype(jnp.float32), "batch", None, "vocab"), new_states


def loss_fn(cfg, params, batch):
    logits, _ = forward(cfg, params, batch["tokens"])
    return cross_entropy(logits, batch["labels"])


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    del seq  # constant-size state: the long_500k story
    dt = dtype_of(cfg)
    D, H, hd = cfg.d_model, cfg.ssm_heads, cfg.head_dim
    return {
        "tm_shift": jax.ShapeDtypeStruct((cfg.n_layers, batch, D), dt),
        "cm_shift": jax.ShapeDtypeStruct((cfg.n_layers, batch, D), dt),
        "wkv": jax.ShapeDtypeStruct((cfg.n_layers, batch, H, hd, hd),
                                    jnp.float32),
    }


def decode_fn(cfg, params, states, batch, pos):
    del pos  # recurrence is position-free
    return forward(cfg, params, batch["tokens"], states=states)


def prefill_fn(cfg, params, batch):
    logits, states = forward(cfg, params, batch["tokens"], last_only=True)
    return logits, states


def build(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        param_specs=functools.partial(param_specs, cfg),
        loss_fn=functools.partial(loss_fn, cfg),
        train_input_specs=lambda s: token_specs(s.global_batch, s.seq_len),
        prefill_fn=functools.partial(prefill_fn, cfg),
        decode_fn=functools.partial(decode_fn, cfg),
        cache_specs=functools.partial(cache_specs, cfg),
        decode_input_specs=lambda s: {
            "tokens": jax.ShapeDtypeStruct((s.global_batch, 1), jnp.int32)},
    )
