"""Model bundle protocol shared by every family + spec-driven init.

Every family module exposes `build(cfg) -> ModelBundle`. Params are pytrees
of jax arrays; `param_specs()` returns the same tree as ShapeDtypeStructs so
the dry-run can lower without allocating 400B parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ModelBundle:
    cfg: object
    param_specs: Callable[[], dict]
    loss_fn: Callable                 # (params, batch) -> scalar
    train_input_specs: Callable       # (ShapeConfig) -> batch spec dict
    prefill_fn: Optional[Callable] = None   # (params, batch) -> (logits, cache)
    decode_fn: Optional[Callable] = None    # (params, cache, batch, pos) -> (logits, cache)
    cache_specs: Optional[Callable] = None  # (batch, seq) -> cache spec tree
    decode_input_specs: Optional[Callable] = None  # (ShapeConfig) -> batch spec dict

    def init(self, seed: int = 0):
        return init_from_specs(self.param_specs(), seed)


def init_from_specs(specs, seed: int = 0):
    """Deterministic init: 1-D leaves (norm gains, biases) zero; matrices
    normal(0, 0.02)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, s in enumerate(leaves):
        if len(s.shape) <= 1:
            out.append(jnp.zeros(s.shape, s.dtype))
        else:
            k = jax.random.fold_in(key, i)
            out.append((0.02 * jax.random.normal(k, s.shape)).astype(s.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ignore: int = -100) -> jnp.ndarray:
    """Mean next-token CE; label `ignore` positions excluded (VLM frontends).

    Vocab-parallel by construction (§Perf T4): the gold logit is extracted by
    an iota==label select + reduce instead of take_along_axis — a gather
    along the model-sharded vocab axis makes GSPMD all-gather the full
    (B, S, V) f32 logits (8.4 GB/device on mixtral train_4k); the masked
    reduce stays sharded and lowers to a cheap psum.
    """
    logits = logits.astype(jnp.float32)
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    sel = jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, logits.ndim - 1) == safe[..., None]
    gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    tokloss = (lse - gold) * valid
    return tokloss.sum() / jnp.maximum(valid.sum(), 1)


def token_specs(batch: int, seq: int):
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def dtype_of(cfg):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
