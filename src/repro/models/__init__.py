from repro.models.base import ModelBundle, init_from_specs
from repro.models.registry import get_model

__all__ = ["ModelBundle", "get_model", "init_from_specs"]
