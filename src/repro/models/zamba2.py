"""Zamba2: Mamba2 (SSD) backbone + a *shared* attention block applied every
`shared_attn_every` layers (one parameter set, per-site KV caches).

Mamba2 block: in_proj -> (z, x, B, C, dt); causal depthwise conv over
(x,B,C); per-head scalar decay exp(A*dt); state h (B, H, P, N) scanned over
time; y = C.h + D*x, gated by silu(z). Constant-size state + a handful of
shared-attn KV caches => long_500k runs with the caches mesh-sharded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distr.shardctx import shard
from repro.models import layers as L
from repro.models.base import (ModelBundle, cross_entropy, dtype_of,
                               token_specs)


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    H = cfg.ssm_heads
    P = d_inner // H
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N
    return d_inner, H, P, N, conv_ch


def _sites(cfg: ModelConfig):
    """Segments of mamba layers, each preceded by the shared attn block."""
    every = cfg.shared_attn_every
    n_full, rem = divmod(cfg.n_layers, every)
    segs = [every] * n_full + ([rem] if rem else [])
    return segs


def mamba_block_specs(cfg: ModelConfig, dt):
    D = cfg.d_model
    d_inner, H, P, N, conv_ch = _dims(cfg)
    return {
        "ln": L.spec((D,), dt),
        "in_proj": L.spec((D, 2 * d_inner + 2 * N + H), dt),
        "conv_w": L.spec((conv_ch, cfg.ssm_conv), dt),
        "conv_b": L.spec((conv_ch,), dt),
        "a_log": L.spec((H,), jnp.float32),
        "d_skip": L.spec((H,), jnp.float32),
        "dt_bias": L.spec((H,), jnp.float32),
        "ln_y": L.spec((d_inner,), dt),
        "out_proj": L.spec((d_inner, D), dt),
    }


def shared_attn_specs(cfg: ModelConfig, dt):
    fl = L.AttnFlavor(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    return {
        "ln1": L.spec((cfg.d_model,), dt),
        "attn": L.attn_specs(cfg.d_model, fl, dt),
        "ln2": L.spec((cfg.d_model,), dt),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def param_specs(cfg: ModelConfig):
    dt = dtype_of(cfg)
    segs = _sites(cfg)
    blocks = {}
    for i, seg in enumerate(segs):
        blocks[f"seg{i}"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((seg,) + s.shape, s.dtype),
            mamba_block_specs(cfg, dt))
    return {
        "embed": L.embed_specs(cfg.vocab, cfg.d_model, dt, tied=False),
        "shared": shared_attn_specs(cfg, dt),
        "segments": blocks,
        "ln_f": L.spec((cfg.d_model,), dt),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, T, C); depthwise causal conv, kernel K. state: (B, K-1, C)."""
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(K))
    new_state = xp[:, -(K - 1):, :]
    return jax.nn.silu(out + b), new_state


def _ssd_scan(xh, Bm, Cm, dtv, a, state):
    """xh: (B,T,H,P); Bm,Cm: (B,T,N); dtv: (B,T,H); a: (H,) < 0.
    h_t = exp(a dt) h_{t-1} + dt * x_t (x) B_t ;  y_t = h_t . C_t.
    state: (B,H,P,N)."""
    def step(h, xs):
        xt, bt, ct, dt_t = xs                    # (B,H,P) (B,N) (B,N) (B,H)
        decay = jnp.exp(a[None, :] * dt_t)       # (B,H)
        upd = (dt_t[..., None, None] * xt[..., :, None]
               * bt[:, None, None, :])           # (B,H,P,N)
        h = decay[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct,
                       preferred_element_type=jnp.float32)
        return h, y

    xs = jax.tree.map(lambda v: v.swapaxes(0, 1), (xh, Bm, Cm, dtv))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state              # (B,T,H,P)


def mamba_block(cfg, p, h, conv_state=None, ssd_state=None):
    B, T, D = h.shape
    d_inner, H, P, N, conv_ch = _dims(cfg)
    hin = L.rmsnorm(h, p["ln"])
    proj = hin @ p["in_proj"]                    # (B,T,2di+2N+H)
    z, xbc, dtv = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xh = x.reshape(B, T, H, P).astype(jnp.float32)
    dtv = jax.nn.softplus(dtv.astype(jnp.float32)
                          + p["dt_bias"][None, None, :])      # (B,T,H)
    a = -jnp.exp(p["a_log"])
    if ssd_state is None:
        ssd_state = jnp.zeros((B, H, P, N), jnp.float32)
    y, new_ssd = _ssd_scan(xh, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           dtv, a, ssd_state)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(B, T, d_inner).astype(h.dtype)
    y = L.rmsnorm(y, p["ln_y"]) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return h + out, new_conv, new_ssd


def shared_block(cfg, p, h, positions, cache=None, cache_slot=None,
                 kv_positions=None):
    fl = L.AttnFlavor(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    att, new_cache = L.attention(p["attn"], L.rmsnorm(h, p["ln1"]), fl,
                                 positions=positions, cache=cache,
                                 cache_slot=cache_slot,
                                 kv_positions=kv_positions,
                                 kv_chunk=cfg.kv_chunk)
    h = h + att
    h = h + L.mlp(p["mlp"], L.rmsnorm(h, p["ln2"]), "gelu")
    return shard(h, "batch", None, "embed"), new_cache


def forward(cfg: ModelConfig, params, tokens, positions, states=None,
            cache_slot=None, kv_positions=None):
    B, T = tokens.shape
    d_inner, H, P, N, conv_ch = _dims(cfg)
    segs = _sites(cfg)
    h = L.embed(params["embed"], tokens, cfg.d_model, False)
    decode = states is not None
    new_states = {"conv": [], "ssd": [], "kv": []} if decode else None

    for i, seg in enumerate(segs):
        cache = (states["kv"][i] if decode else None)
        h, new_cache = shared_block(cfg, params["shared"], h, positions,
                                    cache=cache, cache_slot=cache_slot,
                                    kv_positions=kv_positions)

        def body(carry, xs):
            hh = carry
            if decode:
                lp, cs, ss = xs
                hh, nc, ns = mamba_block(cfg, lp, hh, cs, ss)
                return hh, (nc, ns)
            hh, _, _ = mamba_block(cfg, xs, hh)
            return hh, None

        if cfg.remat and not decode:
            body = jax.checkpoint(body)
        if decode:
            h, (ncs, nss) = jax.lax.scan(
                body, h, (params["segments"][f"seg{i}"],
                          states["conv"][i], states["ssd"][i]),
                unroll=seg if cfg.scan_unroll else 1)
            new_states["conv"].append(ncs)
            new_states["ssd"].append(nss)
            new_states["kv"].append(new_cache)
        else:
            h, _ = jax.lax.scan(body, h, params["segments"][f"seg{i}"],
                                unroll=seg if cfg.scan_unroll else 1)

    h = L.rmsnorm(h, params["ln_f"])
    logits = h @ params["embed"]["out"].astype(h.dtype)
    return shard(logits.astype(jnp.float32), "batch", None, "vocab"), new_states


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens, jnp.arange(tokens.shape[1]))
    return cross_entropy(logits, batch["labels"])


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    dt = dtype_of(cfg)
    d_inner, H, P, N, conv_ch = _dims(cfg)
    segs = _sites(cfg)
    kv = (cfg.n_heads and True)
    return {
        "conv": [jax.ShapeDtypeStruct(
            (seg, batch, cfg.ssm_conv - 1, conv_ch), dt) for seg in segs],
        "ssd": [jax.ShapeDtypeStruct((seg, batch, H, P, N), jnp.float32)
                for seg in segs],
        "kv": [(jax.ShapeDtypeStruct(
                    (batch, seq, cfg.n_kv_heads, cfg.head_dim), dt),
                jax.ShapeDtypeStruct(
                    (batch, seq, cfg.n_kv_heads, cfg.head_dim), dt))
               for _ in segs],
    }


def decode_fn(cfg, params, states, batch, pos):
    T = states["kv"][0][0].shape[1]
    kv_positions = L.cache_kv_positions(pos, T, ring=False)
    return forward(cfg, params, batch["tokens"], jnp.asarray([pos]),
                   states=states, cache_slot=pos, kv_positions=kv_positions)


def prefill_fn(cfg, params, batch):
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens, jnp.arange(tokens.shape[1]))
    return logits[:, -1:], None


def build(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        param_specs=functools.partial(param_specs, cfg),
        loss_fn=functools.partial(loss_fn, cfg),
        train_input_specs=lambda s: token_specs(s.global_batch, s.seq_len),
        prefill_fn=functools.partial(prefill_fn, cfg),
        decode_fn=functools.partial(decode_fn, cfg),
        cache_specs=functools.partial(cache_specs, cfg),
        decode_input_specs=lambda s: {
            "tokens": jax.ShapeDtypeStruct((s.global_batch, 1), jnp.int32)},
    )
