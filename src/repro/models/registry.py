"""Model registry: config -> ModelBundle (family dispatch)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import rwkv6, transformer, whisper, zamba2
from repro.models.base import ModelBundle

_FAMILIES = {
    "dense": transformer.build,
    "moe": transformer.build,
    "llava": transformer.build,
    "rwkv6": rwkv6.build,
    "zamba2": zamba2.build,
    "whisper": whisper.build,
}


def get_model(cfg: ModelConfig) -> ModelBundle:
    return _FAMILIES[cfg.family](cfg)
