"""Whisper-medium backbone: transformer encoder-decoder with cross-attention.

Per the brief the conv/audio frontend is a STUB: `input_specs()` provides
precomputed frame embeddings (B, n_frames, d_frontend); a linear adapter maps
them to d_model. Positional encoding is on-the-fly sinusoidal for both stacks
(stand-in for Whisper's learned decoder table — documented in DESIGN.md).
Decoder-seq shapes (4k/32k) are structural stand-ins beyond Whisper's 448.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distr.shardctx import shard
from repro.models import layers as L
from repro.models.base import (ModelBundle, cross_entropy, dtype_of,
                               token_specs)


def _fl(cfg, causal):
    return L.AttnFlavor(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                        causal=causal, use_rope=False)


def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def param_specs(cfg: ModelConfig):
    dt = dtype_of(cfg)
    D = cfg.d_model
    enc_block = {
        "ln1": L.spec((D,), dt),
        "attn": L.attn_specs(D, _fl(cfg, False), dt),
        "ln2": L.spec((D,), dt),
        "mlp": L.mlp_specs(D, cfg.d_ff, "gelu", dt),
    }
    dec_block = {
        "ln1": L.spec((D,), dt),
        "self_attn": L.attn_specs(D, _fl(cfg, True), dt),
        "lnx": L.spec((D,), dt),
        "cross_attn": L.attn_specs(D, _fl(cfg, False), dt),
        "ln2": L.spec((D,), dt),
        "mlp": L.mlp_specs(D, cfg.d_ff, "gelu", dt),
    }
    stack = lambda b, n: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), b)
    return {
        "front_proj": L.spec((cfg.d_frontend, D), dt),
        "enc_layers": stack(enc_block, cfg.encoder_layers),
        "enc_ln_f": L.spec((D,), dt),
        "embed": L.embed_specs(cfg.vocab, D, dt, tied=True),
        "dec_layers": stack(dec_block, cfg.n_layers),
        "ln_f": L.spec((D,), dt),
    }


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, F, d_frontend) stub frontend output -> (B, F, D)."""
    h = frames.astype(dtype_of(cfg)) @ params["front_proj"]
    h = h + _sinusoid(jnp.arange(h.shape[1]), cfg.d_model).astype(h.dtype)
    h = shard(h, "batch", None, "embed")
    fl = _fl(cfg, False)
    positions = jnp.arange(h.shape[1])

    def body(carry, lp):
        hh = carry
        att, _ = L.attention(lp["attn"], L.rmsnorm(hh, lp["ln1"]), fl,
                             positions=positions, kv_chunk=cfg.kv_chunk)
        hh = hh + att
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(hh, lp["ln2"]), "gelu")
        return shard(hh, "batch", None, "embed"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=cfg.encoder_layers if cfg.scan_unroll else 1)
    return L.rmsnorm(h, params["enc_ln_f"])


def _cross_attention(p, x, kv, fl, kv_chunk=1024, q_chunk=4096):
    """q from decoder x; k,v precomputed (B, F, K, h) from encoder output.

    Queries are chunked (§Perf T10): at prefill_32k the full (S, F_enc)
    cross-logit tensor is 6.3 GB/layer f32; q chunks of 4096 bound it at
    ~0.8 GB while keeping the MXU shape.
    """
    B, S, _ = x.shape
    K, h = fl.n_kv_heads, fl.head_dim
    q = (x @ p["wq"]).reshape(B, S, K, fl.n_heads // K, h)
    k, v = kv
    F = k.shape[1]

    def attend(qc):
        return L.chunked_attention(
            qc, k, v, q_positions=jnp.zeros(qc.shape[1], jnp.int32),
            kv_positions=jnp.arange(F), fl=fl, kv_chunk=kv_chunk)

    if S > q_chunk and S % q_chunk == 0:
        qs = q.reshape(B, S // q_chunk, q_chunk, K, fl.n_heads // K, h)
        qs = qs.transpose(1, 0, 2, 3, 4, 5)
        out = jax.lax.map(attend, qs)                      # (nc, B, qc, ...)
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K,
                                                      fl.n_heads // K, h)
    else:
        out = attend(q)
    return out.reshape(B, S, fl.n_heads * h) @ p["wo"]


def _enc_kv(p, enc_h, fl):
    B, F, _ = enc_h.shape
    k = (enc_h @ p["wk"]).reshape(B, F, fl.n_kv_heads, fl.head_dim)
    v = (enc_h @ p["wv"]).reshape(B, F, fl.n_kv_heads, fl.head_dim)
    return k, v


def decode_stack(cfg, params, tokens, positions, enc_h=None, caches=None,
                 cache_slot=None, kv_positions=None, last_only=False):
    """enc_h given (train/prefill) XOR caches given (decode: holds enc kv)."""
    fl_self, fl_cross = _fl(cfg, True), _fl(cfg, False)
    h = L.embed(params["embed"], tokens, cfg.d_model, False)
    h = h + _sinusoid(positions, cfg.d_model).astype(h.dtype)[None, :, :]
    h = shard(h, "batch", None, "embed")
    decode = caches is not None

    def body(carry, xs):
        hh = carry
        if decode:
            lp, sk, sv, xk, xv = xs
            cache = (sk, sv)
        else:
            lp = xs
            cache = None
        att, new_cache = L.attention(
            lp["self_attn"], L.rmsnorm(hh, lp["ln1"]), fl_self,
            positions=positions, cache=cache, cache_slot=cache_slot,
            kv_positions=kv_positions, kv_chunk=cfg.kv_chunk)
        hh = hh + att
        if decode:
            kv = (xk, xv)
        else:
            kv = _enc_kv(lp["cross_attn"], enc_h, fl_cross)
        hh = hh + _cross_attention(lp["cross_attn"],
                                   L.rmsnorm(hh, lp["lnx"]), kv, fl_cross,
                                   kv_chunk=cfg.kv_chunk)
        hh = hh + L.mlp(lp["mlp"], L.rmsnorm(hh, lp["ln2"]), "gelu")
        hh = shard(hh, "batch", None, "embed")
        return hh, new_cache

    if cfg.remat and not decode:
        body = jax.checkpoint(body)
    if decode:
        xs = (params["dec_layers"], caches["self_k"], caches["self_v"],
              caches["cross_k"], caches["cross_v"])
        h, new_self = jax.lax.scan(
            body, h, xs, unroll=cfg.n_layers if cfg.scan_unroll else 1)
        new_caches = {"self_k": new_self[0], "self_v": new_self[1],
                      "cross_k": caches["cross_k"],
                      "cross_v": caches["cross_v"]}
    else:
        h, _ = jax.lax.scan(body, h, params["dec_layers"],
                            unroll=cfg.n_layers if cfg.scan_unroll else 1)
        new_caches = None
    h = L.rmsnorm(h, params["ln_f"])
    if last_only:
        # §Perf T10b: whisper's vocab (51865) is not 16-divisible, so full
        # (B, S, V) logits replicate over "model" (13.6 GB at prefill_32k);
        # prefill only needs the last position.
        h = h[:, -1:]
    logits = h @ params["embed"]["tok"].T.astype(h.dtype)
    return shard(logits.astype(jnp.float32), "batch", None, "vocab"), new_caches


def loss_fn(cfg, params, batch):
    enc_h = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    logits, _ = decode_stack(cfg, params, tokens,
                             jnp.arange(tokens.shape[1]), enc_h=enc_h)
    return cross_entropy(logits, batch["labels"])


def train_input_specs(cfg, shape: ShapeConfig):
    specs = token_specs(shape.global_batch, shape.seq_len)
    specs["frames"] = jax.ShapeDtypeStruct(
        (shape.global_batch, cfg.n_audio_frames, cfg.d_frontend), jnp.bfloat16)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    dt = dtype_of(cfg)
    L_, K, h = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "self_k": jax.ShapeDtypeStruct((L_, batch, seq, K, h), dt),
        "self_v": jax.ShapeDtypeStruct((L_, batch, seq, K, h), dt),
        "cross_k": jax.ShapeDtypeStruct((L_, batch, cfg.n_audio_frames, K, h), dt),
        "cross_v": jax.ShapeDtypeStruct((L_, batch, cfg.n_audio_frames, K, h), dt),
    }


def decode_fn(cfg, params, caches, batch, pos):
    T = caches["self_k"].shape[2]
    kv_positions = L.cache_kv_positions(pos, T, ring=False)
    return decode_stack(cfg, params, batch["tokens"], jnp.asarray([pos]),
                        caches=caches, cache_slot=pos,
                        kv_positions=kv_positions)


def prefill_fn(cfg, params, batch):
    enc_h = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    logits, _ = decode_stack(cfg, params, tokens,
                             jnp.arange(tokens.shape[1]), enc_h=enc_h,
                             last_only=True)
    return logits, None


def build(cfg: ModelConfig) -> ModelBundle:
    return ModelBundle(
        cfg=cfg,
        param_specs=functools.partial(param_specs, cfg),
        loss_fn=functools.partial(loss_fn, cfg),
        train_input_specs=functools.partial(train_input_specs, cfg),
        prefill_fn=functools.partial(prefill_fn, cfg),
        decode_fn=functools.partial(decode_fn, cfg),
        cache_specs=functools.partial(cache_specs, cfg),
        decode_input_specs=lambda s: {
            "tokens": jax.ShapeDtypeStruct((s.global_batch, 1), jnp.int32)},
    )
