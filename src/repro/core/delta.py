"""Delta matrices: live mutations over a frozen base — the fifth storage kind.

RedisGraph's production write path (the paper's design) never rebuilds the
adjacency on a write: each relation keeps small *delta* matrices — pending
additions and pending deletions — that are lazily merged into the main
matrix, so reads stay fast while writes stream in. :class:`DeltaMatrix` is
that form here: a frozen base (BSR / ELL / dense jnp array) plus two small
host-side COO sets,

  plus   entries added (or overwritten) since the base froze,
  minus  base entries deleted since the base froze,

with the effective matrix defined as ``(base \\ minus) overridden-by plus``.
The shape may be *larger* than the base's — node creation grows the matrix
without touching the frozen storage (rows/cols past the base are served
entirely from the deltas).

Dispatch lives behind ``grb.GBMatrix`` like every other kind (fmt
``"delta"``). The matmul family composes with **zero rebuild**: result row i
depends only on matrix row i, so ``mxm(D, B) = where(touched_row,
mxm(patch, B), mxm(base, B))`` where ``patch`` (:meth:`DeltaMatrix.patch`)
is a small ELL holding the exact effective content of just the delta-touched
rows. The same row decomposition serves plus/or reductions; transposes are
maintained *incrementally* (the graph layer appends swapped deltas to the
linked twin — never a runtime flip). The element-wise family and the SpGEMM
route fall back to a lazily cached :meth:`materialize` of the effective
matrix in the base's own format — the delta analog of the sharded
gather-to-host fallback (docs/API.md §Delta).

Updates are **functional**: :meth:`apply_ops` returns a new DeltaMatrix
sharing the base (and its host-side entry index), so a reader holding an
earlier handle keeps a snapshot-consistent view while a writer streams
edits — the Redis fork-snapshot spirit without the fork.

Compaction: once the pending-entry count crosses
``AUTO_DELTA_COMPACT * base_nnz`` (:func:`needs_compaction`; measured by
``benchmarks/bench_mutations.py``), composing per read costs more than one
rebuild amortizes — callers (``engine.MutableGraph.freeze``) then fold the
deltas back into a fresh base via :meth:`compact`.

Invariants (maintained by :meth:`apply_ops`):
  * ``minus`` keys are all present in the base; ``plus`` and ``minus`` are
    disjoint; ``plus`` values are nonzero (stored == nonzero, repo-wide).
  * adding an entry with value 0, or deleting it, are the same operation.
  * nnz is exact: ``base.nnz - |minus| + |plus keys not in base|``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BSR
from repro.core.ell import ELL

# -- compaction policy ---------------------------------------------------------
# Measured by benchmarks/bench_mutations.py (RMAT scale 12, edge_factor 8,
# plus_times mxv reads, XLA-CPU reference host): delta-served reads stay
# within ~1.3-1.4x of compacted-base reads up to a pending fraction of 0.05
# of base nnz, then cliff to >4x at 0.1 — past ~5% random edits nearly every
# row is touched, so the patch becomes a second full-height ELL whose width
# buckets up to the hub degree. One compaction costs ~0.3 reads (10-20ms vs
# a 39ms read), so folding at the cliff's base amortizes within a single
# read while keeping the write path O(pending) below it. See docs/API.md
# §Delta dispatch and the crossover_ratio* rows of the bench.
AUTO_DELTA_COMPACT = 0.05


def needs_compaction(d: "DeltaMatrix") -> bool:
    """Measured compaction policy: pending deltas past this fraction of the
    base's stored entries cost more per read than a rebuild amortizes."""
    return d.pending > AUTO_DELTA_COMPACT * max(d.base_nnz, 1)


BaseStorage = Union[BSR, ELL, jnp.ndarray]

# one edit: ("add", row, col, value) | ("del", row, col, 0.0)
Op = Tuple[str, int, int, float]


class _BaseIndex:
    """Host-side entry index of a frozen base, built once and shared by every
    DeltaMatrix over that base (functional updates reuse it — the one-time
    O(nnz) host extraction is paid per *freeze*, not per write)."""

    def __init__(self, store: BaseStorage):
        if isinstance(store, (BSR, ELL)):
            r, c, v = store.to_coo()
        else:
            a = np.asarray(store)
            r, c = np.nonzero(a)
            v = a[r, c]
        self.rows = np.asarray(r, dtype=np.int64)
        self.cols = np.asarray(c, dtype=np.int64)
        self.vals = np.asarray(v, dtype=np.float32)
        # row-sorted view for O(deg) touched-row gathers
        order = np.argsort(self.rows, kind="stable")
        self.r_sorted = self.rows[order]
        self.c_sorted = self.cols[order]
        self.v_sorted = self.vals[order]
        self.nnz = len(self.rows)

    def keys(self, ncols: int) -> np.ndarray:
        """Sorted entry keys under a (possibly grown) column extent."""
        k = self.rows * int(ncols) + self.cols
        return np.sort(k)

    def row_slice(self, rows: np.ndarray):
        """(rows, cols, vals) of base entries whose row is in `rows`
        (unique), via binary search on the row-sorted view."""
        lo = np.searchsorted(self.r_sorted, rows, side="left")
        hi = np.searchsorted(self.r_sorted, rows, side="right")
        take = np.concatenate(
            [np.arange(a, b) for a, b in zip(lo, hi)]
        ) if len(rows) else np.zeros(0, np.int64)
        take = take.astype(np.int64)
        return (self.r_sorted[take], self.c_sorted[take],
                self.v_sorted[take])


def _shape_of(store: BaseStorage) -> Tuple[int, int]:
    return tuple(store.shape)


def _in_sorted(sorted_keys: np.ndarray, query: np.ndarray) -> np.ndarray:
    """Membership of `query` keys in a sorted key array."""
    if len(sorted_keys) == 0:
        return np.zeros(len(query), dtype=bool)
    j = np.clip(np.searchsorted(sorted_keys, query), 0,
                len(sorted_keys) - 1)
    return sorted_keys[j] == query


@dataclasses.dataclass(eq=False)
class DeltaMatrix:
    """Frozen base + pending plus/minus COO deltas (see module docstring).

    Treat instances as immutable: every mutation goes through
    :meth:`apply_ops` / :meth:`resize`, which return a new DeltaMatrix
    sharing the base and its host index. The composed views (`patch`,
    `materialize`) are cached per instance.
    """
    base: BaseStorage
    shape: Tuple[int, int]
    plus_r: np.ndarray          # int64 rows of added/overridden entries
    plus_c: np.ndarray          # int64 cols
    plus_v: np.ndarray          # f32 values (all nonzero)
    minus_r: np.ndarray         # int64 rows of deleted base entries
    minus_c: np.ndarray         # int64 cols

    def __post_init__(self):
        self._index: Optional[_BaseIndex] = None
        self._patch = None            # (ELL, touched bool (n,)) or (None, None)
        self._mat: Optional[BaseStorage] = None

    # -- construction ----------------------------------------------------------
    @classmethod
    def wrap(cls, store: BaseStorage,
             shape: Optional[Tuple[int, int]] = None) -> "DeltaMatrix":
        """Empty-delta view over a frozen base. `shape` >= base shape grows
        the matrix (new rows/cols served purely from future deltas)."""
        if isinstance(store, DeltaMatrix):
            return store if shape is None else store.resize(shape)
        from repro.core.bitadj import BitELL
        if isinstance(store, BitELL):
            # bit-tiles have no row-patch composition (a delta write lands
            # mid-word): mutate over the cached ELL materialization — the
            # same fallback the weighted-semiring dispatch takes
            store = store.to_ell()
        if not isinstance(store, (BSR, ELL)):
            store = jnp.asarray(store)
        bshape = _shape_of(store)
        shape = bshape if shape is None else tuple(shape)
        if shape[0] < bshape[0] or shape[1] < bshape[1]:
            raise ValueError(f"DeltaMatrix shape {shape} smaller than base "
                             f"{bshape} — deltas grow, never shrink")
        z = np.zeros(0, dtype=np.int64)
        return cls(store, shape, z, z, np.zeros(0, np.float32), z.copy(),
                   z.copy())

    def _with(self, **kw) -> "DeltaMatrix":
        d = dataclasses.replace(self, **kw)
        d._index = self._index           # base is shared; so is its index
        return d

    # -- introspection -----------------------------------------------------------
    @property
    def index(self) -> _BaseIndex:
        if self._index is None:
            self._index = _BaseIndex(self.base)
        return self._index

    @property
    def base_nnz(self) -> int:
        if isinstance(self.base, (BSR, ELL)):
            return self.base.nnz
        return int(np.count_nonzero(np.asarray(self.base)))

    @property
    def pending(self) -> int:
        """Pending delta entries (the compaction-policy quantity)."""
        return len(self.plus_r) + len(self.minus_r)

    @property
    def nnz(self) -> int:
        """Exact effective stored-entry count."""
        if self.pending == 0:
            return self.base_nnz
        m = self.shape[1]
        bk = self.index.keys(m)
        new = ~_in_sorted(bk, self.plus_r * m + self.plus_c)
        return self.base_nnz - len(self.minus_r) + int(new.sum())

    @property
    def fmt(self) -> str:
        """Base storage format the deltas compact back into."""
        if isinstance(self.base, BSR):
            return "bsr"
        if isinstance(self.base, ELL):
            return "ell"
        return "dense"

    def __repr__(self) -> str:
        n, m = self.shape
        return (f"DeltaMatrix {n}x{m} base={self.fmt}{_shape_of(self.base)} "
                f"+{len(self.plus_r)}/-{len(self.minus_r)} nnz={self.nnz}")

    # -- mutation (functional) ----------------------------------------------------
    def resize(self, shape: Tuple[int, int]) -> "DeltaMatrix":
        shape = tuple(shape)
        if shape == self.shape:
            return self
        if shape[0] < self.shape[0] or shape[1] < self.shape[1]:
            raise ValueError(f"DeltaMatrix resize {self.shape} -> {shape}: "
                             f"deltas grow, never shrink")
        return self._with(shape=shape)

    def apply_ops(self, ops: Sequence[Op],
                  grow_to: Optional[Tuple[int, int]] = None) -> "DeltaMatrix":
        """One ordered batch of edits -> a new DeltaMatrix (self unchanged).

        ops: ("add", i, j, w) sets entry (i, j) to w (w == 0 deletes);
             ("del", i, j, _) deletes it (a no-op if absent). Later ops win.
        """
        out = self if grow_to is None else self.resize(grow_to)
        if not ops:
            return out
        n, m = out.shape
        plus = {(int(r), int(c)): float(v)
                for r, c, v in zip(out.plus_r, out.plus_c, out.plus_v)}
        minus = set(zip(out.minus_r.tolist(), out.minus_c.tolist()))
        # base membership for the delete/nnz invariants
        bk = self.index.keys(m)
        for kind, i, j, w in ops:
            i, j = int(i), int(j)
            if i >= n or j >= m or i < 0 or j < 0:
                raise ValueError(f"delta op {kind} ({i}, {j}) out of bounds "
                                 f"for shape {(n, m)}")
            key = (i, j)
            if kind == "add" and w != 0.0:
                minus.discard(key)
                plus[key] = float(w)
            else:                         # delete (or add of an explicit 0)
                plus.pop(key, None)
                if _in_sorted(bk, np.asarray([i * m + j]))[0]:
                    minus.add(key)
        pk = sorted(plus)
        mk = sorted(minus)
        return out._with(
            plus_r=np.asarray([k[0] for k in pk], dtype=np.int64),
            plus_c=np.asarray([k[1] for k in pk], dtype=np.int64),
            plus_v=np.asarray([plus[k] for k in pk], dtype=np.float32),
            minus_r=np.asarray([k[0] for k in mk], dtype=np.int64),
            minus_c=np.asarray([k[1] for k in mk], dtype=np.int64))

    def add_entries(self, rows, cols, vals=None) -> "DeltaMatrix":
        rows = np.asarray(rows).ravel()
        vals = np.ones(len(rows), np.float32) if vals is None \
            else np.asarray(vals, np.float32).ravel()
        return self.apply_ops([("add", i, j, w) for i, j, w in
                               zip(rows, np.asarray(cols).ravel(), vals)])

    def delete_entries(self, rows, cols) -> "DeltaMatrix":
        return self.apply_ops([("del", i, j, 0.0) for i, j in
                               zip(np.asarray(rows).ravel(),
                                   np.asarray(cols).ravel())])

    # -- composition --------------------------------------------------------------
    def touched_rows(self) -> np.ndarray:
        """Unique rows any pending delta touches."""
        return np.unique(np.concatenate([self.plus_r, self.minus_r]))

    def patch(self):
        """(ELL patch, scatter rows): the exact effective content of the
        delta-touched rows — the row half of the mxm/reduce composition.

        The patch holds ONLY the touched rows (t of them, bucketed up to a
        power of two), so composing it costs O(t * deg) regardless of the
        matrix size; ``rows`` maps patch row -> matrix row, padded with the
        out-of-bounds index n so consumers scatter the patch product with
        ``.at[rows].set(..., mode="drop")``. Both the row count and the ELL
        width are power-of-two bucketed: each distinct shape is a fresh XLA
        compile on the serving path, bucketing caps a live-write stream at
        O(log^2 n) patch compilations. (None, None) if no deltas pending."""
        if self._patch is None:
            if self.pending == 0:
                self._patch = (None, None)
            else:
                n, m = self.shape
                rows = self.touched_rows()
                br, bc, bv = self.index.row_slice(rows)
                k = br * m + bc
                drop = _in_sorted(np.sort(self.minus_r * m + self.minus_c), k)
                drop |= _in_sorted(np.sort(self.plus_r * m + self.plus_c), k)
                er = np.concatenate([br[drop == False], self.plus_r])  # noqa: E712
                ec = np.concatenate([bc[~drop], self.plus_c])
                ev = np.concatenate([bv[~drop], self.plus_v])
                er = np.searchsorted(rows, er)      # patch-local row ids
                t, tp = len(rows), 8
                while tp < t:
                    tp *= 2
                md = int(np.bincount(er, minlength=1).max()) if len(er) else 1
                pad = 8
                while pad < md:
                    pad *= 2
                scatter = np.full(tp, n, dtype=np.int32)
                scatter[:t] = rows
                # the cache outlives any trace that triggers the build (e.g.
                # sssp's while_loop body) — arrays must be concrete, never
                # trace-bound tracers (same rule as GBMatrix.T)
                with jax.ensure_compile_time_eval():
                    self._patch = (ELL.from_coo(er, ec, ev, (tp, m),
                                                pad_deg_to=pad),
                                   jnp.asarray(scatter))
        return self._patch

    def effective_coo(self):
        """(rows, cols, vals) of the effective matrix — base minus deletions,
        overridden/extended by the plus set."""
        m = self.shape[1]
        idx = self.index
        k = idx.rows * m + idx.cols
        drop = _in_sorted(np.sort(self.minus_r * m + self.minus_c), k)
        drop |= _in_sorted(np.sort(self.plus_r * m + self.plus_c), k)
        return (np.concatenate([idx.rows[~drop], self.plus_r]),
                np.concatenate([idx.cols[~drop], self.plus_c]),
                np.concatenate([idx.vals[~drop], self.plus_v]))

    def materialize(self) -> BaseStorage:
        """Effective matrix composed into the base's own format (cached) —
        the fallback the element-wise family and SpGEMM dispatch use, and
        the compaction product. Deterministic: identical entries produce
        storage identical to a from-scratch build of the same format."""
        if self._mat is None:
            # cached past the current trace — keep the arrays concrete
            # (same rule as patch() above and GBMatrix.T)
            with jax.ensure_compile_time_eval():
                if self.pending == 0 and self.shape == _shape_of(self.base):
                    self._mat = self.base
                elif isinstance(self.base, BSR):
                    r, c, v = self.effective_coo()
                    self._mat = BSR.from_coo(r, c, v, self.shape,
                                             block=self.base.block)
                elif isinstance(self.base, ELL):
                    r, c, v = self.effective_coo()
                    self._mat = ELL.from_coo(r, c, v, self.shape)
                else:
                    d = np.zeros(self.shape, dtype=np.float32)
                    bn, bm = _shape_of(self.base)
                    d[:bn, :bm] = np.asarray(self.base)
                    if len(self.minus_r):
                        d[self.minus_r, self.minus_c] = 0.0
                    if len(self.plus_r):
                        d[self.plus_r, self.plus_c] = self.plus_v
                    self._mat = jnp.asarray(d)
        return self._mat

    def compact(self) -> "DeltaMatrix":
        """Fold the deltas into a fresh base (empty-delta DeltaMatrix)."""
        return DeltaMatrix.wrap(self.materialize())

    # -- storage protocol (what GBMatrix forwards) ---------------------------------
    def to_dense(self) -> jnp.ndarray:
        if isinstance(self.base, (BSR, ELL)):
            d = np.zeros(self.shape, dtype=np.float32)
            r, c, v = self.effective_coo()
            d[r, c] = v
            return jnp.asarray(d)
        return self.materialize()        # dense base: the scatter above

    def to_coo(self):
        r, c, v = self.effective_coo()
        order = np.argsort(r * self.shape[1] + c)
        return (r[order].astype(np.int64), c[order].astype(np.int64),
                v[order].astype(np.float32))

    def transpose(self) -> "DeltaMatrix":
        """Transposed delta view. The graph layer never calls this on the
        hot path — it maintains linked twins incrementally by applying
        swapped deltas (engine.MutableGraph); this exists so an unlinked
        ``.T`` on a bare delta handle still resolves correctly."""
        bt = self.base.T if isinstance(self.base, jnp.ndarray) \
            else self.base.transpose()
        d = DeltaMatrix(bt, (self.shape[1], self.shape[0]),
                        self.plus_c.copy(), self.plus_r.copy(),
                        self.plus_v.copy(), self.minus_c.copy(),
                        self.minus_r.copy())
        return d
