"""Block-Sparse-Row matrices: the TPU-native replacement for SuiteSparse CSR.

The adjacency matrix is partitioned into ``block x block`` *dense* tiles; only
tiles containing at least one edge are stored.  Dense 128x128 tiles feed the MXU
directly; the tile-index lists carry the sparsity *between* tiles.  Construction
is host-side numpy (the database load path); the device representation is a
registered pytree so BSR matrices flow through jit/shard_map.

Kernel-steering invariants (relied on by kernels/bsr_mxm.py):
  * blocks are sorted by (block_row, block_col);
  * every block-row has >= 1 stored block (empty rows get a padding block with
    valid=0) so the output tile of every row is initialized exactly once;
  * `first` marks the first block of each block-row; `last` the last;
  * trailing grid padding repeats the final block with valid=0, first=0, last=0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import xfer


# ---------------------------------------------------------------------------
# densification accounting
# ---------------------------------------------------------------------------
# Every BSR -> dense materialization bumps this counter. The sparse
# algorithm paths (SpGEMM triangle counting, k-truss) promise *zero*
# densifications on their hot loops; tests snapshot the counter around a run
# and assert the delta (tests/test_ktruss.py). Host-side only — not traced.
_densify_calls = [0]


def densify_calls() -> int:
    """Total BSR.to_dense() materializations so far (monotonic)."""
    return _densify_calls[0]


# Every numeric-phase round-trip through host numpy bumps this counter:
# `BSR.from_blocks` (the host assembler that takes a *host* payload array)
# is the choke point. The element-wise family and the SpGEMM numeric phase
# promise zero bumps — their payloads go through `BSR.from_blocks_device`
# and never leave the device (tests/test_transfers.py pins the delta).
_host_numeric = [0]


def host_numeric_calls() -> int:
    """Total host-numpy numeric-phase assemblies so far (monotonic)."""
    return _host_numeric[0]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BSR:
    shape: Tuple[int, int]
    block: int
    # device arrays -------------------------------------------------------
    blocks: jnp.ndarray      # (nnzb, block, block) tile payloads
    block_rows: jnp.ndarray  # (nnzb,) i32 block-row of each tile
    block_cols: jnp.ndarray  # (nnzb,) i32 block-col of each tile
    first: jnp.ndarray       # (nnzb,) i32 1 iff first tile in its block-row
    last: jnp.ndarray        # (nnzb,) i32 1 iff last tile in its block-row
    valid: jnp.ndarray       # (nnzb,) i32 0 for padding tiles
    row_ptr: jnp.ndarray     # (nbrows+1,) i32 CSR-style pointers over tiles
    # static metadata ------------------------------------------------------
    nnz: int                 # scalar element count (pre-blocking)
    # optional per-entry structural mask (nnzb, block, block) bool: present
    # ONLY when the build saw explicit 0.0-valued entries, which the dense
    # tile payload cannot distinguish from absent-within-tile. The tropical
    # (bcast) matmul, to_coo, and transpose consult it so a stored
    # zero-weight edge participates (min_plus relaxes through it) instead
    # of vanishing — the sssp.py zero-weight caveat, closed. None (the
    # common case, no explicit zeros) keeps the `blocks != 0` convention
    # and is zero-cost.
    emask: Optional[jnp.ndarray] = None

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        children = (self.blocks, self.block_rows, self.block_cols,
                    self.first, self.last, self.valid, self.row_ptr,
                    self.emask)
        aux = (self.shape, self.block, self.nnz)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, block, nnz = aux
        *arrs, emask = children
        return cls(shape, block, *arrs, nnz=nnz, emask=emask)

    # -- properties ----------------------------------------------------------
    @property
    def nnzb(self) -> int:
        return self.blocks.shape[0]

    @property
    def nbrows(self) -> int:
        return -(-self.shape[0] // self.block)

    @property
    def nbcols(self) -> int:
        return -(-self.shape[1] // self.block)

    @property
    def fill_ratio(self) -> float:
        """nnz / stored-tile capacity — the BSR-vs-ELL format-switch signal."""
        cap = int(np.asarray(self.valid).sum()) * self.block * self.block
        return self.nnz / max(cap, 1)

    # -- construction --------------------------------------------------------
    @staticmethod
    def _empty(shape, block: int, nnz: int, dtype=jnp.float32) -> "BSR":
        """Zero-row shapes (an empty extract): no tiles at all."""
        z32 = jnp.zeros(0, dtype=jnp.int32)
        return BSR(shape=shape, block=block,
                   blocks=jnp.zeros((0, block, block), dtype=dtype),
                   block_rows=z32, block_cols=z32, first=z32, last=z32,
                   valid=z32, row_ptr=jnp.zeros(1, dtype=jnp.int32),
                   nnz=nnz)

    @staticmethod
    def _assemble_meta(b_r, b_c, nbr: int, nbc: int, pad_to: int = 8):
        """Structural phase shared by the host and device assemblers.

        From unique, unsorted valid-tile coordinates, establish every
        kernel-steering invariant — padding rows, sort order, first/last
        flags, row_ptr, grid padding — on *coordinates only*. Returns
        ``(a_r, a_c, valid, first, last, row_ptr, src)`` where ``src`` maps
        each output slot to its position in the caller's valid-tile list
        (-1 = an all-zero padding tile), so the payload gather can run on
        either side of the device boundary."""
        b_r = np.asarray(b_r, dtype=np.int32)
        b_c = np.asarray(b_c, dtype=np.int32)
        nv = len(b_r)

        # ensure every block-row has >= 1 tile: add invalid padding tiles
        present = np.zeros(nbr, dtype=bool)
        present[b_r] = True
        missing = np.nonzero(~present)[0].astype(np.int32)
        tot = nv + len(missing)

        a_r = np.concatenate([b_r, missing])
        a_c = np.concatenate([b_c, np.zeros(len(missing), np.int32)])
        valid = np.concatenate([np.ones(nv, np.int32),
                                np.zeros(len(missing), np.int32)])
        src = np.concatenate([np.arange(nv, dtype=np.int32),
                              np.full(len(missing), -1, np.int32)])

        # sort with padding tiles interleaved
        order = np.argsort(a_r.astype(np.int64) * nbc + a_c, kind="stable")
        a_r, a_c, valid, src = a_r[order], a_c[order], valid[order], src[order]

        first = np.zeros(tot, dtype=np.int32)
        last = np.zeros(tot, dtype=np.int32)
        first[0] = 1
        first[1:] = (a_r[1:] != a_r[:-1]).astype(np.int32)
        last[:-1] = first[1:]
        last[-1] = 1

        row_ptr = np.zeros(nbr + 1, dtype=np.int32)
        np.add.at(row_ptr, a_r + 1, 1)
        row_ptr = np.cumsum(row_ptr).astype(np.int32)

        # pad nnzb to a grid-friendly multiple; pads repeat the final tile's
        # coordinates with an all-zero payload
        pad = (-tot) % pad_to
        if pad:
            a_r = np.concatenate([a_r, np.full(pad, a_r[-1], np.int32)])
            a_c = np.concatenate([a_c, np.full(pad, a_c[-1], np.int32)])
            valid = np.concatenate([valid, np.zeros(pad, np.int32)])
            first = np.concatenate([first, np.zeros(pad, np.int32)])
            last = np.concatenate([last, np.zeros(pad, np.int32)])
            src = np.concatenate([src, np.full(pad, -1, np.int32)])
        return a_r, a_c, valid, first, last, row_ptr, src

    @staticmethod
    def _assemble(blocks, b_r, b_c, shape, block: int, nnz: int,
                  dtype=jnp.float32, pad_to: int = 8,
                  emask=None) -> "BSR":
        """Build a BSR from a host-side list of *valid* tiles with unique,
        unsorted (block_row, block_col) coordinates (the structural phase
        runs in :meth:`_assemble_meta`; this gathers the payload in numpy).
        ``emask`` (same tile list, bool) rides the same gather when the
        caller carries explicit-zero structure."""
        n, m = shape
        nbr, nbc = -(-n // block), -(-m // block)
        if nbr == 0:
            return BSR._empty((n, m), block, nnz, dtype)

        a_r, a_c, valid, first, last, row_ptr, src = BSR._assemble_meta(
            b_r, b_c, nbr, nbc, pad_to)
        allb = np.zeros((len(a_r), block, block), dtype=np.float32)
        pos = src >= 0
        if pos.any():
            allb[pos] = np.asarray(blocks, dtype=np.float32)[src[pos]]
        allm = None
        if emask is not None:
            allm = np.zeros((len(a_r), block, block), dtype=bool)
            if pos.any():
                allm[pos] = np.asarray(emask, dtype=bool)[src[pos]]

        return BSR(
            shape=(n, m), block=block,
            blocks=jnp.asarray(allb, dtype=dtype),
            block_rows=jnp.asarray(a_r), block_cols=jnp.asarray(a_c),
            first=jnp.asarray(first), last=jnp.asarray(last),
            valid=jnp.asarray(valid), row_ptr=jnp.asarray(row_ptr),
            nnz=nnz,
            emask=None if allm is None else jnp.asarray(allm),
        )

    @staticmethod
    def from_coo(rows, cols, vals, shape, block: int = 128,
                 dtype=jnp.float32, pad_to: int = 8) -> "BSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if vals is None:
            vals = np.ones(rows.shape[0], dtype=np.float64)
        vals = np.asarray(vals, dtype=np.float64)
        n, m = shape
        nbc = -(-m // block)
        brow, bcol = rows // block, cols // block
        key = brow * nbc + bcol
        order = np.argsort(key, kind="stable")
        rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
        ukey, starts = np.unique(key, return_index=True)
        starts = np.append(starts, rows.shape[0])
        ubrow, ubcol = (ukey // nbc).astype(np.int32), (ukey % nbc).astype(np.int32)

        blocks = np.zeros((len(ukey), block, block), dtype=np.float32)
        # explicit 0.0-weighted entries are structure the dense tile payload
        # cannot carry — track them in a per-entry mask, but only when they
        # actually occur (the emask stays None on every all-nonzero build)
        emask = (np.zeros((len(ukey), block, block), dtype=bool)
                 if np.any(vals == 0.0) else None)
        for i in range(len(ukey)):
            s, e = starts[i], starts[i + 1]
            lr = (rows[s:e] - ubrow[i] * block).astype(np.int64)
            lc = (cols[s:e] - ubcol[i] * block).astype(np.int64)
            np.add.at(blocks[i], (lr, lc), 0.0)  # touch
            blocks[i][lr, lc] = vals[s:e]
            if emask is not None:
                emask[i][lr, lc] = True

        return BSR._assemble(blocks, ubrow, ubcol, (n, m), block,
                             nnz=int(rows.shape[0]), dtype=dtype,
                             pad_to=pad_to, emask=emask)

    @staticmethod
    def from_blocks(block_rows, block_cols, blocks, shape, block: int,
                    dtype=jnp.float32, pad_to: int = 8,
                    prune: bool = True) -> "BSR":
        """Assemble a BSR from computed tile payloads (the SpGEMM numeric
        phase). All-zero tiles — masked-out or numerically cancelled output
        blocks — are pruned so `nvals`/`fill_ratio` report stored structure,
        not kernel artifacts; `nnz` counts the surviving nonzero entries."""
        _host_numeric[0] += 1
        blocks = np.asarray(blocks, dtype=np.float32)
        b_r = np.asarray(block_rows, dtype=np.int32)
        b_c = np.asarray(block_cols, dtype=np.int32)
        if prune and len(b_r):
            keep = (blocks != 0).any(axis=(1, 2))
            blocks, b_r, b_c = blocks[keep], b_r[keep], b_c[keep]
        nnz = int(np.count_nonzero(blocks))
        return BSR._assemble(blocks, b_r, b_c, shape, block, nnz=nnz,
                             dtype=dtype, pad_to=pad_to)

    @staticmethod
    def from_blocks_device(block_rows, block_cols, blocks, shape, block: int,
                           dtype=jnp.float32, pad_to: int = 8,
                           prune: bool = True) -> "BSR":
        """Device-side counterpart of :meth:`from_blocks`: the structural
        phase (pruning decisions, sort, padding rows) runs on the host
        *coordinate* lists, but the tile payloads never leave the device —
        only one (nt,) tile-occupancy pull and one nnz scalar cross the
        boundary (structural metadata, the same class as ShardedELL's nnz;
        not a counted host transfer)."""
        n, m = shape
        nbr, nbc = -(-n // block), -(-m // block)
        b_r = np.asarray(block_rows, dtype=np.int32)
        b_c = np.asarray(block_cols, dtype=np.int32)
        if nbr == 0:
            return BSR._empty((n, m), block, 0, dtype)
        if len(b_r) == 0:
            return BSR._assemble(np.zeros((0, block, block), np.float32),
                                 b_r, b_c, (n, m), block, nnz=0,
                                 dtype=dtype, pad_to=pad_to)
        blocks = jnp.asarray(blocks).astype(jnp.float32)
        nnz = int(jnp.count_nonzero(blocks))
        if prune:
            occupied = np.asarray(jnp.any(blocks != 0, axis=(1, 2)))
            keep_idx = np.nonzero(occupied)[0].astype(np.int32)
            b_r, b_c = b_r[occupied], b_c[occupied]
        else:
            keep_idx = np.arange(len(b_r), dtype=np.int32)
        if len(b_r) == 0:       # everything cancelled / masked out
            return BSR._assemble(np.zeros((0, block, block), np.float32),
                                 b_r, b_c, (n, m), block, nnz=0,
                                 dtype=dtype, pad_to=pad_to)
        a_r, a_c, valid, first, last, row_ptr, src = BSR._assemble_meta(
            b_r, b_c, nbr, nbc, pad_to)
        gather = jnp.asarray(keep_idx[np.clip(src, 0, None)])
        payload = jnp.where(jnp.asarray(src >= 0)[:, None, None],
                            blocks[gather],
                            jnp.float32(0.0)).astype(dtype)
        return BSR(
            shape=(n, m), block=block, blocks=payload,
            block_rows=jnp.asarray(a_r), block_cols=jnp.asarray(a_c),
            first=jnp.asarray(first), last=jnp.asarray(last),
            valid=jnp.asarray(valid), row_ptr=jnp.asarray(row_ptr),
            nnz=nnz,
        )

    @staticmethod
    def from_dense(A, block: int = 128, dtype=jnp.float32) -> "BSR":
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return BSR.from_coo(r, c, A[r, c], A.shape, block=block, dtype=dtype)

    def to_dense(self) -> jnp.ndarray:
        _densify_calls[0] += 1
        xfer.record("bsr_densify")
        n, m = self.shape
        block = self.block
        nbr, nbc = self.nbrows, self.nbcols
        out = np.zeros((nbr * block, nbc * block), dtype=np.float32)
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        for i in range(blocks.shape[0]):
            if va[i]:
                out[br[i] * block:(br[i] + 1) * block,
                    bc[i] * block:(bc[i] + 1) * block] = blocks[i]
        return jnp.asarray(out[:n, :m])

    def transpose(self) -> "BSR":
        """Host-side rebuild (RedisGraph also maintains explicit transposes).
        With explicit-zero structure (emask) the rebuild goes through COO —
        a dense round-trip would drop the zero-weight entries."""
        if self.emask is not None:
            r, c, v = self.to_coo()
            return BSR.from_coo(c, r, v, (self.shape[1], self.shape[0]),
                                block=self.block, dtype=self.blocks.dtype)
        dense = np.asarray(self.to_dense()).T
        return BSR.from_dense(dense, block=self.block, dtype=self.blocks.dtype)

    def valid_tiles(self):
        """Host-side (indices, block_rows, block_cols) of the valid tiles."""
        va = np.asarray(self.valid).astype(bool)
        idx = np.nonzero(va)[0].astype(np.int32)
        return (idx, np.asarray(self.block_rows)[idx],
                np.asarray(self.block_cols)[idx])

    def to_coo(self):
        """Host-side COO extraction (snapshot/persistence path)."""
        xfer.record("bsr_to_coo")
        b = self.block
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        em = None if self.emask is None else np.asarray(self.emask)
        rows, cols, vals = [], [], []
        for i in range(blocks.shape[0]):
            if not va[i]:
                continue
            lr, lc = np.nonzero(blocks[i] if em is None else em[i])
            rows.append(lr + br[i] * b)
            cols.append(lc + bc[i] * b)
            vals.append(blocks[i][lr, lc])
        if not rows:
            return (np.zeros(0, np.int64),) * 2 + (np.zeros(0, np.float32),)
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))


# ---------------------------------------------------------------------------
# SpGEMM: C<M> = A (x) B with BOTH operands block-sparse
# ---------------------------------------------------------------------------
# Semiring modes the SpGEMM numeric phase supports (every MXU-dot mode; the
# tropical bcast modes fall back to the dense pipeline in grb.mxm).
SPGEMM_MODES = ("dot", "dot_pair", "dot_indicator", "dot_first")


@dataclasses.dataclass
class SpGEMMPlan:
    """Output of the *symbolic* phase: the block-level multiply schedule.

    One task t multiplies A tile ``a_sel[t]`` by B tile ``b_sel[t]`` and
    accumulates into output tile ``c_sel[t]``; tasks are sorted by c_sel so
    each output tile is a contiguous run (``first``/``last`` bound it, the
    Pallas revisit schedule relies on it). ``valid=0`` marks grid padding.
    With a non-complemented mask the schedule is already restricted to the
    mask's block pattern; ``mask_sel[j]`` is the mask tile backing output
    tile j (-1 = absent, i.e. an all-zero mask tile).
    """
    a_sel: np.ndarray     # (T,) i32 index into A.blocks
    b_sel: np.ndarray     # (T,) i32 index into B.blocks
    c_sel: np.ndarray     # (T,) i32 index into the output tile list
    first: np.ndarray     # (T,) i32 1 iff first task of its output tile
    last: np.ndarray      # (T,) i32 1 iff last task of its output tile
    valid: np.ndarray     # (T,) i32 0 for padding tasks
    c_rows: np.ndarray    # (nc,) i32 block-row per output tile
    c_cols: np.ndarray    # (nc,) i32 block-col per output tile
    mask_sel: Optional[np.ndarray]  # (nc,) i32 mask tile per output tile / -1

    @property
    def ntasks(self) -> int:
        return int(self.a_sel.shape[0])

    @property
    def nc(self) -> int:
        return int(self.c_rows.shape[0])


def _ragged_ranges(offsets: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """concat(range(offsets[i], offsets[i]+lens[i]) for i) vectorized."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    task_start = np.repeat(ends - lens, lens)
    return np.arange(total, dtype=np.int64) - task_start + np.repeat(offsets, lens)


def spgemm_symbolic(A: "BSR", B: "BSR", mask: Optional["BSR"] = None,
                    complement: bool = False, pad_to: int = 8) -> SpGEMMPlan:
    """Block-level pattern of C = A (x) B, optionally restricted to <M>.

    Host-side numpy over tile coordinate lists (the analog of SuiteSparse's
    symbolic pass over column patterns): pair every valid A tile (i, l) with
    every valid B tile (l, j), group tasks by output tile (i, j). A
    non-complemented structural mask prunes output tiles — and therefore
    whole task groups — *before* any numeric work; a complemented mask
    cannot prune (absent mask tiles are kept entries), so it only annotates.
    """
    ia, bra, bca = A.valid_tiles()
    ib, brb, bcb = B.valid_tiles()
    nbc_out = B.nbcols

    # group B tiles by block-row (the inner dimension)
    order = np.argsort(brb, kind="stable")
    ib, brb, bcb = ib[order], brb[order], bcb[order]
    nbk = B.nbrows
    cnt = np.bincount(brb, minlength=nbk)
    ptr = np.concatenate([[0], np.cumsum(cnt)])

    # one task per (A tile, matching B tile) pair
    lens = cnt[bca]
    a_rep = np.repeat(np.arange(len(ia), dtype=np.int64), lens)
    pos = _ragged_ranges(ptr[bca], lens)
    a_sel = ia[a_rep]
    b_sel = ib[pos]
    ckey = bra[a_rep].astype(np.int64) * nbc_out + bcb[pos]

    mkeys = midx = None
    if mask is not None:
        im, brm, bcm = mask.valid_tiles()
        mkeys = brm.astype(np.int64) * nbc_out + bcm
        morder = np.argsort(mkeys)
        mkeys, midx = mkeys[morder], im[morder]
        if not complement:
            # structural mask prunes the schedule block-wise, up front
            keep = np.isin(ckey, mkeys)
            a_sel, b_sel, ckey = a_sel[keep], b_sel[keep], ckey[keep]

    # sort tasks by output tile -> contiguous accumulation runs
    order = np.argsort(ckey, kind="stable")
    a_sel, b_sel, ckey = a_sel[order], b_sel[order], ckey[order]
    ukey, c_sel = np.unique(ckey, return_inverse=True)
    c_rows = (ukey // nbc_out).astype(np.int32)
    c_cols = (ukey % nbc_out).astype(np.int32)

    ntask = len(ckey)
    first = np.zeros(ntask, dtype=np.int32)
    last = np.zeros(ntask, dtype=np.int32)
    if ntask:
        first[0] = 1
        first[1:] = (ckey[1:] != ckey[:-1]).astype(np.int32)
        last[:-1] = first[1:]
        last[-1] = 1
    valid = np.ones(ntask, dtype=np.int32)

    mask_sel = None
    if mask is not None:
        # mask tile index per output tile (-1: no stored mask tile there)
        if len(mkeys):
            j = np.clip(np.searchsorted(mkeys, ukey), 0, len(mkeys) - 1)
            mask_sel = np.where(mkeys[j] == ukey, midx[j], -1).astype(np.int32)
        else:
            mask_sel = np.full(len(ukey), -1, dtype=np.int32)

    # pad the task list to a grid-friendly multiple (repeat the last task
    # with valid=0 so index maps stay in range and no tile re-inits)
    pad = (-ntask) % pad_to if ntask else 0
    if pad:
        a_sel = np.concatenate([a_sel, np.full(pad, a_sel[-1])])
        b_sel = np.concatenate([b_sel, np.full(pad, b_sel[-1])])
        c_sel = np.concatenate([c_sel, np.full(pad, c_sel[-1])])
        first = np.concatenate([first, np.zeros(pad, np.int32)])
        last = np.concatenate([last, np.zeros(pad, np.int32)])
        valid = np.concatenate([valid, np.zeros(pad, np.int32)])

    return SpGEMMPlan(a_sel=a_sel.astype(np.int32), b_sel=b_sel.astype(np.int32),
                      c_sel=c_sel.astype(np.int32), first=first, last=last,
                      valid=valid, c_rows=c_rows, c_cols=c_cols,
                      mask_sel=mask_sel)


def spgemm(A: "BSR", B: "BSR", sr, mask: Optional["BSR"] = None,
           complement: bool = False, impl: str = "xla",
           interpret: Optional[bool] = None) -> "BSR":
    """Two-phase sparse-times-sparse mxm: C<M> = A (x) B, C stays BSR.

    Symbolic phase (host) plans the block schedule and applies a structural
    mask block-wise; numeric phase (device) runs it through the Pallas
    SpGEMM kernel (``impl="pallas"``) or the XLA gather/segment-sum
    reference (``impl="xla"``), folding the mask's *element* pattern into
    the last task of each output tile. All-zero output tiles are pruned.
    """
    if A.shape[1] != B.shape[0]:
        raise ValueError(f"spgemm inner dims: {A.shape} x {B.shape}")
    if mask is not None and mask.shape != (A.shape[0], B.shape[1]):
        raise ValueError(f"spgemm mask shape {mask.shape} != output "
                         f"{(A.shape[0], B.shape[1])}")
    if sr.mode not in SPGEMM_MODES:
        raise NotImplementedError(
            f"spgemm does not support mode {sr.mode!r} (semiring {sr.name})")
    if A.block != B.block:
        B = BSR.from_coo(*B.to_coo(), B.shape, block=A.block)
    if mask is not None and mask.block != A.block:
        mask = BSR.from_coo(*mask.to_coo(), mask.shape, block=A.block)

    shape = (A.shape[0], B.shape[1])
    plan = spgemm_symbolic(A, B, mask=mask, complement=complement)
    if plan.ntasks == 0:
        return BSR.from_blocks_device(plan.c_rows, plan.c_cols,
                                      np.zeros((0, A.block, A.block),
                                               np.float32),
                                      shape, A.block)

    from repro.kernels import bsr_spgemm as _k   # lazy: kernels import core
    mask_blocks = None
    if mask is not None:
        sel = jnp.asarray(np.clip(plan.mask_sel, 0, None))
        present = jnp.asarray((plan.mask_sel >= 0).astype(np.float32))
        mask_blocks = (mask.blocks.astype(jnp.float32)[sel]
                       * present[:, None, None])
    cblocks = _k.spgemm_blocks(A.blocks, B.blocks, plan, sr,
                               mask_blocks=mask_blocks, complement=complement,
                               impl=impl, interpret=interpret)
    # device-side assembly: the numeric-phase output tiles never visit host
    return BSR.from_blocks_device(plan.c_rows, plan.c_cols, cblocks,
                                  shape, A.block)


def bsr_union(A: "BSR", B: "BSR") -> "BSR":
    """Structural (boolean) union of two same-shape BSR patterns — the
    GrB_eWiseAdd(or) analog the multi-hop reachability matrices need."""
    if A.shape != B.shape:
        raise ValueError(f"bsr_union shapes: {A.shape} vs {B.shape}")
    ra, ca, _ = A.to_coo()
    rb, cb, _ = B.to_coo()
    r = np.concatenate([ra, rb]).astype(np.int64)
    c = np.concatenate([ca, cb]).astype(np.int64)
    key = r * A.shape[1] + c
    _, idx = np.unique(key, return_index=True)
    return BSR.from_coo(r[idx], c[idx], None, A.shape, block=A.block)


# ---------------------------------------------------------------------------
# element-wise family: block-aligned sparse ops (GrB_eWiseAdd / eWiseMult /
# GrB_apply / GxB_select), never materializing a dense operand
# ---------------------------------------------------------------------------
# Stored == nonzero (the repo-wide structural convention); an absent entry
# renders as 0 when densified. All ops therefore split into a host-side
# coordinate plan (union / intersection of block keys, the element-wise
# analog of the SpGEMM symbolic phase) and a *device-resident* numeric phase:
# the gathered-tile map in kernels/bsr_ewise.py (Pallas on TPU, an XLA
# gather reference elsewhere). Results go through BSR.from_blocks_device, so
# tiles that end up all-zero (a select that empties a tile, a cancelled add)
# are pruned and nvals/fill_ratio stay truthful — and the payloads never
# round-trip through host numpy (`host_numeric_calls()` pins this).

def reblock(A: "BSR", block: int) -> "BSR":
    """Rebuild at a different tile size (sparse: COO round-trip, no dense)."""
    if A.block == block:
        return A
    return BSR.from_coo(*A.to_coo(), A.shape, block=block)


def as_bsr(store, block: int) -> "BSR":
    """Coerce sparse storage — a BSR at any tile size, or anything exposing
    ``to_coo`` (ELL) — to a BSR at the given block size. Sparse-to-sparse:
    goes through the COO entry list, never a dense intermediate."""
    if isinstance(store, BSR):
        return reblock(store, block)
    return BSR.from_coo(*store.to_coo(), store.shape, block=block)


def _check_same_shape(A: "BSR", B: "BSR", opname: str) -> None:
    if A.shape != B.shape:
        raise ValueError(f"{opname} shapes: {A.shape} vs {B.shape}")


def _tile_keys(brows: np.ndarray, bcols: np.ndarray, nbc: int) -> np.ndarray:
    return brows.astype(np.int64) * nbc + bcols.astype(np.int64)


def _key_select(wanted: np.ndarray, keys: np.ndarray,
                idx: np.ndarray) -> np.ndarray:
    """For each key in ``wanted``, the tile index in ``idx`` holding it, or
    -1 when no stored tile has that key. ``keys`` need not be sorted."""
    out = np.full(len(wanted), -1, dtype=np.int32)
    if len(keys) == 0 or len(wanted) == 0:
        return out
    order = np.argsort(keys)
    keys, idx = keys[order], idx[order]
    j = np.clip(np.searchsorted(keys, wanted), 0, len(keys) - 1)
    hit = keys[j] == wanted
    out[hit] = idx[j[hit]]
    return out


def _map_tiles(Ablocks, sel_a, Bblocks, sel_b, mode, op, impl):
    from repro.kernels import bsr_ewise as _k   # lazy: kernels import core
    return _k.map_tiles(Ablocks, sel_a, Bblocks, sel_b, mode, op, impl=impl)


def ewise_add(A: "BSR", B: "BSR", op, impl: str = "xla") -> "BSR":
    """C = A (+) B — GraphBLAS *union* semantics over stored entries.

    Pattern(C) = pattern(A) | pattern(B). Where both sides store an entry
    the value is op(a, b); where only one side does, the stored value passes
    through *unchanged* — the absent side is never fed to op, so
    non-zero-preserving monoids (min, max with negatives) stay correct.
    Block-aligned: one gathered tile pair per union tile, numerics on device.
    """
    _check_same_shape(A, B, "bsr.ewise_add")
    B = reblock(B, A.block)
    ia, ra, ca = A.valid_tiles()
    ib, rb, cb = B.valid_tiles()
    nbc = A.nbcols
    ka = _tile_keys(ra, ca, nbc)
    kb = _tile_keys(rb, cb, nbc)
    keys = np.union1d(ka, kb)
    res = _map_tiles(A.blocks, _key_select(keys, ka, ia),
                     B.blocks, _key_select(keys, kb, ib), "union", op, impl)
    return BSR.from_blocks_device((keys // nbc).astype(np.int32),
                                  (keys % nbc).astype(np.int32),
                                  res, A.shape, A.block)


def ewise_mult(A: "BSR", B: "BSR", op, impl: str = "xla") -> "BSR":
    """C = A (.*) B — GraphBLAS *intersection* semantics over stored entries.

    Pattern(C) = pattern(A) & pattern(B); values op(a, b) on the
    intersection. Only tiles valid in BOTH operands are even gathered — the
    structural intersection prunes whole blocks before any element work.
    """
    _check_same_shape(A, B, "bsr.ewise_mult")
    B = reblock(B, A.block)
    ia, ra, ca = A.valid_tiles()
    ib, rb, cb = B.valid_tiles()
    nbc = A.nbcols
    ka = _tile_keys(ra, ca, nbc)
    kb = _tile_keys(rb, cb, nbc)
    keys = np.intersect1d(ka, kb)
    res = _map_tiles(A.blocks, _key_select(keys, ka, ia),
                     B.blocks, _key_select(keys, kb, ib), "intersect", op,
                     impl)
    return BSR.from_blocks_device((keys // nbc).astype(np.int32),
                                  (keys % nbc).astype(np.int32),
                                  res, A.shape, A.block)


def apply_stored(A: "BSR", f, impl: str = "xla") -> "BSR":
    """GrB_apply over stored entries only: C[i,j] = f(A[i,j]) where stored.

    f runs on the valid tile payloads; zero lanes inside a stored tile are
    *absent* entries and stay zero regardless of f(0) — structural
    semantics, not a dense map."""
    ia, ra, ca = A.valid_tiles()
    res = _map_tiles(A.blocks, ia, None, None, "apply", f, impl)
    return BSR.from_blocks_device(ra, ca, res, A.shape, A.block)


def select_stored(A: "BSR", pred, impl: str = "xla") -> "BSR":
    """GxB_select: keep stored entries where pred(value); drop the rest.
    Tiles the predicate empties entirely are pruned (from_blocks_device)."""
    ia, ra, ca = A.valid_tiles()
    res = _map_tiles(A.blocks, ia, None, None, "select", pred, impl)
    return BSR.from_blocks_device(ra, ca, res, A.shape, A.block)


def mask_keep(A: "BSR", M: "BSR", complement: bool = False,
              impl: str = "xla") -> "BSR":
    """A restricted to M's stored element pattern (<M>), or to its absent
    pattern (<!M>) — the sparse building block of the descriptor blend.
    Non-complemented masks drop A tiles with no mask tile without gathering
    them; complemented masks keep those tiles whole (an absent mask tile
    reads as all-zero, which `mask_c` keeps in full)."""
    _check_same_shape(A, M, "bsr.mask_keep")
    M = reblock(M, A.block)
    ia, ra, ca = A.valid_tiles()
    im, rm, cm = M.valid_tiles()
    nbc = A.nbcols
    sel_m = _key_select(_tile_keys(ra, ca, nbc), _tile_keys(rm, cm, nbc), im)
    if not complement:
        keep_tile = sel_m >= 0          # block-level prune, SpGEMM-style
        ia, ra, ca, sel_m = ia[keep_tile], ra[keep_tile], ca[keep_tile], \
            sel_m[keep_tile]
    res = _map_tiles(A.blocks, ia, M.blocks, sel_m,
                     "mask_c" if complement else "mask", None, impl)
    return BSR.from_blocks_device(ra, ca, res, A.shape, A.block)


def extract_ranges(A: "BSR", r0: int, r1: int, c0: int, c1: int) -> "BSR":
    """Block-aligned GrB_extract fast path: A[r0:r1, c0:c1] with r0/c0 on
    tile boundaries — tile-list surgery on host coordinates; the payload
    gather and boundary cropping stay on device."""
    if r0 % A.block or c0 % A.block:
        raise ValueError("extract_ranges needs block-aligned starts "
                         f"(got {r0}, {c0} for block {A.block})")
    b = A.block
    br0, bc0 = r0 // b, c0 // b
    br1, bc1 = -(-r1 // b), -(-c1 // b)
    ia, ra, ca = A.valid_tiles()
    keep = (ra >= br0) & (ra < br1) & (ca >= bc0) & (ca < bc1)
    ia, ra, ca = ia[keep], ra[keep] - br0, ca[keep] - bc0
    out_n, out_m = r1 - r0, c1 - c0
    if len(ia):
        blk = jnp.asarray(A.blocks).astype(jnp.float32)[jnp.asarray(ia)]
        # crop boundary tiles that extend past the slice end (the crop
        # pattern is host structural metadata; the multiply runs on device)
        rows_ok = (ra[:, None] * b + np.arange(b)[None, :]) < out_n
        cols_ok = (ca[:, None] * b + np.arange(b)[None, :]) < out_m
        blk = blk * jnp.asarray((rows_ok[:, :, None]
                                 & cols_ok[:, None, :]).astype(np.float32))
    else:
        blk = jnp.zeros((0, b, b), jnp.float32)
    return BSR.from_blocks_device(ra, ca, blk, (out_n, out_m), b)
