"""Block-Sparse-Row matrices: the TPU-native replacement for SuiteSparse CSR.

The adjacency matrix is partitioned into ``block x block`` *dense* tiles; only
tiles containing at least one edge are stored.  Dense 128x128 tiles feed the MXU
directly; the tile-index lists carry the sparsity *between* tiles.  Construction
is host-side numpy (the database load path); the device representation is a
registered pytree so BSR matrices flow through jit/shard_map.

Kernel-steering invariants (relied on by kernels/bsr_mxm.py):
  * blocks are sorted by (block_row, block_col);
  * every block-row has >= 1 stored block (empty rows get a padding block with
    valid=0) so the output tile of every row is initialized exactly once;
  * `first` marks the first block of each block-row; `last` the last;
  * trailing grid padding repeats the final block with valid=0, first=0, last=0.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BSR:
    shape: Tuple[int, int]
    block: int
    # device arrays -------------------------------------------------------
    blocks: jnp.ndarray      # (nnzb, block, block) tile payloads
    block_rows: jnp.ndarray  # (nnzb,) i32 block-row of each tile
    block_cols: jnp.ndarray  # (nnzb,) i32 block-col of each tile
    first: jnp.ndarray       # (nnzb,) i32 1 iff first tile in its block-row
    last: jnp.ndarray        # (nnzb,) i32 1 iff last tile in its block-row
    valid: jnp.ndarray       # (nnzb,) i32 0 for padding tiles
    row_ptr: jnp.ndarray     # (nbrows+1,) i32 CSR-style pointers over tiles
    # static metadata ------------------------------------------------------
    nnz: int                 # scalar element count (pre-blocking)

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        children = (self.blocks, self.block_rows, self.block_cols,
                    self.first, self.last, self.valid, self.row_ptr)
        aux = (self.shape, self.block, self.nnz)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, block, nnz = aux
        return cls(shape, block, *children, nnz=nnz)

    # -- properties ----------------------------------------------------------
    @property
    def nnzb(self) -> int:
        return self.blocks.shape[0]

    @property
    def nbrows(self) -> int:
        return -(-self.shape[0] // self.block)

    @property
    def nbcols(self) -> int:
        return -(-self.shape[1] // self.block)

    @property
    def fill_ratio(self) -> float:
        """nnz / stored-tile capacity — the BSR-vs-ELL format-switch signal."""
        cap = int(np.asarray(self.valid).sum()) * self.block * self.block
        return self.nnz / max(cap, 1)

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_coo(rows, cols, vals, shape, block: int = 128,
                 dtype=jnp.float32, pad_to: int = 8) -> "BSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if vals is None:
            vals = np.ones(rows.shape[0], dtype=np.float64)
        vals = np.asarray(vals, dtype=np.float64)
        n, m = shape
        nbr, nbc = -(-n // block), -(-m // block)
        brow, bcol = rows // block, cols // block
        key = brow * nbc + bcol
        order = np.argsort(key, kind="stable")
        rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
        ukey, starts = np.unique(key, return_index=True)
        starts = np.append(starts, rows.shape[0])
        ubrow, ubcol = (ukey // nbc).astype(np.int32), (ukey % nbc).astype(np.int32)

        # ensure every block-row has >= 1 tile: add invalid padding tiles
        present = np.zeros(nbr, dtype=bool)
        present[ubrow] = True
        missing = np.nonzero(~present)[0].astype(np.int32)

        tot = len(ukey) + len(missing)
        blocks = np.zeros((tot, block, block), dtype=np.float32)
        b_r = np.empty(tot, dtype=np.int32)
        b_c = np.empty(tot, dtype=np.int32)
        valid = np.empty(tot, dtype=np.int32)

        for i in range(len(ukey)):
            s, e = starts[i], starts[i + 1]
            lr = (rows[s:e] - ubrow[i] * block).astype(np.int64)
            lc = (cols[s:e] - ubcol[i] * block).astype(np.int64)
            np.add.at(blocks[i], (lr, lc), 0.0)  # touch
            blocks[i][lr, lc] = vals[s:e]
        b_r[: len(ukey)] = ubrow
        b_c[: len(ukey)] = ubcol
        valid[: len(ukey)] = 1
        b_r[len(ukey):] = missing
        b_c[len(ukey):] = 0
        valid[len(ukey):] = 0

        # re-sort with padding tiles interleaved
        order = np.argsort(b_r * nbc + b_c, kind="stable")
        blocks, b_r, b_c, valid = blocks[order], b_r[order], b_c[order], valid[order]

        first = np.zeros(tot, dtype=np.int32)
        last = np.zeros(tot, dtype=np.int32)
        first[0] = 1
        first[1:] = (b_r[1:] != b_r[:-1]).astype(np.int32)
        last[:-1] = first[1:]
        last[-1] = 1

        row_ptr = np.zeros(nbr + 1, dtype=np.int32)
        np.add.at(row_ptr, b_r + 1, 1)
        row_ptr = np.cumsum(row_ptr).astype(np.int32)

        # pad nnzb to a grid-friendly multiple; pads repeat the final tile
        pad = (-tot) % pad_to
        if pad:
            blocks = np.concatenate([blocks, np.zeros((pad, block, block), np.float32)])
            b_r = np.concatenate([b_r, np.full(pad, b_r[-1], np.int32)])
            b_c = np.concatenate([b_c, np.full(pad, b_c[-1], np.int32)])
            valid = np.concatenate([valid, np.zeros(pad, np.int32)])
            first = np.concatenate([first, np.zeros(pad, np.int32)])
            last = np.concatenate([last, np.zeros(pad, np.int32)])

        return BSR(
            shape=(n, m), block=block,
            blocks=jnp.asarray(blocks, dtype=dtype),
            block_rows=jnp.asarray(b_r), block_cols=jnp.asarray(b_c),
            first=jnp.asarray(first), last=jnp.asarray(last),
            valid=jnp.asarray(valid), row_ptr=jnp.asarray(row_ptr),
            nnz=int(rows.shape[0]),
        )

    @staticmethod
    def from_dense(A, block: int = 128, dtype=jnp.float32) -> "BSR":
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return BSR.from_coo(r, c, A[r, c], A.shape, block=block, dtype=dtype)

    def to_dense(self) -> jnp.ndarray:
        n, m = self.shape
        block = self.block
        nbr, nbc = self.nbrows, self.nbcols
        out = np.zeros((nbr * block, nbc * block), dtype=np.float32)
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        for i in range(blocks.shape[0]):
            if va[i]:
                out[br[i] * block:(br[i] + 1) * block,
                    bc[i] * block:(bc[i] + 1) * block] = blocks[i]
        return jnp.asarray(out[:n, :m])

    def transpose(self) -> "BSR":
        """Host-side rebuild (RedisGraph also maintains explicit transposes)."""
        dense = np.asarray(self.to_dense()).T
        return BSR.from_dense(dense, block=self.block, dtype=self.blocks.dtype)

    def to_coo(self):
        """Host-side COO extraction (snapshot/persistence path)."""
        b = self.block
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        rows, cols, vals = [], [], []
        for i in range(blocks.shape[0]):
            if not va[i]:
                continue
            lr, lc = np.nonzero(blocks[i])
            rows.append(lr + br[i] * b)
            cols.append(lc + bc[i] * b)
            vals.append(blocks[i][lr, lc])
        if not rows:
            return (np.zeros(0, np.int64),) * 2 + (np.zeros(0, np.float32),)
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))
