"""Block-Sparse-Row matrices: the TPU-native replacement for SuiteSparse CSR.

The adjacency matrix is partitioned into ``block x block`` *dense* tiles; only
tiles containing at least one edge are stored.  Dense 128x128 tiles feed the MXU
directly; the tile-index lists carry the sparsity *between* tiles.  Construction
is host-side numpy (the database load path); the device representation is a
registered pytree so BSR matrices flow through jit/shard_map.

Kernel-steering invariants (relied on by kernels/bsr_mxm.py):
  * blocks are sorted by (block_row, block_col);
  * every block-row has >= 1 stored block (empty rows get a padding block with
    valid=0) so the output tile of every row is initialized exactly once;
  * `first` marks the first block of each block-row; `last` the last;
  * trailing grid padding repeats the final block with valid=0, first=0, last=0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BSR:
    shape: Tuple[int, int]
    block: int
    # device arrays -------------------------------------------------------
    blocks: jnp.ndarray      # (nnzb, block, block) tile payloads
    block_rows: jnp.ndarray  # (nnzb,) i32 block-row of each tile
    block_cols: jnp.ndarray  # (nnzb,) i32 block-col of each tile
    first: jnp.ndarray       # (nnzb,) i32 1 iff first tile in its block-row
    last: jnp.ndarray        # (nnzb,) i32 1 iff last tile in its block-row
    valid: jnp.ndarray       # (nnzb,) i32 0 for padding tiles
    row_ptr: jnp.ndarray     # (nbrows+1,) i32 CSR-style pointers over tiles
    # static metadata ------------------------------------------------------
    nnz: int                 # scalar element count (pre-blocking)

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        children = (self.blocks, self.block_rows, self.block_cols,
                    self.first, self.last, self.valid, self.row_ptr)
        aux = (self.shape, self.block, self.nnz)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, block, nnz = aux
        return cls(shape, block, *children, nnz=nnz)

    # -- properties ----------------------------------------------------------
    @property
    def nnzb(self) -> int:
        return self.blocks.shape[0]

    @property
    def nbrows(self) -> int:
        return -(-self.shape[0] // self.block)

    @property
    def nbcols(self) -> int:
        return -(-self.shape[1] // self.block)

    @property
    def fill_ratio(self) -> float:
        """nnz / stored-tile capacity — the BSR-vs-ELL format-switch signal."""
        cap = int(np.asarray(self.valid).sum()) * self.block * self.block
        return self.nnz / max(cap, 1)

    # -- construction --------------------------------------------------------
    @staticmethod
    def _assemble(blocks, b_r, b_c, shape, block: int, nnz: int,
                  dtype=jnp.float32, pad_to: int = 8) -> "BSR":
        """Build a BSR from a host-side list of *valid* tiles with unique,
        unsorted (block_row, block_col) coordinates, establishing every
        kernel-steering invariant (padding rows, sort order, first/last
        flags, row_ptr, grid padding)."""
        n, m = shape
        nbr, nbc = -(-n // block), -(-m // block)

        # ensure every block-row has >= 1 tile: add invalid padding tiles
        present = np.zeros(nbr, dtype=bool)
        present[b_r] = True
        missing = np.nonzero(~present)[0].astype(np.int32)

        nv = len(b_r)
        tot = nv + len(missing)
        allb = np.zeros((tot, block, block), dtype=np.float32)
        allb[:nv] = blocks
        a_r = np.empty(tot, dtype=np.int32)
        a_c = np.empty(tot, dtype=np.int32)
        valid = np.empty(tot, dtype=np.int32)
        a_r[:nv] = b_r
        a_c[:nv] = b_c
        valid[:nv] = 1
        a_r[nv:] = missing
        a_c[nv:] = 0
        valid[nv:] = 0

        # sort with padding tiles interleaved
        order = np.argsort(a_r * nbc + a_c, kind="stable")
        allb, a_r, a_c, valid = allb[order], a_r[order], a_c[order], valid[order]

        first = np.zeros(tot, dtype=np.int32)
        last = np.zeros(tot, dtype=np.int32)
        first[0] = 1
        first[1:] = (a_r[1:] != a_r[:-1]).astype(np.int32)
        last[:-1] = first[1:]
        last[-1] = 1

        row_ptr = np.zeros(nbr + 1, dtype=np.int32)
        np.add.at(row_ptr, a_r + 1, 1)
        row_ptr = np.cumsum(row_ptr).astype(np.int32)

        # pad nnzb to a grid-friendly multiple; pads repeat the final tile
        pad = (-tot) % pad_to
        if pad:
            allb = np.concatenate([allb, np.zeros((pad, block, block), np.float32)])
            a_r = np.concatenate([a_r, np.full(pad, a_r[-1], np.int32)])
            a_c = np.concatenate([a_c, np.full(pad, a_c[-1], np.int32)])
            valid = np.concatenate([valid, np.zeros(pad, np.int32)])
            first = np.concatenate([first, np.zeros(pad, np.int32)])
            last = np.concatenate([last, np.zeros(pad, np.int32)])

        return BSR(
            shape=(n, m), block=block,
            blocks=jnp.asarray(allb, dtype=dtype),
            block_rows=jnp.asarray(a_r), block_cols=jnp.asarray(a_c),
            first=jnp.asarray(first), last=jnp.asarray(last),
            valid=jnp.asarray(valid), row_ptr=jnp.asarray(row_ptr),
            nnz=nnz,
        )

    @staticmethod
    def from_coo(rows, cols, vals, shape, block: int = 128,
                 dtype=jnp.float32, pad_to: int = 8) -> "BSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if vals is None:
            vals = np.ones(rows.shape[0], dtype=np.float64)
        vals = np.asarray(vals, dtype=np.float64)
        n, m = shape
        nbc = -(-m // block)
        brow, bcol = rows // block, cols // block
        key = brow * nbc + bcol
        order = np.argsort(key, kind="stable")
        rows, cols, vals, key = rows[order], cols[order], vals[order], key[order]
        ukey, starts = np.unique(key, return_index=True)
        starts = np.append(starts, rows.shape[0])
        ubrow, ubcol = (ukey // nbc).astype(np.int32), (ukey % nbc).astype(np.int32)

        blocks = np.zeros((len(ukey), block, block), dtype=np.float32)
        for i in range(len(ukey)):
            s, e = starts[i], starts[i + 1]
            lr = (rows[s:e] - ubrow[i] * block).astype(np.int64)
            lc = (cols[s:e] - ubcol[i] * block).astype(np.int64)
            np.add.at(blocks[i], (lr, lc), 0.0)  # touch
            blocks[i][lr, lc] = vals[s:e]

        return BSR._assemble(blocks, ubrow, ubcol, (n, m), block,
                             nnz=int(rows.shape[0]), dtype=dtype,
                             pad_to=pad_to)

    @staticmethod
    def from_blocks(block_rows, block_cols, blocks, shape, block: int,
                    dtype=jnp.float32, pad_to: int = 8,
                    prune: bool = True) -> "BSR":
        """Assemble a BSR from computed tile payloads (the SpGEMM numeric
        phase). All-zero tiles — masked-out or numerically cancelled output
        blocks — are pruned so `nvals`/`fill_ratio` report stored structure,
        not kernel artifacts; `nnz` counts the surviving nonzero entries."""
        blocks = np.asarray(blocks, dtype=np.float32)
        b_r = np.asarray(block_rows, dtype=np.int32)
        b_c = np.asarray(block_cols, dtype=np.int32)
        if prune and len(b_r):
            keep = (blocks != 0).any(axis=(1, 2))
            blocks, b_r, b_c = blocks[keep], b_r[keep], b_c[keep]
        nnz = int(np.count_nonzero(blocks))
        return BSR._assemble(blocks, b_r, b_c, shape, block, nnz=nnz,
                             dtype=dtype, pad_to=pad_to)

    @staticmethod
    def from_dense(A, block: int = 128, dtype=jnp.float32) -> "BSR":
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return BSR.from_coo(r, c, A[r, c], A.shape, block=block, dtype=dtype)

    def to_dense(self) -> jnp.ndarray:
        n, m = self.shape
        block = self.block
        nbr, nbc = self.nbrows, self.nbcols
        out = np.zeros((nbr * block, nbc * block), dtype=np.float32)
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        for i in range(blocks.shape[0]):
            if va[i]:
                out[br[i] * block:(br[i] + 1) * block,
                    bc[i] * block:(bc[i] + 1) * block] = blocks[i]
        return jnp.asarray(out[:n, :m])

    def transpose(self) -> "BSR":
        """Host-side rebuild (RedisGraph also maintains explicit transposes)."""
        dense = np.asarray(self.to_dense()).T
        return BSR.from_dense(dense, block=self.block, dtype=self.blocks.dtype)

    def valid_tiles(self):
        """Host-side (indices, block_rows, block_cols) of the valid tiles."""
        va = np.asarray(self.valid).astype(bool)
        idx = np.nonzero(va)[0].astype(np.int32)
        return (idx, np.asarray(self.block_rows)[idx],
                np.asarray(self.block_cols)[idx])

    def to_coo(self):
        """Host-side COO extraction (snapshot/persistence path)."""
        b = self.block
        blocks = np.asarray(self.blocks, dtype=np.float32)
        br = np.asarray(self.block_rows)
        bc = np.asarray(self.block_cols)
        va = np.asarray(self.valid)
        rows, cols, vals = [], [], []
        for i in range(blocks.shape[0]):
            if not va[i]:
                continue
            lr, lc = np.nonzero(blocks[i])
            rows.append(lr + br[i] * b)
            cols.append(lc + bc[i] * b)
            vals.append(blocks[i][lr, lc])
        if not rows:
            return (np.zeros(0, np.int64),) * 2 + (np.zeros(0, np.float32),)
        return (np.concatenate(rows), np.concatenate(cols),
                np.concatenate(vals))


# ---------------------------------------------------------------------------
# SpGEMM: C<M> = A (x) B with BOTH operands block-sparse
# ---------------------------------------------------------------------------
# Semiring modes the SpGEMM numeric phase supports (every MXU-dot mode; the
# tropical bcast modes fall back to the dense pipeline in grb.mxm).
SPGEMM_MODES = ("dot", "dot_pair", "dot_indicator", "dot_first")


@dataclasses.dataclass
class SpGEMMPlan:
    """Output of the *symbolic* phase: the block-level multiply schedule.

    One task t multiplies A tile ``a_sel[t]`` by B tile ``b_sel[t]`` and
    accumulates into output tile ``c_sel[t]``; tasks are sorted by c_sel so
    each output tile is a contiguous run (``first``/``last`` bound it, the
    Pallas revisit schedule relies on it). ``valid=0`` marks grid padding.
    With a non-complemented mask the schedule is already restricted to the
    mask's block pattern; ``mask_sel[j]`` is the mask tile backing output
    tile j (-1 = absent, i.e. an all-zero mask tile).
    """
    a_sel: np.ndarray     # (T,) i32 index into A.blocks
    b_sel: np.ndarray     # (T,) i32 index into B.blocks
    c_sel: np.ndarray     # (T,) i32 index into the output tile list
    first: np.ndarray     # (T,) i32 1 iff first task of its output tile
    last: np.ndarray      # (T,) i32 1 iff last task of its output tile
    valid: np.ndarray     # (T,) i32 0 for padding tasks
    c_rows: np.ndarray    # (nc,) i32 block-row per output tile
    c_cols: np.ndarray    # (nc,) i32 block-col per output tile
    mask_sel: Optional[np.ndarray]  # (nc,) i32 mask tile per output tile / -1

    @property
    def ntasks(self) -> int:
        return int(self.a_sel.shape[0])

    @property
    def nc(self) -> int:
        return int(self.c_rows.shape[0])


def _ragged_ranges(offsets: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """concat(range(offsets[i], offsets[i]+lens[i]) for i) vectorized."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    task_start = np.repeat(ends - lens, lens)
    return np.arange(total, dtype=np.int64) - task_start + np.repeat(offsets, lens)


def spgemm_symbolic(A: "BSR", B: "BSR", mask: Optional["BSR"] = None,
                    complement: bool = False, pad_to: int = 8) -> SpGEMMPlan:
    """Block-level pattern of C = A (x) B, optionally restricted to <M>.

    Host-side numpy over tile coordinate lists (the analog of SuiteSparse's
    symbolic pass over column patterns): pair every valid A tile (i, l) with
    every valid B tile (l, j), group tasks by output tile (i, j). A
    non-complemented structural mask prunes output tiles — and therefore
    whole task groups — *before* any numeric work; a complemented mask
    cannot prune (absent mask tiles are kept entries), so it only annotates.
    """
    ia, bra, bca = A.valid_tiles()
    ib, brb, bcb = B.valid_tiles()
    nbc_out = B.nbcols

    # group B tiles by block-row (the inner dimension)
    order = np.argsort(brb, kind="stable")
    ib, brb, bcb = ib[order], brb[order], bcb[order]
    nbk = B.nbrows
    cnt = np.bincount(brb, minlength=nbk)
    ptr = np.concatenate([[0], np.cumsum(cnt)])

    # one task per (A tile, matching B tile) pair
    lens = cnt[bca]
    a_rep = np.repeat(np.arange(len(ia), dtype=np.int64), lens)
    pos = _ragged_ranges(ptr[bca], lens)
    a_sel = ia[a_rep]
    b_sel = ib[pos]
    ckey = bra[a_rep].astype(np.int64) * nbc_out + bcb[pos]

    mkeys = midx = None
    if mask is not None:
        im, brm, bcm = mask.valid_tiles()
        mkeys = brm.astype(np.int64) * nbc_out + bcm
        morder = np.argsort(mkeys)
        mkeys, midx = mkeys[morder], im[morder]
        if not complement:
            # structural mask prunes the schedule block-wise, up front
            keep = np.isin(ckey, mkeys)
            a_sel, b_sel, ckey = a_sel[keep], b_sel[keep], ckey[keep]

    # sort tasks by output tile -> contiguous accumulation runs
    order = np.argsort(ckey, kind="stable")
    a_sel, b_sel, ckey = a_sel[order], b_sel[order], ckey[order]
    ukey, c_sel = np.unique(ckey, return_inverse=True)
    c_rows = (ukey // nbc_out).astype(np.int32)
    c_cols = (ukey % nbc_out).astype(np.int32)

    ntask = len(ckey)
    first = np.zeros(ntask, dtype=np.int32)
    last = np.zeros(ntask, dtype=np.int32)
    if ntask:
        first[0] = 1
        first[1:] = (ckey[1:] != ckey[:-1]).astype(np.int32)
        last[:-1] = first[1:]
        last[-1] = 1
    valid = np.ones(ntask, dtype=np.int32)

    mask_sel = None
    if mask is not None:
        # mask tile index per output tile (-1: no stored mask tile there)
        if len(mkeys):
            j = np.clip(np.searchsorted(mkeys, ukey), 0, len(mkeys) - 1)
            mask_sel = np.where(mkeys[j] == ukey, midx[j], -1).astype(np.int32)
        else:
            mask_sel = np.full(len(ukey), -1, dtype=np.int32)

    # pad the task list to a grid-friendly multiple (repeat the last task
    # with valid=0 so index maps stay in range and no tile re-inits)
    pad = (-ntask) % pad_to if ntask else 0
    if pad:
        a_sel = np.concatenate([a_sel, np.full(pad, a_sel[-1])])
        b_sel = np.concatenate([b_sel, np.full(pad, b_sel[-1])])
        c_sel = np.concatenate([c_sel, np.full(pad, c_sel[-1])])
        first = np.concatenate([first, np.zeros(pad, np.int32)])
        last = np.concatenate([last, np.zeros(pad, np.int32)])
        valid = np.concatenate([valid, np.zeros(pad, np.int32)])

    return SpGEMMPlan(a_sel=a_sel.astype(np.int32), b_sel=b_sel.astype(np.int32),
                      c_sel=c_sel.astype(np.int32), first=first, last=last,
                      valid=valid, c_rows=c_rows, c_cols=c_cols,
                      mask_sel=mask_sel)


def spgemm(A: "BSR", B: "BSR", sr, mask: Optional["BSR"] = None,
           complement: bool = False, impl: str = "xla",
           interpret: Optional[bool] = None) -> "BSR":
    """Two-phase sparse-times-sparse mxm: C<M> = A (x) B, C stays BSR.

    Symbolic phase (host) plans the block schedule and applies a structural
    mask block-wise; numeric phase (device) runs it through the Pallas
    SpGEMM kernel (``impl="pallas"``) or the XLA gather/segment-sum
    reference (``impl="xla"``), folding the mask's *element* pattern into
    the last task of each output tile. All-zero output tiles are pruned.
    """
    if A.shape[1] != B.shape[0]:
        raise ValueError(f"spgemm inner dims: {A.shape} x {B.shape}")
    if mask is not None and mask.shape != (A.shape[0], B.shape[1]):
        raise ValueError(f"spgemm mask shape {mask.shape} != output "
                         f"{(A.shape[0], B.shape[1])}")
    if sr.mode not in SPGEMM_MODES:
        raise NotImplementedError(
            f"spgemm does not support mode {sr.mode!r} (semiring {sr.name})")
    if A.block != B.block:
        B = BSR.from_coo(*B.to_coo(), B.shape, block=A.block)
    if mask is not None and mask.block != A.block:
        mask = BSR.from_coo(*mask.to_coo(), mask.shape, block=A.block)

    shape = (A.shape[0], B.shape[1])
    plan = spgemm_symbolic(A, B, mask=mask, complement=complement)
    if plan.ntasks == 0:
        return BSR.from_blocks(plan.c_rows, plan.c_cols,
                               np.zeros((0, A.block, A.block), np.float32),
                               shape, A.block)

    from repro.kernels import bsr_spgemm as _k   # lazy: kernels import core
    mask_blocks = None
    if mask is not None:
        sel = jnp.asarray(np.clip(plan.mask_sel, 0, None))
        present = jnp.asarray((plan.mask_sel >= 0).astype(np.float32))
        mask_blocks = (mask.blocks.astype(jnp.float32)[sel]
                       * present[:, None, None])
    cblocks = _k.spgemm_blocks(A.blocks, B.blocks, plan, sr,
                               mask_blocks=mask_blocks, complement=complement,
                               impl=impl, interpret=interpret)
    return BSR.from_blocks(plan.c_rows, plan.c_cols, np.asarray(cblocks),
                           shape, A.block)


def bsr_union(A: "BSR", B: "BSR") -> "BSR":
    """Structural (boolean) union of two same-shape BSR patterns — the
    GrB_eWiseAdd(or) analog the multi-hop reachability matrices need."""
    if A.shape != B.shape:
        raise ValueError(f"bsr_union shapes: {A.shape} vs {B.shape}")
    ra, ca, _ = A.to_coo()
    rb, cb, _ = B.to_coo()
    r = np.concatenate([ra, rb]).astype(np.int64)
    c = np.concatenate([ca, cb]).astype(np.int64)
    key = r * A.shape[1] + c
    _, idx = np.unique(key, return_index=True)
    return BSR.from_coo(r[idx], c[idx], None, A.shape, block=A.block)
