"""GraphBLAS operations over BSR / ELL / dense operands.

The op surface kept here is the legacy kwargs spelling of the semiring
matmul family over raw storage:
  mxm / mxv / vxm          (semiring matmul, the traversal primitive)
plus GraphBLAS masks (with complement) and accumulators. The element-wise
family (ewise_add / ewise_mult / reduce / apply / select / assign /
extract) lives in `repro.core.grb` — format-aware, sparse-preserving.

Frontiers are dense ``(N, F)`` matrices: F queries traverse at once — the TPU
analog of RedisGraph's threadpool (one column = one query's frontier).

Three execution paths per format:
  dense  -> semiring.dense_mxm (oracle)
  BSR    -> Pallas kernel (kernels/bsr_mxm.py) or the XLA-native batched-matmul
            + segment-reduce path below (`bsr_mxm_jnp`)
  ELL    -> gather + masked reduce on the VPU (`ell_mxm`)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semiring as S
from repro.core.bsr import BSR
from repro.core.ell import ELL

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# masks & accumulators
# ---------------------------------------------------------------------------
def apply_mask(result: Array, mask: Optional[Array], complement: bool,
               accum: Optional[S.Monoid], old: Optional[Array],
               identity: float) -> Array:
    """GraphBLAS C<M> (+)= result, replace semantics when old is None.

    Legacy kwargs spelling; the canonical semantics live in
    :func:`repro.core.grb.finalize`, which this delegates to.
    """
    from repro.core import grb
    d = grb.Descriptor(mask=mask, complement=complement, accum=accum)
    return grb.finalize(d, result, old, identity)


# ---------------------------------------------------------------------------
# BSR semiring matmul — XLA-native path (batched matmul + segment reduce)
# ---------------------------------------------------------------------------
def _segment_reduce(vals: Array, ids: Array, num: int, monoid: S.Monoid) -> Array:
    if monoid.name == "plus":
        return jax.ops.segment_sum(vals, ids, num_segments=num)
    if monoid.name in ("or", "max"):
        out = jax.ops.segment_max(vals, ids, num_segments=num)
        return jnp.maximum(out, np.float32(monoid.identity) if monoid.name == "or" else out)
    if monoid.name == "min":
        return jax.ops.segment_min(vals, ids, num_segments=num)
    raise NotImplementedError(monoid.name)


def bsr_mxm_jnp(A: BSR, X: Array, sr: S.Semiring) -> Array:
    """Y = A (x) X with A in BSR. Batched 128x128 matmuls (MXU-shaped even in
    XLA) + a segment reduction over block rows."""
    n, m = A.shape
    b = A.block
    f = X.shape[1]
    nbr, nbc = A.nbrows, A.nbcols
    Xp = jnp.pad(X.astype(jnp.float32), ((0, nbc * b - m), (0, 0)))
    Xb = Xp.reshape(nbc, b, f)
    Xg = Xb[A.block_cols]                       # (nnzb, b, f) gather of X tiles
    blocks = A.blocks.astype(jnp.float32)
    valid = A.valid.astype(jnp.float32)[:, None, None]

    if sr.mode == "dot":
        contrib = jnp.einsum("kab,kbf->kaf", blocks, Xg,
                             preferred_element_type=jnp.float32) * valid
        y = _segment_reduce(contrib, A.block_rows, nbr, sr.add)
    elif sr.mode in ("dot_indicator", "dot_pair"):
        contrib = jnp.einsum("kab,kbf->kaf", (blocks != 0).astype(jnp.float32),
                             (Xg != 0).astype(jnp.float32),
                             preferred_element_type=jnp.float32) * valid
        y = _segment_reduce(contrib, A.block_rows, nbr, sr.add)
        if sr.mode == "dot_indicator":
            y = (y > 0).astype(jnp.float32)
    elif sr.mode == "dot_first":
        contrib = jnp.einsum("kab,kbf->kaf", blocks,
                             (Xg != 0).astype(jnp.float32),
                             preferred_element_type=jnp.float32) * valid
        y = _segment_reduce(contrib, A.block_rows, nbr, sr.add)
    elif sr.mode == "bcast":
        ident = np.float32(sr.identity)
        # structure: the per-entry emask when explicit 0.0 entries exist
        # (a zero-weight edge must relax under min_plus, not vanish into
        # the +inf identity), else the stored == nonzero convention
        stored = (blocks != 0) if A.emask is None else A.emask
        a = jnp.where(stored & (A.valid[:, None, None] != 0),
                      blocks, ident)

        def one(k):
            prod = sr.mul(a[k][:, :, None], Xg[k][None, :, :])   # (b, b, f)
            return sr.add.reduce(prod, axis=1)

        contrib = jax.lax.map(one, jnp.arange(A.nnzb))
        y = _segment_reduce(contrib, A.block_rows, nbr, sr.add)
    else:
        raise NotImplementedError(sr.mode)
    return y.reshape(nbr * b, f)[:n]


# ---------------------------------------------------------------------------
# ELL semiring matmul — gather path (hypersparse)
# ---------------------------------------------------------------------------
def ell_mxm(A: ELL, X: Array, sr: S.Semiring, row_chunk: int = 0) -> Array:
    """Y[i,f] = add_{j in adj(i)} mul(w_ij, X[j,f]) via gather + masked reduce."""
    n, _ = A.shape
    ident = np.float32(sr.identity)

    def block(idx, msk, val):
        Xg = X.astype(jnp.float32)[idx]                    # (rows, deg, f)
        w = val[:, :, None]
        m = msk[:, :, None]
        if sr.mode == "dot":
            term = jnp.where(m, w * Xg, ident)
        elif sr.mode in ("dot_indicator", "dot_pair"):
            term = jnp.where(m & (Xg != 0), 1.0, ident)
        elif sr.mode == "dot_first":
            term = jnp.where(m & (Xg != 0), w, ident)
        elif sr.mode == "bcast":
            term = jnp.where(m, sr.mul(w, Xg), ident)
        else:
            raise NotImplementedError(sr.mode)
        y = sr.add.reduce(term, axis=1)
        if sr.mode == "dot_indicator":
            y = (y > 0).astype(jnp.float32)
        return y

    if row_chunk and n > row_chunk:
        pads = (-n) % row_chunk
        idx = jnp.pad(A.indices, ((0, pads), (0, 0)))
        msk = jnp.pad(A.mask, ((0, pads), (0, 0)))
        val = jnp.pad(A.values, ((0, pads), (0, 0)))
        nb = (n + pads) // row_chunk
        out = jax.lax.map(
            lambda i: block(
                jax.lax.dynamic_slice_in_dim(idx, i * row_chunk, row_chunk),
                jax.lax.dynamic_slice_in_dim(msk, i * row_chunk, row_chunk),
                jax.lax.dynamic_slice_in_dim(val, i * row_chunk, row_chunk)),
            jnp.arange(nb))
        return out.reshape(nb * row_chunk, -1)[:n]
    return block(A.indices, A.mask, A.values)


# ---------------------------------------------------------------------------
# bitmap-packed or_and matmul — XLA reference paths (CPU + shard_map bodies)
# ---------------------------------------------------------------------------
def ell_mxm_packed(A: ELL, Xw: Array) -> Array:
    """Yw[i] = OR_{j in adj(i)} Xw[j] on uint32 frontier words — the or_and
    gather-reduce with the frontier in `core.bitmap` packed form. This is
    the XLA reference for `kernels.bitmap_mxv.ell_mxv_packed` and the
    shard-local body of the packed row-form `distr.graph2d.mxm_2d`."""
    gathered = Xw[A.indices]                               # (n, deg, W) u32
    gathered = jnp.where(A.mask[:, :, None], gathered, jnp.uint32(0))
    return jax.lax.reduce(gathered, jnp.uint32(0), jax.lax.bitwise_or, (1,))


def dense_mxm_packed(A: Array, Xw: Array, k_chunk: int = 1024) -> Array:
    """Packed or_and matmul for a dense A: Yw[i] = OR_{j: A[i,j] != 0} Xw[j].
    K is chunked to bound the (n, k_chunk, W) broadcast intermediate — the
    packed analog of semiring.dense_mxm's bcast chunking."""
    n, k = A.shape
    acc = jnp.zeros((n, Xw.shape[1]), dtype=jnp.uint32)
    for start in range(0, k, k_chunk):
        a = A[:, start:start + k_chunk] != 0
        term = jnp.where(a[:, :, None], Xw[None, start:start + k_chunk, :],
                         jnp.uint32(0))
        acc = jnp.bitwise_or(
            acc, jax.lax.reduce(term, jnp.uint32(0),
                                jax.lax.bitwise_or, (1,)))
    return acc


# ---------------------------------------------------------------------------
# public op surface
# ---------------------------------------------------------------------------
def mxm(A, X: Array, sr: S.Semiring, *, mask: Optional[Array] = None,
        complement: bool = False, accum: Optional[S.Monoid] = None,
        C: Optional[Array] = None, impl: str = "auto") -> Array:
    """Semiring matmul Y<mask> (accum)= A (x) X. A: BSR | ELL | dense.

    Legacy kwargs spelling of :func:`repro.core.grb.mxm`, kept for callers
    that hold raw storage. "auto" preserves the historical meaning (the
    XLA-native path); use a GBMatrix handle to get backend-aware policy.
    """
    from repro.core import grb
    d = grb.Descriptor(mask=mask, complement=complement, accum=accum)
    if isinstance(A, grb.GBMatrix):
        handle = A if impl == "auto" else A.with_impl(impl)
    else:
        handle = grb.GBMatrix(A, impl="pallas" if impl == "pallas" else "xla")
    return grb.mxm(handle, X, sr, d, out=C)


def mxv(A, x: Array, sr: S.Semiring, **kw) -> Array:
    """y = A (x) x for a single vector (column frontier of width 1)."""
    y = mxm(A, x[:, None], sr, **{k: (v[:, None] if k in ("mask", "C") and v is not None else v)
                                  for k, v in kw.items()})
    return y[:, 0]


def vxm(x: Array, A, sr: S.Semiring, *, A_T=None, **kw) -> Array:
    """y = x (x) A == A^T (x) x. Pass A_T (stored transpose) when available —
    RedisGraph maintains explicit transposes for exactly this."""
    target = A_T if A_T is not None else _transpose(A)
    return mxv(target, x, sr, **kw)


def _transpose(A):
    from repro.core import grb
    if isinstance(A, grb.GBMatrix):
        return A.T
    if isinstance(A, (BSR, ELL)):
        return A.transpose()
    return A.T


# The dense-only ewise_add / ewise_mult / reduce / apply / select shims that
# used to live here are retired: the format-aware element-wise family (sparse
# BSR/ELL paths, GraphBLAS union/intersection entry semantics, descriptor
# blend) is `repro.core.grb.ewise_add` / `ewise_mult` / `apply` / `select` /
# `reduce` / `assign` / `extract` — see docs/API.md §eWise.


# ---------------------------------------------------------------------------
# format auto-selection (SuiteSparse's CSR/bitmap/hyper switch, TPU edition)
# ---------------------------------------------------------------------------
def auto_format(rows, cols, vals, shape, block: int = 128,
                bsr_min_fill: float = 0.02):
    """Pick the storage kind for a COO build (fmt="auto" / impl="auto"):
    BitELL for *boolean* relations whose 32x32 tiles clear the measured
    word-route crossover (core.bitadj.auto_bitadj_ok — structure is the
    whole payload, so bit-packing wins 8x+ on memory and the or_and family
    runs word-level), else BSR (MXU path) when stored ``block``-tiles are
    dense enough, else ELL."""
    from repro.core import bitadj as _bitadj
    if _bitadj.auto_bitadj_ok(rows, cols, vals, shape):
        return _bitadj.BitELL.from_coo(rows, cols, vals, shape)
    rows_np = np.asarray(rows)
    cols_np = np.asarray(cols)
    nbc = -(-shape[1] // block)
    nb = len(np.unique(rows_np // block * nbc + cols_np // block))
    fill = len(rows_np) / max(nb * block * block, 1)
    if fill >= bsr_min_fill:
        return BSR.from_coo(rows, cols, vals, shape, block=block)
    return ELL.from_coo(rows, cols, vals, shape)
