"""Bitmap-packed boolean frontiers: 32 queries per uint32 word.

Bit-GraphBLAS (arXiv 2201.08560) observes that boolean/structural workloads
— BFS, k-hop, reachability, anything on the or_and semiring — waste 31/32 of
their bandwidth carrying float32 indicators. This module is the packed
*frontier form* behind the `grb` surface: an (n, F) boolean frontier becomes
an (n, ceil(F/32)) uint32 word array, every or_and primitive (neighbor
gather, OR-reduce, mask / complement blend) becomes a word-wise bitwise op,
and the per-hop all-gather of a sharded traversal moves 32x fewer bytes
(`distr.graph2d`). Packing is an *execution detail*: `grb.mxm`/`mxv`/`vxm`
pack and unpack at the call boundary (policy: `grb.AUTO_PACK_MIN_WIDTH`),
so algorithms keep seeing ordinary 0/1 float frontiers and results stay
bit-identical to the unpacked route.

Two lane layouts live here:

  * **bit lanes** (`pack`/`unpack`, 32 booleans per word) — the frontier
    form itself; OR across shards/neighbors is `|`, masking is `&`/`&~`.
  * **nibble lanes** (`pack_nibbles`/`unpack_nibbles`, 8 booleans per word,
    4 bits each) — the *summable* spelling used where the combining
    collective can only add (psum_scatter in the transposed sharded mxm):
    each lane holds a per-shard 0/1 partial, the sum across <= 15 row
    shards never carries into the next lane, and `> 0` per lane restores
    the OR. Still an 8x payload cut over float32.

Everything is plain jnp (traceable inside jit / shard_map / while_loop);
the Pallas inner-loop kernel for the packed ELL gather lives in
`repro.kernels.bitmap_mxv`. `pack_calls()` is the observability counter
tests pin policy decisions with (trace-time semantics, like
`core.bsr.densify_calls`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

WORD_BITS = 32          # bit lanes per uint32 word (the frontier form)
NIBBLE_LANES = 8        # summable lanes per word: 4 bits each, carry-free
NIBBLE_MAX_SHARDS = 15  # nibble sums stay carry-free up to this many addends

# -- observability: how many times a frontier was packed ----------------------
# Trace-time semantics (a cached jit does not re-count), same caveat as
# core.bsr.densify_calls; tests use deltas around eager calls.
_pack_calls = [0]


def pack_calls() -> int:
    """Total :func:`pack` invocations so far (policy-pin counter)."""
    return _pack_calls[0]


def n_words(f: int) -> int:
    """uint32 words per frontier row for an F-column boolean frontier."""
    return max(-(-int(f) // WORD_BITS), 1)


def payload_bytes(rows: int, f: int, packed: bool) -> int:
    """Wire bytes of one frontier all-gather payload: the words-per-frontier
    accounting the sharded regression pins. Unpacked frontiers travel as
    float32 indicators (4 bytes/entry); packed ones as uint32 words."""
    if packed:
        return rows * n_words(f) * 4
    return rows * f * 4


def payload_reduction(f: int) -> float:
    """Packed-vs-unpacked payload ratio for an F-wide frontier (-> 32x as F
    grows; >= 8x from F = 8)."""
    return payload_bytes(1, f, packed=False) / payload_bytes(1, f, packed=True)


def _bit_weights() -> Array:
    return jnp.left_shift(jnp.uint32(1),
                          jnp.arange(WORD_BITS, dtype=jnp.uint32))


def pack(x: Array) -> Array:
    """(n, F) anything-numeric -> (n, ceil(F/32)) uint32; bit b of word w of
    row i is `x[i, 32*w + b] != 0`. The stored-iff-nonzero convention makes
    this exact for every or_and operand, not just 0/1 arrays."""
    _pack_calls[0] += 1
    n, f = x.shape
    w = n_words(f)
    bits = (x != 0)
    bits = jnp.pad(bits, ((0, 0), (0, w * WORD_BITS - f)))
    lanes = bits.reshape(n, w, WORD_BITS).astype(jnp.uint32) * _bit_weights()
    return jax.lax.reduce(lanes, jnp.uint32(0), jax.lax.bitwise_or, (2,))


def unpack(xw: Array, f: int) -> Array:
    """(n, W) uint32 words -> (n, f) float32 0/1 indicators — the exact
    values the unpacked or_and route produces (bit-identity boundary)."""
    n, w = xw.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = jnp.bitwise_and(
        jnp.right_shift(xw[:, :, None], shifts), jnp.uint32(1))
    return bits.reshape(n, w * WORD_BITS)[:, :f].astype(jnp.float32)


# -- word-wise boolean algebra (mask / complement / visited blends) -----------
def word_or(a: Array, b: Array) -> Array:
    """Frontier union — the or_and add monoid on words."""
    return jnp.bitwise_or(a, b)


def word_and(a: Array, b: Array) -> Array:
    """`C<M>` mask keep on words."""
    return jnp.bitwise_and(a, b)


def word_andnot(a: Array, b: Array) -> Array:
    """`C<!M>` complement-mask keep on words: a & ~b (the BFS visited
    blend)."""
    return jnp.bitwise_and(a, jnp.bitwise_not(b))


def popcount(xw: Array) -> Array:
    """Per-word set-bit count (SWAR), uint32 in -> int32 out. Summed over a
    word column this is the or_and `reduce` of 32 frontiers at once."""
    x = xw.astype(jnp.uint32)
    x = x - (jnp.right_shift(x, 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + \
        (jnp.right_shift(x, 2) & jnp.uint32(0x33333333))
    x = (x + jnp.right_shift(x, 4)) & jnp.uint32(0x0F0F0F0F)
    return jnp.right_shift(x * jnp.uint32(0x01010101), 24).astype(jnp.int32)


def reduce_or_columns(xw: Array, f: int) -> Array:
    """(n, W) words -> (f,) per-query reached counts: popcount spelled as an
    unpack + column sum (the packed `grb.reduce(plus, axis=0)` of an
    indicator frontier)."""
    return jnp.sum(unpack(xw, f), axis=0, dtype=jnp.float32)


# -- nibble lanes: the summable packing for add-only collectives --------------
def _nibble_weights() -> Array:
    return jnp.left_shift(jnp.uint32(1),
                          jnp.uint32(4) * jnp.arange(NIBBLE_LANES,
                                                     dtype=jnp.uint32))


def pack_nibbles(bits: Array) -> Array:
    """(n, F) 0/1 partials -> (n, ceil(F/8)) uint32, 4 bits per lane. Sums
    of <= NIBBLE_MAX_SHARDS such words never carry across lanes — the
    psum_scatter payload of the transposed packed mxm."""
    n, f = bits.shape
    w = max(-(-f // NIBBLE_LANES), 1)
    b = jnp.pad((bits != 0), ((0, 0), (0, w * NIBBLE_LANES - f)))
    lanes = b.reshape(n, w, NIBBLE_LANES).astype(jnp.uint32) * \
        _nibble_weights()
    return jax.lax.reduce(lanes, jnp.uint32(0), jax.lax.bitwise_or, (2,))


def unpack_nibbles(xw: Array, f: int) -> Array:
    """(n, Wn) summed nibble words -> (n, f) bool "any shard contributed"
    (each lane saturates with > 0, restoring the OR the sum stood in for)."""
    n, w = xw.shape
    shifts = jnp.uint32(4) * jnp.arange(NIBBLE_LANES, dtype=jnp.uint32)
    lanes = jnp.bitwise_and(
        jnp.right_shift(xw[:, :, None], shifts), jnp.uint32(0xF))
    return (lanes.reshape(n, w * NIBBLE_LANES)[:, :f] > 0)
