"""ShardedELL: row-sharded ELL storage behind the GBMatrix surface.

The fourth GBMatrix kind (dense / BSR / ELL / *sharded*): the same ELL
(indices, mask, values) row layout, but laid out over a ``jax.sharding.Mesh``
instead of one device —

  * adjacency rows           -> the mesh's "data" axis (row blocks),
  * frontier/query columns F -> the "pod" x "model" axes (query scale-out,
    the paper's threadpool claim at pod scale),
  * padded rows (mask-false) square the row count up to a multiple of the
    "data" axis so every shard_map spec divides evenly.

Storage only lives here; the *operations* stay where they always were:
``grb.mxm``/``mxv``/``reduce`` dispatch on the format tag and lower to the
explicit-collective shard_map bodies in ``repro.distr.graph2d`` (one frontier
all-gather per hop in row form, a psum_scatter of row blocks in transposed
form), so algorithms and the query executor run unchanged on a mesh. Wide
or_and frontiers cross the mesh bitmap-packed (``core.bitmap`` uint32
words — 32x less all-gather payload; grb sets ``packed=`` from its policy,
this module only pads/packs/unpacks at the lowering boundary).
``apply``/``select`` are embarrassingly local (stored-entry value maps) and
run right on the sharded arrays below. eWiseAdd/Mult, mask restricts,
column extract/assign, and min/max reduce are *also* mesh-resident now:
two identically-meshed operands merge shard-locally through the
slot-alignment pass in ``distr.graph2d.ewise_2d`` (rows live whole on one
shard, so COO set algebra is row-local). Only genuinely cross-shard
requests — row-subset extract/assign, a mask sharded on a *different*
mesh — still gather to host, and every such gather bumps
``core.xfer.host_transfers()`` (surfaced as ``grb.host_transfers()``).

Public contract: construction needs a Mesh with a "data" axis (TypeError /
ValueError otherwise); ``to_ell``/``to_dense``/``to_coo``/``transpose``
gather to host by design *and are counted*; everything in the "local
stored-entry ops" section is collective-free. Mixed sharded/unsharded
operand TypeErrors are raised one layer up, in ``repro.core.grb``, which
owns the pairing rules.

Handles over this storage are host-side objects like every GBMatrix; the
sharded jnp arrays are what flows through jit. The padded row block is an
internal detail: logical ``shape`` and stored-entry ``nnz`` never include it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import xfer
from repro.core.ell import ELL

ROW_AXIS = "data"                      # adjacency rows shard over this axis
FRONTIER_AXES = ("pod", "model")       # frontier columns shard over these


def frontier_axes(mesh: Mesh) -> tuple:
    """The mesh axes (in canonical order) that shard the frontier's F dim."""
    return tuple(a for a in FRONTIER_AXES if a in mesh.axis_names)


def frontier_spec(mesh: Mesh):
    """PartitionSpec entry for the frontier's F dimension on this mesh."""
    fr = frontier_axes(mesh)
    if not fr:
        return None
    return fr if len(fr) > 1 else fr[0]


def _check_mesh(mesh: Mesh) -> Mesh:
    if not isinstance(mesh, Mesh):
        raise TypeError(f"ShardedELL needs a jax.sharding.Mesh, got "
                        f"{type(mesh).__name__}")
    if ROW_AXIS not in mesh.axis_names:
        raise ValueError(f"ShardedELL needs a mesh with a {ROW_AXIS!r} axis "
                         f"(rows shard over it); got axes {mesh.axis_names}")
    return mesh


class ShardedELL:
    """Row-sharded ELL storage over a mesh (see module doc).

    indices/mask/values are (n_pad, max_deg) device arrays placed with
    NamedSharding(mesh, P("data", None)); n_pad rounds the logical row count
    up to a multiple of the "data" axis size, the extra rows all mask-false.
    """
    __slots__ = ("shape", "mesh", "indices", "mask", "values", "nnz", "n_pad")

    def __init__(self, shape: Tuple[int, int], mesh: Mesh, indices, mask,
                 values, nnz: int):
        self.shape = tuple(shape)
        self.mesh = _check_mesh(mesh)
        self.indices = indices
        self.mask = mask
        self.values = values
        self.nnz = int(nnz)
        self.n_pad = int(indices.shape[0])

    # -- construction --------------------------------------------------------
    @classmethod
    def from_ell(cls, e: ELL, mesh: Mesh) -> "ShardedELL":
        """Pad the row block to the "data" axis and scatter it over the mesh."""
        _check_mesh(mesh)
        dsz = mesh.shape[ROW_AXIS]
        n, m = e.shape
        n_pad = n + (-n) % dsz
        idx = np.zeros((n_pad, e.max_deg), np.int32)
        msk = np.zeros((n_pad, e.max_deg), bool)
        val = np.zeros((n_pad, e.max_deg), np.float32)
        idx[:n] = np.asarray(e.indices)
        msk[:n] = np.asarray(e.mask)
        val[:n] = np.asarray(e.values)
        sh = NamedSharding(mesh, P(ROW_AXIS, None))
        return cls((n, m), mesh,
                   jax.device_put(jnp.asarray(idx), sh),
                   jax.device_put(jnp.asarray(msk), sh),
                   jax.device_put(jnp.asarray(val), sh), nnz=e.nnz)

    @classmethod
    def from_coo(cls, rows, cols, vals, shape, mesh: Mesh) -> "ShardedELL":
        return cls.from_ell(ELL.from_coo(rows, cols, vals, shape), mesh)

    @classmethod
    def from_dense(cls, A, mesh: Mesh) -> "ShardedELL":
        return cls.from_ell(ELL.from_dense(A), mesh)

    # -- mesh geometry -------------------------------------------------------
    @property
    def max_deg(self) -> int:
        return self.indices.shape[1]

    @property
    def data_size(self) -> int:
        return self.mesh.shape[ROW_AXIS]

    @property
    def frontier_size(self) -> int:
        """Number of shards the frontier's F dimension splits into."""
        return int(np.prod([self.mesh.shape[a]
                            for a in frontier_axes(self.mesh)] or [1]))

    # -- gather-to-host conversions ------------------------------------------
    def to_ell(self) -> ELL:
        """Gather the row shards back to one host-side ELL (drops padding).
        Counted: this is *the* device->host choke point (to_dense/to_coo/
        transpose all route through it), so every remaining gather fallback
        shows up in grb.host_transfers()."""
        xfer.record("sharded_gather")
        n, m = self.shape
        return ELL(shape=(n, m),
                   indices=jnp.asarray(np.asarray(self.indices)[:n]),
                   mask=jnp.asarray(np.asarray(self.mask)[:n]),
                   values=jnp.asarray(np.asarray(self.values)[:n]),
                   nnz=self.nnz)

    def to_dense(self) -> jnp.ndarray:
        return self.to_ell().to_dense()

    def to_coo(self):
        return self.to_ell().to_coo()

    def transpose(self) -> "ShardedELL":
        """Host-gathered transpose, re-sharded onto the same mesh. Graph
        relations link explicitly-built transposes instead (grb.distribute),
        and un-linked handles never call this on the mxm path — the
        transposed (psum_scatter) lowering reads the forward rows."""
        return ShardedELL.from_ell(self.to_ell().transpose(), self.mesh)

    # -- local (collective-free) stored-entry ops ----------------------------
    def apply_stored(self, f) -> "ShardedELL":
        """f over stored entries, zero results dropped — runs shard-local on
        the mesh (values/mask are elementwise over the same row layout)."""
        vals = jnp.where(self.mask, f(self.values),
                         jnp.zeros_like(self.values))
        mask = self.mask & (vals != 0)
        vals = jnp.where(mask, vals, jnp.zeros_like(vals))
        return ShardedELL(self.shape, self.mesh, self.indices, mask, vals,
                          nnz=int(jnp.sum(mask)))

    def select_stored(self, pred) -> "ShardedELL":
        """Stored entries passing pred, shard-local (mask surgery only)."""
        mask = self.mask & jnp.asarray(pred(self.values)) & (self.values != 0)
        vals = jnp.where(mask, self.values, jnp.zeros_like(self.values))
        return ShardedELL(self.shape, self.mesh, self.indices, mask, vals,
                          nnz=int(jnp.sum(mask)))

    def __repr__(self) -> str:
        n, m = self.shape
        axes = "x".join(f"{a}:{self.mesh.shape[a]}"
                        for a in self.mesh.axis_names)
        return (f"ShardedELL {n}x{m} mesh=({axes}) nnz={self.nnz} "
                f"max_deg={self.max_deg}")


# ---------------------------------------------------------------------------
# op execution: pad, run the graph2d lowering, slice — what grb dispatches to
# ---------------------------------------------------------------------------
def _pad_frontier(s: ShardedELL, X: jnp.ndarray, x_rows: int):
    """Pad an (x_rows, F) frontier to the mesh-divisible (x_rows_pad, F_pad)."""
    dsz = s.data_size
    r_pad = (-x_rows) % dsz
    f_pad = (-X.shape[1]) % s.frontier_size
    if r_pad or f_pad:
        X = jnp.pad(X.astype(jnp.float32), ((0, r_pad), (0, f_pad)))
    return X.astype(jnp.float32)


def _pad_frontier_packed(s: ShardedELL, X: jnp.ndarray, x_rows: int):
    """Pack an (x_rows, F) frontier into uint32 words and pad both axes to
    the mesh: rows to the "data" axis, words to the frontier shard count."""
    from repro.core import bitmap
    Xw = bitmap.pack(X)
    r_pad = (-x_rows) % s.data_size
    w_pad = (-Xw.shape[1]) % s.frontier_size
    if r_pad or w_pad:
        Xw = jnp.pad(Xw, ((0, r_pad), (0, w_pad)))
    return Xw


def mxm(s: ShardedELL, X: jnp.ndarray, sr, transposed: bool = False,
        packed: bool = False):
    """Y = A (x) X (or A^T (x) X) on the mesh. X: dense (k, F) global array
    (k = A's columns in row form, A's rows in transposed form); the result is
    a global (rows, F) array, row-sharded over "data" under GSPMD.

    packed=True (or_and only, set by grb's bitmap policy): X crosses the
    mesh as core.bitmap uint32 words — the frontier all-gather moves 32x
    fewer bytes in row form; the transposed form psum_scatters summable
    nibble words (8x) up to bitmap.NIBBLE_MAX_SHARDS row shards, beyond
    which graph2d.mxm_2d itself builds the unpacked-psum_scatter body
    (same word signature — the limit is enforced at the lowering, not
    here).
    """
    from repro.core import bitmap
    from repro.distr import graph2d                 # lazy: core never pulls
    n, m = s.shape                                  # distr at import time
    dsz = s.data_size
    if transposed:
        fn = graph2d.mxm_2d(s.mesh, sr, transposed=True,
                            out_rows=m + (-m) % dsz, packed=packed)
        Xp = (_pad_frontier_packed(s, X, n) if packed
              else _pad_frontier(s, X, n))          # x rides A's row shards
        out_rows = m
    else:
        fn = graph2d.mxm_2d(s.mesh, sr, packed=packed)
        Xp = (_pad_frontier_packed(s, X, m) if packed
              else _pad_frontier(s, X, m))          # x rows are A's columns
        out_rows = n
    Y = fn(s.indices, s.mask, s.values, Xp)
    if packed:
        return bitmap.unpack(Y[:out_rows], X.shape[1])
    return Y[:out_rows, :X.shape[1]]


def _pad_words(s: ShardedELL, Xw: jnp.ndarray, x_rows: int):
    """Pad an already-packed (x_rows, W) word frontier to the mesh: rows to
    the "data" axis, words to the frontier shard count. Device-side jnp.pad —
    word-resident loops never bounce through pack/unpack here."""
    r_pad = (-x_rows) % s.data_size
    w_pad = (-Xw.shape[1]) % s.frontier_size
    if r_pad or w_pad:
        Xw = jnp.pad(Xw, ((0, r_pad), (0, w_pad)))
    return Xw


def mxm_words(s: ShardedELL, Xw: jnp.ndarray, transposed: bool = False):
    """or_and mxm with the frontier already in uint32 words: words in, words
    out — the packed-in/packed-out entry word-resident hop loops thread
    through (no pack/unpack at the call boundary, grb.mxm_words dispatches
    here). Beyond bitmap.NIBBLE_MAX_SHARDS row shards the transposed
    lowering itself swaps the nibble psum for the unpacked psum_scatter
    body (graph2d.mxm_2d detects the mesh width at build time), so the
    word-in/word-out contract holds at any shard count."""
    from repro.core import semiring as S
    from repro.distr import graph2d
    n, m = s.shape
    dsz = s.data_size
    if transposed:
        fn = graph2d.mxm_2d(s.mesh, S.OR_AND, transposed=True,
                            out_rows=m + (-m) % dsz, packed=True)
        Xp = _pad_words(s, Xw, n)
        out_rows = m
    else:
        fn = graph2d.mxm_2d(s.mesh, S.OR_AND, packed=True)
        Xp = _pad_words(s, Xw, m)
        out_rows = n
    Y = fn(s.indices, s.mask, s.values, Xp)
    return Y[:out_rows, :Xw.shape[1]]


def reduce_stored(s: ShardedELL, monoid, axis):
    """plus/or stored-entry reduction via the graph2d psum lowering; min/max
    go through :func:`reduce_minmax` (dense semantics, still mesh-resident);
    anything else gathers via the counted dense fallback in grb.reduce."""
    from repro.distr import graph2d
    n, m = s.shape
    fn = graph2d.reduce_2d(s.mesh, monoid.name, axis, m)
    out = fn(s.indices, s.mask, s.values)
    if axis == 1:
        return out[:n]
    return out


def reduce_minmax(s: ShardedELL, monoid, axis):
    """min/max reduction with dense semantics (absent entries render 0),
    mesh-resident: stored-entry pmin/pmax over "data" + a stored-count
    compare to fold the implicit zeros back in (graph2d.reduce_minmax_2d).
    Replaces the old gather-to-host special case in grb.reduce."""
    from repro.distr import graph2d
    n, m = s.shape
    fn = graph2d.reduce_minmax_2d(s.mesh, monoid.name, axis, n, m)
    out = fn(s.indices, s.mask, s.values)
    if axis == 1:
        return out[:n]
    return out


# ---------------------------------------------------------------------------
# shard-local element-wise family — the slot-aligned merge grb dispatches to
# ---------------------------------------------------------------------------
def _pair_check(a: ShardedELL, b: ShardedELL, what: str):
    if a.shape != b.shape:
        raise ValueError(f"{what}: shape mismatch {a.shape} vs {b.shape}")
    if a.mesh is not b.mesh and a.mesh != b.mesh:
        raise TypeError(f"{what}: operands live on different meshes")


def merge_stored(a: ShardedELL, b: ShardedELL, op, mode: str) -> ShardedELL:
    """Shard-local merge of two identically-meshed operands (see
    graph2d._ewise_merge for the slot-alignment pass and mode semantics).
    Same shape + mesh implies the same padded row count, so the row blocks
    align shard-for-shard; the merged layout is the concatenated slot width.
    """
    from repro.distr import graph2d
    _pair_check(a, b, f"merge_stored[{mode}]")
    fn = graph2d.ewise_2d(a.mesh, mode, op)
    idx, msk, val = fn(a.indices, a.mask, a.values,
                       b.indices, b.mask, b.values)
    return ShardedELL(a.shape, a.mesh, idx, msk, val, nnz=int(jnp.sum(msk)))


def restrict_dense(a: ShardedELL, dense_mask, complement: bool) -> ShardedELL:
    """Keep a's stored entries where a dense (n, m) mask is nonzero (or zero,
    complemented) — shard-local per-slot gather (graph2d.restrict_dense_2d).
    The mask row block is padded to the mesh like every operand."""
    from repro.distr import graph2d
    dm = jnp.asarray(dense_mask)
    r_pad = a.n_pad - dm.shape[0]
    if r_pad:
        dm = jnp.pad(dm, ((0, r_pad), (0, 0)))
    fn = graph2d.restrict_dense_2d(a.mesh, bool(complement))
    idx, msk, val = fn(a.indices, a.mask, a.values, dm)
    return ShardedELL(a.shape, a.mesh, idx, msk, val, nnz=int(jnp.sum(msk)))


def extract_cols(a: ShardedELL, cols) -> ShardedELL:
    """Column-subset extract (rows stay put): relabel stored columns through
    a replicated LUT, shard-local. Row subsets re-partition the "data" axis
    and stay on the counted gather fallback in grb.extract."""
    from repro.distr import graph2d
    cols = np.asarray(cols, np.int64)
    lut = np.full((a.shape[1],), -1, np.int32)
    lut[cols] = np.arange(len(cols), dtype=np.int32)
    fn = graph2d.extract_cols_2d(a.mesh)
    idx, msk, val = fn(a.indices, a.mask, a.values, jnp.asarray(lut))
    return ShardedELL((a.shape[0], len(cols)), a.mesh, idx, msk, val,
                      nnz=int(jnp.sum(msk)))


def relabel_cols(a: ShardedELL, new_cols, ncols_out: int) -> ShardedELL:
    """Map every stored column j -> new_cols[j] (all >= 0), producing an
    (n, ncols_out) operand — the inverse relabel assign(:, J) needs to put a
    region operand back into global coordinates. Shard-local LUT gather."""
    from repro.distr import graph2d
    lut = np.asarray(new_cols, np.int32)
    fn = graph2d.extract_cols_2d(a.mesh)
    idx, msk, val = fn(a.indices, a.mask, a.values, jnp.asarray(lut))
    return ShardedELL((a.shape[0], ncols_out), a.mesh, idx, msk, val,
                      nnz=int(jnp.sum(msk)))
