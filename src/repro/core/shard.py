"""ShardedELL: row-sharded ELL storage behind the GBMatrix surface.

The fourth GBMatrix kind (dense / BSR / ELL / *sharded*): the same ELL
(indices, mask, values) row layout, but laid out over a ``jax.sharding.Mesh``
instead of one device —

  * adjacency rows           -> the mesh's "data" axis (row blocks),
  * frontier/query columns F -> the "pod" x "model" axes (query scale-out,
    the paper's threadpool claim at pod scale),
  * padded rows (mask-false) square the row count up to a multiple of the
    "data" axis so every shard_map spec divides evenly.

Storage only lives here; the *operations* stay where they always were:
``grb.mxm``/``mxv``/``reduce`` dispatch on the format tag and lower to the
explicit-collective shard_map bodies in ``repro.distr.graph2d`` (one frontier
all-gather per hop in row form, a psum_scatter of row blocks in transposed
form), so algorithms and the query executor run unchanged on a mesh. Wide
or_and frontiers cross the mesh bitmap-packed (``core.bitmap`` uint32
words — 32x less all-gather payload; grb sets ``packed=`` from its policy,
this module only pads/packs/unpacks at the lowering boundary).
``apply``/``select`` are embarrassingly local (stored-entry value maps) and
run right on the sharded arrays below. Everything else (eWise, assign,
extract, non-plus/or reductions) falls back to a documented gather-to-host
round trip — see docs/API.md §Sharded.

Public contract: construction needs a Mesh with a "data" axis (TypeError /
ValueError otherwise); ``to_ell``/``to_dense``/``to_coo``/``transpose``
gather to host by design; everything in the "local stored-entry ops"
section is collective-free. Mixed sharded/unsharded operand TypeErrors are
raised one layer up, in ``repro.core.grb``, which owns the pairing rules.

Handles over this storage are host-side objects like every GBMatrix; the
sharded jnp arrays are what flows through jit. The padded row block is an
internal detail: logical ``shape`` and stored-entry ``nnz`` never include it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.ell import ELL

ROW_AXIS = "data"                      # adjacency rows shard over this axis
FRONTIER_AXES = ("pod", "model")       # frontier columns shard over these


def frontier_axes(mesh: Mesh) -> tuple:
    """The mesh axes (in canonical order) that shard the frontier's F dim."""
    return tuple(a for a in FRONTIER_AXES if a in mesh.axis_names)


def frontier_spec(mesh: Mesh):
    """PartitionSpec entry for the frontier's F dimension on this mesh."""
    fr = frontier_axes(mesh)
    if not fr:
        return None
    return fr if len(fr) > 1 else fr[0]


def _check_mesh(mesh: Mesh) -> Mesh:
    if not isinstance(mesh, Mesh):
        raise TypeError(f"ShardedELL needs a jax.sharding.Mesh, got "
                        f"{type(mesh).__name__}")
    if ROW_AXIS not in mesh.axis_names:
        raise ValueError(f"ShardedELL needs a mesh with a {ROW_AXIS!r} axis "
                         f"(rows shard over it); got axes {mesh.axis_names}")
    return mesh


class ShardedELL:
    """Row-sharded ELL storage over a mesh (see module doc).

    indices/mask/values are (n_pad, max_deg) device arrays placed with
    NamedSharding(mesh, P("data", None)); n_pad rounds the logical row count
    up to a multiple of the "data" axis size, the extra rows all mask-false.
    """
    __slots__ = ("shape", "mesh", "indices", "mask", "values", "nnz", "n_pad")

    def __init__(self, shape: Tuple[int, int], mesh: Mesh, indices, mask,
                 values, nnz: int):
        self.shape = tuple(shape)
        self.mesh = _check_mesh(mesh)
        self.indices = indices
        self.mask = mask
        self.values = values
        self.nnz = int(nnz)
        self.n_pad = int(indices.shape[0])

    # -- construction --------------------------------------------------------
    @classmethod
    def from_ell(cls, e: ELL, mesh: Mesh) -> "ShardedELL":
        """Pad the row block to the "data" axis and scatter it over the mesh."""
        _check_mesh(mesh)
        dsz = mesh.shape[ROW_AXIS]
        n, m = e.shape
        n_pad = n + (-n) % dsz
        idx = np.zeros((n_pad, e.max_deg), np.int32)
        msk = np.zeros((n_pad, e.max_deg), bool)
        val = np.zeros((n_pad, e.max_deg), np.float32)
        idx[:n] = np.asarray(e.indices)
        msk[:n] = np.asarray(e.mask)
        val[:n] = np.asarray(e.values)
        sh = NamedSharding(mesh, P(ROW_AXIS, None))
        return cls((n, m), mesh,
                   jax.device_put(jnp.asarray(idx), sh),
                   jax.device_put(jnp.asarray(msk), sh),
                   jax.device_put(jnp.asarray(val), sh), nnz=e.nnz)

    @classmethod
    def from_coo(cls, rows, cols, vals, shape, mesh: Mesh) -> "ShardedELL":
        return cls.from_ell(ELL.from_coo(rows, cols, vals, shape), mesh)

    @classmethod
    def from_dense(cls, A, mesh: Mesh) -> "ShardedELL":
        return cls.from_ell(ELL.from_dense(A), mesh)

    # -- mesh geometry -------------------------------------------------------
    @property
    def max_deg(self) -> int:
        return self.indices.shape[1]

    @property
    def data_size(self) -> int:
        return self.mesh.shape[ROW_AXIS]

    @property
    def frontier_size(self) -> int:
        """Number of shards the frontier's F dimension splits into."""
        return int(np.prod([self.mesh.shape[a]
                            for a in frontier_axes(self.mesh)] or [1]))

    # -- gather-to-host conversions ------------------------------------------
    def to_ell(self) -> ELL:
        """Gather the row shards back to one host-side ELL (drops padding)."""
        n, m = self.shape
        return ELL(shape=(n, m),
                   indices=jnp.asarray(np.asarray(self.indices)[:n]),
                   mask=jnp.asarray(np.asarray(self.mask)[:n]),
                   values=jnp.asarray(np.asarray(self.values)[:n]),
                   nnz=self.nnz)

    def to_dense(self) -> jnp.ndarray:
        return self.to_ell().to_dense()

    def to_coo(self):
        return self.to_ell().to_coo()

    def transpose(self) -> "ShardedELL":
        """Host-gathered transpose, re-sharded onto the same mesh. Graph
        relations link explicitly-built transposes instead (grb.distribute),
        and un-linked handles never call this on the mxm path — the
        transposed (psum_scatter) lowering reads the forward rows."""
        return ShardedELL.from_ell(self.to_ell().transpose(), self.mesh)

    # -- local (collective-free) stored-entry ops ----------------------------
    def apply_stored(self, f) -> "ShardedELL":
        """f over stored entries, zero results dropped — runs shard-local on
        the mesh (values/mask are elementwise over the same row layout)."""
        vals = jnp.where(self.mask, f(self.values),
                         jnp.zeros_like(self.values))
        mask = self.mask & (vals != 0)
        vals = jnp.where(mask, vals, jnp.zeros_like(vals))
        return ShardedELL(self.shape, self.mesh, self.indices, mask, vals,
                          nnz=int(jnp.sum(mask)))

    def select_stored(self, pred) -> "ShardedELL":
        """Stored entries passing pred, shard-local (mask surgery only)."""
        mask = self.mask & jnp.asarray(pred(self.values)) & (self.values != 0)
        vals = jnp.where(mask, self.values, jnp.zeros_like(self.values))
        return ShardedELL(self.shape, self.mesh, self.indices, mask, vals,
                          nnz=int(jnp.sum(mask)))

    def __repr__(self) -> str:
        n, m = self.shape
        axes = "x".join(f"{a}:{self.mesh.shape[a]}"
                        for a in self.mesh.axis_names)
        return (f"ShardedELL {n}x{m} mesh=({axes}) nnz={self.nnz} "
                f"max_deg={self.max_deg}")


# ---------------------------------------------------------------------------
# op execution: pad, run the graph2d lowering, slice — what grb dispatches to
# ---------------------------------------------------------------------------
def _pad_frontier(s: ShardedELL, X: jnp.ndarray, x_rows: int):
    """Pad an (x_rows, F) frontier to the mesh-divisible (x_rows_pad, F_pad)."""
    dsz = s.data_size
    r_pad = (-x_rows) % dsz
    f_pad = (-X.shape[1]) % s.frontier_size
    if r_pad or f_pad:
        X = jnp.pad(X.astype(jnp.float32), ((0, r_pad), (0, f_pad)))
    return X.astype(jnp.float32)


def _pad_frontier_packed(s: ShardedELL, X: jnp.ndarray, x_rows: int):
    """Pack an (x_rows, F) frontier into uint32 words and pad both axes to
    the mesh: rows to the "data" axis, words to the frontier shard count."""
    from repro.core import bitmap
    Xw = bitmap.pack(X)
    r_pad = (-x_rows) % s.data_size
    w_pad = (-Xw.shape[1]) % s.frontier_size
    if r_pad or w_pad:
        Xw = jnp.pad(Xw, ((0, r_pad), (0, w_pad)))
    return Xw


def mxm(s: ShardedELL, X: jnp.ndarray, sr, transposed: bool = False,
        packed: bool = False):
    """Y = A (x) X (or A^T (x) X) on the mesh. X: dense (k, F) global array
    (k = A's columns in row form, A's rows in transposed form); the result is
    a global (rows, F) array, row-sharded over "data" under GSPMD.

    packed=True (or_and only, set by grb's bitmap policy): X crosses the
    mesh as core.bitmap uint32 words — the frontier all-gather moves 32x
    fewer bytes in row form; the transposed form psum_scatters summable
    nibble words (8x) and needs <= bitmap.NIBBLE_MAX_SHARDS row shards,
    beyond which this falls back to the float route.
    """
    from repro.core import bitmap
    from repro.distr import graph2d                 # lazy: core never pulls
    n, m = s.shape                                  # distr at import time
    dsz = s.data_size
    if packed and transposed and dsz > bitmap.NIBBLE_MAX_SHARDS:
        packed = False                              # nibble sums would carry
    if transposed:
        fn = graph2d.mxm_2d(s.mesh, sr, transposed=True,
                            out_rows=m + (-m) % dsz, packed=packed)
        Xp = (_pad_frontier_packed(s, X, n) if packed
              else _pad_frontier(s, X, n))          # x rides A's row shards
        out_rows = m
    else:
        fn = graph2d.mxm_2d(s.mesh, sr, packed=packed)
        Xp = (_pad_frontier_packed(s, X, m) if packed
              else _pad_frontier(s, X, m))          # x rows are A's columns
        out_rows = n
    Y = fn(s.indices, s.mask, s.values, Xp)
    if packed:
        return bitmap.unpack(Y[:out_rows], X.shape[1])
    return Y[:out_rows, :X.shape[1]]


def reduce_stored(s: ShardedELL, monoid, axis):
    """plus/or stored-entry reduction via the graph2d psum lowering; other
    monoids need absent entries and go through the gather-to-host dense
    fallback in grb.reduce."""
    from repro.distr import graph2d
    n, m = s.shape
    fn = graph2d.reduce_2d(s.mesh, monoid.name, axis, m)
    out = fn(s.indices, s.mask, s.values)
    if axis == 1:
        return out[:n]
    return out
