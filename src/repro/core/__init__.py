# The paper's primary contribution: GraphBLAS (sparse semiring linear algebra)
# as the storage + execution substrate of a graph database, TPU-native.
# `grb` is the unified operation surface (Descriptor / GBMatrix / mxm-family);
# `ops` keeps the legacy kwargs spelling over raw storage; `shard` holds the
# mesh-sharded storage kind behind the same GBMatrix handle; `bitmap` is the
# packed boolean frontier form or_and traversals ride (docs/API.md §Bitmap).
from repro.core import bitadj, bitmap, grb, ops, semiring
from repro.core.bitadj import BitELL, ShardedBitELL
from repro.core.bsr import BSR
from repro.core.delta import DeltaMatrix
from repro.core.ell import ELL
from repro.core.grb import Descriptor, GBMatrix
from repro.core.shard import ShardedELL

__all__ = ["bitadj", "bitmap", "grb", "ops", "semiring", "BSR", "ELL",
           "ShardedELL", "DeltaMatrix", "BitELL", "ShardedBitELL",
           "Descriptor", "GBMatrix"]
