# The paper's primary contribution: GraphBLAS (sparse semiring linear algebra)
# as the storage + execution substrate of a graph database, TPU-native.
from repro.core import ops, semiring
from repro.core.bsr import BSR
from repro.core.ell import ELL

__all__ = ["ops", "semiring", "BSR", "ELL"]
