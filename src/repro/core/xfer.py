"""Host-transfer accounting: the counter behind ``grb.host_transfers()``.

Sibling of the ``densify_calls()`` / ``pack_calls()`` policy counters: every
device->host *gather inside op dispatch* bumps it — ``ShardedELL.to_ell``
(which ``to_dense``/``to_coo``/``transpose`` route through) and the BSR
host materializations (``BSR.to_dense``/``to_coo``). Pulling a final
*dense* result (``np.asarray(levels)``, ``project`` rows) never touches
those gathers and is deliberately outside scope — but ``to_dense()`` on a
sharded/BSR result handle routes through them and does count, so tests
measure their delta *before* materializing results for comparison. The
contract this counter pins is "no sharded or BSR *hot loop* ever leaves
the device", not "nobody ever reads an answer". Structural metadata pulls
(an ``nvals`` scalar, tile-occupancy flags — host-side planning, not
payload) are likewise not counted.

Lives in its own leaf module so ``core.shard`` and ``core.bsr`` can bump it
without importing ``core.grb`` (which imports both).
"""
from __future__ import annotations

_host_transfers = [0]


def record(tag: str = "") -> None:
    """Count one device->host gather (tag is documentation only)."""
    del tag
    _host_transfers[0] += 1


def host_transfers() -> int:
    """Device->host gathers since process start (see module doc for scope)."""
    return _host_transfers[0]
