"""BitELL: bit-packed structural adjacency — the sixth storage kind.

Bit-GraphBLAS (arXiv 2201.08560) observes that for *unweighted* relations
the adjacency matrix itself is boolean, so storing float32 edge weights
wastes 31/32 of the memory and bandwidth exactly like unpacked frontiers
did before ``core.bitmap``. BitELL packs the structure into uint32
bit-tiles: rows are grouped into 32-row *panels*, each panel keeps an
ELL-style list of occupied 32-column *tile slots*, and one tile — a whole
32x32 block of edges — lives in 32 machine words:

    tiles  (P, S, 32) uint32   bit b of tiles[p, s, r] <=> edge
                               (p*32 + r,  cols[p, s]*32 + b)
    cols   (P, S)     int32    column-tile id per slot (sentinel C = empty)

with P = ceil(n/32) panels and S the widest panel's slot count. Payload is
4 bytes per 32 potential edges vs ELL's ~9 bytes per stored edge — for
tiles above ~2% fill the structure is >= 8x smaller, and the or_and matmul
family becomes word-AND + OR over the packed frontier words of PR 5
(``core.bitmap``), so BFS / k-hop / WCC hop loops run uint32 in, uint32
out, with zero float intermediates. Triangle counting is AND + SWAR
popcount over tile pairs. Weighted semirings, the element-wise family, and
delta mutation have no bit-level form and take a cached materialize-to-ELL
fallback — the exact dispatch contract DeltaMatrix already uses
(docs/API.md §BitAdj).

``ShardedBitELL`` is the mesh twin behind ``grb.distribute``: panels shard
over the "data" axis, the per-hop frontier all-gather carries packed words
over bit-packed panels (the ``distr.graph2d.bit_mxm_2d`` lowering), and
``grb.distribute`` force-builds + links the transpose twin so
``transpose_a`` always serves from stored panels — there is no transposed
bit-scatter lowering. Gather-to-host conversions are counted via
``core.xfer`` like every other storage kind's.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap, xfer
from repro.core.ell import ELL

Array = jnp.ndarray

TILE = bitmap.WORD_BITS     # 32-row panels x 32-column tiles, one uint32/row

# -- impl="auto" crossover policy ---------------------------------------------
# Measured by benchmarks/calibrate.py::calibrate_bitadj_fill (RMAT-style
# random structure, n=2048, occupied-tile fill swept 0.005->0.25, or_and
# mxm at F=128, XLA-CPU reference host): the bit route crosses below ELL
# at ~0.01-0.02 occupied-tile fill and wins 3-6x by 0.1 — one padded slot
# costs 132 bytes against ~9 bytes per ELL entry, so ~15 edges per
# occupied tile (fill 0.014) is also the memory break-even. Committed at
# the measured speed crossover step 0.02. AUTO_BITADJ_MAX_SLOTS caps the
# ELL-style slot padding: past ~64 occupied column tiles in the widest
# panel the padded (P, S, 32) payload outgrows the ELL it replaces on the
# skewed panels this host measured (calibrate_bitadj_slots).
AUTO_BITADJ_MIN_FILL = 0.02   # occupied-tile fill below this: ELL wins
AUTO_BITADJ_MAX_SLOTS = 64    # widest-panel slots above this: padding loses


def _tile_stats(rows, cols, shape):
    """(occupied-tile fill, widest-panel slot count) of a COO structure."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    if rows.size == 0:
        return 0.0, 0
    n_ct = -(-int(shape[1]) // TILE)
    key = np.unique((rows // TILE) * n_ct + (cols // TILE))
    slots = int(np.bincount((key // n_ct).astype(np.int64)).max())
    fill = rows.size / (len(key) * TILE * TILE)
    return fill, slots


def auto_bitadj_ok(rows, cols, vals, shape) -> bool:
    """Construction-time side of the BitELL auto policy: a *boolean*
    relation (all stored values 1.0 — structure is the payload) whose
    occupied 32x32 tiles are dense enough for the word route to win
    (AUTO_BITADJ_MIN_FILL) without slot-padding blowup on skewed panels
    (AUTO_BITADJ_MAX_SLOTS)."""
    if vals is not None and not np.all(np.asarray(vals) == 1.0):
        return False
    if np.asarray(rows).size == 0:
        return False
    fill, slots = _tile_stats(rows, cols, shape)
    return fill >= AUTO_BITADJ_MIN_FILL and slots <= AUTO_BITADJ_MAX_SLOTS


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BitELL:
    shape: Tuple[int, int]
    tiles: Array        # (P, S, 32) uint32 bit-tiles (see module doc)
    cols: Array         # (P, S) i32 column-tile per slot; sentinel = n_ctiles
    nnz: int
    # cached ELL materialization (the weighted/ewise/delta fallback target);
    # host-side cache like GBMatrix._T, never part of the traced pytree
    _ell: Optional[ELL] = dataclasses.field(
        default=None, repr=False, compare=False)

    def tree_flatten(self):
        return (self.tiles, self.cols), (self.shape, self.nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, nnz = aux
        return cls(shape, *children, nnz=nnz)

    # -- geometry ------------------------------------------------------------
    @property
    def n_panels(self) -> int:
        return self.tiles.shape[0]

    @property
    def n_slots(self) -> int:
        return self.tiles.shape[1]

    @property
    def n_ctiles(self) -> int:
        return -(-self.shape[1] // TILE)

    @property
    def payload_bytes(self) -> int:
        """Adjacency payload (tiles + slot index) — what the >= 8x-vs-ELL
        regression and benchmarks/bench_bitadj.py account."""
        return int(self.tiles.size) * 4 + int(self.cols.size) * 4

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_coo(rows, cols, vals, shape, pad_slots_to: int = 1) -> "BitELL":
        """Structural build: every (row, col) pair is an edge. ``vals`` must
        be None or all-ones — BitELL stores no weights (TypeError names the
        materialize-to-ELL escape hatch for weighted relations)."""
        if vals is not None and not np.all(np.asarray(vals) == 1.0):
            raise TypeError(
                "BitELL is structural (boolean) storage and cannot carry "
                "edge weights; build fmt='ell' (or let fmt='auto' pick) for "
                "weighted relations")
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        n, k = int(shape[0]), int(shape[1])
        P = max(-(-n // TILE), 1)
        C = max(-(-k // TILE), 1)
        key = rows // TILE * C + cols // TILE          # global tile id
        order = np.argsort(key, kind="stable")
        rows, cols, key = rows[order], cols[order], key[order]
        ukey, inv = np.unique(key, return_inverse=True)
        up = (ukey // C).astype(np.int64)              # panel of each tile
        # slot position of each occupied tile within its panel
        pdeg = np.bincount(up, minlength=P)
        S = int(pdeg.max()) if pdeg.size and pdeg.max() > 0 else 1
        S = S + (-S) % max(pad_slots_to, 1)
        starts = np.zeros(P + 1, dtype=np.int64)
        starts[1:] = np.cumsum(pdeg)
        slot = np.arange(len(ukey)) - starts[up]
        colsA = np.full((P, S), C, dtype=np.int32)     # sentinel = zero X tile
        colsA[up, slot] = (ukey % C).astype(np.int32)
        tiles = np.zeros(P * S * TILE, dtype=np.uint32)
        word = (up[inv] * S + slot[inv]) * TILE + rows % TILE
        np.bitwise_or.at(tiles, word,
                         np.uint32(1) << (cols % TILE).astype(np.uint32))
        # duplicate edges collapse into the same bit; count the set bits
        nnz = int(np.asarray(
            bitmap.popcount(jnp.asarray(tiles)).sum()))
        return BitELL(shape=(n, k),
                      tiles=jnp.asarray(tiles.reshape(P, S, TILE)),
                      cols=jnp.asarray(colsA), nnz=nnz)

    @staticmethod
    def from_ell(e: ELL) -> "BitELL":
        """Structural view of an ELL's stored pattern (values dropped)."""
        idx = np.asarray(e.indices)
        msk = np.asarray(e.mask)
        r, s = np.nonzero(msk)
        return BitELL.from_coo(r, idx[r, s], None, e.shape)

    @staticmethod
    def from_dense(A) -> "BitELL":
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return BitELL.from_coo(r, c, None, A.shape)

    # -- gather-to-host conversions (counted, like every storage kind's) -----
    def to_coo(self):
        """Host-side COO of the stored structure (vals are unit weights)."""
        t = np.asarray(self.tiles)
        c = np.asarray(self.cols)
        p, s, r = np.nonzero(t)
        w = t[p, s, r]
        rows, cols = [], []
        for b in range(TILE):
            hit = (w >> np.uint32(b)) & 1 != 0
            rows.append(p[hit] * TILE + r[hit])
            cols.append(c[p[hit], s[hit]].astype(np.int64) * TILE + b)
        rows = np.concatenate(rows) if rows else np.zeros(0, np.int64)
        cols = np.concatenate(cols) if cols else np.zeros(0, np.int64)
        return rows.astype(np.int64), cols, np.ones(len(rows), np.float32)

    def to_ell(self) -> ELL:
        """Cached ELL materialization — the fallback target for weighted
        semirings, the element-wise family, and delta mutation (mirrors
        DeltaMatrix.materialize). Counted once: the bit-tiles leave the
        device to rebuild the padded neighbor lists."""
        if self._ell is None:
            xfer.record("bitadj_materialize")
            r, c, v = self.to_coo()
            # the first caller may sit inside a lax loop trace (e.g. a
            # weighted-semiring hop in a while_loop body); eval eagerly so
            # the cache holds concrete arrays, not leaked tracers
            with jax.ensure_compile_time_eval():
                self._ell = ELL.from_coo(r, c, v, self.shape)
        return self._ell

    def to_dense(self) -> Array:
        return self.to_ell().to_dense()

    def transpose(self) -> "BitELL":
        """Host-side rebuild from COO (grb caches the result on the handle;
        graph relations link explicitly-built twins instead)."""
        r, c, _ = self.to_coo()
        return BitELL.from_coo(c, r, None, (self.shape[1], self.shape[0]))

    def __repr__(self) -> str:
        n, k = self.shape
        return (f"BitELL {n}x{k} nnz={self.nnz} panels={self.n_panels} "
                f"slots={self.n_slots} payload={self.payload_bytes}B")


# ---------------------------------------------------------------------------
# or_and word kernels — the XLA reference (CPU + shard_map local bodies)
# ---------------------------------------------------------------------------
def _pad_query_tiles(Xw: Array, k: int) -> Array:
    """(>=k, W) packed frontier words -> (C+1, 32, W) query tiles: rows
    squared up to the column-tile grid plus one all-zero sentinel tile that
    empty slots (cols == C) gather harmlessly."""
    C = max(-(-k // TILE), 1)
    Xw = Xw[:min(Xw.shape[0], C * TILE)]
    Xw = jnp.pad(Xw, ((0, (C + 1) * TILE - Xw.shape[0]), (0, 0)))
    return Xw.reshape(C + 1, TILE, Xw.shape[1])


def panels_mxm_words(tiles: Array, cols: Array, Xw: Array, k: int,
                     slot_chunk: int = 8) -> Array:
    """Yw[p*32+r] = OR over slots s and bits b with tiles[p,s,r] bit b set
    of Xw[cols[p,s]*32 + b] — the or_and matmul on bit-tiles against a
    packed frontier, word-AND + OR all the way (no float intermediates).
    Slot chunking bounds the (P, sc, 32, 32, W) bit-spread intermediate.
    This is the XLA reference for ``kernels.bitadj_mxv.bitadj_mxv_packed``
    and the shard-local body of ``distr.graph2d.bit_mxm_2d``."""
    Pn, Sn, _ = tiles.shape
    W = Xw.shape[1]
    Xt = _pad_query_tiles(Xw, k)                       # (C+1, 32, W)
    shifts = jnp.arange(TILE, dtype=jnp.uint32)
    acc = jnp.zeros((Pn, TILE, W), dtype=jnp.uint32)
    for s0 in range(0, Sn, slot_chunk):
        tc = tiles[:, s0:s0 + slot_chunk]              # (P, sc, 32)
        cc = cols[:, s0:s0 + slot_chunk]               # (P, sc)
        G = Xt[cc]                                     # (P, sc, 32, W)
        bits = jnp.bitwise_and(
            jnp.right_shift(tc[:, :, :, None], shifts), jnp.uint32(1))
        term = jnp.where(bits[..., None] != 0,         # (P, sc, 32r, 32b, W)
                         G[:, :, None, :, :], jnp.uint32(0))
        acc = jnp.bitwise_or(
            acc, jax.lax.reduce(term, jnp.uint32(0),
                                jax.lax.bitwise_or, (1, 3)))
    return acc.reshape(Pn * TILE, W)


def mxm_words(b: BitELL, Xw: Array) -> Array:
    """(k-rows, W) packed frontier words -> (n, W) result words."""
    return panels_mxm_words(b.tiles, b.cols, Xw, b.shape[1])[:b.shape[0]]


def reduce_stored(s, monoid, axis) -> Array:
    """plus/or reduction over the stored structure, straight off the
    bit-tiles (SWAR popcounts — never materializes). Works unchanged on
    ShardedBitELL's global arrays: GSPMD inserts the mesh collectives."""
    tiles, cols = s.tiles, s.cols
    n, k = s.shape
    C = -(-k // TILE)
    if axis == 1:
        per = jnp.sum(bitmap.popcount(tiles), axis=1)  # (P, 32) row counts
        out = per.reshape(-1)[:n].astype(jnp.float32)
    elif axis == 0:
        shifts = jnp.arange(TILE, dtype=jnp.uint32)
        bits = jnp.bitwise_and(
            jnp.right_shift(tiles[:, :, :, None], shifts), jnp.uint32(1))
        per = jnp.sum(bits, axis=2).astype(jnp.float32)   # (P, S, 32b)
        seg = jax.ops.segment_sum(per.reshape(-1, TILE),
                                  cols.reshape(-1).astype(jnp.int32),
                                  num_segments=C + 1)     # sentinel bucket
        out = seg[:C].reshape(-1)[:k]
    else:
        tot = jnp.sum(bitmap.popcount(tiles)).astype(jnp.float32)
        return (tot > 0).astype(jnp.float32) if monoid.name == "or" else tot
    return (out > 0).astype(jnp.float32) if monoid.name == "or" else out


def triangle_count(s, slot_chunk: int = 4) -> Array:
    """Triangles of a symmetric structural adjacency as AND + popcount over
    tile pairs: for every stored edge bit (i, j), the common-neighbor count
    is the popcount of ``rowbits[i] & rowbits[j]`` summed over column
    tiles; the masked plus_pair matmul the float route runs is exactly that
    intersection, so the total divides by 6 identically. Stays on device
    (and mesh-resident under GSPMD for ShardedBitELL arrays)."""
    tiles, cols = s.tiles, s.cols
    n, k = s.shape
    if n != k:
        raise ValueError("triangle_count needs a square adjacency")
    Pn, Sn, _ = tiles.shape
    C = -(-k // TILE)
    # row-bit matrix: Brows[p, r, c] = 32 column bits of row p*32+r, tile c
    ids = (jnp.arange(Pn, dtype=jnp.int32)[:, None] * (C + 1)
           + cols).reshape(-1)
    seg = jax.ops.segment_sum(tiles.reshape(-1, TILE).astype(jnp.uint32),
                              ids, num_segments=Pn * (C + 1))
    Brows = seg.reshape(Pn, C + 1, TILE)[:, :C].transpose(0, 2, 1)
    # neighbor-row panels gather via the slot's column tile (square: column
    # tile c == row panel c); sentinel slots hit an all-zero panel
    Bpad = jnp.concatenate(
        [Brows, jnp.zeros((max(C + 1 - Pn, 1), TILE, C), jnp.uint32)])
    shifts = jnp.arange(TILE, dtype=jnp.uint32)
    acc = jnp.float32(0.0)
    for s0 in range(0, Sn, slot_chunk):
        tc = tiles[:, s0:s0 + slot_chunk]              # (P, sc, 32)
        cc = cols[:, s0:s0 + slot_chunk]               # (P, sc)
        G = Bpad[cc]                                   # (P, sc, 32b, C)
        inter = bitmap.popcount(
            Brows[:, None, :, None, :] & G[:, :, None, :, :])
        inter = jnp.sum(inter, axis=-1).astype(jnp.float32)  # (P,sc,32r,32b)
        bits = jnp.bitwise_and(
            jnp.right_shift(tc[:, :, :, None], shifts), jnp.uint32(1))
        acc = acc + jnp.sum(inter * bits.astype(jnp.float32))
    return acc / 6.0


# ---------------------------------------------------------------------------
# ShardedBitELL — the mesh twin behind grb.distribute
# ---------------------------------------------------------------------------
class ShardedBitELL:
    """BitELL panels sharded over the mesh's "data" axis (see module doc).

    tiles/cols are global device arrays placed with NamedSharding; P_pad
    rounds the panel count up to a multiple of the "data" axis, the extra
    panels all-sentinel. Built by :meth:`from_bitell` (grb.distribute);
    transpose_a is always served from the linked twin grb.distribute builds
    — there is no transposed bit-scatter lowering."""
    __slots__ = ("shape", "mesh", "tiles", "cols", "nnz", "p_pad", "_ell2d")

    def __init__(self, shape, mesh, tiles, cols, nnz):
        from repro.core import shard as _shard
        self.shape = tuple(shape)
        self.mesh = _shard._check_mesh(mesh)
        self.tiles = tiles
        self.cols = cols
        self.nnz = int(nnz)
        self.p_pad = int(tiles.shape[0])
        self._ell2d = None          # cached ShardedELL materialization

    @classmethod
    def from_bitell(cls, b: BitELL, mesh) -> "ShardedBitELL":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import shard as _shard
        _shard._check_mesh(mesh)
        dsz = mesh.shape[_shard.ROW_AXIS]
        Pn, Sn, _ = b.tiles.shape
        p_pad = Pn + (-Pn) % dsz
        t = np.zeros((p_pad, Sn, TILE), np.uint32)
        c = np.full((p_pad, Sn), b.n_ctiles, np.int32)
        t[:Pn] = np.asarray(b.tiles)
        c[:Pn] = np.asarray(b.cols)
        return cls(b.shape, mesh,
                   jax.device_put(jnp.asarray(t),
                                  NamedSharding(mesh,
                                                P(_shard.ROW_AXIS,
                                                  None, None))),
                   jax.device_put(jnp.asarray(c),
                                  NamedSharding(mesh,
                                                P(_shard.ROW_AXIS, None))),
                   nnz=b.nnz)

    # -- mesh geometry -------------------------------------------------------
    @property
    def data_size(self) -> int:
        from repro.core import shard as _shard
        return self.mesh.shape[_shard.ROW_AXIS]

    @property
    def frontier_size(self) -> int:
        from repro.core import shard as _shard
        return int(np.prod([self.mesh.shape[a]
                            for a in _shard.frontier_axes(self.mesh)] or [1]))

    @property
    def n_ctiles(self) -> int:
        return -(-self.shape[1] // TILE)

    @property
    def payload_bytes(self) -> int:
        return int(self.tiles.size) * 4 + int(self.cols.size) * 4

    # -- gather-to-host conversions (counted) --------------------------------
    def to_bitell(self) -> BitELL:
        """Gather the panel shards back to one host-side BitELL (drops
        padding panels). Counted like ShardedELL.to_ell."""
        xfer.record("bitadj_gather")
        Pn = -(-self.shape[0] // TILE)
        return BitELL(shape=self.shape,
                      tiles=jnp.asarray(np.asarray(self.tiles)[:Pn]),
                      cols=jnp.asarray(np.asarray(self.cols)[:Pn]),
                      nnz=self.nnz)

    def to_ell(self) -> ELL:
        return self.to_bitell().to_ell()

    def to_dense(self) -> Array:
        return self.to_ell().to_dense()

    def to_coo(self):
        return self.to_bitell().to_coo()

    def transpose(self) -> "ShardedBitELL":
        return ShardedBitELL.from_bitell(self.to_bitell().transpose(),
                                         self.mesh)

    def materialize_sharded(self):
        """Cached ShardedELL on the same mesh — the sharded fallback target
        for weighted semirings / ewise / assign-extract (one counted gather
        to rebuild neighbor lists, then mesh-resident again; the sharded
        analog of BitELL.to_ell)."""
        from repro.core.shard import ShardedELL
        if self._ell2d is None:
            self._ell2d = ShardedELL.from_ell(self.to_ell(), self.mesh)
        return self._ell2d

    def __repr__(self) -> str:
        n, k = self.shape
        axes = "x".join(f"{a}:{self.mesh.shape[a]}"
                        for a in self.mesh.axis_names)
        return (f"ShardedBitELL {n}x{k} mesh=({axes}) nnz={self.nnz} "
                f"slots={self.cols.shape[1]}")


def sharded_mxm_words(s: ShardedBitELL, Xw: Array) -> Array:
    """Row-form or_and mxm on the mesh with a packed frontier: one packed
    all-gather of Xw over "data" per call (the >= 8x payload cut the HLO
    regression pins), then the shard-local word kernel on each panel block.
    Words in, words out — what grb.mxm_words dispatches to."""
    from repro.distr import graph2d
    n, k = s.shape
    r_pad = (-k) % s.data_size
    w_pad = (-Xw.shape[1]) % s.frontier_size
    Xp = jnp.pad(Xw, ((0, r_pad), (0, w_pad))) if (r_pad or w_pad) else Xw
    fn = graph2d.bit_mxm_2d(s.mesh, s.cols.shape[1], k)
    Y = fn(s.tiles, s.cols, Xp)
    return Y[:n, :Xw.shape[1]]
