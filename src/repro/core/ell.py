"""ELL / padded-gather format: the hypersparse (power-law) path.

Power-law graphs (Twitter, Graph500 RMAT) put most edges in a few hub rows;
128x128 dense tiles would store mostly zeros (fill ratio << 1%).  The ELL
format keeps, per vertex, a padded list of neighbor ids.  On TPU this drives
XLA gathers + segment reductions on the VPU — no MXU, but bandwidth-optimal
for fill ratios where BSR would explode the footprint.

`Format auto-selection` (core.ops.auto_format) mirrors SuiteSparse's
CSR/bitmap/hypersparse switching: build BSR, check fill_ratio, fall back here.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ELL:
    shape: Tuple[int, int]
    indices: jnp.ndarray  # (n, max_deg) i32 neighbor ids, padded with 0
    mask: jnp.ndarray     # (n, max_deg) bool validity
    values: jnp.ndarray   # (n, max_deg) f32 edge weights (1.0 structural)
    nnz: int

    def tree_flatten(self):
        return (self.indices, self.mask, self.values), (self.shape, self.nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, nnz = aux
        return cls(shape, *children, nnz=nnz)

    @property
    def max_deg(self) -> int:
        return self.indices.shape[1]

    @staticmethod
    def from_coo(rows, cols, vals, shape, pad_deg_to: int = 8) -> "ELL":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        if vals is None:
            vals = np.ones(rows.shape[0], dtype=np.float32)
        vals = np.asarray(vals, dtype=np.float32)
        n, _ = shape
        order = np.argsort(rows, kind="stable")
        rows, cols, vals = rows[order], cols[order], vals[order]
        deg = np.bincount(rows, minlength=n)
        md = int(deg.max()) if deg.size and deg.max() > 0 else 1
        md = md + (-md) % pad_deg_to
        idx = np.zeros((n, md), dtype=np.int32)
        msk = np.zeros((n, md), dtype=bool)
        val = np.zeros((n, md), dtype=np.float32)
        # slot position of each edge within its row
        starts = np.zeros(n + 1, dtype=np.int64)
        starts[1:] = np.cumsum(deg)
        slot = np.arange(rows.shape[0]) - starts[rows]
        idx[rows, slot] = cols
        msk[rows, slot] = True
        val[rows, slot] = vals
        return ELL(shape=(n, shape[1]), indices=jnp.asarray(idx),
                   mask=jnp.asarray(msk), values=jnp.asarray(val),
                   nnz=int(rows.shape[0]))

    @staticmethod
    def from_entries(keys, vals, shape, pad_deg_to: int = 8) -> "ELL":
        """Build from flat row-major entry keys (``row * ncols + col``) —
        the spelling the COO set algebra (repro.core.coo) hands back from
        the sparse element-wise / assign / extract paths."""
        w = max(shape[1], 1)
        keys = np.asarray(keys, dtype=np.int64)
        return ELL.from_coo(keys // w, keys % w, vals, shape,
                            pad_deg_to=pad_deg_to)

    @staticmethod
    def from_dense(A, pad_deg_to: int = 8) -> "ELL":
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return ELL.from_coo(r, c, A[r, c].astype(np.float32), A.shape,
                            pad_deg_to=pad_deg_to)

    def to_dense(self) -> jnp.ndarray:
        n, m = self.shape
        out = np.zeros((n, m), dtype=np.float32)
        idx = np.asarray(self.indices)
        msk = np.asarray(self.mask)
        val = np.asarray(self.values)
        r, s = np.nonzero(msk)
        out[r, idx[r, s]] = val[r, s]
        return jnp.asarray(out)

    def transpose(self) -> "ELL":
        idx = np.asarray(self.indices)
        msk = np.asarray(self.mask)
        val = np.asarray(self.values)
        r, s = np.nonzero(msk)
        return ELL.from_coo(idx[r, s], r, val[r, s],
                            (self.shape[1], self.shape[0]))

    def to_coo(self):
        """Host-side COO extraction (snapshot/persistence path)."""
        idx = np.asarray(self.indices)
        msk = np.asarray(self.mask)
        val = np.asarray(self.values)
        r, s = np.nonzero(msk)
        return r.astype(np.int64), idx[r, s].astype(np.int64), val[r, s]
