"""GraphBLAS semirings as first-class JAX objects.

A semiring is (add-monoid, multiply-op). The add monoid must be commutative and
associative with an identity; the multiply op distributes over it. RedisGraph's
traversals run on the boolean (or_and) semiring; algorithms use the others:

  plus_times  — classic arithmetic (PageRank, counts)
  or_and      — structural reachability (BFS, k-hop)        [MXU via f32 matmul + >0]
  min_plus    — tropical / shortest paths (SSSP)            [VPU broadcast-reduce]
  max_plus    — critical path / widest-ish                  [VPU broadcast-reduce]
  plus_pair   — common-neighbor counting (triangles)        [MXU on indicators]
  plus_first  — weight-push traversal (y += A_ij present -> x carried)
  any_pair    — structural "pick any witness" (alias of or_and on structure)

`mxu=True` semirings lower to a single `jnp.dot` (optionally on indicator
matrices) inside the Pallas kernel — the 128x128 systolic array path.  The
tropical semirings cannot use the MXU and fall back to a chunked
broadcast-reduce on the VPU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Monoid:
    name: str
    op: Callable[[Array, Array], Array]
    identity: float

    def reduce(self, x: Array, axis=None) -> Array:
        if self.name == "plus":
            return jnp.sum(x, axis=axis)
        if self.name == "min":
            return jnp.min(x, axis=axis)
        if self.name == "max":
            return jnp.max(x, axis=axis)
        if self.name == "or":
            return jnp.max(x, axis=axis)
        raise NotImplementedError(self.name)


PLUS = Monoid("plus", lambda a, b: a + b, 0.0)
MIN = Monoid("min", jnp.minimum, float("inf"))
MAX = Monoid("max", jnp.maximum, float("-inf"))
OR = Monoid("or", jnp.maximum, 0.0)  # over {0,1} indicators


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    add: Monoid
    mul: Callable[[Array, Array], Array]
    mxu: bool  # True if A@B over this semiring lowers to a single MXU matmul
    # How dense_mxm computes it; one of {"dot", "dot_indicator", "bcast"}.
    mode: str

    @property
    def identity(self) -> float:
        return self.add.identity


def _pair(a: Array, b: Array) -> Array:
    return ((a != 0) & (b != 0)).astype(jnp.float32)


def _first(a: Array, b: Array) -> Array:
    del b
    return a


PLUS_TIMES = Semiring("plus_times", PLUS, lambda a, b: a * b, mxu=True, mode="dot")
OR_AND = Semiring("or_and", OR, _pair, mxu=True, mode="dot_indicator")
ANY_PAIR = Semiring("any_pair", OR, _pair, mxu=True, mode="dot_indicator")
PLUS_PAIR = Semiring("plus_pair", PLUS, _pair, mxu=True, mode="dot_pair")
MIN_PLUS = Semiring("min_plus", MIN, lambda a, b: a + b, mxu=False, mode="bcast")
MAX_PLUS = Semiring("max_plus", MAX, lambda a, b: a + b, mxu=False, mode="bcast")
PLUS_FIRST = Semiring("plus_first", PLUS, _first, mxu=True, mode="dot_first")

SEMIRINGS = {
    s.name: s
    for s in [PLUS_TIMES, OR_AND, ANY_PAIR, PLUS_PAIR, MIN_PLUS, MAX_PLUS, PLUS_FIRST]
}


def get(name: str) -> Semiring:
    return SEMIRINGS[name]


def dense_mxm(A: Array, B: Array, sr: Semiring) -> Array:
    """Reference semiring matmul on dense operands: Y[i,f] = add_j mul(A[i,j], B[j,f]).

    Structural semantics: an entry is "stored" iff nonzero (tests construct
    graphs that way). This is the oracle for every sparse kernel.
    """
    if sr.mode == "dot":
        return jnp.dot(
            A.astype(jnp.float32), B.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    if sr.mode == "dot_indicator":
        y = jnp.dot(
            (A != 0).astype(jnp.float32), (B != 0).astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (y > 0).astype(jnp.float32)
    if sr.mode == "dot_pair":
        return jnp.dot(
            (A != 0).astype(jnp.float32), (B != 0).astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    if sr.mode == "dot_first":
        # y[i,f] = sum_j where both stored: A[i,j]  (B acts as structural mask)
        return jnp.dot(
            A.astype(jnp.float32), (B != 0).astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
    if sr.mode == "bcast":
        # Tropical: no MXU analogue. Chunk K to bound the (i,k,f) intermediate.
        # Structural convention: only A is structural (absent edge == add
        # identity, pre-encoded via structural_dense); B is a *dense* operand —
        # every entry participates (0 is a real distance).
        n, k = A.shape
        f = B.shape[1]
        acc = jnp.full((n, f), sr.identity, dtype=jnp.float32)
        chunk = max(1, min(k, 4096 // max(1, f // 64 or 1)))
        for start in range(0, k, chunk):
            a = A[:, start : start + chunk].astype(jnp.float32)
            b = B[start : start + chunk, :].astype(jnp.float32)
            part = sr.add.reduce(sr.mul(a[:, :, None], b[None, :, :]), axis=1)
            acc = sr.add.op(acc, part)
        return acc
    raise NotImplementedError(sr.mode)


def structural_dense(A: Array, sr: Semiring) -> Array:
    """Encode a 0/weight dense matrix for a semiring's dense ref: tropical
    semirings need absent entries to be the add identity, not 0."""
    if sr.mode == "bcast":
        return jnp.where(A != 0, A.astype(jnp.float32), np.float32(sr.identity))
    return A
