"""Host-side COO set algebra for the sparse element-wise / assign / extract
paths.

Every sparse format in the engine (BSR tile lists, ELL padded rows) can hand
its stored entries over as flat ``(row * ncols + col)`` int64 keys plus f32
values. This module implements the GraphBLAS entry-set operations on those
key lists — union-merge (eWiseAdd / accum), intersection (eWiseMult),
pattern restriction (<M> / <!M>) and the full descriptor blend — so the ELL
element-wise family and the GrB_assign/extract analogs never materialize a
dense matrix. The BSR family has its own block-aligned implementations
(repro.core.bsr); this is the format-neutral fallback plan.

Convention (repo-wide): stored == nonzero; an absent entry renders as 0.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

Entries = Tuple[np.ndarray, np.ndarray]  # (int64 keys, f32 values)


def keys_of(rows, cols, ncols: int) -> np.ndarray:
    return (np.asarray(rows, dtype=np.int64) * int(ncols)
            + np.asarray(cols, dtype=np.int64))


def _as_entries(k, v) -> Entries:
    k = np.asarray(k, dtype=np.int64)
    v = np.asarray(v, dtype=np.float32)
    return k, v


def _match(k1: np.ndarray, k2: np.ndarray):
    """For each key in k2, its position in k1 (k1 sorted by caller) or a
    miss. Returns (positions, hit_mask)."""
    if len(k1) == 0:
        return np.zeros(len(k2), np.int64), np.zeros(len(k2), bool)
    j = np.clip(np.searchsorted(k1, k2), 0, len(k1) - 1)
    return j, k1[j] == k2


def union(k1, v1, k2, v2, op) -> Entries:
    """GraphBLAS union-merge: op(a, b) where both stored, pass-through where
    only one side is (the absent side is never fed to op)."""
    k1, v1 = _as_entries(k1, v1)
    k2, v2 = _as_entries(k2, v2)
    order = np.argsort(k1)
    k1, v1 = k1[order], v1[order]
    j, hit = _match(k1, k2)
    merged2 = v2.copy()
    if hit.any():
        merged2[hit] = np.asarray(op(v1[j[hit]], v2[hit]), dtype=np.float32)
    only1 = np.ones(len(k1), dtype=bool)
    only1[j[hit]] = False
    keys = np.concatenate([k1[only1], k2])
    vals = np.concatenate([v1[only1], merged2])
    order = np.argsort(keys)
    return keys[order], vals[order]


def intersect(k1, v1, k2, v2, op) -> Entries:
    """GraphBLAS intersection: op(a, b) on keys stored in both."""
    k1, v1 = _as_entries(k1, v1)
    k2, v2 = _as_entries(k2, v2)
    order = np.argsort(k1)
    k1, v1 = k1[order], v1[order]
    j, hit = _match(k1, k2)
    vals = np.asarray(op(v1[j[hit]], v2[hit]), dtype=np.float32)
    return k2[hit], vals


def restrict(k, v, mask_keys: np.ndarray, complement: bool = False) -> Entries:
    """Entries whose key is in (out of, when complemented) the mask set."""
    k, v = _as_entries(k, v)
    member = np.isin(k, mask_keys)
    keep = ~member if complement else member
    return k[keep], v[keep]


def blend(kz, vz, kc: Optional[np.ndarray], vc: Optional[np.ndarray],
          mask_keys: Optional[np.ndarray], complement: bool,
          accum_op, replace: bool) -> Entries:
    """The descriptor blend rule (grb.finalize) on entry sets.

      z      = union-accum(C, result)  when accum and C given, else result
      inside  the mask: z
      outside the mask: absent when C is None or replace, else old C
    """
    kz, vz = _as_entries(kz, vz)
    if accum_op is not None and kc is not None:
        kz, vz = union(kc, vc, kz, vz, accum_op)
    if mask_keys is None:
        return kz, vz
    kin, vin = restrict(kz, vz, mask_keys, complement)
    if kc is None or replace:
        return kin, vin
    kout, vout = restrict(kc, vc, mask_keys, not complement)
    keys = np.concatenate([kin, kout])       # disjoint by construction
    vals = np.concatenate([vin, vout])
    order = np.argsort(keys)
    return keys[order], vals[order]


def nonzero(keys: np.ndarray, vals: np.ndarray) -> Entries:
    """Drop explicit zeros (stored == nonzero hygiene after an op)."""
    keep = vals != 0
    return keys[keep], vals[keep]


def extract_entries(rows, cols, vals, I: np.ndarray, J: np.ndarray,
                    n: int, m: int):
    """Entries of A[I, J] in local coordinates (GrB_extract relabeling):
    keep entries whose row is in I and col in J, remap to positions."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.float32)
    lutr = np.full(n, -1, dtype=np.int64)
    lutr[I] = np.arange(len(I))
    lutc = np.full(m, -1, dtype=np.int64)
    lutc[J] = np.arange(len(J))
    keep = (lutr[rows] >= 0) & (lutc[cols] >= 0)
    return lutr[rows[keep]], lutc[cols[keep]], vals[keep]
