"""The unified GraphBLAS operation surface: ``C<M> accum= op(A, B, desc)``.

This module is the single API the rest of the engine programs against — the
TPU analog of the GraphBLAS C API subset RedisGraph builds on:

  GrB_Descriptor  -> :class:`Descriptor`  (mask, complement, accum, replace,
                     input-transpose), replacing the mask/complement/accum/
                     ``A_T``/``impl`` kwargs that used to be re-threaded
                     through every caller,
  GrB_Matrix      -> :class:`GBMatrix`    (one handle over dense / BSR / ELL
                     storage: format-agnostic dispatch, lazy cached transpose,
                     nvals/shape introspection, execution policy resolved once
                     at construction),
  GrB_mxm family  -> module-level :func:`mxm` / :func:`mxv` / :func:`vxm` /
                     :func:`ewise_add` / :func:`ewise_mult` / :func:`reduce` /
                     :func:`apply` / :func:`select`.

Algorithms (`repro.algorithms`), the query executor (`repro.query.executor`),
the batched server (`repro.engine.server`) and the sharded path
(`repro.distr.graph2d`) all dispatch through here; new storage formats or
backends plug in behind this surface without touching callers.

Blend (write) semantics, centralized in :func:`finalize`:

  z       = accum(C, result)      if accum given and C given, else result
  C<M>    = z   inside the mask   (all-true when desc.mask is None)
  C<!M>   = identity              when C is None or desc.replace
          = C (old value)         otherwise
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as _ops
from repro.core import semiring as S
from repro.core.bsr import BSR, SPGEMM_MODES as _SPGEMM_MODES
from repro.core.ell import ELL

Array = jnp.ndarray
Storage = Union[BSR, ELL, Array]


# ---------------------------------------------------------------------------
# Descriptor — GrB_Descriptor analog
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Descriptor:
    """Operation modifiers for one GraphBLAS call.

    mask        write mask M (same shape as the output, or a (n,) vector for
                mxv/vxm); entries where M is zero are *not* written. May be
                a dense array or a sparse GBMatrix/BSR handle — the SpGEMM
                path applies sparse masks block-wise (docs/API.md §SpGEMM)
    complement  use !M instead of M (GrB_COMP)
    accum       accumulate monoid: C<M> accum= result instead of C<M> = result
    replace     clear C entries outside the mask (GrB_REPLACE)
    transpose_a op reads A^T instead of A (GrB_INP0 + GrB_TRAN); served from
                the GBMatrix handle's cached transpose, never a runtime flip
    """
    mask: Optional[Union[Array, "GBMatrix", BSR]] = None
    complement: bool = False
    accum: Optional[S.Monoid] = None
    replace: bool = False
    transpose_a: bool = False

    def with_(self, **kw) -> "Descriptor":
        return dataclasses.replace(self, **kw)

    @property
    def mask_only(self) -> bool:
        """True when the write is a pure masked overwrite (no accum, no
        replace) — together with out=None, the kernel-fusable case."""
        return self.accum is None and not self.replace


NULL = Descriptor()
TRANSPOSE_A = Descriptor(transpose_a=True)


def desc(mask: Optional[Array] = None, complement: bool = False,
         accum: Optional[S.Monoid] = None, replace: bool = False,
         transpose_a: bool = False) -> Descriptor:
    """Convenience constructor mirroring GrB_Descriptor_set."""
    return Descriptor(mask=mask, complement=complement, accum=accum,
                      replace=replace, transpose_a=transpose_a)


def finalize(d: Descriptor, result: Array, out: Optional[Array],
             identity: float) -> Array:
    """Blend ``result`` into ``out`` under the descriptor (see module doc)."""
    if d.accum is not None and out is not None:
        z = d.accum.op(out, result)
    else:
        z = result
    if d.mask is None:
        return z
    m = (d.mask == 0) if d.complement else (d.mask != 0)
    if out is None or d.replace:
        outside = jnp.full_like(z, np.float32(identity))
    else:
        outside = out
    return jnp.where(m, z, outside)


# ---------------------------------------------------------------------------
# GBMatrix — GrB_Matrix analog
# ---------------------------------------------------------------------------
def _fmt_of(store: Storage) -> str:
    if isinstance(store, BSR):
        return "bsr"
    if isinstance(store, ELL):
        return "ell"
    return "dense"


def _resolve_impl(requested: str, fmt: str) -> str:
    """Execution policy, resolved once at handle construction.

    Only the BSR format has two paths (Pallas kernel vs the XLA-native
    batched-matmul); "auto" picks the kernel exactly when a real TPU backend
    is present. ELL and dense always lower through XLA.
    """
    if fmt != "bsr":
        return "xla"
    if requested == "pallas":
        return "pallas"
    if requested == "auto" and jax.default_backend() == "tpu":
        return "pallas"
    return "xla"


class GBMatrix:
    """One matrix handle over dense / BSR / ELL storage.

    The handle carries everything per-call kwargs used to: the storage format,
    the resolved execution policy (``impl``), and a lazily-built, cached
    stored transpose (``A.T``) so callers never hand-pass ``A_T``. Transposes
    built by the graph loader are linked in via :meth:`link_transpose`.

    Handles are host-side objects; the underlying storage (registered
    pytrees / jnp arrays) is what flows through jit. Inside traced code,
    close over the handle — do not pass it as a traced argument.
    """
    __slots__ = ("store", "fmt", "impl", "name", "_T")

    def __init__(self, store: Storage, impl: str = "auto", name: str = ""):
        if isinstance(store, GBMatrix):
            store = store.store
        if not isinstance(store, (BSR, ELL)):
            store = jnp.asarray(store)
        self.store = store
        self.fmt = _fmt_of(store)
        self.impl = _resolve_impl(impl, self.fmt)
        self.name = name
        self._T: Optional["GBMatrix"] = None

    # -- construction --------------------------------------------------------
    @classmethod
    def wrap(cls, A, impl: Optional[str] = None) -> "GBMatrix":
        """Adopt an existing handle or wrap raw storage. impl=None keeps an
        existing handle's resolved policy; an explicit impl re-resolves it."""
        if isinstance(A, GBMatrix):
            return A if impl is None else A.with_impl(impl)
        return cls(A, impl=impl or "auto")

    @classmethod
    def from_coo(cls, rows, cols, vals, shape, fmt: str = "auto",
                 block: int = 128, impl: str = "auto",
                 name: str = "") -> "GBMatrix":
        if fmt == "bsr":
            store = BSR.from_coo(rows, cols, vals, shape, block=block)
        elif fmt == "ell":
            store = ELL.from_coo(rows, cols, vals, shape)
        elif fmt == "dense":
            d = np.zeros(shape, dtype=np.float32)
            d[np.asarray(rows), np.asarray(cols)] = (
                1.0 if vals is None else np.asarray(vals, dtype=np.float32))
            store = jnp.asarray(d)
        else:
            store = _ops.auto_format(rows, cols, vals, shape, block=block)
        return cls(store, impl=impl, name=name)

    @classmethod
    def from_dense(cls, A, fmt: str = "dense", block: int = 128,
                   impl: str = "auto", name: str = "") -> "GBMatrix":
        if fmt == "dense":
            return cls(jnp.asarray(A), impl=impl, name=name)
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return cls.from_coo(r, c, A[r, c], A.shape, fmt=fmt, block=block,
                            impl=impl, name=name)

    # -- introspection -------------------------------------------------------
    @property
    def shape(self):
        return self.store.shape

    @property
    def nvals(self) -> int:
        """Stored-entry count (GrB_Matrix_nvals)."""
        if self.fmt == "dense":
            return int(np.count_nonzero(np.asarray(self.store)))
        return self.store.nnz

    # -- transpose -----------------------------------------------------------
    @property
    def T(self) -> "GBMatrix":
        """Stored transpose, built once and cached; ``A.T.T is A``."""
        if self._T is None:
            if self.fmt == "dense":
                t: Storage = self.store.T
            else:
                t = self.store.transpose()
            self.link_transpose(GBMatrix(t, impl=self.impl,
                                         name=self.name + "^T"))
        return self._T

    def link_transpose(self, other: "GBMatrix") -> "GBMatrix":
        """Install an explicitly-built transpose (RedisGraph maintains these
        per relation) so ``.T`` never rebuilds it."""
        self._T = other
        other._T = self
        return self

    # -- policy --------------------------------------------------------------
    def with_impl(self, impl: str) -> "GBMatrix":
        """Re-resolve the execution policy, sharing storage and the transpose
        cache. Returns self when the resolved policy is unchanged."""
        if _resolve_impl(impl, self.fmt) == self.impl:
            return self
        m = GBMatrix(self.store, impl=impl, name=self.name)
        if self._T is not None:
            m.link_transpose(GBMatrix(self._T.store, impl=impl,
                                      name=self._T.name))
        return m

    # -- conversion ----------------------------------------------------------
    def to_dense(self) -> Array:
        if self.fmt == "dense":
            return self.store
        return self.store.to_dense()

    # -- ergonomics ----------------------------------------------------------
    def __getattr__(self, attr: str):
        # forward storage-specific introspection (indices / mask / blocks /
        # nnz / to_coo / ...) so the handle is a drop-in for raw storage
        if attr.startswith("_"):
            raise AttributeError(attr)
        return getattr(self.store, attr)

    def __repr__(self) -> str:
        n, m = self.shape
        tag = f" {self.name!r}" if self.name else ""
        return (f"GBMatrix{tag} {n}x{m} fmt={self.fmt} impl={self.impl} "
                f"nvals={self.nvals}")


def matrix(obj, rel: Optional[str] = None,
           impl: Optional[str] = None) -> GBMatrix:
    """Adjacency handle from a Graph, Relation, GBMatrix, or raw storage.

    Duck-typed so `repro.core` never imports `repro.graph`: a Graph exposes
    ``relation()``/``relations``, a Relation exposes ``A``/``name``.
    impl=None (the default) keeps the handle's construction-time policy;
    an explicit impl re-resolves it via ``with_impl``.
    """
    if hasattr(obj, "relation") and hasattr(obj, "relations"):   # Graph
        try:
            r = obj.relation(rel)
        except KeyError:
            r = None
        if r is None:
            raise ValueError(f"no relation {rel!r} in graph "
                             f"(have: {sorted(obj.relations)})")
        obj = r
    if hasattr(obj, "A") and hasattr(obj, "name"):               # Relation
        return GBMatrix.wrap(obj.A, impl=impl)
    return GBMatrix.wrap(obj, impl=impl)


# ---------------------------------------------------------------------------
# uniform op surface — GrB_mxm family
# ---------------------------------------------------------------------------
def _dispatch_mxm(A: GBMatrix, B: Array, sr: S.Semiring,
                  d: Descriptor, fuse_mask: bool):
    """Format + policy dispatch for one semiring matmul. Returns
    (raw_result, mask_already_applied)."""
    if A.fmt == "bsr":
        if A.impl == "pallas":
            from repro.kernels import ops as kops   # lazy: kernels import core
            if fuse_mask:
                # the kernel folds <M>/<!M> into its epilogue on the last
                # tile of each block-row — no separate masking pass
                return kops.bsr_mxm(A.store, B, sr, mask=d.mask,
                                    complement=d.complement), True
            return kops.bsr_mxm(A.store, B, sr), False
        return _ops.bsr_mxm_jnp(A.store, B, sr), False
    if A.fmt == "ell":
        return _ops.ell_mxm(A.store, B, sr), False
    return S.dense_mxm(S.structural_dense(A.store, sr), B, sr), False


def _mask_storage(mask) -> Optional[Storage]:
    """Unwrap a descriptor mask that may be a GBMatrix handle."""
    if isinstance(mask, GBMatrix):
        return mask.store
    return mask


def _mask_as_bsr(mask, block: int) -> Optional[BSR]:
    """Structural BSR view of a descriptor mask for the SpGEMM path."""
    mask = _mask_storage(mask)
    if mask is None or isinstance(mask, BSR):
        return mask
    if isinstance(mask, ELL):
        mask = mask.to_dense()
    return BSR.from_dense(np.asarray(mask), block=block)


def _mxm_spgemm(A: GBMatrix, B: GBMatrix, sr: S.Semiring,
                d: Descriptor) -> GBMatrix:
    """Sparse-times-sparse dispatch: C<M> = A (x) B with C staying BSR.

    The structural mask is applied block-wise during accumulation planning
    (non-complemented masks prune whole output tiles symbolically) and
    element-wise in the kernel epilogue — never on a dense product.
    """
    from repro.core.bsr import spgemm
    mask = _mask_as_bsr(d.mask, A.store.block)
    C = spgemm(A.store, B.store, sr, mask=mask, complement=d.complement,
               impl=A.impl)
    name = f"({A.name}x{B.name})" if (A.name or B.name) else ""
    return GBMatrix(C, impl=A.impl, name=name)


def mxm(A, B, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None):
    """C<M> accum= A (x) B over a semiring — the uniform GraphBLAS call.

    A: GBMatrix (or raw BSR/ELL/dense, wrapped on the fly). B: either a
    dense (m, f) frontier matrix (returns a dense C) or a *sparse* GBMatrix
    (BSR x BSR routes through the SpGEMM kernel and returns a BSR-backed
    GBMatrix — see docs/API.md §SpGEMM for the dispatch rule). ``out`` is
    the existing C for accum/blend; None means replace-into-empty.
    """
    A = GBMatrix.wrap(A)
    if d.transpose_a:
        A = A.T
        d = d.with_(transpose_a=False)
    if (isinstance(B, GBMatrix) and A.fmt == "bsr" and B.fmt == "bsr"
            and out is None and sr.mode in _SPGEMM_MODES):
        return _mxm_spgemm(A, B, sr, d)
    if isinstance(B, GBMatrix):
        B = B.to_dense()
    if isinstance(d.mask, GBMatrix) or isinstance(d.mask, (BSR, ELL)):
        m = _mask_storage(d.mask)
        d = d.with_(mask=m if isinstance(m, jnp.ndarray) else m.to_dense())
    fuse = d.mask is not None and out is None and d.mask_only
    y, mask_done = _dispatch_mxm(A, B, sr, d, fuse)
    if mask_done:
        return y
    return finalize(d, y, out, sr.identity)


def _columnize(v) -> Optional[Array]:
    # sparse GBMatrix/BSR masks have no ndim and pass through to mxm's
    # mask conversion untouched; (n,) vectors become width-1 columns
    if v is not None and getattr(v, "ndim", None) == 1:
        return v[:, None]
    return v


def mxv(A, x: Array, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None) -> Array:
    """y<m> accum= A (x) x — a width-1 frontier."""
    dm = d.with_(mask=_columnize(d.mask))
    y = mxm(A, x[:, None], sr, dm, out=_columnize(out))
    return y[:, 0]


def vxm(x: Array, A, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None) -> Array:
    """y = x (x) A == A^T (x) x, served from the handle's cached transpose."""
    return mxv(A, x, sr, d.with_(transpose_a=not d.transpose_a), out=out)


def ewise_add(a: Array, b: Array, monoid: S.Monoid,
              d: Descriptor = NULL, out: Optional[Array] = None) -> Array:
    return finalize(d, monoid.op(a, b), out, monoid.identity)


def ewise_mult(a: Array, b: Array, op: Callable[[Array, Array], Array],
               d: Descriptor = NULL, out: Optional[Array] = None,
               identity: float = 0.0) -> Array:
    return finalize(d, op(a, b), out, identity)


def reduce(x, monoid: S.Monoid, axis=None) -> Array:
    """Monoid reduction; sparse GBMatrix handles reduce over stored blocks
    without densifying (plus/or over full extent), else via to_dense()."""
    if isinstance(x, GBMatrix):
        if x.fmt == "bsr" and axis is None and monoid.name in ("plus", "or"):
            s = x.store
            v = s.blocks.astype(jnp.float32) * s.valid.astype(
                jnp.float32)[:, None, None]
            return jnp.max(v) if monoid.name == "or" else jnp.sum(v)
        x = x.to_dense()
    return monoid.reduce(x, axis=axis)


def apply(f: Callable[[Array], Array], x: Array, d: Descriptor = NULL,
          out: Optional[Array] = None, identity: float = 0.0) -> Array:
    return finalize(d, f(x), out, identity)


def select(pred: Callable[[Array], Array], x: Array,
           identity: float = 0.0) -> Array:
    return jnp.where(pred(x), x, np.float32(identity))
