"""The unified GraphBLAS operation surface: ``C<M> accum= op(A, B, desc)``.

This module is the single API the rest of the engine programs against — the
TPU analog of the GraphBLAS C API subset RedisGraph builds on:

  GrB_Descriptor  -> :class:`Descriptor`  (mask, complement, accum, replace,
                     input-transpose), replacing the mask/complement/accum/
                     ``A_T``/``impl`` kwargs that used to be re-threaded
                     through every caller,
  GrB_Matrix      -> :class:`GBMatrix`    (one handle over dense / BSR / ELL
                     / ShardedELL / DeltaMatrix storage: format-agnostic
                     dispatch, lazy cached transpose, nvals/shape
                     introspection, execution policy resolved once at
                     construction),
  GrB_mxm family  -> module-level :func:`mxm` / :func:`mxv` / :func:`vxm` /
                     :func:`ewise_add` / :func:`ewise_mult` / :func:`reduce` /
                     :func:`apply` / :func:`select` / :func:`assign` /
                     :func:`extract`.

The mxm family takes dense frontiers or sparse GBMatrix operands (BSR x BSR
routes through SpGEMM); the element-wise family is *format-aware*: sparse
operands run block-aligned (BSR, core.bsr) or COO set-algebra (ELL,
core.coo) paths with GraphBLAS union/intersection entry semantics and stay
sparse end to end — no silent densification (docs/API.md §eWise).

The fourth storage kind is *sharded* (`core.shard.ShardedELL`): the same ELL
row layout laid out over a mesh ("data" axis rows, pod x model frontier
columns). :func:`distribute` re-homes an ELL handle onto a mesh; mxm/mxv/
reduce then lower to the explicit-collective shard_map bodies in
`repro.distr.graph2d` (all-gather frontier in row form, psum_scatter row
blocks in transposed form), and the element-wise family — eWiseAdd/Mult,
apply/select with full descriptor blending, column extract/assign, min/max
reduce — runs *shard-local* through the slot-aligned merge lowering
(`graph2d.ewise_2d`): rows live whole on one shard, so COO set algebra
never needs a collective, let alone a gather. The few genuinely
cross-shard requests (row-subset extract/assign, cross-mesh masks) gather
to host and bump :func:`host_transfers` (docs/API.md §Sharded).

The fifth storage kind is the *delta* form (`core.delta.DeltaMatrix`,
docs/API.md §Delta): a frozen base plus pending plus/minus COO deltas, the
live-mutation path of `engine.Database`. The matmul family and plus/or
reduce compose the deltas with zero rebuild (row-patch decomposition —
exact for every semiring); the element-wise family, SpGEMM, descriptor
masks, and min/max reduce fall back to a cached materialize of the
effective matrix in the base's own format. Compaction back into the base
is policy-driven (`AUTO_DELTA_COMPACT`, re-exported here; measured by
benchmarks/bench_mutations.py).

Boolean traversals additionally ride the *bitmap-packed frontier* form
(`core.bitmap`, docs/API.md §Bitmap): an or_and mxm/mxv/vxm whose dense
frontier is at least AUTO_PACK_MIN_WIDTH wide packs it into uint32 words
(32 queries/word) on dense, ELL, and ShardedELL operands, blends
pure-masked writes word-wise, and unpacks at the boundary — results are
bit-identical to the float route and callers never see a packed array.
The policy is trace-time static; `packed_frontiers("on"|"off"|"auto")`
overrides it.

Public contract (what raises, what moves data):

  * TypeError — mixed operand kinds, always naming the expected ones:
    sparse with dense in the eWise family; sharded with unsharded
    anywhere; sparse B against a sharded A; non-ELL storage handed to
    :func:`distribute`; sharded `out=` under unsharded operands.
  * ValueError — shape mismatches (operands, masks vs result, assign
    regions) and invalid/duplicate index vectors.
  * Gathers to host (documented, correct, *counted* by
    :func:`host_transfers`) — only genuinely cross-shard requests:
    row-subset assign/extract (rows re-partition the "data" axis) and a
    sparse mask sharded on a *different* mesh. Everything else on a
    sharded handle stays on the mesh: eWiseAdd/Mult, apply/select under
    any descriptor blend, column extract/assign, and min/max reduce all
    run shard-local through the slot-aligned merge in
    `distr.graph2d.ewise_2d` (docs/API.md §Sharded).

Algorithms (`repro.algorithms`), the query executor (`repro.query.executor`),
and the batched server (`repro.engine.server`) all dispatch through here —
single-device and on a mesh, with zero sharding-specific call-site
arguments; new storage formats or backends plug in behind this surface
without touching callers.

Blend (write) semantics, centralized in :func:`finalize`:

  z       = accum(C, result)      if accum given and C given, else result
  C<M>    = z   inside the mask   (all-true when desc.mask is None)
  C<!M>   = identity              when C is None or desc.replace
          = C (old value)         otherwise
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitadj as _bitadj
from repro.core import bitmap as _bitmap
from repro.core import bsr as _bsr
from repro.core import coo as _coo
from repro.core import ops as _ops
from repro.core import semiring as S
from repro.core import shard as _shard
from repro.core import xfer as _xfer
from repro.core.bitadj import (AUTO_BITADJ_MAX_SLOTS,  # noqa: F401
                               AUTO_BITADJ_MIN_FILL, BitELL, ShardedBitELL)
from repro.core.bsr import BSR, SPGEMM_MODES as _SPGEMM_MODES
from repro.core.delta import AUTO_DELTA_COMPACT, DeltaMatrix  # noqa: F401
from repro.core.ell import ELL
from repro.core.shard import ShardedELL

Array = jnp.ndarray
Storage = Union[BSR, ELL, ShardedELL, DeltaMatrix, BitELL, ShardedBitELL,
                Array]


# ---------------------------------------------------------------------------
# Descriptor — GrB_Descriptor analog
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Descriptor:
    """Operation modifiers for one GraphBLAS call.

    mask        write mask M (same shape as the output, or a (n,) vector for
                mxv/vxm); entries where M is zero are *not* written. May be
                a dense array or a sparse GBMatrix/BSR handle — the SpGEMM
                path applies sparse masks block-wise (docs/API.md §SpGEMM)
    complement  use !M instead of M (GrB_COMP)
    accum       accumulate monoid: C<M> accum= result instead of C<M> = result
    replace     clear C entries outside the mask (GrB_REPLACE)
    transpose_a op reads A^T instead of A (GrB_INP0 + GrB_TRAN); served from
                the GBMatrix handle's cached transpose, never a runtime flip
    """
    mask: Optional[Union[Array, "GBMatrix", BSR]] = None
    complement: bool = False
    accum: Optional[S.Monoid] = None
    replace: bool = False
    transpose_a: bool = False

    def with_(self, **kw) -> "Descriptor":
        return dataclasses.replace(self, **kw)

    @property
    def mask_only(self) -> bool:
        """True when the write is a pure masked overwrite (no accum, no
        replace) — together with out=None, the kernel-fusable case."""
        return self.accum is None and not self.replace


NULL = Descriptor()
TRANSPOSE_A = Descriptor(transpose_a=True)


def desc(mask: Optional[Array] = None, complement: bool = False,
         accum: Optional[S.Monoid] = None, replace: bool = False,
         transpose_a: bool = False) -> Descriptor:
    """Convenience constructor mirroring GrB_Descriptor_set."""
    return Descriptor(mask=mask, complement=complement, accum=accum,
                      replace=replace, transpose_a=transpose_a)


def finalize(d: Descriptor, result: Array, out: Optional[Array],
             identity: float) -> Array:
    """Blend ``result`` into ``out`` under the descriptor (see module doc)."""
    if d.accum is not None and out is not None:
        z = d.accum.op(out, result)
    else:
        z = result
    if d.mask is None:
        return z
    m = (d.mask == 0) if d.complement else (d.mask != 0)
    if out is None or d.replace:
        outside = jnp.full_like(z, np.float32(identity))
    else:
        outside = out
    return jnp.where(m, z, outside)


# ---------------------------------------------------------------------------
# GBMatrix — GrB_Matrix analog
# ---------------------------------------------------------------------------
def _fmt_of(store: Storage) -> str:
    if isinstance(store, BSR):
        return "bsr"
    if isinstance(store, ELL):
        return "ell"
    if isinstance(store, ShardedELL):
        return "sharded"
    if isinstance(store, DeltaMatrix):
        return "delta"
    if isinstance(store, BitELL):
        return "bitadj"
    if isinstance(store, ShardedBitELL):
        return "bitshard"
    return "dense"


# -- impl="auto" crossover policy --------------------------------------------
# Measured by benchmarks/bench_triangles.py (RMAT edge_factor 8, block 128,
# XLA-CPU reference host): the sparse-kernel formulation loses below RMAT
# scale 9 and wins from it — 1.1x at s9 (512 rows = 4 block-rows,
# stored-tile fill 0.022), 1.6x at s10 (8 block-rows, fill 0.012). Below
# AUTO_MIN_GRID block-rows, or with stored tiles mostly full, one batched
# XLA matmul amortizes better than per-tile kernel scheduling; a B operand
# narrower than AUTO_MIN_WIDTH columns cannot fill an MXU pass either way.
AUTO_MIN_GRID = 4     # block-rows/-cols below this: one dense matmul wins
AUTO_MAX_FILL = 0.25  # stored-tile fill above this: effectively dense
AUTO_MIN_WIDTH = 8    # B frontier narrower than this: XLA (auto handles only)

# -- bitmap-packed frontier policy -------------------------------------------
# or_and-semiring mxm/mxv/vxm on dense / ELL / ShardedELL operands pack the
# boolean frontier into uint32 words (core.bitmap) when it is at least this
# wide. Measured by benchmarks/bench_khop.run_packed (RMAT s10 k-hop,
# XLA-CPU reference host): the packed route wins at every swept width —
# 9.8x at F=8, 26x at F=32, 84x at F=128 — because the unpacked ELL gather
# materializes an (n, deg, F) float32 intermediate the words shrink 32x.
# The floor only exempts near-scalar frontiers (a width-1 or_and mxv),
# where a word is >= 97% padding and the pack/unpack boundary is pure
# overhead; it mirrors AUTO_MIN_WIDTH. BSR operands never pack — their
# or_and route is the MXU indicator matmul, which packing would abandon.
AUTO_PACK_MIN_WIDTH = 8

_PACK_MODE = "auto"   # "auto" (width threshold) | "on" | "off"


@contextlib.contextmanager
def packed_frontiers(mode: str):
    """Temporarily override the bitmap-packing policy: "on" packs every
    or_and-eligible call regardless of width, "off" disables packing,
    "auto" restores the AUTO_PACK_MIN_WIDTH crossover. Benchmarks and the
    differential tests use this; production code should leave "auto"."""
    global _PACK_MODE
    if mode not in ("auto", "on", "off"):
        raise ValueError(f"packed_frontiers mode {mode!r} not in "
                         f"('auto', 'on', 'off')")
    prev, _PACK_MODE = _PACK_MODE, mode
    try:
        yield
    finally:
        _PACK_MODE = prev


def _pack_wanted(f: int) -> bool:
    """Width side of the packed-frontier policy (static at trace time)."""
    if _PACK_MODE == "off":
        return False
    return _PACK_MODE == "on" or f >= AUTO_PACK_MIN_WIDTH


def _kernel_pays_off(store: BSR) -> bool:
    """Fill-ratio/grid-size side of the measured crossover (width is only
    known per call and is checked in _dispatch_mxm)."""
    return (min(store.nbrows, store.nbcols) >= AUTO_MIN_GRID
            and store.fill_ratio <= AUTO_MAX_FILL)


def _resolve_impl(requested: str, fmt: str, store: Optional[BSR] = None) -> str:
    """Execution policy, resolved once at handle construction.

    Only the BSR format has two paths (Pallas kernel vs the XLA-native
    batched-matmul); explicit "pallas"/"xla" force one. "auto" picks the
    kernel when a real TPU backend is present AND the measured
    dense-vs-sparse crossover says the per-tile schedule beats one batched
    matmul for this operand (see _kernel_pays_off). ELL and dense always
    lower through XLA.
    """
    if fmt != "bsr":
        return "xla"
    if requested == "pallas":
        return "pallas"
    if requested == "auto" and jax.default_backend() == "tpu":
        if store is None or _kernel_pays_off(store):
            return "pallas"
    return "xla"


class GBMatrix:
    """One matrix handle over dense / BSR / ELL / ShardedELL / DeltaMatrix /
    BitELL (+ its ShardedBitELL mesh twin) storage.

    The handle carries everything per-call kwargs used to: the storage format,
    the resolved execution policy (``impl``), and a lazily-built, cached
    stored transpose (``A.T``) so callers never hand-pass ``A_T``. Transposes
    built by the graph loader are linked in via :meth:`link_transpose`.

    Handles are host-side objects; the underlying storage (registered
    pytrees / jnp arrays) is what flows through jit. Inside traced code,
    close over the handle — do not pass it as a traced argument.
    """
    __slots__ = ("store", "fmt", "impl", "auto", "name", "_T", "_sharded")

    def __init__(self, store: Storage, impl: str = "auto", name: str = ""):
        if isinstance(store, GBMatrix):
            store = store.store
        if not isinstance(store, (BSR, ELL, ShardedELL, DeltaMatrix,
                                  BitELL, ShardedBitELL)):
            store = jnp.asarray(store)
        self.store = store
        self.fmt = _fmt_of(store)
        # auto marks a policy the crossover heuristics may refine per call
        # (operand width); an explicit "pallas"/"xla" request is never
        # second-guessed.
        self.auto = impl == "auto"
        self.impl = _resolve_impl(impl, self.fmt,
                                  store if isinstance(store, BSR) else None)
        self.name = name
        self._T: Optional["GBMatrix"] = None
        # mesh -> distributed twin, filled by grb.distribute (like the _T
        # cache: serving contexts re-resolve per query and must not re-pad
        # + re-device_put the whole graph each time)
        self._sharded: Optional[dict] = None

    # -- construction --------------------------------------------------------
    @classmethod
    def wrap(cls, A, impl: Optional[str] = None) -> "GBMatrix":
        """Adopt an existing handle or wrap raw storage. impl=None keeps an
        existing handle's resolved policy; an explicit impl re-resolves it."""
        if isinstance(A, GBMatrix):
            return A if impl is None else A.with_impl(impl)
        return cls(A, impl=impl or "auto")

    @classmethod
    def from_coo(cls, rows, cols, vals, shape, fmt: str = "auto",
                 block: int = 128, impl: str = "auto",
                 name: str = "") -> "GBMatrix":
        if fmt == "bsr":
            store = BSR.from_coo(rows, cols, vals, shape, block=block)
        elif fmt == "ell":
            store = ELL.from_coo(rows, cols, vals, shape)
        elif fmt == "bitadj":
            store = BitELL.from_coo(rows, cols, vals, shape)
        elif fmt == "dense":
            d = np.zeros(shape, dtype=np.float32)
            d[np.asarray(rows), np.asarray(cols)] = (
                1.0 if vals is None else np.asarray(vals, dtype=np.float32))
            store = jnp.asarray(d)
        else:
            store = _ops.auto_format(rows, cols, vals, shape, block=block)
        return cls(store, impl=impl, name=name)

    @classmethod
    def from_dense(cls, A, fmt: str = "dense", block: int = 128,
                   impl: str = "auto", name: str = "") -> "GBMatrix":
        if fmt == "dense":
            return cls(jnp.asarray(A), impl=impl, name=name)
        A = np.asarray(A)
        r, c = np.nonzero(A)
        return cls.from_coo(r, c, A[r, c], A.shape, fmt=fmt, block=block,
                            impl=impl, name=name)

    # -- introspection -------------------------------------------------------
    @property
    def shape(self):
        return self.store.shape

    @property
    def nvals(self) -> int:
        """Stored-entry count (GrB_Matrix_nvals)."""
        if self.fmt == "dense":
            return int(np.count_nonzero(np.asarray(self.store)))
        return self.store.nnz

    # -- transpose -----------------------------------------------------------
    @property
    def T(self) -> "GBMatrix":
        """Stored transpose, built once and cached; ``A.T.T is A``."""
        if self._T is None:
            # the handle cache outlives any trace that triggers the build
            # (e.g. transpose_a inside a while_loop body), so the transpose
            # arrays must be concrete, never trace-bound tracers
            with jax.ensure_compile_time_eval():
                if self.fmt == "dense":
                    t: Storage = self.store.T
                else:
                    t = self.store.transpose()
            # an auto policy stays auto: re-resolve against the transposed
            # store and keep the per-call crossover heuristics active
            self.link_transpose(GBMatrix(t,
                                         impl="auto" if self.auto
                                         else self.impl,
                                         name=self.name + "^T"))
        return self._T

    def link_transpose(self, other: "GBMatrix") -> "GBMatrix":
        """Install an explicitly-built transpose (RedisGraph maintains these
        per relation) so ``.T`` never rebuilds it."""
        self._T = other
        other._T = self
        return self

    # -- policy --------------------------------------------------------------
    def with_impl(self, impl: str) -> "GBMatrix":
        """Re-resolve the execution policy, sharing storage and the transpose
        cache. Returns self when the resolved policy is unchanged."""
        store = self.store if self.fmt == "bsr" else None
        if (_resolve_impl(impl, self.fmt, store) == self.impl
                and (impl == "auto") == self.auto):
            return self
        m = GBMatrix(self.store, impl=impl, name=self.name)
        if self._T is not None:
            m.link_transpose(GBMatrix(self._T.store, impl=impl,
                                      name=self._T.name))
        return m

    # -- conversion ----------------------------------------------------------
    def to_dense(self) -> Array:
        if self.fmt == "dense":
            return self.store
        return self.store.to_dense()

    # -- ergonomics ----------------------------------------------------------
    def __getattr__(self, attr: str):
        # forward storage-specific introspection (indices / mask / blocks /
        # nnz / to_coo / ...) so the handle is a drop-in for raw storage
        if attr.startswith("_"):
            raise AttributeError(attr)
        return getattr(self.store, attr)

    def __repr__(self) -> str:
        n, m = self.shape
        tag = f" {self.name!r}" if self.name else ""
        return (f"GBMatrix{tag} {n}x{m} fmt={self.fmt} impl={self.impl} "
                f"nvals={self.nvals}")


def matrix(obj, rel: Optional[str] = None,
           impl: Optional[str] = None) -> GBMatrix:
    """Adjacency handle from a Graph, Relation, GBMatrix, or raw storage.

    Duck-typed so `repro.core` never imports `repro.graph`: a Graph exposes
    ``relation()``/``relations``, a Relation exposes ``A``/``name``.
    impl=None (the default) keeps the handle's construction-time policy;
    an explicit impl re-resolves it via ``with_impl``.
    """
    if hasattr(obj, "relation") and hasattr(obj, "relations"):   # Graph
        try:
            r = obj.relation(rel)
        except KeyError:
            r = None
        if r is None:
            raise ValueError(f"no relation {rel!r} in graph "
                             f"(have: {sorted(obj.relations)})")
        obj = r
    if hasattr(obj, "A") and hasattr(obj, "name"):               # Relation
        return GBMatrix.wrap(obj.A, impl=impl)
    return GBMatrix.wrap(obj, impl=impl)


def distribute(obj, mesh, rel: Optional[str] = None) -> GBMatrix:
    """Re-home an ELL or BitELL handle onto a mesh: the sharded-storage
    constructor.

    Takes anything :func:`matrix` takes (Graph + rel, Relation, GBMatrix,
    raw ELL/BitELL). Returns a GBMatrix whose storage is a row-sharded
    ``core.shard.ShardedELL`` (or ``core.bitadj.ShardedBitELL`` for
    bit-packed structural adjacency — its transpose twin is force-built and
    linked, since the bit route has no transposed scatter lowering); a
    linked transpose is sharded and linked too, so ``A.T`` / ``transpose_a``
    descriptors keep resolving to stored transposes on the mesh. Every
    later `grb` call on the handle lowers to the mesh collectives — call
    sites carry zero sharding arguments.

    Other storage raises a TypeError naming the expected kinds (the mesh
    layout row-shards ELL's padded neighbor lists / BitELL's word panels;
    BSR tiles and dense arrays have no row-block layout here).

    Distributed twins are cached on the source handle per mesh (like the
    transpose cache), so per-query contexts re-resolving the same relation
    never re-pad + re-device_put the graph.
    """
    h = matrix(obj, rel)
    if h.fmt == "sharded":
        if h.store.mesh == mesh:
            return h
        hh = GBMatrix(h.store.to_ell(), name=h.name)  # re-home across meshes
        if h._T is not None and h._T.fmt == "sharded":
            hh.link_transpose(GBMatrix(h._T.store.to_ell(), name=h._T.name))
        h = hh
    if h.fmt == "bitshard":
        if h.store.mesh == mesh:
            return h
        hh = GBMatrix(h.store.to_bitell(), name=h.name)
        if h._T is not None and h._T.fmt == "bitshard":
            hh.link_transpose(GBMatrix(h._T.store.to_bitell(),
                                       name=h._T.name))
        h = hh
    if h.fmt == "delta":
        # the mesh layout has no delta lowering: compact into the base
        # format first (engine.Database freezes mesh-served graphs with
        # compact=True so serving contexts never pay this per query)
        hh = GBMatrix(h.store.materialize(), name=h.name)
        if h._T is not None and h._T.fmt == "delta":
            hh.link_transpose(GBMatrix(h._T.store.materialize(),
                                       name=h._T.name))
        h = hh
    if h.fmt == "bitadj":
        # bit-packed panels shard like ELL rows do — but transpose_a on the
        # mesh is always served from a stored twin (there is no transposed
        # bit-scatter lowering), so force-build + link it here, once
        cache = h._sharded if h._sharded is not None else {}
        m = cache.get(mesh)
        if m is None:
            hT = h.T                      # host rebuild, cached on the handle
            m = GBMatrix(ShardedBitELL.from_bitell(h.store, mesh),
                         name=h.name)
            m.link_transpose(
                GBMatrix(ShardedBitELL.from_bitell(hT.store, mesh),
                         name=hT.name))
            cache[mesh] = m
            h._sharded = cache
        return m
    if h.fmt != "ell":
        raise TypeError(
            f"grb.distribute: sharded dispatch needs ELL or BitELL row "
            f"storage, got {h.fmt!r} — rebuild with fmt='ell' "
            f"(GBMatrix.from_dense(x, fmt='ell') / "
            f"GraphBuilder.build(fmt='ell')) before distributing onto a "
            f"mesh")
    cache = h._sharded if h._sharded is not None else {}
    m = cache.get(mesh)
    if m is None:
        m = GBMatrix(ShardedELL.from_ell(h.store, mesh), name=h.name)
        if h._T is not None and h._T.fmt == "ell":
            m.link_transpose(GBMatrix(ShardedELL.from_ell(h._T.store, mesh),
                                      name=h._T.name))
        cache[mesh] = m
        h._sharded = cache
    return m


# ---------------------------------------------------------------------------
# uniform op surface — GrB_mxm family
# ---------------------------------------------------------------------------
def _dispatch_mxm(A: GBMatrix, B: Array, sr: S.Semiring,
                  d: Descriptor, fuse_mask: bool):
    """Format + policy dispatch for one semiring matmul. Returns
    (raw_result, mask_already_applied)."""
    if A.fmt == "bsr":
        impl = A.impl
        if impl == "pallas" and A.auto and B.shape[1] < AUTO_MIN_WIDTH:
            impl = "xla"   # auto policy: narrow frontier can't fill the MXU
        if (impl == "pallas" and sr.mode == "bcast"
                and A.store.emask is not None):
            impl = "xla"   # explicit-zero structure: kernel has no emask lane
        if impl == "pallas":
            from repro.kernels import ops as kops   # lazy: kernels import core
            if fuse_mask:
                # the kernel folds <M>/<!M> into its epilogue on the last
                # tile of each block-row — no separate masking pass
                return kops.bsr_mxm(A.store, B, sr, mask=d.mask,
                                    complement=d.complement), True
            return kops.bsr_mxm(A.store, B, sr), False
        return _ops.bsr_mxm_jnp(A.store, B, sr), False
    if A.fmt == "ell":
        return _ops.ell_mxm(A.store, B, sr), False
    return S.dense_mxm(S.structural_dense(A.store, sr), B, sr), False


def _mask_storage(mask) -> Optional[Storage]:
    """Unwrap a descriptor mask that may be a GBMatrix handle. Sharded masks
    gather to a host ELL; delta masks compose into their base format (the
    documented materialize fallback, docs/API.md §Delta) — mask blending
    happens host/dense-side."""
    if isinstance(mask, GBMatrix):
        mask = mask.store
    if isinstance(mask, (ShardedELL, ShardedBitELL, BitELL)):
        mask = mask.to_ell()
    if isinstance(mask, DeltaMatrix):
        mask = mask.materialize()
    return mask


def _mask_as_bsr(mask, block: int) -> Optional[BSR]:
    """Structural BSR view of a descriptor mask for the SpGEMM and sparse
    element-wise paths. Sparse masks convert sparse-to-sparse (COO);
    only a mask that is *already dense* is tiled from its array."""
    mask = _mask_storage(mask)
    if mask is None:
        return None
    if isinstance(mask, (BSR, ELL)):
        return _bsr.as_bsr(mask, block)
    return BSR.from_dense(np.asarray(mask), block=block)


def _mxm_spgemm(A: GBMatrix, B: GBMatrix, sr: S.Semiring,
                d: Descriptor) -> GBMatrix:
    """Sparse-times-sparse dispatch: C<M> = A (x) B with C staying BSR.

    The structural mask is applied block-wise during accumulation planning
    (non-complemented masks prune whole output tiles symbolically) and
    element-wise in the kernel epilogue — never on a dense product.
    """
    from repro.core.bsr import spgemm
    mask = _mask_as_bsr(d.mask, A.store.block)
    C = spgemm(A.store, B.store, sr, mask=mask, complement=d.complement,
               impl=A.impl)
    name = f"({A.name}x{B.name})" if (A.name or B.name) else ""
    return GBMatrix(C, impl="auto" if A.auto else A.impl, name=name)


def _mxm_sharded(A: GBMatrix, B, sr: S.Semiring, d: Descriptor,
                 out: Optional[Array]) -> Array:
    """Mesh dispatch: C<M> accum= A (x) B with A's rows sharded over "data".

    B must be a dense (k, F) frontier (sharded x sparse has no mesh
    lowering — the TypeError names the expected kinds). transpose_a is
    served from a linked sharded transpose when one exists; otherwise the
    transposed (psum_scatter) lowering reads the forward row shards — no
    materialization either way. The blend (mask/accum/replace) runs on the
    global result under GSPMD, identical to the dense path.
    """
    if isinstance(B, GBMatrix) and B.fmt == "dense":
        B = B.store                      # dense handle == dense frontier
    if isinstance(B, (GBMatrix, BSR, ELL, ShardedELL)):
        kind = _operand_kind(B)[0]
        raise TypeError(
            f"grb.mxm: a sharded A multiplies a dense (k, F) frontier "
            f"array; got a sparse {kind} operand for B. Gather it "
            f"explicitly (B.to_dense()) or keep both sides unsharded for "
            f"the SpGEMM path.")
    transposed = False
    if d.transpose_a:
        if A._T is not None:
            A = A.T
        else:
            transposed = True
        d = d.with_(transpose_a=False)
    if isinstance(d.mask, (GBMatrix, BSR, ELL, ShardedELL)):
        m = _mask_storage(d.mask)
        d = d.with_(mask=m if isinstance(m, jnp.ndarray) else m.to_dense())
    B = jnp.asarray(B)
    # or_and frontiers ride the mesh as packed uint32 words — the per-hop
    # all-gather (row form) / psum_scatter (transposed form) payload cut
    packed = (sr.mode == "dot_indicator" and B.ndim == 2
              and _pack_wanted(B.shape[1]))
    y = _shard.mxm(A.store, B, sr, transposed=transposed, packed=packed)
    return finalize(d, y, out, sr.identity)


def _mxm_delta(A: GBMatrix, B: Array, sr: S.Semiring, d: Descriptor,
               out: Optional[Array]) -> Array:
    """Delta-composed semiring matmul, exact for every semiring with zero
    rebuild: result row i depends only on A row i, so rows no delta touches
    come from the frozen base's product and delta-touched rows from the
    product of a small ELL *patch* holding their exact effective content
    (docs/API.md §Delta). The patch covers only the touched rows, so the
    composition overhead is O(touched * deg), not a second full product;
    its rows scatter over the base product (out-of-bounds padding drops).
    Rows past the base's extent (live node growth) are the add identity
    unless patched. Both sub-products recurse through :func:`mxm`, so the
    base keeps its own route — BSR kernel/XLA policy, bitmap-packed or_and
    frontiers — untouched."""
    dm: DeltaMatrix = A.store
    impl = "auto" if A.auto else A.impl
    baseh = GBMatrix(dm.base, impl=impl, name=A.name)
    bn, bm = baseh.shape
    n = dm.shape[0]
    patch, rows = dm.patch()
    if patch is None and n == bn:
        return mxm(baseh, B, sr, d, out=out)       # empty delta: base verbatim
    yb = mxm(baseh, B[:bm], sr)
    if n > bn:
        pad = jnp.full((n - bn, yb.shape[1]), np.float32(sr.identity),
                       dtype=yb.dtype)
        yb = jnp.concatenate([yb, pad], axis=0)
    if patch is not None:
        yp = mxm(GBMatrix(patch, impl=impl), B, sr)
        yb = yb.at[rows].set(yp, mode="drop")
    return finalize(d, yb, out, sr.identity)


def _packed_route_ok(A: GBMatrix, B, sr: S.Semiring) -> bool:
    """Static (trace-time) gate for the bitmap-packed or_and route: boolean
    semiring, dense frontier B, dense/ELL/BitELL storage (BSR keeps the MXU
    indicator matmul), frontier wide enough per the measured crossover.
    BitELL is exempt from the width floor — its adjacency side is packed
    whatever the frontier width, so the word route never loses."""
    if sr.mode != "dot_indicator" or getattr(B, "ndim", 0) != 2:
        return False
    if A.fmt == "bitadj":
        return True                          # structural: words always win
    return A.fmt in ("dense", "ell") and _pack_wanted(B.shape[1])


def _mxm_packed(A: GBMatrix, B: Array, sr: S.Semiring, d: Descriptor,
                out: Optional[Array]) -> Array:
    """or_and mxm with the frontier in core.bitmap packed form: pack at the
    call boundary, OR words through the packed gather (Pallas kernel on TPU,
    XLA reference otherwise), blend the mask word-wise when the write is a
    pure masked overwrite, unpack at the other boundary. Bit-identical to
    the float indicator route (the unpack renders exactly {0.0, 1.0})."""
    f = B.shape[1]
    Bw = _bitmap.pack(B)
    if A.fmt == "bitadj":
        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops   # lazy: kernels import core
            Yw = kops.bitadj_mxv_packed(A.store, Bw)
        else:
            Yw = _bitadj.mxm_words(A.store, Bw)
    elif A.fmt == "ell":
        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops   # lazy: kernels import core
            Yw = kops.ell_mxv_packed(A.store, Bw)
        else:
            Yw = _ops.ell_mxm_packed(A.store, Bw)
    else:
        Yw = _ops.dense_mxm_packed(A.store, Bw)
    if d.mask is not None and d.mask_only and out is None:
        # the or_and identity is 0, so <M> / <!M> on a replace-into-empty
        # write is pure word algebra: keep = and, complement keep = andnot
        Mw = _bitmap.pack(jnp.asarray(d.mask))
        Yw = (_bitmap.word_andnot(Yw, Mw) if d.complement
              else _bitmap.word_and(Yw, Mw))
        return _bitmap.unpack(Yw, f)
    return finalize(d, _bitmap.unpack(Yw, f), out, sr.identity)


def _mxm_bitshard(A: GBMatrix, B, sr: S.Semiring, d: Descriptor,
                  out: Optional[Array]) -> Array:
    """Mesh dispatch for bit-packed adjacency: or_and/any_pair calls run
    fully bit-level (pack at the boundary, `bitadj.sharded_mxm_words` — one
    packed all-gather per call, word-AND + OR locally, zero float
    intermediates; the route is taken for *every* dot_indicator call, so
    results never depend on the packing policy). transpose_a always serves
    from the linked twin grb.distribute force-built. Other semirings take
    the cached ShardedELL materialization and the regular sharded route."""
    if isinstance(B, GBMatrix) and B.fmt == "dense":
        B = B.store
    if isinstance(B, (GBMatrix, BSR, ELL, ShardedELL, BitELL,
                      ShardedBitELL)):
        kind = _operand_kind(B)[0]
        raise TypeError(
            f"grb.mxm: a sharded A multiplies a dense (k, F) frontier "
            f"array; got a sparse {kind} operand for B. Gather it "
            f"explicitly (B.to_dense()) or keep both sides unsharded for "
            f"the SpGEMM path.")
    if d.transpose_a:
        if A._T is None or A._T.fmt != "bitshard":
            raise RuntimeError(
                "grb.mxm: transpose_a on bit-sharded storage needs the "
                "linked transpose twin grb.distribute builds — distribute "
                "the handle (not a hand-wrapped ShardedBitELL) first")
        A = A.T
        d = d.with_(transpose_a=False)
    if isinstance(d.mask, (GBMatrix, BSR, ELL, ShardedELL, BitELL,
                           ShardedBitELL, DeltaMatrix)):
        m = _mask_storage(d.mask)
        d = d.with_(mask=m if isinstance(m, jnp.ndarray) else m.to_dense())
    B = jnp.asarray(B)
    if sr.mode == "dot_indicator" and B.ndim == 2:
        f = B.shape[1]
        Yw = _bitadj.sharded_mxm_words(A.store, _bitmap.pack(B))
        if d.mask is not None and d.mask_only and out is None:
            Mw = _bitmap.pack(jnp.asarray(d.mask))
            Yw = (_bitmap.word_andnot(Yw, Mw) if d.complement
                  else _bitmap.word_and(Yw, Mw))
            return _bitmap.unpack(Yw, f)
        return finalize(d, _bitmap.unpack(Yw, f), out, sr.identity)
    Ae = GBMatrix(A.store.materialize_sharded(), name=A.name)
    if A._T is not None and A._T.fmt == "bitshard":
        Ae.link_transpose(GBMatrix(A._T.store.materialize_sharded(),
                                   name=A._T.name))
    return _mxm_sharded(Ae, B, sr, d, out)


def mxm(A, B, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None):
    """C<M> accum= A (x) B over a semiring — the uniform GraphBLAS call.

    A: GBMatrix (or raw BSR/ELL/dense, wrapped on the fly). B: either a
    dense (m, f) frontier matrix (returns a dense C) or a *sparse* GBMatrix
    (BSR x BSR routes through the SpGEMM kernel and returns a BSR-backed
    GBMatrix — see docs/API.md §SpGEMM for the dispatch rule). ``out`` is
    the existing C for accum/blend; None means replace-into-empty.
    """
    A = GBMatrix.wrap(A)
    if A.fmt == "sharded":
        return _mxm_sharded(A, B, sr, d, out)
    if A.fmt == "bitshard":
        return _mxm_bitshard(A, B, sr, d, out)
    if isinstance(B, (ShardedELL, ShardedBitELL)) or (
            isinstance(B, GBMatrix) and B.fmt in ("sharded", "bitshard")):
        raise TypeError(
            "grb.mxm: B is sharded but A is not — operand kinds must match. "
            "Distribute A onto the same mesh (grb.distribute(A, mesh)) or "
            "gather B explicitly (B.to_dense()).")
    if d.transpose_a:
        A = A.T
        d = d.with_(transpose_a=False)
    # delta operands against a *sparse* partner (the SpGEMM route and its
    # BSR result type) compose via the cached materialize fallback; against
    # a dense frontier, A stays delta and takes the row-patch route below
    if isinstance(B, (GBMatrix, BSR, ELL)) and A.fmt == "delta":
        A = GBMatrix(A.store.materialize(), impl="auto" if A.auto else A.impl,
                     name=A.name)
    if isinstance(B, GBMatrix) and B.fmt == "delta":
        B = GBMatrix(B.store.materialize(), name=B.name)
    if (isinstance(B, GBMatrix) and A.fmt == "bsr" and B.fmt == "bsr"
            and out is None and sr.mode in _SPGEMM_MODES):
        return _mxm_spgemm(A, B, sr, d)
    if isinstance(B, GBMatrix):
        B = B.to_dense()
    if isinstance(d.mask, (GBMatrix, BSR, ELL, ShardedELL, BitELL,
                           ShardedBitELL, DeltaMatrix)):
        m = _mask_storage(d.mask)
        d = d.with_(mask=m if isinstance(m, jnp.ndarray) else m.to_dense())
    if A.fmt == "delta":
        return _mxm_delta(A, jnp.asarray(B), sr, d, out)
    if A.fmt == "bitadj" and not _packed_route_ok(A, B, sr):
        # weighted / non-indicator call on structural storage: the cached
        # materialize-to-ELL fallback (mirrors the DeltaMatrix contract)
        A = GBMatrix(A.store.to_ell(), impl="auto" if A.auto else A.impl,
                     name=A.name)
    if _packed_route_ok(A, B, sr):
        return _mxm_packed(A, jnp.asarray(B), sr, d, out)
    fuse = d.mask is not None and out is None and d.mask_only
    y, mask_done = _dispatch_mxm(A, B, sr, d, fuse)
    if mask_done:
        return y
    return finalize(d, y, out, sr.identity)


def host_transfers() -> int:
    """Device->host gathers inside op dispatch since process start — the
    transfer-accounting sibling of ``densify_calls()`` / ``pack_calls()``
    (core.xfer). Sharded gathers (``ShardedELL.to_ell`` and everything that
    routes through it) and BSR host materializations bump it; materializing
    a final algorithm *result* does not. "Zero host transfers in any
    sharded hot loop" is pinned as a delta of this counter plus the
    structural HLO scan in ``distr.graph2d.scan_host_transfers``."""
    return _xfer.host_transfers()


def mxm_words(A, Bw: Array, transpose_a: bool = False) -> Array:
    """or_and mxm with the frontier already bitmap-packed: (k, W) uint32
    words in, (rows, W) words out — the packed-in/packed-out entry that
    word-resident hop loops (BFS / k-hop / WCC / executor sweeps) thread
    through a ``while_loop`` carry, so nothing packs, unpacks, or gathers
    at the per-hop call boundary.

    No descriptor: the or_and identity is 0, so callers blend masks
    word-wise themselves (``bitmap.word_and`` / ``word_andnot`` — exactly
    what the visited-complement mask of a traversal is). Dense, ELL, and
    sharded operands lower natively packed; BSR/delta operands have no
    packed route (their or_and path is the MXU indicator matmul) and
    detour through the float mxm *on device*, re-packing the result —
    gate callers with :func:`words_route_ok` to avoid that.
    """
    A = GBMatrix.wrap(A)
    if A.fmt == "sharded":
        transposed = False
        if transpose_a:
            if A._T is not None:
                A = A.T
            else:
                transposed = True
        return _shard.mxm_words(A.store, Bw, transposed=transposed)
    if A.fmt == "bitshard":
        if transpose_a:
            if A._T is None or A._T.fmt != "bitshard":
                raise RuntimeError(
                    "grb.mxm_words: transpose_a on bit-sharded storage "
                    "needs the linked twin grb.distribute builds")
            A = A.T
        return _bitadj.sharded_mxm_words(A.store, Bw)
    if transpose_a:
        A = A.T
    if A.fmt == "bitadj":
        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops   # lazy: kernels import core
            return kops.bitadj_mxv_packed(A.store, Bw)
        return _bitadj.mxm_words(A.store, Bw)
    if A.fmt == "ell":
        if jax.default_backend() == "tpu":
            from repro.kernels import ops as kops   # lazy: kernels import core
            return kops.ell_mxv_packed(A.store, Bw)
        return _ops.ell_mxm_packed(A.store, Bw)
    if A.fmt == "dense":
        return _ops.dense_mxm_packed(A.store, Bw)
    f = Bw.shape[1] * _bitmap.WORD_BITS
    y = mxm(A, _bitmap.unpack(Bw, f), S.OR_AND)
    if isinstance(y, GBMatrix):
        y = y.to_dense()
    return _bitmap.pack(y)


def words_route_ok(A, f: int) -> bool:
    """Trace-time gate for word-resident hop loops: True when
    :func:`mxm_words` lowers natively packed for this operand (dense / ELL /
    sharded storage) and the packing policy wants a width-``f`` frontier
    packed (``packed_frontiers`` / AUTO_PACK_MIN_WIDTH). BitELL /
    ShardedBitELL pass unconditionally — the adjacency side is packed
    whatever the frontier width. BSR and delta operands keep the float
    hop loop."""
    A = GBMatrix.wrap(A)
    if A.fmt in ("bitadj", "bitshard"):
        return True      # adjacency itself is packed: words always win
    return A.fmt in ("dense", "ell", "sharded") and _pack_wanted(f)


def _columnize(v) -> Optional[Array]:
    # sparse GBMatrix/BSR masks have no ndim and pass through to mxm's
    # mask conversion untouched; (n,) vectors become width-1 columns
    if v is not None and getattr(v, "ndim", None) == 1:
        return v[:, None]
    return v


def mxv(A, x: Array, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None) -> Array:
    """y<m> accum= A (x) x — a width-1 frontier."""
    dm = d.with_(mask=_columnize(d.mask))
    y = mxm(A, x[:, None], sr, dm, out=_columnize(out))
    return y[:, 0]


def vxm(x: Array, A, sr: S.Semiring, d: Descriptor = NULL,
        out: Optional[Array] = None) -> Array:
    """y = x (x) A == A^T (x) x, served from the handle's cached transpose."""
    return mxv(A, x, sr, d.with_(transpose_a=not d.transpose_a), out=out)


# ---------------------------------------------------------------------------
# element-wise family — GrB_eWiseAdd / eWiseMult / apply / select
# ---------------------------------------------------------------------------
# Structural convention (repo-wide): an entry is stored iff nonzero; an
# absent entry renders as 0 when a sparse result is densified. The whole
# family therefore uses GraphBLAS *entry* semantics uniformly across dense /
# BSR / ELL operands:
#
#   ewise_add   pattern = union;        op(a, b) where both stored, the
#               stored value where only one side is (absent never fed to op)
#   ewise_mult  pattern = intersection; op(a, b) on the intersection
#   apply       pattern = stored(x);    f applied to stored entries only
#   select      stored entries passing pred, zero-blocks pruned
#
# and the descriptor blend writes *empty* (renders 0) outside the mask —
# not the monoid identity — with accum merging by union. Sparse operands
# stay sparse end-to-end (block-aligned ops in core.bsr, COO set algebra in
# core.coo for ELL); mixing a sparse operand with a dense array raises a
# TypeError naming the expected kinds rather than densifying silently.

def _operand_kind(x):
    """('bsr'|'ell'|'sharded'|'dense', storage) of a handle / store / array.
    Delta operands compose into their base format here (cached materialize,
    docs/API.md §Delta) — the whole element-wise / assign / extract family
    sees exact post-mutation entries without per-op special cases."""
    if isinstance(x, GBMatrix):
        x = x.store
    if isinstance(x, DeltaMatrix):
        x = x.materialize()
    if isinstance(x, BitELL):
        x = x.to_ell()        # cached structural materialization (§BitAdj)
    if isinstance(x, ShardedBitELL):
        return "sharded", x.materialize_sharded()
    if isinstance(x, BSR):
        return "bsr", x
    if isinstance(x, ELL):
        return "ell", x
    if isinstance(x, ShardedELL):
        return "sharded", x
    return "dense", jnp.asarray(x)


def _unshard(x):
    """Gather-to-host view of a sharded operand (ELL, handle-ness kept);
    non-sharded operands pass through."""
    if x is None:
        return None
    kind, s = _operand_kind(x)
    if kind != "sharded":
        return x
    e = s.to_ell()
    return GBMatrix(e, name=x.name) if isinstance(x, GBMatrix) else e


def _sharded_pair_mesh(fn: str, a, b, out=None):
    """Pairing contract for ops with a gather-to-host mesh path: both main
    operands sharded on one mesh (out sharded or None) -> that mesh; no
    sharded operand -> None; anything mixed -> TypeError naming the kinds."""
    kinds = [_operand_kind(x) for x in (a, b) if x is not None]
    shd = [s for k, s in kinds if k == "sharded"]
    ko, so = _operand_kind(out) if out is not None else (None, None)
    if not shd:
        if ko == "sharded":
            raise TypeError(
                f"grb.{fn}: out= is sharded but the operands are not — "
                f"operand kinds must match; distribute the operands "
                f"(grb.distribute) or gather out (out.to_ell())")
        return None
    if len(shd) != len(kinds):
        got = " and ".join(k for k, _ in kinds)
        raise TypeError(
            f"grb.{fn}: operand kinds must match — a sharded matrix pairs "
            f"only with another sharded matrix on the same mesh; got {got}. "
            f"Distribute the unsharded side (grb.distribute(x, mesh)) or "
            f"gather the sharded one (x.to_ell() / x.to_dense()).")
    mesh = shd[0].mesh
    for s in shd[1:]:
        if s.mesh != mesh:
            raise TypeError(f"grb.{fn}: sharded operands live on different "
                            f"meshes — distribute both onto one mesh")
    if ko == "sharded" and so.mesh != mesh:
        raise TypeError(f"grb.{fn}: out= lives on a different mesh than the "
                        f"operands — distribute all three onto one mesh")
    return mesh


# stable-identity ops for the shard-local merge (graph2d.ewise_2d lru-caches
# its shard_map per (mesh, mode, op) — module-level callables keep it warm)
def _take_second(a, b):           # mask restricts never consult the op
    del a
    return b


def _disjoint_concat(a, b):       # unions of provably disjoint patterns
    return a + b


def _sharded_restrict(res: ShardedELL, mask, complement: bool) -> ShardedELL:
    """Mask restrict on a sharded result, shard-local whenever possible:
    a same-mesh sharded mask merges through the slot-aligned pass; any
    dense/host-sparse mask takes the per-slot dense gather. Only a mask
    sharded on a *different* mesh still gathers (counted via to_ell)."""
    m = mask.store if isinstance(mask, GBMatrix) else mask
    if isinstance(m, ShardedELL) and m.mesh == res.mesh:
        if m.shape != res.shape:
            raise ValueError(f"descriptor mask shape {tuple(m.shape)} != "
                             f"result {tuple(res.shape)}")
        return _shard.merge_stored(res, m, _take_second,
                                   "mask_c" if complement else "mask")
    md = _mask_storage(mask)
    dense = md if isinstance(md, (jnp.ndarray, np.ndarray)) else md.to_dense()
    if tuple(dense.shape) != tuple(res.shape):
        raise ValueError(f"descriptor mask shape {tuple(dense.shape)} != "
                         f"result {tuple(res.shape)}")
    return _shard.restrict_dense(res, dense, complement)


def _sharded_blend(d: Descriptor, res: ShardedELL,
                   out: Optional[ShardedELL]) -> ShardedELL:
    """The structural blend rule (union-accum, empty outside the mask) on
    ShardedELL storage — the mesh-resident sibling of
    _structural_finalize_bsr, composed entirely from shard-local merges."""
    if d.accum is not None and out is not None:
        res = _shard.merge_stored(out, res, d.accum.op, "union")
    if d.mask is None:
        return res
    z_in = _sharded_restrict(res, d.mask, d.complement)
    if out is None or d.replace:
        return z_in
    old = _sharded_restrict(out, d.mask, not d.complement)
    return _shard.merge_stored(z_in, old, _disjoint_concat, "union")


def _sharded_out(out, fn: str, mesh, shape) -> Optional[ShardedELL]:
    """Coerce an out= operand for the shard-local blend. A same-mesh sharded
    out passes through; host-sparse outs re-home onto the mesh (a host->
    device put, not a gather); dense outs raise the family's TypeError."""
    if out is None:
        return None
    kind, store = _operand_kind(out)
    if kind == "dense":
        raise TypeError(f"grb.{fn}: sparse operands need a sparse out= "
                        f"(GBMatrix/BSR/ELL) or None (got a dense array); "
                        f"wrap it with GBMatrix.from_dense(out, fmt='ell')")
    if tuple(store.shape) != tuple(shape):
        raise ValueError(f"grb.{fn}: out shape {store.shape} != result "
                         f"{shape}")
    if kind == "sharded":
        return store                      # same mesh: _sharded_pair_mesh ran
    if kind == "bsr":
        store = ELL.from_coo(*store.to_coo(), store.shape)
    return ShardedELL.from_ell(store, mesh)


def _ewise_pair(a, b, fn: str):
    """Classify an operand pair into one execution path, coercing only in
    sparse-to-sparse directions (ELL joins a BSR partner via COO, never
    through a dense intermediate)."""
    ka, sa = _operand_kind(a)
    kb, sb = _operand_kind(b)
    if (ka == "dense") != (kb == "dense"):
        raise TypeError(
            f"grb.{fn}: operand kinds must match — both dense arrays or both "
            f"sparse matrices (GBMatrix/BSR/ELL); got {ka} and {kb}. Convert "
            f"explicitly: GBMatrix.from_dense(x, fmt=...) for the dense side "
            f"or x.to_dense() for the sparse side.")
    if sa.shape != sb.shape:
        raise ValueError(f"grb.{fn} shapes: {sa.shape} vs {sb.shape}")
    if ka == "dense":
        return "dense", sa, sb
    if "bsr" in (ka, kb):
        if isinstance(sa, ELL):
            sa = _bsr.as_bsr(sa, sb.block)
        if isinstance(sb, ELL):
            sb = _bsr.as_bsr(sb, sa.block)
        return "bsr", sa, sb
    return "ell", sa, sb


def _dense_out(out, fn: str) -> Optional[Array]:
    if out is None:
        return None
    kind, store = _operand_kind(out)
    if kind != "dense":
        raise TypeError(f"grb.{fn}: dense operands need a dense out= array "
                        f"(got a sparse {kind} matrix); densify it "
                        f"explicitly with out.to_dense() if intended")
    return store


def _sparse_out_bsr(out, fn: str, block: int) -> Optional[BSR]:
    if out is None:
        return None
    kind, store = _operand_kind(out)
    if kind == "dense":
        raise TypeError(f"grb.{fn}: sparse operands need a sparse out= "
                        f"(GBMatrix/BSR/ELL) or None (got a dense array); "
                        f"wrap it with GBMatrix.from_dense(out, fmt='bsr')")
    return _bsr.as_bsr(store, block)


def _sparse_out_entries(out, fn: str, shape=None):
    """(keys, vals) of a sparse out= operand for the COO blend."""
    if out is None:
        return None, None
    kind, store = _operand_kind(out)
    if kind == "dense":
        raise TypeError(f"grb.{fn}: sparse operands need a sparse out= "
                        f"(GBMatrix/BSR/ELL) or None (got a dense array); "
                        f"wrap it with GBMatrix.from_dense(out, fmt='ell')")
    if shape is not None and store.shape != shape:
        raise ValueError(f"grb.{fn}: out shape {store.shape} != result "
                         f"{shape}")
    r, c, v = store.to_coo()
    return _coo.keys_of(r, c, max(store.shape[1], 1)), \
        np.asarray(v, np.float32)


def _wrap_sparse(store: Storage, *operands) -> "GBMatrix":
    """Wrap a sparse result, inheriting the first handle operand's policy.
    An auto policy stays auto so the crossover heuristics re-resolve against
    the *result's* store (a select can change the grid/fill drastically)."""
    for o in operands:
        if isinstance(o, GBMatrix):
            return GBMatrix(store, impl="auto" if o.auto else o.impl)
    return GBMatrix(store)


def _mask_entry_keys(mask, shape) -> np.ndarray:
    """Stored-entry key set of a descriptor mask (dense or sparse), checked
    against the result shape (a mis-shaped mask must error, not corrupt)."""
    m = _mask_storage(mask)
    if tuple(m.shape) != tuple(shape):
        raise ValueError(f"descriptor mask shape {tuple(m.shape)} != "
                         f"result {tuple(shape)}")
    ncols = max(shape[1], 1)
    if isinstance(m, (BSR, ELL)):
        r, c, _ = m.to_coo()
        return _coo.keys_of(r, c, ncols)
    r, c = np.nonzero(np.asarray(m))
    return _coo.keys_of(r, c, ncols)


def _dense_union(a: Array, b: Array, op) -> Array:
    both = (a != 0) & (b != 0)
    # a + b is exactly "the stored value" where only one side stores one
    return jnp.where(both, op(a, b), a + b)


def _structural_finalize_dense(d: Descriptor, result: Array,
                               out: Optional[Array]) -> Array:
    """The blend rule with entry semantics on dense storage: union-accum,
    and *empty* (0) — not a monoid identity — outside the mask."""
    if d.accum is not None and out is not None:
        z = _dense_union(out, result, d.accum.op)
    else:
        z = result
    mask = d.mask
    if mask is None:
        return z
    m = _mask_storage(mask)
    mask = m.to_dense() if isinstance(m, (BSR, ELL)) else jnp.asarray(m)
    keep = (mask == 0) if d.complement else (mask != 0)
    outside = jnp.zeros_like(z) if (out is None or d.replace) else out
    return jnp.where(keep, z, outside)


def _structural_finalize_bsr(d: Descriptor, res: BSR,
                             out: Optional[BSR]) -> BSR:
    """The same blend rule out of block-aligned sparse primitives — the
    result pattern never leaves tile-list land."""
    if d.accum is not None and out is not None:
        res = _bsr.ewise_add(out, res, d.accum.op)
    if d.mask is None:
        return res
    M = _mask_as_bsr(d.mask, res.block)
    z_in = _bsr.mask_keep(res, M, complement=d.complement)
    if out is None or d.replace:
        return z_in
    old = _bsr.mask_keep(out, M, complement=not d.complement)
    return _bsr.ewise_add(z_in, old, lambda x, y: x + y)   # disjoint patterns


def _structural_finalize_ell(d: Descriptor, keys, vals, out, fn: str,
                             shape) -> ELL:
    """The blend rule on COO entry sets, rebuilt into ELL at the end."""
    w = max(shape[1], 1)                 # zero-width region: no entries
    kc, vc = _sparse_out_entries(out, fn, shape)
    mk = None if d.mask is None else _mask_entry_keys(d.mask, shape)
    accum_op = None if d.accum is None else d.accum.op
    k, v = _coo.blend(keys, vals, kc, vc, mk, d.complement, accum_op,
                      d.replace)
    return ELL.from_entries(*_coo.nonzero(k, v), shape)


def _ell_entries(e) -> tuple:
    r, c, v = e.to_coo()
    return _coo.keys_of(r, c, e.shape[1]), np.asarray(v, np.float32)


def ewise_add(a, b, monoid: S.Monoid, d: Descriptor = NULL, out=None):
    """C<M> accum= A (+) B — GrB_eWiseAdd, union semantics (see above).

    Both operands dense arrays -> dense array; both sparse -> a sparse
    GBMatrix (BSR when either side is BSR, else ELL). Mixed kinds raise
    TypeError. ``monoid`` may be a Monoid or a raw binary callable.
    """
    mesh = _sharded_pair_mesh("ewise_add", a, b, out)
    if mesh is not None:                 # mesh-resident slot-aligned merge
        op = getattr(monoid, "op", monoid)
        A, B = _operand_kind(a)[1], _operand_kind(b)[1]
        if A.shape != B.shape:
            raise ValueError(f"grb.ewise_add shapes: {A.shape} vs {B.shape}")
        res = _shard.merge_stored(A, B, op, "union")
        C = _sharded_out(out, "ewise_add", mesh, A.shape)
        return _wrap_sparse(_sharded_blend(d, res, C), a, b, out)
    op = getattr(monoid, "op", monoid)
    kind, A, B = _ewise_pair(a, b, "ewise_add")
    if kind == "dense":
        return _structural_finalize_dense(
            d, _dense_union(A, B, op), _dense_out(out, "ewise_add"))
    if kind == "bsr":
        res = _bsr.ewise_add(A, B, op)
        C = _sparse_out_bsr(out, "ewise_add", A.block)
        return _wrap_sparse(_structural_finalize_bsr(d, res, C), a, b, out)
    k, v = _coo.nonzero(*_coo.union(*_ell_entries(A), *_ell_entries(B), op))
    return _wrap_sparse(
        _structural_finalize_ell(d, k, v, out, "ewise_add", A.shape),
        a, b, out)


def ewise_mult(a, b, op: Callable[[Array, Array], Array],
               d: Descriptor = NULL, out=None):
    """C<M> accum= A (.*) B — GrB_eWiseMult, intersection semantics.

    Same dispatch contract as :func:`ewise_add`; on BSR operands only tiles
    valid in both patterns are gathered (structural pruning before any
    element work). ``op`` may be a Monoid or a raw binary callable.
    """
    mesh = _sharded_pair_mesh("ewise_mult", a, b, out)
    if mesh is not None:                 # mesh-resident slot-aligned merge
        op2 = getattr(op, "op", op)
        A, B = _operand_kind(a)[1], _operand_kind(b)[1]
        if A.shape != B.shape:
            raise ValueError(f"grb.ewise_mult shapes: {A.shape} vs {B.shape}")
        res = _shard.merge_stored(A, B, op2, "intersect")
        C = _sharded_out(out, "ewise_mult", mesh, A.shape)
        return _wrap_sparse(_sharded_blend(d, res, C), a, b, out)
    op = getattr(op, "op", op)
    kind, A, B = _ewise_pair(a, b, "ewise_mult")
    if kind == "dense":
        both = (A != 0) & (B != 0)
        raw = jnp.where(both, op(A, B), jnp.zeros_like(A))
        return _structural_finalize_dense(d, raw, _dense_out(out, "ewise_mult"))
    if kind == "bsr":
        res = _bsr.ewise_mult(A, B, op)
        C = _sparse_out_bsr(out, "ewise_mult", A.block)
        return _wrap_sparse(_structural_finalize_bsr(d, res, C), a, b, out)
    k, v = _coo.nonzero(*_coo.intersect(*_ell_entries(A), *_ell_entries(B),
                                        op))
    return _wrap_sparse(
        _structural_finalize_ell(d, k, v, out, "ewise_mult", A.shape),
        a, b, out)


def apply(f: Callable[[Array], Array], x, d: Descriptor = NULL, out=None):
    """C<M> accum= f(A) — GrB_apply over *stored* entries only.

    Zero entries of a dense operand (and zero lanes inside stored BSR
    tiles) are absent and stay zero regardless of f(0). On a sharded
    operand every call is mesh-resident: the value map runs on each row
    shard in place, and descriptor blends compose shard-local merges
    (docs/API.md §Sharded).
    """
    _sharded_pair_mesh("apply", x, None, out)       # mixed-out contract
    kind, X = _operand_kind(x)
    if kind == "sharded":
        res = X.apply_stored(f)
        C = _sharded_out(out, "apply", X.mesh, X.shape)
        return _wrap_sparse(_sharded_blend(d, res, C), x, out)
    if kind == "dense":
        raw = jnp.where(X != 0, f(X), jnp.zeros_like(X))
        return _structural_finalize_dense(d, raw, _dense_out(out, "apply"))
    if kind == "bsr":
        res = _bsr.apply_stored(X, f)
        C = _sparse_out_bsr(out, "apply", X.block)
        return _wrap_sparse(_structural_finalize_bsr(d, res, C), x, out)
    k, v = _ell_entries(X)
    k, v = _coo.nonzero(k, np.asarray(f(v), dtype=np.float32))
    return _wrap_sparse(
        _structural_finalize_ell(d, k, v, out, "apply", X.shape), x, out)


def select(pred: Callable[[Array], Array], x, d: Descriptor = NULL,
           out=None):
    """C<M> accum= A where pred(A) — GxB_select over stored entries.

    Same signature and descriptor semantics as :func:`apply` (the mask /
    accum / out path goes through the same finalize); sparse results prune
    tiles the predicate emptied, so nvals/fill_ratio stay truthful. Sharded
    dispatch mirrors :func:`apply`: shard-local mask surgery, with
    descriptor blends composed from shard-local merges.
    """
    _sharded_pair_mesh("select", x, None, out)      # mixed-out contract
    kind, X = _operand_kind(x)
    if kind == "sharded":
        res = X.select_stored(pred)
        C = _sharded_out(out, "select", X.mesh, X.shape)
        return _wrap_sparse(_sharded_blend(d, res, C), x, out)
    if kind == "dense":
        raw = jnp.where((X != 0) & pred(X), X, jnp.zeros_like(X))
        return _structural_finalize_dense(d, raw, _dense_out(out, "select"))
    if kind == "bsr":
        res = _bsr.select_stored(X, pred)
        C = _sparse_out_bsr(out, "select", X.block)
        return _wrap_sparse(_structural_finalize_bsr(d, res, C), x, out)
    k, v = _ell_entries(X)
    keep = np.asarray(pred(v), dtype=bool)
    return _wrap_sparse(
        _structural_finalize_ell(d, k[keep], v[keep], out, "select",
                                 X.shape), x, out)


# ---------------------------------------------------------------------------
# reduce — GrB_reduce
# ---------------------------------------------------------------------------
def _reduce_bsr(s: BSR, monoid: S.Monoid, axis) -> Array:
    if monoid.name not in ("plus", "or") or axis not in (None, 0, 1):
        # min/max need the absent entries (dense zeros) to participate
        return monoid.reduce(s.to_dense(), axis=axis)
    v = s.blocks.astype(jnp.float32) * s.valid.astype(jnp.float32)[:, None,
                                                                   None]
    if monoid.name == "or":
        # boolean OR == "any stored entry", NOT max (wrong for negatives)
        v = (v != 0).astype(jnp.float32)
    if axis is None:
        tot = jnp.sum(v)
        return (tot > 0).astype(jnp.float32) if monoid.name == "or" else tot
    per = jnp.sum(v, axis=2 if axis == 1 else 1)          # (nnzb, block)
    seg = s.block_rows if axis == 1 else s.block_cols
    nseg = s.nbrows if axis == 1 else s.nbcols
    out = jax.ops.segment_sum(per, seg, num_segments=nseg).reshape(-1)
    out = out[:s.shape[0] if axis == 1 else s.shape[1]]
    return (out > 0).astype(jnp.float32) if monoid.name == "or" else out


def _reduce_ell(e: ELL, monoid: S.Monoid, axis) -> Array:
    if monoid.name not in ("plus", "or") or axis not in (None, 0, 1):
        return monoid.reduce(e.to_dense(), axis=axis)
    w = e.values * e.mask.astype(jnp.float32)
    if monoid.name == "or":
        w = (w != 0).astype(jnp.float32)
    if axis is None:
        tot = jnp.sum(w)
        return (tot > 0).astype(jnp.float32) if monoid.name == "or" else tot
    if axis == 1:
        out = jnp.sum(w, axis=1)
    else:
        m = e.shape[1]
        ids = jnp.where(e.mask, e.indices, m).reshape(-1)
        out = jax.ops.segment_sum(w.reshape(-1), ids,
                                  num_segments=m + 1)[:m]
    return (out > 0).astype(jnp.float32) if monoid.name == "or" else out


def _reduce_delta(h: "GBMatrix", monoid: S.Monoid, axis) -> Array:
    """Delta-composed reduce for the plus/or monoids, zero rebuild: per-row
    (axis=1) uses the same row decomposition as _mxm_delta — untouched rows
    from the base's reduce, delta-touched rows from the patch's; per-column
    (axis=0) is the per-row reduce of the *linked transpose twin* (the graph
    layer maintains twins incrementally); the full reduction folds the
    per-row vector. Anything else — min/max (absent entries participate),
    or axis=0 without a twin — takes the cached materialize fallback."""
    dm: DeltaMatrix = h.store
    if monoid.name in ("plus", "or"):
        if axis == 1:
            rb = reduce(dm.base, monoid, axis=1)
            if monoid.name == "or":
                # "any stored entry" uniformly (a dense base's raw max
                # would leak non-indicator values into the indicator path)
                rb = (rb != 0).astype(jnp.float32)
            n, bn = dm.shape[0], dm.base.shape[0]
            if n > bn:
                rb = jnp.concatenate(
                    [rb, jnp.zeros(n - bn, dtype=rb.dtype)])
            patch, rows = dm.patch()
            if patch is None:
                return rb
            rp = _reduce_ell(patch, monoid, axis=1)
            return rb.at[rows].set(rp, mode="drop")
        if axis == 0 and h._T is not None and h._T.fmt == "delta":
            return _reduce_delta(h._T, monoid, axis=1)
        if axis is None:
            tot = jnp.sum(_reduce_delta(h, monoid, axis=1))
            return (tot > 0).astype(jnp.float32) if monoid.name == "or" \
                else tot
    return reduce(dm.materialize(), monoid, axis=axis)


def reduce(x, monoid: S.Monoid, axis=None) -> Array:
    """Monoid reduction (GrB_reduce). Sparse operands (GBMatrix or raw
    BSR/ELL) reduce over *stored* entries without densifying for the plus
    and or monoids — full reduction, axis=0 (per column) and axis=1 (per
    row); "or" means "any stored entry", correct for negative values. Other
    monoids need the absent entries (dense zeros) and fall back through
    to_dense(). Sharded operands reduce on the mesh for plus/or (per-row
    sums shard-local, full/per-column sums psum partials over "data") *and*
    for min/max (stored-entry pmin/pmax + a stored-count compare folds the
    implicit zeros back in — graph2d.reduce_minmax_2d, no gather). Delta
    operands compose (plus/or) with zero rebuild — see _reduce_delta."""
    s = x.store if isinstance(x, GBMatrix) else x
    if isinstance(s, DeltaMatrix):
        h = x if isinstance(x, GBMatrix) else GBMatrix(s)
        return _reduce_delta(h, monoid, axis)
    if isinstance(s, (BitELL, ShardedBitELL)):
        # degree sums / any-stored straight off the bit-tiles (SWAR
        # popcounts, no materialization; sharded arrays reduce under GSPMD)
        if monoid.name in ("plus", "or") and axis in (None, 0, 1):
            return _bitadj.reduce_stored(s, monoid, axis)
        x = GBMatrix(s.to_ell()) if isinstance(s, BitELL) else x
    kind, X = _operand_kind(x)
    if kind == "bsr":
        return _reduce_bsr(X, monoid, axis)
    if kind == "ell":
        return _reduce_ell(X, monoid, axis)
    if kind == "sharded":
        if monoid.name in ("plus", "or") and axis in (None, 0, 1):
            return _shard.reduce_stored(X, monoid, axis)
        if monoid.name in ("min", "max") and axis in (None, 0, 1):
            return _shard.reduce_minmax(X, monoid, axis)
        return monoid.reduce(X.to_dense(), axis=axis)   # counted gather
    return monoid.reduce(X, axis=axis)


# ---------------------------------------------------------------------------
# assign / extract — GrB_assign / GrB_extract analogs
# ---------------------------------------------------------------------------
def _norm_index(idx, n: int, fn: str) -> np.ndarray:
    """Normalize a rows=/cols= argument to a unique int64 index vector."""
    if idx is None:
        return np.arange(n, dtype=np.int64)
    if isinstance(idx, slice):
        idx = range(*idx.indices(n))
    idx = np.asarray(idx, dtype=np.int64)
    if idx.ndim != 1:
        raise TypeError(f"grb.{fn}: indices must be 1-D (got ndim={idx.ndim})")
    if len(idx) and (idx.min() < 0 or idx.max() >= n):
        raise ValueError(f"grb.{fn}: index out of range for extent {n}")
    if len(np.unique(idx)) != len(idx):
        raise ValueError(f"grb.{fn}: duplicate indices are not supported")
    return idx


def _is_aligned_range(idx: np.ndarray, block: int) -> bool:
    return (len(idx) > 0 and idx[0] % block == 0
            and bool(np.all(np.diff(idx) == 1)))


def extract(A, rows=None, cols=None, d: Descriptor = NULL, out=None):
    """C<M> accum= A[rows, cols] — the GrB_extract analog.

    rows/cols: None (all), a slice/range, or a unique index vector. Dense
    operands return dense arrays; sparse operands stay sparse (BSR uses
    pure tile-list surgery when the ranges are contiguous and block-aligned,
    COO relabeling otherwise) and return a GBMatrix. The descriptor applies
    to the extracted (len(rows), len(cols)) result. Sharded operands stay
    mesh-resident for column subsets (rows=None — a shard-local LUT
    relabel); row subsets re-partition the "data" axis and take the counted
    gather fallback (docs/API.md §Sharded).
    """
    mesh = _sharded_pair_mesh("extract", A, None, out)
    if mesh is not None:
        SA = _operand_kind(A)[1]
        n, m = SA.shape
        I = _norm_index(rows, n, "extract")
        J = _norm_index(cols, m, "extract")
        if rows is None or (len(I) == n and np.array_equal(I, np.arange(n))):
            sub = _shard.extract_cols(SA, J)
            C = _sharded_out(out, "extract", mesh, sub.shape)
            return _wrap_sparse(_sharded_blend(d, sub, C), A, out)
        return distribute(extract(_unshard(A), rows, cols, d, _unshard(out)),
                          mesh)
    kind, SA = _operand_kind(A)
    n, m = SA.shape
    I = _norm_index(rows, n, "extract")
    J = _norm_index(cols, m, "extract")
    if kind == "dense":
        raw = SA[jnp.asarray(I)][:, jnp.asarray(J)]
        return _structural_finalize_dense(d, raw, _dense_out(out, "extract"))
    if kind == "bsr":
        if _is_aligned_range(I, SA.block) and _is_aligned_range(J, SA.block):
            sub = _bsr.extract_ranges(SA, int(I[0]), int(I[-1]) + 1,
                                      int(J[0]), int(J[-1]) + 1)
        else:
            r, c, v = SA.to_coo()
            rr, cc, vv = _coo.extract_entries(r, c, v, I, J, n, m)
            sub = BSR.from_coo(rr, cc, vv, (len(I), len(J)), block=SA.block)
        C = _sparse_out_bsr(out, "extract", sub.block)
        return _wrap_sparse(_structural_finalize_bsr(d, sub, C), A, out)
    r, c, v = SA.to_coo()
    rr, cc, vv = _coo.extract_entries(r, c, v, I, J, n, m)
    k = _coo.keys_of(rr, cc, max(len(J), 1))
    return _wrap_sparse(
        _structural_finalize_ell(d, k, vv, out, "extract",
                                 (len(I), len(J))), A, out)


def _assign_sharded_cols(C, sc: ShardedELL, A, J: np.ndarray,
                         d: Descriptor):
    """C(:, J)<M> accum= A with C sharded — fully mesh-resident: the region
    (all rows x J) splits from the rest of C by shard-local column LUTs, the
    blend runs on the (n, len(J)) region in local coordinates, and the
    result relabels back into global columns and unions with the untouched
    entries (disjoint patterns, so the merge never consults the op)."""
    n, m = sc.shape
    ka, sa = _operand_kind(A)
    if sa.shape != (n, len(J)):
        raise ValueError(f"grb.assign: A shape {sa.shape} != region "
                         f"{(n, len(J))}")
    if len(J) == 0:
        return C if isinstance(C, GBMatrix) else sc
    if ka == "sharded":
        if sa.mesh != sc.mesh:
            raise TypeError("grb.assign: sharded operands live on different "
                            "meshes — distribute both onto one mesh")
    else:
        # re-home the region operand onto C's mesh (host->device put)
        if ka == "dense":
            e = ELL.from_dense(np.asarray(sa))
        elif isinstance(sa, ELL):
            e = sa
        else:
            e = ELL.from_coo(*sa.to_coo(), sa.shape)
        sa = ShardedELL.from_ell(e, sc.mesh)
    lut_out = np.arange(m, dtype=np.int32)
    lut_out[J] = -1
    c_out = _shard.relabel_cols(sc, lut_out, m)     # entries outside region
    c_in = _shard.extract_cols(sc, J)               # region, local coords
    blended = _sharded_blend(d, sa, c_in)
    back = _shard.relabel_cols(blended, np.asarray(J, np.int32), m)
    res = _shard.merge_stored(c_out, back, _disjoint_concat, "union")
    return _wrap_sparse(res, C)


def assign(C, A, rows=None, cols=None, d: Descriptor = NULL):
    """C(rows, cols)<M> accum= A — the GrB_assign analog (functional: C is
    not mutated; a new handle/array of C's kind is returned).

    A must be (len(rows), len(cols)); the descriptor mask has that shape
    too (the mask-on-submatrix GrB_assign variant). Without accum/mask the
    region's pattern is *replaced* by A's (entries of C absent in A are
    deleted). Sparse C stays sparse: entries are re-split by region
    host-side and the blend runs on COO entry sets — no densification.
    Sharded C stays mesh-resident for column regions (rows=None): region
    split, blend, and reassembly are shard-local LUT relabels + merges;
    row subsets re-partition the "data" axis and take the counted gather
    fallback (docs/API.md §Sharded). A may be sharded alongside C (same
    mesh) or host-side (re-homed onto the mesh, a host->device put).
    """
    if "sharded" in (_operand_kind(C)[0], _operand_kind(A)[0]):
        kc, sc = _operand_kind(C)
        if kc != "sharded":
            raise TypeError(
                "grb.assign: A is sharded but C is not — operand kinds must "
                "match; distribute C (grb.distribute) or gather A "
                "(A.to_ell())")
        n, m = sc.shape
        I = _norm_index(rows, n, "assign")
        J = _norm_index(cols, m, "assign")
        if rows is None or (len(I) == n and np.array_equal(I, np.arange(n))):
            return _assign_sharded_cols(C, sc, A, J, d)
        return distribute(assign(_unshard(C), _unshard(A), rows, cols, d),
                          sc.mesh)
    kindC, SC = _operand_kind(C)
    n, m = SC.shape
    I = _norm_index(rows, n, "assign")
    J = _norm_index(cols, m, "assign")
    kindA, SA = _operand_kind(A)
    if SA.shape != (len(I), len(J)):
        raise ValueError(f"grb.assign: A shape {SA.shape} != region "
                         f"{(len(I), len(J))}")
    if len(I) == 0 or len(J) == 0:
        return C if isinstance(C, GBMatrix) else SC
    if kindC == "dense":
        subA = SA if kindA == "dense" else SA.to_dense()
        Ij, Jj = jnp.asarray(I), jnp.asarray(J)
        sub = SC[Ij][:, Jj]
        blended = _structural_finalize_dense(d, subA, sub)
        res = SC.at[Ij[:, None], Jj[None, :]].set(blended)
        return GBMatrix(res) if isinstance(C, GBMatrix) else res
    # sparse C: split stored entries by region membership, blend the local
    # entry set, and reassemble — COO set algebra end to end
    r, c, v = SC.to_coo()
    lutr = np.full(n, -1, dtype=np.int64)
    lutr[I] = np.arange(len(I))
    lutc = np.full(m, -1, dtype=np.int64)
    lutc[J] = np.arange(len(J))
    inreg = (lutr[r] >= 0) & (lutc[c] >= 0)
    w = len(J)
    kc = _coo.keys_of(lutr[r[inreg]], lutc[c[inreg]], w)
    vc = np.asarray(v[inreg], np.float32)
    if kindA == "dense":
        ar, ac = np.nonzero(np.asarray(SA))
        ka = _coo.keys_of(ar, ac, w)
        va = np.asarray(SA)[ar, ac].astype(np.float32)
    else:
        ar, ac, av = SA.to_coo()
        ka = _coo.keys_of(ar, ac, w)
        va = np.asarray(av, np.float32)
    mk = None if d.mask is None else _mask_entry_keys(d.mask,
                                                      (len(I), len(J)))
    accum_op = None if d.accum is None else d.accum.op
    k, val = _coo.blend(ka, va, kc, vc, mk, d.complement, accum_op,
                        d.replace)
    k, val = _coo.nonzero(k, val)
    gr = np.concatenate([r[~inreg], I[k // w]])
    gc = np.concatenate([c[~inreg], J[k % w]])
    gv = np.concatenate([np.asarray(v[~inreg], np.float32), val])
    if kindC == "bsr":
        store: Storage = BSR.from_coo(gr, gc, gv, (n, m), block=SC.block)
    else:
        store = ELL.from_coo(gr, gc, gv, (n, m))
    return _wrap_sparse(store, C)
