"""Neighborhood similarity (Jaccard / cosine / overlap) on the semiring.

Vertex similarity compares out-neighborhoods as sets; every variant is a
normalization of the same common-neighbor count, which is one plus_pair
product — the k-truss composition (`grb.mxm(..., S.PLUS_PAIR, ...)`):

  jaccard(u, v)  = |N(u) & N(v)| / |N(u) | N(v)|
  cosine(u, v)   = |N(u) & N(v)| / sqrt(deg(u) * deg(v))
  overlap(u, v)  = |N(u) & N(v)| / min(deg(u), deg(v))

Two entry points:

  similarity(A, sources, kind)   scores of every vertex against F source
      vertices, dense (n, F). Three mxm calls — the source neighborhoods
      as an or_and frontier, the plus_pair intersection counts, and a
      plus_pair degree reduce — then elementwise normalization. Runs on
      every storage kind including a sharded handle (the mxm's lower to
      mesh collectives; counts are small integers, so the sharded result
      is bit-identical to local). This is what `CALL algo.jaccard(...)`
      batches over.

  similarity_matrix(A, kind)     sparse all-pairs scores on a candidate
      pattern (default: the adjacency — similarity of connected pairs).
      Masked plus_pair SpGEMM for the counts, then a sparse `ewise_mult`
      against a reciprocal-denominator matrix assembled on the same stored
      pattern — the counts never densify (BSR route). Symmetric adjacency
      only (it reuses A for A^T, like k-truss).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import grb, semiring as S
from repro.core.bsr import BSR, as_bsr
from repro.core.grb import Descriptor, GBMatrix
from repro.algorithms.traverse import seeds_to_frontier

KINDS = ("jaccard", "cosine", "overlap")


def _normalize(kind: str, M, deg_rows, deg_cols):
    """Scores from intersection counts M and the two degree vectors;
    entries with no common neighbor are 0 under every kind."""
    if kind == "jaccard":
        denom = deg_rows + deg_cols - M
    elif kind == "cosine":
        denom = jnp.sqrt(deg_rows * deg_cols)
    elif kind == "overlap":
        denom = jnp.minimum(deg_rows, deg_cols)
    else:
        raise ValueError(f"unknown similarity kind {kind!r} "
                         f"(one of {', '.join(KINDS)})")
    # denom >= 1 wherever M > 0 (counts); the where() keeps the M == 0
    # branch away from any 0/0
    return jnp.where(M > 0, M / jnp.where(M > 0, denom, 1.0), 0.0)


def degrees(A, rel=None) -> jnp.ndarray:
    """(n,) stored-entry out-degrees via one plus_pair reduce-by-mxm."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    ones = jnp.ones((n, 1), dtype=jnp.float32)
    return grb.mxm(A, ones, S.PLUS_PAIR)[:, 0]


def similarity(A, sources, kind: str = "jaccard", rel=None) -> jnp.ndarray:
    """(n, F) scores: column j compares every vertex's out-neighborhood
    against that of ``sources[j]``. Entry [v, j] is 0 when the two share
    no neighbor; a vertex paired with itself scores 1 (if it has edges)."""
    if kind not in KINDS:
        raise ValueError(f"unknown similarity kind {kind!r} "
                         f"(one of {', '.join(KINDS)})")
    A = grb.matrix(A, rel)
    n = A.shape[0]
    sources = np.asarray(sources, dtype=np.int64)
    f = len(sources)
    if f == 0 or A.nvals == 0:
        return jnp.zeros((n, f), dtype=jnp.float32)
    E = seeds_to_frontier(sources, n)
    # NB[w, j] = 1 iff (sources[j], w) is a stored edge: the source
    # neighborhoods as indicator columns (A^T against one-hots, or_and)
    NB = grb.mxm(A, E, S.OR_AND, Descriptor(transpose_a=True))
    # M[v, j] = |N(v) & N(sources[j])|: plus_pair counts stored-entry hits
    M = grb.mxm(A, NB, S.PLUS_PAIR)
    deg = degrees(A)
    return _normalize(kind, M, deg[:, None],
                      deg[jnp.asarray(sources)][None, :])


def similarity_matrix(A, kind: str = "jaccard", rel=None,
                      mask=None) -> GBMatrix:
    """Sparse all-pairs similarity on the ``mask`` pattern (default: A's
    own edges). C<mask> = A (x)_plus_pair A is the masked SpGEMM k-truss
    uses; the normalization is a sparse ewise_mult against the reciprocal
    denominators assembled once on C's stored pattern (host-side COO, like
    k-truss's self-loop filter — outside any loop). Needs a symmetric
    adjacency; ELL/BitELL handles are reblocked sparse-to-sparse to BSR."""
    if kind not in KINDS:
        raise ValueError(f"unknown similarity kind {kind!r} "
                         f"(one of {', '.join(KINDS)})")
    A = grb.matrix(A, rel)
    n, m = A.shape
    if n != m:
        raise ValueError(f"similarity_matrix needs a square adjacency, "
                         f"got {A.shape}")
    impl = "auto" if A.auto else A.impl
    if A.fmt in ("bitadj", "bitshard"):
        A = GBMatrix(A.store.to_ell(), impl=impl)
    if A.fmt == "ell":
        A = GBMatrix(as_bsr(A.store, 128), impl=impl)
    deg = np.asarray(degrees(A))
    C = grb.mxm(A, A, S.PLUS_PAIR, Descriptor(mask=mask if mask is not None
                                              else A))
    if A.fmt == "dense":
        D = jnp.asarray(C)
        return GBMatrix(_normalize(kind, D, jnp.asarray(deg)[:, None],
                                   jnp.asarray(deg)[None, :]))
    if not isinstance(C, GBMatrix):
        C = GBMatrix(C)
    r, c, v = C.store.to_coo()
    if kind == "jaccard":
        denom = deg[r] + deg[c] - v
    elif kind == "cosine":
        denom = np.sqrt(deg[r] * deg[c])
    else:
        denom = np.minimum(deg[r], deg[c])
    recip = GBMatrix(BSR.from_coo(r, c,
                                  (1.0 / np.maximum(denom, 1.0)).astype(
                                      np.float32),
                                  C.shape, block=C.store.block), impl=impl)
    return grb.ewise_mult(C, recip, lambda a, b: a * b)
