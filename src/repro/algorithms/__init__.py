from repro.algorithms.traverse import bfs_levels, khop_counts
from repro.algorithms.centrality import (betweenness, brandes_parts,
                                         closeness, closeness_from_levels)
from repro.algorithms.ktruss import ktruss
from repro.algorithms.labelprop import label_propagation
from repro.algorithms.pagerank import pagerank
from repro.algorithms.similarity import similarity, similarity_matrix
from repro.algorithms.sssp import sssp
from repro.algorithms.wcc import wcc
from repro.algorithms.triangles import triangle_count

__all__ = ["bfs_levels", "betweenness", "brandes_parts", "closeness",
           "closeness_from_levels", "khop_counts", "ktruss",
           "label_propagation", "pagerank", "similarity",
           "similarity_matrix", "sssp", "wcc", "triangle_count"]
