from repro.algorithms.traverse import bfs_levels, khop_counts
from repro.algorithms.ktruss import ktruss
from repro.algorithms.pagerank import pagerank
from repro.algorithms.sssp import sssp
from repro.algorithms.wcc import wcc
from repro.algorithms.triangles import triangle_count

__all__ = ["bfs_levels", "khop_counts", "ktruss", "pagerank", "sssp", "wcc",
           "triangle_count"]
