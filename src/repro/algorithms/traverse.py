"""BFS / k-hop over the boolean semiring — the paper's benchmark workload.

`MATCH (a)-[:R*1..k]->(b) WHERE id(a)=seed RETURN count(DISTINCT b)` lowers to
exactly `khop_counts`: k masked or_and hops with a complemented visited mask,
batched over seeds in the frontier's F dimension (the threadpool analog:
one column == one concurrent query).

Every entry point takes the graph's adjacency (a Graph, Relation, GBMatrix, or
raw storage) and pulls along out-edges through the handle's cached transpose
(`desc.transpose_a`) — callers never hand-pass `A_T`, and the execution policy
is whatever the handle resolved at construction. That includes a mesh: hand
in a sharded handle (`grb.distribute(rel.A, mesh)`) and the same loop runs
distributed — each hop's mxm lowers to one frontier all-gather plus local
gather-reduce (distr.graph2d), with zero sharding arguments here.

Frontiers wider than `grb.AUTO_PACK_MIN_WIDTH` ride the bitmap-packed
boolean form automatically (or_and is this module's only semiring): each
hop packs the frontier into uint32 words, ORs neighbor words, blends the
complemented visited mask word-wise, and unpacks — bit-identical results,
32x less frontier traffic, and on a mesh a 32x smaller per-hop all-gather
(core.bitmap, docs/API.md §Bitmap). Nothing here opts in; the loops below
are written against plain 0/1 float frontiers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grb, semiring as S
from repro.core.grb import Descriptor


def seeds_to_frontier(seeds, n: int) -> jnp.ndarray:
    """(F,) seed vertex ids -> one-hot (n, F) frontier matrix."""
    seeds = jnp.asarray(seeds)
    return (jax.nn.one_hot(seeds, n, dtype=jnp.float32)).T


def bfs_step(A, frontier: jnp.ndarray, visited: jnp.ndarray) -> jnp.ndarray:
    """next<!visited> = A^T (x)_or_and frontier  — one traversal hop."""
    d = Descriptor(mask=visited, complement=True, transpose_a=True)
    return grb.mxm(A, frontier, S.OR_AND, d)


def bfs_levels(A, seeds, max_iter: int = 0, rel=None):
    """Levels (n, F): hop distance from each seed column; +inf if unreached."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    iters = max_iter or n
    frontier = seeds_to_frontier(seeds, n)
    levels = jnp.where(frontier > 0, 0.0, jnp.inf).astype(jnp.float32)

    def cond(state):
        t, frontier, _ = state
        return jnp.logical_and(t < iters, jnp.any(frontier > 0))

    def body(state):
        t, frontier, levels = state
        visited = jnp.isfinite(levels).astype(jnp.float32)
        nxt = bfs_step(A, frontier, visited)
        levels = jnp.where(nxt > 0, t + 1.0, levels)
        return t + 1.0, nxt, levels

    _, _, levels = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), frontier, levels))
    return levels


def khop_counts(A, seeds, k: int, rel=None) -> jnp.ndarray:
    """TigerGraph k-hop benchmark semantics: |{v : 1 <= dist(seed, v) <= k}|."""
    levels = bfs_levels(A, seeds, max_iter=k, rel=rel)
    inrange = jnp.logical_and(levels >= 1.0, levels <= float(k))
    return jnp.sum(inrange.astype(jnp.int32), axis=0)
