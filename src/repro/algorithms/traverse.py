"""BFS / k-hop over the boolean semiring — the paper's benchmark workload.

`MATCH (a)-[:R*1..k]->(b) WHERE id(a)=seed RETURN count(DISTINCT b)` lowers to
exactly `khop_counts`: k masked or_and vxm steps with a complemented visited
mask, batched over seeds in the frontier's F dimension (the threadpool analog:
one column == one concurrent query).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops, semiring as S


def seeds_to_frontier(seeds, n: int) -> jnp.ndarray:
    """(F,) seed vertex ids -> one-hot (n, F) frontier matrix."""
    seeds = jnp.asarray(seeds)
    return (jax.nn.one_hot(seeds, n, dtype=jnp.float32)).T


def bfs_step(A_T, frontier: jnp.ndarray, visited: jnp.ndarray,
             impl: str = "auto") -> jnp.ndarray:
    """next<!visited> = A^T (x)_or_and frontier  — one traversal hop."""
    return ops.mxm(A_T, frontier, S.OR_AND, mask=visited, complement=True,
                   impl=impl)


def bfs_levels(A_T, seeds, n: int, max_iter: int, impl: str = "auto"):
    """Levels (n, F): hop distance from each seed column; +inf if unreached."""
    frontier = seeds_to_frontier(seeds, n)
    levels = jnp.where(frontier > 0, 0.0, jnp.inf).astype(jnp.float32)

    def cond(state):
        t, frontier, _ = state
        return jnp.logical_and(t < max_iter, jnp.any(frontier > 0))

    def body(state):
        t, frontier, levels = state
        visited = jnp.isfinite(levels).astype(jnp.float32)
        nxt = bfs_step(A_T, frontier, visited, impl=impl)
        levels = jnp.where(nxt > 0, t + 1.0, levels)
        return t + 1.0, nxt, levels

    _, _, levels = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), frontier, levels))
    return levels


def khop_counts(A_T, seeds, n: int, k: int, impl: str = "auto") -> jnp.ndarray:
    """TigerGraph k-hop benchmark semantics: |{v : 1 <= dist(seed, v) <= k}|."""
    levels = bfs_levels(A_T, seeds, n, max_iter=k, impl=impl)
    inrange = jnp.logical_and(levels >= 1.0, levels <= float(k))
    return jnp.sum(inrange.astype(jnp.int32), axis=0)
