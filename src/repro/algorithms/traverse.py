"""BFS / k-hop over the boolean semiring — the paper's benchmark workload.

`MATCH (a)-[:R*1..k]->(b) WHERE id(a)=seed RETURN count(DISTINCT b)` lowers to
exactly `khop_counts`: k masked or_and hops with a complemented visited mask,
batched over seeds in the frontier's F dimension (the threadpool analog:
one column == one concurrent query).

Every entry point takes the graph's adjacency (a Graph, Relation, GBMatrix, or
raw storage) and pulls along out-edges through the handle's cached transpose
(`desc.transpose_a`) — callers never hand-pass `A_T`, and the execution policy
is whatever the handle resolved at construction. That includes a mesh: hand
in a sharded handle (`grb.distribute(rel.A, mesh)`) and the same loop runs
distributed — each hop's mxm lowers to one frontier all-gather plus local
gather-reduce (distr.graph2d), with zero sharding arguments here.

Frontiers wider than `grb.AUTO_PACK_MIN_WIDTH` ride the bitmap-packed
boolean form *word-resident*: the loops below thread the packed uint32
frontier (and visited mask) straight through the hop ``while_loop`` carry
via `grb.mxm_words` — one pack at loop entry, word-wise visited blends
per hop, one unpack at exit — instead of packing/unpacking at every
`grb.mxm` call boundary. Bit-identical results, 32x less frontier traffic,
and on a mesh a 32x smaller per-hop all-gather that never touches the host
(core.bitmap, docs/API.md §Bitmap, §Transfer-accounting). Narrow frontiers
and BSR/delta adjacency (no packed lowering) keep the plain 0/1 float
loop — `grb.words_route_ok` is the gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitmap, grb, semiring as S
from repro.core.grb import Descriptor


def seeds_to_frontier(seeds, n: int) -> jnp.ndarray:
    """(F,) seed vertex ids -> one-hot (n, F) frontier matrix."""
    seeds = jnp.asarray(seeds)
    return (jax.nn.one_hot(seeds, n, dtype=jnp.float32)).T


def bfs_step(A, frontier: jnp.ndarray, visited: jnp.ndarray) -> jnp.ndarray:
    """next<!visited> = A^T (x)_or_and frontier  — one traversal hop."""
    d = Descriptor(mask=visited, complement=True, transpose_a=True)
    return grb.mxm(A, frontier, S.OR_AND, d)


def _bfs_levels_words(A, frontier: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Word-resident BFS: the frontier and visited set live as packed uint32
    words across hops; the only per-hop unpack is the level stamp (a device
    op — nothing crosses to the host)."""
    f = frontier.shape[1]
    fw = bitmap.pack(frontier)
    vw = fw
    levels = jnp.where(frontier > 0, 0.0, jnp.inf).astype(jnp.float32)

    def cond(state):
        t, fw, _, _ = state
        return jnp.logical_and(t < iters, jnp.any(fw != 0))

    def body(state):
        t, fw, vw, levels = state
        nw = bitmap.word_andnot(
            grb.mxm_words(A, fw, transpose_a=True), vw)
        levels = jnp.where(bitmap.unpack(nw, f) > 0, t + 1.0, levels)
        return t + 1.0, nw, bitmap.word_or(vw, nw), levels

    _, _, _, levels = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), fw, vw, levels))
    return levels


def bfs_levels(A, seeds, max_iter: int = 0, rel=None):
    """Levels (n, F): hop distance from each seed column; +inf if unreached."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    iters = max_iter or n
    frontier = seeds_to_frontier(seeds, n)
    if A.nvals == 0:
        # zero-edge adjacency: the frontier empties after hop 0 — the
        # levels are fully determined by the seeds, so don't trace a hop
        # loop whose condition is false on entry
        return jnp.where(frontier > 0, 0.0, jnp.inf).astype(jnp.float32)
    if grb.words_route_ok(A, frontier.shape[1]):
        return _bfs_levels_words(A, frontier, iters)
    levels = jnp.where(frontier > 0, 0.0, jnp.inf).astype(jnp.float32)

    def cond(state):
        t, frontier, _ = state
        return jnp.logical_and(t < iters, jnp.any(frontier > 0))

    def body(state):
        t, frontier, levels = state
        visited = jnp.isfinite(levels).astype(jnp.float32)
        nxt = bfs_step(A, frontier, visited)
        levels = jnp.where(nxt > 0, t + 1.0, levels)
        return t + 1.0, nxt, levels

    _, _, levels = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), frontier, levels))
    return levels


def _reach_words(A, fw: jnp.ndarray, iters: int,
                 both_directions: bool = False) -> jnp.ndarray:
    """Visited words after up-to-``iters`` or_and hops from packed frontier
    ``fw`` — the fully word-resident reachability loop k-hop and WCC share:
    no unpack anywhere in the carry, so a sharded adjacency runs the whole
    closure on the mesh."""
    def cond(state):
        t, fw, _ = state
        return jnp.logical_and(t < iters, jnp.any(fw != 0))

    def body(state):
        t, fw, vw = state
        nw = grb.mxm_words(A, fw, transpose_a=True)
        if both_directions:
            # (a & ~v) | (b & ~v) == (a | b) & ~v: one visited blend serves
            # both edge directions
            nw = bitmap.word_or(nw, grb.mxm_words(A, fw))
        nw = bitmap.word_andnot(nw, vw)
        return t + 1, nw, bitmap.word_or(vw, nw)

    _, _, vw = jax.lax.while_loop(cond, body, (jnp.int32(0), fw, fw))
    return vw


def khop_counts(A, seeds, k: int, rel=None) -> jnp.ndarray:
    """TigerGraph k-hop benchmark semantics: |{v : 1 <= dist(seed, v) <= k}|."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    frontier = seeds_to_frontier(seeds, n)
    f = frontier.shape[1]
    if A.nvals == 0:
        # zero-edge adjacency: nothing is within 1..k of anything
        return jnp.zeros((f,), dtype=jnp.int32)
    if grb.words_route_ok(A, f):
        # reached-within-k minus the seed itself: levels never stamp a seed
        # above 0, so the seed column contributes exactly its own bit
        fw = bitmap.pack(frontier)
        vw = _reach_words(A, fw, k)
        counts = (bitmap.reduce_or_columns(vw, f)
                  - bitmap.reduce_or_columns(fw, f))
        return counts.astype(jnp.int32)
    levels = bfs_levels(A, seeds, max_iter=k, rel=rel)
    inrange = jnp.logical_and(levels >= 1.0, levels <= float(k))
    return jnp.sum(inrange.astype(jnp.int32), axis=0)
