"""k-truss — the Graphulo formulation on the masked SpGEMM kernel.

The k-truss of an undirected graph is the maximal subgraph in which every
edge closes at least k-2 triangles. Graphulo (PAPERS.md) reduces one
peeling round to two sparse primitives the `grb` surface now has:

  support<A> = A (x)_plus_pair A     # masked SpGEMM: common-neighbor count
                                     # computed ONLY on A's stored edges
  A'         = select(support >= k-2)

iterated to fixpoint (the pattern shrinks monotonically, so it terminates).
On a BSR-backed handle every step stays sparse: the support matrix comes
out of the two-phase BSR x BSR SpGEMM with the structural mask <A> pruning
output tiles symbolically, and the select prunes emptied tiles on
reassembly — no ``to_dense()`` anywhere on the hot path (pinned by a
densification-counter test). Dense handles run the same recurrence through
the dense pipeline; ELL handles are reblocked to BSR (COO relabeling, still
sparse) first. `benchmarks/bench_ktruss.py` races the two formulations.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import grb, semiring as S
from repro.core.bsr import BSR, as_bsr
from repro.core.grb import Descriptor, GBMatrix


def ktruss(A, k: int, rel: Optional[str] = None,
           max_iter: Optional[int] = None) -> GBMatrix:
    """Edges of the k-truss, values = final triangle support per edge.

    A: Graph / Relation / GBMatrix / raw storage of a symmetric adjacency.
    Self-loops are dropped up front (they would inflate support counts with
    diagonal walk terms). Returns a GBMatrix whose stored pattern is the
    truss's edge set and whose values are each surviving edge's support
    (common-neighbor count within the truss). k <= 2 returns the input
    unchanged (every edge is trivially in a 2-truss).
    """
    A = grb.matrix(A, rel)
    n, m = A.shape
    if n != m:
        raise ValueError(f"ktruss needs a square adjacency, got {A.shape}")
    if k <= 2:
        return A
    if A.fmt == "ell":          # sparse-to-sparse reblock, no densification
        A = GBMatrix(as_bsr(A.store, 128),
                     impl="auto" if A.auto else A.impl)
    # self-loops would add spurious diagonal walk terms (A[i,i] * A[i,j]) to
    # the plus_pair product, inflating support; drop them up front (a
    # host-side COO filter on the sparse path — no densification)
    if A.fmt == "bsr":
        r, c, v = A.store.to_coo()
        loops = r == c
        if loops.any():
            A = GBMatrix(BSR.from_coo(r[~loops], c[~loops], v[~loops],
                                      A.shape, block=A.store.block),
                         impl="auto" if A.auto else A.impl)
    else:
        A = GBMatrix(A.store * (1.0 - jnp.eye(n, dtype=jnp.float32)))
    need = float(k - 2)
    rounds = 0
    while True:
        # plus_pair counts common neighbors; the mask <A> restricts both the
        # symbolic schedule and the element pattern to current edges
        C = grb.mxm(A, A, S.PLUS_PAIR, Descriptor(mask=A))
        if not isinstance(C, GBMatrix):
            C = GBMatrix(C)     # dense pipeline returns a raw array
        T = grb.select(lambda s: s >= need, C)
        if not isinstance(T, GBMatrix):
            T = GBMatrix(T)
        rounds += 1
        if T.nvals == A.nvals or T.nvals == 0:
            return T
        if max_iter is not None and rounds >= max_iter:
            return T
        A = T
