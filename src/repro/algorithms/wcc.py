"""Weakly-connected components on boolean frontiers (min-seed labeling).

The classic min-label propagation pulls numeric labels over min_plus — a
tropical semiring that can never ride the bitmap-packed frontier path. This
formulation keeps the *labels* host-side and does all the graph work as
or_and reachability closures, so WCC's inner loop is the same packed
boolean mxm BFS and k-hop use (core.bitmap, `grb.AUTO_PACK_MIN_WIDTH`):

  1. take the `batch` smallest unlabeled vertex ids as seed columns,
  2. run an undirected reachability closure (both directions per hop,
     complemented visited mask) to fixpoint — each column is its seed's
     whole weak component,
  3. label every member of a column with the column's minimum member id.

Step 3 makes the result *identical* to min-label propagation: a closure
column contains the full component, so its minimum member IS the
component's minimum id, regardless of which seeds were chosen. Seeds that
share a component produce identical columns and agree on the label.

Takes a Graph/Relation/GBMatrix like every algorithm here; hand in a
sharded handle (`grb.distribute`) and the closure hops lower to mesh
collectives with packed all-gathers, unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap, grb, semiring as S
from repro.core.grb import Descriptor
from repro.algorithms.traverse import _reach_words, seeds_to_frontier


def _closure(A: grb.GBMatrix, seeds, max_iter: int) -> jnp.ndarray:
    """(n, F) 0/1 closure: column j is everything weakly reachable from
    seeds[j] (seed included) — or_and hops in both edge directions until
    the frontier empties. Wide-enough closures run word-resident: the
    packed frontier/visited words thread straight through the hop loop
    (one pack in, one unpack out — `traverse._reach_words`)."""
    n = A.shape[0]
    iters = max_iter or n
    frontier = seeds_to_frontier(seeds, n)
    if grb.words_route_ok(A, frontier.shape[1]):
        vw = _reach_words(A, bitmap.pack(frontier), iters,
                          both_directions=True)
        return bitmap.unpack(vw, frontier.shape[1])

    def cond(state):
        t, fr, _ = state
        return jnp.logical_and(t < iters, jnp.any(fr > 0))

    def body(state):
        t, fr, visited = state
        d = Descriptor(mask=visited, complement=True)
        nxt = jnp.maximum(
            grb.mxm(A, fr, S.OR_AND, d.with_(transpose_a=True)),
            grb.mxm(A, fr, S.OR_AND, d))
        return t + 1, nxt, jnp.maximum(visited, nxt)

    _, _, visited = jax.lax.while_loop(
        cond, body, (jnp.int32(0), frontier, frontier))
    return visited


def wcc(A, max_iter: int = 0, rel=None, batch: int = 128) -> jnp.ndarray:
    """Component labels (n,) int32: each vertex gets the minimum vertex id
    of its weak component — the same labels min-label propagation yields.
    `batch` seeds traverse per closure (one frontier matrix column each);
    `max_iter` bounds hops per closure (0 = diameter-safe n)."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    if A.nvals == 0:
        # zero-edge adjacency: every vertex is an isolated singleton. The
        # pre-labeling below would reach the same labels, but only after
        # tracing the or-reduces — short-circuit instead of compiling
        # closure machinery that can never run a hop
        return jnp.asarray(np.arange(n, dtype=np.int32))
    labels = np.full(n, -1, dtype=np.int64)
    # isolated vertices (no stored entry in their row or column) are their
    # own singleton components — label them up front so the closure loop
    # never spends a round on them (power-law generators leave many)
    if A.fmt == "dense":
        D = np.asarray(A.store) != 0
        iso = ~(D.any(axis=1) | D.any(axis=0))
    else:
        # sparse/sharded "or" reduce is any-stored (docs/API.md §eWise)
        iso = (np.asarray(grb.reduce(A, S.OR, axis=1)) == 0) & \
            (np.asarray(grb.reduce(A, S.OR, axis=0)) == 0)
    labels[iso] = np.nonzero(iso)[0]
    while True:
        unlabeled = np.nonzero(labels < 0)[0]
        if len(unlabeled) == 0:
            break
        seeds = unlabeled[:batch]
        reach = np.asarray(_closure(A, seeds, max_iter)) > 0
        for j in range(reach.shape[1]):
            members = reach[:, j]
            labels[members] = int(np.flatnonzero(members)[0])
    return jnp.asarray(labels.astype(np.int32))
