"""Weakly-connected components by min-label propagation over min_plus.

label'_i = min(label_i, min_{j in N(i)} label_j); the min over neighbors is a
min_plus pull with unit weights followed by a -1 shift (unit weights because
0-weights are not storable in tropical tile format). Both directions come
from one adjacency handle — the in-neighbor pull uses the cached transpose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grb, semiring as S


def wcc(A, max_iter: int = 0, rel=None) -> jnp.ndarray:
    A = grb.matrix(A, rel)
    n = A.shape[0]
    labels = jnp.arange(n, dtype=jnp.float32)
    iters = max_iter or n

    def step(labels, d):
        pulled = grb.mxv(A, labels, S.MIN_PLUS, d)
        return jnp.minimum(labels, pulled - 1.0)

    def cond(state):
        t, labels, changed = state
        return jnp.logical_and(t < iters, changed)

    def body(state):
        t, labels, _ = state
        new = step(labels, grb.TRANSPOSE_A)    # pull from in-neighbors
        new = step(new, grb.NULL)              # and out-neighbors (undirected)
        return t + 1, new, jnp.any(new < labels)

    _, labels, _ = jax.lax.while_loop(cond, body, (0, labels, True))
    return labels.astype(jnp.int32)
