"""Weakly-connected components by min-label propagation over min_plus.

label'_i = min(label_i, min_{j in N(i)} label_j); the min over neighbors is a
min_plus vxm with unit weights followed by a -1 shift (unit weights because
0-weights are not storable in tropical tile format).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops, semiring as S


def wcc(A_T, A, n: int, max_iter: int = 0, impl: str = "auto") -> jnp.ndarray:
    labels = jnp.arange(n, dtype=jnp.float32)
    iters = max_iter or n

    def step(A_dir, labels):
        pulled = ops.mxm(A_dir, labels[:, None], S.MIN_PLUS, impl=impl)[:, 0]
        return jnp.minimum(labels, pulled - 1.0)

    def cond(state):
        t, labels, changed = state
        return jnp.logical_and(t < iters, changed)

    def body(state):
        t, labels, _ = state
        new = step(A_T, labels)     # pull from in-neighbors
        new = step(A, new)          # and out-neighbors (undirected closure)
        return t + 1, new, jnp.any(new < labels)

    _, labels, _ = jax.lax.while_loop(cond, body, (0, labels, True))
    return labels.astype(jnp.int32)
