"""Label-propagation community detection on label-indicator frontiers.

Synchronous CDLP (the LDBC Graphalytics rule): every vertex adopts the
most frequent label among its neighbors' current labels — in BOTH edge
directions, plus its own vote — ties broken toward the smallest label,
iterated until no label moves (or ``max_iter``). The self-vote lets a
vertex keep its label absent strictly stronger evidence and lets dense
regions hold their own label against a bridge — which is what makes this
community detection rather than component labeling. Like every
synchronous CDLP it is not a contraction everywhere: a bare 2-clique
trades labels forever (both members see 2 votes for the other's label vs
1 for their own) and exits at ``max_iter`` — the LDBC rule accepts that;
cliques of size >= 3 converge (tests/test_algo_suite.py sweeps it).

This rides the WCC machinery: like `wcc`, the labels live host-side and
ALL graph work is batched column sweeps over the adjacency — here the
columns are label indicators instead of reachability frontiers, and the
per-hop op is a plus_pair vote count instead of an or_and closure:

  votes[v, c] = |{w : (v,w) or (w,v) stored, label(w) = c}|

chunked `batch` labels at a time (the same knob as `wcc`'s seed batch),
with a running (best_count, best_label) fold across chunks. Structural
plus_pair counts ignore edge values, and on a mesh the per-chunk counts
psum as small integers — the sharded labels are bit-identical to local
(tests/test_algo_suite.py pins it, along with the zero-transfer delta).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import grb, semiring as S
from repro.core.grb import Descriptor


def label_propagation(A, max_iter: int = 50, rel=None,
                      batch: int = 256) -> jnp.ndarray:
    """Community labels (n,) int32; initial label = vertex id, so a
    surviving label is always the id of some member of its community.
    Deterministic: synchronous updates, min-label tie-break."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    labels = np.arange(n, dtype=np.int64)
    if A.nvals == 0 or n == 0:
        # zero-edge adjacency: nobody receives a vote — every vertex is
        # its own community; skip tracing any vote sweep
        return jnp.asarray(labels.astype(np.int32))
    for _ in range(max_iter):
        uniq, inv = np.unique(labels, return_inverse=True)
        best_cnt = np.zeros(n, dtype=np.float64)
        best_lab = labels.copy()            # no votes at all -> keep own
        for c0 in range(0, len(uniq), batch):
            width = min(batch, len(uniq) - c0)
            onehot = np.zeros((n, width), dtype=np.float32)
            sel = (inv >= c0) & (inv < c0 + width)
            onehot[np.nonzero(sel)[0], inv[sel] - c0] = 1.0
            L = jnp.asarray(onehot)
            V = grb.mxm(A, L, S.PLUS_PAIR, Descriptor(transpose_a=True))
            V = V + grb.mxm(A, L, S.PLUS_PAIR)
            Vn = np.asarray(V) + onehot     # + self-vote
            cmax = Vn.max(axis=1)
            # uniq is sorted, so the first argmax column IS the smallest
            # label with the chunk's top count
            lab = uniq[c0 + np.argmax(Vn >= cmax[:, None], axis=1)]
            better = (cmax > best_cnt) | ((cmax == best_cnt) & (cmax > 0)
                                          & (lab < best_lab))
            best_lab = np.where(better, lab, best_lab)
            best_cnt = np.maximum(best_cnt, cmax)
        if np.array_equal(best_lab, labels):
            break
        labels = best_lab
    return jnp.asarray(labels.astype(np.int32))
