"""Betweenness and closeness centrality — batched multi-source Brandes.

Brandes' algorithm splits betweenness into a forward BFS that counts
shortest paths (sigma) and a backward sweep that accumulates dependencies
(delta) down the BFS DAG. Both phases are one semiring mxm per hop over a
multi-source frontier matrix, so the whole computation batches over sources
exactly like the k-hop benchmark batches over queries: column j of every
(n, F) carry belongs to source j.

  levels  or_and BFS (`traverse.bfs_levels`) — word-resident across hops
          wherever `grb.words_route_ok` says the packed uint32 route
          applies (dense/ELL/BitELL/sharded at width >= policy), the same
          `_reach_words`-style loop PR 8 built
  sigma   plus_times hops masked to `levels == t+1`: path counts only
          accumulate along BFS-DAG edges
  delta   the Brandes recurrence pulled backward one level at a time:
          delta[v] += sigma[v] * sum_w A[v,w] (1 + delta[w]) / sigma[w]
          for w exactly one level below v

Everything is mxm + ewise on device carries inside lax loops: no
``to_dense()``, no host transfers — a sharded handle (`grb.distribute`)
runs both phases as mesh collectives unchanged, and the BSR path never
touches the densify counter (tests/test_algo_suite.py pins both).

Structural semantics: edge values are treated as unit (path *counts*);
hand in a 0/1 adjacency — every datagen graph qualifies. Closeness uses
the Wasserman-Faust formula, so disconnected graphs score per reachable
set instead of collapsing to zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import grb, semiring as S
from repro.core.grb import Descriptor
from repro.algorithms.traverse import bfs_levels, seeds_to_frontier

# Sources per batched Brandes sweep (and the closeness BFS batch). Measured
# on the XLA-CPU reference host by benchmarks/bench_algos.py: per-source
# cost keeps dropping up to ~128 frontier columns (4 packed words of
# sources amortize one adjacency sweep), flat beyond — and 128 matches the
# WCC closure batch, so the two share compiled sweep shapes.
# `make calibrate` re-measures the crossover (calibrate_centrality_batch).
AUTO_CENTRALITY_BATCH = 128


def brandes_parts(A, seeds, rel=None) -> jnp.ndarray:
    """(n, F) per-source Brandes dependency columns: entry [v, j] is the
    dependency of source ``seeds[j]`` on vertex v (its own row zeroed, as
    Brandes excludes the source). Summing columns gives betweenness over
    that source set — the query layer batches many CALLs through this and
    sums each member's own slice."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    seeds = np.asarray(seeds, dtype=np.int64)
    f = len(seeds)
    if f == 0 or A.nvals == 0:
        # zero-edge adjacency: no vertex sits on any path — skip tracing
        # the zero-trip hop loops entirely
        return jnp.zeros((n, f), dtype=jnp.float32)
    levels = bfs_levels(A, seeds)
    sigma0 = seeds_to_frontier(seeds, n)

    def fwd_cond(state):
        t, _, frontier = state
        return jnp.logical_and(t < n, jnp.any(frontier > 0))

    def fwd_body(state):
        t, sigma, frontier = state
        nxt = grb.mxm(A, frontier, S.PLUS_TIMES, Descriptor(transpose_a=True))
        nxt = jnp.where(levels == t + 1.0, nxt, 0.0)
        return t + 1.0, sigma + nxt, nxt

    _, sigma, _ = jax.lax.while_loop(
        fwd_cond, fwd_body, (jnp.float32(0.0), sigma0, sigma0))

    finite = jnp.isfinite(levels)
    dmax = jnp.max(jnp.where(finite, levels, 0.0))

    def bwd_cond(state):
        d, _ = state
        return d > 0.5

    def bwd_body(state):
        d, delta = state
        # sigma > 0 wherever levels is finite; the maximum() only guards
        # unreached rows the where() already zeroes
        coef = jnp.where(levels == d,
                         (1.0 + delta) / jnp.maximum(sigma, 1.0), 0.0)
        pulled = grb.mxm(A, coef, S.PLUS_TIMES)
        delta = delta + jnp.where(levels == d - 1.0, sigma * pulled, 0.0)
        return d - 1.0, delta

    _, delta = jax.lax.while_loop(
        bwd_cond, bwd_body, (dmax, jnp.zeros((n, f), dtype=jnp.float32)))
    return jnp.where(levels > 0.0, delta, 0.0)


def betweenness(A, sources=None, rel=None,
                batch: int = AUTO_CENTRALITY_BATCH) -> jnp.ndarray:
    """Betweenness centrality (n,) float32 over shortest paths from
    ``sources`` (default: every vertex — exact directed betweenness).
    A subset gives source-sampled betweenness: the same dependency sums
    restricted to those sources; the matching oracle restricts alike."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    if sources is None:
        sources = np.arange(n)
    sources = np.asarray(sources, dtype=np.int64)
    bc = jnp.zeros((n,), dtype=jnp.float32)
    if len(sources) == 0 or A.nvals == 0:
        return bc
    for c0 in range(0, len(sources), batch):
        bc = bc + jnp.sum(brandes_parts(A, sources[c0:c0 + batch]), axis=1)
    return bc


def closeness_from_levels(levels: jnp.ndarray) -> jnp.ndarray:
    """(F,) Wasserman-Faust closeness per BFS-level column:
    ((r-1)/(n-1)) * ((r-1)/sum_of_distances) with r the reachable count
    (the source included at distance 0); 0.0 when nothing is reachable."""
    n = levels.shape[0]
    finite = jnp.isfinite(levels)
    r = jnp.sum(finite.astype(jnp.float32), axis=0)
    tot = jnp.sum(jnp.where(finite, levels, 0.0), axis=0)
    denom = float(max(n - 1, 1)) * jnp.where(tot > 0.0, tot, 1.0)
    return jnp.where(tot > 0.0, (r - 1.0) ** 2 / denom, 0.0)


def closeness(A, sources=None, rel=None,
              batch: int = AUTO_CENTRALITY_BATCH) -> jnp.ndarray:
    """Closeness centrality (F,) float32 of each source vertex, over
    outgoing BFS distances (default sources: every vertex)."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    if sources is None:
        sources = np.arange(n)
    sources = np.asarray(sources, dtype=np.int64)
    if len(sources) == 0:
        return jnp.zeros((0,), dtype=jnp.float32)
    if A.nvals == 0:
        return jnp.zeros((len(sources),), dtype=jnp.float32)
    outs = [closeness_from_levels(bfs_levels(A, sources[c0:c0 + batch]))
            for c0 in range(0, len(sources), batch)]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)
