"""Single-source shortest paths = Bellman-Ford over the min_plus semiring.

Zero-weight edges are carried correctly by every *structural* storage kind:
ELL stores them mask-true, and BSR builds a per-entry structural mask
(``emask``) whenever explicit 0.0 values occur, so the tropical matmul
relaxes through them instead of rendering them as the +inf identity (the
historical tile-storage caveat, now closed — tests/test_sssp.py pins a
zero-weight golden). Only a *dense* adjacency array inherently cannot
express a stored 0.0 (dense 0.0 == absent by convention); build sparse for
zero-weight graphs.

Takes the graph's adjacency (Graph / Relation / GBMatrix / raw); relaxation
pulls along in-edges through the handle's cached transpose. Sharded handles
run the same loop on a mesh (min_plus has no scatter-reduce collective, so
the unlinked-transpose lowering combines row blocks with pmin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grb, semiring as S


def sssp(A, seeds, max_iter: int = 0, rel=None):
    """dist (n, F): tropical distance from each seed column."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    seeds = jnp.asarray(seeds)
    f = seeds.shape[0]
    dist = jnp.full((n, f), jnp.inf, dtype=jnp.float32)
    dist = dist.at[seeds, jnp.arange(f)].set(0.0)
    iters = max_iter or n - 1

    def cond(state):
        t, dist, changed = state
        return jnp.logical_and(t < iters, changed)

    def body(state):
        t, dist, _ = state
        relaxed = grb.mxm(A, dist, S.MIN_PLUS, grb.TRANSPOSE_A)
        new = jnp.minimum(dist, relaxed)
        return t + 1, new, jnp.any(new < dist)

    _, dist, _ = jax.lax.while_loop(cond, body, (0, dist, True))
    return dist
