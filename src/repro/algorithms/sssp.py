"""Single-source shortest paths = Bellman-Ford over the min_plus semiring.

Tropical-format caveat (documented in DESIGN.md): edge weights of exactly 0.0
are indistinguishable from "absent" in tile storage; generators use w >= 0.5.

Takes the graph's adjacency (Graph / Relation / GBMatrix / raw); relaxation
pulls along in-edges through the handle's cached transpose. Sharded handles
run the same loop on a mesh (min_plus has no scatter-reduce collective, so
the unlinked-transpose lowering combines row blocks with pmin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grb, semiring as S


def sssp(A, seeds, max_iter: int = 0, rel=None):
    """dist (n, F): tropical distance from each seed column."""
    A = grb.matrix(A, rel)
    n = A.shape[0]
    seeds = jnp.asarray(seeds)
    f = seeds.shape[0]
    dist = jnp.full((n, f), jnp.inf, dtype=jnp.float32)
    dist = dist.at[seeds, jnp.arange(f)].set(0.0)
    iters = max_iter or n - 1

    def cond(state):
        t, dist, changed = state
        return jnp.logical_and(t < iters, changed)

    def body(state):
        t, dist, _ = state
        relaxed = grb.mxm(A, dist, S.MIN_PLUS, grb.TRANSPOSE_A)
        new = jnp.minimum(dist, relaxed)
        return t + 1, new, jnp.any(new < dist)

    _, dist, _ = jax.lax.while_loop(cond, body, (0, dist, True))
    return dist
