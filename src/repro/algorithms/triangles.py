"""Triangle counting — C<A> = A (x)_plus_pair A, sum(C)/6 (GraphChallenge;
listed as RedisGraph future work, implemented here).

Requires a symmetric (undirected) adjacency. Both operands stay sparse: for
BSR-backed handles `grb.mxm` routes through the two-phase BSR x BSR SpGEMM
kernel with the structural mask <A> applied block-wise during accumulation,
so C never materializes as a dense product (dense/ELL handles still take the
dense pipeline inside `grb.mxm`). BitELL-backed handles skip the semiring
surface entirely: the masked plus_pair product is a neighborhood
intersection, which on bit-tiles is word-AND + SWAR popcount over tile
pairs (`core.bitadj.triangle_count`) — no float product at any size.
`benchmarks/bench_triangles.py` reports the dense-vs-SpGEMM crossover and
`benchmarks/bench_bitadj.py` the bit-route speedup.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitadj as _bitadj
from repro.core import grb, semiring as S
from repro.core.grb import Descriptor


def triangle_count(A, rel=None) -> jnp.ndarray:
    A = grb.matrix(A, rel)
    if A.fmt in ("bitadj", "bitshard"):
        return _bitadj.triangle_count(A.store).astype(jnp.int32)
    C = grb.mxm(A, A, S.PLUS_PAIR, Descriptor(mask=A))
    return (grb.reduce(C, S.PLUS) / 6.0).astype(jnp.int32)
