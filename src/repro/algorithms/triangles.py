"""Triangle counting — C<A> = A (x)_plus_pair A, sum(C)/6 (GraphChallenge;
listed as RedisGraph future work, implemented here).

Requires a symmetric (undirected) adjacency. The B operand is densified —
fine at bench scale; a BSR x BSR SpGEMM kernel is the documented scale-out
path (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ops, semiring as S


def triangle_count(A, impl: str = "auto") -> jnp.ndarray:
    dense = A.to_dense() if hasattr(A, "to_dense") else A
    mask = (dense != 0).astype(jnp.int8)
    C = ops.mxm(A, dense, S.PLUS_PAIR, mask=mask, impl=impl)
    return (jnp.sum(C) / 6.0).astype(jnp.int32)
