"""Triangle counting — C<A> = A (x)_plus_pair A, sum(C)/6 (GraphChallenge;
listed as RedisGraph future work, implemented here).

Requires a symmetric (undirected) adjacency. The B operand is densified —
fine at bench scale; a BSR x BSR SpGEMM kernel is the documented scale-out
path (EXPERIMENTS.md §Perf). The structural mask rides in the Descriptor.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import grb, semiring as S
from repro.core.grb import Descriptor


def triangle_count(A, rel=None) -> jnp.ndarray:
    A = grb.matrix(A, rel)
    dense = A.to_dense()
    mask = (dense != 0).astype(jnp.int8)
    C = grb.mxm(A, dense, S.PLUS_PAIR, Descriptor(mask=mask))
    return (jnp.sum(C) / 6.0).astype(jnp.int32)
