"""PageRank via plus_times pulls (transpose descriptor) with dangling-mass
correction. Takes the graph's adjacency (Graph / Relation / GBMatrix / raw);
the pull direction comes from the handle's cached transpose. On a sharded
handle (grb.distribute) the identical loop runs on the mesh: the pull mxv
all-gathers the push vector over "data" when the transpose is linked, or
psum_scatters row blocks when it is not — this file stays sharding-free."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import grb, semiring as S


def pagerank(A, alpha: float = 0.85, iters: int = 50,
             rel=None) -> jnp.ndarray:
    A = grb.matrix(A, rel)
    n = A.shape[0]
    ones = jnp.ones((n, 1), dtype=jnp.float32)
    deg = grb.mxm(A, ones, S.PLUS_TIMES)[:, 0]                 # out-degree
    dangling = deg == 0
    inv_deg = jnp.where(dangling, 0.0, 1.0 / jnp.maximum(deg, 1e-30))

    def body(_, r):
        push = r * inv_deg
        pulled = grb.mxv(A, push, S.PLUS_TIMES, grb.TRANSPOSE_A)
        dmass = jnp.sum(jnp.where(dangling, r, 0.0)) / n
        return (1.0 - alpha) / n + alpha * (pulled + dmass)

    r0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    return jax.lax.fori_loop(0, iters, body, r0)
