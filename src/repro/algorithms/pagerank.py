"""PageRank via plus_times vxm (pull form) with dangling-mass correction."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops, semiring as S


def pagerank(A, A_T, n: int, alpha: float = 0.85, iters: int = 50,
             impl: str = "auto") -> jnp.ndarray:
    ones = jnp.ones((n, 1), dtype=jnp.float32)
    deg = ops.mxm(A, ones, S.PLUS_TIMES, impl=impl)[:, 0]      # out-degree
    dangling = deg == 0
    inv_deg = jnp.where(dangling, 0.0, 1.0 / jnp.maximum(deg, 1e-30))

    def body(_, r):
        push = r * inv_deg
        pulled = ops.mxm(A_T, push[:, None], S.PLUS_TIMES, impl=impl)[:, 0]
        dmass = jnp.sum(jnp.where(dangling, r, 0.0)) / n
        return (1.0 - alpha) / n + alpha * (pulled + dmass)

    r0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    return jax.lax.fori_loop(0, iters, body, r0)
